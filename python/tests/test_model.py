"""L2 correctness: model shapes, variants, training dynamics, serving path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref


def cfg_for(attn="dense", preset="tiny", **kw):
    return M.make_config(preset, attn, **kw)


def toks_for(cfg, batch=2, seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (batch, cfg.max_seq), 0, cfg.vocab
    ).astype(jnp.int32)


class TestConfig:
    def test_presets_valid(self):
        for preset in M.PRESETS:
            M.make_config(preset, "dense").validate()
            M.make_config(preset, "sfa", sparsity=4).validate()

    def test_variant_names(self):
        assert M.variant_name(cfg_for("dense")) == "dense"
        assert M.variant_name(cfg_for("sfa", sparsity=8)) == "sfa_k8"
        assert M.variant_name(cfg_for("short", short_d=32)) == "short_d32"
        assert M.variant_name(cfg_for("window", window=64)) == "window_w64"

    def test_sparsity_bounds_checked(self):
        with pytest.raises(AssertionError):
            M.make_config("tiny", "sfa", sparsity=1000)

    def test_short_qk_dim(self):
        c = cfg_for("short", short_d=16)
        assert c.qk_head_dim == 16
        assert cfg_for("dense").qk_head_dim == cfg_for("dense").d_head

    def test_param_count_reasonable(self):
        c = cfg_for()
        n = M.count_params(c)
        # tok_emb + pos_emb + 2 blocks + final ln, ~0.44M for tiny
        assert 3e5 < n < 6e5

    def test_gpt2_124m_param_count(self):
        """Paper Table 4: GPT-2 Small is ~124M params."""
        n = M.count_params(M.make_config("gpt2-124m", "dense"))
        assert 1.1e8 < n < 1.4e8


class TestForward:
    @pytest.mark.parametrize("attn,kw", [
        ("dense", {}), ("sfa", {"sparsity": 4}), ("short", {}), ("window", {}),
    ])
    def test_logits_shape_finite(self, attn, kw):
        cfg = cfg_for(attn, **kw)
        p = M.init_params(cfg, 0)
        t = toks_for(cfg)
        logits, _ = M.forward(cfg, p, t)
        assert logits.shape == (2, cfg.max_seq, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()

    def test_causality(self):
        """Perturbing a future token must not change earlier logits."""
        for attn, kw in [("dense", {}), ("sfa", {"sparsity": 4})]:
            cfg = cfg_for(attn, **kw)
            p = M.init_params(cfg, 1)
            t1 = toks_for(cfg, batch=1)
            t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab)
            l1, _ = M.forward(cfg, p, t1)
            l2, _ = M.forward(cfg, p, t2)
            np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)

    def test_sfa_pallas_equals_ref_path(self):
        """FlashSFA-kernel forward == densified-reference forward."""
        cfg_k = cfg_for("sfa", sparsity=4, use_pallas=True)
        cfg_r = cfg_for("sfa", sparsity=4, use_pallas=False)
        p = M.init_params(cfg_k, 2)
        t = toks_for(cfg_k)
        lk, _ = M.forward(cfg_k, p, t)
        lr, _ = M.forward(cfg_r, p, t)
        np.testing.assert_allclose(lk, lr, rtol=1e-4, atol=1e-4)

    def test_sfa_full_k_equals_dense(self):
        cfg_s = cfg_for("sfa", sparsity=64)  # k == d_head
        cfg_d = cfg_for("dense")
        p = M.init_params(cfg_d, 3)
        t = toks_for(cfg_d)
        ls, _ = M.forward(cfg_s, p, t)
        ld, _ = M.forward(cfg_d, p, t)
        np.testing.assert_allclose(ls, ld, rtol=1e-4, atol=1e-4)

    def test_window_matches_dense_when_window_covers_seq(self):
        cfg_w = cfg_for("window", window=10_000)
        cfg_d = cfg_for("dense")
        p = M.init_params(cfg_d, 4)
        t = toks_for(cfg_d)
        lw, _ = M.forward(cfg_w, p, t)
        ld, _ = M.forward(cfg_d, p, t)
        np.testing.assert_allclose(lw, ld, rtol=1e-5, atol=1e-5)

    def test_rope_variant_runs(self):
        cfg = cfg_for("sfa", sparsity=4, rope=True)
        p = M.init_params(cfg, 5)
        loss = M.lm_loss(cfg, p, toks_for(cfg))
        assert np.isfinite(float(loss))

    def test_rope_position_sensitivity(self):
        """With RoPE, shifting a bigram changes its prediction context."""
        cfg = cfg_for("dense", rope=True)
        p = M.init_params(cfg, 6)
        t = toks_for(cfg, batch=1)
        logits, _ = M.forward(cfg, p, t)
        assert np.isfinite(np.asarray(logits)).all()


class TestTraining:
    def test_loss_at_init_near_uniform(self):
        cfg = cfg_for()
        p = M.init_params(cfg, 0)
        loss = float(M.lm_loss(cfg, p, toks_for(cfg)))
        assert abs(loss - np.log(cfg.vocab)) < 0.5

    @pytest.mark.parametrize("attn,kw", [
        ("dense", {}), ("sfa", {"sparsity": 4}), ("short", {}),
    ])
    def test_train_step_reduces_loss(self, attn, kw):
        cfg = cfg_for(attn, **kw)
        p = M.init_params(cfg, 0)
        m = {k: jnp.zeros_like(v) for k, v in p.items()}
        v = {k: jnp.zeros_like(x) for k, x in p.items()}
        t = jnp.tile(jnp.arange(16, dtype=jnp.int32), (2, cfg.max_seq // 16))
        step, lr = jnp.float32(0), jnp.float32(3e-3)
        ts = jax.jit(lambda p, m, v, s, lr, t: M.train_step(cfg, p, m, v, s, lr, t))
        first = None
        for _ in range(6):
            p, m, v, step, loss = ts(p, m, v, step, lr, t)
            first = first if first is not None else float(loss)
        assert float(loss) < first - 0.5
        assert float(step) == 6.0

    def test_adamw_grad_clip_bounds_update(self):
        cfg = cfg_for()
        p = M.init_params(cfg, 0)
        g = {k: 1e6 * jnp.ones_like(v) for k, v in p.items()}
        m = {k: jnp.zeros_like(v) for k, v in p.items()}
        v = {k: jnp.zeros_like(x) for k, x in p.items()}
        p2, _, _ = M.adamw_update(p, g, m, v, jnp.float32(0), jnp.float32(1e-3))
        delta = max(
            float(jnp.max(jnp.abs(p2[k] - p[k]))) for k in p
        )
        assert delta < 1.0  # clipped + Adam-normalized

    def test_adapt_loss_regularizer_positive(self):
        cfg_s = cfg_for("sfa", sparsity=2)
        cfg_d = cfg_for("dense")
        p = M.init_params(cfg_s, 0)
        t = toks_for(cfg_s)
        base = float(M.lm_loss(cfg_s, p, t))
        tot = float(M.adapt_loss(cfg_s, cfg_d, p, t, jnp.float32(10.0)))
        assert tot > base  # sparse != dense at init, so reg > 0

    def test_adapt_loss_zero_lambda_equals_lm(self):
        cfg_s = cfg_for("sfa", sparsity=4)
        cfg_d = cfg_for("dense")
        p = M.init_params(cfg_s, 0)
        t = toks_for(cfg_s)
        np.testing.assert_allclose(
            float(M.adapt_loss(cfg_s, cfg_d, p, t, jnp.float32(0.0))),
            float(M.lm_loss(cfg_s, p, t)), rtol=1e-6,
        )

    def test_sfa_gradients_sparse_on_qk(self):
        """Per-row Q-grad (through wq) exists; STE keeps them finite."""
        cfg = cfg_for("sfa", sparsity=2)
        p = M.init_params(cfg, 0)
        g = jax.grad(lambda pp: M.lm_loss(cfg, pp, toks_for(cfg)))(p)
        for k, v in g.items():
            assert np.isfinite(np.asarray(v)).all(), k
        assert float(jnp.abs(g["l00.attn.wq"]).sum()) > 0


class TestServing:
    @pytest.mark.parametrize("attn,kw", [
        ("dense", {}), ("sfa", {"sparsity": 4}),
    ])
    def test_prefill_decode_matches_forward(self, attn, kw):
        cfg = cfg_for(attn, **kw)
        p = M.init_params(cfg, 3)
        B, S = 2, cfg.max_seq
        t = toks_for(cfg, batch=B, seed=1)
        plen = S // 2
        last, caches = M.prefill(
            cfg, p, t[:, :plen], jnp.full((B,), plen, jnp.int32)
        )
        full, _ = M.forward(cfg, p, t)
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(full[:, plen - 1]), rtol=2e-4, atol=2e-4
        )
        pos = plen
        for _ in range(4):
            logits, caches = M.decode_step(
                cfg, p, caches, t[:, pos], jnp.full((B,), pos, jnp.int32)
            )
            np.testing.assert_allclose(
                np.asarray(logits), np.asarray(full[:, pos]), rtol=2e-3, atol=2e-3
            )
            pos += 1

    def test_prefill_ragged_lengths(self):
        """Different true lengths in one batch gather the right logits."""
        cfg = cfg_for("dense")
        p = M.init_params(cfg, 4)
        S = cfg.max_seq // 2
        t = toks_for(cfg, batch=2, seed=2)[:, :S]
        lengths = jnp.array([S // 4, S], jnp.int32)
        last, _ = M.prefill(cfg, p, t, lengths)
        full, _ = M.forward(cfg, p, t)
        np.testing.assert_allclose(
            np.asarray(last[0]), np.asarray(full[0, S // 4 - 1]), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(last[1]), np.asarray(full[1, S - 1]), rtol=2e-4, atol=2e-4
        )

    def test_cache_flatten_roundtrip(self):
        for attn, kw in [("dense", {}), ("sfa", {"sparsity": 4})]:
            cfg = cfg_for(attn, **kw)
            p = M.init_params(cfg, 5)
            t = toks_for(cfg)
            _, caches = M.prefill(
                cfg, p, t[:, : cfg.max_seq // 2],
                jnp.full((2,), cfg.max_seq // 2, jnp.int32),
            )
            flat = M.flatten_caches(cfg, caches)
            names = M.cache_entry_names(cfg)
            assert len(flat) == len(names)
            rt = M.unflatten_caches(cfg, tuple(flat))
            for a, b in zip(caches, rt):
                assert set(a) == set(b)
                for k in a:
                    np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))

    def test_cache_shapes_match_prefill(self):
        cfg = cfg_for("sfa", sparsity=4)
        p = M.init_params(cfg, 6)
        B = 2
        t = toks_for(cfg, batch=B)
        _, caches = M.prefill(
            cfg, p, t[:, : cfg.max_seq // 2],
            jnp.full((B,), cfg.max_seq // 2, jnp.int32),
        )
        flat = M.flatten_caches(cfg, caches)
        for arr, (name, shape, dtype) in zip(flat, M.cache_shapes(cfg, B)):
            assert tuple(arr.shape) == shape, name
            assert ("i32" if arr.dtype == jnp.int32 else "f32") == dtype, name

    def test_sfa_cache_is_sparse(self):
        """SFA K-cache stores exactly k entries per (layer, head, pos)."""
        cfg = cfg_for("sfa", sparsity=4)
        p = M.init_params(cfg, 7)
        t = toks_for(cfg)
        _, caches = M.prefill(
            cfg, p, t[:, : cfg.max_seq // 2],
            jnp.full((2,), cfg.max_seq // 2, jnp.int32),
        )
        c = caches[0]
        assert c["k_vals"].shape[-1] == 4
        idx = np.asarray(c["k_idx"][:, :, : cfg.max_seq // 2])
        assert idx.min() >= 0 and idx.max() < cfg.qk_head_dim
        # per-position indices are distinct
        flat = idx.reshape(-1, 4)
        for row in flat[:64]:
            assert len(set(row.tolist())) == 4


class TestMemoryModel:
    def test_appendix_j_ratio(self):
        """Paper App. J: dense/CSR memory ratio ≈ 2d/(3k+4) for fp16/int8.

        Compare the K-cache only (V is identical in both variants).
        """
        dense = M.make_config("small", "dense")
        for k in (4, 8, 16):
            sfa = M.make_config("small", "sfa", sparsity=k)
            seq = 4096
            d = dense.qk_head_dim
            dense_k = M.kv_cache_bytes(dense, seq, s_val=2, s_idx=1) - \
                M.kv_cache_bytes(
                    M.make_config("small", "sfa", sparsity=0x7FFF)
                    if False else dense, 0)
            # Simpler: isolate K bytes directly.
            def k_bytes(cfg):
                total = M.kv_cache_bytes(cfg, seq, s_val=2, s_idx=1, s_ptr=4)
                v = cfg.n_layers * cfg.n_heads * seq * cfg.d_head * 2
                return total - v
            ratio = k_bytes(dense) / k_bytes(sfa)
            expected = 2 * d / (3 * k + 4)
            assert abs(ratio - expected) / expected < 0.05, (k, ratio, expected)
            del dense_k

    def test_sfa_saves_memory_when_k_below_two_thirds_d(self):
        dense = M.make_config("small", "dense")
        sfa_small = M.make_config("small", "sfa", sparsity=8)
        assert M.kv_cache_bytes(sfa_small, 1024, s_val=2, s_idx=1) < \
            M.kv_cache_bytes(dense, 1024, s_val=2, s_idx=1)

    def test_memory_monotone_in_seq(self):
        cfg = M.make_config("small", "sfa", sparsity=8)
        sizes = [M.kv_cache_bytes(cfg, s) for s in (128, 512, 2048)]
        assert sizes[0] < sizes[1] < sizes[2]
