"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes / sparsity / block sizes; assert_allclose
against the reference for forward AND straight-through backward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_sfa import flash_sfa, sfa_attention
from compile.kernels.topk import topk_pallas

jax.config.update("jax_enable_x64", False)

RTOL = 2e-5
ATOL = 2e-5


def rand(shape, seed, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ---------------------------------------------------------------------------
# ref.py self-consistency
# ---------------------------------------------------------------------------

class TestReferences:
    def test_topk_mask_counts(self):
        x = rand((17, 33), 0)
        for k in (1, 4, 33):
            m = ref.topk_mask(x, k)
            np.testing.assert_array_equal(np.asarray(m.sum(axis=1)), k)

    def test_topk_sparsify_keeps_largest(self):
        x = jnp.array([[3.0, -5.0, 1.0, 0.5]])
        np.testing.assert_allclose(
            ref.topk_sparsify(x, 2), jnp.array([[3.0, -5.0, 0.0, 0.0]])
        )

    def test_topk_codes_roundtrip(self):
        x = rand((16, 32), 1)
        vals, idx = ref.topk_codes(x, 8)
        dense = ref.densify(vals, idx, 32)
        np.testing.assert_allclose(dense, ref.topk_sparsify(x, 8), rtol=1e-6)

    def test_topk_codes_orders_by_magnitude(self):
        x = rand((8, 16), 2)
        vals, _ = ref.topk_codes(x, 5)
        mags = np.abs(np.asarray(vals))
        assert (np.diff(mags, axis=1) <= 1e-7).all()

    def test_full_k_equals_dense(self):
        """k = d must reduce SFA to dense attention exactly."""
        q, k_, v = rand((24, 16), 3), rand((24, 16), 4), rand((24, 16), 5)
        np.testing.assert_allclose(
            ref.sfa_attention_ref(q, k_, v, sparsity=16),
            ref.attention_ref(q, k_, v),
            rtol=1e-5, atol=1e-6,
        )

    def test_causal_mask_no_future_leak(self):
        """Changing future keys/values must not change past outputs."""
        q, k_, v = rand((32, 16), 6), rand((32, 16), 7), rand((32, 16), 8)
        o1 = ref.sfa_attention_ref(q, k_, v, sparsity=4)
        k2 = k_.at[20:].set(99.0)
        v2 = v.at[20:].set(-99.0)
        o2 = ref.sfa_attention_ref(q, k2, v2, sparsity=4)
        np.testing.assert_allclose(o1[:20], o2[:20], rtol=1e-6)

    def test_overlap_score_equals_matmul(self):
        """Masked k×k outer product == densified sparse matmul (Eq. 5)."""
        q, k_ = rand((20, 32), 9), rand((20, 32), 10)
        qv, qi = ref.topk_codes(q, 6)
        kv, ki = ref.topk_codes(k_, 6)
        s_overlap = ref.overlap_score_ref(qv, qi, kv, ki, 32)
        s_dense = (
            ref.topk_sparsify(q, 6) @ ref.topk_sparsify(k_, 6).T
        ) / jnp.sqrt(32.0)
        np.testing.assert_allclose(s_overlap, s_dense, rtol=1e-5, atol=1e-6)

    def test_softmax_rows_sum_to_one(self):
        q, k_ = rand((16, 8), 11), rand((16, 8), 12)
        s = ref.sfa_scores_ref(q, k_, sparsity=4)
        p = jax.nn.softmax(s, axis=-1)
        np.testing.assert_allclose(np.asarray(p.sum(axis=-1)), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# Pallas top-k vs reference
# ---------------------------------------------------------------------------

class TestTopkPallas:
    @pytest.mark.parametrize("n,d,k,br", [
        (64, 32, 4, 32), (64, 32, 8, 64), (128, 64, 16, 32),
        (32, 128, 2, 32), (64, 16, 16, 16),
    ])
    def test_matches_ref(self, n, d, k, br):
        x = rand((n, d), n + d + k)
        tv, ti = topk_pallas(x, k, br)
        rv, ri = ref.topk_codes(x, k)
        np.testing.assert_allclose(
            ref.densify(tv, ti, d), ref.densify(rv, ri, d), rtol=1e-6
        )

    def test_selects_all_when_k_equals_d(self):
        x = rand((32, 8), 13)
        tv, ti = topk_pallas(x, 8, 32)
        np.testing.assert_allclose(
            np.sort(np.asarray(ref.densify(tv, ti, 8)), axis=1),
            np.sort(np.asarray(x), axis=1), rtol=1e-6,
        )

    def test_signs_preserved(self):
        x = -jnp.abs(rand((32, 16), 14))  # all-negative input
        tv, _ = topk_pallas(x, 4, 32)
        assert (np.asarray(tv) < 0).all()

    def test_indices_unique_per_row(self):
        x = rand((64, 32), 15)
        _, ti = topk_pallas(x, 8, 32)
        ti = np.asarray(ti)
        for row in ti:
            assert len(set(row.tolist())) == 8

    def test_ste_gradient(self):
        x = rand((64, 32), 16)
        g_kernel = jax.grad(lambda a: jnp.sum(topk_pallas(a, 8, 32)[0] ** 3))(x)
        g_ref = jax.grad(lambda a: jnp.sum(ref.topk_codes(a, 8)[0] ** 3))(x)
        np.testing.assert_allclose(g_kernel, g_ref, rtol=1e-5, atol=1e-6)

    def test_gradient_zero_off_support(self):
        x = rand((32, 32), 17)
        g = jax.grad(lambda a: jnp.sum(topk_pallas(a, 4, 32)[0]))(x)
        mask = np.asarray(ref.topk_mask(x, 4))
        assert (np.asarray(g)[~mask] == 0).all()

    @settings(max_examples=20, deadline=None)
    @given(
        n_tiles=st.integers(1, 3),
        d=st.sampled_from([16, 32, 64, 128]),
        k=st.integers(1, 16),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, n_tiles, d, k, seed):
        k = min(k, d)
        n = 32 * n_tiles
        x = rand((n, d), seed)
        tv, ti = topk_pallas(x, k, 32)
        rv, ri = ref.topk_codes(x, k)
        np.testing.assert_allclose(
            ref.densify(tv, ti, d), ref.densify(rv, ri, d), rtol=1e-6
        )


# ---------------------------------------------------------------------------
# FlashSFA vs reference
# ---------------------------------------------------------------------------

class TestFlashSFA:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("n,d,k,dv", [
        (64, 64, 8, 64), (128, 128, 16, 64), (96, 32, 4, 32), (32, 64, 2, 128),
    ])
    def test_matches_ref(self, n, d, k, dv, causal):
        q, k_, v = rand((n, d), 1), rand((n, d), 2), rand((n, dv), 3)
        o = sfa_attention(q, k_, v, sparsity=k, causal=causal)
        o_ref = ref.sfa_attention_ref(q, k_, v, sparsity=k, causal=causal)
        np.testing.assert_allclose(o, o_ref, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("bq,bk", [(16, 16), (32, 64), (64, 32), (128, 128)])
    def test_block_size_invariance(self, bq, bk):
        """Output must not depend on the tiling schedule."""
        q, k_, v = rand((128, 64), 4), rand((128, 64), 5), rand((128, 64), 6)
        o = sfa_attention(q, k_, v, sparsity=8, block_q=bq, block_k=bk)
        o_ref = ref.sfa_attention_ref(q, k_, v, sparsity=8)
        np.testing.assert_allclose(o, o_ref, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("n", [33, 50, 65, 127])
    def test_non_divisible_lengths(self, n):
        q, k_, v = rand((n, 32), 7), rand((n, 32), 8), rand((n, 32), 9)
        o = sfa_attention(q, k_, v, sparsity=4)
        o_ref = ref.sfa_attention_ref(q, k_, v, sparsity=4)
        np.testing.assert_allclose(o, o_ref, rtol=RTOL, atol=ATOL)

    def test_cross_attention_shapes(self):
        """Non-causal with n_q != n_kv (encoder-decoder style)."""
        q = rand((40, 32), 10)
        k_, v = rand((72, 32), 11), rand((72, 16), 12)
        qv, qi = ref.topk_codes(q, 4)
        kv, ki = ref.topk_codes(k_, 4)
        o = flash_sfa(qv, qi, kv, ki, v, 32, False)
        o_ref = ref.sfa_attention_from_codes_ref(
            qv, qi, kv, ki, v, d_orig=32, causal=False
        )
        np.testing.assert_allclose(o, o_ref, rtol=RTOL, atol=ATOL)

    def test_causal_requires_equal_lengths(self):
        qv, qi = ref.topk_codes(rand((32, 32), 13), 4)
        kv, ki = ref.topk_codes(rand((64, 32), 14), 4)
        with pytest.raises(ValueError, match="n_q == n_kv"):
            flash_sfa(qv, qi, kv, ki, rand((64, 16), 15), 32, True)

    def test_no_future_leak(self):
        q = rand((64, 32), 16)
        k1, v1 = rand((64, 32), 17), rand((64, 32), 18)
        k2 = k1.at[40:].set(7.0)
        v2 = v1.at[40:].set(-7.0)
        o1 = sfa_attention(q, k1, v1, sparsity=4)
        o2 = sfa_attention(q, k2, v2, sparsity=4)
        np.testing.assert_allclose(o1[:40], o2[:40], rtol=1e-6)

    def test_extreme_logits_stable(self):
        """Online softmax must survive large-magnitude scores (no inf/nan)."""
        q, k_, v = rand((64, 32), 19, 30.0), rand((64, 32), 20, 30.0), rand((64, 32), 21)
        o = sfa_attention(q, k_, v, sparsity=8)
        assert np.isfinite(np.asarray(o)).all()
        o_ref = ref.sfa_attention_ref(q, k_, v, sparsity=8)
        np.testing.assert_allclose(o, o_ref, rtol=1e-4, atol=1e-4)

    def test_ste_gradients_match_ref(self):
        q, k_, v = rand((64, 64), 22), rand((64, 64), 23), rand((64, 64), 24)

        def loss_kernel(q, k_, v):
            return jnp.sum(sfa_attention(q, k_, v, sparsity=8) ** 2)

        def loss_ref(q, k_, v):
            return jnp.sum(ref.sfa_attention_ref(q, k_, v, sparsity=8) ** 2)

        g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k_, v)
        g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k_, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)

    def test_grad_zero_off_support(self):
        q, k_, v = rand((32, 32), 25), rand((32, 32), 26), rand((32, 32), 27)
        gq = jax.grad(
            lambda a: jnp.sum(sfa_attention(a, k_, v, sparsity=4))
        )(q)
        mask = np.asarray(ref.topk_mask(q, 4))
        assert (np.asarray(gq)[~mask] == 0).all()

    def test_vmap_heads(self):
        qh, kh, vh = rand((3, 64, 32), 28), rand((3, 64, 32), 29), rand((3, 64, 32), 30)
        f = lambda a, b, c: sfa_attention(a, b, c, sparsity=4)
        fr = lambda a, b, c: ref.sfa_attention_ref(a, b, c, sparsity=4)
        np.testing.assert_allclose(
            jax.vmap(f)(qh, kh, vh), jax.vmap(fr)(qh, kh, vh), rtol=RTOL, atol=ATOL
        )

    def test_jit_compatible(self):
        q, k_, v = rand((64, 32), 31), rand((64, 32), 32), rand((64, 32), 33)
        f = jax.jit(lambda a, b, c: sfa_attention(a, b, c, sparsity=4))
        np.testing.assert_allclose(
            f(q, k_, v), ref.sfa_attention_ref(q, k_, v, sparsity=4),
            rtol=RTOL, atol=ATOL,
        )

    def test_k_equals_d_matches_dense_flash(self):
        """Sanity: with k == d FlashSFA computes plain dense attention."""
        q, k_, v = rand((64, 16), 34), rand((64, 16), 35), rand((64, 16), 36)
        o = sfa_attention(q, k_, v, sparsity=16)
        np.testing.assert_allclose(
            o, ref.attention_ref(q, k_, v), rtol=RTOL, atol=ATOL
        )

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.sampled_from([32, 48, 64, 96, 128]),
        d=st.sampled_from([16, 32, 64, 128]),
        k=st.sampled_from([2, 4, 8, 16]),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, n, d, k, causal, seed):
        k = min(k, d)
        q, k_, v = rand((n, d), seed), rand((n, d), seed + 1), rand((n, d), seed + 2)
        o = sfa_attention(q, k_, v, sparsity=k, causal=causal)
        o_ref = ref.sfa_attention_ref(q, k_, v, sparsity=k, causal=causal)
        np.testing.assert_allclose(o, o_ref, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        dtype=st.sampled_from(["float32", "bfloat16"]),
        seed=st.integers(0, 2**10),
    )
    def test_dtype_sweep(self, dtype, seed):
        dt = jnp.dtype(dtype)
        q = rand((64, 32), seed).astype(dt)
        k_ = rand((64, 32), seed + 1).astype(dt)
        v = rand((64, 32), seed + 2).astype(dt)
        o = sfa_attention(q, k_, v, sparsity=4)
        o_ref = ref.sfa_attention_ref(q, k_, v, sparsity=4)
        tol = 1e-4 if dtype == "float32" else 5e-2
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
            rtol=tol, atol=tol,
        )
