"""AOT path: manifest correctness, weights round-trip, HLO text sanity."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model as M

ART = "/tmp/sfa_aot_pytest"


@pytest.fixture(scope="module")
def artifacts():
    """Compile a minimal tiny artifact set once per test session."""
    subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--out-dir", ART, "--preset", "tiny",
            "--variants", "dense,sfa_k4",
            "--entries", "train,eval,serve",
            "--train-batch", "2", "--serve-batches", "1",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


class TestVariantParsing:
    def test_parse_variants(self):
        assert aot.parse_variant("tiny", "dense", False).attn == "dense"
        c = aot.parse_variant("tiny", "sfa_k8", False)
        assert c.attn == "sfa" and c.sparsity == 8
        c = aot.parse_variant("tiny", "short_d16", False)
        assert c.attn == "short" and c.short_d == 16
        c = aot.parse_variant("tiny", "window_w32", False)
        assert c.attn == "window" and c.window == 32

    def test_unknown_variant_raises(self):
        with pytest.raises(ValueError):
            aot.parse_variant("tiny", "bogus", False)


class TestManifest:
    def test_variants_present(self, artifacts):
        assert set(artifacts["variants"]) == {"dense", "sfa_k4"}

    def test_files_exist(self, artifacts):
        for v in artifacts["variants"].values():
            assert os.path.exists(os.path.join(ART, v["weights"]))
            for e in v["entries"].values():
                assert os.path.exists(os.path.join(ART, e["file"]))

    def test_param_list_matches_model(self, artifacts):
        cfg = M.make_config("tiny", "dense")
        names = M.param_names(cfg)
        man = [p["name"] for p in artifacts["variants"]["dense"]["params"]]
        assert man == names

    def test_train_step_arity(self, artifacts):
        v = artifacts["variants"]["dense"]
        np_ = len(v["params"])
        e = v["entries"]["train_step"]
        assert len(e["inputs"]) == 3 * np_ + 3   # params, m, v, step, lr, tokens
        assert len(e["outputs"]) == 3 * np_ + 2  # ... step, loss

    def test_decode_io_symmetry(self, artifacts):
        """decode outputs (minus logits) must match its cache inputs, so the
        Rust engine can feed step t outputs straight into step t+1."""
        for v in artifacts["variants"].values():
            e = v["entries"]["decode_b1"]
            cache_in = [i for i in e["inputs"] if i["name"].startswith("cache.")]
            cache_out = [o for o in e["outputs"] if o["name"].startswith("cache.")]
            assert [c["name"] for c in cache_in] == [c["name"] for c in cache_out]
            assert [c["shape"] for c in cache_in] == [c["shape"] for c in cache_out]

    def test_prefill_outputs_match_decode_cache_inputs(self, artifacts):
        for v in artifacts["variants"].values():
            pre = v["entries"]["prefill_b1"]["outputs"][1:]
            dec = [i for i in v["entries"]["decode_b1"]["inputs"]
                   if i["name"].startswith("cache.")]
            assert [p["name"] for p in pre] == [d["name"] for d in dec]
            assert [p["shape"] for p in pre] == [d["shape"] for d in dec]

    def test_shapes_match_model_config(self, artifacts):
        v = artifacts["variants"]["sfa_k4"]
        cfg = M.make_config("tiny", "sfa", sparsity=4)
        params = M.init_params(cfg, 0)
        for p in v["params"]:
            assert tuple(p["shape"]) == tuple(params[p["name"]].shape)


class TestWeights:
    def test_weights_roundtrip_order(self, artifacts):
        cfg = M.make_config("tiny", "dense")
        expected = M.init_params(cfg, artifacts["seed"])
        with np.load(os.path.join(ART, "dense/weights.npz")) as z:
            keys = sorted(z.files)
            names = [k.split("|", 1)[1] for k in keys]
            assert names == sorted(expected)
            for key, name in zip(keys, names):
                np.testing.assert_allclose(
                    z[key], np.asarray(expected[name]), rtol=1e-6
                )

    def test_weights_deterministic_per_seed(self, artifacts):
        cfg = M.make_config("tiny", "dense")
        a = M.init_params(cfg, 42)["tok_emb"]
        b = M.init_params(cfg, 42)["tok_emb"]
        c = M.init_params(cfg, 43)["tok_emb"]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.abs(np.asarray(a) - np.asarray(c)).max() > 0


class TestHloText:
    def test_hlo_is_parseable_text(self, artifacts):
        path = os.path.join(ART, artifacts["variants"]["dense"]["entries"]
                            ["eval_step"]["file"])
        text = open(path).read()
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_no_topk_opcode(self, artifacts):
        """XLA 0.5.1's parser rejects the `topk` HLO op; our lowering must
        only emit `sort`-based selection (DESIGN.md §Artifact contract)."""
        for v in artifacts["variants"].values():
            for e in v["entries"].values():
                text = open(os.path.join(ART, e["file"])).read()
                for line in text.splitlines():
                    ls = line.strip()
                    assert not ls.startswith("topk") and " topk(" not in ls, (
                        e["file"])
