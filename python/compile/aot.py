"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per variant v ∈ {dense, sfa_k8, sfa_k16, short_d32, ...} this writes:

    artifacts/<v>/train_step.hlo.txt
    artifacts/<v>/eval_step.hlo.txt
    artifacts/<v>/logits.hlo.txt
    artifacts/<v>/prefill_b{B}.hlo.txt
    artifacts/<v>/decode_b{B}.hlo.txt
    artifacts/<v>/adapt_step.hlo.txt        (sfa variants only)
    artifacts/<v>/weights.npz               (seeded initial params)
    artifacts/manifest.json                 (shapes/dtypes/arg order)

Python runs ONCE at build time (`make artifacts`); the Rust coordinator
is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape: tuple[int, ...], dtype: str) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, DTYPES[dtype])


def _param_specs(cfg: M.ModelConfig, prefix: str) -> list[dict]:
    p = M.init_params(cfg, 0)
    return [
        {"name": f"{prefix}{n}", "shape": list(p[n].shape), "dtype": "f32"}
        for n in sorted(p)
    ]


def _shape_of(s: dict) -> jax.ShapeDtypeStruct:
    return spec(tuple(s["shape"]), s["dtype"])


# ---------------------------------------------------------------------------
# Entry-point builders: each returns (flat_fn, input_specs, output_specs)
# ---------------------------------------------------------------------------

def _train_cfg(cfg: M.ModelConfig) -> M.ModelConfig:
    """Training entries use the masked-dense SFA formulation instead of
    the Pallas kernel: the two are mathematically identical (both sides
    are tested equal, python/tests/test_model.py::
    test_sfa_pallas_equals_ref_path) and autodiff through the masked
    form IS the straight-through estimator (Eq. 6), but XLA fuses the
    dense-masked graph far better than the interpret-mode kernel loops,
    which matters for the CPU training throughput. The serving entries
    (prefill/decode) and eval_step keep the FlashSFA kernel on the hot
    path."""
    import dataclasses
    return dataclasses.replace(cfg, use_pallas=False)


def build_train_step(cfg: M.ModelConfig, batch: int, seq: int):
    cfg = _train_cfg(cfg)
    names = M.param_names(cfg)
    np_ = len(names)
    inputs = (
        _param_specs(cfg, "param:")
        + _param_specs(cfg, "adam_m:")
        + _param_specs(cfg, "adam_v:")
        + [
            {"name": "step", "shape": [], "dtype": "f32"},
            {"name": "lr", "shape": [], "dtype": "f32"},
            {"name": "tokens", "shape": [batch, seq], "dtype": "i32"},
        ]
    )
    outputs = (
        _param_specs(cfg, "param:")
        + _param_specs(cfg, "adam_m:")
        + _param_specs(cfg, "adam_v:")
        + [
            {"name": "step", "shape": [], "dtype": "f32"},
            {"name": "loss", "shape": [], "dtype": "f32"},
        ]
    )

    def fn(*flat):
        params = M.unflatten_params(names, flat[:np_])
        m = M.unflatten_params(names, flat[np_ : 2 * np_])
        v = M.unflatten_params(names, flat[2 * np_ : 3 * np_])
        step, lr, tokens = flat[3 * np_ :]
        p2, m2, v2, step2, loss = M.train_step(cfg, params, m, v, step, lr, tokens)
        return tuple(
            M.flatten_params(p2) + M.flatten_params(m2) + M.flatten_params(v2)
            + [step2, loss]
        )

    return fn, inputs, outputs


def build_adapt_step(cfg: M.ModelConfig, batch: int, seq: int):
    """Eq. 8 fine-tuning step: SFA student + stop-grad dense teacher."""
    assert cfg.attn == "sfa"
    cfg = _train_cfg(cfg)
    cfg_dense = M.make_config(cfg.name, "dense", rope=cfg.rope)
    names = M.param_names(cfg)
    np_ = len(names)
    inputs = (
        _param_specs(cfg, "param:")
        + _param_specs(cfg, "adam_m:")
        + _param_specs(cfg, "adam_v:")
        + [
            {"name": "step", "shape": [], "dtype": "f32"},
            {"name": "lr", "shape": [], "dtype": "f32"},
            {"name": "lambda", "shape": [], "dtype": "f32"},
            {"name": "tokens", "shape": [batch, seq], "dtype": "i32"},
        ]
    )
    outputs = (
        _param_specs(cfg, "param:")
        + _param_specs(cfg, "adam_m:")
        + _param_specs(cfg, "adam_v:")
        + [
            {"name": "step", "shape": [], "dtype": "f32"},
            {"name": "loss", "shape": [], "dtype": "f32"},
        ]
    )

    def fn(*flat):
        params = M.unflatten_params(names, flat[:np_])
        m = M.unflatten_params(names, flat[np_ : 2 * np_])
        v = M.unflatten_params(names, flat[2 * np_ : 3 * np_])
        step, lr, lam, tokens = flat[3 * np_ :]
        p2, m2, v2, step2, loss = M.adapt_step(
            cfg, cfg_dense, params, m, v, step, lr, lam, tokens
        )
        return tuple(
            M.flatten_params(p2) + M.flatten_params(m2) + M.flatten_params(v2)
            + [step2, loss]
        )

    return fn, inputs, outputs


def build_eval_step(cfg: M.ModelConfig, batch: int, seq: int):
    names = M.param_names(cfg)
    inputs = _param_specs(cfg, "param:") + [
        {"name": "tokens", "shape": [batch, seq], "dtype": "i32"}
    ]
    outputs = [{"name": "loss", "shape": [], "dtype": "f32"}]

    def fn(*flat):
        params = M.unflatten_params(names, flat[: len(names)])
        tokens = flat[len(names)]
        return (M.lm_loss(cfg, params, tokens),)

    return fn, inputs, outputs


def build_logits(cfg: M.ModelConfig, batch: int, seq: int):
    names = M.param_names(cfg)
    inputs = _param_specs(cfg, "param:") + [
        {"name": "tokens", "shape": [batch, seq], "dtype": "i32"}
    ]
    outputs = [{"name": "logits", "shape": [batch, seq, cfg.vocab], "dtype": "f32"}]

    def fn(*flat):
        params = M.unflatten_params(names, flat[: len(names)])
        tokens = flat[len(names)]
        logits, _ = M.forward(cfg, params, tokens)
        return (logits,)

    return fn, inputs, outputs


def build_qk_acts(cfg: M.ModelConfig, batch: int, seq: int):
    """Per-layer Q/K activations for the Fig. 7 / Fig. 11 analyses."""
    names = M.param_names(cfg)
    inputs = _param_specs(cfg, "param:") + [
        {"name": "tokens", "shape": [batch, seq], "dtype": "i32"}
    ]
    dq = cfg.qk_head_dim
    outputs = []
    for i in range(cfg.n_layers):
        for which in ("q", "k"):
            outputs.append({
                "name": f"acts.l{i:02d}.{which}",
                "shape": [batch, cfg.n_heads, seq, dq],
                "dtype": "f32",
            })
    # qk_activations doesn't touch every parameter (no lm head, no last
    # MLP); XLA prunes unused entry parameters, which would break the
    # manifest's positional contract. A checksum output keeps every
    # parameter live.
    outputs.append({"name": "param_checksum", "shape": [], "dtype": "f32"})

    def fn(*flat):
        params = M.unflatten_params(names, flat[: len(names)])
        tokens = flat[len(names)]
        acts = M.qk_activations(cfg, params, tokens)
        flat_out = []
        for q, k in acts:
            flat_out.extend([q, k])
        checksum = sum(jax.numpy.sum(p) for p in params.values())
        flat_out.append(checksum)
        return tuple(flat_out)

    return fn, inputs, outputs


def build_prefill(cfg: M.ModelConfig, batch: int, seq: int):
    names = M.param_names(cfg)
    inputs = _param_specs(cfg, "param:") + [
        {"name": "tokens", "shape": [batch, seq], "dtype": "i32"},
        {"name": "lengths", "shape": [batch], "dtype": "i32"},
    ]
    outputs = [{"name": "logits_last", "shape": [batch, cfg.vocab], "dtype": "f32"}] + [
        {"name": n, "shape": list(s), "dtype": d}
        for n, s, d in M.cache_shapes(cfg, batch)
    ]

    def fn(*flat):
        params = M.unflatten_params(names, flat[: len(names)])
        tokens, lengths = flat[len(names) :]
        last, caches = M.prefill(cfg, params, tokens, lengths)
        return tuple([last] + M.flatten_caches(cfg, caches))

    return fn, inputs, outputs


def build_decode_step(cfg: M.ModelConfig, batch: int, _seq: int):
    names = M.param_names(cfg)
    cache_sp = [
        {"name": n, "shape": list(s), "dtype": d}
        for n, s, d in M.cache_shapes(cfg, batch)
    ]
    inputs = (
        _param_specs(cfg, "param:")
        + cache_sp
        + [
            {"name": "token", "shape": [batch], "dtype": "i32"},
            {"name": "pos", "shape": [batch], "dtype": "i32"},
        ]
    )
    outputs = [{"name": "logits", "shape": [batch, cfg.vocab], "dtype": "f32"}] + cache_sp

    def fn(*flat):
        params = M.unflatten_params(names, flat[: len(names)])
        nc = len(cache_sp)
        caches = M.unflatten_caches(cfg, flat[len(names) : len(names) + nc])
        token, pos = flat[len(names) + nc :]
        logits, new_caches = M.decode_step(cfg, params, caches, token, pos)
        return tuple([logits] + M.flatten_caches(cfg, new_caches))

    return fn, inputs, outputs


# ---------------------------------------------------------------------------
# Variant compilation
# ---------------------------------------------------------------------------

def parse_variant(cfg_name: str, variant: str, rope: bool, **over) -> M.ModelConfig:
    """'dense' | 'sfa_k8' | 'sfa_k16' | 'short_d32' | 'window_w64' -> config."""
    if variant == "dense":
        return M.make_config(cfg_name, "dense", rope=rope, **over)
    if variant.startswith("sfa_k"):
        return M.make_config(cfg_name, "sfa", sparsity=int(variant[5:]), rope=rope, **over)
    if variant.startswith("short_d"):
        return M.make_config(cfg_name, "short", short_d=int(variant[7:]), rope=rope, **over)
    if variant.startswith("window_w"):
        return M.make_config(cfg_name, "window", window=int(variant[8:]), rope=rope, **over)
    raise ValueError(f"unknown variant {variant!r}")


def lower_entry(fn, input_specs: list[dict]) -> str:
    shapes = [_shape_of(s) for s in input_specs]
    lowered = jax.jit(fn).lower(*shapes)
    return to_hlo_text(lowered)


def save_weights(cfg: M.ModelConfig, path: str, seed: int) -> None:
    params = M.init_params(cfg, seed)
    # Order-prefixed keys so any reader can restore the flattening order.
    arrays = {
        f"{i:04d}|{n}": np.asarray(params[n])
        for i, n in enumerate(sorted(params))
    }
    np.savez(path, **arrays)


def compile_variant(
    cfg: M.ModelConfig,
    out_dir: str,
    entries: list[str],
    train_batch: int,
    serve_batches: list[int],
    prefill_seq: int,
    seed: int,
    verbose: bool = True,
) -> dict:
    variant = M.variant_name(cfg)
    vdir = os.path.join(out_dir, variant)
    os.makedirs(vdir, exist_ok=True)

    manifest_entries: dict[str, dict] = {}

    def emit(entry_name: str, builder, batch: int, seq: int):
        t0 = time.time()
        fn, ins, outs = builder(cfg, batch, seq)
        text = lower_entry(fn, ins)
        fname = f"{entry_name}.hlo.txt"
        with open(os.path.join(vdir, fname), "w") as f:
            f.write(text)
        manifest_entries[entry_name] = {
            "file": f"{variant}/{fname}",
            "inputs": ins,
            "outputs": outs,
            "batch": batch,
            "seq": seq,
        }
        if verbose:
            print(
                f"  [{variant}] {entry_name}: {len(ins)} in / {len(outs)} out, "
                f"{len(text) / 1e6:.1f} MB hlo, {time.time() - t0:.1f}s"
            )

    seq = cfg.max_seq
    if "train" in entries:
        emit("train_step", build_train_step, train_batch, seq)
    if "eval" in entries:
        emit("eval_step", build_eval_step, train_batch, seq)
    if "logits" in entries:
        emit("logits", build_logits, train_batch, seq)
    if "adapt" in entries and cfg.attn == "sfa":
        emit("adapt_step", build_adapt_step, train_batch, seq)
    if "acts" in entries:
        emit("qk_acts", build_qk_acts, min(train_batch, 4), seq)
    if "serve" in entries and cfg.attn in ("dense", "sfa"):
        for b in serve_batches:
            emit(f"prefill_b{b}", build_prefill, b, prefill_seq)
            emit(f"decode_b{b}", build_decode_step, b, seq)

    weights = f"{variant}/weights.npz"
    save_weights(cfg, os.path.join(out_dir, weights), seed)

    return {
        "config": cfg.to_json_dict(),
        "params": [
            {"name": n, "shape": list(s.shape), "dtype": "f32"}
            for n, s in sorted(M.init_params(cfg, 0).items())
        ],
        "weights": weights,
        "entries": manifest_entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(M.PRESETS))
    ap.add_argument(
        "--variants", default="dense,sfa_k8,sfa_k16,short_d32",
        help="comma-separated: dense | sfa_k<K> | short_d<D> | window_w<W>",
    )
    ap.add_argument(
        "--entries", default="train,eval,logits,serve,adapt,acts",
        help="comma-separated subset of train,eval,logits,serve,adapt,acts",
    )
    ap.add_argument("--train-batch", type=int, default=8)
    ap.add_argument("--serve-batches", default="1,4")
    ap.add_argument("--prefill-seq", type=int, default=0,
                    help="prompt bucket length (default max_seq // 2)")
    ap.add_argument("--rope", action="store_true")
    ap.add_argument("--seed", type=int, default=42)
    # Architecture overrides for ablation artifact sets (paper Fig. 9's
    # d_head sweep): e.g. --d-head 32 --n-heads 8 keeps d_model fixed.
    ap.add_argument("--d-head", type=int, default=0)
    ap.add_argument("--n-heads", type=int, default=0)
    ap.add_argument("--max-seq", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    entries = args.entries.split(",")
    serve_batches = [int(b) for b in args.serve_batches.split(",") if b]

    manifest: dict = {
        "preset": args.preset,
        "seed": args.seed,
        "train_batch": args.train_batch,
        "serve_batches": serve_batches,
        "variants": {},
    }
    over = {}
    if args.d_head:
        over["d_head"] = args.d_head
    if args.n_heads:
        over["n_heads"] = args.n_heads
    if args.max_seq:
        over["max_seq"] = args.max_seq

    t0 = time.time()
    for variant in args.variants.split(","):
        cfg = parse_variant(args.preset, variant, args.rope, **over)
        prefill_seq = args.prefill_seq or cfg.max_seq // 2
        manifest["prefill_seq"] = prefill_seq
        manifest["max_seq"] = cfg.max_seq
        print(f"[aot] compiling variant {variant} "
              f"({M.count_params(cfg) / 1e6:.2f}M params)")
        manifest["variants"][M.variant_name(cfg)] = compile_variant(
            cfg, args.out_dir, entries, args.train_batch, serve_batches,
            prefill_seq, args.seed,
        )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest.json ({time.time() - t0:.1f}s total)")


if __name__ == "__main__":
    main()
