"""L2 — GPT-2-style decoder LM with pluggable attention (paper §4.1).

Variants (paper Table 1 / Table 10):
  dense  — standard causal softmax attention (the "Dense (full)" baseline)
  sfa    — Sparse Feature Attention: top-k sparse Q/K codes scored by
           feature overlap via the FlashSFA Pallas kernel (L1)
  short  — "short embeddings": Q/K projected to a reduced per-head dim
           (the paper's Dense(d=X) baseline; V stays full width)
  window — Longformer-style local causal window (token-level sparsity
           baseline, used by the Table 10/11 orthogonality experiments)

Everything here is build-time Python: ``aot.py`` lowers the entry points
(train_step / eval_step / logits / prefill / decode_step / adapt_step)
to HLO text, and the Rust L3 coordinator drives the compiled artifacts.

Parameters are a flat ``{name: array}`` dict; flattening order is
``sorted(params)`` and is recorded in the manifest, so Rust can treat
them as an opaque ordered buffer list.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.flash_sfa import flash_sfa

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + attention-variant configuration.

    ``attn`` selects the scoring rule; all other compute is identical so
    quality/latency differences are attributable to attention alone
    (paper's controlled comparison).
    """

    name: str = "small"
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_head: int = 64
    max_seq: int = 256
    attn: str = "dense"          # dense | sfa | short | window
    sparsity: int = 8            # k for the sfa variant
    short_d: int = 32            # per-head Q/K width for the short variant
    window: int = 64             # window size for the window variant
    rope: bool = False           # rotary positions (Qwen3 track)
    use_pallas: bool = True      # route SFA through the FlashSFA kernel
    block_q: int = 32            # FlashSFA tile sizes
    block_k: int = 32

    @property
    def qk_head_dim(self) -> int:
        return self.short_d if self.attn == "short" else self.d_head

    def validate(self) -> None:
        assert self.attn in ("dense", "sfa", "short", "window"), self.attn
        assert self.d_model == self.n_heads * self.d_head, (
            "d_model must equal n_heads * d_head"
        )
        if self.attn == "sfa":
            assert 1 <= self.sparsity <= self.d_head
        if self.rope:
            assert self.qk_head_dim % 2 == 0

    def to_json_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


PRESETS: dict[str, dict[str, Any]] = {
    # CPU-friendly default used by smoke tests.
    "tiny": dict(vocab=256, d_model=128, n_layers=2, n_heads=2, d_head=64,
                 max_seq=128),
    # Default preset for the end-to-end training example.
    "small": dict(vocab=512, d_model=256, n_layers=4, n_heads=4, d_head=64,
                  max_seq=256),
    # NIAH long-context track (paper §4.2): small vocab, longer sequences.
    "niah": dict(vocab=64, d_model=128, n_layers=2, n_heads=4, d_head=32,
                 max_seq=512),
    "medium": dict(vocab=1024, d_model=512, n_layers=8, n_heads=8, d_head=64,
                   max_seq=512),
    # Paper-scale configs (Table 4) — compile targets, not CI defaults.
    "gpt2-124m": dict(vocab=50257, d_model=768, n_layers=12, n_heads=12,
                      d_head=64, max_seq=1024),
    "gpt2-350m": dict(vocab=50257, d_model=1024, n_layers=24, n_heads=16,
                      d_head=64, max_seq=1024),
}


def make_config(preset: str, attn: str = "dense", **over: Any) -> ModelConfig:
    base = dict(PRESETS[preset])
    base.update(over)
    cfg = ModelConfig(name=preset, attn=attn, **base)
    cfg.validate()
    return cfg


def variant_name(cfg: ModelConfig) -> str:
    """Canonical artifact-directory name for a config's attention variant."""
    if cfg.attn == "sfa":
        return f"sfa_k{cfg.sparsity}"
    if cfg.attn == "short":
        return f"short_d{cfg.short_d}"
    if cfg.attn == "window":
        return f"window_w{cfg.window}"
    return "dense"


# ---------------------------------------------------------------------------
# Parameter init / flattening
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 42) -> dict[str, jax.Array]:
    """GPT-2-style init: N(0, 0.02), output projections scaled 1/sqrt(2L)."""
    key = jax.random.PRNGKey(seed)
    p: dict[str, jax.Array] = {}

    def nrm(key, shape, std=0.02):
        return (std * jax.random.normal(key, shape)).astype(jnp.float32)

    keys = iter(jax.random.split(key, 16 * cfg.n_layers + 8))
    p["tok_emb"] = nrm(next(keys), (cfg.vocab, cfg.d_model))
    p["pos_emb"] = nrm(next(keys), (cfg.max_seq, cfg.d_model), 0.01)
    dq = cfg.qk_head_dim
    resid_std = 0.02 / math.sqrt(2 * cfg.n_layers)
    for i in range(cfg.n_layers):
        pre = f"l{i:02d}."
        p[pre + "ln1.g"] = jnp.ones((cfg.d_model,))
        p[pre + "ln1.b"] = jnp.zeros((cfg.d_model,))
        p[pre + "attn.wq"] = nrm(next(keys), (cfg.d_model, cfg.n_heads * dq))
        p[pre + "attn.wk"] = nrm(next(keys), (cfg.d_model, cfg.n_heads * dq))
        p[pre + "attn.wv"] = nrm(next(keys), (cfg.d_model, cfg.n_heads * cfg.d_head))
        p[pre + "attn.wo"] = nrm(
            next(keys), (cfg.n_heads * cfg.d_head, cfg.d_model), resid_std
        )
        p[pre + "ln2.g"] = jnp.ones((cfg.d_model,))
        p[pre + "ln2.b"] = jnp.zeros((cfg.d_model,))
        p[pre + "mlp.w1"] = nrm(next(keys), (cfg.d_model, 4 * cfg.d_model))
        p[pre + "mlp.b1"] = jnp.zeros((4 * cfg.d_model,))
        p[pre + "mlp.w2"] = nrm(next(keys), (4 * cfg.d_model, cfg.d_model), resid_std)
        p[pre + "mlp.b2"] = jnp.zeros((cfg.d_model,))
    p["lnf.g"] = jnp.ones((cfg.d_model,))
    p["lnf.b"] = jnp.zeros((cfg.d_model,))
    return p


def param_names(cfg: ModelConfig) -> list[str]:
    return sorted(init_params(cfg, 0).keys())


def flatten_params(p: dict[str, jax.Array]) -> list[jax.Array]:
    return [p[k] for k in sorted(p)]


def unflatten_params(names: list[str], flat: tuple) -> dict[str, jax.Array]:
    return dict(zip(names, flat))


def count_params(cfg: ModelConfig) -> int:
    return sum(int(x.size) for x in init_params(cfg, 0).values())


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def layer_norm(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu(x: jax.Array) -> jax.Array:
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x**3)))


def rope_tables(seq: int, dim: int) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables of shape (seq, dim//2)."""
    pos = jnp.arange(seq)[:, None]
    inv = 10000.0 ** (-jnp.arange(0, dim, 2) / dim)[None, :]
    ang = pos * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., seq, dim); cos/sin (seq, dim//2). Rotates consecutive pairs."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape)


def apply_rope_at(x: jax.Array, pos: jax.Array, dim: int, max_seq: int) -> jax.Array:
    """x (B, H, dim) rotated by per-row positions pos (B,)."""
    cos, sin = rope_tables(max_seq, dim)
    c = cos[pos][:, None, :]  # (B,1,dim/2)
    s = sin[pos][:, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    """(B,S,H*dh) -> (B,H,S,dh)"""
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    """(B,H,S,dh) -> (B,S,H*dh)"""
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


# ---------------------------------------------------------------------------
# Attention variants (single head, vmapped over batch*heads)
# ---------------------------------------------------------------------------

def _head_attention(cfg: ModelConfig) -> Callable[[jax.Array, jax.Array, jax.Array], jax.Array]:
    """Returns a (S,dq),(S,dq),(S,dv) -> (S,dv) causal attention fn."""
    if cfg.attn == "sfa":
        if cfg.use_pallas:
            def fn(q, k, v):
                d = q.shape[-1]
                qv, qi = ref.topk_codes(q, cfg.sparsity)
                kv, ki = ref.topk_codes(k, cfg.sparsity)
                return flash_sfa(qv, qi, kv, ki, v, d, True,
                                 cfg.block_q, cfg.block_k, True)
        else:
            def fn(q, k, v):
                return ref.sfa_attention_ref(q, k, v, sparsity=cfg.sparsity)
        return fn
    if cfg.attn == "window":
        def fn(q, k, v):
            d = q.shape[-1]
            s = (q @ k.T) / jnp.sqrt(d)
            n = s.shape[0]
            i = jnp.arange(n)[:, None]
            j = jnp.arange(n)[None, :]
            mask = (j <= i) & (i - j < cfg.window)
            s = jnp.where(mask, s, NEG_INF)
            return jax.nn.softmax(s, -1) @ v
        return fn
    # dense & short share the dense scoring rule (short just has smaller dq).
    def fn(q, k, v):
        return ref.attention_ref(q, k, v, causal=True)
    return fn


def _attention_block(
    cfg: ModelConfig, params: dict, layer: int, x: jax.Array,
    collect_cache: bool = False,
) -> tuple[jax.Array, dict | None]:
    """Full multi-head attention over (B,S,d_model) hidden states."""
    pre = f"l{layer:02d}.attn."
    b, s, _ = x.shape
    dq = cfg.qk_head_dim
    q = _split_heads(x @ params[pre + "wq"], cfg.n_heads)  # (B,H,S,dq)
    k = _split_heads(x @ params[pre + "wk"], cfg.n_heads)
    v = _split_heads(x @ params[pre + "wv"], cfg.n_heads)

    if cfg.rope:
        cos, sin = rope_tables(s, dq)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    head_fn = _head_attention(cfg)
    qf = q.reshape(b * cfg.n_heads, s, dq)
    kf = k.reshape(b * cfg.n_heads, s, dq)
    vf = v.reshape(b * cfg.n_heads, s, cfg.d_head)
    of = jax.vmap(head_fn)(qf, kf, vf)
    o = _merge_heads(of.reshape(b, cfg.n_heads, s, cfg.d_head))
    out = o @ params[pre + "wo"]

    cache = None
    if collect_cache:
        if cfg.attn == "sfa":
            kv, ki = jax.vmap(lambda kk: ref.topk_codes(kk, cfg.sparsity))(kf)
            cache = {
                "k_vals": kv.reshape(b, cfg.n_heads, s, cfg.sparsity),
                "k_idx": ki.reshape(b, cfg.n_heads, s, cfg.sparsity),
                "v": vf.reshape(b, cfg.n_heads, s, cfg.d_head),
            }
        else:
            cache = {
                "k": kf.reshape(b, cfg.n_heads, s, dq),
                "v": vf.reshape(b, cfg.n_heads, s, cfg.d_head),
            }
    return out, cache


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def forward(
    cfg: ModelConfig, params: dict, tokens: jax.Array,
    collect_cache: bool = False,
) -> tuple[jax.Array, list[dict] | None]:
    """tokens (B,S) int32 -> logits (B,S,vocab) [+ per-layer KV caches]."""
    _, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:s][None, :, :]
    caches: list[dict] = []
    for i in range(cfg.n_layers):
        pre = f"l{i:02d}."
        h = layer_norm(x, params[pre + "ln1.g"], params[pre + "ln1.b"])
        a, cache = _attention_block(cfg, params, i, h, collect_cache)
        x = x + a
        if collect_cache:
            caches.append(cache)
        h = layer_norm(x, params[pre + "ln2.g"], params[pre + "ln2.b"])
        m = gelu(h @ params[pre + "mlp.w1"] + params[pre + "mlp.b1"])
        x = x + m @ params[pre + "mlp.w2"] + params[pre + "mlp.b2"]
    x = layer_norm(x, params["lnf.g"], params["lnf.b"])
    logits = x @ params["tok_emb"].T  # tied embeddings
    return logits, (caches if collect_cache else None)


def qk_activations(
    cfg: ModelConfig, params: dict, tokens: jax.Array,
) -> list[tuple[jax.Array, jax.Array]]:
    """Per-layer post-RoPE Q/K activations, shape (B,H,S,dq) each —
    feeds the Fig. 7 load-balance entropy and Fig. 11 SVD analyses."""
    _, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:s][None, :, :]
    out: list[tuple[jax.Array, jax.Array]] = []
    dq = cfg.qk_head_dim
    for i in range(cfg.n_layers):
        pre = f"l{i:02d}."
        h = layer_norm(x, params[pre + "ln1.g"], params[pre + "ln1.b"])
        q = _split_heads(h @ params[pre + "attn.wq"], cfg.n_heads)
        k = _split_heads(h @ params[pre + "attn.wk"], cfg.n_heads)
        if cfg.rope:
            cos, sin = rope_tables(s, dq)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        out.append((q, k))
        a, _ = _attention_block(cfg, params, i, h)
        x = x + a
        h = layer_norm(x, params[pre + "ln2.g"], params[pre + "ln2.b"])
        m = gelu(h @ params[pre + "mlp.w1"] + params[pre + "mlp.b1"])
        x = x + m @ params[pre + "mlp.w2"] + params[pre + "mlp.b2"]
    return out


def lm_loss(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    """Next-token cross entropy over positions 0..S-2 (mean, nats)."""
    logits, _ = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


def adapt_loss(
    cfg_sfa: ModelConfig, cfg_dense: ModelConfig, params: dict,
    tokens: jax.Array, lam: jax.Array,
) -> jax.Array:
    """Paper Eq. 8: L_LM(SFA) + λ · mean_h ‖Õ_h − stopgrad(O_h)‖²_F.

    Both paths share the same weights; the dense path is stop-gradiented
    so the regularizer only pulls the sparse attention outputs toward the
    dense teacher (SFA adaptation of a dense-pretrained model, §5).
    """
    loss_lm = lm_loss(cfg_sfa, params, tokens)

    _, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:s][None, :, :]
    reg = 0.0
    for i in range(cfg_sfa.n_layers):
        pre = f"l{i:02d}."
        h = layer_norm(x, params[pre + "ln1.g"], params[pre + "ln1.b"])
        a_sfa, _ = _attention_block(cfg_sfa, params, i, h)
        a_dense, _ = _attention_block(cfg_dense, params, i, h)
        reg = reg + jnp.mean((a_sfa - jax.lax.stop_gradient(a_dense)) ** 2)
        # Advance hidden state along the *sparse* path (the student).
        x = x + a_sfa
        hh = layer_norm(x, params[pre + "ln2.g"], params[pre + "ln2.b"])
        m = gelu(hh @ params[pre + "mlp.w1"] + params[pre + "mlp.b1"])
        x = x + m @ params[pre + "mlp.w2"] + params[pre + "mlp.b2"]
    reg = reg / cfg_sfa.n_layers
    return loss_lm + lam * reg


# ---------------------------------------------------------------------------
# AdamW train step
# ---------------------------------------------------------------------------

B1, B2, EPS, WD, CLIP = 0.9, 0.95, 1e-8, 0.1, 1.0


def _global_norm(tree: dict) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(g * g) for g in tree.values()))


def adamw_update(
    params: dict, grads: dict, m: dict, v: dict, step: jax.Array, lr: jax.Array,
) -> tuple[dict, dict, dict]:
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, CLIP / (gnorm + 1e-12))
    t = step + 1.0
    new_p, new_m, new_v = {}, {}, {}
    for k_ in params:
        g = grads[k_] * scale
        m2 = B1 * m[k_] + (1 - B1) * g
        v2 = B2 * v[k_] + (1 - B2) * g * g
        mhat = m2 / (1 - B1**t)
        vhat = v2 / (1 - B2**t)
        upd = mhat / (jnp.sqrt(vhat) + EPS)
        if params[k_].ndim >= 2:  # decoupled weight decay on matrices only
            upd = upd + WD * params[k_]
        new_p[k_] = params[k_] - lr * upd
        new_m[k_] = m2
        new_v[k_] = v2
    return new_p, new_m, new_v


def train_step(
    cfg: ModelConfig, params: dict, m: dict, v: dict,
    step: jax.Array, lr: jax.Array, tokens: jax.Array,
) -> tuple[dict, dict, dict, jax.Array, jax.Array]:
    loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, tokens))(params)
    new_p, new_m, new_v = adamw_update(params, grads, m, v, step, lr)
    return new_p, new_m, new_v, step + 1.0, loss


def adapt_step(
    cfg_sfa: ModelConfig, cfg_dense: ModelConfig, params: dict, m: dict, v: dict,
    step: jax.Array, lr: jax.Array, lam: jax.Array, tokens: jax.Array,
) -> tuple[dict, dict, dict, jax.Array, jax.Array]:
    loss, grads = jax.value_and_grad(
        lambda p: adapt_loss(cfg_sfa, cfg_dense, p, tokens, lam)
    )(params)
    new_p, new_m, new_v = adamw_update(params, grads, m, v, step, lr)
    return new_p, new_m, new_v, step + 1.0, loss


# ---------------------------------------------------------------------------
# Serving path: prefill + decode with (sparse) KV cache
# ---------------------------------------------------------------------------

def prefill(
    cfg: ModelConfig, params: dict, tokens: jax.Array, lengths: jax.Array,
) -> tuple[jax.Array, list[dict]]:
    """Process padded prompts (B,S); return last-position logits + caches.

    ``lengths`` (B,) gives each prompt's true length; logits are gathered
    at position lengths-1 (causality makes padding past the true length
    harmless for earlier positions). Caches are padded to max_seq so the
    decode loop can append in place.
    """
    logits, caches = forward(cfg, params, tokens, collect_cache=True)
    last = jnp.take_along_axis(
        logits, (lengths - 1)[:, None, None], axis=1
    )[:, 0, :]
    assert caches is not None
    s = tokens.shape[1]
    pad = cfg.max_seq - s
    padded: list[dict] = []
    for c in caches:
        padded.append({
            k_: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
            for k_, a in c.items()
        })
    return last, padded


def _decode_attention_dense(
    q: jax.Array, kc: jax.Array, vc: jax.Array, pos: jax.Array,
) -> jax.Array:
    """q (B,H,dq), kc (B,H,S,dq), vc (B,H,S,dv), pos (B,) -> (B,H,dv)."""
    dq = q.shape[-1]
    s = jnp.einsum("bhd,bhsd->bhs", q, kc) / jnp.sqrt(dq)
    smax = kc.shape[2]
    ok = jnp.arange(smax)[None, None, :] <= pos[:, None, None]
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhs,bhsd->bhd", p, vc)


def _decode_attention_sfa(
    cfg: ModelConfig, q: jax.Array, kc_vals: jax.Array, kc_idx: jax.Array,
    vc: jax.Array, pos: jax.Array,
) -> jax.Array:
    """Feature-overlap decode scoring against the sparse K cache.

    q (B,H,dq) dense query; kc_vals/kc_idx (B,H,S,k); vc (B,H,S,dv).
    The K cache stores only O(S·k) numbers per head (the paper's ~2d/3k
    KV-memory saving, App. J); the score is the masked k×k overlap sum.
    """
    b, h, dq = q.shape
    qv, qi = ref.topk_codes(q.reshape(b * h, dq), cfg.sparsity)
    qv = qv.reshape(b, h, cfg.sparsity)
    qi = qi.reshape(b, h, cfg.sparsity)
    match = qi[:, :, None, :, None] == kc_idx[:, :, :, None, :]  # (B,H,S,k,k)
    prod = qv[:, :, None, :, None] * kc_vals[:, :, :, None, :]
    s = jnp.where(match, prod, 0.0).sum(axis=(3, 4)) / jnp.sqrt(dq)
    smax = kc_vals.shape[2]
    ok = jnp.arange(smax)[None, None, :] <= pos[:, None, None]
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhs,bhsd->bhd", p, vc)


def _scatter_time(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """cache (B,H,S,d), new (B,H,d), pos (B,) -> cache with row pos written."""
    def one(c, n, p):  # (H,S,d), (H,d), ()
        return jax.lax.dynamic_update_slice_in_dim(c, n[:, None, :], p, axis=1)
    return jax.vmap(one)(cache, new, pos)


def decode_step(
    cfg: ModelConfig, params: dict, caches: list[dict],
    token: jax.Array, pos: jax.Array,
) -> tuple[jax.Array, list[dict]]:
    """One autoregressive step. token (B,) i32; pos (B,) i32 (0-based slot
    the new token occupies). Returns next-token logits (B,vocab) and the
    updated caches."""
    b = token.shape[0]
    x = params["tok_emb"][token] + params["pos_emb"][pos]  # (B,d_model)
    x = x[:, None, :]  # (B,1,d)
    new_caches: list[dict] = []
    dq = cfg.qk_head_dim
    for i in range(cfg.n_layers):
        pre = f"l{i:02d}."
        h = layer_norm(x, params[pre + "ln1.g"], params[pre + "ln1.b"])
        q = (h[:, 0] @ params[pre + "attn.wq"]).reshape(b, cfg.n_heads, dq)
        k = (h[:, 0] @ params[pre + "attn.wk"]).reshape(b, cfg.n_heads, dq)
        v = (h[:, 0] @ params[pre + "attn.wv"]).reshape(b, cfg.n_heads, cfg.d_head)
        if cfg.rope:
            q = apply_rope_at(q, pos, dq, cfg.max_seq)
            k = apply_rope_at(k, pos, dq, cfg.max_seq)
        c = caches[i]
        if cfg.attn == "sfa":
            kv, ki = ref.topk_codes(k.reshape(b * cfg.n_heads, dq), cfg.sparsity)
            kv = kv.reshape(b, cfg.n_heads, cfg.sparsity)
            ki = ki.reshape(b, cfg.n_heads, cfg.sparsity)
            kc_vals = _scatter_time(c["k_vals"], kv, pos)
            kc_idx = _scatter_time(c["k_idx"], ki, pos)
            vc = _scatter_time(c["v"], v, pos)
            o = _decode_attention_sfa(cfg, q, kc_vals, kc_idx, vc, pos)
            new_caches.append({"k_vals": kc_vals, "k_idx": kc_idx, "v": vc})
        else:
            kc = _scatter_time(c["k"], k, pos)
            vc = _scatter_time(c["v"], v, pos)
            o = _decode_attention_dense(q, kc, vc, pos)
            new_caches.append({"k": kc, "v": vc})
        x = x + (o.reshape(b, 1, cfg.n_heads * cfg.d_head) @ params[pre + "attn.wo"])
        h2 = layer_norm(x, params[pre + "ln2.g"], params[pre + "ln2.b"])
        mm = gelu(h2 @ params[pre + "mlp.w1"] + params[pre + "mlp.b1"])
        x = x + mm @ params[pre + "mlp.w2"] + params[pre + "mlp.b2"]
    x = layer_norm(x, params["lnf.g"], params["lnf.b"])
    logits = (x @ params["tok_emb"].T)[:, 0, :]
    return logits, new_caches


# ---------------------------------------------------------------------------
# Cache (de)flattening for the AOT boundary
# ---------------------------------------------------------------------------

def _cache_keys(cfg: ModelConfig) -> list[str]:
    return ["k_vals", "k_idx", "v"] if cfg.attn == "sfa" else ["k", "v"]


def cache_entry_names(cfg: ModelConfig) -> list[str]:
    return [
        f"cache.l{i:02d}.{k}" for i in range(cfg.n_layers) for k in _cache_keys(cfg)
    ]


def flatten_caches(cfg: ModelConfig, caches: list[dict]) -> list[jax.Array]:
    return [caches[i][k] for i in range(cfg.n_layers) for k in _cache_keys(cfg)]


def unflatten_caches(cfg: ModelConfig, flat: tuple) -> list[dict]:
    keys = _cache_keys(cfg)
    out = []
    it = iter(flat)
    for _ in range(cfg.n_layers):
        out.append({k: next(it) for k in keys})
    return out


def cache_shapes(cfg: ModelConfig, batch: int) -> list[tuple[str, tuple[int, ...], str]]:
    """(name, shape, dtype) per flattened cache tensor at max_seq capacity."""
    b, h, s = batch, cfg.n_heads, cfg.max_seq
    out: list[tuple[str, tuple[int, ...], str]] = []
    for i in range(cfg.n_layers):
        if cfg.attn == "sfa":
            out.append((f"cache.l{i:02d}.k_vals", (b, h, s, cfg.sparsity), "f32"))
            out.append((f"cache.l{i:02d}.k_idx", (b, h, s, cfg.sparsity), "i32"))
            out.append((f"cache.l{i:02d}.v", (b, h, s, cfg.d_head), "f32"))
        else:
            out.append((f"cache.l{i:02d}.k", (b, h, s, cfg.qk_head_dim), "f32"))
            out.append((f"cache.l{i:02d}.v", (b, h, s, cfg.d_head), "f32"))
    return out


# ---------------------------------------------------------------------------
# KV-cache memory accounting (paper Appendix J)
# ---------------------------------------------------------------------------

def kv_cache_bytes(cfg: ModelConfig, seq: int, batch: int = 1,
                   s_val: int = 4, s_idx: int = 4, s_ptr: int = 4) -> int:
    """Bytes of K+V cache for one model instance at context length seq.

    For SFA the K half stores CSR-style (values + indices [+ indptr]);
    V stays dense (the paper keeps V dense). Defaults reflect our f32/i32
    artifacts; pass s_val=2, s_idx=1, s_ptr=4 for the paper's
    fp16/int8/int32 setting (App. J ratio ≈ 2d/(3k+4)).
    """
    h, L = cfg.n_heads, cfg.n_layers
    v_bytes = L * batch * h * seq * cfg.d_head * s_val
    if cfg.attn == "sfa":
        k_bytes = L * batch * h * (
            seq * cfg.sparsity * (s_val + s_idx) + (seq + 1) * s_ptr
        )
    else:
        k_bytes = L * batch * h * seq * cfg.qk_head_dim * s_val
    return k_bytes + v_bytes
