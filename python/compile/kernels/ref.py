"""Pure-jnp reference oracles for SFA / FlashSFA.

These are the CORE correctness signal: every Pallas kernel in this
package is tested (pytest + hypothesis) against the functions here.

All functions operate on a single head: q, k of shape (n, d), v of
shape (n, d_v). Batch / head axes are added by the caller with
``jax.vmap`` (mirrors how model.py composes them).

Scaling convention (paper §3.1, Eq. 5): scores are divided by sqrt(d)
where d is the *dense* head dimension — NOT k — so SFA is a drop-in
replacement whose logits approximate the dense logits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free
                 # on padded / fully-masked rows.


# ---------------------------------------------------------------------------
# Top-k sparsification (paper Eq. 3-4)
# ---------------------------------------------------------------------------

def _topk_indices(x_abs: jax.Array, k: int) -> jax.Array:
    """Indices of the k largest entries per row, ties toward lower index.

    Implemented with a stable descending argsort rather than
    ``jax.lax.top_k``: recent jax lowers top_k to the `topk` HLO opcode,
    which the runtime's XLA 0.5.1 text parser cannot parse. `sort` is
    ancient and round-trips fine (DESIGN.md §Artifact contract).
    """
    order = jnp.argsort(-x_abs, axis=1, stable=True)
    return order[:, :k].astype(jnp.int32)


def topk_mask(x: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the k largest-|x| entries per row.

    Ties are broken toward the lower index (same as jax.lax.top_k).
    """
    idx = _topk_indices(jnp.abs(x), k)
    return jnp.zeros(x.shape, bool).at[
        jnp.arange(x.shape[0])[:, None], idx
    ].set(True)


def topk_sparsify(x: jax.Array, k: int) -> jax.Array:
    """Dense tensor with all but the top-k |x| entries per row zeroed.

    Gradient behaviour: the mask is computed from stop_gradient(x), so
    autodiff through this function IS the straight-through estimator of
    paper Eq. 6 — gradients flow only through selected coordinates.
    """
    mask = topk_mask(jax.lax.stop_gradient(x), k)
    return jnp.where(mask, x, 0.0)


def topk_codes(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Padded sparse codes: (values (n,k), indices (n,k) int32).

    Entries are ordered by descending |value| (jax.lax.top_k order).
    values keep their sign; indices are column ids in [0, d).
    Gradient: STE — d(values)[i,a] scatters back to x[i, indices[i,a]].
    """
    idx = _topk_indices(jnp.abs(jax.lax.stop_gradient(x)), k)
    vals = jnp.take_along_axis(x, idx, axis=1)
    return vals, idx


def densify(vals: jax.Array, idx: jax.Array, d: int) -> jax.Array:
    """Inverse of topk_codes: scatter padded codes back to (n, d) dense."""
    n = vals.shape[0]
    return jnp.zeros((n, d), vals.dtype).at[
        jnp.arange(n)[:, None], idx
    ].set(vals)


# ---------------------------------------------------------------------------
# Attention references
# ---------------------------------------------------------------------------

def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Standard softmax(q k^T / sqrt(d)) v with optional causal mask."""
    d = q.shape[-1]
    scale = (1.0 / jnp.sqrt(d)) if scale is None else scale
    s = (q @ k.T) * scale
    if causal:
        n, m = s.shape
        mask = jnp.arange(m)[None, :] <= jnp.arange(n)[:, None] + (m - n)
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return p @ v


def sfa_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    sparsity: int,
    causal: bool = True,
) -> jax.Array:
    """SFA by densified top-k codes (paper Eq. 3-5), the oracle for FlashSFA.

    Exactly softmax(Topk(q) Topk(k)^T / sqrt(d)) v. Autodiff through this
    function implements the straight-through backward of Eq. 6.
    """
    d = q.shape[-1]
    qs = topk_sparsify(q, sparsity)
    ks = topk_sparsify(k, sparsity)
    return attention_ref(qs, ks, v, causal=causal, scale=1.0 / jnp.sqrt(d))


def sfa_scores_ref(
    q: jax.Array, k: jax.Array, *, sparsity: int, causal: bool = True
) -> jax.Array:
    """Pre-softmax SFA score matrix (for FLOP-counting and tests)."""
    d = q.shape[-1]
    s = (topk_sparsify(q, sparsity) @ topk_sparsify(k, sparsity).T) / jnp.sqrt(d)
    if causal:
        n = s.shape[0]
        mask = jnp.arange(n)[None, :] <= jnp.arange(n)[:, None]
        s = jnp.where(mask, s, NEG_INF)
    return s


def sfa_attention_from_codes_ref(
    q_vals: jax.Array,
    q_idx: jax.Array,
    k_vals: jax.Array,
    k_idx: jax.Array,
    v: jax.Array,
    *,
    d_orig: int,
    causal: bool = True,
) -> jax.Array:
    """Oracle taking the padded sparse codes directly (FlashSFA's interface)."""
    qs = densify(q_vals, q_idx, d_orig)
    ks = densify(k_vals, k_idx, d_orig)
    return attention_ref(qs, ks, v, causal=causal, scale=1.0 / jnp.sqrt(d_orig))


# ---------------------------------------------------------------------------
# Feature-overlap scoring (paper Eq. 5) — structural reference used to test
# that the masked-outer-product formulation equals the posting-list sum.
# ---------------------------------------------------------------------------

def overlap_score_ref(
    q_vals: jax.Array,
    q_idx: jax.Array,
    k_vals: jax.Array,
    k_idx: jax.Array,
    d_orig: int,
) -> jax.Array:
    """s_ij = (1/sqrt(d)) * sum_{u in S_i ∩ S_j} q̃_iu k̃_ju, via the
    masked k×k outer product used by the Pallas kernel."""
    match = q_idx[:, None, :, None] == k_idx[None, :, None, :]
    prod = q_vals[:, None, :, None] * k_vals[None, :, None, :]
    return jnp.where(match, prod, 0.0).sum(axis=(2, 3)) / jnp.sqrt(d_orig)
