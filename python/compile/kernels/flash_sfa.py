"""FlashSFA — IO-aware Sparse Feature Attention as a Pallas kernel.

This is the TPU adaptation of the paper's CUDA kernel (App. C):
FlashAttention-style tiling + online softmax, with the dense tile
matmul replaced by *feature-overlap* scoring over top-k sparse Q/K
codes (paper Eq. 5).

Hardware adaptation (DESIGN.md §Hardware-Adaptation)
----------------------------------------------------
CUDA FlashSFA walks CSR(Q) rows and per-feature CSC(K) posting lists
with binary search + register scatter-adds. TPUs have no efficient
scatter into registers, but they have a wide VPU, so we keep the
fixed-k padded sparse format (values[n,k], indices[n,k]) — the natural
output of row-wise top-k — and express the support intersection as a
branch-free masked k×k outer product per (Bq, Bk) tile:

    S[i,j] = (1/sqrt(d)) * sum_{a,b} qv[i,a] * kv[j,b] * [qi[i,a] == kj[j,b]]

This costs Θ(Bq·Bk·k²) per tile — the same k² scaling the posting-list
intersection achieves for balanced supports — while staying fully
vectorizable. BlockSpec expresses the HBM↔VMEM schedule the CUDA kernel
expressed with threadblocks; the online-softmax running (m, l, acc)
are the fori_loop carry across key tiles.

VMEM budget per grid step (fp32):
    match/prod tensors:  Bq*Bk*k*k * 4 bytes   (dominant)
    score tile:          Bq*Bk * 4
    q codes:             2*Bq*k * 4, k codes: 2*Bk*k * 4, v tile: Bk*dv * 4
Defaults Bq=Bk=32, k<=16 keep the dominant term <= 1 MiB (fits VMEM
with double-buffering headroom); see DESIGN.md §Perf for the estimate.

MUST run with interpret=True on CPU (real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute).

Gradient: custom_vjp straight-through estimator (paper Eq. 6). The
backward densifies the sparse codes and runs the standard attention
backward, then gathers grads at the selected coordinates — gradients
flow only through the active supports, and never differentiate through
the Pallas forward.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Kernel
# ---------------------------------------------------------------------------

def _flash_sfa_kernel(
    qv_ref, qi_ref, kv_ref, ki_ref, v_ref, o_ref,
    *,
    d_orig: int,
    causal: bool,
    block_q: int,
    block_k: int,
    n_kv: int,
    kv_valid: int,
):
    """One grid step = one query tile; loops over key tiles (online softmax)."""
    iq = pl.program_id(0)
    qv = qv_ref[...]            # (Bq, k)
    qi = qi_ref[...]            # (Bq, k) int32
    block_q_, k = qv.shape
    dv = v_ref.shape[-1]
    inv_sqrt_d = 1.0 / math.sqrt(d_orig)

    row_ids = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    n_k_tiles = n_kv // block_k
    if causal:
        # Key tiles strictly above the diagonal band contribute nothing:
        # last needed tile covers column (iq+1)*block_q - 1.
        num_tiles = jnp.minimum(
            (iq * block_q + block_q + block_k - 1) // block_k, n_k_tiles
        )
    else:
        num_tiles = n_k_tiles

    def body(jk, carry):
        m_run, l_run, acc = carry
        kv_t = kv_ref[pl.ds(jk * block_k, block_k), :]
        ki_t = ki_ref[pl.ds(jk * block_k, block_k), :]
        v_t = v_ref[pl.ds(jk * block_k, block_k), :]

        # Feature-overlap scoring: masked k×k outer product (Eq. 5).
        match = qi[:, None, :, None] == ki_t[None, :, None, :]
        prod = qv[:, None, :, None] * kv_t[None, :, None, :]
        s = jnp.where(match, prod, 0.0).sum(axis=(2, 3)) * inv_sqrt_d  # (Bq,Bk)

        col_ids = jk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        ok = col_ids < kv_valid
        if causal:
            ok = ok & (col_ids <= row_ids)
        s = jnp.where(ok, s, NEG_INF)

        # Online softmax update (FlashAttention recurrence).
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v_t
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, dv), jnp.float32)
    m_f, l_f, acc_f = jax.lax.fori_loop(0, num_tiles, body, (m0, l0, acc0))

    out = jnp.where(l_f[:, None] > 0.0, acc_f / l_f[:, None], 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


def _flash_sfa_fwd_impl(
    q_vals, q_idx, k_vals, k_idx, v,
    d_orig: int, causal: bool, block_q: int, block_k: int, interpret: bool,
):
    n_q, k = q_vals.shape
    n_kv = k_vals.shape[0]
    dv = v.shape[-1]
    if causal and n_q != n_kv:
        raise ValueError(f"causal FlashSFA requires n_q == n_kv, got {n_q} vs {n_kv}")

    qv = _pad_rows(q_vals, block_q)
    qi = _pad_rows(q_idx, block_q)
    kv = _pad_rows(k_vals, block_k)
    ki = _pad_rows(k_idx, block_k)
    vp = _pad_rows(v, block_k)
    n_q_p, n_kv_p = qv.shape[0], kv.shape[0]

    kernel = functools.partial(
        _flash_sfa_kernel,
        d_orig=d_orig,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        n_kv=n_kv_p,
        kv_valid=n_kv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_q_p // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, k), lambda i: (i, 0)),   # q values tile
            pl.BlockSpec((block_q, k), lambda i: (i, 0)),   # q indices tile
            pl.BlockSpec((n_kv_p, k), lambda i: (0, 0)),    # full K values
            pl.BlockSpec((n_kv_p, k), lambda i: (0, 0)),    # full K indices
            pl.BlockSpec((n_kv_p, dv), lambda i: (0, 0)),   # full V
        ],
        out_specs=pl.BlockSpec((block_q, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_q_p, dv), v.dtype),
        interpret=interpret,
    )(qv, qi, kv, ki, vp)
    return out[:n_q]


# ---------------------------------------------------------------------------
# custom_vjp wrapper (straight-through backward, Eq. 6)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def flash_sfa(
    q_vals: jax.Array,
    q_idx: jax.Array,
    k_vals: jax.Array,
    k_idx: jax.Array,
    v: jax.Array,
    d_orig: int,
    causal: bool = True,
    block_q: int = 32,
    block_k: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """softmax(Q̃ K̃ᵀ/√d) V over top-k sparse codes, never materializing n×n.

    Args:
      q_vals/q_idx: padded top-k query codes, shape (n_q, k) / int32.
      k_vals/k_idx: padded top-k key codes, shape (n_kv, k) / int32.
      v: dense values, shape (n_kv, d_v).
      d_orig: the dense head dimension d (for the 1/sqrt(d) scale).
      causal: apply the causal mask (requires n_q == n_kv).
    Returns: (n_q, d_v) attention output, exact w.r.t. the sparse codes.
    """
    return _flash_sfa_fwd_impl(
        q_vals, q_idx, k_vals, k_idx, v, d_orig, causal, block_q, block_k, interpret
    )


def _flash_sfa_vjp_fwd(q_vals, q_idx, k_vals, k_idx, v,
                       d_orig, causal, block_q, block_k, interpret):
    o = _flash_sfa_fwd_impl(
        q_vals, q_idx, k_vals, k_idx, v, d_orig, causal, block_q, block_k, interpret
    )
    return o, (q_vals, q_idx, k_vals, k_idx, v)


def _flash_sfa_vjp_bwd(d_orig, causal, block_q, block_k, interpret, res, do):
    """Standard attention backward on the densified codes, gathered back to
    the active supports (straight-through, paper Eq. 6)."""
    q_vals, q_idx, k_vals, k_idx, v = res
    n_q, kk = q_vals.shape
    n_kv = k_vals.shape[0]
    scale = 1.0 / math.sqrt(d_orig)

    qs = jnp.zeros((n_q, d_orig), q_vals.dtype).at[
        jnp.arange(n_q)[:, None], q_idx
    ].set(q_vals)
    ks = jnp.zeros((n_kv, d_orig), k_vals.dtype).at[
        jnp.arange(n_kv)[:, None], k_idx
    ].set(k_vals)

    s = (qs @ ks.T) * scale
    if causal:
        mask = jnp.arange(n_kv)[None, :] <= jnp.arange(n_q)[:, None]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)

    dv_ = p.T @ do
    dp = do @ v.T
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dqs = (ds @ ks) * scale
    dks = (ds.T @ qs) * scale

    dq_vals = jnp.take_along_axis(dqs, q_idx, axis=1)
    dk_vals = jnp.take_along_axis(dks, k_idx, axis=1)
    # Integer index inputs receive no gradient.
    return dq_vals, None, dk_vals, None, dv_


flash_sfa.defvjp(_flash_sfa_vjp_fwd, _flash_sfa_vjp_bwd)


# ---------------------------------------------------------------------------
# Dense-head convenience wrapper (top-k + kernel), vmap-friendly.
# ---------------------------------------------------------------------------

def sfa_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    sparsity: int,
    causal: bool = True,
    block_q: int = 32,
    block_k: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """Full SFA head: top-k sparsify dense q/k (Eq. 3-4), then FlashSFA."""
    from . import ref

    d = q.shape[-1]
    q_vals, q_idx = ref.topk_codes(q, sparsity)
    k_vals, k_idx = ref.topk_codes(k, sparsity)
    return flash_sfa(
        q_vals, q_idx, k_vals, k_idx, v,
        d, causal, block_q, block_k, interpret,
    )
