"""Pallas row-wise top-k selection — the RTopK analog (paper App. C.5).

The paper sparsifies Q/K with the RTopK CUDA kernel (Xie et al., 2024):
one warp per row, GPU-parallel selection, O(Nd) total. On TPU/Pallas the
natural mapping is one *row tile* per grid step with the selection done
as k unrolled iterative-max passes over the row held in VMEM — k is a
small compile-time constant (2..32), so the unroll is cheap and fully
vectorized across the row tile (the VPU analog of RTopK's warp-per-row).

Interface mirrors ref.topk_codes: returns (values (n,k), indices (n,k)
int32), entries ordered by descending |value|, values keep their sign.

Gradient: custom_vjp straight-through — d(values)[i,a] scatters back to
x[i, indices[i,a]] (paper Eq. 6). Indices get no gradient.

MUST run with interpret=True on CPU (real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k: int):
    """One grid step selects top-k per row for a (block_rows, d) tile."""
    x = x_ref[...]
    absx = jnp.abs(x)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    # k unrolled iterative-max passes. Ties break toward the lower index
    # (same as jax.lax.top_k) because argmax returns the first maximum.
    for a in range(k):
        best = jnp.argmax(absx, axis=-1).astype(jnp.int32)  # (rows,)
        onehot = cols == best[:, None]
        val = jnp.sum(jnp.where(onehot, x, 0.0), axis=-1)
        vals_ref[:, a] = val
        idx_ref[:, a] = best
        # Knock the selected coordinate out for the next pass.
        absx = jnp.where(onehot, NEG_INF, absx)


def _topk_fwd_impl(x: jax.Array, k: int, block_rows: int, interpret: bool):
    n, d = x.shape
    assert n % block_rows == 0, (n, block_rows)
    kernel = functools.partial(_topk_kernel, k=k)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), x.dtype),
            jax.ShapeDtypeStruct((n, k), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    return vals, idx


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def topk_pallas(
    x: jax.Array, k: int, block_rows: int = 64, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Row-wise top-|x| selection as a Pallas kernel (values, indices)."""
    return _topk_fwd_impl(x, k, block_rows, interpret)


def _topk_vjp_fwd(x, k, block_rows, interpret):
    vals, idx = _topk_fwd_impl(x, k, block_rows, interpret)
    return (vals, idx), (idx, jnp.zeros_like(x))


def _topk_vjp_bwd(k, block_rows, interpret, res, g):
    idx, zeros = res
    g_vals, _g_idx = g  # indices are integer outputs: no gradient
    n = zeros.shape[0]
    dx = zeros.at[jnp.arange(n)[:, None], idx].add(g_vals.astype(zeros.dtype))
    return (dx,)


topk_pallas.defvjp(_topk_vjp_fwd, _topk_vjp_bwd)
