//! `cargo bench --bench attention_kernels`
//!
//! Regenerates the kernel-level comparisons:
//!   * Fig 3 — latency vs sparsity at module levels (score-only vs full
//!     attention) at one context;
//!   * Table 8 — top-k selection latency (partial-select RTopK analog
//!     vs full-sort torch.topk analog) and its share of attention time;
//!   * Table 10/11 latency block — token-sparse / low-rank / kernel /
//!     quant baselines and their "+SFA" compositions.

use sfa::bench::figures;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let budget = env_f64("SFA_BENCH_BUDGET", 0.15);
    let ctx = env_usize("SFA_BENCH_CTX", 1024);

    figures::fig3(ctx, 128, &[2, 8, 16, 32], budget).print();
    figures::table8(&[1024, 4096, 8192], 128, 16, budget).print();
    figures::table10_latency(ctx, 128, 8, budget).print();
    figures::table7(ctx, 128, 8, budget).print();
}
