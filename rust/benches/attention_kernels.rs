//! `cargo bench --bench attention_kernels`
//!
//! Regenerates the kernel-level comparisons:
//!   * Fig 3 — latency vs sparsity at module levels (score-only vs full
//!     attention) at one context;
//!   * Table 8 — top-k selection latency (partial-select RTopK analog
//!     vs full-sort torch.topk analog) and its share of attention time;
//!   * Table 10/11 latency block — token-sparse / low-rank / kernel /
//!     quant baselines and their "+SFA" compositions (registry specs);
//!   * Table 7 — effective bandwidth.
//!
//! Extras via env: SFA_BENCH_ENGINES="spec;spec;..." appends an
//! arbitrary registry-spec grid. Every engine measurement is also
//! written to BENCH_attention.json for cross-PR tracking.

use sfa::bench::figures;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let budget = env_f64("SFA_BENCH_BUDGET", 0.15);
    let ctx = env_usize("SFA_BENCH_CTX", 1024);

    figures::fig3(ctx, 128, &[2, 8, 16, 32], budget).print();
    figures::table8(&[1024, 4096, 8192], 128, 16, budget).print();
    figures::table10_latency(ctx, 128, 8, budget).print();
    figures::table7(ctx, 128, 8, budget).print();

    if let Ok(engines) = std::env::var("SFA_BENCH_ENGINES") {
        let specs = sfa::attention::registry::split_spec_list(&engines);
        if !specs.is_empty() {
            figures::engine_grid(&specs, &[ctx], 128, budget).print();
        }
    }

    match sfa::bench::write_records("BENCH_attention.json") {
        Ok(0) => {}
        Ok(n) => eprintln!("[bench] wrote {n} engine records to BENCH_attention.json"),
        Err(e) => eprintln!("[bench] failed to write BENCH_attention.json: {e}"),
    }
}
