//! `cargo bench --bench flops_tables`
//!
//! Regenerates the analytic cost tables (no wall-clock, instant):
//!   * Table 6 — TFLOPs / INOPs per configuration (B=8, H=8);
//!   * Fig 5 — FLOPs + KV-cache scaling with context;
//!   * Fig 1b — headline FLOP/KV reductions at the default config;
//!   * Appendix J — dense/CSR memory ratio grid;
//! plus the Eq. 7 validation: measured overlap counts vs the n²k²/d
//! prediction on sampled Gaussian features.

use sfa::analysis::flops::measured_vs_predicted_overlaps;
use sfa::bench::figures;
use sfa::bench::Table;
use sfa::sparse::memory::{memory_ratio, paper_ratio_approx, Widths};

fn main() {
    figures::table6(&[8192, 16384, 32768, 65536]).print();
    figures::fig5(&[1024, 4096, 16384, 65536, 262144], 64, 4).print();
    figures::fig1(131072, 16).print();

    let mut t = Table::new(
        "Appendix J — dense/CSR memory ratio (fp16/int8/int32)",
        &["d", "k", "exact", "2d/(3k+4)"],
    );
    for &d in &[64usize, 128, 256, 1024] {
        for &k in &[4usize, 8, 16, 32] {
            if k >= d {
                continue;
            }
            t.row(vec![
                d.to_string(),
                k.to_string(),
                format!("{:.2}", memory_ratio(65536, d, k, Widths::PAPER)),
                format!("{:.2}", paper_ratio_approx(d, k)),
            ]);
        }
    }
    t.print();

    let mut t = Table::new(
        "Eq. 7 validation — measured vs predicted overlap pairs",
        &["n", "d", "k", "measured", "n²k²/d", "ratio"],
    );
    for (n, d, k) in [(512, 64, 8), (1024, 128, 16), (512, 128, 4), (2048, 128, 8)] {
        let (m, p) = measured_vs_predicted_overlaps(n, d, k, 7);
        t.row(vec![
            n.to_string(),
            d.to_string(),
            k.to_string(),
            m.to_string(),
            p.to_string(),
            format!("{:.2}", m as f64 / p as f64),
        ]);
    }
    t.print();
}
