//! `cargo bench --bench latency_tables`
//!
//! Regenerates the latency grids: paper Table 9 / Fig 4 (forward
//! latency vs context × head-dim × sparsity) and Fig 6 (log-log TTFT /
//! TTNT scaling with fitted exponents).
//!
//! Context lengths default to the single-core CPU-feasible range; the
//! 64k-128k paper columns are produced by the power-law extrapolation
//! printed at the end (see EXPERIMENTS.md for the audit trail).
//! Override via env: SFA_BENCH_CTXS=1024,4096 SFA_BENCH_BUDGET=0.3

use sfa::analysis::costmodel::PowerLaw;
use sfa::bench::figures;

fn env_list(name: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(name)
        .ok()
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let budget = env_f64("SFA_BENCH_BUDGET", 0.15);
    let ctxs = env_list("SFA_BENCH_CTXS", &[512, 1024, 2048]);
    let dims = env_list("SFA_BENCH_DIMS", &[64, 128]);
    let ks = env_list("SFA_BENCH_KS", &[2, 8, 32]);

    figures::table9(&ctxs, &dims, &ks, budget).print();

    let (a, b) = figures::fig6(&ctxs, 128, 8, budget);
    a.print();
    b.print();

    // 128k extrapolation from the measured sweep (Table 1/10 columns).
    // Engines come from the registry so the pair is overridable:
    // SFA_BENCH_EXTRAP_ENGINES="flash_dense;sfa:k=8" (';'-separated).
    println!("\n## Latency@128k extrapolation (power-law fit over measured ctxs)");
    let extrap = std::env::var("SFA_BENCH_EXTRAP_ENGINES")
        .unwrap_or_else(|_| "flash_dense;sfa:k=8".to_string());
    for spec in sfa::attention::registry::split_spec_list(&extrap) {
        use sfa::attention::registry::build_engine;
        use sfa::attention::Engine;
        use sfa::util::matrix::Matrix;
        use sfa::util::rng::Rng;
        let engine = build_engine(&spec).expect("extrapolation engine spec");
        let times: Vec<f64> = ctxs
            .iter()
            .map(|&n| {
                let mut rng = Rng::new(1);
                let q = Matrix::randn(n, 128, &mut rng, 1.0);
                let k = Matrix::randn(n, 128, &mut rng, 1.0);
                let v = Matrix::randn(n, 128, &mut rng, 1.0);
                let t0 = std::time::Instant::now();
                std::hint::black_box(engine.forward(&q, &k, &v, true));
                t0.elapsed().as_secs_f64()
            })
            .collect();
        let pl = PowerLaw::fit(&ctxs, &times);
        println!(
            "  {spec}: alpha={:.2} R2={:.4} predicted t(131072)={:.1}s",
            pl.alpha,
            pl.r2(&ctxs, &times),
            pl.predict(131072)
        );
    }

    match sfa::bench::write_records("BENCH_attention.json") {
        Ok(0) => {}
        Ok(n) => eprintln!("[bench] wrote {n} engine records to BENCH_attention.json"),
        Err(e) => eprintln!("[bench] failed to write BENCH_attention.json: {e}"),
    }
}
