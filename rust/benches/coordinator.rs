//! `cargo bench --bench coordinator`
//!
//! L3 coordinator micro-benchmarks (artifact-independent):
//!   * decode-path KV caches: dense vs SFA-sparse vs pruned policies
//!     across context lengths (the TTNT story, Fig 5/6b + Table 11);
//!   * paged KV-cache allocator throughput;
//!   * batcher admission overhead (must be negligible vs a decode step).

use std::time::Duration;

use sfa::attention::decode::{
    DenseKvCache, H2oPolicy, PrunedKvCache, QuestPolicy, SparseKvCache,
};
use sfa::attention::Scorer;
use sfa::bench::harness::bench;
use sfa::bench::table::{fmt_speedup, fmt_time, Table};
use sfa::coordinator::request::GenRequest;
use sfa::coordinator::Batcher;
use sfa::kv_cache::paged::SlotLayout;
use sfa::kv_cache::PagedKvCache;
use sfa::util::matrix::Matrix;
use sfa::util::rng::Rng;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let budget = env_f64("SFA_BENCH_BUDGET", 0.1);
    let d = 128;
    let k = 8;

    // --- decode path across context lengths --------------------------
    let mut t = Table::new(
        "Decode (TTNT) — dense vs SFA cache vs pruning policies (d=128, k=8)",
        &["ctx", "dense", "sfa", "sfa speedup", "h2o(b=512)", "quest(p=16)"],
    );
    for ctx in [2048usize, 8192, 32768] {
        let mut rng = Rng::new(0);
        let keys = Matrix::randn(ctx, d, &mut rng, 1.0);
        let vals = Matrix::randn(ctx, d, &mut rng, 1.0);
        let q: Vec<f32> = rng.normal_vec(d, 1.0);

        let mut dense = DenseKvCache::new(d, d);
        let mut sparse = SparseKvCache::new(d, d, k);
        let mut h2o = PrunedKvCache::new(d, d, H2oPolicy::new(512, 64), Scorer::Dense);
        let mut quest = PrunedKvCache::new(
            d, d, QuestPolicy::new(16, 64, d), Scorer::Dense,
        );
        for i in 0..ctx {
            dense.append(keys.row(i), vals.row(i));
            sparse.append(keys.row(i), vals.row(i));
            h2o.append(keys.row(i), vals.row(i));
            quest.policy.ingest_key(i, keys.row(i));
            quest.append(keys.row(i), vals.row(i));
        }
        quest.policy.set_query(&q);
        let mut out = vec![0f32; d];
        let rd = bench("dense", budget, || {
            dense.decode(&q, &mut out);
            std::hint::black_box(&out);
        });
        let rs = bench("sfa", budget, || {
            sparse.decode(&q, &mut out);
            std::hint::black_box(&out);
        });
        let rh = bench("h2o", budget, || {
            h2o.decode(&q, &mut out);
            std::hint::black_box(&out);
        });
        let rq = bench("quest", budget, || {
            quest.decode(&q, &mut out);
            std::hint::black_box(&out);
        });
        t.row(vec![
            ctx.to_string(),
            fmt_time(rd.median_s),
            fmt_time(rs.median_s),
            fmt_speedup(rd.median_s / rs.median_s),
            fmt_time(rh.median_s),
            fmt_time(rq.median_s),
        ]);
    }
    t.print();

    // --- paged allocator ------------------------------------------------
    let layout = SlotLayout::Sparse { k: 8, d_v: 64 };
    let payload = vec![0.5f32; layout.floats_per_token()];
    let r = bench("paged append+free", 0.3, || {
        let mut cache = PagedKvCache::new(4096, 16, layout);
        let s = cache.create_seq();
        for _ in 0..1024 {
            cache.append(s, &payload).unwrap();
        }
        cache.free(s).unwrap();
    });
    println!(
        "\npaged cache: 1024 appends+free in {} ({:.1}M tokens/s)",
        fmt_time(r.median_s),
        1024.0 / r.median_s / 1e6
    );

    // --- batcher --------------------------------------------------------
    let r = bench("batcher", 0.2, || {
        let mut b = Batcher::new(8, Duration::from_millis(5));
        for i in 0..64 {
            b.push(GenRequest::new(i, vec![1, 2, 3], 4)).expect("unbounded queue");
        }
        let now = std::time::Instant::now();
        while b.next_batch(now).is_some() {}
    });
    println!(
        "batcher: 64 requests through admission in {} — negligible vs any decode step",
        fmt_time(r.median_s)
    );
}
