//! Offline stand-in for the `anyhow` crate.
//!
//! The image this repo builds in has no crates.io access, so the usual
//! ecosystem error crate is replaced by this vendored subset exposing
//! exactly the API surface the `sfa` crate uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension
//! trait for `Result` and `Option`.
//!
//! Semantics mirror the real crate where it matters to callers:
//!
//! * `{}` displays the outermost message only; `{:#}` joins the whole
//!   context chain with `": "` (the format the manifest tests assert);
//! * `{:?}` prints the outermost message plus a `Caused by:` list;
//! * every `E: std::error::Error + Send + Sync + 'static` converts via
//!   `?`, capturing its `source()` chain.

use std::fmt;

/// A dynamic error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn wrap(mut self, context: String) -> Error {
        self.chain.insert(0, context);
        self
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what keeps this blanket conversion
// coherent with the reflexive `From<Error> for Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to a fallible value, anyhow-style.
pub trait Context<T>: Sized {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().wrap(f().to_string())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("bad value {:?}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        let f = || -> Result<()> { bail!("stop {}", "here") };
        assert_eq!(f().unwrap_err().to_string(), "stop here");
    }

    #[test]
    fn std_errors_convert_with_sources() {
        let e = fails_io().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e = fails_io().context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        let full = format!("{e:#}");
        assert!(full.contains("reading manifest") && full.contains("gone"), "{full}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(3u32).context("fine").unwrap(), 3);
    }
}
