//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The image this repo builds in has neither crates.io access nor a
//! PJRT runtime, so the `sfa` crate links against this vendored stub
//! instead of the real bindings:
//!
//! * [`Literal`] is **real** — an in-memory (element type, dims, bytes)
//!   container whose create/read/clone surface round-trips data, so
//!   every host-side tensor path (and its tests) works;
//! * the **runtime** surface ([`PjRtClient`], compile/execute, npz IO)
//!   returns a typed [`Error`] explaining that the PJRT runtime is not
//!   vendored. Artifact-driven paths (`sfa train`, `sfa exp`, legacy
//!   serve) fail with that error at startup; the artifact-free serving
//!   and bench stacks never touch it.

use std::fmt;

/// Stub error: always a message, implements `std::error::Error` so it
/// flows through `?` into the caller's error type.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable in this offline build (the PJRT runtime is not vendored; \
         host-side Literal operations still work)"
    ))
}

/// Element types a literal can carry. Matches the real crate's naming
/// for the variants the repo touches; marked non-exhaustive so
/// downstream matches keep their wildcard arms.
#[non_exhaustive]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    pub fn byte_width(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Array shape of a literal: element type + dimensions.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host types a literal can be decoded into.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
    fn write_le(self, out: &mut Vec<u8>);
}

macro_rules! native {
    ($t:ty, $ty:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;
            fn from_le(bytes: &[u8]) -> Self {
                <$t>::from_le_bytes(bytes.try_into().expect("byte width checked by caller"))
            }
            fn write_le(self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
        }
    };
}

native!(f32, ElementType::F32);
native!(i32, ElementType::S32);
native!(f64, ElementType::F64);
native!(i64, ElementType::S64);

/// An in-memory host tensor: element type, dims, little-endian bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let expect = dims.iter().product::<usize>() * ty.byte_width();
        if data.len() != expect {
            return Err(Error(format!(
                "literal data is {} bytes but shape {dims:?} of {ty:?} needs {expect}",
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { ty: self.ty, dims: self.dims.clone() })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal holds {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        let w = self.ty.byte_width();
        Ok(self.data.chunks_exact(w).map(T::from_le).collect())
    }

    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let v = self.to_vec::<T>()?;
        if v.len() != dst.len() {
            return Err(Error(format!(
                "copy_raw_to: literal has {} elements, destination {}",
                v.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(&v);
        Ok(())
    }

    /// Decompose a tuple literal — tuples only exist as executable
    /// outputs, which the stub cannot produce.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple decomposition of executable outputs"))
    }

    pub fn write_npz<T: AsRef<Literal>>(_entries: &[(&str, T)], _path: &str) -> Result<()> {
        Err(unavailable("Literal::write_npz"))
    }
}

/// Trait the real crate routes npz/raw-byte reads through; `read_npz`
/// is called as `xla::Literal::read_npz(path, &())`.
pub trait FromRawBytes: Sized {
    fn read_npz(path: &str, config: &()) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    fn read_npz(_path: &str, _config: &()) -> Result<Vec<(String, Literal)>> {
        Err(unavailable("Literal::read_npz"))
    }
}

/// PJRT client handle — construction fails in the stub.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_f32_and_i32() {
        let xs = [1.5f32, -2.0, 0.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.size_bytes(), 12);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[3]);
        assert!(lit.to_vec::<i32>().is_err(), "type-checked decode");

        let mut dst = [0f32; 3];
        lit.copy_raw_to(&mut dst).unwrap();
        assert_eq!(dst, xs);
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2, 2], &[0u8; 8])
                .is_err()
        );
    }

    #[test]
    fn runtime_surface_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline"), "{e}");
        assert!(Literal::read_npz("x.npz", &()).is_err());
    }
}
