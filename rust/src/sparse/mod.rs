//! Sparse formats and kernels for feature-sparse attention (paper §2, §3.1).
//!
//! * [`topk`] — row-wise top-k selection (the RTopK analog, App. C.5)
//! * [`csr`] — CSR matrices + the fixed-k padded code format
//! * [`csc_feat`] — feature-wise CSC posting lists (App. C.3)
//! * [`spgemm`] — Gustavson row-wise sparse score computation (Eq. 5)
//! * [`memory`] — Appendix-J byte accounting for sparse vs dense storage

pub mod csc_feat;
pub mod csr;
pub mod memory;
pub mod spgemm;
pub mod topk;

pub use csc_feat::{CscBlockIndex, CscFeat};
pub use csr::{CsrMatrix, TopkCodes};
pub use topk::{topk_codes, topk_codes_full_sort, TopkAlgo};
