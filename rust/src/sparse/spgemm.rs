//! Gustavson row-wise SpGEMM for attention scores (paper §2, Eq. 5).
//!
//! Computes the sparse score matrix S = Q̃ K̃ᵀ / √d as CSR, walking each
//! query row's features and accumulating the matching posting lists —
//! the "structural intersections" the paper's cost model counts. This
//! is the *materializing* SFA path (used by the naive engine and the
//! FLOP-count validation); FlashSFA (attention::flash_sfa) fuses the
//! same traversal with the online softmax so S never hits memory.

use crate::sparse::csc_feat::CscFeat;
use crate::sparse::csr::TopkCodes;

/// Sparse score rows: for each query, the (key, score) pairs with
/// non-empty support intersection, ascending by key id.
#[derive(Debug, Clone)]
pub struct SparseScores {
    pub n_queries: usize,
    pub n_keys: usize,
    pub indptr: Vec<u32>,
    pub key_ids: Vec<u32>,
    pub scores: Vec<f32>,
}

/// Operation counters (paper Table 6: FLOPs vs INOPs).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCounts {
    /// Floating-point multiply-adds performed (2 FLOPs each).
    pub fmas: u64,
    /// Integer ops: posting-list index reads + accumulator bookkeeping.
    pub inops: u64,
}

/// Gustavson row-wise accumulation: for query i and each active feature
/// f with value qv, scores[j] += qv * K̃[j, f] for all j in posting(f).
/// `causal` restricts to keys j ≤ i.
pub fn spgemm_scores(
    q: &TopkCodes,
    kf: &CscFeat,
    scale: f32,
    causal: bool,
) -> (SparseScores, OpCounts) {
    assert_eq!(q.dim, kf.dim);
    let n = q.rows;
    let m = kf.n_tokens;
    let mut indptr = Vec::with_capacity(n + 1);
    let mut key_ids: Vec<u32> = Vec::new();
    let mut scores: Vec<f32> = Vec::new();
    indptr.push(0u32);

    // Dense accumulator + visited list (classic Gustavson scratch).
    let mut acc = vec![0f32; m];
    let mut visited: Vec<u32> = Vec::with_capacity(m.min(1024));
    let mut ops = OpCounts::default();

    for i in 0..n {
        visited.clear();
        let hi = if causal { (i + 1) as u32 } else { m as u32 };
        for (&f, &qv) in q.row_idx(i).iter().zip(q.row_vals(i)) {
            if qv == 0.0 {
                continue;
            }
            let r = kf.posting_range(f as usize, 0, hi);
            ops.inops += 2 * (kf.posting(f as usize).0.len().max(1) as f64).log2().ceil() as u64; // binary search
            for t in r {
                let j = kf.token_ids[t] as usize;
                ops.inops += 1; // index read
                if acc[j] == 0.0 && !visited.contains(&(j as u32)) {
                    visited.push(j as u32);
                }
                acc[j] += qv * kf.vals[t];
                ops.fmas += 1;
            }
        }
        visited.sort_unstable();
        for &j in &visited {
            key_ids.push(j);
            scores.push(acc[j as usize] * scale);
            acc[j as usize] = 0.0;
        }
        ops.fmas += visited.len() as u64; // the scale multiply
        indptr.push(key_ids.len() as u32);
    }
    (
        SparseScores { n_queries: n, n_keys: m, indptr, key_ids, scores },
        ops,
    )
}

impl SparseScores {
    pub fn nnz(&self) -> usize {
        self.key_ids.len()
    }

    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let r = self.indptr[i] as usize..self.indptr[i + 1] as usize;
        (&self.key_ids[r.clone()], &self.scores[r])
    }

    /// Densify with a fill value for structurally-missing entries
    /// (scores of empty intersections are 0 pre-softmax in the sparse
    /// semantics, but tests compare against -inf-masked dense paths).
    pub fn to_dense(&self, fill: f32) -> crate::util::matrix::Matrix {
        let mut m = crate::util::matrix::Matrix::zeros(self.n_queries, self.n_keys);
        m.data.fill(fill);
        for i in 0..self.n_queries {
            let (keys, vals) = self.row(i);
            for (&j, &s) in keys.iter().zip(vals) {
                m.set(i, j as usize, s);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::topk::topk_codes;
    use crate::util::matrix::Matrix;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn scores_dense_reference(
        q: &TopkCodes, k: &TopkCodes, scale: f32, causal: bool,
    ) -> Matrix {
        let dq = q.densify();
        let dk = k.densify();
        let mut s = dq.matmul(&dk.transpose());
        for v in s.data.iter_mut() {
            *v *= scale;
        }
        if causal {
            for i in 0..s.rows {
                for j in i + 1..s.cols {
                    s.set(i, j, 0.0);
                }
            }
        }
        s
    }

    #[test]
    fn matches_dense_reference() {
        check("spgemm == dense masked matmul", 32, |g| {
            let n = g.usize_in(2..48);
            let d = *g.choose(&[16usize, 32, 64]);
            let k = g.usize_in(1..(d / 2).max(2));
            let causal = g.bool();
            let mut rng = Rng::new(g.seed ^ 1);
            let qm = Matrix::randn(n, d, &mut rng, 1.0);
            let km = Matrix::randn(n, d, &mut rng, 1.0);
            let qc = topk_codes(&qm, k);
            let kc = topk_codes(&km, k);
            let kf = CscFeat::from_codes(&kc);
            let scale = 1.0 / (d as f32).sqrt();
            let (sp, _) = spgemm_scores(&qc, &kf, scale, causal);
            let dense = scores_dense_reference(&qc, &kc, scale, causal);
            let got = sp.to_dense(0.0);
            crate::util::matrix::assert_close(&got, &dense, 1e-5, 1e-6);
        });
    }

    #[test]
    fn causal_never_emits_future_keys() {
        let mut rng = Rng::new(7);
        let qm = Matrix::randn(20, 32, &mut rng, 1.0);
        let qc = topk_codes(&qm, 4);
        let kf = CscFeat::from_codes(&qc);
        let (sp, _) = spgemm_scores(&qc, &kf, 1.0, true);
        for i in 0..20 {
            let (keys, _) = sp.row(i);
            assert!(keys.iter().all(|&j| j as usize <= i));
        }
    }

    #[test]
    fn nnz_bounded_by_eq7_style_bound() {
        // nnz(S) <= min(n², Σ_u deg_q(u)·deg_k(u)) — each overlap pair
        // contributes at most one structural nonzero.
        let mut rng = Rng::new(8);
        let qm = Matrix::randn(64, 64, &mut rng, 1.0);
        let km = Matrix::randn(64, 64, &mut rng, 1.0);
        let qc = topk_codes(&qm, 8);
        let kc = topk_codes(&km, 8);
        let qf = CscFeat::from_codes(&qc);
        let kf = CscFeat::from_codes(&kc);
        let bound = CscFeat::predicted_overlaps(&qf.degrees(), &kf.degrees());
        let (sp, ops) = spgemm_scores(&qc, &kf, 1.0, false);
        assert!(sp.nnz() as u64 <= bound);
        assert_eq!(ops.fmas, bound + sp.nnz() as u64, "one fma per overlap + scale");
    }

    #[test]
    fn disjoint_supports_give_empty_scores() {
        // Queries activate features 0..4, keys activate 8..12.
        let mut qm = Matrix::zeros(4, 16);
        let mut km = Matrix::zeros(4, 16);
        for i in 0..4 {
            for j in 0..4 {
                qm.set(i, j, 1.0 + j as f32);
                km.set(i, j + 8, 1.0 + j as f32);
            }
        }
        let qc = topk_codes(&qm, 4);
        let kc = topk_codes(&km, 4);
        let kf = CscFeat::from_codes(&kc);
        let (sp, ops) = spgemm_scores(&qc, &kf, 1.0, false);
        assert_eq!(sp.nnz(), 0);
        assert_eq!(ops.fmas, 0);
    }
}
