//! Appendix-J memory accounting: CSR vs dense storage for Q/K features
//! and the KV cache, with configurable element widths.
//!
//! Paper result: with fp16 values, int8 indices, int32 indptr the ratio
//! dense/CSR ≈ 2d / (3k + 4), so memory is saved whenever k < ⅔·d.

/// Element widths in bytes for the three CSR arrays (paper Eq. 10-12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Widths {
    pub s_val: usize,
    pub s_idx: usize,
    pub s_ptr: usize,
}

impl Widths {
    /// The paper's production setting (App. B/J): fp16 / int8 / int32.
    pub const PAPER: Widths = Widths { s_val: 2, s_idx: 1, s_ptr: 4 };
    /// This repo's artifact setting: f32 / u16 / u32.
    pub const OURS: Widths = Widths { s_val: 4, s_idx: 2, s_ptr: 4 };
}

/// Bytes for an (n, d) dense matrix (paper Mem_dense).
pub fn dense_bytes(n: usize, d: usize, w: Widths) -> usize {
    n * d * w.s_val
}

/// Bytes for an (n, d) CSR matrix with exactly k nnz per row (Eq. 14).
pub fn csr_bytes(n: usize, k: usize, w: Widths) -> usize {
    n * k * (w.s_val + w.s_idx) + (n + 1) * w.s_ptr
}

/// Exact dense/CSR memory ratio (Eq. 15).
pub fn memory_ratio(n: usize, d: usize, k: usize, w: Widths) -> f64 {
    dense_bytes(n, d, w) as f64 / csr_bytes(n, k, w) as f64
}

/// The paper's closed-form approximation 2d/(3k+4) (Eq. 16; fp16/int8).
pub fn paper_ratio_approx(d: usize, k: usize) -> f64 {
    2.0 * d as f64 / (3.0 * k as f64 + 4.0)
}

/// Sparsity threshold below which CSR wins: k < (d·s_val − s_ptr/n̄) /
/// (s_val + s_idx) ≈ ⅔·d for the paper widths.
pub fn break_even_k(d: usize, w: Widths) -> f64 {
    d as f64 * w.s_val as f64 / (w.s_val + w.s_idx) as f64
}

/// KV-cache bytes per layer-head at context length `seq`: sparse K
/// (CSR) + dense V (paper keeps V dense).
pub fn kv_cache_bytes_sfa(seq: usize, d_head: usize, k: usize, w: Widths) -> usize {
    csr_bytes(seq, k, w) + dense_bytes(seq, d_head, w)
}

/// Dense KV-cache bytes per layer-head.
pub fn kv_cache_bytes_dense(seq: usize, d_head: usize, w: Widths) -> usize {
    2 * dense_bytes(seq, d_head, w)
}

/// Fractional KV-cache saving of SFA vs dense (paper Fig. 1b: ~41% at
/// the default config; Fig. 5: ~40% at k=4, d=64).
pub fn kv_saving_fraction(seq: usize, d_head: usize, k: usize, w: Widths) -> f64 {
    1.0 - kv_cache_bytes_sfa(seq, d_head, k, w) as f64
        / kv_cache_bytes_dense(seq, d_head, w) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn eq16_matches_exact_ratio_at_scale() {
        // For large n the (n+1)/n indptr term vanishes; Eq. 16 says
        // ratio ≈ 2d/(3k+4) with paper widths.
        for (d, k) in [(64, 8), (128, 16), (256, 32), (128, 8)] {
            let exact = memory_ratio(100_000, d, k, Widths::PAPER);
            let approx = paper_ratio_approx(d, k);
            assert!(
                (exact - approx).abs() / approx < 0.01,
                "d={d} k={k}: {exact} vs {approx}"
            );
        }
    }

    #[test]
    fn paper_headline_numbers() {
        // §3.1: d=128, k=16 → 64× arithmetic; memory ratio 2·128/52 ≈ 4.9×.
        assert!((paper_ratio_approx(128, 16) - 4.923).abs() < 0.01);
        // Break-even ≈ ⅔·d for fp16/int8.
        assert!((break_even_k(128, Widths::PAPER) - 85.33).abs() < 0.1);
    }

    #[test]
    fn memory_gain_iff_k_below_two_thirds_d() {
        check("break-even", 64, |g| {
            let d = *g.choose(&[32usize, 64, 128, 256]);
            let k = g.usize_in(1..d + 1);
            let n = 4096;
            let w = Widths::PAPER;
            let saves = csr_bytes(n, k, w) < dense_bytes(n, d, w);
            // Appendix J: "memory gain when k < 2/3 d" (up to the small
            // indptr term).
            let threshold = break_even_k(d, w) - (w.s_ptr as f64) / (w.s_val + w.s_idx) as f64;
            if (k as f64) < threshold - 1.0 {
                assert!(saves, "k={k} d={d} should save");
            }
            if (k as f64) > threshold + 1.0 {
                assert!(!saves, "k={k} d={d} should not save");
            }
        });
    }

    #[test]
    fn kv_saving_matches_paper_fig5() {
        // Fig. 5 / §4.3: "~40% memory saving at k=4" (d_head=64, fp16).
        let s = kv_saving_fraction(65536, 64, 4, Widths::PAPER);
        assert!((0.38..0.50).contains(&s), "saving {s}");
        // Fig. 1b: 41% KV reduction at the default d=128, k=16 setting
        // (K-half shrinks 4.9×; with dense V the total drops ~40%).
        let s = kv_saving_fraction(131072, 128, 16, Widths::PAPER);
        assert!((0.35..0.45).contains(&s), "saving {s}");
    }

    #[test]
    fn monotonicity() {
        check("csr bytes monotone in k and n", 32, |g| {
            let n = g.usize_in(1..10_000);
            let k = g.usize_in(1..128);
            let w = Widths::OURS;
            assert!(csr_bytes(n, k, w) < csr_bytes(n + 1, k, w));
            assert!(csr_bytes(n, k, w) < csr_bytes(n, k + 1, w));
        });
    }

    #[test]
    fn ratio_positive_and_finite() {
        check("ratio sane", 32, |g| {
            let d = g.usize_in(1..512);
            let k = g.usize_in(1..d + 1);
            let r = memory_ratio(1024, d, k, Widths::OURS);
            assert!(r.is_finite() && r > 0.0);
        });
    }
}
