//! Row-wise top-k selection — the RTopK analog (paper App. C.5, Table 8).
//!
//! Two implementations with identical outputs:
//!
//! * [`topk_codes`] — partial selection via `select_nth_unstable`
//!   (average O(d) per row, the fast path; the CPU counterpart of the
//!   RTopK kernel's warp-parallel binary search).
//! * [`topk_codes_full_sort`] — full row sort (O(d log d)), the
//!   `torch.topk`-style baseline Table 8 compares against.
//!
//! Tie-breaking matches the Python side (`ref.topk_codes`): larger |x|
//! first, ties toward the lower feature index. Output entries are
//! ordered by descending |value|.

use crate::sparse::csr::TopkCodes;
use crate::util::matrix::Matrix;

/// Which selection algorithm to use (bench harness sweeps both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopkAlgo {
    PartialSelect,
    FullSort,
}

#[inline]
fn key(v: f32, j: usize) -> (f32, usize) {
    // Order: |v| descending, then index ascending.
    (v.abs(), j)
}

#[inline]
fn better(a: (f32, usize), b: (f32, usize)) -> bool {
    // true if a should come before b.
    a.0 > b.0 || (a.0 == b.0 && a.1 < b.1)
}

/// Partial-selection top-k (the default / fast path).
pub fn topk_codes(x: &Matrix, k: usize) -> TopkCodes {
    topk_with(x, k, TopkAlgo::PartialSelect)
}

/// Full-sort top-k (the torch.topk-analog baseline).
pub fn topk_codes_full_sort(x: &Matrix, k: usize) -> TopkCodes {
    topk_with(x, k, TopkAlgo::FullSort)
}

/// Top-k with an explicit algorithm choice.
pub fn topk_with(x: &Matrix, k: usize, algo: TopkAlgo) -> TopkCodes {
    assert!(k >= 1 && k <= x.cols, "k={} out of range for d={}", k, x.cols);
    assert!(x.cols <= u16::MAX as usize + 1);
    let mut vals = vec![0f32; x.rows * k];
    let mut idx = vec![0u16; x.rows * k];
    let mut scratch: Vec<usize> = Vec::with_capacity(x.cols);
    for i in 0..x.rows {
        let row = x.row(i);
        scratch.clear();
        scratch.extend(0..x.cols);
        match algo {
            TopkAlgo::PartialSelect => {
                if k < x.cols {
                    scratch.select_nth_unstable_by(k - 1, |&a, &b| {
                        if better(key(row[a], a), key(row[b], b)) {
                            std::cmp::Ordering::Less
                        } else {
                            std::cmp::Ordering::Greater
                        }
                    });
                }
                scratch.truncate(k);
                scratch.sort_unstable_by(|&a, &b| {
                    if better(key(row[a], a), key(row[b], b)) {
                        std::cmp::Ordering::Less
                    } else {
                        std::cmp::Ordering::Greater
                    }
                });
            }
            TopkAlgo::FullSort => {
                scratch.sort_by(|&a, &b| {
                    if better(key(row[a], a), key(row[b], b)) {
                        std::cmp::Ordering::Less
                    } else {
                        std::cmp::Ordering::Greater
                    }
                });
                scratch.truncate(k);
            }
        }
        for (slot, &j) in scratch.iter().enumerate() {
            vals[i * k + slot] = row[j];
            idx[i * k + slot] = j as u16;
        }
    }
    TopkCodes { rows: x.rows, dim: x.cols, k, vals, idx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn selects_largest_magnitudes() {
        let m = Matrix::from_vec(1, 6, vec![0.5, -3.0, 1.0, 2.0, -0.1, 0.0]);
        let c = topk_codes(&m, 3);
        assert_eq!(c.row_idx(0), &[1, 3, 2]);
        assert_eq!(c.row_vals(0), &[-3.0, 2.0, 1.0]);
    }

    #[test]
    fn tie_breaks_toward_lower_index() {
        let m = Matrix::from_vec(1, 4, vec![1.0, -1.0, 1.0, 1.0]);
        let c = topk_codes(&m, 2);
        assert_eq!(c.row_idx(0), &[0, 1]);
        let c = topk_codes_full_sort(&m, 2);
        assert_eq!(c.row_idx(0), &[0, 1]);
    }

    #[test]
    fn k_equals_d_keeps_everything() {
        let mut rng = Rng::new(0);
        let m = Matrix::randn(4, 8, &mut rng, 1.0);
        let c = topk_codes(&m, 8);
        crate::util::matrix::assert_close(&c.densify(), &m, 0.0, 0.0);
    }

    #[test]
    fn algorithms_agree() {
        check("partial-select == full-sort", 64, |g| {
            let rows = g.usize_in(1..8);
            let d = *g.choose(&[4usize, 16, 64, 128]);
            let k = g.usize_in(1..d + 1);
            let data = g.vec_normal(rows * d, 1.0);
            let m = Matrix::from_vec(rows, d, data);
            let a = topk_with(&m, k, TopkAlgo::PartialSelect);
            let b = topk_with(&m, k, TopkAlgo::FullSort);
            assert_eq!(a, b);
        });
    }

    #[test]
    fn output_sorted_by_magnitude_desc() {
        check("magnitude ordering", 32, |g| {
            let d = 32;
            let m = Matrix::from_vec(2, d, g.vec_normal(2 * d, 2.0));
            let c = topk_codes(&m, 8);
            for i in 0..2 {
                let v = c.row_vals(i);
                for w in v.windows(2) {
                    assert!(w[0].abs() >= w[1].abs());
                }
            }
        });
    }

    #[test]
    fn indices_unique_per_row() {
        check("unique indices", 32, |g| {
            let d = 64;
            let m = Matrix::from_vec(3, d, g.vec_normal(3 * d, 1.0));
            let c = topk_codes(&m, 16);
            for i in 0..3 {
                let mut seen = [false; 64];
                for &f in c.row_idx(i) {
                    assert!(!seen[f as usize], "duplicate feature {f}");
                    seen[f as usize] = true;
                }
            }
        });
    }

    #[test]
    fn dropped_entries_are_smaller() {
        check("dropped <= kept", 32, |g| {
            let d = 32;
            let k = 8;
            let m = Matrix::from_vec(1, d, g.vec_normal(d, 1.0));
            let c = topk_codes(&m, k);
            let kept: Vec<u16> = c.row_idx(0).to_vec();
            let min_kept = c.row_vals(0).iter().map(|v| v.abs()).fold(f32::MAX, f32::min);
            for j in 0..d {
                if !kept.contains(&(j as u16)) {
                    assert!(m.get(0, j).abs() <= min_kept + 1e-7);
                }
            }
        });
    }
}
