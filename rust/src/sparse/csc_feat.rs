//! Feature-wise CSC posting lists — the paper's CSC_feat(K) format
//! (App. C.3): for every feature id f, the ascending list of key/token
//! ids that activate f, with their values. The FlashSFA inner loop
//! walks the query row's features and binary-searches each posting list
//! down to the current key tile (App. C Algorithm 1, line 10).

use crate::sparse::csr::TopkCodes;

/// Posting lists over features: column = feature id, rows = token ids.
#[derive(Debug, Clone, PartialEq)]
pub struct CscFeat {
    /// Number of tokens (keys).
    pub n_tokens: usize,
    /// Dense feature dimension d.
    pub dim: usize,
    /// len dim+1; posting list for feature f is tokens[indptr[f]..indptr[f+1]].
    pub indptr: Vec<u32>,
    /// Token ids, ascending within each posting list.
    pub token_ids: Vec<u32>,
    /// Key values aligned with `token_ids`.
    pub vals: Vec<f32>,
}

impl CscFeat {
    /// Build from padded top-k key codes by counting sort over features.
    /// O(n·k + d); token ids come out ascending per feature because we
    /// scan tokens in order.
    pub fn from_codes(codes: &TopkCodes) -> CscFeat {
        let d = codes.dim;
        let mut counts = vec![0u32; d + 1];
        for t in 0..codes.rows {
            for (&f, &v) in codes.row_idx(t).iter().zip(codes.row_vals(t)) {
                if v != 0.0 {
                    counts[f as usize + 1] += 1;
                }
            }
        }
        for f in 0..d {
            counts[f + 1] += counts[f];
        }
        let indptr = counts.clone();
        let nnz = indptr[d] as usize;
        let mut token_ids = vec![0u32; nnz];
        let mut vals = vec![0f32; nnz];
        let mut cursor = indptr.clone();
        for t in 0..codes.rows {
            for (&f, &v) in codes.row_idx(t).iter().zip(codes.row_vals(t)) {
                if v != 0.0 {
                    let slot = cursor[f as usize] as usize;
                    token_ids[slot] = t as u32;
                    vals[slot] = v;
                    cursor[f as usize] += 1;
                }
            }
        }
        CscFeat { n_tokens: codes.rows, dim: d, indptr, token_ids, vals }
    }

    pub fn nnz(&self) -> usize {
        self.token_ids.len()
    }

    /// Posting list (token ids, values) for a feature.
    pub fn posting(&self, f: usize) -> (&[u32], &[f32]) {
        let r = self.indptr[f] as usize..self.indptr[f + 1] as usize;
        (&self.token_ids[r.clone()], &self.vals[r])
    }

    /// BINARY_SEARCH_RANGE (App. C Algorithm 1, line 10): the sub-range
    /// of feature f's posting list whose token ids fall in [lo, hi).
    /// Returns absolute offsets into `token_ids` / `vals`.
    pub fn posting_range(&self, f: usize, lo: u32, hi: u32) -> std::ops::Range<usize> {
        let start = self.indptr[f] as usize;
        let end = self.indptr[f + 1] as usize;
        let list = &self.token_ids[start..end];
        let a = list.partition_point(|&t| t < lo);
        let b = list.partition_point(|&t| t < hi);
        start + a..start + b
    }

    /// Per-feature degree histogram deg(u) (paper Eq. 7's load-balance
    /// quantity; also feeds the Fig. 7 entropy analysis).
    pub fn degrees(&self) -> Vec<u32> {
        (0..self.dim)
            .map(|f| self.indptr[f + 1] - self.indptr[f])
            .collect()
    }

    /// Predicted number of query-key overlap pairs Σ_u deg_q(u)·deg_k(u)
    /// (paper Eq. 7 generalized to distinct Q/K supports).
    pub fn predicted_overlaps(q_degrees: &[u32], k_degrees: &[u32]) -> u64 {
        q_degrees
            .iter()
            .zip(k_degrees)
            .map(|(&a, &b)| a as u64 * b as u64)
            .sum()
    }

    /// Build the per-(feature, key-tile) block index used by the
    /// block-skipping FlashSFA kernel. O(nnz + dim · n_tiles).
    pub fn block_index(&self, tile: usize) -> CscBlockIndex {
        CscBlockIndex::build(self, tile)
    }

    /// Structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.dim + 1 {
            return Err("indptr length".into());
        }
        if *self.indptr.last().unwrap() as usize != self.nnz() {
            return Err("indptr end".into());
        }
        for f in 0..self.dim {
            let (toks, _) = self.posting(f);
            for w in toks.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("posting list {f} not strictly ascending"));
                }
            }
            if let Some(&last) = toks.last() {
                if last as usize >= self.n_tokens {
                    return Err("token id out of range".into());
                }
            }
        }
        Ok(())
    }
}

/// Block index over a [`CscFeat`]: key tiles of `tile` tokens, and for
/// every (feature, tile) cell the posting sub-range plus a max-|value|
/// summary. The block-skipping FlashSFA kernel classifies each key tile
/// from this in O(k) per query row — *empty* cells (zero degree) fold
/// into the softmax in O(1) per row, and the max-|value| summaries give
/// a tile score upper bound for threshold skipping ("Block Sparse Flash
/// Attention"-style, driven by feature overlap instead of a learned
/// block mask).
#[derive(Debug, Clone, PartialEq)]
pub struct CscBlockIndex {
    /// Tokens per key tile (the kernel's Bc).
    pub tile: usize,
    pub n_tiles: usize,
    pub dim: usize,
    /// dim × (n_tiles + 1), row-major: `starts[f · (n_tiles+1) + t]` is
    /// the absolute offset into `token_ids`/`vals` of the first posting
    /// of feature f with token id ≥ t·tile; the cell's range is
    /// `starts[f][t]..starts[f][t+1]` (so the trailing entry is
    /// `indptr[f+1]`).
    pub starts: Vec<u32>,
    /// dim × n_tiles, row-major: max |value| within the cell, 0.0 when
    /// the cell is empty.
    pub max_abs: Vec<f32>,
}

impl CscBlockIndex {
    pub fn build(feat: &CscFeat, tile: usize) -> CscBlockIndex {
        assert!(tile >= 1, "tile width must be >= 1");
        let n_tiles = feat.n_tokens.div_ceil(tile).max(1);
        let stride = n_tiles + 1;
        let mut starts = vec![0u32; feat.dim * stride];
        let mut max_abs = vec![0f32; feat.dim * n_tiles];
        for f in 0..feat.dim {
            let base = feat.indptr[f];
            let end = feat.indptr[f + 1];
            let row = &mut starts[f * stride..(f + 1) * stride];
            row[0] = base;
            // One monotone walk over the posting list: emit each tile
            // boundary as the walk crosses it, fold |v| into the cell.
            let mut t = 0usize;
            for c in base..end {
                let tok = feat.token_ids[c as usize] as usize;
                let cell = tok / tile;
                while t < cell {
                    t += 1;
                    row[t] = c;
                }
                let m = &mut max_abs[f * n_tiles + cell];
                *m = m.max(feat.vals[c as usize].abs());
            }
            while t < n_tiles {
                t += 1;
                row[t] = end;
            }
        }
        CscBlockIndex { tile, n_tiles, dim: feat.dim, starts, max_abs }
    }

    /// Absolute posting offset of the first posting of feature `f` in
    /// tile `t` (or past it, when the cell is empty). `t == n_tiles`
    /// gives the end of the feature's posting list.
    #[inline]
    pub fn start(&self, f: usize, t: usize) -> u32 {
        self.starts[f * (self.n_tiles + 1) + t]
    }

    /// Posting sub-range of the (feature, tile) cell, as absolute
    /// offsets into the parent's `token_ids` / `vals`.
    #[inline]
    pub fn range(&self, f: usize, t: usize) -> std::ops::Range<usize> {
        self.start(f, t) as usize..self.start(f, t + 1) as usize
    }

    /// Number of postings of feature `f` inside tile `t`.
    #[inline]
    pub fn degree(&self, f: usize, t: usize) -> u32 {
        self.start(f, t + 1) - self.start(f, t)
    }

    /// Max |value| of feature `f` inside tile `t` (0.0 when empty).
    #[inline]
    pub fn cell_max_abs(&self, f: usize, t: usize) -> f32 {
        self.max_abs[f * self.n_tiles + t]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::topk::topk_codes;
    use crate::util::matrix::Matrix;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn fixture(n: usize, d: usize, k: usize, seed: u64) -> (TopkCodes, CscFeat) {
        let mut rng = Rng::new(seed);
        let m = Matrix::randn(n, d, &mut rng, 1.0);
        let codes = topk_codes(&m, k);
        let feat = CscFeat::from_codes(&codes);
        (codes, feat)
    }

    #[test]
    fn nnz_conserved() {
        let (codes, feat) = fixture(32, 64, 8, 0);
        feat.validate().unwrap();
        assert_eq!(feat.nnz(), codes.rows * codes.k); // gaussian: no zeros
    }

    #[test]
    fn transpose_consistency() {
        // Every (token, feature, value) triple in the codes appears in
        // exactly the right posting list.
        let (codes, feat) = fixture(16, 32, 4, 1);
        for t in 0..codes.rows {
            for (&f, &v) in codes.row_idx(t).iter().zip(codes.row_vals(t)) {
                let (toks, vals) = feat.posting(f as usize);
                let pos = toks.binary_search(&(t as u32)).expect("token in posting");
                assert_eq!(vals[pos], v);
            }
        }
    }

    #[test]
    fn posting_range_matches_linear_scan() {
        check("binary search range", 48, |g| {
            let n = g.usize_in(4..64);
            let d = 32;
            let k = g.usize_in(1..9);
            let (_, feat) = fixture(n, d, k, g.seed);
            let f = g.usize_in(0..d);
            let lo = g.usize_in(0..n) as u32;
            let hi = (lo + g.usize_in(0..n + 1) as u32).min(n as u32);
            let r = feat.posting_range(f, lo, hi);
            let (toks, _) = feat.posting(f);
            let expected: Vec<u32> = toks.iter().copied().filter(|&t| t >= lo && t < hi).collect();
            let got: Vec<u32> = feat.token_ids[r].to_vec();
            assert_eq!(got, expected);
        });
    }

    #[test]
    fn degrees_sum_to_nnz() {
        let (_, feat) = fixture(24, 48, 6, 2);
        let sum: u32 = feat.degrees().iter().sum();
        assert_eq!(sum as usize, feat.nnz());
    }

    #[test]
    fn predicted_overlaps_eq7_balanced_approximation() {
        // With Gaussian features the supports should be roughly balanced,
        // so Σ deg² should be within ~2x of d·(nk/d)² (paper Eq. 7).
        let n = 256;
        let d = 64;
        let k = 8;
        let (_, feat) = fixture(n, d, k, 3);
        let deg = feat.degrees();
        let actual = CscFeat::predicted_overlaps(&deg, &deg) as f64;
        let ideal = d as f64 * ((n * k) as f64 / d as f64).powi(2);
        assert!(actual >= ideal, "Cauchy-Schwarz: balanced is the minimum");
        assert!(actual < 2.0 * ideal, "supports badly imbalanced: {actual} vs {ideal}");
    }

    #[test]
    fn from_codes_with_explicit_zero_padding() {
        // Padded codes carry explicit zero values (rows with fewer than
        // k live features); the CSC build must drop them while keeping
        // every structural invariant — for arbitrary sparse inputs, not
        // just Gaussian fixtures.
        check("csc_feat from padded codes", 48, |g| {
            let rows = g.usize_in(1..24);
            let d = g.usize_in(2..48);
            let k = g.usize_in(1..d.min(9));
            let mut vals = vec![0f32; rows * k];
            let mut idx = vec![0u16; rows * k];
            let mut nonzero = 0usize;
            let mut feats: Vec<u16> = (0..d as u16).collect();
            for t in 0..rows {
                // Distinct features per row via partial Fisher-Yates.
                for slot in 0..k {
                    let j = g.usize_in(slot..d);
                    feats.swap(slot, j);
                    idx[t * k + slot] = feats[slot];
                    // ~30% of the slots stay explicit zeros (padding).
                    if g.usize_in(0..10) >= 3 {
                        let sign = if g.bool() { 1.0 } else { -1.0 };
                        vals[t * k + slot] = sign * g.f32_in(0.5..2.0);
                        nonzero += 1;
                    }
                }
            }
            let codes = TopkCodes { rows, dim: d, k, vals, idx };
            let feat = CscFeat::from_codes(&codes);
            feat.validate().unwrap();
            assert_eq!(feat.nnz(), nonzero, "nnz must count only nonzero entries");
            let degree_sum: u32 = feat.degrees().iter().sum();
            assert_eq!(degree_sum as usize, nonzero);
            // Every nonzero (token, feature, value) triple survives.
            for t in 0..rows {
                for (&f, &v) in codes.row_idx(t).iter().zip(codes.row_vals(t)) {
                    if v != 0.0 {
                        let (toks, vs) = feat.posting(f as usize);
                        let pos = toks
                            .binary_search(&(t as u32))
                            .expect("nonzero entry present in posting");
                        assert_eq!(vs[pos], v);
                    }
                }
            }
        });
    }

    #[test]
    fn block_index_ranges_match_binary_search() {
        // Every (feature, tile) cell of the block index must agree with
        // posting_range on the same token window, and the max-|value|
        // summary must equal the true max over that window.
        check("block index == posting_range", 48, |g| {
            let n = g.usize_in(1..96);
            let d = 32;
            let k = g.usize_in(1..9);
            let tile = *g.choose(&[1usize, 3, 8, 16, 64]);
            let (_, feat) = fixture(n, d, k, g.seed);
            let bi = feat.block_index(tile);
            assert_eq!(bi.n_tiles, n.div_ceil(tile).max(1));
            for f in 0..d {
                for t in 0..bi.n_tiles {
                    let lo = (t * tile) as u32;
                    let hi = ((t + 1) * tile).min(n) as u32;
                    let expect = feat.posting_range(f, lo, hi.max(lo));
                    assert_eq!(bi.range(f, t), expect, "f={f} t={t}");
                    assert_eq!(bi.degree(f, t) as usize, expect.len());
                    let true_max = feat.vals[expect]
                        .iter()
                        .fold(0f32, |a, &v| a.max(v.abs()));
                    assert_eq!(bi.cell_max_abs(f, t), true_max, "f={f} t={t}");
                }
                assert_eq!(bi.start(f, bi.n_tiles), feat.indptr[f + 1]);
            }
        });
    }

    #[test]
    fn block_index_degenerate_shapes() {
        // Zero tokens and tile widths larger than the sequence.
        let codes = TopkCodes { rows: 0, dim: 4, k: 2, vals: vec![], idx: vec![] };
        let feat = CscFeat::from_codes(&codes);
        let bi = feat.block_index(8);
        assert_eq!(bi.n_tiles, 1);
        for f in 0..4 {
            assert_eq!(bi.degree(f, 0), 0);
            assert_eq!(bi.cell_max_abs(f, 0), 0.0);
        }
        let (_, feat) = fixture(5, 16, 2, 11);
        let bi = feat.block_index(64);
        assert_eq!(bi.n_tiles, 1);
        let total: u32 = (0..16).map(|f| bi.degree(f, 0)).sum();
        assert_eq!(total as usize, feat.nnz());
    }

    #[test]
    fn empty_features_have_empty_postings() {
        // Force all tokens onto feature 0..k by making those huge.
        let mut m = Matrix::zeros(8, 16);
        for i in 0..8 {
            for j in 0..4 {
                m.set(i, j, 100.0 + j as f32);
            }
            for j in 4..16 {
                m.set(i, j, 0.001);
            }
        }
        let codes = topk_codes(&m, 4);
        let feat = CscFeat::from_codes(&codes);
        for f in 4..16 {
            assert_eq!(feat.posting(f).0.len(), 0);
        }
        assert_eq!(feat.posting(0).0.len(), 8);
    }
}
