//! CSR storage (paper §2 "Sparse formats for efficient storage") and the
//! fixed-k padded code format produced by row-wise top-k.
//!
//! Index width follows the paper's choice (App. B/J): feature ids fit in
//! u16 for any d ≤ 65,535 (u8 would cover the d ≤ 256 configs; we keep
//! u16 for uniformity and count bytes for both in [`super::memory`]).

use crate::util::matrix::Matrix;

/// Padded top-k sparse codes: exactly `k` (value, feature) pairs per row,
/// ordered by descending |value|. The natural output of row-wise top-k
/// and the input format of the FlashSFA kernels (both Pallas and CPU).
#[derive(Debug, Clone, PartialEq)]
pub struct TopkCodes {
    pub rows: usize,
    /// Dense feature dimension d the codes were selected from.
    pub dim: usize,
    /// Nonzeros per row.
    pub k: usize,
    /// len rows*k, row-major.
    pub vals: Vec<f32>,
    /// len rows*k, feature ids.
    pub idx: Vec<u16>,
}

impl TopkCodes {
    pub fn row_vals(&self, i: usize) -> &[f32] {
        &self.vals[i * self.k..(i + 1) * self.k]
    }

    pub fn row_idx(&self, i: usize) -> &[u16] {
        &self.idx[i * self.k..(i + 1) * self.k]
    }

    /// Scatter back to a dense matrix (inverse of top-k up to dropped
    /// coordinates) — the oracle-side of kernel tests.
    pub fn densify(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.dim);
        for i in 0..self.rows {
            let row = m.row_mut(i);
            for (v, &f) in self.row_vals(i).iter().zip(self.row_idx(i)) {
                row[f as usize] = *v;
            }
        }
        m
    }

    /// Dot product of two code rows over their support intersection
    /// (paper Eq. 5, unscaled). O(k²) pairwise compare — the scalar
    /// reference for the engines' vectorized versions.
    pub fn overlap_dot(&self, i: usize, other: &TopkCodes, j: usize) -> f32 {
        let (av, ai) = (self.row_vals(i), self.row_idx(i));
        let (bv, bi) = (other.row_vals(j), other.row_idx(j));
        let mut acc = 0.0;
        for (x, &fx) in av.iter().zip(ai) {
            for (y, &fy) in bv.iter().zip(bi) {
                if fx == fy {
                    acc += x * y;
                }
            }
        }
        acc
    }
}

/// General CSR sparse matrix (u32 indptr, u16 column indices, f32 data),
/// matching the paper's storage layout (§2).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u16>,
    pub data: Vec<f32>,
}

impl CsrMatrix {
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.indptr[i] as usize..self.indptr[i + 1] as usize
    }

    /// Build from padded codes (drops explicit zeros, sorts each row's
    /// indices ascending — canonical CSR ordering).
    pub fn from_codes(codes: &TopkCodes) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(codes.rows + 1);
        let mut indices = Vec::with_capacity(codes.rows * codes.k);
        let mut data = Vec::with_capacity(codes.rows * codes.k);
        indptr.push(0u32);
        let mut row: Vec<(u16, f32)> = Vec::with_capacity(codes.k);
        for i in 0..codes.rows {
            row.clear();
            for (v, &f) in codes.row_vals(i).iter().zip(codes.row_idx(i)) {
                if *v != 0.0 {
                    row.push((f, *v));
                }
            }
            row.sort_unstable_by_key(|&(f, _)| f);
            for &(f, v) in &row {
                indices.push(f);
                data.push(v);
            }
            indptr.push(indices.len() as u32);
        }
        CsrMatrix { rows: codes.rows, cols: codes.dim, indptr, indices, data }
    }

    /// Build from a dense matrix keeping all nonzeros.
    pub fn from_dense(m: &Matrix) -> CsrMatrix {
        assert!(m.cols <= u16::MAX as usize + 1);
        let mut indptr = vec![0u32];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for i in 0..m.rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u16);
                    data.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        CsrMatrix { rows: m.rows, cols: m.cols, indptr, indices, data }
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let row = m.row_mut(i);
            for t in self.row_range(i) {
                row[self.indices[t] as usize] = self.data[t];
            }
        }
        m
    }

    /// Structural invariants (used by property tests + debug assertions).
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.rows + 1 {
            return Err(format!("indptr len {} != rows+1 {}", self.indptr.len(), self.rows + 1));
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() as usize != self.nnz() {
            return Err("indptr endpoints wrong".into());
        }
        for w in self.indptr.windows(2) {
            if w[0] > w[1] {
                return Err("indptr not monotone".into());
            }
        }
        for i in 0..self.rows {
            let r = self.row_range(i);
            for t in r.clone() {
                if self.indices[t] as usize >= self.cols {
                    return Err(format!("col {} out of bounds", self.indices[t]));
                }
            }
            for t in r.start + 1..r.end {
                if self.indices[t - 1] >= self.indices[t] {
                    return Err(format!("row {i} indices not strictly ascending"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::topk::topk_codes;
    use crate::util::matrix::assert_close;
    use crate::util::rng::Rng;

    fn codes_fixture(rows: usize, dim: usize, k: usize, seed: u64) -> (Matrix, TopkCodes) {
        let mut rng = Rng::new(seed);
        let m = Matrix::randn(rows, dim, &mut rng, 1.0);
        let c = topk_codes(&m, k);
        (m, c)
    }

    #[test]
    fn densify_preserves_topk_entries() {
        let (m, c) = codes_fixture(8, 32, 4, 0);
        let d = c.densify();
        // Each row of d has exactly k nonzeros, all matching m.
        for i in 0..8 {
            let nnz = d.row(i).iter().filter(|&&x| x != 0.0).count();
            assert_eq!(nnz, 4);
            for j in 0..32 {
                if d.get(i, j) != 0.0 {
                    assert_eq!(d.get(i, j), m.get(i, j));
                }
            }
        }
    }

    #[test]
    fn csr_roundtrip() {
        let (_, c) = codes_fixture(16, 64, 8, 1);
        let csr = CsrMatrix::from_codes(&c);
        csr.validate().unwrap();
        assert_close(&csr.to_dense(), &c.densify(), 1e-7, 0.0);
    }

    #[test]
    fn csr_from_dense_roundtrip() {
        let (m, _) = codes_fixture(8, 16, 4, 2);
        let csr = CsrMatrix::from_dense(&m);
        csr.validate().unwrap();
        assert_close(&csr.to_dense(), &m, 0.0, 0.0);
        assert_eq!(csr.nnz(), 8 * 16); // gaussian entries are all nonzero
    }

    #[test]
    fn overlap_dot_matches_dense_dot() {
        let (_, a) = codes_fixture(6, 32, 5, 3);
        let (_, b) = codes_fixture(6, 32, 5, 4);
        let da = a.densify();
        let db = b.densify();
        for i in 0..6 {
            for j in 0..6 {
                let dense: f32 = da.row(i).iter().zip(db.row(j)).map(|(x, y)| x * y).sum();
                let sparse = a.overlap_dot(i, &b, j);
                assert!((dense - sparse).abs() < 1e-5, "{dense} vs {sparse}");
            }
        }
    }

    #[test]
    fn csr_drops_explicit_zeros() {
        let codes = TopkCodes {
            rows: 1, dim: 8, k: 3,
            vals: vec![1.0, 0.0, -2.0],
            idx: vec![3, 5, 7],
        };
        let csr = CsrMatrix::from_codes(&codes);
        assert_eq!(csr.nnz(), 2);
        csr.validate().unwrap();
    }

    #[test]
    fn validate_catches_corruption() {
        let (_, c) = codes_fixture(4, 16, 2, 5);
        let mut csr = CsrMatrix::from_codes(&c);
        csr.indices[0] = 999; // out of bounds for cols=16
        assert!(csr.validate().is_err());
    }
}
