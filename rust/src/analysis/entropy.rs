//! Top-k selection load balance (paper Fig. 7 / App. F): normalized
//! entropy of the feature-index histogram per head. High entropy
//! (≈0.9+) means the supports spread across dimensions — the property
//! Eq. 7's balanced-load cost model assumes.

use crate::sparse::topk_codes;
use crate::util::matrix::Matrix;
use crate::util::stats::normalized_entropy;

/// Histogram of selected feature ids for one activation matrix.
pub fn selection_histogram(x: &Matrix, k: usize) -> Vec<u64> {
    let codes = topk_codes(x, k);
    let mut counts = vec![0u64; x.cols];
    for i in 0..codes.rows {
        for (&f, &v) in codes.row_idx(i).iter().zip(codes.row_vals(i)) {
            if v != 0.0 {
                counts[f as usize] += 1;
            }
        }
    }
    counts
}

/// Normalized entropy of top-k selection (Fig. 7 cell value).
pub fn selection_entropy(x: &Matrix, k: usize) -> f64 {
    normalized_entropy(&selection_histogram(x, k))
}

/// Per-(layer, head) entropy grid from stacked activations.
/// `acts[layer][head]` is the (n, d) activation matrix.
pub fn entropy_grid(acts: &[Vec<Matrix>], k: usize) -> Vec<Vec<f64>> {
    acts.iter()
        .map(|heads| heads.iter().map(|m| selection_entropy(m, k)).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gaussian_features_are_balanced() {
        // Isotropic activations select nearly uniformly: entropy > 0.95
        // (the paper reports 0.85–0.98 on trained models).
        let mut rng = Rng::new(0);
        let x = Matrix::randn(512, 64, &mut rng, 1.0);
        let e = selection_entropy(&x, 8);
        assert!(e > 0.95, "entropy {e}");
    }

    #[test]
    fn collapsed_features_have_low_entropy() {
        // Activations dominated by 2 fixed dimensions.
        let mut rng = Rng::new(1);
        let mut x = Matrix::randn(512, 64, &mut rng, 0.1);
        for i in 0..512 {
            x.set(i, 3, 10.0);
            x.set(i, 17, -9.0);
        }
        // Two active dims out of 64: H = ln2/ln64 ≈ 0.167 ≪ balanced.
        let e = selection_entropy(&x, 2);
        assert!(e < 0.2, "entropy {e}");
    }

    #[test]
    fn histogram_counts_sum_to_nk() {
        let mut rng = Rng::new(2);
        let x = Matrix::randn(100, 32, &mut rng, 1.0);
        let h = selection_histogram(&x, 4);
        assert_eq!(h.iter().sum::<u64>(), 400);
    }

    #[test]
    fn grid_shape_matches_input() {
        let mut rng = Rng::new(3);
        let acts: Vec<Vec<Matrix>> = (0..3)
            .map(|_| (0..2).map(|_| Matrix::randn(64, 16, &mut rng, 1.0)).collect())
            .collect();
        let g = entropy_grid(&acts, 4);
        assert_eq!(g.len(), 3);
        assert!(g.iter().all(|row| row.len() == 2));
        assert!(g.iter().flatten().all(|&e| (0.0..=1.0).contains(&e)));
    }
}
