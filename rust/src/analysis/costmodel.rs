//! Latency scaling fits + extrapolation.
//!
//! CPU wall-clock at 128k-dense is hours, so the Latency@128k columns
//! (Tables 1/10) are produced the way App. B.1 analyzes them: measure
//! a sweep of feasible context lengths, fit log(t) = α·log(n) + c
//! (the paper observes α ≈ 2 for prefill, ≈ 1 for decode), and
//! extrapolate. Both measured points and the fit are reported in
//! EXPERIMENTS.md so the extrapolation is auditable.

/// Least-squares fit of y = a·x + b.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let a = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let b = (sy - a * sx) / n;
    (a, b)
}

/// Power-law latency model t(n) = c·n^α fit in log-log space.
#[derive(Debug, Clone, Copy)]
pub struct PowerLaw {
    pub alpha: f64,
    pub log_c: f64,
}

impl PowerLaw {
    pub fn fit(ns: &[usize], times_s: &[f64]) -> PowerLaw {
        let xs: Vec<f64> = ns.iter().map(|&n| (n as f64).ln()).collect();
        let ys: Vec<f64> = times_s.iter().map(|&t| t.max(1e-12).ln()).collect();
        let (alpha, log_c) = linear_fit(&xs, &ys);
        PowerLaw { alpha, log_c }
    }

    pub fn predict(&self, n: usize) -> f64 {
        (self.log_c + self.alpha * (n as f64).ln()).exp()
    }

    /// R² of the fit on the training points.
    pub fn r2(&self, ns: &[usize], times_s: &[f64]) -> f64 {
        let ys: Vec<f64> = times_s.iter().map(|&t| t.ln()).collect();
        let mean = ys.iter().sum::<f64>() / ys.len() as f64;
        let ss_tot: f64 = ys.iter().map(|y| (y - mean) * (y - mean)).sum();
        let ss_res: f64 = ns
            .iter()
            .zip(&ys)
            .map(|(&n, y)| {
                let p = self.log_c + self.alpha * (n as f64).ln();
                (y - p) * (y - p)
            })
            .sum();
        1.0 - ss_res / ss_tot.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_fit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-9);
        assert!((b - 1.0).abs() < 1e-9);
    }

    #[test]
    fn recovers_quadratic_exponent() {
        let ns = [1024usize, 2048, 4096, 8192];
        let ts: Vec<f64> = ns.iter().map(|&n| 3e-9 * (n as f64).powi(2)).collect();
        let pl = PowerLaw::fit(&ns, &ts);
        assert!((pl.alpha - 2.0).abs() < 1e-6);
        let pred = pl.predict(131072);
        let exact = 3e-9 * (131072f64).powi(2);
        assert!((pred - exact).abs() / exact < 1e-6);
    }

    #[test]
    fn r2_near_one_for_clean_power_law() {
        let ns = [512usize, 1024, 2048, 4096, 8192];
        let ts: Vec<f64> = ns.iter().map(|&n| 1e-7 * (n as f64).powf(1.5)).collect();
        let pl = PowerLaw::fit(&ns, &ts);
        assert!(pl.r2(&ns, &ts) > 0.9999);
    }

    #[test]
    fn noisy_fit_still_reasonable() {
        let ns = [1024usize, 2048, 4096, 8192, 16384];
        // ±10% multiplicative noise.
        let noise = [1.05, 0.95, 1.08, 0.93, 1.02];
        let ts: Vec<f64> = ns
            .iter()
            .zip(noise)
            .map(|(&n, z)| 2e-9 * (n as f64).powi(2) * z)
            .collect();
        let pl = PowerLaw::fit(&ns, &ts);
        assert!((pl.alpha - 2.0).abs() < 0.1, "{}", pl.alpha);
    }
}
