//! Measurement & modeling: the quantitative backbone of the paper's
//! evaluation tables that are *models* rather than wall-clock runs.
//!
//! * [`flops`] — FLOP / INOP cost model (Table 6), validated against
//!   instrumented SpGEMM counts
//! * [`bandwidth`] — bytes-moved model + host memory-bandwidth
//!   microbench (Table 7)
//! * [`entropy`] — top-k feature-selection load balance (Fig. 7)
//! * [`svd`] — Jacobi eigensolver + effective rank (Fig. 11)
//! * [`costmodel`] — power-law latency fit + extrapolation to contexts
//!   too large to measure on CPU (the 128k columns of Tables 1/10)

pub mod bandwidth;
pub mod costmodel;
pub mod entropy;
pub mod flops;
pub mod pallas_est;
pub mod svd;
