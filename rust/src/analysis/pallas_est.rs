//! L1 (Pallas/TPU) performance estimates.
//!
//! Interpret-mode Pallas gives CPU-numpy timings only — not a TPU
//! proxy — so the kernel's TPU story is argued structurally (DESIGN.md
//! §Perf): VMEM footprint per grid step from the BlockSpecs, arithmetic
//! intensity, and the MXU/VPU utilization ceiling implied by the
//! masked-k×k-intersection formulation. These estimates gate the
//! block-size choices compiled into `python/compile/kernels/
//! flash_sfa.py` and are reproduced in EXPERIMENTS.md §Perf.

/// TPU-v4-ish machine model (per-core).
#[derive(Debug, Clone, Copy)]
pub struct TpuModel {
    pub vmem_bytes: usize,       // ~16 MiB
    pub mxu_flops_per_s: f64,    // bf16 matmul peak
    pub vpu_flops_per_s: f64,    // vector unit peak
    pub hbm_bytes_per_s: f64,
}

impl TpuModel {
    pub const V4: TpuModel = TpuModel {
        vmem_bytes: 16 << 20,
        mxu_flops_per_s: 137.5e12,
        vpu_flops_per_s: 4.3e12,
        hbm_bytes_per_s: 1.2e12,
    };
}

/// FlashSFA kernel tile configuration (mirrors the Pallas BlockSpecs).
#[derive(Debug, Clone, Copy)]
pub struct SfaTile {
    pub block_q: usize,
    pub block_k: usize,
    pub k: usize,
    pub d_v: usize,
    pub elem_bytes: usize, // 4 for f32, 2 for bf16
}

impl SfaTile {
    /// VMEM bytes live during one grid step. The dominant term is the
    /// (Bq, Bk, k, k) match/product intermediate of the masked outer
    /// product; the rest is codes, V tile, score tile and the online
    /// softmax state.
    pub fn vmem_bytes(&self) -> usize {
        let e = self.elem_bytes;
        let match_prod = 2 * self.block_q * self.block_k * self.k * self.k * e;
        let scores = self.block_q * self.block_k * e;
        let q_codes = 2 * self.block_q * self.k * e;
        let k_codes = 2 * self.block_k * self.k * e;
        let v_tile = self.block_k * self.d_v * e;
        let softmax_state = self.block_q * (2 + self.d_v) * e;
        match_prod + scores + q_codes + k_codes + v_tile + softmax_state
    }

    /// Does the tile fit VMEM with double-buffering headroom (×2 on the
    /// streamed operands, ~25% reserve)?
    pub fn fits(&self, model: TpuModel) -> bool {
        (self.vmem_bytes() as f64) * 1.25 < model.vmem_bytes as f64 / 2.0
    }

    /// FLOPs per tile: intersection contraction (VPU) + P·V (MXU).
    pub fn tile_flops(&self) -> (u64, u64) {
        let vpu = 2 * (self.block_q * self.block_k * self.k * self.k) as u64;
        let mxu = 2 * (self.block_q * self.block_k * self.d_v) as u64;
        (vpu, mxu)
    }

    /// HBM bytes streamed per tile step: K codes (values + indices) +
    /// the V tile (Q codes amortize over the key loop).
    pub fn tile_hbm_bytes(&self) -> usize {
        (2 * self.block_k * self.k + self.block_k * self.d_v) * self.elem_bytes
    }

    /// Strategy A — VPU intersection: the masked k×k outer product.
    /// Compute cost 2·Bq·Bc·k² on the vector unit.
    pub fn tile_time_vpu_intersect(&self, m: TpuModel) -> f64 {
        let (vpu, mxu) = self.tile_flops();
        let t = (vpu as f64 / m.vpu_flops_per_s).max(mxu as f64 / m.mxu_flops_per_s);
        t.max(self.tile_hbm_bytes() as f64 / m.hbm_bytes_per_s)
    }

    /// Strategy B — densify-then-MXU: scatter the sparse codes into a
    /// dense (B, d) VMEM scratch (VPU, ~B·k ops) and run the dense MXU
    /// matmul. Same arithmetic as dense attention, but only the sparse
    /// code bytes cross HBM — the win is pure bandwidth, which is the
    /// regime long-context attention actually lives in. This mirrors
    /// the paper's own observation (App. C.5/Table 7) that the GPU
    /// kernel's advantage survives because kernels are memory-bound.
    pub fn tile_time_densify_mxu(&self, d: usize, m: TpuModel) -> f64 {
        let scatter = (self.block_q + self.block_k) * self.k;
        let mxu = 2 * self.block_q * self.block_k * (d + self.d_v);
        let t_compute = (scatter as f64 / m.vpu_flops_per_s)
            + mxu as f64 / m.mxu_flops_per_s;
        t_compute.max(self.tile_hbm_bytes() as f64 / m.hbm_bytes_per_s)
    }

    /// Best-strategy tile time and which strategy wins.
    pub fn tile_time_s(&self, d: usize, m: TpuModel) -> (f64, &'static str) {
        let a = self.tile_time_vpu_intersect(m);
        let b = self.tile_time_densify_mxu(d, m);
        if a <= b {
            (a, "vpu-intersect")
        } else {
            (b, "densify-mxu")
        }
    }

    /// Dense flash tile time (MXU matmuls, dense K/V bytes).
    pub fn dense_tile_time_s(&self, d: usize, m: TpuModel) -> f64 {
        let flops = 2 * self.block_q * self.block_k * (d + self.d_v);
        let bytes = (self.block_k * d + self.block_k * self.d_v) * self.elem_bytes;
        (flops as f64 / m.mxu_flops_per_s).max(bytes as f64 / m.hbm_bytes_per_s)
    }

    /// Whole-sequence estimate vs a dense-flash kernel of the same
    /// tiling: the headline efficiency ratio (paper: up to 2.5×).
    pub fn speedup_vs_dense(&self, d: usize, _n: usize, m: TpuModel) -> f64 {
        let (t_sfa, _) = self.tile_time_s(d, m);
        self.dense_tile_time_s(d, m) / t_sfa
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn default_tile() -> SfaTile {
        // The compiled defaults: Bq = Bk = 32, k = 8, d_v = 64, f32.
        SfaTile { block_q: 32, block_k: 32, k: 8, d_v: 64, elem_bytes: 4 }
    }

    #[test]
    fn default_tile_fits_vmem() {
        let t = default_tile();
        assert!(t.vmem_bytes() < 2 << 20, "VMEM {} bytes", t.vmem_bytes());
        assert!(t.fits(TpuModel::V4));
    }

    #[test]
    fn k16_at_64x64_needs_bf16() {
        // At f32 the (64,64,16,16) match tensor (8.4 MB) blows the
        // double-buffering budget — the reason the compiled default is
        // 32×32. In bf16 it fits.
        let f32_tile = SfaTile { block_q: 64, block_k: 64, k: 16, d_v: 64, elem_bytes: 4 };
        assert!(!f32_tile.fits(TpuModel::V4));
        let bf16_tile = SfaTile { elem_bytes: 2, ..f32_tile };
        assert!(bf16_tile.fits(TpuModel::V4), "VMEM {} bytes", bf16_tile.vmem_bytes());
    }

    #[test]
    fn huge_tiles_rejected() {
        let t = SfaTile { block_q: 256, block_k: 256, k: 32, d_v: 128, elem_bytes: 4 };
        assert!(!t.fits(TpuModel::V4));
    }

    #[test]
    fn vmem_dominated_by_match_tensor() {
        let t = default_tile();
        let match_prod = 2 * 32 * 32 * 8 * 8 * 4;
        assert!(t.vmem_bytes() < 2 * match_prod);
        assert!(t.vmem_bytes() > match_prod);
    }

    #[test]
    fn densify_mxu_wins_at_moderate_k() {
        // The honest TPU finding (DESIGN.md §Hardware-Adaptation): the
        // VPU intersection only wins for very small k (k² < d·VPU/MXU);
        // at k=8, d=128 the right lowering is densify-then-MXU, whose
        // advantage over dense flash is the sparse-code HBM traffic.
        let m = TpuModel::V4;
        let t8 = SfaTile { k: 8, ..default_tile() };
        let (_, strategy) = t8.tile_time_s(128, m);
        assert_eq!(strategy, "densify-mxu");
        let s8 = t8.speedup_vs_dense(128, 16384, m);
        assert!(s8 > 1.0, "SFA should beat dense at d=128,k=8: {s8}");
        // Smaller k widens the bandwidth gap.
        let s2 = SfaTile { k: 2, ..default_tile() }.speedup_vs_dense(128, 16384, m);
        assert!(s2 >= s8, "{s2} vs {s8}");
    }

    #[test]
    fn vpu_intersect_wins_for_tiny_k_low_mxu_gap() {
        // With a hypothetical accelerator whose VPU≈MXU, the
        // intersection strategy wins at small k (it does k²/d of the
        // arithmetic).
        let m = TpuModel {
            mxu_flops_per_s: 5e12,
            vpu_flops_per_s: 4.3e12,
            ..TpuModel::V4
        };
        let t = SfaTile { k: 2, ..default_tile() };
        let (_, strategy) = t.tile_time_s(128, m);
        assert_eq!(strategy, "vpu-intersect");
    }
}
