//! Spectral analysis for Fig. 11 (App. L): effective rank of Q/K
//! activations. Eigenvalues of the d×d covariance XᵀX are computed
//! with a cyclic Jacobi eigensolver (d ≤ a few hundred, so O(d³)
//! sweeps are fine); the effective rank at energy threshold τ is the
//! number of leading eigenvalues whose cumulative sum reaches τ of the
//! total.

use crate::util::matrix::Matrix;

/// Symmetric d×d covariance XᵀX / n.
pub fn covariance(x: &Matrix) -> Matrix {
    let d = x.cols;
    let mut c = Matrix::zeros(d, d);
    for i in 0..x.rows {
        let row = x.row(i);
        for a in 0..d {
            let xa = row[a];
            if xa == 0.0 {
                continue;
            }
            let crow = c.row_mut(a);
            for (b, &xb) in row.iter().enumerate() {
                crow[b] += xa * xb;
            }
        }
    }
    let inv = 1.0 / x.rows as f32;
    for v in c.data.iter_mut() {
        *v *= inv;
    }
    c
}

/// Eigenvalues of a symmetric matrix by cyclic Jacobi rotations,
/// descending order.
pub fn symmetric_eigenvalues(a: &Matrix, sweeps: usize) -> Vec<f32> {
    assert_eq!(a.rows, a.cols);
    let d = a.rows;
    let mut m = a.clone();
    for _ in 0..sweeps {
        let mut off = 0.0f32;
        for p in 0..d {
            for q in (p + 1)..d {
                off += m.get(p, q).abs();
            }
        }
        if off < 1e-9 {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = m.get(p, q);
                if apq.abs() < 1e-12 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply the rotation J(p, q, θ) on both sides.
                for i in 0..d {
                    let aip = m.get(i, p);
                    let aiq = m.get(i, q);
                    m.set(i, p, c * aip - s * aiq);
                    m.set(i, q, s * aip + c * aiq);
                }
                for i in 0..d {
                    let api = m.get(p, i);
                    let aqi = m.get(q, i);
                    m.set(p, i, c * api - s * aqi);
                    m.set(q, i, s * api + c * aqi);
                }
            }
        }
    }
    let mut eig: Vec<f32> = (0..d).map(|i| m.get(i, i)).collect();
    eig.sort_by(|a, b| b.partial_cmp(a).unwrap());
    eig
}

/// Effective rank at cumulative-energy threshold τ (Fig. 11: τ = 0.9).
pub fn effective_rank(x: &Matrix, tau: f32) -> usize {
    let eig = symmetric_eigenvalues(&covariance(x), 30);
    let total: f32 = eig.iter().map(|&e| e.max(0.0)).sum();
    if total <= 0.0 {
        return 0;
    }
    let mut acc = 0.0;
    for (i, &e) in eig.iter().enumerate() {
        acc += e.max(0.0);
        if acc >= tau * total {
            return i + 1;
        }
    }
    eig.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn eigenvalues_of_diagonal_matrix() {
        let mut a = Matrix::zeros(4, 4);
        for (i, v) in [3.0, 1.0, 4.0, 1.5].iter().enumerate() {
            a.set(i, i, *v);
        }
        let eig = symmetric_eigenvalues(&a, 10);
        assert_eq!(eig.len(), 4);
        assert!((eig[0] - 4.0).abs() < 1e-5);
        assert!((eig[3] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn eigenvalue_sum_equals_trace() {
        let mut rng = Rng::new(0);
        let x = Matrix::randn(32, 12, &mut rng, 1.0);
        let c = covariance(&x);
        let trace: f32 = (0..12).map(|i| c.get(i, i)).sum();
        let eig = symmetric_eigenvalues(&c, 30);
        let sum: f32 = eig.iter().sum();
        assert!((trace - sum).abs() / trace < 1e-3, "{trace} vs {sum}");
    }

    #[test]
    fn full_rank_gaussian_has_high_effective_rank() {
        let mut rng = Rng::new(1);
        let x = Matrix::randn(1024, 32, &mut rng, 1.0);
        let r = effective_rank(&x, 0.9);
        assert!(r >= 26, "effective rank {r}");
    }

    #[test]
    fn planted_low_rank_detected() {
        // X = U S: rank 5. Fig. 11's finding is that trained Q/K live
        // on such low-dimensional manifolds (≈50-60 of 128).
        let mut rng = Rng::new(2);
        let u = Matrix::randn(512, 5, &mut rng, 1.0);
        let s = Matrix::randn(5, 64, &mut rng, 1.0);
        let x = u.matmul(&s);
        let r = effective_rank(&x, 0.9);
        assert!(r <= 5, "effective rank {r}");
    }

    #[test]
    fn effective_rank_monotone_in_tau() {
        let mut rng = Rng::new(3);
        let x = Matrix::randn(256, 24, &mut rng, 1.0);
        let r5 = effective_rank(&x, 0.5);
        let r9 = effective_rank(&x, 0.9);
        let r99 = effective_rank(&x, 0.99);
        assert!(r5 <= r9 && r9 <= r99);
    }
}
