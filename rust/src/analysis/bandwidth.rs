//! Memory-traffic model + host bandwidth microbench (paper Table 7).
//!
//! The paper's claim: with compute disabled the kernels stream near
//! peak HBM bandwidth (919–1194 GB/s on A800), while the full kernels
//! run at ~14–17 GB/s — i.e. both dense-flash and FlashSFA are
//! *compute-bound*, so the FLOP/INOP savings translate to wall-clock.
//! We reproduce the *structure*: a pure-streaming microbench measures
//! this host's memory ceiling, the model counts the bytes each kernel
//! moves, and the measured kernel bandwidths land far below the ceiling.

use crate::sparse::memory::Widths;

/// Bytes moved by a tiled dense-flash forward (IO-complexity model):
/// Q read once; K and V streamed once per query tile.
pub fn dense_flash_bytes(n: usize, d: usize, d_v: usize, block_q: usize, w: Widths) -> u64 {
    let tiles = n.div_ceil(block_q) as u64;
    let q = (n * d * w.s_val) as u64;
    let kv = ((n * d + n * d_v) * w.s_val) as u64 * tiles;
    let out = (n * d_v * w.s_val) as u64;
    q + kv + out
}

/// Bytes moved by FlashSFA: sparse Q/K codes (values + u16 indices)
/// streamed per tile, V rows loaded only where the tile attends.
pub fn flash_sfa_bytes(
    n: usize, _d: usize, d_v: usize, k: usize, block_q: usize, w: Widths,
) -> u64 {
    let tiles = n.div_ceil(block_q) as u64;
    let q_codes = (n * k * (w.s_val + w.s_idx)) as u64;
    let k_codes = (n * k * (w.s_val + w.s_idx)) as u64 * tiles;
    let v = (n * d_v * w.s_val) as u64 * tiles;
    let out = (n * d_v * w.s_val) as u64;
    q_codes + k_codes + v + out
}

/// Pure-streaming memory bandwidth of this host (GB/s): large-buffer
/// read+write sweep, best of `reps` (the "w/o compute" row analog).
pub fn measure_stream_bandwidth(bytes: usize, reps: usize) -> f64 {
    let n = bytes / 8;
    let src: Vec<u64> = (0..n as u64).collect();
    let mut dst: Vec<u64> = vec![0; n];
    let mut best = 0.0f64;
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        dst.copy_from_slice(&src);
        std::hint::black_box(&dst);
        let dt = t0.elapsed().as_secs_f64();
        // copy = read + write
        let gbps = (2 * bytes) as f64 / dt / 1e9;
        best = best.max(gbps);
    }
    best
}

/// Effective bandwidth of a measured kernel run (bytes model / time).
pub fn effective_bandwidth(bytes: u64, seconds: f64) -> f64 {
    bytes as f64 / seconds / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sfa_moves_fewer_bytes_for_sparse_k() {
        let w = Widths::OURS;
        let dense = dense_flash_bytes(16384, 128, 128, 64, w);
        let sfa = flash_sfa_bytes(16384, 128, 128, 8, 64, w);
        assert!(sfa < dense, "{sfa} vs {dense}");
    }

    #[test]
    fn bytes_scale_quadratically_with_n() {
        // Streaming K per query tile makes IO ~ n²/Bq.
        let w = Widths::OURS;
        let a = dense_flash_bytes(4096, 64, 64, 64, w);
        let b = dense_flash_bytes(8192, 64, 64, 64, w);
        let ratio = b as f64 / a as f64;
        assert!((3.5..4.5).contains(&ratio), "{ratio}");
    }

    #[test]
    fn stream_bandwidth_positive_and_sane() {
        let gbps = measure_stream_bandwidth(8 << 20, 3);
        assert!(gbps > 0.5, "implausibly low bandwidth {gbps}");
        assert!(gbps < 2000.0, "implausibly high bandwidth {gbps}");
    }

    #[test]
    fn larger_block_q_reduces_traffic() {
        let w = Widths::OURS;
        let small = dense_flash_bytes(8192, 64, 64, 16, w);
        let large = dense_flash_bytes(8192, 64, 64, 128, w);
        assert!(large < small);
    }
}
