//! FLOP / INOP cost model for attention variants (paper Table 6 and
//! the Fig. 1b / Fig. 5 "49% FLOPs" headline).
//!
//! Conventions (calibrated to reproduce Table 6's dense entries
//! exactly): one multiply-add = 2 FLOPs, no causal halving (the paper's
//! counts are for the full n×n computation), counts are per
//! (batch × heads) and scaled by both.

use crate::sparse::csc_feat::CscFeat;
use crate::sparse::topk_codes;
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// Workload shape for the cost model.
#[derive(Debug, Clone, Copy)]
pub struct AttnShape {
    pub batch: usize,
    pub heads: usize,
    pub seq: usize,
    pub d_head: usize,
    pub d_v: usize,
}

impl AttnShape {
    /// Paper Table 6 setting: Batch=8, Heads=8, d_v = d.
    pub fn table6(seq: usize, d: usize) -> Self {
        AttnShape { batch: 8, heads: 8, seq, d_head: d, d_v: d }
    }

    fn bh(&self) -> u64 {
        (self.batch * self.heads) as u64
    }
}

/// Cost report in raw operation counts.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Cost {
    pub flops: u64,
    pub inops: u64,
}

impl Cost {
    pub fn tflops(&self) -> f64 {
        self.flops as f64 / 1e12
    }

    pub fn ginops(&self) -> f64 {
        self.inops as f64 / 1e9
    }
}

/// Dense attention forward: QKᵀ (2n²d) + softmax (≈5n²) + PV (2n²d_v).
pub fn dense_forward(s: AttnShape) -> Cost {
    let n = s.seq as u64;
    let qk = 2 * n * n * s.d_head as u64;
    let soft = 5 * n * n;
    let pv = 2 * n * n * s.d_v as u64;
    Cost { flops: s.bh() * (qk + soft + pv), inops: 0 }
}

/// SFA forward (FlashSFA):
/// * scoring FLOPs: 2·E where E = n²k²/d expected overlaps (Eq. 7);
/// * softmax over all keys (the sparse semantics keep n-wide rows);
/// * PV stays dense (paper App. B.2: "a large proportion of the FLOPs
///   in the sparse version come from P@V");
/// * INOPs: posting-list traversal (one index read per overlap) plus
///   per-(row, feature, tile) binary searches.
pub fn sfa_forward(s: AttnShape, k: usize, block_k: usize) -> Cost {
    let n = s.seq as u64;
    let e = n * n * (k * k) as u64 / s.d_head as u64; // Eq. 7
    let scoring = 2 * e;
    let soft = 5 * n * n;
    let pv = 2 * n * n * s.d_v as u64;
    let topk = 2 * n * s.d_head as u64; // RTopK is O(nd)
    // Index reads: one per overlap; binary searches: per query row,
    // per active feature, per key tile, ~log2(posting length).
    let tiles = n.div_ceil(block_k as u64);
    let posting_len = (n * k as u64 / s.d_head as u64).max(1);
    let bsearch = n * k as u64 * tiles * (64 - posting_len.leading_zeros() as u64).max(1);
    Cost {
        flops: s.bh() * (scoring + soft + pv + topk),
        inops: s.bh() * (2 * e + bsearch),
    }
}

/// Dense decode step (TTNT): one query over a cache of length n.
pub fn dense_decode(s: AttnShape) -> Cost {
    let n = s.seq as u64;
    let qk = 2 * n * s.d_head as u64;
    let soft = 5 * n;
    let pv = 2 * n * s.d_v as u64;
    Cost { flops: s.bh() * (qk + soft + pv), inops: 0 }
}

/// SFA decode step: E_row = n·k²/d expected overlaps for the one query.
pub fn sfa_decode(s: AttnShape, k: usize) -> Cost {
    let n = s.seq as u64;
    let e = n * (k * k) as u64 / s.d_head as u64;
    let soft = 5 * n;
    let pv = 2 * n * s.d_v as u64;
    let topk = 2 * s.d_head as u64;
    Cost {
        flops: s.bh() * (2 * e + soft + pv + topk),
        inops: s.bh() * (2 * e + k as u64 * 16),
    }
}

/// Fractional FLOP saving of SFA vs dense at the same shape (the
/// paper's Fig. 1b "reduces FLOPs by 49%" aggregates QK-stage savings
/// over the full model; here we report the attention-only fraction).
pub fn flop_saving(s: AttnShape, k: usize) -> f64 {
    1.0 - sfa_forward(s, k, 64).flops as f64 / dense_forward(s).flops as f64
}

/// Measure the *actual* overlap count on sampled Gaussian features and
/// compare with the Eq. 7 prediction (validation path for Table 6).
pub fn measured_vs_predicted_overlaps(
    n: usize, d: usize, k: usize, seed: u64,
) -> (u64, u64) {
    let mut rng = Rng::new(seed);
    let q = Matrix::randn(n, d, &mut rng, 1.0);
    let kk = Matrix::randn(n, d, &mut rng, 1.0);
    let qf = CscFeat::from_codes(&topk_codes(&q, k));
    let kf = CscFeat::from_codes(&topk_codes(&kk, k));
    let measured = CscFeat::predicted_overlaps(&qf.degrees(), &kf.degrees());
    let predicted = (n as u64 * n as u64 * (k * k) as u64) / d as u64;
    (measured, predicted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table6_dense_entries() {
        // Table 6: Dense_128 @ 8192 = 2.23 TFLOPs; @ 65536 = 142.67;
        // Dense_64 @ 8192 = 1.12. Our model counts QK+PV (+small
        // softmax term), no causal halving, ×64 batch-heads.
        let t = |seq, d| dense_forward(AttnShape::table6(seq, d)).tflops();
        assert!((t(8192, 128) - 2.23).abs() / 2.23 < 0.02, "{}", t(8192, 128));
        assert!((t(65536, 128) - 142.67).abs() / 142.67 < 0.02);
        assert!((t(8192, 64) - 1.12).abs() / 1.12 < 0.03);
        assert!((t(16384, 64) - 4.48).abs() / 4.48 < 0.03);
    }

    #[test]
    fn sfa_flops_dominated_by_pv_as_in_table6() {
        // Table 6: Sparse_8/128 @ 8192 = 1.13 TFLOPs ≈ half of dense —
        // i.e. the PV stage; the sparse QK term is negligible.
        let c = sfa_forward(AttnShape::table6(8192, 128), 8, 64);
        assert!((c.tflops() - 1.13).abs() / 1.13 < 0.05, "{}", c.tflops());
        let c16 = sfa_forward(AttnShape::table6(8192, 128), 16, 64);
        let c32 = sfa_forward(AttnShape::table6(8192, 128), 32, 64);
        assert!(c16.tflops() < c32.tflops());
        assert!((c32.tflops() - 1.20).abs() / 1.20 < 0.08, "{}", c32.tflops());
    }

    #[test]
    fn inops_scale_linearly_in_overlaps() {
        let s = AttnShape::table6(16384, 128);
        let i8_ = sfa_forward(s, 8, 64).ginops();
        let i16 = sfa_forward(s, 16, 64).ginops();
        let i32_ = sfa_forward(s, 32, 64).ginops();
        // Table 6 shape: INOPs roughly double k=8→16→32 (29.4/39.9/58.7
        // at 16k — super-linear in k via the k² overlap term, damped by
        // the k·log binary-search term).
        assert!(i16 > 1.3 * i8_ && i32_ > 1.5 * i16, "{i8_} {i16} {i32_}");
    }

    #[test]
    fn headline_flop_saving_near_half() {
        // Fig. 1b: "reduces FLOPs by 49%" (d=128, k=16): attention-only
        // saving should be just under 50% (PV is preserved).
        let s = flop_saving(AttnShape::table6(32768, 128), 16);
        assert!((0.40..0.52).contains(&s), "saving {s}");
    }

    #[test]
    fn decode_costs_scale_linearly_in_context() {
        let a = dense_decode(AttnShape::table6(8192, 128)).flops;
        let b = dense_decode(AttnShape::table6(16384, 128)).flops;
        assert!((b as f64 / a as f64 - 2.0).abs() < 0.01);
        let a = sfa_decode(AttnShape::table6(8192, 128), 8).flops;
        let b = sfa_decode(AttnShape::table6(16384, 128), 8).flops;
        assert!((b as f64 / a as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn eq7_prediction_matches_measured_overlaps() {
        // Gaussian features have near-balanced supports: measured
        // overlap count within 2× of n²k²/d (and never below ~0.8×).
        for (n, d, k) in [(256, 64, 8), (512, 128, 16), (256, 128, 4)] {
            let (measured, predicted) = measured_vs_predicted_overlaps(n, d, k, 7);
            let ratio = measured as f64 / predicted as f64;
            assert!((0.8..2.0).contains(&ratio), "n={n} d={d} k={k}: {ratio}");
        }
    }

    #[test]
    fn sfa_decode_cheaper_than_dense_decode() {
        let s = AttnShape::table6(65536, 128);
        assert!(sfa_decode(s, 8).flops < dense_decode(s).flops);
    }
}
