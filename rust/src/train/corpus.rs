//! Synthetic workloads.
//!
//! **ZipfCorpus** — a Markov bigram process with Zipf-distributed
//! transition targets: each token has a seeded preference list over
//! successors, so an LM can reduce loss well below the unigram entropy
//! but never to zero. This stands in for OpenWebText/Pile (DESIGN.md
//! §Substitutions): what matters for Table 1 is the *ordering*
//! Dense(full) ≥ SFA(k) > Short(d/2) on held-out PPL, which is
//! architecture-level, not corpus-level.
//!
//! **NIAH** — paper §4.2 / RULER: the haystack is a repeated filler
//! token; a needle `[KEY, value]` is inserted at a random depth; the
//! sequence ends with `[QUERY, KEY]` and the model must emit `value`
//! as the next token. Retrieval accuracy = argmax match at the answer
//! position.

use crate::util::rng::{zipf_cdf, Rng};

/// Reserved token ids for the NIAH grammar.
pub const TOK_FILLER: i32 = 0;
pub const TOK_BOS: i32 = 1;
pub const TOK_QUERY: i32 = 2;
pub const TOK_KEY: i32 = 3;
/// Values live in [TOK_VAL0, vocab).
pub const TOK_VAL0: i32 = 4;

/// Which pretraining workload to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusKind {
    Zipf,
    Niah,
}

impl CorpusKind {
    pub fn parse(s: &str) -> Option<CorpusKind> {
        match s {
            "zipf" => Some(CorpusKind::Zipf),
            "niah" => Some(CorpusKind::Niah),
            _ => None,
        }
    }
}

/// Markov bigram corpus with Zipf transitions.
pub struct ZipfCorpus {
    vocab: usize,
    /// successor preference table: succ[t] = ranked successor ids
    succ: Vec<Vec<u32>>,
    cdf: Vec<f64>,
    rng: Rng,
}

impl ZipfCorpus {
    /// Structure (the transition table = "the language") and sampling
    /// stream both derived from `seed`.
    pub fn new(vocab: usize, seed: u64) -> ZipfCorpus {
        Self::with_stream(vocab, seed, seed ^ 0xC0_FF_EE)
    }

    /// Same language as `structure_seed`, independent sampling stream —
    /// THE held-out eval construction: a model must be evaluated on
    /// fresh samples of the process it was trained on, not on a
    /// different process.
    pub fn with_stream(vocab: usize, structure_seed: u64, stream: u64) -> ZipfCorpus {
        assert!(vocab >= 8);
        let mut master = Rng::new(structure_seed);
        let branch = 32.min(vocab);
        let succ = (0..vocab)
            .map(|t| {
                let mut r = master.fork(t as u64);
                let mut ids: Vec<u32> = (0..vocab as u32).collect();
                r.shuffle(&mut ids);
                ids.truncate(branch);
                ids
            })
            .collect();
        ZipfCorpus {
            vocab,
            succ,
            cdf: zipf_cdf(branch, 1.3),
            rng: Rng::new(stream.wrapping_mul(0x9E3779B97F4A7C15) ^ structure_seed),
        }
    }

    /// Sample a (batch, seq) token grid, flattened row-major.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut t = self.rng.range(0, self.vocab);
            out.push(t as i32);
            for _ in 1..seq {
                let rank = self.rng.zipf(&self.cdf);
                t = self.succ[t][rank] as usize;
                out.push(t as i32);
            }
        }
        out
    }

    /// Theoretical per-token entropy of the transition process (nats) —
    /// the floor any model's PPL can approach.
    pub fn transition_entropy(&self) -> f64 {
        let mut prev = 0.0;
        let mut h = 0.0;
        for &c in &self.cdf {
            let p = c - prev;
            prev = c;
            if p > 0.0 {
                h -= p * p.ln();
            }
        }
        h
    }
}

/// One NIAH example with its ground truth.
#[derive(Debug, Clone)]
pub struct NiahSample {
    pub tokens: Vec<i32>,
    /// Position whose *prediction* must equal `value` (i.e. logits at
    /// this index are scored against `value`).
    pub answer_pos: usize,
    pub value: i32,
}

/// Generate one NIAH sample of total length `seq` with the needle at a
/// uniform random depth. Layout:
/// `[BOS, #, #, ..., KEY, value, #, ..., QUERY, KEY, value]`
pub fn niah_sample(vocab: usize, seq: usize, rng: &mut Rng) -> NiahSample {
    assert!(seq >= 8, "sequence too short for the NIAH grammar");
    assert!(vocab as i32 > TOK_VAL0 + 1);
    let n_vals = vocab as i32 - TOK_VAL0;
    let value = TOK_VAL0 + rng.below(n_vals as u64) as i32;
    let mut tokens = vec![TOK_FILLER; seq];
    tokens[0] = TOK_BOS;
    // Needle position: anywhere that keeps [KEY, value] clear of the
    // trailing [QUERY, KEY, value] suffix.
    let needle = rng.range(1, seq - 4);
    tokens[needle] = TOK_KEY;
    tokens[needle + 1] = value;
    tokens[seq - 3] = TOK_QUERY;
    tokens[seq - 2] = TOK_KEY;
    tokens[seq - 1] = value;
    NiahSample { tokens, answer_pos: seq - 2, value }
}

/// Batch of NIAH samples flattened to (batch, seq) + metadata.
pub fn niah_batch(
    vocab: usize,
    seq: usize,
    batch: usize,
    rng: &mut Rng,
) -> (Vec<i32>, Vec<NiahSample>) {
    let samples: Vec<NiahSample> = (0..batch).map(|_| niah_sample(vocab, seq, rng)).collect();
    let mut flat = Vec::with_capacity(batch * seq);
    for s in &samples {
        flat.extend_from_slice(&s.tokens);
    }
    (flat, samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_corpus_tokens_in_range() {
        let mut c = ZipfCorpus::new(64, 0);
        let b = c.batch(4, 128);
        assert_eq!(b.len(), 4 * 128);
        assert!(b.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn zipf_corpus_is_learnable_not_trivial() {
        // Bigram process: successor distribution entropy must be well
        // below uniform entropy but above zero.
        let c = ZipfCorpus::new(256, 1);
        let h = c.transition_entropy();
        assert!(h > 0.5 && h < (32f64).ln(), "h={h}");
    }

    #[test]
    fn zipf_deterministic_per_seed() {
        let mut a = ZipfCorpus::new(64, 7);
        let mut b = ZipfCorpus::new(64, 7);
        assert_eq!(a.batch(2, 64), b.batch(2, 64));
    }


    #[test]
    fn with_stream_same_language_different_samples() {
        let mut train = ZipfCorpus::with_stream(64, 42, 1);
        let mut heldout = ZipfCorpus::with_stream(64, 42, 2);
        assert_eq!(train.succ, heldout.succ, "same structure seed => same language");
        assert_ne!(train.batch(1, 256), heldout.batch(1, 256), "streams differ");
        let other = ZipfCorpus::with_stream(64, 43, 1);
        assert_ne!(train.succ, other.succ, "different structure => different language");
    }

    #[test]
    fn niah_sample_structure() {
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let s = niah_sample(64, 128, &mut rng);
            assert_eq!(s.tokens.len(), 128);
            assert_eq!(s.tokens[0], TOK_BOS);
            assert_eq!(s.tokens[125], TOK_QUERY);
            assert_eq!(s.tokens[126], TOK_KEY);
            assert_eq!(s.tokens[127], s.value);
            assert_eq!(s.answer_pos, 126);
            assert!(s.value >= TOK_VAL0 && s.value < 64);
            // Exactly two KEY tokens: needle + query restatement.
            assert_eq!(s.tokens.iter().filter(|&&t| t == TOK_KEY).count(), 2);
            // The needle's value follows the first KEY.
            let needle = s.tokens.iter().position(|&t| t == TOK_KEY).unwrap();
            assert_eq!(s.tokens[needle + 1], s.value);
        }
    }

    #[test]
    fn niah_needle_depth_varies() {
        let mut rng = Rng::new(1);
        let depths: Vec<usize> = (0..100)
            .map(|_| {
                let s = niah_sample(32, 64, &mut rng);
                s.tokens.iter().position(|&t| t == TOK_KEY).unwrap()
            })
            .collect();
        let min = *depths.iter().min().unwrap();
        let max = *depths.iter().max().unwrap();
        assert!(min < 10 && max > 50, "needle depths should span: {min}..{max}");
    }

    #[test]
    fn niah_batch_flattening() {
        let mut rng = Rng::new(2);
        let (flat, samples) = niah_batch(32, 64, 4, &mut rng);
        assert_eq!(flat.len(), 4 * 64);
        for (i, s) in samples.iter().enumerate() {
            assert_eq!(&flat[i * 64..(i + 1) * 64], s.tokens.as_slice());
        }
    }
}
