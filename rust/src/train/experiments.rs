//! Training-based experiment drivers (paper Tables 1-3, 12; Figs 8-10).
//!
//! All of them drive the AOT artifacts through [`Trainer`]; scale knobs
//! (steps, eval batches) come from the CLI so quick smoke runs and the
//! recorded EXPERIMENTS.md runs share code.

use std::time::Instant;

use anyhow::Result;

use crate::bench::table::Table;
use crate::runtime::Runtime;
use crate::train::corpus::{niah_batch, CorpusKind, ZipfCorpus};
use crate::train::trainer::{TrainReport, Trainer};
use crate::util::rng::Rng;

/// Train one variant on the chosen corpus; returns the trainer (with
/// its trained parameters) and the run report.
pub fn train_variant<'rt>(
    runtime: &'rt Runtime,
    variant: &str,
    corpus: CorpusKind,
    steps: usize,
    lr: f32,
    seed: u64,
    log_every: usize,
) -> Result<(Trainer<'rt>, TrainReport)> {
    let mut trainer = Trainer::new(runtime, variant)?;
    let vocab = runtime.manifest.variant(variant)?.cfg_usize("vocab")?;
    let (batch, seq) = (trainer.batch, trainer.seq);
    let mut zipf = ZipfCorpus::new(vocab, seed);
    let mut rng = Rng::new(seed ^ 0xD1CE);
    let t0 = Instant::now();
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        // Linear warmup over the first 10% of steps.
        let warm = (steps / 10).max(1);
        let lr_t = if step < warm { lr * (step + 1) as f32 / warm as f32 } else { lr };
        let tokens = match corpus {
            CorpusKind::Zipf => zipf.batch(batch, seq),
            CorpusKind::Niah => niah_batch(vocab, seq, batch, &mut rng).0,
        };
        let loss = trainer.train_step(&tokens, lr_t)?;
        losses.push(loss);
        if log_every > 0 && (step % log_every == 0 || step + 1 == steps) {
            eprintln!("[train {variant}] step {step:>5} loss {loss:.4}");
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = TrainReport {
        variant: variant.to_string(),
        steps,
        final_loss: *losses.last().unwrap_or(&f32::NAN),
        losses,
        wall_s: wall,
        tokens_per_s: (steps * batch * seq) as f64 / wall,
    };
    Ok((trainer, report))
}

/// Held-out PPL on fresh corpus batches.
pub fn eval_ppl(
    trainer: &Trainer,
    corpus: CorpusKind,
    vocab: usize,
    batches: usize,
    seed: u64,
) -> Result<f32> {
    // Same language (structure seed 42 = the training corpus), fresh
    // held-out sampling stream.
    let mut zipf = ZipfCorpus::with_stream(vocab, 42, seed);
    let mut rng = Rng::new(seed ^ 0xE7A1);
    let mut total = 0.0;
    for _ in 0..batches {
        let tokens = match corpus {
            CorpusKind::Zipf => zipf.batch(trainer.batch, trainer.seq),
            CorpusKind::Niah => niah_batch(vocab, trainer.seq, trainer.batch, &mut rng).0,
        };
        total += trainer.eval_loss(&tokens)?;
    }
    Ok((total / batches as f32).exp())
}

/// NIAH retrieval accuracy at a given (effective) context length ≤
/// trained seq: the sample occupies the first `length` positions and
/// the tail is filler (causality makes the tail irrelevant).
pub fn eval_niah_accuracy(
    trainer: &Trainer,
    vocab: usize,
    length: usize,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let mut acc = 0.0;
    for _ in 0..n_batches {
        let (mut flat, mut samples) =
            niah_batch(vocab, length, trainer.batch, &mut rng);
        // Pad every row out to the compiled seq with filler.
        if length < trainer.seq {
            let mut padded = Vec::with_capacity(trainer.batch * trainer.seq);
            for row in 0..trainer.batch {
                padded.extend_from_slice(&flat[row * length..(row + 1) * length]);
                padded.extend(std::iter::repeat(0).take(trainer.seq - length));
            }
            flat = padded;
            // answer positions unchanged (they index within the row).
            for s in samples.iter_mut() {
                assert!(s.answer_pos + 1 < trainer.seq);
            }
        }
        acc += trainer.niah_accuracy(&flat, &samples)?;
    }
    Ok(acc / n_batches as f64)
}

/// Table 1 analog: train dense / SFA / short variants on the synthetic
/// corpus, report held-out PPL + train throughput.
pub fn table1(
    runtime: &Runtime,
    variants: &[String],
    steps: usize,
    lr: f32,
    eval_batches: usize,
) -> Result<(Table, Vec<TrainReport>)> {
    let mut t = Table::new(
        &format!("Table 1 — synthetic-corpus pretraining ({steps} steps)"),
        &["variant", "final train loss", "held-out PPL", "train tok/s", "wall s"],
    );
    let mut reports = Vec::new();
    for variant in variants {
        let (trainer, report) = train_variant(
            runtime, variant, CorpusKind::Zipf, steps, lr, 42, (steps / 10).max(1),
        )?;
        let vocab = runtime.manifest.variant(variant)?.cfg_usize("vocab")?;
        let ppl = eval_ppl(&trainer, CorpusKind::Zipf, vocab, eval_batches, 777)?;
        t.row(vec![
            variant.clone(),
            format!("{:.4}", report.final_loss),
            format!("{ppl:.3}"),
            format!("{:.0}", report.tokens_per_s),
            format!("{:.1}", report.wall_s),
        ]);
        reports.push(report);
    }
    Ok((t, reports))
}

/// Table 2 analog: train on NIAH data, evaluate retrieval accuracy
/// across held-out lengths + relative speed.
pub fn table2(
    runtime: &Runtime,
    variants: &[String],
    steps: usize,
    lr: f32,
    lengths: &[usize],
    eval_batches: usize,
) -> Result<Table> {
    let mut header: Vec<String> = vec!["variant".into()];
    header.extend(lengths.iter().map(|l| format!("acc@{l}")));
    header.push("train tok/s".into());
    header.push("speed vs dense".into());
    let mut t = Table::new(
        &format!("Table 2 — NIAH length generalization ({steps} steps)"),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut dense_tps = None;
    for variant in variants {
        let mut trainer = Trainer::new(runtime, variant)?;
        let vocab = runtime.manifest.variant(variant)?.cfg_usize("vocab")?;
        let mut rng = Rng::new(42);
        let t0 = Instant::now();
        for step in 0..steps {
            let warm = (steps / 10).max(1);
            let lr_t = if step < warm { lr * (step + 1) as f32 / warm as f32 } else { lr };
            // Variable-length training (paper §4.2 evaluates *within*
            // the training window): sample a context length for this
            // batch from {seq/8 .. seq}, pad rows with filler. With
            // absolute position embeddings this is what makes shorter
            // eval lengths in-distribution.
            let len = *[trainer.seq / 8, trainer.seq / 4, trainer.seq / 2, trainer.seq]
                [..].get(rng.range(0, 4)).unwrap();
            let (short, _) = niah_batch(vocab, len, trainer.batch, &mut rng);
            let mut tokens = Vec::with_capacity(trainer.batch * trainer.seq);
            for row in 0..trainer.batch {
                tokens.extend_from_slice(&short[row * len..(row + 1) * len]);
                tokens.extend(std::iter::repeat(0).take(trainer.seq - len));
            }
            let loss = trainer.train_step(&tokens, lr_t)?;
            if step % (steps / 10).max(1) == 0 {
                eprintln!("[niah {variant}] step {step:>5} loss {loss:.4} (len {len})");
            }
        }
        let tps = (steps * trainer.batch * trainer.seq) as f64 / t0.elapsed().as_secs_f64();
        if variant.starts_with("dense") {
            dense_tps = Some(tps);
        }
        let mut row = vec![variant.clone()];
        for &l in lengths {
            let acc = eval_niah_accuracy(&trainer, vocab, l, eval_batches, 999)?;
            row.push(format!("{:.0}%", acc * 100.0));
        }
        row.push(format!("{tps:.0}"));
        row.push(match dense_tps {
            Some(dt) => format!("{:.2}x", tps / dt),
            None => "-".into(),
        });
        t.row(row);
    }
    Ok(t)
}

/// Fig 8/10 analog: sparsity ablation — train SFA at each k, record
/// loss curves (stability) and final PPL.
pub fn fig8(
    runtime: &Runtime,
    ks: &[usize],
    steps: usize,
    lr: f32,
    eval_batches: usize,
) -> Result<(Table, Vec<(usize, Vec<f32>)>)> {
    let mut t = Table::new(
        &format!("Fig 8/10 — sparsity ablation on SFA ({steps} steps)"),
        &["variant", "final loss", "held-out PPL", "loss monotone?"],
    );
    let mut curves = Vec::new();
    for &k in ks {
        let variant = format!("sfa_k{k}");
        if runtime.manifest.variants.get(&variant).is_none() {
            eprintln!("[fig8] skipping {variant}: not compiled in artifacts");
            continue;
        }
        let mut trainer = Trainer::new(runtime, &variant)?;
        let vocab = runtime.manifest.variant(&variant)?.cfg_usize("vocab")?;
        let mut zipf = ZipfCorpus::new(vocab, 42);
        let mut losses = Vec::new();
        for step in 0..steps {
            let warm = (steps / 10).max(1);
            let lr_t = if step < warm { lr * (step + 1) as f32 / warm as f32 } else { lr };
            let tokens = zipf.batch(trainer.batch, trainer.seq);
            losses.push(trainer.train_step(&tokens, lr_t)?);
        }
        let ppl = eval_ppl(&trainer, CorpusKind::Zipf, vocab, eval_batches, 777)?;
        // Stability check (Fig 10): smoothed curve decreases without spikes.
        let window = (steps / 8).max(1);
        let smooth: Vec<f32> = losses
            .windows(window)
            .map(|w| w.iter().sum::<f32>() / w.len() as f32)
            .collect();
        let monotone = smooth.windows(2).all(|w| w[1] <= w[0] + 0.05);
        t.row(vec![
            variant.clone(),
            format!("{:.4}", losses.last().unwrap()),
            format!("{ppl:.3}"),
            if monotone { "yes".into() } else { "NO".into() },
        ]);
        curves.push((k, losses));
    }
    Ok((t, curves))
}

/// Table 3 analog (§5 adaptation): dense-pretrain, then continue with
/// (a) plain SFA fine-tuning and (b) Eq.-8 regularized fine-tuning;
/// compare recovered quality against from-scratch SFA.
pub fn table3(
    runtime: &Runtime,
    sfa_variant: &str,
    pre_steps: usize,
    ft_steps: usize,
    lr: f32,
    lam: f32,
    eval_batches: usize,
) -> Result<Table> {
    use crate::runtime::HostTensor;

    let vocab = runtime.manifest.variant(sfa_variant)?.cfg_usize("vocab")?;
    let mut t = Table::new(
        &format!(
            "Table 3 — SFA adaptation of a dense-pretrained model \
             (pre={pre_steps}, ft={ft_steps}, λ={lam})"
        ),
        &["path", "held-out PPL (SFA scoring)"],
    );

    // 1. Dense pretrain.
    let mut dense = Trainer::new(runtime, "dense")?;
    let mut zipf = ZipfCorpus::new(vocab, 42);
    for step in 0..pre_steps {
        let warm = (pre_steps / 10).max(1);
        let lr_t = if step < warm { lr * (step + 1) as f32 / warm as f32 } else { lr };
        let tokens = zipf.batch(dense.batch, dense.seq);
        dense.train_step(&tokens, lr_t)?;
    }
    // Baseline: dense weights evaluated under SFA scoring, no tuning.
    let mut sfa_eval = Trainer::new(runtime, sfa_variant)?;
    transplant_params(&dense, &mut sfa_eval)?;
    let ppl_zero = eval_ppl(&sfa_eval, CorpusKind::Zipf, vocab, eval_batches, 777)?;
    t.row(vec!["dense weights, no adaptation".into(), format!("{ppl_zero:.3}")]);

    // 2a. Plain SFA fine-tune from the dense weights (same language,
    // fresh stream — NOT a different-seed process).
    let mut plain = Trainer::new(runtime, sfa_variant)?;
    transplant_params(&dense, &mut plain)?;
    let mut zipf_ft = ZipfCorpus::with_stream(vocab, 42, 43);
    for _ in 0..ft_steps {
        let tokens = zipf_ft.batch(plain.batch, plain.seq);
        plain.train_step(&tokens, lr * 0.3)?;
    }
    let ppl_plain = eval_ppl(&plain, CorpusKind::Zipf, vocab, eval_batches, 777)?;
    t.row(vec!["+ plain SFA fine-tune".into(), format!("{ppl_plain:.3}")]);

    // 2b. Eq-8 regularized adaptation (adapt_step artifact).
    let has_adapt = runtime
        .manifest
        .variant(sfa_variant)?
        .entries
        .contains_key("adapt_step");
    if has_adapt {
        let mut reg = Trainer::new(runtime, sfa_variant)?;
        transplant_params(&dense, &mut reg)?;
        let mut zipf_ft = ZipfCorpus::with_stream(vocab, 42, 43);
        for _ in 0..ft_steps {
            let tokens = zipf_ft.batch(reg.batch, reg.seq);
            reg.adapt_step(&tokens, lr * 0.3, lam)?;
        }
        let ppl_reg = eval_ppl(&reg, CorpusKind::Zipf, vocab, eval_batches, 777)?;
        t.row(vec![
            format!("+ Eq.8 regularized fine-tune (λ={lam})"),
            format!("{ppl_reg:.3}"),
        ]);
    }

    // 3. From-scratch SFA reference.
    let mut scratch = Trainer::new(runtime, sfa_variant)?;
    let mut zipf_s = ZipfCorpus::new(vocab, 42);
    for step in 0..pre_steps + ft_steps {
        let total = pre_steps + ft_steps;
        let warm = (total / 10).max(1);
        let lr_t = if step < warm { lr * (step + 1) as f32 / warm as f32 } else { lr };
        let tokens = zipf_s.batch(scratch.batch, scratch.seq);
        scratch.train_step(&tokens, lr_t)?;
    }
    let ppl_scratch = eval_ppl(&scratch, CorpusKind::Zipf, vocab, eval_batches, 777)?;
    t.row(vec!["from-scratch SFA (same budget)".into(), format!("{ppl_scratch:.3}")]);

    // Dense-on-dense reference row.
    let ppl_dense = eval_ppl(&dense, CorpusKind::Zipf, vocab, eval_batches, 777)?;
    t.row(vec!["dense weights, dense scoring (ref)".into(), format!("{ppl_dense:.3}")]);

    let _ = HostTensor::scalar_f32(0.0);
    Ok(t)
}

/// Copy trained parameters from one trainer to another (same shapes —
/// dense and SFA variants share the parameter space by construction).
fn transplant_params(from: &Trainer, to: &mut Trainer) -> Result<()> {
    let cloned: Result<Vec<_>> = from
        .params()
        .iter()
        .map(crate::train::trainer::clone_literal)
        .collect();
    to.set_params(cloned?)
}

/// Table 12 analog: zero-shot NIAH of Zipf-pretrained models. With a
/// synthetic corpus there is no semantic transfer, so accuracy sits at
/// chance — recorded as a documented divergence (EXPERIMENTS.md).
pub fn table12(
    runtime: &Runtime,
    variants: &[String],
    steps: usize,
    lr: f32,
    lengths: &[usize],
    eval_batches: usize,
) -> Result<Table> {
    let mut header: Vec<String> = vec!["variant".into()];
    header.extend(lengths.iter().map(|l| format!("acc@{l}")));
    let mut t = Table::new(
        "Table 12 — zero-shot NIAH after plain LM pretraining",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for variant in variants {
        let mut trainer = Trainer::new(runtime, variant)?;
        let vocab = runtime.manifest.variant(variant)?.cfg_usize("vocab")?;
        let mut zipf = ZipfCorpus::new(vocab, 42);
        for _ in 0..steps {
            let tokens = zipf.batch(trainer.batch, trainer.seq);
            trainer.train_step(&tokens, lr)?;
        }
        let mut row = vec![variant.clone()];
        for &l in lengths {
            let acc = eval_niah_accuracy(&trainer, vocab, l, eval_batches, 31)?;
            row.push(format!("{:.0}%", acc * 100.0));
        }
        t.row(row);
    }
    Ok(t)
}
