//! The L3 training loop: drives the AOT-compiled `train_step`
//! (fwd + bwd + AdamW fused into one HLO executable) from Rust.
//! Parameters and optimizer state live as PJRT literals and round-trip
//! through each step's tuple output — Python never runs.

use anyhow::{bail, Context, Result};

use crate::runtime::{HostTensor, Runtime};
use crate::train::corpus::NiahSample;

/// Per-run summary (recorded in EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub variant: String,
    pub steps: usize,
    pub losses: Vec<f32>,
    pub final_loss: f32,
    pub wall_s: f64,
    pub tokens_per_s: f64,
}

impl TrainReport {
    /// Validation perplexity from a mean-NLL loss (nats).
    pub fn ppl(loss: f32) -> f32 {
        loss.exp()
    }
}

/// Owns the training state for one variant.
pub struct Trainer<'rt> {
    pub runtime: &'rt Runtime,
    pub variant: String,
    params: Vec<xla::Literal>,
    adam_m: Vec<xla::Literal>,
    adam_v: Vec<xla::Literal>,
    step: xla::Literal,
    pub steps_done: usize,
    pub batch: usize,
    pub seq: usize,
}

impl<'rt> Trainer<'rt> {
    /// Initialize from the seeded weights in the artifact directory.
    pub fn new(runtime: &'rt Runtime, variant: &str) -> Result<Trainer<'rt>> {
        let v = runtime.manifest.variant(variant)?;
        let e = v.entry("train_step")?;
        let params = runtime.load_weights(variant)?;
        let n = v.params.len();
        let adam_m = runtime.zeros(&v.params)?;
        let adam_v = runtime.zeros(&v.params)?;
        // Input layout: params, m, v, step, lr, tokens.
        if e.inputs.len() != 3 * n + 3 {
            bail!("unexpected train_step arity: {} vs 3*{n}+3", e.inputs.len());
        }
        Ok(Trainer {
            runtime,
            variant: variant.to_string(),
            params,
            adam_m,
            adam_v,
            step: HostTensor::scalar_f32(0.0).to_literal()?,
            steps_done: 0,
            batch: e.batch,
            seq: e.seq,
        })
    }

    fn tokens_literal(&self, tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
        if tokens.len() != batch * seq {
            bail!("tokens len {} != {batch}x{seq}", tokens.len());
        }
        HostTensor::I32(tokens.to_vec(), vec![batch, seq]).to_literal()
    }

    /// One optimizer step; returns the LM loss (mean nats).
    pub fn train_step(&mut self, tokens: &[i32], lr: f32) -> Result<f32> {
        let n = self.params.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(3 * n + 3);
        args.extend(self.params.drain(..));
        args.extend(self.adam_m.drain(..));
        args.extend(self.adam_v.drain(..));
        args.push(std::mem::replace(
            &mut self.step,
            HostTensor::scalar_f32(0.0).to_literal()?,
        ));
        args.push(HostTensor::scalar_f32(lr).to_literal()?);
        args.push(self.tokens_literal(tokens, self.batch, self.seq)?);

        let mut outs = self
            .runtime
            .run(&self.variant, "train_step", &args)
            .context("train_step")?;
        // Output layout: params, m, v, step, loss.
        let loss = HostTensor::from_literal(&outs.pop().unwrap())?.as_f32()?[0];
        self.step = outs.pop().unwrap();
        self.adam_v = outs.split_off(2 * n);
        self.adam_m = outs.split_off(n);
        self.params = outs;
        self.steps_done += 1;
        if !loss.is_finite() {
            bail!("loss diverged at step {}: {loss}", self.steps_done);
        }
        Ok(loss)
    }

    /// One Eq.-8 regularized adaptation step (requires the variant to
    /// have been compiled with the `adapt` entry; SFA variants only).
    pub fn adapt_step(&mut self, tokens: &[i32], lr: f32, lam: f32) -> Result<f32> {
        let n = self.params.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(3 * n + 4);
        args.extend(self.params.drain(..));
        args.extend(self.adam_m.drain(..));
        args.extend(self.adam_v.drain(..));
        args.push(std::mem::replace(
            &mut self.step,
            HostTensor::scalar_f32(0.0).to_literal()?,
        ));
        args.push(HostTensor::scalar_f32(lr).to_literal()?);
        args.push(HostTensor::scalar_f32(lam).to_literal()?);
        args.push(self.tokens_literal(tokens, self.batch, self.seq)?);
        let mut outs = self
            .runtime
            .run(&self.variant, "adapt_step", &args)
            .context("adapt_step")?;
        let loss = HostTensor::from_literal(&outs.pop().unwrap())?.as_f32()?[0];
        self.step = outs.pop().unwrap();
        self.adam_v = outs.split_off(2 * n);
        self.adam_m = outs.split_off(n);
        self.params = outs;
        self.steps_done += 1;
        if !loss.is_finite() {
            bail!("adapt loss diverged at step {}: {loss}", self.steps_done);
        }
        Ok(loss)
    }

    /// Replace the parameters (checkpoint transplant), resetting the
    /// optimizer state and step counter.
    pub fn set_params(&mut self, params: Vec<xla::Literal>) -> Result<()> {
        if params.len() != self.params.len() {
            bail!("param count mismatch");
        }
        let v = self.runtime.manifest.variant(&self.variant)?;
        self.adam_m = self.runtime.zeros(&v.params)?;
        self.adam_v = self.runtime.zeros(&v.params)?;
        self.step = HostTensor::scalar_f32(0.0).to_literal()?;
        self.params = params;
        Ok(())
    }

    /// Mean eval loss on one (batch, seq) token grid.
    pub fn eval_loss(&self, tokens: &[i32]) -> Result<f32> {
        let e = self.runtime.manifest.variant(&self.variant)?.entry("eval_step")?;
        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 1);
        for p in &self.params {
            args.push(clone_literal(p)?);
        }
        args.push(self.tokens_literal(tokens, e.batch, e.seq)?);
        let outs = self.runtime.run(&self.variant, "eval_step", &args)?;
        Ok(HostTensor::from_literal(&outs[0])?.as_f32()?[0])
    }

    /// Full logits grid (batch, seq, vocab) for retrieval scoring.
    pub fn logits(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<usize>)> {
        let e = self.runtime.manifest.variant(&self.variant)?.entry("logits")?;
        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 1);
        for p in &self.params {
            args.push(clone_literal(p)?);
        }
        args.push(self.tokens_literal(tokens, e.batch, e.seq)?);
        let outs = self.runtime.run(&self.variant, "logits", &args)?;
        match HostTensor::from_literal(&outs[0])? {
            HostTensor::F32(d, s) => Ok((d, s)),
            _ => bail!("logits not f32"),
        }
    }

    /// NIAH retrieval accuracy: fraction of samples whose argmax
    /// prediction at `answer_pos - 1`'s next-token slot equals the
    /// needle value. Samples are laid out one per batch row.
    pub fn niah_accuracy(&self, batch_tokens: &[i32], samples: &[NiahSample]) -> Result<f64> {
        let (logits, shape) = self.logits(batch_tokens)?;
        let (b, s, v) = (shape[0], shape[1], shape[2]);
        if samples.len() != b {
            bail!("expected {b} samples, got {}", samples.len());
        }
        let mut correct = 0;
        for (i, sample) in samples.iter().enumerate() {
            // logits at position answer_pos predict token answer_pos+1;
            // our NiahSample scores the prediction *of* token at
            // answer_pos+1, i.e. logits index answer_pos.
            let pos = sample.answer_pos;
            assert!(pos + 1 < s);
            let row = &logits[(i * s + pos) * v..(i * s + pos + 1) * v];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            if argmax == sample.value {
                correct += 1;
            }
        }
        Ok(correct as f64 / b as f64)
    }

    /// Snapshot current parameters to an .npz (checkpointing).
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        let v = self.runtime.manifest.variant(&self.variant)?;
        let named: Vec<(String, &xla::Literal)> = v
            .params
            .iter()
            .zip(&self.params)
            .enumerate()
            .map(|(i, (spec, lit))| (format!("{i:04}|{}", spec.name), lit))
            .collect();
        // write_npz wants T: AsRef<Literal>, which the xla crate never
        // implements for Literal itself — bridge with a ref newtype.
        struct LitRef<'a>(&'a xla::Literal);
        impl AsRef<xla::Literal> for LitRef<'_> {
            fn as_ref(&self) -> &xla::Literal {
                self.0
            }
        }
        let pairs: Vec<(&str, LitRef)> =
            named.iter().map(|(n, l)| (n.as_str(), LitRef(l))).collect();
        xla::Literal::write_npz(&pairs, path)?;
        Ok(())
    }

    /// Borrow the current parameter literals (read-only analysis paths).
    pub fn params(&self) -> &[xla::Literal] {
        &self.params
    }

    /// Current parameter tensor by manifest name (host copy).
    pub fn param_by_name(&self, name: &str) -> Result<HostTensor> {
        let v = self.runtime.manifest.variant(&self.variant)?;
        for (spec, lit) in v.params.iter().zip(&self.params) {
            if spec.name == name {
                return HostTensor::from_literal(lit);
            }
        }
        bail!("no parameter named {name:?}")
    }
}

/// Literal cloning via host round-trip (the xla crate has no buffer
/// clone; literals are host-side so this is a memcpy).
pub fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let n_bytes = l.size_bytes();
    let bytes: Vec<u8> = match shape.ty() {
        xla::ElementType::F32 => {
            let mut host = vec![0f32; l.element_count()];
            l.copy_raw_to(&mut host)?;
            unsafe { std::slice::from_raw_parts(host.as_ptr() as *const u8, n_bytes) }.to_vec()
        }
        xla::ElementType::S32 => {
            let mut host = vec![0i32; l.element_count()];
            l.copy_raw_to(&mut host)?;
            unsafe { std::slice::from_raw_parts(host.as_ptr() as *const u8, n_bytes) }.to_vec()
        }
        other => bail!("clone_literal: unsupported {other:?}"),
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        shape.ty(),
        &dims,
        &bytes,
    )?)
}
