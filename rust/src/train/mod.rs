//! Training driver: workload generators + the loop that drives the
//! AOT-compiled `train_step` / `eval_step` / `logits` artifacts.
//!
//! * [`corpus`] — synthetic pretraining corpus (Zipf-weighted Markov
//!   bigram process; stands in for OpenWebText/Pile, DESIGN.md
//!   §Substitutions) and the NIAH generator (paper §4.2: '#'-haystack
//!   with an inserted key/value needle, RULER-style)
//! * [`trainer`] — owns the parameter/optimizer literals and steps the
//!   compiled train_step; evaluation (PPL, NIAH retrieval accuracy)

pub mod corpus;
pub mod experiments;
pub mod trainer;

pub use corpus::{CorpusKind, NiahSample, ZipfCorpus};
pub use trainer::{TrainReport, Trainer};
