//! Decode-path (KV-cache) attention: one query token against a growing
//! key/value cache — the paper's TTNT scenario (Fig. 5/6, App. B.1).
//!
//! * [`DenseKvCache`] — standard dense cache, O(len·d) per step.
//! * [`SparseKvCache`] — SFA cache: keys stored as top-k codes in
//!   *incremental feature-wise posting lists*, O(len·k²/d) expected
//!   score work per step, and App-J memory (values+indices only).
//! * [`KvPolicy`] + [`PrunedKvCache`] — training-free token-pruning
//!   baselines (H2O, SnapKV-style, Quest) for the Table 11 comparison,
//!   each composable with the SFA scorer (the "+SFA" rows).

use crate::attention::{Scorer, NEG_INF};
use crate::sparse::csr::TopkCodes;
use crate::sparse::topk_codes;
use crate::util::matrix::Matrix;

/// Softmax + weighted V-sum over an explicit (key id, score) set
/// (shared with the session decode path).
pub(crate) fn softmax_weighted_sum(
    scores: &[(u32, f32)],
    v_row: impl Fn(usize) -> *const f32,
    d_v: usize,
    out: &mut [f32],
) {
    let m = scores.iter().fold(NEG_INF, |a, &(_, s)| a.max(s));
    out.fill(0.0);
    if m <= NEG_INF {
        return;
    }
    let mut l = 0.0;
    for &(_, s) in scores {
        l += (s - m).exp();
    }
    let inv = 1.0 / l;
    for &(j, s) in scores {
        let w = (s - m).exp() * inv;
        let vp = v_row(j as usize);
        unsafe {
            for t in 0..d_v {
                out[t] += w * *vp.add(t);
            }
        }
    }
}

/// Row-wise top-k of a single vector (shared with the session decode
/// path; the padded (vals, idx) twin of [`topk_codes`]).
pub(crate) fn topk_row(q: &[f32], k: usize) -> (Vec<f32>, Vec<u16>) {
    let m = Matrix::from_vec(1, q.len(), q.to_vec());
    let c = topk_codes(&m, k);
    (c.vals, c.idx)
}

// ---------------------------------------------------------------------------
// Dense cache
// ---------------------------------------------------------------------------

/// Dense KV cache for one head.
#[derive(Debug, Clone)]
pub struct DenseKvCache {
    pub d: usize,
    pub d_v: usize,
    pub keys: Vec<f32>,
    pub values: Vec<f32>,
    pub len: usize,
}

impl DenseKvCache {
    pub fn new(d: usize, d_v: usize) -> Self {
        DenseKvCache { d, d_v, keys: Vec::new(), values: Vec::new(), len: 0 }
    }

    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d_v);
        self.keys.extend_from_slice(k);
        self.values.extend_from_slice(v);
        self.len += 1;
    }

    /// One decode step: softmax(q·Kᵀ/√d)·V over the whole cache.
    pub fn decode(&self, q: &[f32], out: &mut [f32]) {
        let scale = 1.0 / (self.d as f32).sqrt();
        let mut scores = Vec::with_capacity(self.len);
        for j in 0..self.len {
            let krow = &self.keys[j * self.d..(j + 1) * self.d];
            let mut acc = 0.0;
            for t in 0..self.d {
                acc += q[t] * krow[t];
            }
            scores.push((j as u32, acc * scale));
        }
        let values = &self.values;
        let dv = self.d_v;
        softmax_weighted_sum(&scores, |j| values[j * dv..].as_ptr(), dv, out);
    }

    pub fn bytes(&self) -> usize {
        (self.keys.len() + self.values.len()) * 4
    }
}

// ---------------------------------------------------------------------------
// Sparse (SFA) cache
// ---------------------------------------------------------------------------

/// SFA KV cache: top-k key codes in growable feature-wise posting
/// lists (token ids stay ascending because appends are in order), plus
/// dense V. This is the Rust twin of the L2 sparse decode cache.
#[derive(Debug, Clone)]
pub struct SparseKvCache {
    pub d: usize,
    pub d_v: usize,
    pub k: usize,
    /// posting[f] = ascending (token, value) pairs for feature f.
    posting: Vec<Vec<(u32, f32)>>,
    values: Vec<f32>,
    pub len: usize,
}

impl SparseKvCache {
    pub fn new(d: usize, d_v: usize, k: usize) -> Self {
        SparseKvCache {
            d,
            d_v,
            k,
            posting: vec![Vec::new(); d],
            values: Vec::new(),
            len: 0,
        }
    }

    /// Append a *dense* key (top-k happens here) + dense value.
    pub fn append(&mut self, key: &[f32], v: &[f32]) {
        assert_eq!(key.len(), self.d);
        let (vals, idx) = topk_row(key, self.k);
        for (&val, &f) in vals.iter().zip(&idx) {
            if val != 0.0 {
                self.posting[f as usize].push((self.len as u32, val));
            }
        }
        self.values.extend_from_slice(v);
        self.len += 1;
    }

    /// One decode step: sparsify q, walk its features' posting lists
    /// (scores default to 0 for keys with no overlap — all cached keys
    /// participate in the softmax, matching the L1/L2 semantics).
    pub fn decode(&self, q: &[f32], out: &mut [f32]) {
        let scale = 1.0 / (self.d as f32).sqrt();
        let (qv, qi) = topk_row(q, self.k);
        let mut acc = vec![0f32; self.len];
        for (&val, &f) in qv.iter().zip(&qi) {
            if val == 0.0 {
                continue;
            }
            for &(tok, kv) in &self.posting[f as usize] {
                acc[tok as usize] += val * kv;
            }
        }
        let scores: Vec<(u32, f32)> = acc
            .iter()
            .enumerate()
            .map(|(j, &s)| (j as u32, s * scale))
            .collect();
        let values = &self.values;
        let dv = self.d_v;
        softmax_weighted_sum(&scores, |j| values[j * dv..].as_ptr(), dv, out);
    }

    /// Appendix-J style byte accounting (vals+indices for K, dense V).
    pub fn bytes(&self, w: crate::sparse::memory::Widths) -> usize {
        let k_nnz: usize = self.posting.iter().map(|p| p.len()).sum();
        k_nnz * (w.s_val + w.s_idx) + (self.len + 1) * w.s_ptr
            + self.values.len() * w.s_val
    }
}

// ---------------------------------------------------------------------------
// Token-pruning policies (Table 11 baselines)
// ---------------------------------------------------------------------------

/// Which keys a pruning policy retains for the current step.
pub trait KvPolicy: Send {
    fn name(&self) -> String;
    /// Called once per decode step *before* scoring; returns the key ids
    /// to score against (always includes the most recent keys).
    fn select(&mut self, cache_len: usize) -> Vec<u32>;
    /// Called after scoring with the (key, prob) pairs so stateful
    /// policies (H2O) can update their statistics.
    fn observe(&mut self, probs: &[(u32, f32)]);
}

/// H2O: keep `budget` heavy hitters by cumulative attention mass plus a
/// `recent` tail window (Zhang et al. 2023).
pub struct H2oPolicy {
    pub budget: usize,
    pub recent: usize,
    cumulative: Vec<f32>,
}

impl H2oPolicy {
    pub fn new(budget: usize, recent: usize) -> Self {
        H2oPolicy { budget, recent, cumulative: Vec::new() }
    }
}

impl KvPolicy for H2oPolicy {
    fn name(&self) -> String {
        format!("h2o(b={},r={})", self.budget, self.recent)
    }

    fn select(&mut self, cache_len: usize) -> Vec<u32> {
        self.cumulative.resize(cache_len, 0.0);
        let recent_lo = cache_len.saturating_sub(self.recent);
        let mut heavy: Vec<u32> = (0..recent_lo as u32).collect();
        if heavy.len() > self.budget {
            heavy.select_nth_unstable_by(self.budget - 1, |&a, &b| {
                self.cumulative[b as usize]
                    .partial_cmp(&self.cumulative[a as usize])
                    .unwrap()
            });
            heavy.truncate(self.budget);
        }
        heavy.extend(recent_lo as u32..cache_len as u32);
        heavy.sort_unstable();
        heavy
    }

    fn observe(&mut self, probs: &[(u32, f32)]) {
        for &(j, p) in probs {
            self.cumulative[j as usize] += p;
        }
    }
}

/// SnapKV-style: a fixed retained set chosen once (at prefill end, from
/// pooled recent-query attention) plus the recent tail.
pub struct SnapKvPolicy {
    pub keep: Vec<u32>,
    pub recent: usize,
}

impl KvPolicy for SnapKvPolicy {
    fn name(&self) -> String {
        format!("snapkv(keep={},r={})", self.keep.len(), self.recent)
    }

    fn select(&mut self, cache_len: usize) -> Vec<u32> {
        let recent_lo = cache_len.saturating_sub(self.recent) as u32;
        let mut set: Vec<u32> = self.keep.iter().copied().filter(|&j| j < recent_lo).collect();
        set.extend(recent_lo..cache_len as u32);
        set.sort_unstable();
        set.dedup();
        set
    }

    fn observe(&mut self, _probs: &[(u32, f32)]) {}
}

/// Quest-style page selection: summarize pages of `page` keys by
/// per-dimension min/max; per step keep the `budget` pages with the
/// highest upper-bound score for the current query.
pub struct QuestPolicy {
    pub page: usize,
    pub budget_pages: usize,
    pub d: usize,
    page_min: Vec<f32>,
    page_max: Vec<f32>,
    n_pages: usize,
    /// Query for the current step (set via [`QuestPolicy::set_query`]).
    q: Vec<f32>,
}

impl QuestPolicy {
    pub fn new(page: usize, budget_pages: usize, d: usize) -> Self {
        QuestPolicy {
            page,
            budget_pages,
            d,
            page_min: Vec::new(),
            page_max: Vec::new(),
            n_pages: 0,
            q: vec![0.0; d],
        }
    }

    /// Update page summaries with a freshly appended key.
    pub fn ingest_key(&mut self, key_id: usize, key: &[f32]) {
        let pg = key_id / self.page;
        if pg >= self.n_pages {
            self.n_pages = pg + 1;
            self.page_min.resize(self.n_pages * self.d, f32::INFINITY);
            self.page_max.resize(self.n_pages * self.d, f32::NEG_INFINITY);
        }
        for t in 0..self.d {
            let i = pg * self.d + t;
            self.page_min[i] = self.page_min[i].min(key[t]);
            self.page_max[i] = self.page_max[i].max(key[t]);
        }
    }

    pub fn set_query(&mut self, q: &[f32]) {
        self.q.copy_from_slice(q);
    }

    fn page_bound(&self, pg: usize) -> f32 {
        let mut b = 0.0;
        for t in 0..self.d {
            let q = self.q[t];
            let lo = self.page_min[pg * self.d + t];
            let hi = self.page_max[pg * self.d + t];
            b += (q * lo).max(q * hi);
        }
        b
    }
}

impl KvPolicy for QuestPolicy {
    fn name(&self) -> String {
        format!("quest(page={},pages={})", self.page, self.budget_pages)
    }

    fn select(&mut self, cache_len: usize) -> Vec<u32> {
        let n_pages = cache_len.div_ceil(self.page);
        let mut pages: Vec<usize> = (0..n_pages).collect();
        if pages.len() > self.budget_pages {
            pages.select_nth_unstable_by(self.budget_pages - 1, |&a, &b| {
                self.page_bound(b).partial_cmp(&self.page_bound(a)).unwrap()
            });
            pages.truncate(self.budget_pages);
        }
        // Always include the newest page (recency, as in Quest).
        if n_pages > 0 && !pages.contains(&(n_pages - 1)) {
            pages.push(n_pages - 1);
        }
        let mut keys = Vec::with_capacity(pages.len() * self.page);
        for pg in pages {
            let lo = pg * self.page;
            let hi = ((pg + 1) * self.page).min(cache_len);
            keys.extend(lo as u32..hi as u32);
        }
        keys.sort_unstable();
        keys
    }

    fn observe(&mut self, _probs: &[(u32, f32)]) {}
}

/// Dense KV cache + pruning policy + pluggable scorer (Table 11 rows
/// and their "+SFA" compositions).
pub struct PrunedKvCache<P: KvPolicy> {
    pub cache: DenseKvCache,
    pub policy: P,
    pub scorer: Scorer,
    /// Cached top-k key codes (built lazily when scorer is SFA).
    key_codes: Option<TopkCodes>,
}

impl<P: KvPolicy> PrunedKvCache<P> {
    pub fn new(d: usize, d_v: usize, policy: P, scorer: Scorer) -> Self {
        PrunedKvCache { cache: DenseKvCache::new(d, d_v), policy, scorer, key_codes: None }
    }

    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        self.cache.append(k, v);
        if let Scorer::Sfa { k: kk } = self.scorer {
            let (vals, idx) = topk_row(k, kk);
            match &mut self.key_codes {
                Some(codes) => {
                    codes.vals.extend_from_slice(&vals);
                    codes.idx.extend_from_slice(&idx);
                    codes.rows += 1;
                }
                None => {
                    self.key_codes = Some(TopkCodes {
                        rows: 1,
                        dim: self.cache.d,
                        k: kk,
                        vals,
                        idx,
                    });
                }
            }
        }
    }

    pub fn decode(&mut self, q: &[f32], out: &mut [f32]) {
        let selected = self.policy.select(self.cache.len);
        let scale = 1.0 / (self.cache.d as f32).sqrt();
        let mut scores = Vec::with_capacity(selected.len());
        match self.scorer {
            Scorer::Dense => {
                for &j in &selected {
                    let krow = &self.cache.keys
                        [j as usize * self.cache.d..(j as usize + 1) * self.cache.d];
                    let mut acc = 0.0;
                    for t in 0..self.cache.d {
                        acc += q[t] * krow[t];
                    }
                    scores.push((j, acc * scale));
                }
            }
            Scorer::Sfa { k: kk } => {
                let (qv, qi) = topk_row(q, kk);
                let codes = self.key_codes.as_ref().expect("codes built on append");
                let qcodes = TopkCodes {
                    rows: 1, dim: self.cache.d, k: kk, vals: qv, idx: qi,
                };
                for &j in &selected {
                    scores.push((j, qcodes.overlap_dot(0, codes, j as usize) * scale));
                }
            }
        }
        // softmax over the retained set
        let m = scores.iter().fold(NEG_INF, |a, &(_, s)| a.max(s));
        let mut probs: Vec<(u32, f32)> = Vec::with_capacity(scores.len());
        let mut l = 0.0;
        for &(j, s) in &scores {
            let e = (s - m).exp();
            l += e;
            probs.push((j, e));
        }
        for p in probs.iter_mut() {
            p.1 /= l;
        }
        out.fill(0.0);
        for &(j, w) in &probs {
            let vrow = self.cache.values
                [j as usize * self.cache.d_v..(j as usize + 1) * self.cache.d_v]
                .as_ptr();
            unsafe {
                for t in 0..self.cache.d_v {
                    out[t] += w * *vrow.add(t);
                }
            }
        }
        self.policy.observe(&probs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::DenseAttention;
    use crate::attention::Engine;
    use crate::util::rng::Rng;

    fn fill_caches(n: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        (
            Matrix::randn(n, d, &mut rng, 1.0),
            Matrix::randn(n, d, &mut rng, 1.0),
            Matrix::randn(n, d, &mut rng, 1.0),
        )
    }

    #[test]
    fn dense_decode_matches_last_row_of_forward() {
        let (q, k, v) = fill_caches(24, 16, 0);
        let mut cache = DenseKvCache::new(16, 16);
        for i in 0..24 {
            cache.append(k.row(i), v.row(i));
        }
        let mut out = vec![0f32; 16];
        cache.decode(q.row(23), &mut out);
        let full = DenseAttention.forward(&q, &k, &v, true);
        for t in 0..16 {
            assert!((out[t] - full.get(23, t)).abs() < 1e-5);
        }
    }

    #[test]
    fn sparse_decode_matches_sfa_reference_last_row() {
        let (q, k, v) = fill_caches(32, 32, 1);
        let mut cache = SparseKvCache::new(32, 32, 4);
        for i in 0..32 {
            cache.append(k.row(i), v.row(i));
        }
        let mut out = vec![0f32; 32];
        cache.decode(q.row(31), &mut out);
        let full = crate::attention::dense::SfaReference { k: 4 }
            .forward(&q, &k, &v, true);
        for t in 0..32 {
            assert!((out[t] - full.get(31, t)).abs() < 1e-5, "t={t}");
        }
    }

    #[test]
    fn sparse_cache_uses_less_memory() {
        let (_, k, v) = fill_caches(512, 64, 2);
        let mut dense = DenseKvCache::new(64, 64);
        let mut sparse = SparseKvCache::new(64, 64, 8);
        for i in 0..512 {
            dense.append(k.row(i), v.row(i));
            sparse.append(k.row(i), v.row(i));
        }
        let w = crate::sparse::memory::Widths::OURS;
        assert!(sparse.bytes(w) < dense.bytes());
    }

    #[test]
    fn h2o_respects_budget_and_recency() {
        let mut p = H2oPolicy::new(4, 2);
        // Simulate 20 cached tokens with mass concentrated on key 3.
        let sel = p.select(20);
        assert!(sel.len() <= 4 + 2);
        p.observe(&[(3, 0.9), (0, 0.1)]);
        let sel = p.select(20);
        assert!(sel.contains(&3));
        assert!(sel.contains(&18) && sel.contains(&19), "recent tail kept");
    }

    #[test]
    fn snapkv_keeps_fixed_set() {
        let mut p = SnapKvPolicy { keep: vec![1, 5, 9], recent: 2 };
        let sel = p.select(30);
        for j in [1, 5, 9, 28, 29] {
            assert!(sel.contains(&j));
        }
        assert_eq!(sel.len(), 5);
    }

    #[test]
    fn quest_selects_high_bound_pages() {
        let d = 4;
        let mut p = QuestPolicy::new(4, 1, d);
        // 3 pages; page 1 has large-magnitude keys.
        for i in 0..12 {
            let scale = if (4..8).contains(&i) { 10.0 } else { 0.1 };
            let key = vec![scale; d];
            p.ingest_key(i, &key);
        }
        p.set_query(&[1.0, 1.0, 1.0, 1.0]);
        let sel = p.select(12);
        // Budget page 1 (+always newest page 2).
        assert!(sel.contains(&4) && sel.contains(&7), "{sel:?}");
        assert!(sel.contains(&11));
        assert!(!sel.contains(&0));
    }

    #[test]
    fn pruned_cache_with_full_budget_matches_dense() {
        let (q, k, v) = fill_caches(16, 8, 3);
        let mut pruned = PrunedKvCache::new(
            8, 8, H2oPolicy::new(1000, 1000), Scorer::Dense,
        );
        let mut dense = DenseKvCache::new(8, 8);
        for i in 0..16 {
            pruned.append(k.row(i), v.row(i));
            dense.append(k.row(i), v.row(i));
        }
        let mut a = vec![0f32; 8];
        let mut b = vec![0f32; 8];
        pruned.decode(q.row(15), &mut a);
        dense.decode(q.row(15), &mut b);
        for t in 0..8 {
            assert!((a[t] - b[t]).abs() < 1e-5);
        }
    }

    #[test]
    fn pruned_cache_sfa_scorer_matches_sparse_cache_full_budget() {
        let (q, k, v) = fill_caches(20, 16, 4);
        let mut pruned = PrunedKvCache::new(
            16, 16, H2oPolicy::new(1000, 1000), Scorer::Sfa { k: 4 },
        );
        let mut sparse = SparseKvCache::new(16, 16, 4);
        for i in 0..20 {
            pruned.append(k.row(i), v.row(i));
            sparse.append(k.row(i), v.row(i));
        }
        let mut a = vec![0f32; 16];
        let mut b = vec![0f32; 16];
        pruned.decode(q.row(19), &mut a);
        sparse.decode(q.row(19), &mut b);
        for t in 0..16 {
            assert!((a[t] - b[t]).abs() < 1e-5, "t={t}: {} vs {}", a[t], b[t]);
        }
    }
}
