//! Decode-path (KV-cache) attention: one query token against a growing
//! key/value cache — the paper's TTNT scenario (Fig. 5/6, App. B.1).
//!
//! * [`DenseKvCache`] — standard dense cache, O(len·d) per step.
//! * [`SparseKvCache`] — SFA cache: keys stored as top-k codes in
//!   *incremental feature-wise posting lists*, O(len·k²/d) expected
//!   score work per step, and App-J memory (values+indices only).
//! * [`KvPolicy`] + [`PrunedKvCache`] — training-free token-pruning
//!   baselines (H2O, SnapKV-style, Quest) for the Table 11 comparison,
//!   each composable with the SFA scorer (the "+SFA" rows).

use crate::attention::{Scorer, NEG_INF};
use crate::sparse::csr::TopkCodes;
use crate::sparse::topk_codes;
use crate::util::matrix::Matrix;

/// Stable softmax over an explicit (key id, score) set; returns the
/// matching (key id, probability) pairs (empty iff no finite score).
pub(crate) fn softmax_probs(scores: &[(u32, f32)]) -> Vec<(u32, f32)> {
    let m = scores.iter().fold(NEG_INF, |a, &(_, s)| a.max(s));
    if m <= NEG_INF {
        return Vec::new();
    }
    let mut l = 0.0;
    for &(_, s) in scores {
        l += (s - m).exp();
    }
    let inv = 1.0 / l;
    scores.iter().map(|&(j, s)| (j, (s - m).exp() * inv)).collect()
}

/// Probability-weighted V-sum over (key id, weight) pairs (zeroes `out`
/// first, so an empty set yields the zero vector).
pub(crate) fn weighted_sum(
    probs: &[(u32, f32)],
    v_row: impl Fn(usize) -> *const f32,
    d_v: usize,
    out: &mut [f32],
) {
    out.fill(0.0);
    for &(j, w) in probs {
        let vp = v_row(j as usize);
        unsafe {
            for t in 0..d_v {
                out[t] += w * *vp.add(t);
            }
        }
    }
}

/// Softmax + weighted V-sum over an explicit (key id, score) set
/// (shared with the session decode path). Streams with zero
/// allocation, but computes each weight with exactly the
/// [`softmax_probs`] formula (`(s-m).exp() * (1/l)`, same order), so
/// callers that also need the probabilities (KV-policy observation)
/// can run [`softmax_probs`] ∘ [`weighted_sum`] instead and get
/// bit-identical outputs.
pub(crate) fn softmax_weighted_sum(
    scores: &[(u32, f32)],
    v_row: impl Fn(usize) -> *const f32,
    d_v: usize,
    out: &mut [f32],
) {
    let m = scores.iter().fold(NEG_INF, |a, &(_, s)| a.max(s));
    out.fill(0.0);
    if m <= NEG_INF {
        return;
    }
    let mut l = 0.0;
    for &(_, s) in scores {
        l += (s - m).exp();
    }
    let inv = 1.0 / l;
    for &(j, s) in scores {
        let w = (s - m).exp() * inv;
        let vp = v_row(j as usize);
        unsafe {
            for t in 0..d_v {
                out[t] += w * *vp.add(t);
            }
        }
    }
}

/// Row-wise top-k of a single vector (shared with the session decode
/// path; the padded (vals, idx) twin of [`topk_codes`]).
pub(crate) fn topk_row(q: &[f32], k: usize) -> (Vec<f32>, Vec<u16>) {
    let m = Matrix::from_vec(1, q.len(), q.to_vec());
    let c = topk_codes(&m, k);
    (c.vals, c.idx)
}

// ---------------------------------------------------------------------------
// Dense cache
// ---------------------------------------------------------------------------

/// Dense KV cache for one head.
#[derive(Debug, Clone)]
pub struct DenseKvCache {
    pub d: usize,
    pub d_v: usize,
    pub keys: Vec<f32>,
    pub values: Vec<f32>,
    pub len: usize,
}

impl DenseKvCache {
    pub fn new(d: usize, d_v: usize) -> Self {
        DenseKvCache { d, d_v, keys: Vec::new(), values: Vec::new(), len: 0 }
    }

    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d_v);
        self.keys.extend_from_slice(k);
        self.values.extend_from_slice(v);
        self.len += 1;
    }

    /// One decode step: softmax(q·Kᵀ/√d)·V over the whole cache.
    pub fn decode(&self, q: &[f32], out: &mut [f32]) {
        let scale = 1.0 / (self.d as f32).sqrt();
        let mut scores = Vec::with_capacity(self.len);
        for j in 0..self.len {
            let krow = &self.keys[j * self.d..(j + 1) * self.d];
            let mut acc = 0.0;
            for t in 0..self.d {
                acc += q[t] * krow[t];
            }
            scores.push((j as u32, acc * scale));
        }
        let values = &self.values;
        let dv = self.d_v;
        softmax_weighted_sum(&scores, |j| values[j * dv..].as_ptr(), dv, out);
    }

    pub fn bytes(&self) -> usize {
        (self.keys.len() + self.values.len()) * 4
    }
}

// ---------------------------------------------------------------------------
// Sparse (SFA) cache
// ---------------------------------------------------------------------------

/// SFA KV cache: top-k key codes in growable feature-wise posting
/// lists (token ids stay ascending because appends are in order), plus
/// dense V. This is the Rust twin of the L2 sparse decode cache.
#[derive(Debug, Clone)]
pub struct SparseKvCache {
    pub d: usize,
    pub d_v: usize,
    pub k: usize,
    /// posting[f] = ascending (token, value) pairs for feature f.
    posting: Vec<Vec<(u32, f32)>>,
    values: Vec<f32>,
    pub len: usize,
}

impl SparseKvCache {
    pub fn new(d: usize, d_v: usize, k: usize) -> Self {
        SparseKvCache {
            d,
            d_v,
            k,
            posting: vec![Vec::new(); d],
            values: Vec::new(),
            len: 0,
        }
    }

    /// Append a *dense* key (top-k happens here) + dense value.
    pub fn append(&mut self, key: &[f32], v: &[f32]) {
        assert_eq!(key.len(), self.d);
        let (vals, idx) = topk_row(key, self.k);
        for (&val, &f) in vals.iter().zip(&idx) {
            if val != 0.0 {
                self.posting[f as usize].push((self.len as u32, val));
            }
        }
        self.values.extend_from_slice(v);
        self.len += 1;
    }

    /// One decode step: sparsify q, walk its features' posting lists
    /// (scores default to 0 for keys with no overlap — all cached keys
    /// participate in the softmax, matching the L1/L2 semantics).
    pub fn decode(&self, q: &[f32], out: &mut [f32]) {
        let scale = 1.0 / (self.d as f32).sqrt();
        let (qv, qi) = topk_row(q, self.k);
        let mut acc = vec![0f32; self.len];
        for (&val, &f) in qv.iter().zip(&qi) {
            if val == 0.0 {
                continue;
            }
            for &(tok, kv) in &self.posting[f as usize] {
                acc[tok as usize] += val * kv;
            }
        }
        let scores: Vec<(u32, f32)> = acc
            .iter()
            .enumerate()
            .map(|(j, &s)| (j as u32, s * scale))
            .collect();
        let values = &self.values;
        let dv = self.d_v;
        softmax_weighted_sum(&scores, |j| values[j * dv..].as_ptr(), dv, out);
    }

    /// Appendix-J style byte accounting (vals+indices for K, dense V).
    pub fn bytes(&self, w: crate::sparse::memory::Widths) -> usize {
        let k_nnz: usize = self.posting.iter().map(|p| p.len()).sum();
        k_nnz * (w.s_val + w.s_idx) + (self.len + 1) * w.s_ptr
            + self.values.len() * w.s_val
    }
}

// ---------------------------------------------------------------------------
// Token-pruning policies (Table 11 baselines)
// ---------------------------------------------------------------------------

/// Which keys a pruning policy retains for the current step.
///
/// Two consumers drive this trait. The Table-11 baselines
/// ([`PrunedKvCache`]) call `select` to *score a subset* each step and
/// keep every key resident. The serve stack's policy-budgeted lanes
/// (`AttentionSession::admit_lane_with_policy`) instead use `select`'s
/// result as the *survivor set* of a physical eviction
/// ([`crate::kv_cache::paged::PagedKvCache::retain`]) and then call
/// [`KvPolicy::compact`] so the policy remaps its statistics onto the
/// compacted coordinates. `Sync` is required because policies live
/// inside sessions that are shared across scoring threads (the
/// policies themselves are only mutated between parallel sections).
pub trait KvPolicy: Send + Sync {
    fn name(&self) -> String;
    /// Called once per decode step *before* scoring; returns the key ids
    /// to score against, ascending (always includes the most recent
    /// keys).
    fn select(&mut self, cache_len: usize) -> Vec<u32>;
    /// Called after scoring with the (key, prob) pairs so stateful
    /// policies (H2O) can update their statistics.
    fn observe(&mut self, probs: &[(u32, f32)]);
    /// Feed one freshly cached key (`key_id` is its cache position) to
    /// policies that summarize keys (Quest page min/max). Default: no-op.
    fn ingest_key(&mut self, _key_id: usize, _key: &[f32]) {}
    /// Latest query, for query-aware selection (Quest). Default: no-op.
    fn set_query(&mut self, _q: &[f32]) {}
    /// The cache physically evicted everything outside `keep`
    /// (ascending): key `keep[i]` is now key `i`. Remap internal state.
    /// Default: no-op (stateless policies).
    fn compact(&mut self, _keep: &[u32]) {}
    /// Tier-demotion verdict (the tiered paged KV's precision axis):
    /// key ids, ascending, that should drop to the int8 cold tier.
    /// This fires *before* the evict verdict in a token's lifecycle —
    /// the cold set is the keys the policy would still `select`
    /// (keep) but that sit outside its recency window: kept, old,
    /// re-scored every step, tolerant of bounded dequantization error.
    /// Keys outside the select set never need demoting (the next prune
    /// evicts them outright). Default: empty (no tiering opinion).
    fn demote(&mut self, _cache_len: usize) -> Vec<u32> {
        Vec::new()
    }
}

/// Top-`budget` ids from `[0, recent_lo)` by cumulative attention mass
/// (the heavy-hitter selection H2O and SnapKV-once share). Caller
/// guarantees `cumulative.len() >= recent_lo`.
fn top_by_mass(cumulative: &[f32], budget: usize, recent_lo: usize) -> Vec<u32> {
    let mut heavy: Vec<u32> = (0..recent_lo as u32).collect();
    if heavy.len() > budget {
        heavy.select_nth_unstable_by(budget - 1, |&a, &b| {
            cumulative[b as usize].partial_cmp(&cumulative[a as usize]).unwrap()
        });
        heavy.truncate(budget);
    }
    heavy
}

/// Accumulate observed probability mass per key id, growing the vector
/// as new ids appear.
fn accumulate_mass(cumulative: &mut Vec<f32>, probs: &[(u32, f32)]) {
    for &(j, p) in probs {
        if j as usize >= cumulative.len() {
            cumulative.resize(j as usize + 1, 0.0);
        }
        cumulative[j as usize] += p;
    }
}

/// Remap key ids into the post-compaction numbering (`keep` ascending;
/// ids not in `keep` were evicted and drop out).
fn remap_ids(ids: &[u32], keep: &[u32]) -> Vec<u32> {
    ids.iter().filter_map(|&j| keep.binary_search(&j).ok().map(|i| i as u32)).collect()
}

/// Gather each kept id's cumulative mass into the compacted numbering.
fn remap_mass(cumulative: &[f32], keep: &[u32]) -> Vec<f32> {
    keep.iter().map(|&j| cumulative.get(j as usize).copied().unwrap_or(0.0)).collect()
}

/// H2O: keep `budget` heavy hitters by cumulative attention mass plus a
/// `recent` tail window (Zhang et al. 2023).
pub struct H2oPolicy {
    pub budget: usize,
    pub recent: usize,
    cumulative: Vec<f32>,
}

impl H2oPolicy {
    pub fn new(budget: usize, recent: usize) -> Self {
        H2oPolicy { budget, recent, cumulative: Vec::new() }
    }
}

impl KvPolicy for H2oPolicy {
    fn name(&self) -> String {
        format!("h2o(b={},r={})", self.budget, self.recent)
    }

    fn select(&mut self, cache_len: usize) -> Vec<u32> {
        self.cumulative.resize(cache_len, 0.0);
        let recent_lo = cache_len.saturating_sub(self.recent);
        let mut heavy = top_by_mass(&self.cumulative, self.budget, recent_lo);
        heavy.extend(recent_lo as u32..cache_len as u32);
        heavy.sort_unstable();
        heavy
    }

    fn observe(&mut self, probs: &[(u32, f32)]) {
        accumulate_mass(&mut self.cumulative, probs);
    }

    fn compact(&mut self, keep: &[u32]) {
        self.cumulative = remap_mass(&self.cumulative, keep);
    }

    /// Cold set: the heavy hitters themselves — kept by mass but
    /// outside the recent tail, exactly the keys `select` retains
    /// beyond recency.
    fn demote(&mut self, cache_len: usize) -> Vec<u32> {
        self.cumulative.resize(cache_len, 0.0);
        let recent_lo = cache_len.saturating_sub(self.recent);
        let mut cold = top_by_mass(&self.cumulative, self.budget, recent_lo);
        cold.sort_unstable();
        cold
    }
}

/// SnapKV-style: a fixed retained set chosen once (at prefill end, from
/// pooled recent-query attention) plus the recent tail.
pub struct SnapKvPolicy {
    pub keep: Vec<u32>,
    pub recent: usize,
}

impl KvPolicy for SnapKvPolicy {
    fn name(&self) -> String {
        format!("snapkv(keep={},r={})", self.keep.len(), self.recent)
    }

    fn select(&mut self, cache_len: usize) -> Vec<u32> {
        let recent_lo = cache_len.saturating_sub(self.recent) as u32;
        let mut set: Vec<u32> = self.keep.iter().copied().filter(|&j| j < recent_lo).collect();
        set.extend(recent_lo..cache_len as u32);
        set.sort_unstable();
        set.dedup();
        set
    }

    fn observe(&mut self, _probs: &[(u32, f32)]) {}

    fn compact(&mut self, keep: &[u32]) {
        self.keep = remap_ids(&self.keep, keep);
    }

    /// Cold set: the frozen retained ids outside the recent tail.
    fn demote(&mut self, cache_len: usize) -> Vec<u32> {
        let recent_lo = cache_len.saturating_sub(self.recent) as u32;
        let mut cold: Vec<u32> =
            self.keep.iter().copied().filter(|&j| j < recent_lo).collect();
        cold.sort_unstable();
        cold.dedup();
        cold
    }
}

/// Serve-side SnapKV: like [`SnapKvPolicy`] the retained set is chosen
/// *once*, but here the policy chooses it itself — at the first
/// compaction (prefill end under policy-budget serving) — from the
/// attention mass observed so far (the pooled recent-query window the
/// session feeds it during prefill). Until then it accumulates like
/// H2O; afterwards `observe` is ignored and the frozen set plus the
/// recent tail is all that survives.
pub struct SnapKvOncePolicy {
    pub budget: usize,
    pub recent: usize,
    cumulative: Vec<f32>,
    /// Chosen-once retained set in *current* cache coordinates; `None`
    /// until the first compaction freezes it.
    frozen: Option<Vec<u32>>,
    /// `cache_len - recent` at the last `select`, to split scored picks
    /// from the recent tail when the freeze happens.
    last_recent_lo: u32,
}

impl SnapKvOncePolicy {
    pub fn new(budget: usize, recent: usize) -> Self {
        SnapKvOncePolicy {
            budget,
            recent,
            cumulative: Vec::new(),
            frozen: None,
            last_recent_lo: 0,
        }
    }
}

impl KvPolicy for SnapKvOncePolicy {
    fn name(&self) -> String {
        format!("snapkv_once(b={},r={})", self.budget, self.recent)
    }

    fn select(&mut self, cache_len: usize) -> Vec<u32> {
        let recent_lo = cache_len.saturating_sub(self.recent);
        self.last_recent_lo = recent_lo as u32;
        let mut set: Vec<u32> = match &self.frozen {
            Some(frozen) => {
                frozen.iter().copied().filter(|&j| j < recent_lo as u32).collect()
            }
            None => {
                self.cumulative.resize(cache_len, 0.0);
                top_by_mass(&self.cumulative, self.budget, recent_lo)
            }
        };
        set.extend(recent_lo as u32..cache_len as u32);
        set.sort_unstable();
        set.dedup();
        set
    }

    fn observe(&mut self, probs: &[(u32, f32)]) {
        if self.frozen.is_some() {
            return; // the set is snapped; later attention can't move it
        }
        accumulate_mass(&mut self.cumulative, probs);
    }

    fn compact(&mut self, keep: &[u32]) {
        self.cumulative = remap_mass(&self.cumulative, keep);
        self.frozen = Some(match &self.frozen {
            // Remap the frozen ids onto the compacted coordinates.
            Some(frozen) => remap_ids(frozen, keep),
            // First compaction: freeze the scored (non-tail) survivors.
            None => keep
                .iter()
                .enumerate()
                .filter(|&(_, &j)| j < self.last_recent_lo)
                .map(|(i, _)| i as u32)
                .collect(),
        });
    }

    /// Cold set: the frozen snapped set (or, pre-freeze, the current
    /// heavy hitters) outside the recent tail — the same non-tail keys
    /// `select` keeps.
    fn demote(&mut self, cache_len: usize) -> Vec<u32> {
        let recent_lo = cache_len.saturating_sub(self.recent);
        let mut cold: Vec<u32> = match &self.frozen {
            Some(frozen) => {
                frozen.iter().copied().filter(|&j| j < recent_lo as u32).collect()
            }
            None => {
                self.cumulative.resize(cache_len, 0.0);
                top_by_mass(&self.cumulative, self.budget, recent_lo)
            }
        };
        cold.sort_unstable();
        cold.dedup();
        cold
    }
}

/// Quest-style page selection: summarize pages of `page` keys by
/// per-dimension min/max; per step keep the `budget` pages with the
/// highest upper-bound score for the current query.
pub struct QuestPolicy {
    pub page: usize,
    pub budget_pages: usize,
    pub d: usize,
    page_min: Vec<f32>,
    page_max: Vec<f32>,
    n_pages: usize,
    /// Query for the current step (set via [`QuestPolicy::set_query`]).
    q: Vec<f32>,
}

impl QuestPolicy {
    pub fn new(page: usize, budget_pages: usize, d: usize) -> Self {
        QuestPolicy {
            page,
            budget_pages,
            d,
            page_min: Vec::new(),
            page_max: Vec::new(),
            n_pages: 0,
            q: vec![0.0; d],
        }
    }

    fn page_bound(&self, pg: usize) -> f32 {
        let mut b = 0.0;
        for t in 0..self.d {
            let q = self.q[t];
            let lo = self.page_min[pg * self.d + t];
            let hi = self.page_max[pg * self.d + t];
            b += (q * lo).max(q * hi);
        }
        b
    }
}

impl KvPolicy for QuestPolicy {
    fn name(&self) -> String {
        format!("quest(page={},pages={})", self.page, self.budget_pages)
    }

    fn select(&mut self, cache_len: usize) -> Vec<u32> {
        let n_pages = cache_len.div_ceil(self.page);
        let mut pages: Vec<usize> = (0..n_pages).collect();
        if pages.len() > self.budget_pages {
            pages.select_nth_unstable_by(self.budget_pages - 1, |&a, &b| {
                self.page_bound(b).partial_cmp(&self.page_bound(a)).unwrap()
            });
            pages.truncate(self.budget_pages);
        }
        // Always include the newest page (recency, as in Quest).
        if n_pages > 0 && !pages.contains(&(n_pages - 1)) {
            pages.push(n_pages - 1);
        }
        let mut keys = Vec::with_capacity(pages.len() * self.page);
        for pg in pages {
            let lo = pg * self.page;
            let hi = ((pg + 1) * self.page).min(cache_len);
            keys.extend(lo as u32..hi as u32);
        }
        keys.sort_unstable();
        keys
    }

    fn observe(&mut self, _probs: &[(u32, f32)]) {}

    /// Update page summaries with a freshly appended key.
    fn ingest_key(&mut self, key_id: usize, key: &[f32]) {
        let pg = key_id / self.page;
        if pg >= self.n_pages {
            self.n_pages = pg + 1;
            self.page_min.resize(self.n_pages * self.d, f32::INFINITY);
            self.page_max.resize(self.n_pages * self.d, f32::NEG_INFINITY);
        }
        for t in 0..self.d {
            let i = pg * self.d + t;
            self.page_min[i] = self.page_min[i].min(key[t]);
            self.page_max[i] = self.page_max[i].max(key[t]);
        }
    }

    fn set_query(&mut self, q: &[f32]) {
        self.q.copy_from_slice(q);
    }

    /// Rebuild page summaries for the compacted key numbering. Each new
    /// page's bounds are the elementwise min/max over the old pages its
    /// surviving keys came from — exact when whole pages survive (the
    /// shape Quest's own `select` produces), conservative (bounds only
    /// widen, never tighten incorrectly) for arbitrary keeps.
    fn compact(&mut self, keep: &[u32]) {
        let n_new = keep.len().div_ceil(self.page);
        let mut nmin = vec![f32::INFINITY; n_new * self.d];
        let mut nmax = vec![f32::NEG_INFINITY; n_new * self.d];
        for (new_id, &old_id) in keep.iter().enumerate() {
            let np = new_id / self.page;
            let op = old_id as usize / self.page;
            if op >= self.n_pages {
                continue;
            }
            for t in 0..self.d {
                nmin[np * self.d + t] = nmin[np * self.d + t].min(self.page_min[op * self.d + t]);
                nmax[np * self.d + t] = nmax[np * self.d + t].max(self.page_max[op * self.d + t]);
            }
        }
        self.page_min = nmin;
        self.page_max = nmax;
        self.n_pages = n_new;
    }

    /// Cold set: the query-selected pages *except* the newest — Quest
    /// keeps whole pages, so its verdict is naturally page-granular
    /// (matching the paged cache's whole-page demotion) and always
    /// spares the page still being appended to.
    fn demote(&mut self, cache_len: usize) -> Vec<u32> {
        let n_pages = cache_len.div_ceil(self.page);
        if n_pages <= 1 {
            return Vec::new();
        }
        let mut pages: Vec<usize> = (0..n_pages).collect();
        if pages.len() > self.budget_pages {
            pages.select_nth_unstable_by(self.budget_pages - 1, |&a, &b| {
                self.page_bound(b).partial_cmp(&self.page_bound(a)).unwrap()
            });
            pages.truncate(self.budget_pages);
        }
        let mut keys = Vec::new();
        for pg in pages {
            if pg == n_pages - 1 {
                continue;
            }
            let lo = pg * self.page;
            let hi = ((pg + 1) * self.page).min(cache_len);
            keys.extend(lo as u32..hi as u32);
        }
        keys.sort_unstable();
        keys
    }
}

// ---------------------------------------------------------------------------
// Paged-lane policy config (the serve stack's eviction surface)
// ---------------------------------------------------------------------------

/// Configuration for a policy-budgeted paged lane: which [`KvPolicy`]
/// the lane runs and its token budget. The serve stack reserves KV
/// pages by this budget instead of the worst-case `prompt + max_new`
/// footprint (`serve::ContinuousBatcher`), and the session prunes the
/// lane's pages back under it between decode steps
/// (`AttentionSession::admit_lane_with_policy`).
///
/// Spec strings mirror the engine registry:
/// `h2o[:budget=128,recent=16]` | `snapkv[:budget=128,recent=16]` |
/// `quest[:budget=128]` | `none`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PagedKvPolicy {
    /// H2O: `budget` heavy hitters by cumulative attention mass plus a
    /// `recent` tail.
    H2o { budget: usize, recent: usize },
    /// SnapKV-style: the retained set is frozen at the first prune
    /// (prefill end), plus a `recent` tail ([`SnapKvOncePolicy`]).
    SnapKv { budget: usize, recent: usize },
    /// Quest-style query-aware page eviction at the KV cache's own
    /// page granularity; `budget` is in tokens (rounded up to pages).
    Quest { budget: usize },
}

impl PagedKvPolicy {
    pub fn label(&self) -> String {
        match *self {
            PagedKvPolicy::H2o { budget, recent } => format!("h2o(b={budget},r={recent})"),
            PagedKvPolicy::SnapKv { budget, recent } => {
                format!("snapkv(b={budget},r={recent})")
            }
            PagedKvPolicy::Quest { budget } => format!("quest(b={budget})"),
        }
    }

    pub fn family(&self) -> &'static str {
        match self {
            PagedKvPolicy::H2o { .. } => "h2o",
            PagedKvPolicy::SnapKv { .. } => "snapkv",
            PagedKvPolicy::Quest { .. } => "quest",
        }
    }

    /// Most cached tokens a pruned lane holds right after a prune — the
    /// bound the serve admission policy sizes page reservations by
    /// (plus one for the append that precedes each prune).
    pub fn max_cached_tokens(&self, page_size: usize) -> usize {
        match *self {
            PagedKvPolicy::H2o { budget, recent }
            | PagedKvPolicy::SnapKv { budget, recent } => budget + recent,
            // Quest keeps `budget` worth of pages plus the newest page.
            PagedKvPolicy::Quest { budget } => {
                (budget.div_ceil(page_size).max(1) + 1) * page_size
            }
        }
    }

    /// Prompt positions whose prefill attention the session replays
    /// into `observe` before the first prune (the SnapKV pooling
    /// window; also seeds H2O's mass). Quest ignores observations
    /// (query-driven page bounds), so its window is 0 and the session
    /// skips the replay entirely.
    pub fn observe_window(&self) -> usize {
        match *self {
            PagedKvPolicy::H2o { recent, .. } | PagedKvPolicy::SnapKv { recent, .. } => {
                recent.max(1)
            }
            PagedKvPolicy::Quest { .. } => 0,
        }
    }

    /// Build one per-head policy instance. `d` is the head dim (Quest
    /// summaries), `page_size` the KV cache page size (Quest eviction
    /// granularity).
    pub fn build(&self, d: usize, page_size: usize) -> Box<dyn KvPolicy> {
        match *self {
            PagedKvPolicy::H2o { budget, recent } => Box::new(H2oPolicy::new(budget, recent)),
            PagedKvPolicy::SnapKv { budget, recent } => {
                Box::new(SnapKvOncePolicy::new(budget, recent))
            }
            PagedKvPolicy::Quest { budget } => Box::new(QuestPolicy::new(
                page_size,
                budget.div_ceil(page_size).max(1),
                d,
            )),
        }
    }

    /// Parse a policy spec string; `"none"` means no policy
    /// (worst-case page reservations). Defaults: `budget=128`,
    /// `recent=16`. Tokenization is the shared [`crate::util::spec`]
    /// grammar, so malformed/duplicate parameters fail with the same
    /// messages as every other spec surface.
    pub fn parse(spec: &str) -> Result<Option<PagedKvPolicy>, String> {
        let raw = crate::util::spec::tokenize(spec)?;
        let family = raw.family;
        if family == "none" {
            // `none:budget=64` is almost certainly a typo for a real
            // policy — refuse rather than silently not evict.
            if let Some(&(k, v)) = raw.pairs.first() {
                return Err(format!("none takes no parameters, got {:?}", format!("{k}={v}")));
            }
            return Ok(None);
        }
        let mut budget = 128usize;
        let mut recent = 16usize;
        for &(k, v) in &raw.pairs {
            let n: usize = v.parse().map_err(|_| {
                format!("{family}: key {k:?} expects an integer, got {v:?}")
            })?;
            match k {
                "budget" => budget = n,
                "recent" if family != "quest" => recent = n,
                other => return Err(format!("{family}: unknown key {other:?}")),
            }
        }
        if budget == 0 {
            return Err(format!("{family}: budget must be >= 1"));
        }
        match family {
            "h2o" => Ok(Some(PagedKvPolicy::H2o { budget, recent })),
            "snapkv" => Ok(Some(PagedKvPolicy::SnapKv { budget, recent })),
            "quest" => Ok(Some(PagedKvPolicy::Quest { budget })),
            other => Err(format!(
                "unknown KV policy {other:?} — known: none, h2o, snapkv, quest"
            )),
        }
    }
}

/// Dense KV cache + pruning policy + pluggable scorer (Table 11 rows
/// and their "+SFA" compositions).
pub struct PrunedKvCache<P: KvPolicy> {
    pub cache: DenseKvCache,
    pub policy: P,
    pub scorer: Scorer,
    /// Cached top-k key codes (built lazily when scorer is SFA).
    key_codes: Option<TopkCodes>,
}

impl<P: KvPolicy> PrunedKvCache<P> {
    pub fn new(d: usize, d_v: usize, policy: P, scorer: Scorer) -> Self {
        PrunedKvCache { cache: DenseKvCache::new(d, d_v), policy, scorer, key_codes: None }
    }

    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        self.cache.append(k, v);
        if let Scorer::Sfa { k: kk } = self.scorer {
            let (vals, idx) = topk_row(k, kk);
            match &mut self.key_codes {
                Some(codes) => {
                    codes.vals.extend_from_slice(&vals);
                    codes.idx.extend_from_slice(&idx);
                    codes.rows += 1;
                }
                None => {
                    self.key_codes = Some(TopkCodes {
                        rows: 1,
                        dim: self.cache.d,
                        k: kk,
                        vals,
                        idx,
                    });
                }
            }
        }
    }

    pub fn decode(&mut self, q: &[f32], out: &mut [f32]) {
        let selected = self.policy.select(self.cache.len);
        let scale = 1.0 / (self.cache.d as f32).sqrt();
        let mut scores = Vec::with_capacity(selected.len());
        match self.scorer {
            Scorer::Dense => {
                for &j in &selected {
                    let krow = &self.cache.keys
                        [j as usize * self.cache.d..(j as usize + 1) * self.cache.d];
                    let mut acc = 0.0;
                    for t in 0..self.cache.d {
                        acc += q[t] * krow[t];
                    }
                    scores.push((j, acc * scale));
                }
            }
            Scorer::Sfa { k: kk } => {
                let (qv, qi) = topk_row(q, kk);
                let codes = self.key_codes.as_ref().expect("codes built on append");
                let qcodes = TopkCodes {
                    rows: 1, dim: self.cache.d, k: kk, vals: qv, idx: qi,
                };
                for &j in &selected {
                    scores.push((j, qcodes.overlap_dot(0, codes, j as usize) * scale));
                }
            }
        }
        // softmax over the retained set (shared helpers, so the probs
        // fed to `observe` are exactly the weights applied to V)
        let probs = softmax_probs(&scores);
        let values = &self.cache.values;
        let dv = self.cache.d_v;
        weighted_sum(&probs, |j| values[j * dv..].as_ptr(), dv, out);
        self.policy.observe(&probs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::DenseAttention;
    use crate::attention::Engine;
    use crate::util::rng::Rng;

    fn fill_caches(n: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        (
            Matrix::randn(n, d, &mut rng, 1.0),
            Matrix::randn(n, d, &mut rng, 1.0),
            Matrix::randn(n, d, &mut rng, 1.0),
        )
    }

    #[test]
    fn dense_decode_matches_last_row_of_forward() {
        let (q, k, v) = fill_caches(24, 16, 0);
        let mut cache = DenseKvCache::new(16, 16);
        for i in 0..24 {
            cache.append(k.row(i), v.row(i));
        }
        let mut out = vec![0f32; 16];
        cache.decode(q.row(23), &mut out);
        let full = DenseAttention.forward(&q, &k, &v, true);
        for t in 0..16 {
            assert!((out[t] - full.get(23, t)).abs() < 1e-5);
        }
    }

    #[test]
    fn sparse_decode_matches_sfa_reference_last_row() {
        let (q, k, v) = fill_caches(32, 32, 1);
        let mut cache = SparseKvCache::new(32, 32, 4);
        for i in 0..32 {
            cache.append(k.row(i), v.row(i));
        }
        let mut out = vec![0f32; 32];
        cache.decode(q.row(31), &mut out);
        let full = crate::attention::dense::SfaReference { k: 4 }
            .forward(&q, &k, &v, true);
        for t in 0..32 {
            assert!((out[t] - full.get(31, t)).abs() < 1e-5, "t={t}");
        }
    }

    #[test]
    fn sparse_cache_uses_less_memory() {
        let (_, k, v) = fill_caches(512, 64, 2);
        let mut dense = DenseKvCache::new(64, 64);
        let mut sparse = SparseKvCache::new(64, 64, 8);
        for i in 0..512 {
            dense.append(k.row(i), v.row(i));
            sparse.append(k.row(i), v.row(i));
        }
        let w = crate::sparse::memory::Widths::OURS;
        assert!(sparse.bytes(w) < dense.bytes());
    }

    #[test]
    fn h2o_respects_budget_and_recency() {
        let mut p = H2oPolicy::new(4, 2);
        // Simulate 20 cached tokens with mass concentrated on key 3.
        let sel = p.select(20);
        assert!(sel.len() <= 4 + 2);
        p.observe(&[(3, 0.9), (0, 0.1)]);
        let sel = p.select(20);
        assert!(sel.contains(&3));
        assert!(sel.contains(&18) && sel.contains(&19), "recent tail kept");
    }

    #[test]
    fn snapkv_keeps_fixed_set() {
        let mut p = SnapKvPolicy { keep: vec![1, 5, 9], recent: 2 };
        let sel = p.select(30);
        for j in [1, 5, 9, 28, 29] {
            assert!(sel.contains(&j));
        }
        assert_eq!(sel.len(), 5);
    }

    #[test]
    fn quest_selects_high_bound_pages() {
        let d = 4;
        let mut p = QuestPolicy::new(4, 1, d);
        // 3 pages; page 1 has large-magnitude keys.
        for i in 0..12 {
            let scale = if (4..8).contains(&i) { 10.0 } else { 0.1 };
            let key = vec![scale; d];
            p.ingest_key(i, &key);
        }
        p.set_query(&[1.0, 1.0, 1.0, 1.0]);
        let sel = p.select(12);
        // Budget page 1 (+always newest page 2).
        assert!(sel.contains(&4) && sel.contains(&7), "{sel:?}");
        assert!(sel.contains(&11));
        assert!(!sel.contains(&0));
    }

    /// The tier verdict fires before eviction: every cold id is one the
    /// policy would *keep* (`demote ⊆ select`), and none sits in the
    /// recent tail — H2O demotes its heavy hitters, not its window.
    #[test]
    fn h2o_demote_verdict_is_kept_heavy_hitters_outside_tail() {
        let mut p = H2oPolicy::new(2, 2);
        p.observe(&[(3, 0.9), (7, 0.5), (0, 0.1)]);
        let sel = p.select(20);
        let cold = p.demote(20);
        assert!(cold.contains(&3) && cold.contains(&7), "heavy hitters go cold: {cold:?}");
        assert_eq!(cold.len(), 2, "budget-bounded cold set");
        for &j in &cold {
            assert!(sel.contains(&j), "demote must be a subset of select");
            assert!(j < 18, "recent tail never demotes");
        }
        assert!(cold.windows(2).all(|w| w[0] < w[1]), "ascending");
    }

    #[test]
    fn snapkv_demote_verdicts_follow_the_frozen_set() {
        // Plain SnapKV: the fixed keep set outside the tail goes cold.
        let mut fixed = SnapKvPolicy { keep: vec![1, 5, 9], recent: 2 };
        assert_eq!(fixed.demote(30), vec![1, 5, 9]);
        assert_eq!(fixed.demote(10), vec![1, 5], "tail members spared");
        // Serve-side SnapKV-once: pre-freeze it mirrors H2O's masses,
        // post-freeze it demotes the snapped set.
        let mut p = SnapKvOncePolicy::new(2, 2);
        p.observe(&[(1, 0.5), (4, 0.4), (0, 0.1)]);
        assert_eq!(p.demote(8), vec![1, 4], "pre-freeze: heavy hitters");
        let keep = p.select(8);
        p.compact(&keep); // freezes {1,4} as {0,1}
        assert_eq!(p.demote(6), vec![0, 1], "post-freeze: frozen set");
        // A default-impl policy has no tiering opinion.
        struct NoOpinion;
        impl KvPolicy for NoOpinion {
            fn name(&self) -> String {
                "none".into()
            }
            fn select(&mut self, n: usize) -> Vec<u32> {
                (0..n as u32).collect()
            }
            fn observe(&mut self, _p: &[(u32, f32)]) {}
        }
        assert!(NoOpinion.demote(64).is_empty());
    }

    #[test]
    fn quest_demote_verdict_is_page_granular_and_spares_newest() {
        let d = 4;
        let mut p = QuestPolicy::new(4, 1, d);
        for i in 0..12 {
            let scale = if (4..8).contains(&i) { 10.0 } else { 0.1 };
            p.ingest_key(i, &vec![scale; d]);
        }
        p.set_query(&[1.0; 4]);
        let cold = p.demote(12);
        assert_eq!(cold, vec![4, 5, 6, 7], "the selected non-newest page: {cold:?}");
        assert!(p.demote(4).is_empty(), "single page never demotes");
    }

    #[test]
    fn softmax_probs_normalize_and_empty_is_empty() {
        let scores = vec![(0u32, 0.5f32), (1, -1.0), (2, 2.0)];
        let probs = softmax_probs(&scores);
        let total: f32 = probs.iter().map(|&(_, p)| p).sum();
        assert!((total - 1.0).abs() < 1e-6);
        assert!(probs[2].1 > probs[0].1 && probs[0].1 > probs[1].1);
        assert!(softmax_probs(&[]).is_empty());
        let mut out = vec![1.0f32; 2];
        weighted_sum(&[], |_| std::ptr::null(), 2, &mut out);
        assert_eq!(out, vec![0.0, 0.0], "empty set zeroes the output");
    }

    #[test]
    fn h2o_compact_remaps_cumulative_mass() {
        let mut p = H2oPolicy::new(1, 2);
        p.observe(&[(5, 0.9), (0, 0.1)]);
        // Evict everything but {0, 5, 8, 9}: key 5 becomes key 1.
        p.compact(&[0, 5, 8, 9]);
        let sel = p.select(4);
        assert!(sel.contains(&1), "heavy hitter follows the remap: {sel:?}");
        assert!(sel.contains(&2) && sel.contains(&3), "recent tail");
        assert!(!sel.contains(&0), "mass moved off the old coordinate");
    }

    #[test]
    fn snapkv_once_freezes_at_first_compact() {
        let mut p = SnapKvOncePolicy::new(2, 2);
        // Mass on keys 1 and 4; 8 cached keys, tail = {6, 7}.
        p.observe(&[(1, 0.5), (4, 0.4), (0, 0.1)]);
        let keep = p.select(8);
        assert_eq!(keep, vec![1, 4, 6, 7]);
        p.compact(&keep);
        // Frozen: {1, 4} are now keys {0, 1}. Later mass is ignored.
        p.observe(&[(3, 5.0)]);
        let keep2 = p.select(6);
        assert_eq!(keep2, vec![0, 1, 4, 5], "frozen set + new tail");
        p.compact(&keep2);
        let keep3 = p.select(5);
        assert_eq!(keep3, vec![0, 1, 3, 4], "frozen ids track every compaction");
    }

    #[test]
    fn quest_compact_remaps_page_summaries() {
        let d = 2;
        let mut p = QuestPolicy::new(2, 1, d);
        // 3 pages of 2 keys; page 1 is the hot one.
        for i in 0..6 {
            let scale = if (2..4).contains(&i) { 10.0 } else { 0.1 };
            let key = vec![scale; d];
            p.ingest_key(i, &key);
        }
        // Whole-page eviction of page 0 (Quest's own shape): pages 1, 2
        // survive and renumber to 0, 1.
        p.compact(&[2, 3, 4, 5]);
        p.set_query(&[1.0, 1.0]);
        let sel = p.select(4);
        assert!(sel.contains(&0) && sel.contains(&1), "hot page renumbered: {sel:?}");
        assert!(sel.contains(&3), "newest page always kept");
    }

    #[test]
    fn paged_policy_spec_parsing_and_budgets() {
        assert_eq!(PagedKvPolicy::parse("none").unwrap(), None);
        assert_eq!(
            PagedKvPolicy::parse("h2o").unwrap(),
            Some(PagedKvPolicy::H2o { budget: 128, recent: 16 })
        );
        assert_eq!(
            PagedKvPolicy::parse("snapkv:budget=32,recent=4").unwrap(),
            Some(PagedKvPolicy::SnapKv { budget: 32, recent: 4 })
        );
        assert_eq!(
            PagedKvPolicy::parse(" quest:budget=64 ").unwrap(),
            Some(PagedKvPolicy::Quest { budget: 64 })
        );
        assert!(PagedKvPolicy::parse("lru").unwrap_err().contains("unknown KV policy"));
        assert!(PagedKvPolicy::parse("h2o:budget=zero").unwrap_err().contains("integer"));
        assert!(PagedKvPolicy::parse("h2o:window=4").unwrap_err().contains("unknown key"));
        assert!(PagedKvPolicy::parse("quest:recent=4").unwrap_err().contains("unknown key"));
        assert!(PagedKvPolicy::parse("h2o:budget=0").unwrap_err().contains(">= 1"));
        assert!(
            PagedKvPolicy::parse("none:budget=64").unwrap_err().contains("no parameters"),
            "none with parameters is a likely typo and must not parse"
        );

        let h2o = PagedKvPolicy::H2o { budget: 32, recent: 8 };
        assert_eq!(h2o.max_cached_tokens(16), 40);
        assert_eq!(h2o.family(), "h2o");
        assert!(h2o.label().contains("b=32"));
        // Quest rounds its budget up to whole pages, plus the newest:
        // 33 tokens -> 3 budget pages + 1 newest = 64 token slots.
        let quest = PagedKvPolicy::Quest { budget: 33 };
        assert_eq!(quest.max_cached_tokens(16), 4 * 16);
        // Built policies respect their configured geometry.
        let mut built = PagedKvPolicy::SnapKv { budget: 2, recent: 1 }.build(4, 16);
        assert!(built.name().contains("snapkv_once"));
        assert!(built.select(10).len() <= 3);
    }

    #[test]
    fn pruned_cache_with_full_budget_matches_dense() {
        let (q, k, v) = fill_caches(16, 8, 3);
        let mut pruned = PrunedKvCache::new(
            8, 8, H2oPolicy::new(1000, 1000), Scorer::Dense,
        );
        let mut dense = DenseKvCache::new(8, 8);
        for i in 0..16 {
            pruned.append(k.row(i), v.row(i));
            dense.append(k.row(i), v.row(i));
        }
        let mut a = vec![0f32; 8];
        let mut b = vec![0f32; 8];
        pruned.decode(q.row(15), &mut a);
        dense.decode(q.row(15), &mut b);
        for t in 0..8 {
            assert!((a[t] - b[t]).abs() < 1e-5);
        }
    }

    #[test]
    fn pruned_cache_sfa_scorer_matches_sparse_cache_full_budget() {
        let (q, k, v) = fill_caches(20, 16, 4);
        let mut pruned = PrunedKvCache::new(
            16, 16, H2oPolicy::new(1000, 1000), Scorer::Sfa { k: 4 },
        );
        let mut sparse = SparseKvCache::new(16, 16, 4);
        for i in 0..20 {
            pruned.append(k.row(i), v.row(i));
            sparse.append(k.row(i), v.row(i));
        }
        let mut a = vec![0f32; 16];
        let mut b = vec![0f32; 16];
        pruned.decode(q.row(19), &mut a);
        sparse.decode(q.row(19), &mut b);
        for t in 0..16 {
            assert!((a[t] - b[t]).abs() < 1e-5, "t={t}: {} vs {}", a[t], b[t]);
        }
    }
}
