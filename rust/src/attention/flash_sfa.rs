//! FlashSFA on CPU — a structurally faithful port of the paper's CUDA
//! kernel (App. C, Algorithm 1).
//!
//! Pipeline per query tile (rows [i0, i0+Br)):
//!
//! 1. walk the CSR-style top-k codes of each query row (lines 3-8);
//! 2. for every active feature f, BINARY_SEARCH_RANGE the feature-wise
//!    CSC posting list of K̃ down to the current key tile (line 10);
//! 3. scatter-add qv·kv into the Br×Bc score buffer (lines 11-15) —
//!    the CPU analog of the register-resident 2×2 thread patches: each
//!    (r, c) score cell is owned by exactly one accumulation pass, so
//!    no synchronization is needed;
//! 4. causal-mask the tile, fold it into the online-softmax state, and
//!    stream V rows (lines 21-32).
//!
//! Keys with empty support intersection keep score 0 — they still
//! participate in the softmax, which is exactly the semantics of
//! softmax(Q̃K̃ᵀ/√d)V (the paper's "mathematically identical" claim).
//!
//! Work per tile is proportional to the number of posting-list hits,
//! i.e. Θ(n²k²/d) overall for balanced supports (paper Eq. 7), while
//! the n×n score matrix is never materialized.

use crate::attention::online_softmax::OnlineSoftmax;
use crate::attention::{Engine, NEG_INF};
use crate::sparse::{topk_codes, CscFeat, TopkCodes};
use crate::util::matrix::Matrix;
use crate::util::threadpool::{parallel_for_dynamic, SendPtr};

#[derive(Debug, Clone, Copy)]
pub struct FlashSfa {
    /// Feature sparsity budget k (paper Eq. 3-4).
    pub k: usize,
    pub block_q: usize,
    pub block_k: usize,
    pub threads: usize,
}

impl FlashSfa {
    pub fn new(k: usize) -> Self {
        FlashSfa {
            k,
            block_q: 64,
            block_k: 64,
            threads: crate::util::threadpool::default_threads(),
        }
    }

    /// Forward over pre-computed sparse codes (the kernel boundary the
    /// Pallas twin exposes; `forward` adds the top-k step).
    pub fn forward_codes(
        &self,
        q_codes: &TopkCodes,
        k_feat: &CscFeat,
        v: &Matrix,
        d_orig: usize,
        causal: bool,
    ) -> Matrix {
        assert_eq!(k_feat.n_tokens, v.rows);
        let n_q = q_codes.rows;
        let n_kv = k_feat.n_tokens;
        if causal {
            assert_eq!(n_q, n_kv, "causal FlashSFA requires n_q == n_kv");
        }
        let scale = 1.0 / (d_orig as f32).sqrt();
        let mut out = Matrix::zeros(n_q, v.cols);
        let n_tiles = n_q.div_ceil(self.block_q);
        let out_ptr = SendPtr(out.data.as_mut_ptr());

        let kq = q_codes.k;
        parallel_for_dynamic(n_tiles, self.threads, 1, move |tile| {
            let i0 = tile * self.block_q;
            let br = self.block_q.min(n_q - i0);
            let mut os = OnlineSoftmax::new(br, v.cols);
            let mut score_tile = vec![0f32; br * self.block_k];

            // §Perf iteration 1 (EXPERIMENTS.md): key tiles are scanned
            // in ascending j, so each (query row, feature) pair walks
            // its posting list monotonically — one cursor per pair
            // replaces the per-tile BINARY_SEARCH_RANGE with O(1)
            // amortized advancement (each posting hit is consumed
            // exactly once per query tile).
            let mut cursors: Vec<u32> = Vec::with_capacity(br * kq);
            for r in 0..br {
                for &f in q_codes.row_idx(i0 + r) {
                    cursors.push(k_feat.indptr[f as usize]);
                }
            }

            let j_end = if causal { (i0 + br).min(n_kv) } else { n_kv };
            let mut j0 = 0;
            while j0 < j_end {
                let bc = self.block_k.min(j_end - j0);
                score_tile[..br * bc].fill(0.0);
                let tile_hi = (j0 + bc) as u32;

                // Lines 3-15: feature-overlap accumulation.
                for r in 0..br {
                    let i = i0 + r;
                    let srow = &mut score_tile[r * bc..(r + 1) * bc];
                    let idx = q_codes.row_idx(i);
                    let vals = q_codes.row_vals(i);
                    for (slot, (&f, &qv)) in idx.iter().zip(vals).enumerate() {
                        if qv == 0.0 {
                            continue;
                        }
                        let end = k_feat.indptr[f as usize + 1];
                        let mut c = cursors[r * kq + slot];
                        while c < end {
                            let tok = k_feat.token_ids[c as usize];
                            if tok >= tile_hi {
                                break;
                            }
                            srow[tok as usize - j0] += qv * k_feat.vals[c as usize];
                            c += 1;
                        }
                        cursors[r * kq + slot] = c;
                    }
                    // Scale + causal mask (line 21).
                    for (c, s) in srow.iter_mut().enumerate() {
                        *s *= scale;
                        if causal && j0 + c > i {
                            *s = NEG_INF;
                        }
                    }
                }

                // Lines 22-32: online softmax + V streaming.
                let vdata = &v.data;
                let vcols = v.cols;
                os.update(&score_tile[..br * bc], bc, |c| {
                    vdata[(j0 + c) * vcols..].as_ptr()
                });
                j0 += bc;
            }

            // SAFETY: tiles own disjoint output row ranges.
            let out_slice = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.get().add(i0 * v.cols), br * v.cols)
            };
            os.finish(out_slice);
        });
        out
    }
}

impl Engine for FlashSfa {
    fn name(&self) -> String {
        format!("flash_sfa(k={})", self.k)
    }

    fn spec(&self) -> String {
        format!("sfa:k={},bq={},bk={}", self.k, self.block_q, self.block_k)
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        assert_eq!(q.cols, k.cols);
        let q_codes = topk_codes(q, self.k);
        let k_codes = topk_codes(k, self.k);
        let k_feat = CscFeat::from_codes(&k_codes);
        self.forward_codes(&q_codes, &k_feat, v, q.cols, causal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::{DenseAttention, SfaReference};
    use crate::attention::testutil::qkv;
    use crate::util::matrix::assert_close;
    use crate::util::prop::check;

    #[test]
    fn matches_materializing_reference() {
        check("flash_sfa == sfa_ref", 24, |g| {
            let n = g.usize_in(1..80);
            let d = *g.choose(&[16usize, 32, 64, 128]);
            let k = *g.choose(&[2usize, 4, 8]);
            let causal = g.bool();
            let bq = *g.choose(&[8usize, 32, 64]);
            let bk = *g.choose(&[8usize, 32, 64]);
            let (q, kk, v) = qkv(n, d, d.min(32), g.seed);
            let engine = FlashSfa { k: k.min(d), block_q: bq, block_k: bk, threads: 2 };
            let a = engine.forward(&q, &kk, &v, causal);
            let b = SfaReference { k: k.min(d) }.forward(&q, &kk, &v, causal);
            assert_close(&a, &b, 3e-5, 3e-6);
        });
    }

    #[test]
    fn k_equals_d_matches_dense() {
        let (q, k, v) = qkv(48, 32, 32, 1);
        let a = FlashSfa { k: 32, block_q: 16, block_k: 16, threads: 2 }
            .forward(&q, &k, &v, true);
        let b = DenseAttention.forward(&q, &k, &v, true);
        assert_close(&a, &b, 3e-5, 3e-6);
    }

    #[test]
    fn tiling_invariance() {
        let (q, k, v) = qkv(100, 64, 48, 2);
        let base = FlashSfa { k: 8, block_q: 100, block_k: 100, threads: 1 }
            .forward(&q, &k, &v, true);
        for (bq, bk) in [(8, 8), (16, 64), (64, 16), (32, 100)] {
            let other = FlashSfa { k: 8, block_q: bq, block_k: bk, threads: 3 }
                .forward(&q, &k, &v, true);
            assert_close(&other, &base, 2e-5, 2e-6);
        }
    }

    #[test]
    fn causal_no_future_leak() {
        let (q, mut k, mut v) = qkv(64, 32, 32, 3);
        let engine = FlashSfa::new(4);
        let o1 = engine.forward(&q, &k, &v, true);
        // Corrupt the future half of K and V.
        for i in 40..64 {
            k.row_mut(i).fill(9.0);
            v.row_mut(i).fill(-9.0);
        }
        let o2 = engine.forward(&q, &k, &v, true);
        assert_close(&o1.head_rows(40), &o2.head_rows(40), 1e-6, 1e-7);
    }

    #[test]
    fn empty_overlap_rows_attend_uniformly() {
        // Query supports disjoint from key supports -> all scores equal
        // (zero), so output = causal running mean of V.
        let n = 8;
        let d = 16;
        let mut q = Matrix::zeros(n, d);
        let mut k = Matrix::zeros(n, d);
        let mut v = Matrix::zeros(n, 1);
        for i in 0..n {
            q.set(i, 0, 5.0);
            q.set(i, 1, 4.0);
            k.set(i, 8, 5.0);
            k.set(i, 9, 4.0);
            v.set(i, 0, i as f32);
        }
        let out = FlashSfa { k: 2, block_q: 4, block_k: 4, threads: 1 }
            .forward(&q, &k, &v, true);
        for i in 0..n {
            let mean = (0..=i).sum::<usize>() as f32 / (i + 1) as f32;
            assert!((out.get(i, 0) - mean).abs() < 1e-5, "row {i}");
        }
    }

    #[test]
    fn cross_attention_non_causal() {
        // n_q != n_kv is allowed without the causal mask.
        let (q, _, _) = qkv(24, 32, 32, 4);
        let (_, k, v) = qkv(56, 32, 32, 5);
        let qc = topk_codes(&q, 4);
        let kc = topk_codes(&k, 4);
        let kf = CscFeat::from_codes(&kc);
        let eng = FlashSfa { k: 4, block_q: 16, block_k: 16, threads: 2 };
        let a = eng.forward_codes(&qc, &kf, &v, 32, false);
        let b = DenseAttention.forward(&qc.densify(), &kc.densify(), &v, false);
        assert_close(&a, &b, 3e-5, 3e-6);
    }

    #[test]
    #[should_panic(expected = "causal FlashSFA requires")]
    fn causal_rejects_mismatched_lengths() {
        let (q, _, _) = qkv(8, 16, 16, 6);
        let (_, k, v) = qkv(12, 16, 16, 7);
        let qc = topk_codes(&q, 2);
        let kc = topk_codes(&k, 2);
        let kf = CscFeat::from_codes(&kc);
        FlashSfa::new(2).forward_codes(&qc, &kf, &v, 16, true);
    }
}
