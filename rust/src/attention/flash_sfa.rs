//! FlashSFA on CPU — a structurally faithful port of the paper's CUDA
//! kernel (App. C, Algorithm 1), extended with block-level tile
//! skipping driven by the feature codes themselves.
//!
//! Pipeline per query tile (rows [i0, i0+Br)):
//!
//! 1. walk the CSR-style top-k codes of each query row (lines 3-8);
//! 2. classify every Bc-wide key tile from the [`CscBlockIndex`]
//!    summaries (skip mode): **dense** tiles run the SpGEMM-style
//!    cursor walk, **empty** tiles (zero feature overlap) fold into the
//!    online softmax in O(1) per row via precomputed per-tile V row
//!    sums, and **negligible** tiles (score upper bound below the
//!    running row max minus `skip_thresh`) are skipped entirely;
//! 3. dense tiles scatter-add qv·kv into the Br×Bc score buffer
//!    (lines 11-15) — the CPU analog of the register-resident 2×2
//!    thread patches: each (r, c) score cell is owned by exactly one
//!    accumulation pass, so no synchronization is needed;
//! 4. causal-mask the tile, fold it into the online-softmax state, and
//!    stream V rows (lines 21-32).
//!
//! Keys with empty support intersection keep score 0 — they still
//! participate in the softmax, which is exactly the semantics of
//! softmax(Q̃K̃ᵀ/√d)V (the paper's "mathematically identical" claim).
//! The empty-tile fold preserves those semantics exactly (a tile of w
//! zero scores contributes w·exp(-m) mass and exp(-m)·ΣV), so skip mode
//! with `skip_thresh == 0` matches the non-skipping kernel up to f32
//! summation order. Threshold skipping (`skip_thresh > 0`) drops per
//! row at most n·exp(-skip_thresh) of unnormalized softmax mass — the
//! documented approximation bound.
//!
//! Work per dense tile is proportional to the number of posting-list
//! hits, i.e. Θ(n²k²/d) overall for balanced supports (paper Eq. 7),
//! and empty tiles now cost O(Br·(k + d_v)) instead of O(Br·Bc·d_v) —
//! the wall-clock no longer stays Θ(n²) when k-sparse supports barely
//! intersect.
//!
//! Per-worker scratch (`OnlineSoftmax` buffers, score tile, posting
//! cursors, bound buffers) is allocated once per forward and reused
//! across query tiles, so the hot loop allocates nothing after warm-up.

use crate::attention::online_softmax::OnlineSoftmax;
use crate::attention::{Engine, NEG_INF};
use crate::sparse::{topk_codes, CscBlockIndex, CscFeat, TopkCodes};
use crate::util::matrix::Matrix;
use crate::util::threadpool::{parallel_for_dynamic_worker, SendPtr};

#[derive(Debug, Clone, Copy)]
pub struct FlashSfa {
    /// Feature sparsity budget k (paper Eq. 3-4).
    pub k: usize,
    pub block_q: usize,
    pub block_k: usize,
    pub threads: usize,
    /// Enable block-index tile classification (`skip=on` in the spec
    /// grammar). With `skip_thresh == 0` this is exact: empty tiles
    /// fold in O(1) per row, nothing is dropped.
    pub skip: bool,
    /// Threshold-skip margin in score units (`thresh=` in the spec
    /// grammar): a key tile whose per-row score upper bound sits below
    /// `row_max - skip_thresh` for every row of the query tile is
    /// dropped entirely. 0 disables threshold skipping (exact mode).
    pub skip_thresh: f32,
    /// Target dropped unnormalized mass per row (`mass=` in the spec
    /// grammar): when > 0 the threshold margin is derived at forward
    /// time as `ln(n_kv / mass)` so the per-row dropped mass stays
    /// bounded by `mass` at any context length, instead of the
    /// hand-picked `skip_thresh` constant. Mutually exclusive with
    /// `skip_thresh`; 0 disables the auto-tuned mode.
    pub skip_mass: f32,
}

/// Tile-level work counters of one forward pass (the OpCounts-style
/// observability surface of the block-skipping kernel): every
/// enumerated key tile lands in exactly one of the three buckets, so
/// `tiles_visited + tiles_folded + tiles_skipped` is the total tile
/// count and the folded/skipped share is the realized block sparsity.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SfaTileCounts {
    /// Tiles that ran the dense cursor-walk + online-softmax path.
    pub tiles_visited: u64,
    /// Empty-overlap tiles folded in O(1) per row (exact).
    pub tiles_folded: u64,
    /// Tiles dropped by the threshold bound (approximate, opt-in).
    pub tiles_skipped: u64,
    /// Individual rows of *visited* tiles dropped by the same per-row
    /// bound (sub-tile early exit; approximate, opt-in with the tile
    /// threshold). Not part of [`Self::total_tiles`] — a row skip
    /// happens inside a tile that still counts as visited.
    pub rows_skipped: u64,
    /// Posting-list entries consumed by the dense walks.
    pub posting_hits: u64,
}

impl SfaTileCounts {
    pub fn merge(&mut self, o: &SfaTileCounts) {
        self.tiles_visited += o.tiles_visited;
        self.tiles_folded += o.tiles_folded;
        self.tiles_skipped += o.tiles_skipped;
        self.rows_skipped += o.rows_skipped;
        self.posting_hits += o.posting_hits;
    }

    /// Total key tiles enumerated across all query tiles.
    pub fn total_tiles(&self) -> u64 {
        self.tiles_visited + self.tiles_folded + self.tiles_skipped
    }
}

/// Per-worker reusable state: one slot per thread, no allocation in
/// the tile loop after the first few tiles warm the capacities up.
struct Scratch {
    os: OnlineSoftmax,
    score_tile: Vec<f32>,
    cursors: Vec<u32>,
    /// Per-row score upper bounds for the current key tile.
    ub: Vec<f32>,
    /// Distinct nonzero features of the current query tile.
    feats: Vec<u16>,
    counts: SfaTileCounts,
}

impl Scratch {
    fn new(block_q: usize, block_k: usize, kq: usize, d_v: usize) -> Scratch {
        Scratch {
            os: OnlineSoftmax::new(block_q.max(1), d_v),
            score_tile: vec![0f32; block_q * block_k],
            cursors: Vec::with_capacity(block_q * kq),
            ub: vec![0f32; block_q],
            feats: Vec::with_capacity(block_q * kq),
            counts: SfaTileCounts::default(),
        }
    }
}

impl FlashSfa {
    pub fn new(k: usize) -> Self {
        FlashSfa {
            k,
            block_q: 64,
            block_k: 64,
            threads: crate::util::threadpool::default_threads(),
            skip: false,
            skip_thresh: 0.0,
            skip_mass: 0.0,
        }
    }

    /// Forward over pre-computed sparse codes (the kernel boundary the
    /// Pallas twin exposes; `forward` adds the top-k step).
    pub fn forward_codes(
        &self,
        q_codes: &TopkCodes,
        k_feat: &CscFeat,
        v: &Matrix,
        d_orig: usize,
        causal: bool,
    ) -> Matrix {
        self.forward_codes_counted(q_codes, k_feat, v, d_orig, causal).0
    }

    /// [`Self::forward_codes`] plus the tile-level work counters.
    pub fn forward_codes_counted(
        &self,
        q_codes: &TopkCodes,
        k_feat: &CscFeat,
        v: &Matrix,
        d_orig: usize,
        causal: bool,
    ) -> (Matrix, SfaTileCounts) {
        if causal {
            assert_eq!(
                q_codes.rows, k_feat.n_tokens,
                "causal FlashSFA requires n_q == n_kv"
            );
        }
        self.forward_impl(q_codes, k_feat, v, d_orig, causal.then_some(0))
    }

    /// KV-append variant for chunked prefill: query row `t` attends
    /// keys `0..=start_pos + t` of the (longer) cached key sequence — a
    /// suffix of `n_q` new positions over a `start_pos`-token cached
    /// prefix plus the causal suffix itself. `start_pos == 0` with
    /// `n_q == n_kv` is exactly the causal [`Self::forward_codes`].
    pub fn forward_codes_append(
        &self,
        q_codes: &TopkCodes,
        k_feat: &CscFeat,
        v: &Matrix,
        d_orig: usize,
        start_pos: usize,
    ) -> Matrix {
        self.forward_impl(q_codes, k_feat, v, d_orig, Some(start_pos)).0
    }

    /// Shared tiled kernel. `causal` is the diagonal offset: `Some(off)`
    /// lets query row `i` attend keys `0..=i + off`; `None` attends
    /// everything (cross attention).
    fn forward_impl(
        &self,
        q_codes: &TopkCodes,
        k_feat: &CscFeat,
        v: &Matrix,
        d_orig: usize,
        causal: Option<usize>,
    ) -> (Matrix, SfaTileCounts) {
        assert_eq!(k_feat.n_tokens, v.rows);
        let n_q = q_codes.rows;
        let n_kv = k_feat.n_tokens;
        let d_v = v.cols;
        let scale = 1.0 / (d_orig as f32).sqrt();
        let mut out = Matrix::zeros(n_q, d_v);
        let n_tiles = n_q.div_ceil(self.block_q);
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let kq = q_codes.k;
        // Auto-tuned margin: `mass=EPS` derives thresh from the actual
        // key count so the per-row dropped unnormalized mass stays
        // bounded by EPS (n·exp(-ln(n/EPS)) = EPS). EPS >= n would need
        // thresh <= 0 — the bound is vacuous there, so stay exact.
        let eff_thresh = if self.skip_mass > 0.0 {
            (n_kv.max(1) as f32 / self.skip_mass).ln().max(0.0)
        } else {
            self.skip_thresh
        };
        let thresh_on = self.skip && eff_thresh > 0.0;

        // Block-skip summaries, built once per forward: the per-cell
        // posting index and the per-tile V row sums the empty fold
        // streams instead of individual V rows.
        let block_index = if self.skip { Some(k_feat.block_index(self.block_k)) } else { None };
        let v_tile_sums = if self.skip {
            let kt = n_kv.div_ceil(self.block_k).max(1);
            let mut sums = vec![0f32; kt * d_v];
            for j in 0..n_kv {
                let row = &mut sums[(j / self.block_k) * d_v..(j / self.block_k + 1) * d_v];
                for (a, &x) in row.iter_mut().zip(v.row(j)) {
                    *a += x;
                }
            }
            sums
        } else {
            Vec::new()
        };

        let n_workers = self.threads.max(1).min(n_tiles.max(1));
        let mut scratch: Vec<Scratch> = (0..n_workers)
            .map(|_| Scratch::new(self.block_q.min(n_q.max(1)), self.block_k, kq, d_v))
            .collect();
        let scratch_ptr = SendPtr(scratch.as_mut_ptr());
        let bi = block_index.as_ref();
        let v_sums = &v_tile_sums;

        parallel_for_dynamic_worker(n_tiles, n_workers, 1, move |worker, tile| {
            // SAFETY: worker indices are < n_workers and each worker
            // touches only its own scratch slot.
            let scr = unsafe { &mut *scratch_ptr.get().add(worker) };
            let i0 = tile * self.block_q;
            let br = self.block_q.min(n_q - i0);
            scr.os.reset(br, d_v);

            // §Perf iteration 1 (EXPERIMENTS.md): key tiles are scanned
            // in ascending j, so each (query row, feature) pair walks
            // its posting list monotonically — one cursor per pair
            // replaces the per-tile BINARY_SEARCH_RANGE with O(1)
            // amortized advancement. Folded/skipped tiles hold the
            // invariant too: empty tiles have no postings to pass, and
            // the threshold-skip path jumps cursors to the block
            // boundary via the block index.
            scr.cursors.clear();
            for r in 0..br {
                for &f in q_codes.row_idx(i0 + r) {
                    scr.cursors.push(k_feat.indptr[f as usize]);
                }
            }
            if bi.is_some() {
                scr.feats.clear();
                for r in 0..br {
                    for (&f, &qv) in q_codes.row_idx(i0 + r).iter().zip(q_codes.row_vals(i0 + r)) {
                        if qv != 0.0 {
                            scr.feats.push(f);
                        }
                    }
                }
                scr.feats.sort_unstable();
                scr.feats.dedup();
            }

            let j_end = match causal {
                Some(off) => (i0 + br + off).min(n_kv),
                None => n_kv,
            };
            let mut j0 = 0;
            while j0 < j_end {
                let bc = self.block_k.min(j_end - j0);
                // j0 stays block_k-aligned (only the final tile of the
                // loop can be partial), so this is the block-index cell.
                let t = j0 / self.block_k;
                // True once `scr.ub[..br]` holds this tile's per-row
                // score bounds — the dense path reuses them for the
                // sub-tile (per-row) early exit.
                let mut rows_bounded = false;

                if let Some(bi) = bi {
                    let empty = scr.feats.iter().all(|&f| bi.degree(f as usize, t) == 0);
                    // The O(1)-per-row fold needs the whole physical
                    // tile unmasked for every row: V sums cover
                    // [t·Bc, min((t+1)·Bc, n_kv)), and all of it must be
                    // causally visible to row i0 (the strictest row).
                    let phys_end = ((t + 1) * self.block_k).min(n_kv);
                    let fully_visible = match causal {
                        Some(off) => j0 + bc <= i0 + off + 1,
                        None => true,
                    };
                    if empty && fully_visible && j0 + bc == phys_end {
                        scr.os.fold_uniform(0.0, bc, &v_sums[t * d_v..(t + 1) * d_v]);
                        scr.counts.tiles_folded += 1;
                        j0 += bc;
                        continue;
                    }
                    if thresh_on {
                        // Per-row score upper bound from the per-cell
                        // max-|value| summaries; zero-overlap keys in
                        // the tile score exactly 0, covered by the
                        // max(·, 0) below.
                        let ubuf = &mut scr.ub[..br];
                        if empty {
                            ubuf.fill(0.0);
                        } else {
                            for (r, u) in ubuf.iter_mut().enumerate() {
                                let idx = q_codes.row_idx(i0 + r);
                                let vals = q_codes.row_vals(i0 + r);
                                let mut acc = 0.0;
                                for (&f, &qv) in idx.iter().zip(vals) {
                                    if qv != 0.0 {
                                        acc += qv.abs() * bi.cell_max_abs(f as usize, t);
                                    }
                                }
                                *u = acc * scale;
                            }
                        }
                        rows_bounded = true;
                        let skippable = (0..br).all(|r| {
                            scr.ub[r].max(0.0) < scr.os.row_max(r) - eff_thresh
                        });
                        if skippable {
                            // Jump every cursor to the next block
                            // boundary so the monotone-walk invariant
                            // survives the skipped postings.
                            if !empty {
                                for r in 0..br {
                                    for (slot, &f) in q_codes.row_idx(i0 + r).iter().enumerate() {
                                        scr.cursors[r * kq + slot] =
                                            scr.cursors[r * kq + slot].max(bi.start(f as usize, t + 1));
                                    }
                                }
                            }
                            scr.counts.tiles_skipped += 1;
                            j0 += bc;
                            continue;
                        }
                    }
                }

                // Dense tile: lines 3-15, feature-overlap accumulation.
                scr.counts.tiles_visited += 1;
                let score_tile = &mut scr.score_tile;
                score_tile[..br * bc].fill(0.0);
                let tile_hi = (j0 + bc) as u32;
                for r in 0..br {
                    let srow = &mut score_tile[r * bc..(r + 1) * bc];
                    let idx = q_codes.row_idx(i0 + r);
                    let vals = q_codes.row_vals(i0 + r);
                    // Sub-tile early exit: the tile as a whole was dense,
                    // but this row's bound is still negligible — drop the
                    // row alone (NEG_INF scores contribute zero mass,
                    // exactly a threshold skip restricted to one row) and
                    // jump its cursors past the tile.
                    if rows_bounded && scr.ub[r].max(0.0) < scr.os.row_max(r) - eff_thresh {
                        srow.fill(NEG_INF);
                        if let Some(bi) = bi {
                            for (slot, &f) in idx.iter().enumerate() {
                                scr.cursors[r * kq + slot] =
                                    scr.cursors[r * kq + slot].max(bi.start(f as usize, t + 1));
                            }
                        }
                        scr.counts.rows_skipped += 1;
                        continue;
                    }
                    for (slot, (&f, &qv)) in idx.iter().zip(vals).enumerate() {
                        if qv == 0.0 {
                            continue;
                        }
                        let end = k_feat.indptr[f as usize + 1];
                        let start = scr.cursors[r * kq + slot];
                        let mut c = start;
                        while c < end {
                            let tok = k_feat.token_ids[c as usize];
                            if tok >= tile_hi {
                                break;
                            }
                            srow[tok as usize - j0] += qv * k_feat.vals[c as usize];
                            c += 1;
                        }
                        scr.cursors[r * kq + slot] = c;
                        scr.counts.posting_hits += (c - start) as u64;
                    }
                    // Scale + causal mask (line 21).
                    let vis = match causal {
                        Some(off) => (i0 + r + off + 1).saturating_sub(j0).min(bc),
                        None => bc,
                    };
                    for s in srow[..vis].iter_mut() {
                        *s *= scale;
                    }
                    for s in srow[vis..].iter_mut() {
                        *s = NEG_INF;
                    }
                }

                // Lines 22-32: online softmax + V streaming.
                let vdata = &v.data;
                scr.os.update(&score_tile[..br * bc], bc, |c| {
                    vdata[(j0 + c) * d_v..].as_ptr()
                });
                j0 += bc;
            }

            // SAFETY: tiles own disjoint output row ranges.
            let out_slice =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(i0 * d_v), br * d_v) };
            scr.os.finish_into(out_slice);
        });

        let mut counts = SfaTileCounts::default();
        for s in &scratch {
            counts.merge(&s.counts);
        }
        (out, counts)
    }
}

impl Engine for FlashSfa {
    fn name(&self) -> String {
        format!("flash_sfa(k={})", self.k)
    }

    fn spec(&self) -> String {
        let mut s = format!("sfa:k={},bq={},bk={}", self.k, self.block_q, self.block_k);
        if self.skip {
            s.push_str(",skip=on");
            if self.skip_mass > 0.0 {
                s.push_str(&format!(",mass={}", self.skip_mass));
            } else if self.skip_thresh != 0.0 {
                s.push_str(&format!(",thresh={}", self.skip_thresh));
            }
        }
        s
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        assert_eq!(q.cols, k.cols);
        let q_codes = topk_codes(q, self.k);
        let k_codes = topk_codes(k, self.k);
        let k_feat = CscFeat::from_codes(&k_codes);
        self.forward_codes(&q_codes, &k_feat, v, q.cols, causal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::{DenseAttention, SfaReference};
    use crate::attention::testutil::qkv;
    use crate::util::matrix::assert_close;
    use crate::util::prop::check;

    #[test]
    fn matches_materializing_reference() {
        check("flash_sfa == sfa_ref", 24, |g| {
            let n = g.usize_in(1..80);
            let d = *g.choose(&[16usize, 32, 64, 128]);
            let k = *g.choose(&[2usize, 4, 8]);
            let causal = g.bool();
            let bq = *g.choose(&[8usize, 32, 64]);
            let bk = *g.choose(&[8usize, 32, 64]);
            let (q, kk, v) = qkv(n, d, d.min(32), g.seed);
            let engine = FlashSfa {
                k: k.min(d),
                block_q: bq,
                block_k: bk,
                threads: 2,
                skip: false,
                skip_thresh: 0.0,
                skip_mass: 0.0,
            };
            let a = engine.forward(&q, &kk, &v, causal);
            let b = SfaReference { k: k.min(d) }.forward(&q, &kk, &v, causal);
            assert_close(&a, &b, 3e-5, 3e-6);
        });
    }

    #[test]
    fn k_equals_d_matches_dense() {
        let (q, k, v) = qkv(48, 32, 32, 1);
        let a = FlashSfa { block_q: 16, block_k: 16, threads: 2, ..FlashSfa::new(32) }
            .forward(&q, &k, &v, true);
        let b = DenseAttention.forward(&q, &k, &v, true);
        assert_close(&a, &b, 3e-5, 3e-6);
    }

    #[test]
    fn tiling_invariance() {
        let (q, k, v) = qkv(100, 64, 48, 2);
        let base = FlashSfa { block_q: 100, block_k: 100, threads: 1, ..FlashSfa::new(8) }
            .forward(&q, &k, &v, true);
        for (bq, bk) in [(8, 8), (16, 64), (64, 16), (32, 100)] {
            let other = FlashSfa { block_q: bq, block_k: bk, threads: 3, ..FlashSfa::new(8) }
                .forward(&q, &k, &v, true);
            assert_close(&other, &base, 2e-5, 2e-6);
        }
    }

    #[test]
    fn causal_no_future_leak() {
        let (q, mut k, mut v) = qkv(64, 32, 32, 3);
        let engine = FlashSfa::new(4);
        let o1 = engine.forward(&q, &k, &v, true);
        // Corrupt the future half of K and V.
        for i in 40..64 {
            k.row_mut(i).fill(9.0);
            v.row_mut(i).fill(-9.0);
        }
        let o2 = engine.forward(&q, &k, &v, true);
        assert_close(&o1.head_rows(40), &o2.head_rows(40), 1e-6, 1e-7);
    }

    #[test]
    fn empty_overlap_rows_attend_uniformly() {
        // Query supports disjoint from key supports -> all scores equal
        // (zero), so output = causal running mean of V. Exercised both
        // with and without the block-skip fold.
        for skip in [false, true] {
            let n = 8;
            let d = 16;
            let mut q = Matrix::zeros(n, d);
            let mut k = Matrix::zeros(n, d);
            let mut v = Matrix::zeros(n, 1);
            for i in 0..n {
                q.set(i, 0, 5.0);
                q.set(i, 1, 4.0);
                k.set(i, 8, 5.0);
                k.set(i, 9, 4.0);
                v.set(i, 0, i as f32);
            }
            let out = FlashSfa { block_q: 4, block_k: 4, threads: 1, skip, ..FlashSfa::new(2) }
                .forward(&q, &k, &v, true);
            for i in 0..n {
                let mean = (0..=i).sum::<usize>() as f32 / (i + 1) as f32;
                assert!((out.get(i, 0) - mean).abs() < 1e-5, "skip={skip} row {i}");
            }
        }
    }

    #[test]
    fn cross_attention_non_causal() {
        // n_q != n_kv is allowed without the causal mask.
        let (q, _, _) = qkv(24, 32, 32, 4);
        let (_, k, v) = qkv(56, 32, 32, 5);
        let qc = topk_codes(&q, 4);
        let kc = topk_codes(&k, 4);
        let kf = CscFeat::from_codes(&kc);
        let eng = FlashSfa { block_q: 16, block_k: 16, threads: 2, ..FlashSfa::new(4) };
        let a = eng.forward_codes(&qc, &kf, &v, 32, false);
        let b = DenseAttention.forward(&qc.densify(), &kc.densify(), &v, false);
        assert_close(&a, &b, 3e-5, 3e-6);
    }

    #[test]
    #[should_panic(expected = "causal FlashSFA requires")]
    fn causal_rejects_mismatched_lengths() {
        let (q, _, _) = qkv(8, 16, 16, 6);
        let (_, k, v) = qkv(12, 16, 16, 7);
        let qc = topk_codes(&q, 2);
        let kc = topk_codes(&k, 2);
        let kf = CscFeat::from_codes(&kc);
        FlashSfa::new(2).forward_codes(&qc, &kf, &v, 16, true);
    }

    #[test]
    fn skip_on_exact_mode_matches_skip_off() {
        // The tentpole equivalence: exact skip mode (empty-tile fold,
        // no threshold) must match the non-skipping kernel within the
        // reference pin across tilings, sparsity budgets, causal and
        // cross-attention shapes.
        check("skip=on(exact) == skip=off", 32, |g| {
            let d = *g.choose(&[16usize, 32, 64]);
            let k = *g.choose(&[2usize, 4, 8]);
            let causal = g.bool();
            let (n_q, n_kv) = if causal {
                let n = g.usize_in(1..96);
                (n, n)
            } else {
                (g.usize_in(1..96), g.usize_in(1..96))
            };
            let bq = *g.choose(&[8usize, 32, 64]);
            let bk = *g.choose(&[8usize, 32, 64]);
            let (q, _, _) = qkv(n_q, d, d.min(32), g.seed);
            let (_, kk, v) = qkv(n_kv, d, d.min(32), g.seed.wrapping_add(1));
            let qc = topk_codes(&q, k.min(d));
            let kc = topk_codes(&kk, k.min(d));
            let kf = CscFeat::from_codes(&kc);
            let off = FlashSfa {
                k: k.min(d),
                block_q: bq,
                block_k: bk,
                threads: 2,
                skip: false,
                skip_thresh: 0.0,
                skip_mass: 0.0,
            };
            let on = FlashSfa { skip: true, ..off };
            let (a, ca) = on.forward_codes_counted(&qc, &kf, &v, d, causal);
            let (b, cb) = off.forward_codes_counted(&qc, &kf, &v, d, causal);
            assert_close(&a, &b, 3e-5, 3e-6);
            assert_eq!(cb.tiles_folded + cb.tiles_skipped, 0, "skip=off never folds");
            assert_eq!(ca.total_tiles(), cb.total_tiles(), "same tiles enumerated");
            assert_eq!(ca.tiles_skipped, 0, "exact mode never threshold-skips");
        });
    }

    #[test]
    fn disjoint_supports_fold_most_tiles() {
        // Query features 0..8, key features 8..16: every off-diagonal
        // tile has zero overlap, so skip mode folds nearly everything
        // and the output still matches the non-skipping kernel tightly.
        let n = 128;
        let d = 16;
        let mut rng = crate::util::rng::Rng::new(9);
        let mut q = Matrix::zeros(n, d);
        let mut k = Matrix::zeros(n, d);
        let v = Matrix::randn(n, 8, &mut rng, 1.0);
        for i in 0..n {
            for j in 0..4 {
                q.set(i, (i + j) % 8, 1.0 + (j as f32));
                k.set(i, 8 + (i + j) % 8, 1.0 + (j as f32));
            }
        }
        let qc = topk_codes(&q, 4);
        let kc = topk_codes(&k, 4);
        let kf = CscFeat::from_codes(&kc);
        let off =
            FlashSfa { k: 4, block_q: 16, block_k: 16, threads: 2, skip: false, skip_thresh: 0.0, skip_mass: 0.0 };
        let on = FlashSfa { skip: true, ..off };
        let (a, counts) = on.forward_codes_counted(&qc, &kf, &v, d, true);
        let b = off.forward_codes(&qc, &kf, &v, d, true);
        assert_close(&a, &b, 1e-5, 1e-6);
        assert!(counts.tiles_folded > 0, "zero-overlap tiles must fold: {counts:?}");
        assert_eq!(counts.posting_hits, 0, "no feature overlap -> no posting hits");
    }

    #[test]
    fn threshold_skip_drops_only_negligible_mass() {
        // One dominant shared feature in the first keys gives every row
        // a large running max; later keys overlap the same feature with
        // tiny values, so their tiles' upper bounds fall under
        // m - thresh and get skipped — within the documented
        // n·exp(-thresh) mass bound, outputs stay close to exact.
        let n = 96;
        let d = 16;
        let mut q = Matrix::zeros(n, d);
        let mut k = Matrix::zeros(n, d);
        let mut v = Matrix::zeros(n, 4);
        for i in 0..n {
            q.set(i, 0, 8.0);
            q.set(i, 1, 1.0);
            if i < 8 {
                k.set(i, 0, 8.0); // score ≈ 64/√16 = 16
            } else {
                k.set(i, 0, 1e-3); // upper bound ≈ 8e-3/4 « 16 - thresh
            }
            k.set(i, 2 + (i % 4), 0.5);
            for c in 0..4 {
                v.set(i, c, (i % 7) as f32 - 3.0);
            }
        }
        let qc = topk_codes(&q, 2);
        let kc = topk_codes(&k, 2);
        let kf = CscFeat::from_codes(&kc);
        let exact =
            FlashSfa { k: 2, block_q: 16, block_k: 16, threads: 2, skip: false, skip_thresh: 0.0, skip_mass: 0.0 };
        let approx = FlashSfa { skip: true, skip_thresh: 8.0, ..exact };
        let (a, counts) = approx.forward_codes_counted(&qc, &kf, &v, d, false);
        let b = exact.forward_codes(&qc, &kf, &v, d, false);
        assert!(counts.tiles_skipped > 0, "threshold must engage: {counts:?}");
        // Dropped unnormalized mass per row ≤ n·exp(-8) ≈ 3e-2 relative
        // to the exp(0)-scale retained mass; outputs move O(1e-3).
        assert_close(&a, &b, 5e-3, 5e-3);
    }

    #[test]
    fn append_matches_per_row_reference() {
        // forward_codes_append == per-row softmax over the causally
        // growing key prefix (the chunked-prefill contract), and the
        // start_pos == 0 square case degenerates to forward_codes.
        check("append kernel == per-row reference", 24, |g| {
            let d = 16;
            let k = g.usize_in(2..5);
            let total = g.usize_in(2..48);
            let n_q = g.usize_in(1..total + 1);
            let start = total - n_q;
            let skip = g.bool();
            let (kk, _, v) = qkv(total, d, 8, g.seed);
            let (q, _, _) = qkv(total, d, 8, g.seed.wrapping_add(7));
            let mut qsuf = Matrix::zeros(n_q, d);
            for t in 0..n_q {
                qsuf.row_mut(t).copy_from_slice(q.row(start + t));
            }
            let qc_suffix = topk_codes(&qsuf, k);
            let kc = topk_codes(&kk, k);
            let kf = CscFeat::from_codes(&kc);
            let eng = FlashSfa {
                k,
                block_q: *g.choose(&[4usize, 8, 64]),
                block_k: *g.choose(&[4usize, 8, 64]),
                threads: 2,
                skip,
                skip_thresh: 0.0,
                skip_mass: 0.0,
            };
            let got = eng.forward_codes_append(&qc_suffix, &kf, &v, d, start);
            // Reference: densified codes, two-pass softmax per row over
            // keys 0..=start+t.
            let qd = qc_suffix.densify();
            let kd = kc.densify();
            let scale = 1.0 / (d as f32).sqrt();
            for t in 0..n_q {
                let upto = start + t + 1;
                let mut scores = vec![0f32; upto];
                for (j, s) in scores.iter_mut().enumerate() {
                    let mut acc = 0.0;
                    for c in 0..d {
                        acc += qd.get(t, c) * kd.get(j, c);
                    }
                    *s = acc * scale;
                }
                let m = scores.iter().fold(NEG_INF, |a, &b| a.max(b));
                let exps: Vec<f32> = scores.iter().map(|&s| (s - m).exp()).collect();
                let l: f32 = exps.iter().sum();
                for c in 0..v.cols {
                    let want: f32 =
                        (0..upto).map(|j| exps[j] / l * v.get(j, c)).sum();
                    let diff = (got.get(t, c) - want).abs();
                    assert!(
                        diff <= 3e-5 + 3e-5 * want.abs(),
                        "skip={skip} row {t} col {c}: {} vs {want}",
                        got.get(t, c)
                    );
                }
            }
        });
    }

    #[test]
    fn append_with_zero_start_equals_causal_forward() {
        let (q, k, v) = qkv(40, 32, 16, 12);
        let qc = topk_codes(&q, 4);
        let kc = topk_codes(&k, 4);
        let kf = CscFeat::from_codes(&kc);
        for skip in [false, true] {
            let eng =
                FlashSfa { k: 4, block_q: 8, block_k: 8, threads: 2, skip, skip_thresh: 0.0, skip_mass: 0.0 };
            let a = eng.forward_codes_append(&qc, &kf, &v, 32, 0);
            let b = eng.forward_codes(&qc, &kf, &v, 32, true);
            assert_close(&a, &b, 1e-6, 1e-7);
        }
    }

    #[test]
    fn counters_partition_the_tile_grid() {
        let (q, k, v) = qkv(70, 32, 16, 13);
        let qc = topk_codes(&q, 4);
        let kc = topk_codes(&k, 4);
        let kf = CscFeat::from_codes(&kc);
        let eng =
            FlashSfa { k: 4, block_q: 16, block_k: 16, threads: 3, skip: true, skip_thresh: 0.0, skip_mass: 0.0 };
        let (_, c) = eng.forward_codes_counted(&qc, &kf, &v, 32, true);
        // Causal 70 rows, Bq=Bc=16: query tile ti enumerates
        // ceil(min(70, (ti+1)*16)/16) key tiles.
        let expected: u64 = (0..5u64).map(|ti| (ti + 1).min(5)).sum();
        assert_eq!(c.total_tiles(), expected);
        assert!(c.posting_hits > 0);
        assert_eq!(c.rows_skipped, 0, "exact mode never row-skips");
    }

    #[test]
    fn per_row_early_exit_engages_inside_dense_tiles() {
        // Even query rows carry only the dominant feature 0 (matched
        // strongly by the first keys, so their running max is huge and
        // later tiles' bounds are negligible); odd rows also carry
        // feature 1, which later keys match strongly — so every later
        // tile is dense *for the tile* but skippable row-by-row: the
        // even rows must take the sub-tile early exit while the odd
        // rows still accumulate exactly.
        let n = 64;
        let d = 16;
        let mut q = Matrix::zeros(n, d);
        let mut k = Matrix::zeros(n, d);
        let mut v = Matrix::zeros(n, 4);
        for i in 0..n {
            q.set(i, 0, 8.0);
            if i % 2 == 1 {
                q.set(i, 1, 6.0);
            }
            if i < 8 {
                k.set(i, 0, 8.0); // score 64/√16 = 16 for every row
            } else {
                k.set(i, 0, 1e-3); // even-row bound ≈ 2e-3 « 16 − 8
                k.set(i, 1, 6.0); // odd-row score 9 > 16 − 8: tile stays
            }
            for c in 0..4 {
                v.set(i, c, ((i + c) % 5) as f32 - 2.0);
            }
        }
        let qc = topk_codes(&q, 2);
        let kc = topk_codes(&k, 2);
        let kf = CscFeat::from_codes(&kc);
        let exact = FlashSfa {
            k: 2,
            block_q: 8,
            block_k: 8,
            threads: 2,
            skip: false,
            skip_thresh: 0.0,
            skip_mass: 0.0,
        };
        let approx = FlashSfa { skip: true, skip_thresh: 8.0, ..exact };
        let (a, counts) = approx.forward_codes_counted(&qc, &kf, &v, d, false);
        let b = exact.forward_codes(&qc, &kf, &v, d, false);
        assert!(counts.rows_skipped > 0, "per-row exit must engage: {counts:?}");
        assert!(counts.tiles_visited > 0, "odd rows keep the tiles dense: {counts:?}");
        // Same n·exp(-thresh) mass bound as whole-tile skipping.
        assert_close(&a, &b, 5e-3, 5e-3);
    }

    #[test]
    fn mass_mode_equals_explicitly_derived_thresh() {
        // skip_mass=EPS must take exactly the path skip_thresh=ln(n/EPS)
        // takes: same tile decisions, same fp sequence, identical output.
        let (q, k, v) = qkv(96, 32, 16, 21);
        let qc = topk_codes(&q, 4);
        let kc = topk_codes(&k, 4);
        let kf = CscFeat::from_codes(&kc);
        let eps = 0.05f32;
        let base = FlashSfa {
            k: 4,
            block_q: 16,
            block_k: 16,
            threads: 2,
            skip: true,
            skip_thresh: 0.0,
            skip_mass: 0.0,
        };
        let by_mass = FlashSfa { skip_mass: eps, ..base };
        let by_thresh = FlashSfa { skip_thresh: (96.0f32 / eps).ln(), ..base };
        let (a, ca) = by_mass.forward_codes_counted(&qc, &kf, &v, 32, true);
        let (b, cb) = by_thresh.forward_codes_counted(&qc, &kf, &v, 32, true);
        assert_close(&a, &b, 0.0, 0.0);
        assert_eq!(ca, cb);
    }

    #[test]
    fn mass_bound_property() {
        // Satellite pin: with `mass=EPS` the per-row dropped
        // unnormalized mass is ≤ EPS (thresh = ln(n/EPS) ⇒ each dropped
        // key ≤ exp(-thresh), at most n of them), and the retained mass
        // is ≥ exp(0) = 1 (the max key always survives: its bound equals
        // the running max, never below it). So every output element
        // moves by at most ~2·EPS·max|V| — the property a hand-picked
        // thresh can't promise across context lengths.
        check("mass=EPS bounds output drift", 24, |g| {
            let n = g.usize_in(16..128);
            let d = 16;
            let k = g.usize_in(2..5);
            let causal = g.bool();
            let eps = *g.choose(&[0.5f32, 0.05, 0.005]);
            let (q, kk, v) = qkv(n, d, 4, g.seed);
            let qc = topk_codes(&q, k);
            let kc = topk_codes(&kk, k);
            let kf = CscFeat::from_codes(&kc);
            let exact = FlashSfa {
                k,
                block_q: 8,
                block_k: 8,
                threads: 2,
                skip: false,
                skip_thresh: 0.0,
                skip_mass: 0.0,
            };
            let approx = FlashSfa { skip: true, skip_mass: eps, ..exact };
            let a = approx.forward_codes(&qc, &kf, &v, d, causal);
            let b = exact.forward_codes(&qc, &kf, &v, d, causal);
            let vmax = v.data.iter().fold(0f32, |m, x| m.max(x.abs()));
            let tol = 2.2 * eps * vmax + 1e-4;
            for (x, y) in a.data.iter().zip(&b.data) {
                assert!(
                    (x - y).abs() <= tol,
                    "n={n} eps={eps}: {x} vs {y} beyond mass bound {tol}"
                );
            }
        });
    }
}
