//! Loki-style low-rank key attention (Table 10/11 "Low-Rank" rows):
//! training-free PCA of the key matrix; scores are computed in the
//! rank-r projected space (Singhania et al., 2024). Composable with
//! the SFA scorer on the projected coordinates ("+SFA").

use crate::attention::dense::softmax_rows;
use crate::attention::{Engine, Scorer};
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// Top-r PCA basis of the rows of `x` via orthogonal (subspace) power
/// iteration on the covariance XᵀX. Returns (d, r) column-orthonormal.
pub fn pca_basis(x: &Matrix, r: usize, iters: usize, seed: u64) -> Matrix {
    let d = x.cols;
    assert!(r <= d);
    let mut rng = Rng::new(seed);
    let mut basis = Matrix::randn(d, r, &mut rng, 1.0);
    orthonormalize(&mut basis);
    for _ in 0..iters {
        // B <- Xᵀ(X B), then re-orthonormalize (one subspace iteration).
        let xb = x.matmul(&basis); // (n, r)
        let mut nb = Matrix::zeros(d, r);
        for i in 0..x.rows {
            let xrow = x.row(i);
            let xbrow = xb.row(i);
            for t in 0..d {
                let xt = xrow[t];
                if xt == 0.0 {
                    continue;
                }
                let nrow = nb.row_mut(t);
                for c in 0..r {
                    nrow[c] += xt * xbrow[c];
                }
            }
        }
        basis = nb;
        orthonormalize(&mut basis);
    }
    basis
}

/// Modified Gram-Schmidt on columns.
fn orthonormalize(m: &mut Matrix) {
    let (d, r) = (m.rows, m.cols);
    for c in 0..r {
        for prev in 0..c {
            let mut dot = 0.0;
            for i in 0..d {
                dot += m.get(i, c) * m.get(i, prev);
            }
            for i in 0..d {
                let v = m.get(i, c) - dot * m.get(i, prev);
                m.set(i, c, v);
            }
        }
        let mut norm = 0.0;
        for i in 0..d {
            norm += m.get(i, c) * m.get(i, c);
        }
        let norm = norm.sqrt().max(1e-12);
        for i in 0..d {
            m.set(i, c, m.get(i, c) / norm);
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct LowRankAttention {
    /// Projection rank r « d.
    pub rank: usize,
    pub power_iters: usize,
    pub seed: u64,
    pub scorer: Scorer,
}

impl LowRankAttention {
    pub fn new(rank: usize) -> Self {
        LowRankAttention { rank, power_iters: 6, seed: 0, scorer: Scorer::Dense }
    }
}

impl Engine for LowRankAttention {
    fn name(&self) -> String {
        format!("lowrank_r{}+{}", self.rank, self.scorer.label())
    }

    fn spec(&self) -> String {
        format!(
            "lowrank:r={},iters={},seed={},scorer={}",
            self.rank,
            self.power_iters,
            self.seed,
            self.scorer.label()
        )
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        let basis = pca_basis(k, self.rank, self.power_iters, self.seed);
        let qp = q.matmul(&basis); // (n, r)
        let kp = k.matmul(&basis);
        // NOTE: Loki keeps the original softmax temperature (scale by
        // √d of the original space).
        let scale_fix = (self.rank as f32 / q.cols as f32).sqrt();
        match self.scorer {
            Scorer::Dense => {
                let mut s = crate::attention::dense::scores(&qp, &kp, scale_fix / (self.rank as f32).sqrt(), causal);
                softmax_rows(&mut s);
                s.matmul(v)
            }
            Scorer::Sfa { k: kk } => {
                let qc = crate::sparse::topk_codes(&qp, kk.min(self.rank)).densify();
                let kc = crate::sparse::topk_codes(&kp, kk.min(self.rank)).densify();
                let mut s = crate::attention::dense::scores(&qc, &kc, scale_fix / (self.rank as f32).sqrt(), causal);
                softmax_rows(&mut s);
                s.matmul(v)
            }
        }
    }
}

/// Helper used by tests + Fig 11: effective rank (#components holding
/// `tau` of the spectral energy) of a matrix, via the PCA residual.
pub fn reconstruction_error(x: &Matrix, basis: &Matrix) -> f32 {
    // ‖X − X B Bᵀ‖_F / ‖X‖_F
    let proj = x.matmul(basis).matmul(&basis.transpose());
    let mut num = 0.0;
    let mut den = 0.0;
    for (a, b) in x.data.iter().zip(&proj.data) {
        num += (a - b) * (a - b);
        den += a * a;
    }
    (num / den.max(1e-20)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::DenseAttention;
    use crate::attention::testutil::qkv;
    use crate::util::matrix::assert_close;

    #[test]
    fn basis_is_orthonormal() {
        let (_, k, _) = qkv(64, 32, 32, 0);
        let b = pca_basis(&k, 8, 5, 1);
        let g = b.transpose().matmul(&b);
        for i in 0..8 {
            for j in 0..8 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((g.get(i, j) - expect).abs() < 1e-4, "{i},{j}");
            }
        }
    }

    #[test]
    fn full_rank_matches_dense() {
        let (q, k, v) = qkv(24, 16, 16, 2);
        let a = LowRankAttention { rank: 16, power_iters: 8, seed: 0, scorer: Scorer::Dense }
            .forward(&q, &k, &v, true);
        let b = DenseAttention.forward(&q, &k, &v, true);
        // Full-rank projection is a rotation; scores are preserved.
        assert_close(&a, &b, 5e-3, 5e-3);
    }

    #[test]
    fn pca_captures_planted_low_rank_structure() {
        // K = U S with rank 4 planted; rank-4 PCA must reconstruct well.
        let mut rng = Rng::new(3);
        let u = Matrix::randn(64, 4, &mut rng, 1.0);
        let s = Matrix::randn(4, 32, &mut rng, 1.0);
        let k = u.matmul(&s);
        let basis = pca_basis(&k, 4, 10, 4);
        assert!(reconstruction_error(&k, &basis) < 1e-3);
        // Rank-2 cannot.
        let basis2 = pca_basis(&k, 2, 10, 5);
        assert!(reconstruction_error(&k, &basis2) > 0.1);
    }

    #[test]
    fn sfa_composition_runs_and_is_finite() {
        let (q, k, v) = qkv(32, 32, 16, 6);
        let out = LowRankAttention {
            rank: 16, power_iters: 4, seed: 0, scorer: Scorer::Sfa { k: 4 },
        }
        .forward(&q, &k, &v, true);
        assert!(out.data.iter().all(|x| x.is_finite()));
    }
}
