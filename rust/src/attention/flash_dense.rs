//! Tiled dense attention with online softmax — the FlashAttention-2
//! analog the paper benchmarks FlashSFA against (App. C: "FMA-based
//! Dense Flash Attention on the code base of Flash Attention 2").
//!
//! Never materializes the n×n score matrix: per query tile it streams
//! key/value tiles, computes a Br×Bc score buffer, and folds it into
//! the online-softmax state. Query tiles run in parallel (the CUDA
//! grid's blockIdx.x axis mapped onto the thread pool).

use crate::attention::online_softmax::OnlineSoftmax;
use crate::attention::{Engine, NEG_INF};
use crate::util::matrix::Matrix;
use crate::util::threadpool::{parallel_for_dynamic, SendPtr};

#[derive(Debug, Clone, Copy)]
pub struct FlashDense {
    pub block_q: usize,
    pub block_k: usize,
    pub threads: usize,
}

impl Default for FlashDense {
    fn default() -> Self {
        FlashDense { block_q: 64, block_k: 64, threads: crate::util::threadpool::default_threads() }
    }
}

impl FlashDense {
    /// `causal` is the diagonal offset: `Some(off)` lets query row `i`
    /// attend keys `0..=i + off`; `None` attends everything.
    fn forward_tile(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        causal: Option<usize>,
        i0: usize,
        out: &mut [f32],
    ) {
        let n = k.rows;
        let d = q.cols;
        let br = self.block_q.min(q.rows - i0);
        let scale = 1.0 / (d as f32).sqrt();
        let mut os = OnlineSoftmax::new(br, v.cols);
        let mut score_tile = vec![0f32; br * self.block_k];

        let j_max = match causal {
            Some(off) => (i0 + br + off).min(n),
            None => n,
        };
        let mut j0 = 0;
        while j0 < j_max {
            let bc = self.block_k.min(j_max - j0);
            // S_tile = Q_tile · K_tileᵀ · scale (+ causal mask)
            for r in 0..br {
                let qrow = q.row(i0 + r);
                let srow = &mut score_tile[r * bc..(r + 1) * bc];
                for (c, s) in srow.iter_mut().enumerate() {
                    let krow = k.row(j0 + c);
                    let mut acc = 0.0;
                    for t in 0..d {
                        acc += qrow[t] * krow[t];
                    }
                    *s = acc * scale;
                }
                if let Some(off) = causal {
                    let visible = i0 + r + off;
                    for (c, s) in srow.iter_mut().enumerate() {
                        if j0 + c > visible {
                            *s = NEG_INF;
                        }
                    }
                }
            }
            let vdata = &v.data;
            let vcols = v.cols;
            os.update(&score_tile[..br * bc], bc, |c| {
                vdata[(j0 + c) * vcols..].as_ptr()
            });
            j0 += bc;
        }
        os.finish(out);
    }

    fn forward_offset(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: Option<usize>) -> Matrix {
        assert_eq!(q.cols, k.cols);
        assert_eq!(k.rows, v.rows);
        let mut out = Matrix::zeros(q.rows, v.cols);
        let n_tiles = q.rows.div_ceil(self.block_q);
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        parallel_for_dynamic(n_tiles, self.threads, 1, move |tile| {
            let i0 = tile * self.block_q;
            let br = self.block_q.min(q.rows - i0);
            // SAFETY: query tiles write disjoint output row ranges.
            let out_slice = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.get().add(i0 * v.cols), br * v.cols)
            };
            self.forward_tile(q, k, v, causal, i0, out_slice);
        });
        out
    }

    /// KV-append variant for chunked prefill: query row `t` attends
    /// keys `0..=start_pos + t` of the (longer) cached key sequence — a
    /// suffix of `q.rows` new positions over a `start_pos`-token cached
    /// prefix plus the causal suffix itself. `start_pos == 0` with
    /// `q.rows == k.rows` is exactly the causal [`Engine::forward`].
    pub fn forward_append(&self, q: &Matrix, k: &Matrix, v: &Matrix, start_pos: usize) -> Matrix {
        assert!(
            start_pos + q.rows <= k.rows,
            "append window {}+{} exceeds cached keys {}",
            start_pos,
            q.rows,
            k.rows
        );
        self.forward_offset(q, k, v, Some(start_pos))
    }
}

impl Engine for FlashDense {
    fn name(&self) -> String {
        format!("flash_dense(bq={},bk={})", self.block_q, self.block_k)
    }

    fn spec(&self) -> String {
        format!("flash_dense:bq={},bk={}", self.block_q, self.block_k)
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        self.forward_offset(q, k, v, causal.then_some(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::DenseAttention;
    use crate::attention::testutil::qkv;
    use crate::util::matrix::assert_close;
    use crate::util::prop::check;

    #[test]
    fn matches_naive_dense() {
        check("flash_dense == dense", 24, |g| {
            let n = g.usize_in(1..96);
            let d = *g.choose(&[8usize, 32, 64]);
            let causal = g.bool();
            let bq = *g.choose(&[8usize, 16, 64]);
            let bk = *g.choose(&[8usize, 16, 64]);
            let (q, k, v) = qkv(n, d, d, g.seed);
            let flash = FlashDense { block_q: bq, block_k: bk, threads: 2 };
            let a = flash.forward(&q, &k, &v, causal);
            let b = DenseAttention.forward(&q, &k, &v, causal);
            assert_close(&a, &b, 2e-5, 2e-6);
        });
    }

    #[test]
    fn single_vs_multi_thread_identical() {
        let (q, k, v) = qkv(130, 32, 32, 9);
        let a = FlashDense { block_q: 32, block_k: 32, threads: 1 }.forward(&q, &k, &v, true);
        let b = FlashDense { block_q: 32, block_k: 32, threads: 8 }.forward(&q, &k, &v, true);
        assert_close(&a, &b, 0.0, 0.0); // identical fp sequence per tile
    }

    #[test]
    fn non_divisible_sizes() {
        let (q, k, v) = qkv(77, 16, 24, 3);
        let a = FlashDense { block_q: 16, block_k: 32, threads: 4 }.forward(&q, &k, &v, true);
        let b = DenseAttention.forward(&q, &k, &v, true);
        assert_close(&a, &b, 2e-5, 2e-6);
    }

    #[test]
    fn append_suffix_matches_causal_forward_rows() {
        // forward_append over a query suffix must reproduce the matching
        // rows of the full causal forward — the chunked-prefill contract.
        check("dense append == causal suffix rows", 24, |g| {
            let total = g.usize_in(2..80);
            let n_q = g.usize_in(1..total + 1);
            let start = total - n_q;
            let d = *g.choose(&[8usize, 16, 32]);
            let bq = *g.choose(&[4usize, 16, 64]);
            let bk = *g.choose(&[4usize, 16, 64]);
            let (q, k, v) = qkv(total, d, d, g.seed);
            let mut qsuf = Matrix::zeros(n_q, d);
            for t in 0..n_q {
                qsuf.row_mut(t).copy_from_slice(q.row(start + t));
            }
            let eng = FlashDense { block_q: bq, block_k: bk, threads: 2 };
            let got = eng.forward_append(&qsuf, &k, &v, start);
            let full = DenseAttention.forward(&q, &k, &v, true);
            for t in 0..n_q {
                for c in 0..v.cols {
                    let (a, b) = (got.get(t, c), full.get(start + t, c));
                    assert!(
                        (a - b).abs() <= 2e-5 + 2e-5 * b.abs(),
                        "row {t} col {c}: {a} vs {b}"
                    );
                }
            }
        });
    }

    #[test]
    fn append_with_zero_start_equals_causal_forward() {
        let (q, k, v) = qkv(50, 16, 16, 11);
        let eng = FlashDense { block_q: 16, block_k: 16, threads: 2 };
        let a = eng.forward_append(&q, &k, &v, 0);
        let b = eng.forward(&q, &k, &v, true);
        assert_close(&a, &b, 0.0, 0.0); // identical fp sequence
    }
}
