//! Tiled dense attention with online softmax — the FlashAttention-2
//! analog the paper benchmarks FlashSFA against (App. C: "FMA-based
//! Dense Flash Attention on the code base of Flash Attention 2").
//!
//! Never materializes the n×n score matrix: per query tile it streams
//! key/value tiles, computes a Br×Bc score buffer, and folds it into
//! the online-softmax state. Query tiles run in parallel (the CUDA
//! grid's blockIdx.x axis mapped onto the thread pool).

use crate::attention::online_softmax::OnlineSoftmax;
use crate::attention::{Engine, NEG_INF};
use crate::util::matrix::Matrix;
use crate::util::threadpool::{parallel_for_dynamic, SendPtr};

#[derive(Debug, Clone, Copy)]
pub struct FlashDense {
    pub block_q: usize,
    pub block_k: usize,
    pub threads: usize,
}

impl Default for FlashDense {
    fn default() -> Self {
        FlashDense { block_q: 64, block_k: 64, threads: crate::util::threadpool::default_threads() }
    }
}

impl FlashDense {
    fn forward_tile(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        causal: bool,
        i0: usize,
        out: &mut [f32],
    ) {
        let n = k.rows;
        let d = q.cols;
        let br = self.block_q.min(q.rows - i0);
        let scale = 1.0 / (d as f32).sqrt();
        let mut os = OnlineSoftmax::new(br, v.cols);
        let mut score_tile = vec![0f32; br * self.block_k];

        let j_max = if causal { (i0 + br).min(n) } else { n };
        let mut j0 = 0;
        while j0 < j_max {
            let bc = self.block_k.min(j_max - j0);
            // S_tile = Q_tile · K_tileᵀ · scale (+ causal mask)
            for r in 0..br {
                let qrow = q.row(i0 + r);
                let srow = &mut score_tile[r * bc..(r + 1) * bc];
                for (c, s) in srow.iter_mut().enumerate() {
                    let krow = k.row(j0 + c);
                    let mut acc = 0.0;
                    for t in 0..d {
                        acc += qrow[t] * krow[t];
                    }
                    *s = acc * scale;
                }
                if causal {
                    let row_global = i0 + r;
                    for (c, s) in srow.iter_mut().enumerate() {
                        if j0 + c > row_global {
                            *s = NEG_INF;
                        }
                    }
                }
            }
            let vdata = &v.data;
            let vcols = v.cols;
            os.update(&score_tile[..br * bc], bc, |c| {
                vdata[(j0 + c) * vcols..].as_ptr()
            });
            j0 += bc;
        }
        os.finish(out);
    }
}

impl Engine for FlashDense {
    fn name(&self) -> String {
        format!("flash_dense(bq={},bk={})", self.block_q, self.block_k)
    }

    fn spec(&self) -> String {
        format!("flash_dense:bq={},bk={}", self.block_q, self.block_k)
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        assert_eq!(q.cols, k.cols);
        assert_eq!(k.rows, v.rows);
        let mut out = Matrix::zeros(q.rows, v.cols);
        let n_tiles = q.rows.div_ceil(self.block_q);
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        parallel_for_dynamic(n_tiles, self.threads, 1, move |tile| {
            let i0 = tile * self.block_q;
            let br = self.block_q.min(q.rows - i0);
            // SAFETY: query tiles write disjoint output row ranges.
            let out_slice = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.get().add(i0 * v.cols), br * v.cols)
            };
            self.forward_tile(q, k, v, causal, i0, out_slice);
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::DenseAttention;
    use crate::attention::testutil::qkv;
    use crate::util::matrix::assert_close;
    use crate::util::prop::check;

    #[test]
    fn matches_naive_dense() {
        check("flash_dense == dense", 24, |g| {
            let n = g.usize_in(1..96);
            let d = *g.choose(&[8usize, 32, 64]);
            let causal = g.bool();
            let bq = *g.choose(&[8usize, 16, 64]);
            let bk = *g.choose(&[8usize, 16, 64]);
            let (q, k, v) = qkv(n, d, d, g.seed);
            let flash = FlashDense { block_q: bq, block_k: bk, threads: 2 };
            let a = flash.forward(&q, &k, &v, causal);
            let b = DenseAttention.forward(&q, &k, &v, causal);
            assert_close(&a, &b, 2e-5, 2e-6);
        });
    }

    #[test]
    fn single_vs_multi_thread_identical() {
        let (q, k, v) = qkv(130, 32, 32, 9);
        let a = FlashDense { block_q: 32, block_k: 32, threads: 1 }.forward(&q, &k, &v, true);
        let b = FlashDense { block_q: 32, block_k: 32, threads: 8 }.forward(&q, &k, &v, true);
        assert_close(&a, &b, 0.0, 0.0); // identical fp sequence per tile
    }

    #[test]
    fn non_divisible_sizes() {
        let (q, k, v) = qkv(77, 16, 24, 3);
        let a = FlashDense { block_q: 16, block_k: 32, threads: 4 }.forward(&q, &k, &v, true);
        let b = DenseAttention.forward(&q, &k, &v, true);
        assert_close(&a, &b, 2e-5, 2e-6);
    }
}
