//! Simulated 8-bit quantization of Q/K scoring (Table 10 "Quant" row,
//! QAT-style: per-row symmetric int8 with f32 scale). Composable with
//! SFA ("SFA (quant)"): the top-k sparse values are quantized, halving
//! the sparse-cache value bytes again.

use crate::attention::dense::{softmax_rows, DenseAttention};
use crate::attention::{Engine, Scorer};
use crate::util::matrix::Matrix;

/// Per-row symmetric int8 quantization: returns (codes, scales).
pub fn quantize_rows(x: &Matrix) -> (Vec<i8>, Vec<f32>) {
    let mut codes = vec![0i8; x.rows * x.cols];
    let mut scales = vec![0f32; x.rows];
    for i in 0..x.rows {
        let row = x.row(i);
        let maxabs = row.iter().fold(0f32, |a, &b| a.max(b.abs()));
        let scale = if maxabs == 0.0 { 1.0 } else { maxabs / 127.0 };
        scales[i] = scale;
        for (c, &v) in codes[i * x.cols..(i + 1) * x.cols].iter_mut().zip(row) {
            *c = (v / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    (codes, scales)
}

/// Dequantize back to f32 (the simulation half of fake-quant).
pub fn dequantize_rows(codes: &[i8], scales: &[f32], rows: usize, cols: usize) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        let s = scales[i];
        for j in 0..cols {
            m.set(i, j, codes[i * cols + j] as f32 * s);
        }
    }
    m
}

#[derive(Debug, Clone, Copy)]
pub struct QuantAttention {
    pub scorer: Scorer,
}

impl Engine for QuantAttention {
    fn name(&self) -> String {
        format!("quant8+{}", self.scorer.label())
    }

    fn spec(&self) -> String {
        format!("quant:scorer={}", self.scorer.label())
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        let fake = |m: &Matrix| {
            let (c, s) = quantize_rows(m);
            dequantize_rows(&c, &s, m.rows, m.cols)
        };
        match self.scorer {
            Scorer::Dense => DenseAttention.forward(&fake(q), &fake(k), v, causal),
            Scorer::Sfa { k: kk } => {
                // Quantize the sparse *values* (indices are already ints).
                let qs = fake(&crate::sparse::topk_codes(q, kk).densify());
                let ks = fake(&crate::sparse::topk_codes(k, kk).densify());
                let scale = 1.0 / (q.cols as f32).sqrt();
                let mut s = crate::attention::dense::scores(&qs, &ks, scale, causal);
                softmax_rows(&mut s);
                s.matmul(v)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::qkv;

    #[test]
    fn quantization_roundtrip_error_bounded() {
        let (q, _, _) = qkv(16, 32, 32, 0);
        let (c, s) = quantize_rows(&q);
        let deq = dequantize_rows(&c, &s, 16, 32);
        for i in 0..16 {
            let maxabs = q.row(i).iter().fold(0f32, |a, &b| a.max(b.abs()));
            let step = maxabs / 127.0;
            for j in 0..32 {
                assert!((q.get(i, j) - deq.get(i, j)).abs() <= 0.5 * step + 1e-7);
            }
        }
    }

    #[test]
    fn zero_row_handled() {
        let m = Matrix::zeros(2, 4);
        let (c, s) = quantize_rows(&m);
        assert!(c.iter().all(|&x| x == 0));
        assert!(s.iter().all(|&x| x == 1.0));
    }

    /// Satellite property (the tiered-KV accuracy contract): for any
    /// matrix, `dequantize_rows(quantize_rows(x))` is within `scale/2`
    /// of `x` per element, where `scale` is that row's own maxabs/127 —
    /// including all-zero rows (scale pinned to 1.0, exact round trip)
    /// and single-element rows (the element IS the maxabs: code ±127,
    /// error exactly 0 up to fp rounding).
    #[test]
    fn property_quant_roundtrip_error_within_half_scale() {
        crate::util::prop::check("quant round-trip bound", 48, |g| {
            let rows = g.usize_in(1..12);
            let cols = g.usize_in(1..24);
            let mut x = Matrix::zeros(rows, cols);
            for i in 0..rows {
                match g.usize_in(0..4) {
                    // All-zero row: must round-trip exactly.
                    0 => {}
                    // Uniform magnitudes across several decades.
                    1 => {
                        let mag = 10f32.powi(g.usize_in(0..7) as i32 - 3);
                        for j in 0..cols {
                            x.set(i, j, g.f32_in(-mag..mag));
                        }
                    }
                    // Normal-ish data (the KV payload case).
                    _ => {
                        for j in 0..cols {
                            x.set(i, j, g.f32_in(-2.0..2.0));
                        }
                    }
                }
            }
            let (c, s) = quantize_rows(&x);
            let deq = dequantize_rows(&c, &s, rows, cols);
            for i in 0..rows {
                let maxabs = x.row(i).iter().fold(0f32, |a, &b| a.max(b.abs()));
                let scale = if maxabs == 0.0 { 1.0 } else { maxabs / 127.0 };
                assert_eq!(s[i], scale, "scale definition is pinned");
                for j in 0..cols {
                    let err = (x.get(i, j) - deq.get(i, j)).abs();
                    // Slack: the half-step bound plus ~2 fp roundings
                    // of the div/mul pair at |code| <= 127.
                    assert!(
                        err <= 0.5 * scale * (1.0 + 1e-3) + 1e-7,
                        "row {i} col {j}: err {err} > scale/2 {}",
                        0.5 * scale
                    );
                }
                if maxabs == 0.0 {
                    for j in 0..cols {
                        assert_eq!(deq.get(i, j), 0.0, "zero rows round-trip exactly");
                    }
                }
            }
        });
    }

    /// Single-element rows: the lone value is its own maxabs, so the
    /// code saturates at ±127 and the round trip is exact (up to one
    /// fp rounding of maxabs/127*127).
    #[test]
    fn single_element_rows_roundtrip_near_exactly() {
        for &v in &[0.0f32, 1.0, -1.0, 3.25e-6, -7.5e4, 1e-30] {
            let mut m = Matrix::zeros(1, 1);
            m.set(0, 0, v);
            let (c, s) = quantize_rows(&m);
            let deq = dequantize_rows(&c, &s, 1, 1);
            if v == 0.0 {
                assert_eq!(deq.get(0, 0), 0.0);
                assert_eq!(s[0], 1.0);
            } else {
                assert_eq!(c[0], if v > 0.0 { 127 } else { -127 });
                let rel = ((deq.get(0, 0) - v) / v).abs();
                assert!(rel <= 1e-6, "single element should be exact: {v} -> {}", deq.get(0, 0));
            }
        }
    }

    #[test]
    fn quant_attention_close_to_dense() {
        let (q, k, v) = qkv(24, 16, 16, 1);
        let a = QuantAttention { scorer: Scorer::Dense }.forward(&q, &k, &v, true);
        let b = DenseAttention.forward(&q, &k, &v, true);
        let mut err = 0.0;
        for i in 0..a.data.len() {
            err += (a.data[i] - b.data[i]).powi(2);
        }
        let rel = err.sqrt() / b.fro_norm();
        assert!(rel < 0.05, "int8 scoring should be near-lossless: {rel}");
    }

    #[test]
    fn sfa_quant_close_to_sfa() {
        let (q, k, v) = qkv(24, 32, 16, 2);
        let a = QuantAttention { scorer: Scorer::Sfa { k: 8 } }.forward(&q, &k, &v, true);
        let b = crate::attention::dense::SfaReference { k: 8 }.forward(&q, &k, &v, true);
        let mut err = 0.0;
        for i in 0..a.data.len() {
            err += (a.data[i] - b.data[i]).powi(2);
        }
        let rel = err.sqrt() / b.fro_norm();
        assert!(rel < 0.05, "{rel}");
    }
}
