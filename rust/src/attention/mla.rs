//! Multi-head Latent Attention (DeepSeek-V2-style, Table 10 "MLA"):
//! keys/values are compressed through a shared low-dimensional latent
//! vector c = x W_down; per-head K/V are re-expanded at score time but
//! only the latent is cached. "MLA + SFA" applies top-k feature
//! sparsity to the latent codes — the paper's composition row.

use crate::attention::dense::{scores, softmax_rows};
use crate::attention::{Engine, Scorer};
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct MlaAttention {
    /// Latent dimension r (paper caches only this per token).
    pub latent: usize,
    pub seed: u64,
    pub scorer: Scorer,
}

impl MlaAttention {
    pub fn new(latent: usize) -> Self {
        MlaAttention { latent, seed: 0, scorer: Scorer::Dense }
    }
}

impl Engine for MlaAttention {
    fn name(&self) -> String {
        format!("mla_r{}+{}", self.latent, self.scorer.label())
    }

    fn spec(&self) -> String {
        format!("mla:r={},seed={},scorer={}", self.latent, self.seed, self.scorer.label())
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        let d = q.cols;
        let r = self.latent;
        let mut rng = Rng::new(self.seed);
        // Shared down-projection for K and V (the latent cache) and an
        // up-projection absorbed into the query (the MLA trick:
        // qᵀ(W_uk c) = (W_ukᵀ q)ᵀ c, so scores live in latent space).
        let w_down = Matrix::randn(d, r, &mut rng, (1.0 / d as f32).sqrt());
        let w_down_v = Matrix::randn(v.cols, r, &mut rng, (1.0 / v.cols as f32).sqrt());
        let w_uk = Matrix::randn(r, d, &mut rng, (1.0 / r as f32).sqrt());
        let w_uv = Matrix::randn(r, v.cols, &mut rng, (1.0 / r as f32).sqrt());

        let c_kv = k.matmul(&w_down); // (n, r): the only cached tensor
        let q_lat = q.matmul(&w_uk.transpose()); // (n, r)
        let v_lat = v.matmul(&w_down_v); // compress V through the latent too
        let v_expand = |m: &Matrix| m.matmul(&w_uv); // (n, d_v)

        let scale = 1.0 / (d as f32).sqrt();
        let mut s = match self.scorer {
            Scorer::Dense => scores(&q_lat, &c_kv, scale, causal),
            Scorer::Sfa { k: kk } => {
                let kk = kk.min(r);
                let qs = crate::sparse::topk_codes(&q_lat, kk).densify();
                let ks = crate::sparse::topk_codes(&c_kv, kk).densify();
                scores(&qs, &ks, scale, causal)
            }
        };
        softmax_rows(&mut s);
        v_expand(&s.matmul(&v_lat))
    }
}

/// Latent-cache bytes per token (the MLA memory claim): r values vs
/// 2·d for dense K+V.
pub fn mla_cache_bytes_per_token(latent: usize, s_val: usize) -> usize {
    latent * s_val
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::qkv;

    #[test]
    fn output_finite_and_causal() {
        let (q, mut k, mut v) = qkv(40, 32, 32, 0);
        let eng = MlaAttention::new(8);
        let o1 = eng.forward(&q, &k, &v, true);
        assert!(o1.data.iter().all(|x| x.is_finite()));
        for i in 30..40 {
            k.row_mut(i).fill(3.0);
            v.row_mut(i).fill(-3.0);
        }
        let o2 = eng.forward(&q, &k, &v, true);
        crate::util::matrix::assert_close(&o1.head_rows(30), &o2.head_rows(30), 1e-5, 1e-6);
    }

    #[test]
    fn sfa_composition_finite() {
        let (q, k, v) = qkv(32, 32, 16, 1);
        let eng = MlaAttention { latent: 16, seed: 2, scorer: Scorer::Sfa { k: 4 } };
        let out = eng.forward(&q, &k, &v, true);
        assert!(out.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn cache_saving_vs_dense() {
        // MLA caches r floats/token vs 2d for K+V (paper Table 10's
        // dramatic decode advantage).
        assert!(mla_cache_bytes_per_token(16, 2) < 2 * 64 * 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let (q, k, v) = qkv(16, 16, 16, 3);
        let a = MlaAttention { latent: 8, seed: 7, scorer: Scorer::Dense }.forward(&q, &k, &v, true);
        let b = MlaAttention { latent: 8, seed: 7, scorer: Scorer::Dense }.forward(&q, &k, &v, true);
        crate::util::matrix::assert_close(&a, &b, 0.0, 0.0);
    }
}
