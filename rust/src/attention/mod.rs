//! Attention engines — the CPU perf substrate for every latency table
//! and figure in the paper (DESIGN.md §Substitutions: the A800/CUDA
//! kernels are ported to structurally-faithful CPU engines; relative
//! shapes, crossovers and scaling exponents are the reproduction
//! target, not absolute milliseconds).
//!
//! * [`dense`] — naive materializing softmax attention (the reference)
//! * [`flash_dense`] — tiled online-softmax dense attention
//!   (FlashAttention-2 analog; the paper's "Dense" baseline kernel)
//! * [`flash_sfa`] — the FlashSFA engine: posting-list intersection +
//!   online softmax, App. C Algorithm 1
//! * [`window`] — Longformer-style local attention (token sparsity),
//!   composable with the SFA scorer (Table 10/11 "+SFA" rows)
//! * [`decode`] — single-query decode attention + KV-pruning policies
//!   (H2O / SnapKV / Quest) and their SFA compositions
//! * [`lowrank`] — Loki-style PCA-projected keys (training-free)
//! * [`performer`] — FAVOR+ positive random features (kernel baseline)
//! * [`mla`] — multi-head latent attention (shared KV compression),
//!   composable with SFA on the latent vector
//! * [`quant`] — simulated int8 quantization of Q/K scoring (QAT row)

pub mod decode;
pub mod dense;
pub mod flash_dense;
pub mod flash_sfa;
pub mod lowrank;
pub mod mla;
pub mod online_softmax;
pub mod performer;
pub mod quant;
pub mod window;

use crate::util::matrix::Matrix;

/// How retained query-key pairs are scored (feature-level axis).
/// Token-level methods (window, KV pruning) take a `Scorer` so the
/// paper's orthogonal compositions are first-class (Tables 10/11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scorer {
    /// Full d-dimensional dot product.
    Dense,
    /// Feature-overlap scoring over top-k sparse codes (SFA, Eq. 5).
    Sfa { k: usize },
}

impl Scorer {
    pub fn label(&self) -> String {
        match self {
            Scorer::Dense => "dense".into(),
            Scorer::Sfa { k } => format!("sfa_k{k}"),
        }
    }
}

/// A forward (prefill-style) attention engine over one head.
pub trait Engine: Sync {
    fn name(&self) -> String;

    /// q (n, d), k (n, d), v (n, d_v) -> (n, d_v).
    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix;
}

pub(crate) const NEG_INF: f32 = -1.0e30;

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::rng::Rng;

    pub fn qkv(n: usize, d: usize, dv: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        (
            Matrix::randn(n, d, &mut rng, 1.0),
            Matrix::randn(n, d, &mut rng, 1.0),
            Matrix::randn(n, dv, &mut rng, 1.0),
        )
    }
}
