//! Attention engines — the CPU perf substrate for every latency table
//! and figure in the paper (DESIGN.md §Substitutions: the A800/CUDA
//! kernels are ported to structurally-faithful CPU engines; relative
//! shapes, crossovers and scaling exponents are the reproduction
//! target, not absolute milliseconds).
//!
//! * [`dense`] — naive materializing softmax attention (the reference)
//! * [`flash_dense`] — tiled online-softmax dense attention
//!   (FlashAttention-2 analog; the paper's "Dense" baseline kernel)
//! * [`flash_sfa`] — the FlashSFA engine: posting-list intersection +
//!   online softmax, App. C Algorithm 1
//! * [`window`] — Longformer-style local attention (token sparsity),
//!   composable with the SFA scorer (Table 10/11 "+SFA" rows)
//! * [`decode`] — single-query decode attention + KV-pruning policies
//!   (H2O / SnapKV / Quest) and their SFA compositions; also the
//!   [`decode::PagedKvPolicy`] config the serve stack uses to run
//!   those policies as physical page eviction on policy-budgeted
//!   session lanes
//! * [`lowrank`] — Loki-style PCA-projected keys (training-free)
//! * [`performer`] — FAVOR+ positive random features (kernel baseline)
//! * [`mla`] — multi-head latent attention (shared KV compression),
//!   composable with SFA on the latent vector
//! * [`quant`] — simulated int8 quantization of Q/K scoring (QAT row)
//! * [`registry`] — spec strings (`"sfa:k=8,bq=64,bk=64"`) → engines
//! * [`session`] — multi-head batched prefill + paged-cache decode
//!   lifecycle over any engine

pub mod decode;
pub mod dense;
pub mod flash_dense;
pub mod flash_sfa;
pub mod lowrank;
pub mod mla;
pub mod online_softmax;
pub mod performer;
pub mod quant;
pub mod registry;
pub mod session;
pub mod window;

use crate::util::matrix::Matrix;
use crate::util::rng::Rng;
use crate::util::threadpool::{parallel_for_dynamic, SendPtr};

/// How retained query-key pairs are scored (feature-level axis).
/// Token-level methods (window, KV pruning) take a `Scorer` so the
/// paper's orthogonal compositions are first-class (Tables 10/11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scorer {
    /// Full d-dimensional dot product.
    Dense,
    /// Feature-overlap scoring over top-k sparse codes (SFA, Eq. 5).
    Sfa { k: usize },
}

impl Scorer {
    pub fn label(&self) -> String {
        match self {
            Scorer::Dense => "dense".into(),
            Scorer::Sfa { k } => format!("sfa_k{k}"),
        }
    }
}

/// Batched multi-head activations with shape `[batch, heads, n, d]`,
/// row-major — the tensor view the serving path hands the engines
/// (one contiguous `(n, d)` block per `(batch, head)` pair).
#[derive(Debug, Clone, PartialEq)]
pub struct HeadTensor {
    pub batch: usize,
    pub heads: usize,
    pub n: usize,
    pub d: usize,
    /// len `batch * heads * n * d`.
    pub data: Vec<f32>,
}

impl HeadTensor {
    pub fn zeros(batch: usize, heads: usize, n: usize, d: usize) -> HeadTensor {
        HeadTensor { batch, heads, n, d, data: vec![0.0; batch * heads * n * d] }
    }

    /// iid N(0, scale²) entries.
    pub fn randn(
        batch: usize,
        heads: usize,
        n: usize,
        d: usize,
        rng: &mut Rng,
        scale: f32,
    ) -> HeadTensor {
        HeadTensor { batch, heads, n, d, data: rng.normal_vec(batch * heads * n * d, scale) }
    }

    /// Total number of `(batch, head)` pairs.
    pub fn head_count(&self) -> usize {
        self.batch * self.heads
    }

    /// Floats per head block.
    pub fn head_len(&self) -> usize {
        self.n * self.d
    }

    #[inline]
    fn head_offset(&self, b: usize, h: usize) -> usize {
        debug_assert!(b < self.batch && h < self.heads);
        (b * self.heads + h) * self.n * self.d
    }

    /// The `(n, d)` block of one head as a slice.
    #[inline]
    pub fn head_slice(&self, b: usize, h: usize) -> &[f32] {
        let o = self.head_offset(b, h);
        &self.data[o..o + self.n * self.d]
    }

    /// Copy one head out as a standalone matrix (the single-head
    /// engines' native input format).
    pub fn head(&self, b: usize, h: usize) -> Matrix {
        Matrix::from_vec(self.n, self.d, self.head_slice(b, h).to_vec())
    }

    /// Row `t` of head `(b, h)`.
    #[inline]
    pub fn head_row(&self, b: usize, h: usize, t: usize) -> &[f32] {
        debug_assert!(t < self.n);
        let o = self.head_offset(b, h) + t * self.d;
        &self.data[o..o + self.d]
    }

    #[inline]
    pub fn head_row_mut(&mut self, b: usize, h: usize, t: usize) -> &mut [f32] {
        debug_assert!(t < self.n);
        let o = self.head_offset(b, h) + t * self.d;
        &mut self.data[o..o + self.d]
    }

    /// Copy rows `[lo, hi)` of every head into a new tensor (prefill /
    /// decode slicing along the sequence axis).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> HeadTensor {
        assert!(lo <= hi && hi <= self.n, "row slice {lo}..{hi} out of 0..{}", self.n);
        let mut out = HeadTensor::zeros(self.batch, self.heads, hi - lo, self.d);
        for b in 0..self.batch {
            for h in 0..self.heads {
                for (dst, src) in (lo..hi).enumerate() {
                    out.head_row_mut(b, h, dst).copy_from_slice(self.head_row(b, h, src));
                }
            }
        }
        out
    }
}

/// A forward (prefill-style) attention engine.
///
/// Implementors provide the single-head [`Engine::forward`]; the
/// multi-head batched [`Engine::forward_batched`] parallelizes over the
/// `batch × heads` grid with each head's output written into its own
/// disjoint slice of the output tensor.
pub trait Engine: Sync {
    fn name(&self) -> String;

    /// Canonical [`registry`] spec string that reconstructs this engine
    /// (`registry::parse_spec(engine.spec())` round-trips).
    fn spec(&self) -> String;

    /// q (n, d), k (n, d), v (n, d_v) -> (n, d_v).
    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix;

    /// Multi-head batched forward over `[batch, heads, n, d]` views.
    /// Heads run under `parallel_for_dynamic`; per-head outputs land in
    /// disjoint slices of the `[batch, heads, n, d_v]` output.
    fn forward_batched(
        &self,
        q: &HeadTensor,
        k: &HeadTensor,
        v: &HeadTensor,
        causal: bool,
    ) -> HeadTensor {
        assert_eq!((q.batch, q.heads), (k.batch, k.heads), "q/k head grid mismatch");
        assert_eq!((q.batch, q.heads), (v.batch, v.heads), "q/v head grid mismatch");
        assert_eq!(q.d, k.d, "q/k feature dim mismatch");
        assert_eq!(k.n, v.n, "k/v length mismatch");
        let bh = q.batch * q.heads;
        let mut out = HeadTensor::zeros(q.batch, q.heads, q.n, v.d);
        let hv = q.n * v.d;
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let threads = crate::util::threadpool::default_threads().min(bh.max(1));
        parallel_for_dynamic(bh, threads, 1, move |i| {
            let (b, h) = (i / q.heads, i % q.heads);
            let o = self.forward(&q.head(b, h), &k.head(b, h), &v.head(b, h), causal);
            debug_assert_eq!(o.data.len(), hv);
            // SAFETY: each head owns a disjoint output range.
            let dst =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(i * hv), hv) };
            dst.copy_from_slice(&o.data);
        });
        out
    }
}

pub(crate) const NEG_INF: f32 = -1.0e30;

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    pub fn qkv(n: usize, d: usize, dv: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        (
            Matrix::randn(n, d, &mut rng, 1.0),
            Matrix::randn(n, d, &mut rng, 1.0),
            Matrix::randn(n, dv, &mut rng, 1.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_tensor_offsets_are_disjoint_and_ordered() {
        let mut t = HeadTensor::zeros(2, 3, 4, 5);
        for b in 0..2 {
            for h in 0..3 {
                for r in 0..4 {
                    t.head_row_mut(b, h, r).fill((b * 100 + h * 10 + r) as f32);
                }
            }
        }
        assert_eq!(t.head_row(1, 2, 3)[0], 123.0);
        assert_eq!(t.head(0, 1).get(2, 0), 12.0);
        assert_eq!(t.head_slice(1, 0).len(), 20);
        // Blocks are laid out [b, h, n, d]: head (0,1) starts at 20.
        assert_eq!(t.data[20], 10.0);
    }

    #[test]
    fn slice_rows_copies_the_requested_window() {
        let mut rng = Rng::new(0);
        let t = HeadTensor::randn(2, 2, 8, 3, &mut rng, 1.0);
        let s = t.slice_rows(2, 5);
        assert_eq!((s.n, s.d, s.batch, s.heads), (3, 3, 2, 2));
        for b in 0..2 {
            for h in 0..2 {
                for r in 0..3 {
                    assert_eq!(s.head_row(b, h, r), t.head_row(b, h, r + 2));
                }
            }
        }
    }
}
