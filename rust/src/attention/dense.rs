//! Naive dense attention: materializes the full n×n score matrix.
//! The correctness oracle for every other engine, and the "standard
//! attention" end of the paper's Figure 2.

use crate::attention::{Engine, NEG_INF};
use crate::util::matrix::Matrix;

/// Materializing softmax(QKᵀ/√d)V.
#[derive(Debug, Clone, Copy, Default)]
pub struct DenseAttention;

/// Row-wise softmax in place; entries ≤ NEG_INF are treated as masked.
pub fn softmax_rows(s: &mut Matrix) {
    for i in 0..s.rows {
        let row = s.row_mut(i);
        let m = row.iter().fold(NEG_INF, |a, &b| a.max(b));
        if m <= NEG_INF {
            row.fill(0.0);
            continue;
        }
        let mut l = 0.0;
        for x in row.iter_mut() {
            if *x <= NEG_INF {
                *x = 0.0;
            } else {
                *x = (*x - m).exp();
                l += *x;
            }
        }
        let inv = 1.0 / l;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

/// Scores QKᵀ·scale with optional causal mask.
pub fn scores(q: &Matrix, k: &Matrix, scale: f32, causal: bool) -> Matrix {
    assert_eq!(q.cols, k.cols);
    let mut s = q.matmul(&k.transpose());
    for v in s.data.iter_mut() {
        *v *= scale;
    }
    if causal {
        for i in 0..s.rows {
            let row = s.row_mut(i);
            for x in row.iter_mut().skip(i + 1) {
                *x = NEG_INF;
            }
        }
    }
    s
}

impl Engine for DenseAttention {
    fn name(&self) -> String {
        "dense".into()
    }

    fn spec(&self) -> String {
        "dense".into()
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        let scale = 1.0 / (q.cols as f32).sqrt();
        let mut s = scores(q, k, scale, causal);
        softmax_rows(&mut s);
        s.matmul(v)
    }
}

/// Dense attention over *pre-sparsified* Q/K (the materializing SFA
/// reference: softmax(Topk(Q)·Topk(K)ᵀ/√d)·V). Oracle for FlashSFA.
#[derive(Debug, Clone, Copy)]
pub struct SfaReference {
    pub k: usize,
}

impl Engine for SfaReference {
    fn name(&self) -> String {
        format!("sfa_ref_k{}", self.k)
    }

    fn spec(&self) -> String {
        format!("sfa_ref:k={}", self.k)
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        let qc = crate::sparse::topk_codes(q, self.k).densify();
        let kc = crate::sparse::topk_codes(k, self.k).densify();
        DenseAttention.forward(&qc, &kc, v, causal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::qkv;
    use crate::util::matrix::assert_close;

    #[test]
    fn softmax_rows_sum_to_one() {
        let (q, k, _) = qkv(8, 16, 16, 0);
        let mut s = scores(&q, &k, 0.25, true);
        softmax_rows(&mut s);
        for i in 0..8 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            // causal: no mass beyond the diagonal
            for j in i + 1..8 {
                assert_eq!(s.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn uniform_scores_average_values() {
        let q = Matrix::zeros(4, 8);
        let k = Matrix::zeros(4, 8);
        let mut v = Matrix::zeros(4, 2);
        for i in 0..4 {
            v.set(i, 0, i as f32);
        }
        let out = DenseAttention.forward(&q, &k, &v, false);
        // all scores equal -> output = mean of V rows
        for i in 0..4 {
            assert!((out.get(i, 0) - 1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn causal_first_row_copies_v0() {
        let (q, k, v) = qkv(6, 8, 4, 1);
        let out = DenseAttention.forward(&q, &k, &v, true);
        for t in 0..4 {
            assert!((out.get(0, t) - v.get(0, t)).abs() < 1e-6);
        }
    }

    #[test]
    fn sfa_reference_with_full_k_equals_dense() {
        let (q, k, v) = qkv(12, 16, 8, 2);
        let a = SfaReference { k: 16 }.forward(&q, &k, &v, true);
        let b = DenseAttention.forward(&q, &k, &v, true);
        assert_close(&a, &b, 1e-5, 1e-6);
    }
}
