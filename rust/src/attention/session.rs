//! `AttentionSession` — the unified multi-head attention lifecycle the
//! serving stack drives: **prefill** through any tiled [`Engine`]
//! directly into a paged KV cache, then incremental **decode** steps
//! scored from that cache with the engine family's matching scorer
//! (dense dot products, or SFA top-k feature overlap — the same
//! semantics as the [`crate::attention::decode`] caches).
//!
//! Lifecycle: spec string → [`registry`](crate::attention::registry) →
//! [`AttentionSession::prefill`] (K/V appended token-by-token into a
//! [`PagedKvCache`], one sequence per `(batch, head)` pair) →
//! [`AttentionSession::decode_step`] (append the new token, score the
//! 1-row query against the whole cached sequence). Prefill-then-decode
//! through the paged cache is numerically equivalent to a one-shot
//! causal prefill over the concatenated sequence — the session tests
//! pin this for both the dense and the SFA cache layouts.
//!
//! Cache layout follows the engine family: feature-sparse specs store
//! per-token top-k key codes (`SlotLayout::Sparse`, the paper's App-J
//! memory shape), everything else stores dense keys
//! (`SlotLayout::Dense`); values are dense in both.
//!
//! ## Lanes — the continuous-batching surface
//!
//! A session is a set of **lanes**: one lane = one sequence occupying
//! one batch slot across every head (`heads` paged-cache sequences).
//! The uniform-batch API above ([`AttentionSession::prefill`] /
//! [`AttentionSession::decode_step`]) operates on the `cfg.batch` lanes
//! created at construction. The lane API underneath lets a scheduler
//! run sequences of *different* lengths through one session:
//! [`AttentionSession::admit_lane`] (join mid-flight),
//! [`AttentionSession::prefill_lane`] (one lane's prompt, any length),
//! [`AttentionSession::decode_step_lanes`] (decode one token for an
//! arbitrary subset of live lanes), and
//! [`AttentionSession::release_lane`] (free a finished lane's pages
//! immediately, mid-wave). `rust/src/serve/` drives this surface.
//!
//! ## Policy-budgeted lanes — KV eviction inside a live batch
//!
//! [`AttentionSession::admit_lane_with_policy`] attaches one
//! [`KvPolicy`] per head to a lane. The session replays a window of
//! prefill attention into the policies, then prunes the lane's pages
//! back under the policy's token budget after prefill and between
//! [`AttentionSession::decode_step_lanes`] calls
//! ([`PagedKvCache::retain`] physically frees whole pages). A policy
//! whose budget exceeds the sequence length never prunes, and the
//! scoring path is shared with plain lanes, so a no-op-budget policy
//! lane is bit-for-bit identical to an unpruned run — the guarantee
//! the serve equivalence tests pin.

use crate::attention::decode::{
    softmax_probs, softmax_weighted_sum, topk_row, weighted_sum, KvPolicy, PagedKvPolicy,
};
use crate::attention::flash_dense::FlashDense;
use crate::attention::flash_sfa::FlashSfa;
use crate::attention::registry::{parse_spec, EngineSpec, SpecError};
use crate::attention::{Engine, HeadTensor, Scorer};
use crate::kv_cache::paged::{
    KvTierCfg, PageError, PagedKvCache, SeqId, SlotLayout, TierPolicy, TierScratch,
};
use crate::sparse::{topk_codes, CscFeat, TopkCodes};
use crate::util::matrix::Matrix;
use crate::util::threadpool::{default_threads, parallel_for_dynamic, SendPtr};

/// Pack two u16 feature ids into one f32 payload slot bit-for-bit.
/// `SlotLayout::Sparse` budgets indices at two-per-float; the payload
/// floats are only ever memcpy'd, never arithmetically touched, so any
/// bit pattern (including NaN encodings) survives the round-trip.
#[inline]
fn pack_idx(a: u16, b: u16) -> f32 {
    f32::from_bits(a as u32 | ((b as u32) << 16))
}

#[inline]
fn unpack_idx(x: f32) -> (u16, u16) {
    let bits = x.to_bits();
    ((bits & 0xFFFF) as u16, (bits >> 16) as u16)
}

/// Session geometry + paged-cache sizing.
#[derive(Debug, Clone, Copy)]
pub struct SessionConfig {
    pub batch: usize,
    pub heads: usize,
    /// Q/K feature dim per head.
    pub d: usize,
    /// V dim per head.
    pub d_v: usize,
    /// Tokens per KV page.
    pub page_size: usize,
    /// Page budget across all `(batch, head)` sequences.
    pub max_pages: usize,
}

impl SessionConfig {
    pub fn new(batch: usize, heads: usize, d: usize, d_v: usize) -> SessionConfig {
        SessionConfig { batch, heads, d, d_v, page_size: 16, max_pages: 1 << 20 }
    }

    pub fn with_paging(mut self, page_size: usize, max_pages: usize) -> SessionConfig {
        self.page_size = page_size;
        self.max_pages = max_pages;
        self
    }
}

/// Stable handle for one lane (batch slot) of a session. Handles are
/// slot indices: released slots are recycled by later admissions, so a
/// handle is only valid until its lane is released.
pub type LaneId = usize;

/// Progress of an in-flight chunked lane prefill
/// ([`AttentionSession::prefill_chunk`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillState {
    /// Prompt tokens appended so far (equals the lane's length while
    /// the prefill is in flight).
    pub consumed: usize,
    /// Full prompt length; the chunk whose append reaches it finishes
    /// the prefill (policy observe/prune run there).
    pub total: usize,
}

/// One batch slot: `heads` paged-cache sequences plus its own length.
struct Lane {
    /// One cache sequence per head (empty once released).
    seqs: Vec<SeqId>,
    /// Tokens appended to this lane so far — the absolute position
    /// counter. Policy eviction shrinks the *cached* token count (see
    /// [`AttentionSession::lane_cached`]) but never this.
    len: usize,
    live: bool,
    /// Eviction-policy state for a policy-budgeted lane.
    policy: Option<LanePolicy>,
    /// In-flight chunked prefill progress; `None` once complete (or for
    /// monolithic [`AttentionSession::prefill_lane`] lanes).
    prefill: Option<PrefillState>,
}

/// Eviction-policy state of one policy-budgeted lane.
struct LanePolicy {
    /// Cached-token cap per head; any head over it is pruned back
    /// under it after the step.
    limit: usize,
    /// Prompt positions whose prefill attention is replayed into
    /// `observe` before the first prune.
    observe_window: usize,
    /// One policy instance per head — heads prune independently, so
    /// their cached lengths may diverge.
    heads: Vec<Box<dyn KvPolicy>>,
    /// Rolling tail of prompt query rows (per head, flattened rows ×
    /// `d`, newest last) a chunked prefill stashes so the final-chunk
    /// observe replay sees exactly the rows a monolithic prefill would.
    /// Trimmed to `observe_window.max(1)` rows; drained at finish.
    q_tail: Vec<Vec<f32>>,
}

/// One live multi-head attention session over a paged KV cache.
pub struct AttentionSession {
    cfg: SessionConfig,
    spec: EngineSpec,
    engine: Box<dyn Engine>,
    scorer: Scorer,
    cache: PagedKvCache,
    /// Batch slots; `cfg.batch` live lanes at construction, grown and
    /// recycled by [`Self::admit_lane`] / [`Self::release_lane`].
    lanes: Vec<Lane>,
    /// Pages returned to the pool by policy pruning since the last
    /// [`Self::take_policy_freed`] drain.
    policy_freed: usize,
    /// Cumulative cache demote/promote counters already reported by
    /// [`Self::take_tier_counts`] (delta-drain watermarks).
    tier_demote_seen: usize,
    tier_promote_seen: usize,
}

impl AttentionSession {
    /// Build a session from a registry spec string.
    pub fn from_spec(spec: &str, cfg: SessionConfig) -> Result<AttentionSession, SpecError> {
        let parsed = parse_spec(spec)?;
        if let Scorer::Sfa { k } = parsed.cache_scorer() {
            if k > cfg.d {
                return Err(SpecError(format!(
                    "{}: feature budget k={k} exceeds head dim d={}",
                    parsed.family(),
                    cfg.d
                )));
            }
        }
        Ok(AttentionSession::new(parsed, cfg))
    }

    /// Panics if the spec's feature budget exceeds `cfg.d` (the
    /// engines' top-k kernels reject k > d); [`Self::from_spec`]
    /// surfaces the same condition as a [`SpecError`].
    pub fn new(spec: EngineSpec, cfg: SessionConfig) -> AttentionSession {
        let scorer = spec.cache_scorer();
        if let Scorer::Sfa { k } = scorer {
            assert!(
                k <= cfg.d,
                "engine feature budget k={k} exceeds head dim d={}",
                cfg.d
            );
        }
        let layout = match scorer {
            Scorer::Dense => SlotLayout::Dense { d: cfg.d, d_v: cfg.d_v },
            Scorer::Sfa { k } => SlotLayout::Sparse { k, d_v: cfg.d_v },
        };
        let mut cache = PagedKvCache::new(cfg.max_pages, cfg.page_size, layout);
        let lanes: Vec<Lane> = (0..cfg.batch)
            .map(|_| Lane {
                seqs: (0..cfg.heads).map(|_| cache.create_seq()).collect(),
                len: 0,
                live: true,
                policy: None,
                prefill: None,
            })
            .collect();
        AttentionSession {
            engine: spec.build(),
            cfg,
            spec,
            scorer,
            cache,
            lanes,
            policy_freed: 0,
            tier_demote_seen: 0,
            tier_promote_seen: 0,
        }
    }

    pub fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    pub fn engine_name(&self) -> String {
        self.engine.name()
    }

    pub fn scorer(&self) -> Scorer {
        self.scorer
    }

    pub fn config(&self) -> &SessionConfig {
        &self.cfg
    }

    /// Tokens cached in the longest lane — under the uniform-batch API
    /// every lane has this length; under the lane API use
    /// [`Self::lane_len`] for per-lane lengths. Consistent with
    /// [`Self::is_empty`] even when some lanes have been released.
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len).max().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn pages_in_use(&self) -> usize {
        self.cache.pages_in_use()
    }

    /// Budget consumed in half-page units (fp32 page = 2, int8 = 1) —
    /// `2 * pages_in_use()` exactly while nothing is demoted.
    pub fn units_in_use(&self) -> usize {
        self.cache.units_in_use()
    }

    /// Pages still allocatable before the cache's budget is exhausted.
    /// Observability only — the serve admission policy budgets through
    /// worst-case *reservations* (so a live wave can never run out),
    /// not through current headroom.
    pub fn pages_free(&self) -> usize {
        self.cache.pages_free()
    }

    pub fn cache_bytes(&self) -> usize {
        self.cache.bytes_in_use()
    }

    // --- Lane lifecycle (continuous batching) --------------------------

    /// Number of live lanes.
    pub fn live_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.live).count()
    }

    /// Tokens cached in one lane. Panics on a released or unknown lane.
    pub fn lane_len(&self, lane: LaneId) -> usize {
        let l = &self.lanes[lane];
        assert!(l.live, "lane {lane} was released");
        l.len
    }

    /// Pages currently mapped by one lane across all its heads
    /// (per-sequence occupancy observability).
    pub fn lane_pages(&self, lane: LaneId) -> usize {
        let l = &self.lanes[lane];
        assert!(l.live, "lane {lane} was released");
        l.seqs.iter().map(|&s| self.cache.seq_pages(s).unwrap_or(0)).sum()
    }

    /// Tokens physically cached for one lane (max across heads) — equal
    /// to [`Self::lane_len`] until a policy evicts, lower afterwards.
    pub fn lane_cached(&self, lane: LaneId) -> usize {
        let l = &self.lanes[lane];
        assert!(l.live, "lane {lane} was released");
        l.seqs.iter().map(|&s| self.cache.seq_len(s).unwrap_or(0)).max().unwrap_or(0)
    }

    /// Drain the count of pages policy pruning has returned to the
    /// pool since the last drain (the scheduler's per-step
    /// `pages_pruned` observability).
    ///
    /// Accounting invariant (pinned by the session tests): pages
    /// counted here are *disjoint* from the pages
    /// [`Self::release_lane`] later reports — a pruned page left the
    /// lane's table when `retain` compacted it, so releasing the lane
    /// never counts it again. Over a lane's whole life,
    /// `Σ policy_freed + release_freed ==
    /// cache.pages_alloc_total() - cache.pages_rebuild_total()`.
    pub fn take_policy_freed(&mut self) -> usize {
        std::mem::take(&mut self.policy_freed)
    }

    /// Pages currently stored int8 across the whole session cache.
    pub fn pages_demoted(&self) -> usize {
        self.cache.pages_demoted()
    }

    /// Worst per-element |dequant − original| / (scale/2) ratio seen by
    /// any demotion so far (`<= 1.0` means within the pinned accuracy
    /// contract of `quantize_rows`).
    pub fn tier_max_error_ratio(&self) -> f32 {
        self.cache.tier_max_error_ratio()
    }

    /// Demote cold pages of every live, prefill-complete lane under the
    /// given tier config. [`TierPolicy::Lru`] keeps the newest
    /// `cold_after` tokens hot per head and demotes every full page
    /// before them; [`TierPolicy::H2o`] asks each head's
    /// [`KvPolicy::demote`] verdict for the cold token set (falling
    /// back to the LRU cutoff on policy-free lanes). Only whole pages
    /// ever change tier; partially-cold pages stay hot. Returns pages
    /// demoted this pass.
    pub fn demote_cold(&mut self, tier: KvTierCfg) -> usize {
        let mut demoted = 0;
        for lane in 0..self.lanes.len() {
            if !self.lanes[lane].live || self.lanes[lane].prefill.is_some() {
                continue;
            }
            for h in 0..self.cfg.heads {
                let seq = self.lanes[lane].seqs[h];
                let use_policy = tier.policy == TierPolicy::H2o
                    && self.lanes[lane].policy.is_some();
                if use_policy {
                    let cached = self.cache.seq_len(seq).expect("lane sequence exists");
                    let cold = self.lanes[lane]
                        .policy
                        .as_mut()
                        .expect("checked above")
                        .heads[h]
                        .demote(cached);
                    if !cold.is_empty() {
                        demoted += self.cache.demote_token_set(seq, &cold).unwrap_or(0);
                    }
                } else {
                    demoted += self.cache.demote_pages(seq, tier.cold_after).unwrap_or(0);
                }
            }
        }
        demoted
    }

    /// Drain the (demotions, promotions) performed since the last call
    /// — the per-step deltas surfaced as `StepReport::pages_demoted` /
    /// `pages_promoted`. Promotions include copy-on-write dequants of
    /// shared cold pages, so the counters track *work done*, not just
    /// explicit tier flips.
    pub fn take_tier_counts(&mut self) -> (usize, usize) {
        let d = self.cache.pages_demote_total() - self.tier_demote_seen;
        let p = self.cache.pages_promote_total() - self.tier_promote_seen;
        self.tier_demote_seen += d;
        self.tier_promote_seen += p;
        (d, p)
    }

    /// Admit a new empty lane (recycling a released slot when one
    /// exists), creating one paged-cache sequence per head. Page
    /// allocation is deferred to the first appended token, so admission
    /// itself never fails — budget checks belong to the caller's
    /// admission policy (see `serve::ContinuousBatcher`).
    pub fn admit_lane(&mut self) -> LaneId {
        let lane = Lane {
            seqs: (0..self.cfg.heads).map(|_| self.cache.create_seq()).collect(),
            len: 0,
            live: true,
            policy: None,
            prefill: None,
        };
        match self.lanes.iter().position(|l| !l.live) {
            Some(slot) => {
                self.lanes[slot] = lane;
                slot
            }
            None => {
                self.lanes.push(lane);
                self.lanes.len() - 1
            }
        }
    }

    /// Admit a lane seeded from a cached prompt prefix: each head's
    /// sequence is a [`PagedKvCache::fork_prefix`] of `src[h]` at
    /// `prefix_tokens`, sharing the prefix pages instead of re-storing
    /// (or re-computing) them. The lane starts at `len ==
    /// prefix_tokens`; follow with [`Self::extend_lane`] for the
    /// prompt suffix. Forking allocates nothing, so this never runs
    /// out of pages. The radix prefix cache's hit path
    /// (`serve::ContinuousBatcher`) drives this.
    pub fn admit_lane_from_fork(
        &mut self,
        src: &[SeqId],
        prefix_tokens: usize,
    ) -> Result<LaneId, PageError> {
        assert_eq!(src.len(), self.cfg.heads, "one source sequence per head");
        let mut seqs = Vec::with_capacity(self.cfg.heads);
        for &s in src {
            seqs.push(self.cache.fork_prefix(s, prefix_tokens)?);
        }
        let lane = Lane { seqs, len: prefix_tokens, live: true, policy: None, prefill: None };
        Ok(match self.lanes.iter().position(|l| !l.live) {
            Some(slot) => {
                self.lanes[slot] = lane;
                slot
            }
            None => {
                self.lanes.push(lane);
                self.lanes.len() - 1
            }
        })
    }

    /// Append `k.n` tokens of K/V (batch-1 tensors) to a lane without
    /// running an engine forward — the prefix-cache hit path stores the
    /// prompt suffix with exactly the same per-token payloads
    /// [`Self::prefill_lane`] would have produced, so the cache bytes
    /// (and every downstream decode) are bit-identical to a cold
    /// prefill of the whole prompt. On a page-budget error the lane is
    /// auto-released, mirroring `prefill_lane`.
    pub fn extend_lane(
        &mut self,
        lane: LaneId,
        k: &HeadTensor,
        v: &HeadTensor,
    ) -> Result<(), PageError> {
        assert_eq!((k.batch, v.batch), (1, 1), "extend_lane takes batch-1 tensors");
        assert_eq!((k.heads, v.heads), (self.cfg.heads, self.cfg.heads));
        assert_eq!((k.d, v.d), (self.cfg.d, self.cfg.d_v));
        assert_eq!(k.n, v.n, "k/v length");
        assert!(self.lanes[lane].live, "lane {lane} was released");
        assert!(
            self.lanes[lane].policy.is_none(),
            "extend_lane does not drive policy observation (prefix cache runs policy-free)"
        );
        for h in 0..self.cfg.heads {
            let seq = self.lanes[lane].seqs[h];
            for t in 0..k.n {
                if let Err(e) = self.push_token(seq, k.head_row(0, h, t), v.head_row(0, h, t)) {
                    let _ = self.release_lane(lane);
                    return Err(e);
                }
            }
        }
        self.lanes[lane].len += k.n;
        Ok(())
    }

    /// Score a batch-1 single-row query against a lane's full cached
    /// sequence, per head — the serve stack's first-token output (the
    /// same scorer/softmax path as [`Self::decode_step_lanes`], minus
    /// the append). Because it reads only cache bytes, a lane seeded
    /// from a cached prefix and a cold-prefilled lane produce
    /// bit-identical outputs, which is what makes the prefix cache's
    /// greedy streams exactly equal to cold runs.
    pub fn lane_last_output(&self, lane: LaneId, q: &HeadTensor) -> HeadTensor {
        assert_eq!((q.batch, q.n), (1, 1), "lane_last_output takes one query row");
        assert_eq!(q.heads, self.cfg.heads);
        assert_eq!(q.d, self.cfg.d);
        let l = &self.lanes[lane];
        assert!(l.live, "lane {lane} was released");
        let mut out = HeadTensor::zeros(1, self.cfg.heads, 1, self.cfg.d_v);
        for h in 0..self.cfg.heads {
            let seq = l.seqs[h];
            let mut row = vec![0f32; self.cfg.d_v];
            self.decode_head(seq, q.head_row(0, h, 0), &mut row, None);
            out.head_row_mut(0, h, 0).copy_from_slice(&row);
        }
        out
    }

    /// Chunked-prefill outputs for a run of already-cached queries:
    /// row `t` of `q` (batch-1, `n` suffix rows) is scored causally
    /// against the lane's first `start_pos + t + 1` cached tokens —
    /// the compute shape of a real KV-append prefill kernel, which is
    /// what the prefix-cache hit path pays instead of a full-prompt
    /// forward.
    ///
    /// Both scorer families run a tiled KV-append kernel (online
    /// softmax) over payloads rebuilt from the cache: the Sfa scorer
    /// runs [`FlashSfa::forward_codes_append`] over reconstructed
    /// top-k codes (exact skip mode), the Dense scorer runs
    /// [`FlashDense::forward_append`] over the dense key slots — no
    /// per-token scalar loop on either path. Row `n - 1` matches
    /// [`Self::lane_last_output`] within f32 summation-order
    /// tolerance. Greedy serve streams never depend on either: the
    /// scheduler samples the first token from `lane_last_output`.
    pub fn chunked_prefill_outputs(
        &self,
        lane: LaneId,
        q: &HeadTensor,
        start_pos: usize,
    ) -> HeadTensor {
        assert_eq!(q.batch, 1, "chunked_prefill_outputs takes batch-1 tensors");
        assert_eq!(q.heads, self.cfg.heads);
        assert_eq!(q.d, self.cfg.d);
        let l = &self.lanes[lane];
        assert!(l.live, "lane {lane} was released");
        assert!(start_pos + q.n <= l.len, "suffix rows must already be cached");
        let d_v = self.cfg.d_v;
        let v_off = match self.scorer {
            Scorer::Dense => self.cfg.d,
            Scorer::Sfa { k } => k + k.div_ceil(2),
        };
        let mut out = HeadTensor::zeros(1, self.cfg.heads, q.n, d_v);
        match self.scorer {
            Scorer::Dense => {
                // Tiled KV-append kernel: rebuild dense K and V from the
                // slot payloads and run the FlashDense append kernel
                // (online softmax, query row `t` masked to keys
                // `0..=start_pos + t`) instead of a per-token two-pass
                // scalar loop over the prefix.
                let (bq, bk) = match self.spec {
                    EngineSpec::FlashDense { bq, bk } => (bq, bk),
                    _ => (64, 64),
                };
                let eng = FlashDense { block_q: bq, block_k: bk, threads: default_threads() };
                for h in 0..self.cfg.heads {
                    let mut scratch = TierScratch::new();
                    let slots = self
                        .cache
                        .token_slices_tiered(l.seqs[h], &mut scratch)
                        .expect("lane sequence exists");
                    let total = slots.len();
                    let mut kmat = Matrix::zeros(total, self.cfg.d);
                    let mut vmat = Matrix::zeros(total, d_v);
                    for (j, slot) in slots.iter().enumerate() {
                        kmat.row_mut(j).copy_from_slice(&slot[..self.cfg.d]);
                        vmat.row_mut(j).copy_from_slice(&slot[v_off..v_off + d_v]);
                    }
                    let mut qm = Matrix::zeros(q.n, self.cfg.d);
                    for t in 0..q.n {
                        qm.row_mut(t).copy_from_slice(q.head_row(0, h, t));
                    }
                    let o = eng.forward_append(&qm, &kmat, &vmat, start_pos);
                    for t in 0..q.n {
                        out.head_row_mut(0, h, t).copy_from_slice(o.row(t));
                    }
                }
            }
            Scorer::Sfa { k } => {
                // Tiled KV-append kernel: rebuild the cached top-k key
                // codes + dense V from the sparse slot payloads, top-k
                // the suffix queries, and run the block-skipping
                // FlashSFA append kernel (exact mode) instead of a
                // per-token scalar loop.
                let (bq, bk) = match self.spec {
                    EngineSpec::FlashSfa { bq, bk, .. } => (bq, bk),
                    _ => (64, 64),
                };
                let eng = FlashSfa {
                    k,
                    block_q: bq,
                    block_k: bk,
                    threads: default_threads(),
                    skip: true,
                    skip_thresh: 0.0,
                    skip_mass: 0.0,
                };
                for h in 0..self.cfg.heads {
                    let mut scratch = TierScratch::new();
                    let slots = self
                        .cache
                        .token_slices_tiered(l.seqs[h], &mut scratch)
                        .expect("lane sequence exists");
                    let total = slots.len();
                    let mut kvals = Vec::with_capacity(total * k);
                    let mut kidx = Vec::with_capacity(total * k);
                    let mut vmat = Matrix::zeros(total, d_v);
                    for (j, slot) in slots.iter().enumerate() {
                        kvals.extend_from_slice(&slot[..k]);
                        for pos in 0..k {
                            let pair = unpack_idx(slot[k + pos / 2]);
                            kidx.push(if pos % 2 == 0 { pair.0 } else { pair.1 });
                        }
                        vmat.row_mut(j).copy_from_slice(&slot[v_off..v_off + d_v]);
                    }
                    let kcodes =
                        TopkCodes { rows: total, dim: self.cfg.d, k, vals: kvals, idx: kidx };
                    let kfeat = CscFeat::from_codes(&kcodes);
                    let mut qm = Matrix::zeros(q.n, self.cfg.d);
                    for t in 0..q.n {
                        qm.row_mut(t).copy_from_slice(q.head_row(0, h, t));
                    }
                    let qcodes = topk_codes(&qm, k);
                    let o = eng.forward_codes_append(&qcodes, &kfeat, &vmat, self.cfg.d, start_pos);
                    for t in 0..q.n {
                        out.head_row_mut(0, h, t).copy_from_slice(o.row(t));
                    }
                }
            }
        }
        out
    }

    /// Fork the first `n_tokens` of every head-sequence of a live lane
    /// (no pages copied or allocated) — the radix cache's insert path,
    /// run at retirement right before the lane is released.
    pub fn fork_lane_prefix(
        &mut self,
        lane: LaneId,
        n_tokens: usize,
    ) -> Result<Vec<SeqId>, PageError> {
        assert!(self.lanes[lane].live, "lane {lane} was released");
        let srcs = self.lanes[lane].seqs.clone();
        let mut out = Vec::with_capacity(srcs.len());
        for s in srcs {
            out.push(self.cache.fork_prefix(s, n_tokens)?);
        }
        Ok(out)
    }

    /// The lane's backing cache sequences, one per head (prefix-cache
    /// plumbing).
    pub fn lane_seqs(&self, lane: LaneId) -> &[SeqId] {
        let l = &self.lanes[lane];
        assert!(l.live, "lane {lane} was released");
        &l.seqs
    }

    /// Crate-internal access to the backing paged cache, for the radix
    /// prefix cache living beside the session in a serve engine group.
    pub(crate) fn cache_mut(&mut self) -> &mut PagedKvCache {
        &mut self.cache
    }

    /// Admit a policy-budgeted lane: like [`Self::admit_lane`], plus
    /// one [`KvPolicy`] per head that physically prunes the lane's
    /// pages back under `spec`'s token budget after prefill and
    /// between decode steps (freed pages go straight back to the pool,
    /// which is what lets a scheduler reserve the policy budget
    /// instead of the worst-case `prompt + max_new` footprint).
    pub fn admit_lane_with_policy(&mut self, spec: &PagedKvPolicy) -> LaneId {
        let lane = self.admit_lane();
        self.lanes[lane].policy = Some(LanePolicy {
            limit: spec.max_cached_tokens(self.cfg.page_size),
            observe_window: spec.observe_window(),
            heads: (0..self.cfg.heads)
                .map(|_| spec.build(self.cfg.d, self.cfg.page_size))
                .collect(),
            q_tail: vec![Vec::new(); self.cfg.heads],
        });
        lane
    }

    /// Release a lane mid-wave, freeing its pages immediately; returns
    /// how many pages went back to the budget. The handle becomes
    /// invalid (its slot is recycled by the next [`Self::admit_lane`]).
    /// Pages a policy prune already returned to the pool
    /// ([`Self::take_policy_freed`]) are not in the lane's table any
    /// more and are never re-counted here.
    pub fn release_lane(&mut self, lane: LaneId) -> Result<usize, PageError> {
        let l = self.lanes.get_mut(lane).ok_or(PageError::UnknownSeq)?;
        if !l.live {
            return Err(PageError::UnknownSeq);
        }
        l.live = false;
        l.len = 0;
        l.policy = None;
        l.prefill = None;
        let seqs = std::mem::take(&mut l.seqs);
        let mut freed = 0;
        for s in seqs {
            freed += self.cache.free(s)?;
        }
        Ok(freed)
    }

    fn check_shapes(&self, q: &HeadTensor, k: &HeadTensor, v: &HeadTensor) {
        assert_eq!((q.batch, q.heads), (self.cfg.batch, self.cfg.heads), "q head grid");
        assert_eq!((k.batch, k.heads), (self.cfg.batch, self.cfg.heads), "k head grid");
        assert_eq!((v.batch, v.heads), (self.cfg.batch, self.cfg.heads), "v head grid");
        assert_eq!(q.d, self.cfg.d, "q feature dim");
        assert_eq!(k.d, self.cfg.d, "k feature dim");
        assert_eq!(v.d, self.cfg.d_v, "v feature dim");
        assert_eq!(k.n, v.n, "k/v length");
    }

    /// Append one token's K/V payload to one head-sequence.
    fn push_token(&mut self, seq: SeqId, key: &[f32], val: &[f32]) -> Result<(), PageError> {
        debug_assert_eq!(key.len(), self.cfg.d);
        debug_assert_eq!(val.len(), self.cfg.d_v);
        let payload = match self.cache.layout {
            SlotLayout::Dense { .. } => {
                let mut p = Vec::with_capacity(self.cfg.d + self.cfg.d_v);
                p.extend_from_slice(key);
                p.extend_from_slice(val);
                p
            }
            SlotLayout::Sparse { k, .. } => {
                let (vals, idx) = topk_row(key, k);
                let mut p = Vec::with_capacity(k + k.div_ceil(2) + self.cfg.d_v);
                p.extend_from_slice(&vals);
                for pair in idx.chunks(2) {
                    p.push(pack_idx(pair[0], if pair.len() > 1 { pair[1] } else { 0 }));
                }
                p.extend_from_slice(val);
                p
            }
        };
        self.cache.append(seq, &payload)
    }

    /// Prefill `k.n` tokens: appends every K/V token into the paged
    /// cache, then runs the engine's multi-head batched forward. Must
    /// be the first call on a fresh session — the forward only attends
    /// within this prefill, so a second prefill's outputs would
    /// silently ignore the already-cached prefix.
    pub fn prefill(
        &mut self,
        q: &HeadTensor,
        k: &HeadTensor,
        v: &HeadTensor,
        causal: bool,
    ) -> Result<HeadTensor, PageError> {
        assert!(
            self.is_empty(),
            "prefill must be the first call on a fresh session \
             (chunked prefill is not supported yet — use decode_step)"
        );
        assert!(
            self.lanes.len() == self.cfg.batch && self.lanes.iter().all(|l| l.live),
            "uniform-batch prefill requires the construction-time lanes, all live \
             (use prefill_lane under a lane scheduler)"
        );
        self.check_shapes(q, k, v);
        for i in 0..self.cfg.batch * self.cfg.heads {
            let (b, h) = (i / self.cfg.heads, i % self.cfg.heads);
            let seq = self.lanes[b].seqs[h];
            for t in 0..k.n {
                self.push_token(seq, k.head_row(b, h, t), v.head_row(b, h, t))?;
            }
        }
        for lane in &mut self.lanes {
            lane.len += k.n;
        }
        Ok(self.engine.forward_batched(q, k, v, causal))
    }

    /// Prefill one lane's prompt (`q`/`k`/`v` with `batch == 1`):
    /// appends every token's K/V into the lane's paged sequences, then
    /// runs the engine's batched forward over just this lane. Lanes
    /// prefill independently, so mixed prompt lengths coexist in one
    /// session and the outputs are bit-identical to a solo run of the
    /// same prompt regardless of what the other lanes are doing.
    ///
    /// On a page-budget error the lane is **auto-released** (its
    /// partially appended prefix would otherwise silently corrupt a
    /// retry) and the handle becomes invalid; the error carries the
    /// cause.
    pub fn prefill_lane(
        &mut self,
        lane: LaneId,
        q: &HeadTensor,
        k: &HeadTensor,
        v: &HeadTensor,
        causal: bool,
    ) -> Result<HeadTensor, PageError> {
        assert_eq!(q.batch, 1, "prefill_lane takes batch-1 tensors");
        assert_eq!((k.batch, v.batch), (1, 1), "prefill_lane takes batch-1 tensors");
        assert_eq!((q.heads, k.heads, v.heads), (self.cfg.heads, self.cfg.heads, self.cfg.heads));
        assert_eq!((q.d, k.d, v.d), (self.cfg.d, self.cfg.d, self.cfg.d_v));
        assert_eq!(k.n, v.n, "k/v length");
        assert!(self.lanes[lane].live, "lane {lane} was released");
        assert_eq!(self.lanes[lane].len, 0, "lane {lane} is already prefilled");
        for h in 0..self.cfg.heads {
            let seq = self.lanes[lane].seqs[h];
            for t in 0..k.n {
                if let Err(e) = self.push_token(seq, k.head_row(0, h, t), v.head_row(0, h, t)) {
                    let _ = self.release_lane(lane);
                    return Err(e);
                }
            }
        }
        self.lanes[lane].len = k.n;
        if self.lanes[lane].policy.is_some() {
            self.seed_lane_policy(lane, q, k, causal);
        }
        Ok(self.engine.forward_batched(q, k, v, causal))
    }

    /// In-flight chunked prefill progress of a lane; `None` once the
    /// prefill completed (or for monolithic [`Self::prefill_lane`]
    /// lanes, which never enter the chunked path).
    pub fn lane_prefill_state(&self, lane: LaneId) -> Option<PrefillState> {
        let l = &self.lanes[lane];
        assert!(l.live, "lane {lane} was released");
        l.prefill
    }

    /// Append one causal prompt **chunk** (batch-1 tensors, `k.n`
    /// tokens) to a lane mid-prefill and return the chunk's attention
    /// outputs, computed against the full cached prefix through the
    /// tiled KV-append kernels ([`Self::chunked_prefill_outputs`] —
    /// [`FlashSfa::forward_codes_append`] / `FlashDense::forward_append`).
    /// `total` is the full prompt length; the call whose append reaches
    /// it finishes the prefill. The first chunk may start at a non-zero
    /// lane length (the radix prefix cache's hit path: fork the shared
    /// prefix, then chunk through the un-shared suffix).
    ///
    /// Cache bytes after the final chunk are bit-identical to a
    /// monolithic [`Self::prefill_lane`] of the same prompt — appends
    /// store the same per-token payloads in the same per-sequence
    /// order — so every downstream decode (and the scheduler's
    /// first-token [`Self::lane_last_output`]) is bitwise independent
    /// of the chunking. Policy lanes ingest each chunk's keys as they
    /// append and stash the tail of prompt queries; the final chunk
    /// replays the last `observe_window` queries' attention over the
    /// (complete) cache and prunes — the exact call sequence
    /// [`Self::seed_lane_policy`] makes, so policy state and prune
    /// selection are also bitwise chunk-invariant. Chunk outputs are
    /// computed *before* the finishing prune, preserving "row `t`
    /// attends the whole prefix".
    ///
    /// On a page-budget error the lane is auto-released (previously
    /// appended chunks included), mirroring `prefill_lane`'s contract.
    pub fn prefill_chunk(
        &mut self,
        lane: LaneId,
        q: &HeadTensor,
        k: &HeadTensor,
        v: &HeadTensor,
        total: usize,
    ) -> Result<HeadTensor, PageError> {
        assert_eq!(q.batch, 1, "prefill_chunk takes batch-1 tensors");
        assert_eq!((k.batch, v.batch), (1, 1), "prefill_chunk takes batch-1 tensors");
        assert_eq!((q.heads, k.heads, v.heads), (self.cfg.heads, self.cfg.heads, self.cfg.heads));
        assert_eq!((q.d, k.d, v.d), (self.cfg.d, self.cfg.d, self.cfg.d_v));
        assert_eq!((q.n, v.n), (k.n, k.n), "one q/v row per chunk token");
        assert!(k.n > 0, "prefill_chunk takes a non-empty chunk");
        assert!(self.lanes[lane].live, "lane {lane} was released");
        let start = self.lanes[lane].len;
        match self.lanes[lane].prefill {
            None => assert!(
                start + k.n <= total,
                "lane {lane}: first chunk {start}+{} overruns prompt length {total}",
                k.n
            ),
            Some(st) => {
                assert_eq!(st.total, total, "lane {lane}: prompt length changed mid-prefill");
                assert_eq!(st.consumed, start, "lane {lane}: chunk progress out of sync");
                assert!(
                    start + k.n <= total,
                    "lane {lane}: chunk {start}+{} overruns prompt length {total}",
                    k.n
                );
            }
        }
        for h in 0..self.cfg.heads {
            let seq = self.lanes[lane].seqs[h];
            for t in 0..k.n {
                if let Err(e) = self.push_token(seq, k.head_row(0, h, t), v.head_row(0, h, t)) {
                    let _ = self.release_lane(lane);
                    return Err(e);
                }
            }
        }
        self.lanes[lane].len = start + k.n;
        if self.lanes[lane].policy.is_some() {
            let window = {
                let pol = self.lanes[lane].policy.as_ref().expect("checked above");
                pol.observe_window.max(1)
            };
            let d = self.cfg.d;
            let pol = self.lanes[lane].policy.as_mut().expect("checked above");
            for h in 0..self.cfg.heads {
                for t in 0..k.n {
                    pol.heads[h].ingest_key(start + t, k.head_row(0, h, t));
                }
                let tail = &mut pol.q_tail[h];
                for t in 0..q.n {
                    tail.extend_from_slice(q.head_row(0, h, t));
                }
                let rows = tail.len() / d;
                if rows > window {
                    tail.drain(..(rows - window) * d);
                }
            }
        }
        let done = start + k.n == total;
        self.lanes[lane].prefill =
            (!done).then_some(PrefillState { consumed: start + k.n, total });
        let out = self.chunked_prefill_outputs(lane, q, start);
        if done && self.lanes[lane].policy.is_some() {
            self.finish_lane_policy(lane);
        }
        Ok(out)
    }

    /// Final-chunk policy hook — the chunked twin of
    /// [`Self::seed_lane_policy`]: replay the attention of the stashed
    /// last `observe_window` prompt queries over the now-complete
    /// cache, set the final query, observe, and prune. Keys were
    /// already ingested chunk-by-chunk in the same absolute order a
    /// monolithic seed would ingest them, and the replay reads only
    /// cached slots, so the policy sees a call sequence bitwise
    /// identical to the monolithic path's.
    fn finish_lane_policy(&mut self, lane: LaneId) {
        let n = self.lanes[lane].len;
        if n == 0 {
            return;
        }
        let d = self.cfg.d;
        let window =
            self.lanes[lane].policy.as_ref().expect("policy lane").observe_window.min(n);
        for h in 0..self.cfg.heads {
            let seq = self.lanes[lane].seqs[h];
            let (tail, rows) = {
                let pol = self.lanes[lane].policy.as_ref().expect("policy lane");
                let tail = pol.q_tail[h].clone();
                let rows = tail.len() / d;
                (tail, rows)
            };
            assert!(rows >= window.max(1).min(n), "q tail must cover the observe window");
            let mut scratch = TierScratch::new();
            let slots =
                self.cache.token_slices_tiered(seq, &mut scratch).expect("lane sequence exists");
            let mut observed: Vec<Vec<(u32, f32)>> = Vec::with_capacity(window);
            for i in rows - window..rows {
                // Chunked prefill is causal: replay query at absolute
                // position p against keys 0..=p, matching
                // seed_lane_policy's causal branch.
                let p = n - rows + i;
                let scores = self.head_scores(&slots[..p + 1], &tail[i * d..(i + 1) * d]);
                observed.push(softmax_probs(&scores));
            }
            drop(slots);
            let pol = self.lanes[lane].policy.as_mut().expect("policy lane");
            pol.heads[h].set_query(&tail[(rows - 1) * d..rows * d]);
            for probs in &observed {
                pol.heads[h].observe(probs);
            }
            pol.q_tail[h].clear();
        }
        self.prune_lane(lane);
    }

    /// Post-prefill policy hook: feed every cached key and the final
    /// prompt query to the per-head policies, replay the attention of
    /// the last `observe_window` prompt queries into `observe` (the
    /// SnapKV pooling window; it also seeds H2O's cumulative mass —
    /// skipped entirely for observation-free policies like Quest),
    /// then prune the lane back under its budget before it joins the
    /// decode wave — so a long prompt's pages are a prefill-time
    /// transient, not a lifetime reservation.
    fn seed_lane_policy(&mut self, lane: LaneId, q: &HeadTensor, k: &HeadTensor, causal: bool) {
        let n = k.n;
        if n == 0 {
            return; // nothing cached, nothing to observe or prune
        }
        let window =
            self.lanes[lane].policy.as_ref().expect("policy lane").observe_window.min(n);
        for h in 0..self.cfg.heads {
            let seq = self.lanes[lane].seqs[h];
            let mut scratch = TierScratch::new();
            let slots =
                self.cache.token_slices_tiered(seq, &mut scratch).expect("lane sequence exists");
            let mut observed: Vec<Vec<(u32, f32)>> = Vec::with_capacity(window);
            for p in n - window..n {
                // Match the prefill's masking: causal query p sees keys
                // 0..=p, a non-causal one sees the whole prompt.
                let upto = if causal { p + 1 } else { n };
                let scores = self.head_scores(&slots[..upto], q.head_row(0, h, p));
                observed.push(softmax_probs(&scores));
            }
            drop(slots);
            let pol = self.lanes[lane].policy.as_mut().expect("policy lane");
            for t in 0..n {
                pol.heads[h].ingest_key(t, k.head_row(0, h, t));
            }
            pol.heads[h].set_query(q.head_row(0, h, n - 1));
            for probs in &observed {
                pol.heads[h].observe(probs);
            }
        }
        self.prune_lane(lane);
    }

    /// Prune one policy lane back under its token budget: each
    /// over-budget head's policy selects the survivors, the cache
    /// physically evicts the rest ([`PagedKvCache::retain`] — whole
    /// pages return to the pool), and the policy remaps its statistics
    /// onto the compacted coordinates. Returns the pages freed (also
    /// accumulated for [`Self::take_policy_freed`]). No-op for lanes
    /// without a policy or under budget — the no-op-budget guarantee.
    pub fn prune_lane(&mut self, lane: LaneId) -> usize {
        assert!(self.lanes[lane].live, "lane {lane} was released");
        if self.lanes[lane].policy.is_none() {
            return 0;
        }
        let mut freed = 0;
        for h in 0..self.cfg.heads {
            let l = &mut self.lanes[lane];
            let pol = l.policy.as_mut().expect("checked above");
            let seq = l.seqs[h];
            let cached = self.cache.seq_len(seq).expect("lane sequence exists");
            if cached <= pol.limit {
                continue;
            }
            let keep = pol.heads[h].select(cached);
            let keep_pos: Vec<usize> = keep.iter().map(|&j| j as usize).collect();
            match self.cache.retain(seq, &keep_pos) {
                Ok(f) => {
                    freed += f;
                    pol.heads[h].compact(&keep);
                }
                // Fork-shared pages with an exhausted pool: leave this
                // head unpruned (eviction is an optimization, not a
                // correctness requirement).
                Err(_) => continue,
            }
        }
        self.policy_freed += freed;
        freed
    }

    /// One decode step: append the new token's K/V for every head, then
    /// score each head's 1-row query against its full cached sequence
    /// (the new token attends to everything up to and including
    /// itself — the causal TTNT semantics).
    pub fn decode_step(
        &mut self,
        q: &HeadTensor,
        k: &HeadTensor,
        v: &HeadTensor,
    ) -> Result<HeadTensor, PageError> {
        self.check_shapes(q, k, v);
        assert!(
            self.lanes.len() == self.cfg.batch && self.lanes.iter().all(|l| l.live),
            "uniform-batch decode_step requires the construction-time lanes, all live \
             (use decode_step_lanes under a lane scheduler)"
        );
        let all: Vec<LaneId> = (0..self.cfg.batch).collect();
        self.decode_step_lanes(&all, q, k, v)
    }

    /// One decode step over an arbitrary subset of live lanes: batch
    /// row `i` of `q`/`k`/`v` belongs to `lanes[i]`. Appends each
    /// lane's new token and scores its 1-row query against that lane's
    /// full cached sequence (lanes may be at different lengths). Every
    /// `(lane, head)` pair is scored independently in parallel, so a
    /// lane's output does not depend on which other lanes share the
    /// step — the bit-for-bit guarantee the serve equivalence tests
    /// pin.
    pub fn decode_step_lanes(
        &mut self,
        lanes: &[LaneId],
        q: &HeadTensor,
        k: &HeadTensor,
        v: &HeadTensor,
    ) -> Result<HeadTensor, PageError> {
        assert!(!lanes.is_empty(), "decode_step_lanes needs at least one lane");
        assert_eq!(q.batch, lanes.len(), "one q row per lane");
        assert_eq!((k.batch, v.batch), (lanes.len(), lanes.len()), "one k/v row per lane");
        assert_eq!((q.heads, k.heads, v.heads), (self.cfg.heads, self.cfg.heads, self.cfg.heads));
        assert_eq!((q.d, k.d, v.d), (self.cfg.d, self.cfg.d, self.cfg.d_v));
        assert_eq!((q.n, k.n, v.n), (1, 1, 1), "decode takes exactly one new token per lane");
        let heads = self.cfg.heads;
        // (lane-batch-index, head) -> cache sequence, gathered before
        // the appends so the parallel scoring below only reads.
        let mut seqs: Vec<SeqId> = Vec::with_capacity(lanes.len() * heads);
        for (bi, &lane) in lanes.iter().enumerate() {
            assert!(self.lanes[lane].live, "lane {lane} was released");
            assert!(
                self.lanes[lane].prefill.is_none(),
                "lane {lane} has an unfinished chunked prefill"
            );
            for h in 0..heads {
                let seq = self.lanes[lane].seqs[h];
                self.push_token(seq, k.head_row(bi, h, 0), v.head_row(bi, h, 0))?;
                seqs.push(seq);
            }
            self.lanes[lane].len += 1;
        }

        // Policy lanes: feed the step's key/query to each head's policy
        // and size the per-(lane, head) probability ranges the scoring
        // loop fills for observation. Plain lanes keep zero-length
        // ranges and take the exact same scoring path with no buffer.
        let bh = lanes.len() * heads;
        let mut probs_len = vec![0usize; bh];
        for (bi, &lane) in lanes.iter().enumerate() {
            if self.lanes[lane].policy.is_none() {
                continue;
            }
            for h in 0..heads {
                probs_len[bi * heads + h] =
                    self.cache.seq_len(seqs[bi * heads + h]).expect("just appended");
            }
            let pol = self.lanes[lane].policy.as_mut().expect("checked above");
            for h in 0..heads {
                pol.heads[h].ingest_key(probs_len[bi * heads + h] - 1, k.head_row(bi, h, 0));
                pol.heads[h].set_query(q.head_row(bi, h, 0));
            }
        }
        let mut offsets = vec![0usize; bh + 1];
        for i in 0..bh {
            offsets[i + 1] = offsets[i] + probs_len[i];
        }
        let mut probs_buf = vec![0f32; offsets[bh]];

        let mut out = HeadTensor::zeros(lanes.len(), heads, 1, self.cfg.d_v);
        let hv = self.cfg.d_v;
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let probs_ptr = SendPtr(probs_buf.as_mut_ptr());
        let this: &AttentionSession = self;
        let seqs_ref = &seqs;
        let probs_len_ref = &probs_len;
        let offsets_ref = &offsets;
        let threads = default_threads().min(bh.max(1));
        parallel_for_dynamic(bh, threads, 1, move |i| {
            let (bi, h) = (i / heads, i % heads);
            // SAFETY: each (lane, head) owns a disjoint output range,
            // and a disjoint probability range when one was sized.
            let dst =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(i * hv), hv) };
            let probs = (probs_len_ref[i] > 0).then(|| unsafe {
                std::slice::from_raw_parts_mut(
                    probs_ptr.get().add(offsets_ref[i]),
                    probs_len_ref[i],
                )
            });
            this.decode_head(seqs_ref[i], q.head_row(bi, h, 0), dst, probs);
        });

        // Feed the observed attention mass back to the policies, then
        // prune any lane that drifted over its budget (freed pages
        // return to the pool mid-wave; take_policy_freed drains the
        // count).
        for (bi, &lane) in lanes.iter().enumerate() {
            if self.lanes[lane].policy.is_none() {
                continue;
            }
            for h in 0..heads {
                let i = bi * heads + h;
                let pairs: Vec<(u32, f32)> = probs_buf
                    [offsets[i]..offsets[i] + probs_len[i]]
                    .iter()
                    .enumerate()
                    .map(|(j, &p)| (j as u32, p))
                    .collect();
                self.lanes[lane].policy.as_mut().expect("checked above").heads[h]
                    .observe(&pairs);
            }
            self.prune_lane(lane);
        }
        Ok(out)
    }

    /// Multi-position verify step for speculative decoding: append `n`
    /// new tokens' K/V per lane (batch row `i` of `q`/`k`/`v` belongs
    /// to `lanes[i]`), then score query row `t` against the lane's
    /// cached prefix up to and including appended token `t`.
    ///
    /// The output is **bit-for-bit** what `n` sequential
    /// [`Self::decode_step_lanes`] calls would have produced: every
    /// position runs the same [`Self::head_scores`] +
    /// `softmax_weighted_sum` scalar kernel over the same slot prefix.
    /// That exactness is deliberate — the tiled append kernels behind
    /// [`Self::chunked_prefill_outputs`] fold their online softmax in
    /// tile order and are only tolerance-equal, which would break the
    /// speculation-on/off greedy stream pin. The batch dimension only
    /// adds parallelism, never changes a lane's result.
    ///
    /// Speculation runs policy-free (a KV policy observes exactly one
    /// position per decode step; a multi-position verify would feed it
    /// a different call sequence), so policy lanes are rejected.
    ///
    /// On a page-budget error the failing lane is auto-released
    /// (mirroring [`Self::extend_lane`]); lanes earlier in the batch
    /// keep their appended rows — callers on the speculative path
    /// release the forked verify lane on any error anyway.
    pub fn score_lanes(
        &mut self,
        lanes: &[LaneId],
        q: &HeadTensor,
        k: &HeadTensor,
        v: &HeadTensor,
    ) -> Result<HeadTensor, PageError> {
        assert!(!lanes.is_empty(), "score_lanes needs at least one lane");
        assert_eq!(q.batch, lanes.len(), "one q batch row per lane");
        assert_eq!((k.batch, v.batch), (lanes.len(), lanes.len()), "one k/v batch row per lane");
        assert_eq!((q.heads, k.heads, v.heads), (self.cfg.heads, self.cfg.heads, self.cfg.heads));
        assert_eq!((q.d, k.d, v.d), (self.cfg.d, self.cfg.d, self.cfg.d_v));
        assert_eq!((k.n, v.n), (q.n, q.n), "one k/v row per scored position");
        assert!(q.n > 0, "score_lanes needs at least one position");
        let heads = self.cfg.heads;
        let n = q.n;
        let mut seqs: Vec<SeqId> = Vec::with_capacity(lanes.len() * heads);
        let mut base: Vec<usize> = Vec::with_capacity(lanes.len());
        for (bi, &lane) in lanes.iter().enumerate() {
            assert!(self.lanes[lane].live, "lane {lane} was released");
            assert!(
                self.lanes[lane].prefill.is_none(),
                "lane {lane} has an unfinished chunked prefill"
            );
            assert!(
                self.lanes[lane].policy.is_none(),
                "score_lanes does not drive policy observation (speculation runs policy-free)"
            );
            base.push(self.lanes[lane].len);
            for h in 0..heads {
                let seq = self.lanes[lane].seqs[h];
                for t in 0..n {
                    if let Err(e) =
                        self.push_token(seq, k.head_row(bi, h, t), v.head_row(bi, h, t))
                    {
                        let _ = self.release_lane(lane);
                        return Err(e);
                    }
                }
                seqs.push(seq);
            }
            self.lanes[lane].len += n;
        }

        let bh = lanes.len() * heads;
        let d_v = self.cfg.d_v;
        let v_off = match self.scorer {
            Scorer::Dense => self.cfg.d,
            Scorer::Sfa { k } => k + k.div_ceil(2),
        };
        let mut out = HeadTensor::zeros(lanes.len(), heads, n, d_v);
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let this: &AttentionSession = self;
        let seqs_ref = &seqs;
        let base_ref = &base;
        let threads = default_threads().min(bh.max(1));
        parallel_for_dynamic(bh, threads, 1, move |i| {
            let (bi, h) = (i / heads, i % heads);
            let mut scratch = TierScratch::new();
            let slots = this
                .cache
                .token_slices_tiered(seqs_ref[i], &mut scratch)
                .expect("session sequence exists");
            for t in 0..n {
                // SAFETY: each (lane, head, position) owns a disjoint
                // output range.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.get().add((i * n + t) * d_v), d_v)
                };
                let scores =
                    this.head_scores(&slots[..base_ref[bi] + t + 1], q.head_row(bi, h, t));
                softmax_weighted_sum(&scores, |j| slots[j][v_off..].as_ptr(), d_v, dst);
            }
        });
        Ok(out)
    }

    /// Score one query row against a prefix of cached token slots with
    /// the session's scorer — the shared kernel of the decode path and
    /// the policy observation pass.
    fn head_scores(&self, slots: &[&[f32]], q: &[f32]) -> Vec<(u32, f32)> {
        let d = self.cfg.d;
        let scale = 1.0 / (d as f32).sqrt();
        let mut scores: Vec<(u32, f32)> = Vec::with_capacity(slots.len());
        match self.scorer {
            Scorer::Dense => {
                for (j, slot) in slots.iter().enumerate() {
                    let mut acc = 0.0;
                    for t in 0..d {
                        acc += q[t] * slot[t];
                    }
                    scores.push((j as u32, acc * scale));
                }
            }
            Scorer::Sfa { k } => {
                let (qv, qi) = topk_row(q, k);
                for (j, slot) in slots.iter().enumerate() {
                    let mut acc = 0.0;
                    for (&qval, &qf) in qv.iter().zip(&qi) {
                        if qval == 0.0 {
                            continue;
                        }
                        for (pos, &kval) in slot[..k].iter().enumerate() {
                            if kval == 0.0 {
                                continue;
                            }
                            let pair = unpack_idx(slot[k + pos / 2]);
                            let kf = if pos % 2 == 0 { pair.0 } else { pair.1 };
                            if kf == qf {
                                acc += qval * kval;
                            }
                        }
                    }
                    scores.push((j as u32, acc * scale));
                }
            }
        }
        scores
    }

    /// Score one head's query row against its cached sequence and write
    /// the softmax-weighted V sum into `out`. When `probs_out` is given
    /// (policy lanes) each cached key's softmax probability is also
    /// recorded at its position; both paths run the same
    /// softmax-then-weighted-sum helpers, so outputs are bit-identical
    /// with and without observation.
    fn decode_head(&self, seq: SeqId, q: &[f32], out: &mut [f32], probs_out: Option<&mut [f32]>) {
        let d_v = self.cfg.d_v;
        let mut scratch = TierScratch::new();
        let slots =
            self.cache.token_slices_tiered(seq, &mut scratch).expect("session sequence exists");
        let scores = self.head_scores(&slots, q);
        let v_off = match self.scorer {
            Scorer::Dense => self.cfg.d,
            Scorer::Sfa { k } => k + k.div_ceil(2),
        };
        match probs_out {
            None => softmax_weighted_sum(&scores, |j| slots[j][v_off..].as_ptr(), d_v, out),
            Some(buf) => {
                let probs = softmax_probs(&scores);
                for &(j, p) in &probs {
                    buf[j as usize] = p;
                }
                weighted_sum(&probs, |j| slots[j][v_off..].as_ptr(), d_v, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::registry::build_engine;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn full_qkv(
        batch: usize,
        heads: usize,
        n: usize,
        d: usize,
        seed: u64,
    ) -> (HeadTensor, HeadTensor, HeadTensor) {
        let mut rng = Rng::new(seed);
        (
            HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0),
            HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0),
            HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0),
        )
    }

    /// Prefill `n0` tokens then decode `steps` more; every output row
    /// must match the one-shot causal forward over all `n0 + steps`
    /// tokens within `tol`.
    fn assert_session_matches_one_shot(spec: &str, tol: f32) {
        let (batch, heads, d) = (2, 2, 16);
        let (n0, steps) = (12, 6);
        let n = n0 + steps;
        let (q, k, v) = full_qkv(batch, heads, n, d, 42);
        let full = build_engine(spec).unwrap().forward_batched(&q, &k, &v, true);

        let cfg = SessionConfig::new(batch, heads, d, d).with_paging(4, 4096);
        let mut sess = AttentionSession::from_spec(spec, cfg).unwrap();
        let pre = sess
            .prefill(&q.slice_rows(0, n0), &k.slice_rows(0, n0), &v.slice_rows(0, n0), true)
            .unwrap();
        assert_eq!(sess.len(), n0);
        for b in 0..batch {
            for h in 0..heads {
                for t in 0..n0 {
                    for (a, e) in pre.head_row(b, h, t).iter().zip(full.head_row(b, h, t)) {
                        assert!(
                            (a - e).abs() < tol,
                            "{spec}: prefill row {t} head ({b},{h}): {a} vs {e}"
                        );
                    }
                }
            }
        }
        for s in 0..steps {
            let t = n0 + s;
            let o = sess
                .decode_step(
                    &q.slice_rows(t, t + 1),
                    &k.slice_rows(t, t + 1),
                    &v.slice_rows(t, t + 1),
                )
                .unwrap();
            for b in 0..batch {
                for h in 0..heads {
                    for (a, e) in o.head_row(b, h, 0).iter().zip(full.head_row(b, h, t)) {
                        assert!(
                            (a - e).abs() < tol,
                            "{spec}: decode step {s} head ({b},{h}): {a} vs {e}"
                        );
                    }
                }
            }
        }
        assert_eq!(sess.len(), n);
    }

    #[test]
    fn session_equivalence_dense_layout_flash() {
        assert_session_matches_one_shot("flash_dense:bq=8,bk=8", 3e-5);
    }

    #[test]
    fn session_equivalence_dense_layout_naive() {
        assert_session_matches_one_shot("dense", 3e-5);
    }

    #[test]
    fn session_equivalence_sfa_layout_flash() {
        assert_session_matches_one_shot("sfa:k=8,bq=8,bk=8", 3e-5);
    }

    #[test]
    fn session_equivalence_sfa_layout_reference() {
        assert_session_matches_one_shot("sfa_ref:k=4", 3e-5);
    }

    /// Tiered-KV contract at the session layer: demoting the cold
    /// prefix to int8 keeps decode outputs near-lossless (same bound
    /// class as the quant engine tests), the per-step counters drain
    /// exactly once, and the recorded worst-case dequant error stays
    /// inside the `scale/2` contract. Runs both slot layouts — the
    /// sparse one exercises bit-exact packed-index survival end to end.
    #[test]
    fn demote_cold_then_decode_stays_close_and_drains_counters() {
        for spec in ["dense", "sfa_ref:k=8"] {
            let (batch, heads, d) = (1, 2, 16);
            let (n0, steps) = (12, 4);
            let n = n0 + steps;
            let (q, k, v) = full_qkv(batch, heads, n, d, 9);
            let cfg = SessionConfig::new(batch, heads, d, d).with_paging(4, 4096);
            let mut hot = AttentionSession::from_spec(spec, cfg).unwrap();
            let mut cold = AttentionSession::from_spec(spec, cfg).unwrap();
            let p0 = (&q.slice_rows(0, n0), &k.slice_rows(0, n0), &v.slice_rows(0, n0));
            hot.prefill(p0.0, p0.1, p0.2, true).unwrap();
            cold.prefill(p0.0, p0.1, p0.2, true).unwrap();
            assert_eq!(cold.take_tier_counts(), (0, 0), "nothing demoted yet");

            // keep_hot=4 of 12 cached tokens -> 2 full pages go cold
            // per head sequence.
            let tier = KvTierCfg { cold_after: 4, policy: TierPolicy::Lru };
            let demoted = cold.demote_cold(tier);
            assert_eq!(demoted, heads * 2, "{spec}: two cold pages per head");
            assert_eq!(cold.pages_demoted(), demoted);
            assert_eq!(cold.take_tier_counts(), (demoted, 0));
            assert_eq!(cold.take_tier_counts(), (0, 0), "counters drain once");
            assert!(
                cold.tier_max_error_ratio() <= 1.0 + 1e-3,
                "{spec}: dequant error outside the scale/2 contract: {}",
                cold.tier_max_error_ratio()
            );
            // Idempotent: the cold prefix is already int8.
            assert_eq!(cold.demote_cold(tier), 0);

            let (mut err, mut norm) = (0.0f32, 0.0f32);
            for t in n0..n {
                let a = hot.decode_step(&at(&q, t), &at(&k, t), &at(&v, t)).unwrap();
                let b = cold.decode_step(&at(&q, t), &at(&k, t), &at(&v, t)).unwrap();
                for i in 0..a.data.len() {
                    err += (a.data[i] - b.data[i]).powi(2);
                    norm += a.data[i].powi(2);
                }
            }
            let rel = (err / norm.max(1e-12)).sqrt();
            assert!(rel < 0.05, "{spec}: int8 cold pages should be near-lossless: {rel}");
        }
    }

    #[test]
    fn sparse_layout_uses_fewer_cache_bytes() {
        let (batch, heads, d, n) = (1, 2, 64, 40);
        let (q, k, v) = full_qkv(batch, heads, n, d, 7);
        let cfg = SessionConfig::new(batch, heads, d, d).with_paging(8, 4096);
        let mut dense = AttentionSession::from_spec("flash_dense", cfg).unwrap();
        let mut sparse = AttentionSession::from_spec("sfa:k=8", cfg).unwrap();
        dense.prefill(&q, &k, &v, true).unwrap();
        sparse.prefill(&q, &k, &v, true).unwrap();
        assert!(sparse.cache_bytes() < dense.cache_bytes());
        assert_eq!(dense.len(), n);
        assert_eq!(sparse.len(), n);
    }

    #[test]
    fn out_of_pages_surfaces_the_cache_error() {
        let (batch, heads, d, n) = (1, 1, 8, 12);
        let (q, k, v) = full_qkv(batch, heads, n, d, 3);
        let cfg = SessionConfig::new(batch, heads, d, d).with_paging(2, 1);
        let mut sess = AttentionSession::from_spec("dense", cfg).unwrap();
        assert_eq!(sess.prefill(&q, &k, &v, true).unwrap_err(), PageError::OutOfPages);
    }

    #[test]
    fn oversized_feature_budget_is_rejected() {
        let cfg = SessionConfig::new(1, 1, 16, 16);
        let e = AttentionSession::from_spec("sfa:k=128", cfg).unwrap_err();
        assert!(e.0.contains("exceeds head dim"), "{e}");
    }

    #[test]
    #[should_panic(expected = "prefill must be the first call")]
    fn second_prefill_is_rejected() {
        let (batch, heads, d, n) = (1, 1, 8, 4);
        let (q, k, v) = full_qkv(batch, heads, n, d, 5);
        let mut sess =
            AttentionSession::from_spec("dense", SessionConfig::new(batch, heads, d, d)).unwrap();
        sess.prefill(&q, &k, &v, true).unwrap();
        let _ = sess.prefill(&q, &k, &v, true);
    }

    /// Concatenate two same-shape-per-lane tensors along the batch axis
    /// (the serve scheduler's batch-forming step, in miniature).
    fn stack_batch(a: &HeadTensor, b: &HeadTensor) -> HeadTensor {
        assert_eq!((a.heads, a.n, a.d), (b.heads, b.n, b.d));
        let mut out = HeadTensor::zeros(a.batch + b.batch, a.heads, a.n, a.d);
        let per = a.heads * a.n * a.d;
        out.data[..a.batch * per].copy_from_slice(&a.data);
        out.data[a.batch * per..].copy_from_slice(&b.data);
        out
    }

    /// Lanes at different lengths decode together bit-for-bit identical
    /// to solo uniform-batch sessions over the same streams, and a
    /// released lane returns its pages and its slot.
    #[test]
    fn lane_api_matches_solo_runs_bitwise() {
        let (heads, d) = (2, 16);
        let spec = "sfa:k=8,bq=8,bk=8";
        let lane_cfg = SessionConfig::new(0, heads, d, d).with_paging(4, 4096);
        let solo_cfg = SessionConfig::new(1, heads, d, d).with_paging(4, 4096);
        let (qa, ka, va) = full_qkv(1, heads, 12, d, 1);
        let (qb, kb, vb) = full_qkv(1, heads, 10, d, 2);
        let (pre_a, pre_b, steps) = (8, 6, 4);

        let mut sess = AttentionSession::from_spec(spec, lane_cfg).unwrap();
        assert_eq!(sess.live_lanes(), 0);
        let mut solo_a = AttentionSession::from_spec(spec, solo_cfg).unwrap();
        let mut solo_b = AttentionSession::from_spec(spec, solo_cfg).unwrap();

        let a = sess.admit_lane();
        let b = sess.admit_lane();
        assert_ne!(a, b);
        let la = sess
            .prefill_lane(
                a,
                &qa.slice_rows(0, pre_a),
                &ka.slice_rows(0, pre_a),
                &va.slice_rows(0, pre_a),
                true,
            )
            .unwrap();
        let lb = sess
            .prefill_lane(
                b,
                &qb.slice_rows(0, pre_b),
                &kb.slice_rows(0, pre_b),
                &vb.slice_rows(0, pre_b),
                true,
            )
            .unwrap();
        let sa = solo_a
            .prefill(
                &qa.slice_rows(0, pre_a),
                &ka.slice_rows(0, pre_a),
                &va.slice_rows(0, pre_a),
                true,
            )
            .unwrap();
        let sb = solo_b
            .prefill(
                &qb.slice_rows(0, pre_b),
                &kb.slice_rows(0, pre_b),
                &vb.slice_rows(0, pre_b),
                true,
            )
            .unwrap();
        assert_eq!(la.data, sa.data, "lane prefill == solo prefill, bit-for-bit");
        assert_eq!(lb.data, sb.data);
        assert_eq!((sess.lane_len(a), sess.lane_len(b)), (pre_a, pre_b));
        assert_eq!(sess.live_lanes(), 2);

        for s in 0..steps {
            let (ta, tb) = (pre_a + s, pre_b + s);
            let q = stack_batch(&qa.slice_rows(ta, ta + 1), &qb.slice_rows(tb, tb + 1));
            let k = stack_batch(&ka.slice_rows(ta, ta + 1), &kb.slice_rows(tb, tb + 1));
            let v = stack_batch(&va.slice_rows(ta, ta + 1), &vb.slice_rows(tb, tb + 1));
            let out = sess.decode_step_lanes(&[a, b], &q, &k, &v).unwrap();
            let oa = solo_a
                .decode_step(
                    &qa.slice_rows(ta, ta + 1),
                    &ka.slice_rows(ta, ta + 1),
                    &va.slice_rows(ta, ta + 1),
                )
                .unwrap();
            let ob = solo_b
                .decode_step(
                    &qb.slice_rows(tb, tb + 1),
                    &kb.slice_rows(tb, tb + 1),
                    &vb.slice_rows(tb, tb + 1),
                )
                .unwrap();
            for h in 0..heads {
                assert_eq!(out.head_row(0, h, 0), oa.head_row(0, h, 0), "step {s} lane a");
                assert_eq!(out.head_row(1, h, 0), ob.head_row(0, h, 0), "step {s} lane b");
            }
        }

        // Mid-wave eviction: releasing lane a frees its pages while b
        // keeps decoding, and the slot is recycled by the next admit.
        let before = sess.pages_in_use();
        let a_pages = sess.lane_pages(a);
        assert!(a_pages > 0, "a prefilled lane occupies pages");
        let free_before = sess.pages_free();
        let freed = sess.release_lane(a).unwrap();
        assert_eq!(freed, a_pages, "release returns exactly the lane's pages");
        assert_eq!(sess.pages_in_use(), before - freed);
        assert_eq!(sess.pages_free(), free_before + freed);
        assert_eq!(sess.live_lanes(), 1);
        assert!(sess.release_lane(a).is_err(), "double release is an error");
        let tb = pre_b + steps;
        sess.decode_step_lanes(
            &[b],
            &qb.slice_rows(tb, tb + 1),
            &kb.slice_rows(tb, tb + 1),
            &vb.slice_rows(tb, tb + 1),
        )
        .unwrap();
        assert_eq!(sess.lane_len(b), tb + 1);
        let c = sess.admit_lane();
        assert_eq!(c, a, "released slot is recycled");
        assert_eq!(sess.lane_len(c), 0);
    }

    /// A prefill that dies mid-append must not leave a corrupt partial
    /// prefix behind: the lane is auto-released (pages returned, slot
    /// recyclable) and a retry on the handle fails loudly.
    #[test]
    fn failed_lane_prefill_auto_releases() {
        let (heads, d, n) = (2, 8, 12);
        let (q, k, v) = full_qkv(1, heads, n, d, 11);
        // Budget of 2 pages × 2 tokens = 4 token slots across 2 heads —
        // far too small for a 12-token prompt.
        let cfg = SessionConfig::new(0, heads, d, d).with_paging(2, 2);
        let mut sess = AttentionSession::from_spec("dense", cfg).unwrap();
        let lane = sess.admit_lane();
        assert_eq!(
            sess.prefill_lane(lane, &q, &k, &v, true).unwrap_err(),
            PageError::OutOfPages
        );
        assert_eq!(sess.live_lanes(), 0, "failed prefill releases the lane");
        assert_eq!(sess.pages_in_use(), 0, "partial prefix pages are returned");
        assert!(sess.release_lane(lane).is_err(), "handle is already invalid");
        assert_eq!(sess.admit_lane(), lane, "slot is recyclable");
    }

    /// First `n` rows / single row `i` of a test tensor (shorthand for
    /// the policy-lane tests' many slices).
    fn pfx(t: &HeadTensor, n: usize) -> HeadTensor {
        t.slice_rows(0, n)
    }

    fn at(t: &HeadTensor, i: usize) -> HeadTensor {
        t.slice_rows(i, i + 1)
    }

    fn tight_policies() -> Vec<PagedKvPolicy> {
        vec![
            PagedKvPolicy::H2o { budget: 8, recent: 4 },
            PagedKvPolicy::SnapKv { budget: 8, recent: 4 },
            PagedKvPolicy::Quest { budget: 8 },
        ]
    }

    /// The no-op-budget guarantee: a policy lane whose budget exceeds
    /// the whole stream never prunes and is **bit-for-bit** identical
    /// to a plain lane — prefill and every decode step, dense and SFA
    /// layouts, all three policies (the probability-observation path
    /// shares the exact softmax/weighted-sum helpers).
    #[test]
    fn noop_budget_policy_lane_is_bitwise_identical() {
        let loose = [
            PagedKvPolicy::H2o { budget: 64, recent: 8 },
            PagedKvPolicy::SnapKv { budget: 64, recent: 8 },
            PagedKvPolicy::Quest { budget: 64 },
        ];
        for spec in ["dense", "sfa:k=8,bq=8,bk=8"] {
            for pol in &loose {
                let (heads, d) = (2, 16);
                let (pre, steps) = (10, 6);
                let cfg = SessionConfig::new(0, heads, d, d).with_paging(4, 4096);
                let (q, k, v) = full_qkv(1, heads, pre + steps, d, 9);
                let mut plain = AttentionSession::from_spec(spec, cfg).unwrap();
                let mut budgeted = AttentionSession::from_spec(spec, cfg).unwrap();
                let a = plain.admit_lane();
                let b = budgeted.admit_lane_with_policy(pol);
                let oa = plain
                    .prefill_lane(a, &pfx(&q, pre), &pfx(&k, pre), &pfx(&v, pre), true)
                    .unwrap();
                let ob = budgeted
                    .prefill_lane(b, &pfx(&q, pre), &pfx(&k, pre), &pfx(&v, pre), true)
                    .unwrap();
                assert_eq!(oa.data, ob.data, "{spec} {pol:?} prefill");
                for s in 0..steps {
                    let t = pre + s;
                    let xa = plain
                        .decode_step_lanes(&[a], &at(&q, t), &at(&k, t), &at(&v, t))
                        .unwrap();
                    let xb = budgeted
                        .decode_step_lanes(&[b], &at(&q, t), &at(&k, t), &at(&v, t))
                        .unwrap();
                    assert_eq!(xa.data, xb.data, "{spec} {pol:?} step {s}");
                }
                assert_eq!(budgeted.lane_cached(b), budgeted.lane_len(b), "never pruned");
                assert_eq!(budgeted.take_policy_freed(), 0);
                assert_eq!(plain.pages_in_use(), budgeted.pages_in_use());
            }
        }
    }

    /// Tight budgets: a long prompt is pruned back under the policy
    /// limit at prefill end, every decode step re-prunes, the pages go
    /// back to the pool, and the lane's absolute position counter keeps
    /// counting past the shrunken cache.
    #[test]
    fn policy_lane_prunes_pages_mid_stream() {
        for pol in tight_policies() {
            let (heads, d) = (2, 16);
            let (pre, steps) = (24, 16);
            let cfg = SessionConfig::new(0, heads, d, d).with_paging(4, 4096);
            let (q, k, v) = full_qkv(1, heads, pre + steps, d, 13);
            let mut sess = AttentionSession::from_spec("dense", cfg).unwrap();
            let mut plain = AttentionSession::from_spec("dense", cfg).unwrap();
            let lane = sess.admit_lane_with_policy(&pol);
            let p = plain.admit_lane();
            sess.prefill_lane(lane, &pfx(&q, pre), &pfx(&k, pre), &pfx(&v, pre), true)
                .unwrap();
            plain
                .prefill_lane(p, &pfx(&q, pre), &pfx(&k, pre), &pfx(&v, pre), true)
                .unwrap();
            let limit = pol.max_cached_tokens(4);
            assert!(
                sess.lane_cached(lane) <= limit,
                "{pol:?}: prompt pruned at prefill end ({} > {limit})",
                sess.lane_cached(lane)
            );
            assert!(sess.take_policy_freed() > 0, "{pol:?}: prefill prune frees pages");
            for s in 0..steps {
                let t = pre + s;
                sess.decode_step_lanes(&[lane], &at(&q, t), &at(&k, t), &at(&v, t))
                    .unwrap();
                plain
                    .decode_step_lanes(&[p], &at(&q, t), &at(&k, t), &at(&v, t))
                    .unwrap();
                assert!(sess.lane_cached(lane) <= limit, "{pol:?} step {s}");
            }
            assert_eq!(sess.lane_len(lane), pre + steps, "absolute positions keep counting");
            assert!(sess.lane_cached(lane) < sess.lane_len(lane));
            assert!(
                sess.pages_in_use() < plain.pages_in_use(),
                "{pol:?}: pruned lane holds fewer pages ({} vs {})",
                sess.pages_in_use(),
                plain.pages_in_use()
            );
            // Release still returns exactly what the lane holds.
            let held = sess.lane_pages(lane);
            assert_eq!(sess.release_lane(lane).unwrap(), held);
            assert_eq!(sess.pages_in_use(), 0);
        }
    }

    /// Prefix-sharing path: a lane seeded by forking another lane's
    /// prompt prefix, then extended with the suffix, holds bit-identical
    /// cache bytes — so its last-position output and every subsequent
    /// decode step equal a cold-prefilled lane's exactly.
    #[test]
    fn forked_prefix_lane_matches_cold_prefill_bitwise() {
        for spec in ["dense", "sfa:k=8,bq=8,bk=8"] {
            let (heads, d) = (2, 16);
            let (plen, shared, steps) = (11, 6, 4);
            let cfg = SessionConfig::new(0, heads, d, d).with_paging(4, 4096);
            let (q, k, v) = full_qkv(1, heads, plen + steps, d, 17);
            let mut sess = AttentionSession::from_spec(spec, cfg).unwrap();

            // Cold lane: full prompt prefill.
            let cold = sess.admit_lane();
            sess.prefill_lane(cold, &pfx(&q, plen), &pfx(&k, plen), &pfx(&v, plen), true)
                .unwrap();
            let cold_out = sess.lane_last_output(cold, &at(&q, plen - 1));

            // Warm lane: fork the cold lane's first `shared` tokens,
            // append only the suffix.
            let srcs = sess.lane_seqs(cold).to_vec();
            let warm = sess.admit_lane_from_fork(&srcs, shared).unwrap();
            assert_eq!(sess.lane_len(warm), shared);
            let ksuf = k.slice_rows(shared, plen);
            let vsuf = v.slice_rows(shared, plen);
            sess.extend_lane(warm, &ksuf, &vsuf).unwrap();
            assert_eq!(sess.lane_len(warm), plen);
            let warm_out = sess.lane_last_output(warm, &at(&q, plen - 1));
            assert_eq!(cold_out.data, warm_out.data, "{spec}: first-token output");

            // The chunked-prefill compute path (suffix queries over
            // the causally growing cache) ends on the sampled
            // first-token output within f32 summation-order tolerance:
            // both scorer families now run tiled append kernels
            // (FlashDense::forward_append / FlashSfa's code append),
            // whose online-softmax fold orders sums differently from
            // the per-token scalar path behind lane_last_output.
            let chunk =
                sess.chunked_prefill_outputs(warm, &q.slice_rows(shared, plen), shared);
            assert_eq!((chunk.n, chunk.d), (plen - shared, d));
            for h in 0..heads {
                let got = chunk.head_row(0, h, plen - shared - 1);
                let want = warm_out.head_row(0, h, 0);
                for (x, y) in got.iter().zip(want) {
                    assert!(
                        (x - y).abs() <= 3e-6 + 3e-5 * y.abs().max(x.abs()),
                        "{spec}: chunked prefill last row: {x} vs {y}"
                    );
                }
            }

            // Decode steps stay bitwise equal lane-for-lane.
            for s in 0..steps {
                let t = plen + s;
                let oc = sess
                    .decode_step_lanes(&[cold], &at(&q, t), &at(&k, t), &at(&v, t))
                    .unwrap();
                let ow = sess
                    .decode_step_lanes(&[warm], &at(&q, t), &at(&k, t), &at(&v, t))
                    .unwrap();
                assert_eq!(oc.data, ow.data, "{spec}: decode step {s}");
            }
            // Shared full pages are refcounted, not copied: releasing
            // the cold lane leaves the warm lane's stream intact.
            sess.release_lane(cold).unwrap();
            assert_eq!(sess.lane_len(warm), plen + steps);
            sess.release_lane(warm).unwrap();
            assert_eq!(sess.pages_in_use(), 0);
        }
    }

    /// Drive one lane's prompt through [`AttentionSession::prefill_chunk`]
    /// in `chunk`-token pieces, starting at `start` already-cached
    /// tokens (0 for a cold lane, the shared depth for a forked one).
    fn chunk_prefill(
        sess: &mut AttentionSession,
        lane: LaneId,
        q: &HeadTensor,
        k: &HeadTensor,
        v: &HeadTensor,
        start: usize,
        chunk: usize,
    ) {
        let total = k.n;
        let mut c0 = start;
        while c0 < total {
            let c1 = (c0 + chunk).min(total);
            let out = sess
                .prefill_chunk(
                    lane,
                    &q.slice_rows(c0, c1),
                    &k.slice_rows(c0, c1),
                    &v.slice_rows(c0, c1),
                    total,
                )
                .unwrap();
            assert_eq!((out.n, out.d), (c1 - c0, v.d), "chunk output shape");
            let st = sess.lane_prefill_state(lane);
            if c1 < total {
                assert_eq!(st, Some(PrefillState { consumed: c1, total }));
            } else {
                assert_eq!(st, None, "final chunk clears the prefill state");
            }
            c0 = c1;
        }
        assert_eq!(sess.lane_len(lane), total);
    }

    /// The tentpole invariance: chunked prefill stores the exact same
    /// per-token payloads in the same per-sequence order as a
    /// monolithic `prefill_lane`, so for **any** chunk size the
    /// first-token output and every subsequent decode step are
    /// bit-for-bit identical — dense and SFA layouts.
    #[test]
    fn chunked_prefill_matches_monolithic_bitwise() {
        for spec in ["dense", "sfa:k=8,bq=8,bk=8"] {
            for chunk in [1usize, 3, 5, 13, 64] {
                let (heads, d) = (2, 16);
                let (plen, steps) = (13, 4);
                let cfg = SessionConfig::new(0, heads, d, d).with_paging(4, 4096);
                let (q, k, v) = full_qkv(1, heads, plen + steps, d, 31);
                let mut mono = AttentionSession::from_spec(spec, cfg).unwrap();
                let mut chk = AttentionSession::from_spec(spec, cfg).unwrap();
                let a = mono.admit_lane();
                mono.prefill_lane(a, &pfx(&q, plen), &pfx(&k, plen), &pfx(&v, plen), true)
                    .unwrap();
                let b = chk.admit_lane();
                chunk_prefill(
                    &mut chk,
                    b,
                    &pfx(&q, plen),
                    &pfx(&k, plen),
                    &pfx(&v, plen),
                    0,
                    chunk,
                );
                assert_eq!(mono.cache_bytes(), chk.cache_bytes(), "{spec} chunk={chunk}");
                let oa = mono.lane_last_output(a, &at(&q, plen - 1));
                let ob = chk.lane_last_output(b, &at(&q, plen - 1));
                assert_eq!(oa.data, ob.data, "{spec} chunk={chunk}: first-token output");
                for s in 0..steps {
                    let t = plen + s;
                    let xa = mono
                        .decode_step_lanes(&[a], &at(&q, t), &at(&k, t), &at(&v, t))
                        .unwrap();
                    let xb = chk
                        .decode_step_lanes(&[b], &at(&q, t), &at(&k, t), &at(&v, t))
                        .unwrap();
                    assert_eq!(xa.data, xb.data, "{spec} chunk={chunk} step {s}");
                }
            }
        }
    }

    /// Chunked prefill × KV policies: per-chunk key ingestion plus the
    /// final-chunk observe replay reproduce the monolithic policy
    /// seeding exactly — same pruned cache, same freed-page count,
    /// bitwise-equal decode streams (tight budgets), and a no-op
    /// budget chunked policy lane stays bit-identical to a plain
    /// chunked lane.
    #[test]
    fn chunked_prefill_policy_lanes_match_monolithic() {
        let (heads, d) = (2, 16);
        let (pre, steps, chunk) = (24, 8, 5);
        let cfg = SessionConfig::new(0, heads, d, d).with_paging(4, 4096);
        let (q, k, v) = full_qkv(1, heads, pre + steps, d, 37);
        for pol in tight_policies() {
            let mut mono = AttentionSession::from_spec("dense", cfg).unwrap();
            let mut chk = AttentionSession::from_spec("dense", cfg).unwrap();
            let a = mono.admit_lane_with_policy(&pol);
            let b = chk.admit_lane_with_policy(&pol);
            mono.prefill_lane(a, &pfx(&q, pre), &pfx(&k, pre), &pfx(&v, pre), true).unwrap();
            chunk_prefill(&mut chk, b, &pfx(&q, pre), &pfx(&k, pre), &pfx(&v, pre), 0, chunk);
            assert_eq!(
                mono.lane_cached(a),
                chk.lane_cached(b),
                "{pol:?}: same prune survivors"
            );
            assert_eq!(
                mono.take_policy_freed(),
                chk.take_policy_freed(),
                "{pol:?}: same pages freed at prefill end"
            );
            for s in 0..steps {
                let t = pre + s;
                let xa =
                    mono.decode_step_lanes(&[a], &at(&q, t), &at(&k, t), &at(&v, t)).unwrap();
                let xb =
                    chk.decode_step_lanes(&[b], &at(&q, t), &at(&k, t), &at(&v, t)).unwrap();
                assert_eq!(xa.data, xb.data, "{pol:?} step {s}");
                assert_eq!(mono.lane_cached(a), chk.lane_cached(b), "{pol:?} step {s} cached");
            }
        }
        // No-op budget: chunked policy lane == plain chunked lane.
        let loose = PagedKvPolicy::SnapKv { budget: 64, recent: 8 };
        let mut plain = AttentionSession::from_spec("dense", cfg).unwrap();
        let mut pol = AttentionSession::from_spec("dense", cfg).unwrap();
        let a = plain.admit_lane();
        let b = pol.admit_lane_with_policy(&loose);
        chunk_prefill(&mut plain, a, &pfx(&q, pre), &pfx(&k, pre), &pfx(&v, pre), 0, chunk);
        chunk_prefill(&mut pol, b, &pfx(&q, pre), &pfx(&k, pre), &pfx(&v, pre), 0, chunk);
        assert_eq!(pol.take_policy_freed(), 0, "no-op budget never prunes");
        for s in 0..steps {
            let t = pre + s;
            let xa = plain.decode_step_lanes(&[a], &at(&q, t), &at(&k, t), &at(&v, t)).unwrap();
            let xb = pol.decode_step_lanes(&[b], &at(&q, t), &at(&k, t), &at(&v, t)).unwrap();
            assert_eq!(xa.data, xb.data, "no-op budget step {s}");
        }
    }

    /// Chunked prefill × prefix sharing: a lane forked at the shared
    /// depth and chunked through only the un-shared suffix ends with
    /// the same cache bytes as a cold monolithic prefill — the radix
    /// cache's hit path under chunked ingestion.
    #[test]
    fn chunked_suffix_after_fork_matches_cold_prefill_bitwise() {
        for spec in ["dense", "sfa:k=8,bq=8,bk=8"] {
            let (heads, d) = (2, 16);
            let (plen, shared, steps, chunk) = (11, 6, 4, 2);
            let cfg = SessionConfig::new(0, heads, d, d).with_paging(4, 4096);
            let (q, k, v) = full_qkv(1, heads, plen + steps, d, 41);
            let mut sess = AttentionSession::from_spec(spec, cfg).unwrap();
            let cold = sess.admit_lane();
            sess.prefill_lane(cold, &pfx(&q, plen), &pfx(&k, plen), &pfx(&v, plen), true)
                .unwrap();
            let srcs = sess.lane_seqs(cold).to_vec();
            let warm = sess.admit_lane_from_fork(&srcs, shared).unwrap();
            chunk_prefill(
                &mut sess,
                warm,
                &pfx(&q, plen),
                &pfx(&k, plen),
                &pfx(&v, plen),
                shared,
                chunk,
            );
            let oc = sess.lane_last_output(cold, &at(&q, plen - 1));
            let ow = sess.lane_last_output(warm, &at(&q, plen - 1));
            assert_eq!(oc.data, ow.data, "{spec}: first-token output");
            for s in 0..steps {
                let t = plen + s;
                let xc = sess
                    .decode_step_lanes(&[cold], &at(&q, t), &at(&k, t), &at(&v, t))
                    .unwrap();
                let xw = sess
                    .decode_step_lanes(&[warm], &at(&q, t), &at(&k, t), &at(&v, t))
                    .unwrap();
                assert_eq!(xc.data, xw.data, "{spec}: decode step {s}");
            }
            sess.release_lane(cold).unwrap();
            sess.release_lane(warm).unwrap();
            assert_eq!(sess.pages_in_use(), 0);
        }
    }

    /// A chunk append that exhausts the page budget auto-releases the
    /// whole lane (previous chunks included) — prefill_lane's failure
    /// contract, chunk edition.
    #[test]
    fn failed_prefill_chunk_auto_releases() {
        let (heads, d) = (2, 8);
        let (q, k, v) = full_qkv(1, heads, 12, d, 43);
        let cfg = SessionConfig::new(0, heads, d, d).with_paging(2, 2);
        let mut sess = AttentionSession::from_spec("dense", cfg).unwrap();
        let lane = sess.admit_lane();
        // First chunk fits (2 pages × 2 tokens covers 2 tokens × 2
        // heads), the second must run out mid-append.
        sess.prefill_chunk(lane, &pfx(&q, 2), &pfx(&k, 2), &pfx(&v, 2), 12).unwrap();
        let e = sess
            .prefill_chunk(
                lane,
                &q.slice_rows(2, 8),
                &k.slice_rows(2, 8),
                &v.slice_rows(2, 8),
                12,
            )
            .unwrap_err();
        assert_eq!(e, PageError::OutOfPages);
        assert_eq!(sess.live_lanes(), 0, "failed chunk releases the lane");
        assert_eq!(sess.pages_in_use(), 0, "all chunks' pages are returned");
        assert_eq!(sess.admit_lane(), lane, "slot is recyclable");
    }

    /// The tiled SFA append kernel behind `chunked_prefill_outputs`
    /// must reproduce the old per-token semantics: every suffix row `t`
    /// equals a one-row scoring pass over the lane's first
    /// `start_pos + t + 1` cached tokens (realised here through
    /// `lane_last_output` on a fork truncated at that depth — the exact
    /// per-token scalar path the kernel replaced). Greedy serve streams
    /// can't drift either way: the scheduler samples from
    /// `lane_last_output` and discards the chunked outputs.
    #[test]
    fn chunked_prefill_tiled_kernel_matches_per_token_reference() {
        for spec in ["sfa:k=4,bq=8,bk=8", "sfa:k=4", "sfa:k=4,bq=4,bk=16"] {
            let (heads, d) = (2, 16);
            let (plen, shared) = (13, 5);
            let cfg = SessionConfig::new(0, heads, d, d).with_paging(4, 4096);
            let (q, k, v) = full_qkv(1, heads, plen, d, 29);
            let mut sess = AttentionSession::from_spec(spec, cfg).unwrap();
            let lane = sess.admit_lane();
            sess.prefill_lane(lane, &q, &k, &v, true).unwrap();

            let chunk =
                sess.chunked_prefill_outputs(lane, &q.slice_rows(shared, plen), shared);
            let srcs = sess.lane_seqs(lane).to_vec();
            for t in 0..plen - shared {
                let fork = sess.admit_lane_from_fork(&srcs, shared + t + 1).unwrap();
                let want = sess.lane_last_output(fork, &at(&q, shared + t));
                for h in 0..heads {
                    for (x, y) in
                        chunk.head_row(0, h, t).iter().zip(want.head_row(0, h, 0))
                    {
                        assert!(
                            (x - y).abs() <= 3e-6 + 3e-5 * y.abs().max(x.abs()),
                            "{spec}: suffix row {t} head {h}: {x} vs {y}"
                        );
                    }
                }
                sess.release_lane(fork).unwrap();
            }
            sess.release_lane(lane).unwrap();
        }
    }

    /// extend_lane mirrors prefill_lane's failure contract: a suffix
    /// append that exhausts the page budget auto-releases the lane.
    #[test]
    fn failed_extend_auto_releases_the_lane() {
        let (heads, d) = (1, 8);
        let cfg = SessionConfig::new(0, heads, d, d).with_paging(2, 3);
        let (q, k, v) = full_qkv(1, heads, 10, d, 23);
        let mut sess = AttentionSession::from_spec("dense", cfg).unwrap();
        let base = sess.admit_lane();
        sess.prefill_lane(base, &pfx(&q, 4), &pfx(&k, 4), &pfx(&v, 4), true).unwrap();
        let srcs = sess.lane_seqs(base).to_vec();
        let lane = sess.admit_lane_from_fork(&srcs, 4).unwrap();
        // Budget: 3 pages × 2 tokens; base holds 2 pages; the fork
        // shares them, so appending 6 more tokens must run out.
        let e = sess
            .extend_lane(lane, &k.slice_rows(4, 10), &v.slice_rows(4, 10))
            .unwrap_err();
        assert_eq!(e, PageError::OutOfPages);
        assert_eq!(sess.live_lanes(), 1, "failed extend releases the forked lane");
        sess.release_lane(base).unwrap();
        assert_eq!(sess.pages_in_use(), 0);
    }

    /// Satellite regression (release_lane vs take_policy_freed): pages
    /// physically freed by mid-stream policy prunes are never counted
    /// again at lane release — across the lane's whole life,
    /// `Σ policy_freed + release_freed` equals the pages allocated for
    /// appended tokens (`alloc_total - rebuild_total`), and the cache
    /// drains to zero.
    #[test]
    fn policy_prune_and_release_free_each_page_exactly_once() {
        for pol in tight_policies() {
            let (heads, d) = (2, 16);
            let (pre, steps) = (24, 16);
            let cfg = SessionConfig::new(0, heads, d, d).with_paging(4, 4096);
            let (q, k, v) = full_qkv(1, heads, pre + steps, d, 29);
            let mut sess = AttentionSession::from_spec("dense", cfg).unwrap();
            let lane = sess.admit_lane_with_policy(&pol);
            sess.prefill_lane(lane, &pfx(&q, pre), &pfx(&k, pre), &pfx(&v, pre), true)
                .unwrap();
            let mut freed = sess.take_policy_freed();
            for s in 0..steps {
                let t = pre + s;
                sess.decode_step_lanes(&[lane], &at(&q, t), &at(&k, t), &at(&v, t))
                    .unwrap();
                freed += sess.take_policy_freed();
            }
            freed += sess.release_lane(lane).unwrap();
            assert_eq!(sess.pages_in_use(), 0, "{pol:?}: every page back in the pool");
            let appended_allocs =
                sess.cache.pages_alloc_total() - sess.cache.pages_rebuild_total();
            assert_eq!(
                freed, appended_allocs,
                "{pol:?}: prune + release must free each appended page exactly once \
                 (freed {freed} vs allocated {appended_allocs})"
            );
        }
    }

    /// The speculative verify forward: one `score_lanes` call over γ+1
    /// positions is bit-for-bit the γ+1 sequential `decode_step_lanes`
    /// outputs — the property that makes greedy streams identical with
    /// speculation on/off. Run on a fork of the sequential lane so the
    /// two paths score byte-identical cache prefixes.
    #[test]
    fn score_lanes_matches_sequential_decode_bitwise() {
        for spec in ["dense", "flash_dense:bq=4,bk=4", "sfa:k=4,bq=8,bk=8"] {
            let (heads, d) = (2, 16);
            let (plen, n) = (9, 5);
            let cfg = SessionConfig::new(0, heads, d, d).with_paging(4, 4096);
            let (q, k, v) = full_qkv(1, heads, plen + n, d, 53);
            let mut sess = AttentionSession::from_spec(spec, cfg).unwrap();
            let lane = sess.admit_lane();
            sess.prefill_lane(lane, &pfx(&q, plen), &pfx(&k, plen), &pfx(&v, plen), true)
                .unwrap();
            let srcs = sess.lane_seqs(lane).to_vec();
            let fork = sess.admit_lane_from_fork(&srcs, plen).unwrap();

            let mut step_outs = Vec::with_capacity(n);
            for t in plen..plen + n {
                let o = sess
                    .decode_step_lanes(&[lane], &at(&q, t), &at(&k, t), &at(&v, t))
                    .unwrap();
                step_outs.push(o);
            }
            let verify = sess
                .score_lanes(
                    &[fork],
                    &q.slice_rows(plen, plen + n),
                    &k.slice_rows(plen, plen + n),
                    &v.slice_rows(plen, plen + n),
                )
                .unwrap();
            assert_eq!((verify.n, verify.d), (n, d));
            for (t, o) in step_outs.iter().enumerate() {
                for h in 0..heads {
                    assert_eq!(
                        verify.head_row(0, h, t),
                        o.head_row(0, h, 0),
                        "{spec}: verify position {t} head {h} diverged from sequential decode"
                    );
                }
            }
            assert_eq!(sess.lane_len(fork), plen + n);
            sess.release_lane(fork).unwrap();
            sess.release_lane(lane).unwrap();
            assert_eq!(sess.pages_in_use(), 0);
        }
    }

    /// Speculation rollback (satellite regression, session level):
    /// releasing the forked verify lane returns page accounting to its
    /// pre-fork value exactly, and the source lane's decode stream is
    /// untouched — including when the verify append itself dies with
    /// OutOfPages mid-step (the fork is auto-released, the source lane
    /// and its pages survive).
    #[test]
    fn speculative_fork_rollback_restores_pages_and_source_stream() {
        let (heads, d) = (2, 8);
        let (plen, n) = (6, 3);
        let (q, k, v) = full_qkv(1, heads, plen + 2 * n, d, 59);
        let cfg = SessionConfig::new(0, heads, d, d).with_paging(2, 4096);
        let mut sess = AttentionSession::from_spec("dense", cfg).unwrap();
        let lane = sess.admit_lane();
        sess.prefill_lane(lane, &pfx(&q, plen), &pfx(&k, plen), &pfx(&v, plen), true).unwrap();
        let before = sess.pages_in_use();

        // Fork allocates nothing; the verify append pays only new pages.
        let srcs = sess.lane_seqs(lane).to_vec();
        let fork = sess.admit_lane_from_fork(&srcs, plen).unwrap();
        assert_eq!(sess.pages_in_use(), before, "fork_prefix allocates no pages");
        sess.score_lanes(
            &[fork],
            &q.slice_rows(plen, plen + n),
            &k.slice_rows(plen, plen + n),
            &v.slice_rows(plen, plen + n),
        )
        .unwrap();
        assert!(sess.pages_in_use() > before, "verify rows occupy fresh pages");
        sess.release_lane(fork).unwrap();
        assert_eq!(sess.pages_in_use(), before, "rollback returns every verify page");

        // Source lane decodes as if the speculation never happened.
        let o1 = sess
            .decode_step_lanes(&[lane], &at(&q, plen), &at(&k, plen), &at(&v, plen))
            .unwrap();
        let mut clean = AttentionSession::from_spec("dense", cfg).unwrap();
        let c = clean.admit_lane();
        clean.prefill_lane(c, &pfx(&q, plen), &pfx(&k, plen), &pfx(&v, plen), true).unwrap();
        let o2 =
            clean.decode_step_lanes(&[c], &at(&q, plen), &at(&k, plen), &at(&v, plen)).unwrap();
        assert_eq!(o1.data, o2.data, "source lane stream unchanged by fork + rollback");

        // Mid-step OutOfPages during the verify append: the fork is
        // auto-released and the source lane keeps its pages.
        let tight = SessionConfig::new(0, heads, d, d).with_paging(2, 4);
        let mut sess = AttentionSession::from_spec("dense", tight).unwrap();
        let lane = sess.admit_lane();
        sess.prefill_lane(lane, &pfx(&q, 4), &pfx(&k, 4), &pfx(&v, 4), true).unwrap();
        let used = sess.pages_in_use();
        let srcs = sess.lane_seqs(lane).to_vec();
        let fork = sess.admit_lane_from_fork(&srcs, 4).unwrap();
        let e = sess
            .score_lanes(
                &[fork],
                &q.slice_rows(4, 10),
                &k.slice_rows(4, 10),
                &v.slice_rows(4, 10),
            )
            .unwrap_err();
        assert_eq!(e, PageError::OutOfPages);
        assert_eq!(sess.live_lanes(), 1, "failed verify auto-releases the fork");
        assert_eq!(sess.pages_in_use(), used, "source lane pages intact after OOP");
        sess.release_lane(lane).unwrap();
        assert_eq!(sess.pages_in_use(), 0);
    }

    #[test]
    fn pack_unpack_roundtrips_any_index_pair() {
        check("idx pair packing", 64, |g| {
            let a = g.usize_in(0..65536) as u16;
            let b = g.usize_in(0..65536) as u16;
            assert_eq!(unpack_idx(pack_idx(a, b)), (a, b));
        });
        assert_eq!(unpack_idx(pack_idx(u16::MAX, u16::MAX)), (u16::MAX, u16::MAX));
        assert_eq!(unpack_idx(pack_idx(0, 0)), (0, 0));
    }
}
