//! `AttentionSession` — the unified multi-head attention lifecycle the
//! serving stack drives: **prefill** through any tiled [`Engine`]
//! directly into a paged KV cache, then incremental **decode** steps
//! scored from that cache with the engine family's matching scorer
//! (dense dot products, or SFA top-k feature overlap — the same
//! semantics as the [`crate::attention::decode`] caches).
//!
//! Lifecycle: spec string → [`registry`](crate::attention::registry) →
//! [`AttentionSession::prefill`] (K/V appended token-by-token into a
//! [`PagedKvCache`], one sequence per `(batch, head)` pair) →
//! [`AttentionSession::decode_step`] (append the new token, score the
//! 1-row query against the whole cached sequence). Prefill-then-decode
//! through the paged cache is numerically equivalent to a one-shot
//! causal prefill over the concatenated sequence — the session tests
//! pin this for both the dense and the SFA cache layouts.
//!
//! Cache layout follows the engine family: feature-sparse specs store
//! per-token top-k key codes (`SlotLayout::Sparse`, the paper's App-J
//! memory shape), everything else stores dense keys
//! (`SlotLayout::Dense`); values are dense in both.

use crate::attention::decode::{softmax_weighted_sum, topk_row};
use crate::attention::registry::{parse_spec, EngineSpec, SpecError};
use crate::attention::{Engine, HeadTensor, Scorer};
use crate::kv_cache::paged::{PageError, PagedKvCache, SeqId, SlotLayout};
use crate::util::threadpool::{default_threads, parallel_for_dynamic, SendPtr};

/// Pack two u16 feature ids into one f32 payload slot bit-for-bit.
/// `SlotLayout::Sparse` budgets indices at two-per-float; the payload
/// floats are only ever memcpy'd, never arithmetically touched, so any
/// bit pattern (including NaN encodings) survives the round-trip.
#[inline]
fn pack_idx(a: u16, b: u16) -> f32 {
    f32::from_bits(a as u32 | ((b as u32) << 16))
}

#[inline]
fn unpack_idx(x: f32) -> (u16, u16) {
    let bits = x.to_bits();
    ((bits & 0xFFFF) as u16, (bits >> 16) as u16)
}

/// Session geometry + paged-cache sizing.
#[derive(Debug, Clone, Copy)]
pub struct SessionConfig {
    pub batch: usize,
    pub heads: usize,
    /// Q/K feature dim per head.
    pub d: usize,
    /// V dim per head.
    pub d_v: usize,
    /// Tokens per KV page.
    pub page_size: usize,
    /// Page budget across all `(batch, head)` sequences.
    pub max_pages: usize,
}

impl SessionConfig {
    pub fn new(batch: usize, heads: usize, d: usize, d_v: usize) -> SessionConfig {
        SessionConfig { batch, heads, d, d_v, page_size: 16, max_pages: 1 << 20 }
    }

    pub fn with_paging(mut self, page_size: usize, max_pages: usize) -> SessionConfig {
        self.page_size = page_size;
        self.max_pages = max_pages;
        self
    }
}

/// One live multi-head attention session over a paged KV cache.
pub struct AttentionSession {
    cfg: SessionConfig,
    spec: EngineSpec,
    engine: Box<dyn Engine>,
    scorer: Scorer,
    cache: PagedKvCache,
    /// One cache sequence per `(batch, head)` pair, `b * heads + h`.
    seqs: Vec<SeqId>,
    /// Tokens appended so far (uniform across the batch).
    len: usize,
}

impl AttentionSession {
    /// Build a session from a registry spec string.
    pub fn from_spec(spec: &str, cfg: SessionConfig) -> Result<AttentionSession, SpecError> {
        let parsed = parse_spec(spec)?;
        if let Scorer::Sfa { k } = parsed.cache_scorer() {
            if k > cfg.d {
                return Err(SpecError(format!(
                    "{}: feature budget k={k} exceeds head dim d={}",
                    parsed.family(),
                    cfg.d
                )));
            }
        }
        Ok(AttentionSession::new(parsed, cfg))
    }

    /// Panics if the spec's feature budget exceeds `cfg.d` (the
    /// engines' top-k kernels reject k > d); [`Self::from_spec`]
    /// surfaces the same condition as a [`SpecError`].
    pub fn new(spec: EngineSpec, cfg: SessionConfig) -> AttentionSession {
        let scorer = spec.cache_scorer();
        if let Scorer::Sfa { k } = scorer {
            assert!(
                k <= cfg.d,
                "engine feature budget k={k} exceeds head dim d={}",
                cfg.d
            );
        }
        let layout = match scorer {
            Scorer::Dense => SlotLayout::Dense { d: cfg.d, d_v: cfg.d_v },
            Scorer::Sfa { k } => SlotLayout::Sparse { k, d_v: cfg.d_v },
        };
        let mut cache = PagedKvCache::new(cfg.max_pages, cfg.page_size, layout);
        let seqs: Vec<SeqId> = (0..cfg.batch * cfg.heads).map(|_| cache.create_seq()).collect();
        AttentionSession { engine: spec.build(), cfg, spec, scorer, cache, seqs, len: 0 }
    }

    pub fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    pub fn engine_name(&self) -> String {
        self.engine.name()
    }

    pub fn scorer(&self) -> Scorer {
        self.scorer
    }

    /// Tokens cached per sequence so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn pages_in_use(&self) -> usize {
        self.cache.pages_in_use()
    }

    pub fn cache_bytes(&self) -> usize {
        self.cache.bytes_in_use()
    }

    fn check_shapes(&self, q: &HeadTensor, k: &HeadTensor, v: &HeadTensor) {
        assert_eq!((q.batch, q.heads), (self.cfg.batch, self.cfg.heads), "q head grid");
        assert_eq!((k.batch, k.heads), (self.cfg.batch, self.cfg.heads), "k head grid");
        assert_eq!((v.batch, v.heads), (self.cfg.batch, self.cfg.heads), "v head grid");
        assert_eq!(q.d, self.cfg.d, "q feature dim");
        assert_eq!(k.d, self.cfg.d, "k feature dim");
        assert_eq!(v.d, self.cfg.d_v, "v feature dim");
        assert_eq!(k.n, v.n, "k/v length");
    }

    /// Append one token's K/V payload for head-sequence `i`.
    fn push_token(&mut self, i: usize, key: &[f32], val: &[f32]) -> Result<(), PageError> {
        debug_assert_eq!(key.len(), self.cfg.d);
        debug_assert_eq!(val.len(), self.cfg.d_v);
        let payload = match self.cache.layout {
            SlotLayout::Dense { .. } => {
                let mut p = Vec::with_capacity(self.cfg.d + self.cfg.d_v);
                p.extend_from_slice(key);
                p.extend_from_slice(val);
                p
            }
            SlotLayout::Sparse { k, .. } => {
                let (vals, idx) = topk_row(key, k);
                let mut p = Vec::with_capacity(k + k.div_ceil(2) + self.cfg.d_v);
                p.extend_from_slice(&vals);
                for pair in idx.chunks(2) {
                    p.push(pack_idx(pair[0], if pair.len() > 1 { pair[1] } else { 0 }));
                }
                p.extend_from_slice(val);
                p
            }
        };
        self.cache.append(self.seqs[i], &payload)
    }

    /// Prefill `k.n` tokens: appends every K/V token into the paged
    /// cache, then runs the engine's multi-head batched forward. Must
    /// be the first call on a fresh session — the forward only attends
    /// within this prefill, so a second prefill's outputs would
    /// silently ignore the already-cached prefix.
    pub fn prefill(
        &mut self,
        q: &HeadTensor,
        k: &HeadTensor,
        v: &HeadTensor,
        causal: bool,
    ) -> Result<HeadTensor, PageError> {
        assert!(
            self.is_empty(),
            "prefill must be the first call on a fresh session \
             (chunked prefill is not supported yet — use decode_step)"
        );
        self.check_shapes(q, k, v);
        for i in 0..self.seqs.len() {
            let (b, h) = (i / self.cfg.heads, i % self.cfg.heads);
            for t in 0..k.n {
                self.push_token(i, k.head_row(b, h, t), v.head_row(b, h, t))?;
            }
        }
        self.len += k.n;
        Ok(self.engine.forward_batched(q, k, v, causal))
    }

    /// One decode step: append the new token's K/V for every head, then
    /// score each head's 1-row query against its full cached sequence
    /// (the new token attends to everything up to and including
    /// itself — the causal TTNT semantics).
    pub fn decode_step(
        &mut self,
        q: &HeadTensor,
        k: &HeadTensor,
        v: &HeadTensor,
    ) -> Result<HeadTensor, PageError> {
        self.check_shapes(q, k, v);
        assert_eq!(q.n, 1, "decode_step takes exactly one new token");
        for i in 0..self.seqs.len() {
            let (b, h) = (i / self.cfg.heads, i % self.cfg.heads);
            self.push_token(i, k.head_row(b, h, 0), v.head_row(b, h, 0))?;
        }
        self.len += 1;

        let mut out = HeadTensor::zeros(self.cfg.batch, self.cfg.heads, 1, self.cfg.d_v);
        let hv = self.cfg.d_v;
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let this: &AttentionSession = self;
        let bh = this.seqs.len();
        let threads = default_threads().min(bh.max(1));
        parallel_for_dynamic(bh, threads, 1, move |i| {
            let (b, h) = (i / this.cfg.heads, i % this.cfg.heads);
            // SAFETY: each head owns a disjoint output range.
            let dst =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(i * hv), hv) };
            this.decode_head(i, q.head_row(b, h, 0), dst);
        });
        Ok(out)
    }

    /// Score one head's query row against its cached sequence and write
    /// the softmax-weighted V sum into `out`.
    fn decode_head(&self, i: usize, q: &[f32], out: &mut [f32]) {
        let d = self.cfg.d;
        let d_v = self.cfg.d_v;
        let scale = 1.0 / (d as f32).sqrt();
        let slots = self.cache.token_slices(self.seqs[i]).expect("session sequence exists");
        let mut scores: Vec<(u32, f32)> = Vec::with_capacity(slots.len());
        match self.scorer {
            Scorer::Dense => {
                for (j, slot) in slots.iter().enumerate() {
                    let mut acc = 0.0;
                    for t in 0..d {
                        acc += q[t] * slot[t];
                    }
                    scores.push((j as u32, acc * scale));
                }
                softmax_weighted_sum(&scores, |j| slots[j][d..].as_ptr(), d_v, out);
            }
            Scorer::Sfa { k } => {
                let (qv, qi) = topk_row(q, k);
                let v_off = k + k.div_ceil(2);
                for (j, slot) in slots.iter().enumerate() {
                    let mut acc = 0.0;
                    for (&qval, &qf) in qv.iter().zip(&qi) {
                        if qval == 0.0 {
                            continue;
                        }
                        for (pos, &kval) in slot[..k].iter().enumerate() {
                            if kval == 0.0 {
                                continue;
                            }
                            let pair = unpack_idx(slot[k + pos / 2]);
                            let kf = if pos % 2 == 0 { pair.0 } else { pair.1 };
                            if kf == qf {
                                acc += qval * kval;
                            }
                        }
                    }
                    scores.push((j as u32, acc * scale));
                }
                softmax_weighted_sum(&scores, |j| slots[j][v_off..].as_ptr(), d_v, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::registry::build_engine;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn full_qkv(
        batch: usize,
        heads: usize,
        n: usize,
        d: usize,
        seed: u64,
    ) -> (HeadTensor, HeadTensor, HeadTensor) {
        let mut rng = Rng::new(seed);
        (
            HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0),
            HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0),
            HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0),
        )
    }

    /// Prefill `n0` tokens then decode `steps` more; every output row
    /// must match the one-shot causal forward over all `n0 + steps`
    /// tokens within `tol`.
    fn assert_session_matches_one_shot(spec: &str, tol: f32) {
        let (batch, heads, d) = (2, 2, 16);
        let (n0, steps) = (12, 6);
        let n = n0 + steps;
        let (q, k, v) = full_qkv(batch, heads, n, d, 42);
        let full = build_engine(spec).unwrap().forward_batched(&q, &k, &v, true);

        let cfg = SessionConfig::new(batch, heads, d, d).with_paging(4, 4096);
        let mut sess = AttentionSession::from_spec(spec, cfg).unwrap();
        let pre = sess
            .prefill(&q.slice_rows(0, n0), &k.slice_rows(0, n0), &v.slice_rows(0, n0), true)
            .unwrap();
        assert_eq!(sess.len(), n0);
        for b in 0..batch {
            for h in 0..heads {
                for t in 0..n0 {
                    for (a, e) in pre.head_row(b, h, t).iter().zip(full.head_row(b, h, t)) {
                        assert!(
                            (a - e).abs() < tol,
                            "{spec}: prefill row {t} head ({b},{h}): {a} vs {e}"
                        );
                    }
                }
            }
        }
        for s in 0..steps {
            let t = n0 + s;
            let o = sess
                .decode_step(
                    &q.slice_rows(t, t + 1),
                    &k.slice_rows(t, t + 1),
                    &v.slice_rows(t, t + 1),
                )
                .unwrap();
            for b in 0..batch {
                for h in 0..heads {
                    for (a, e) in o.head_row(b, h, 0).iter().zip(full.head_row(b, h, t)) {
                        assert!(
                            (a - e).abs() < tol,
                            "{spec}: decode step {s} head ({b},{h}): {a} vs {e}"
                        );
                    }
                }
            }
        }
        assert_eq!(sess.len(), n);
    }

    #[test]
    fn session_equivalence_dense_layout_flash() {
        assert_session_matches_one_shot("flash_dense:bq=8,bk=8", 3e-5);
    }

    #[test]
    fn session_equivalence_dense_layout_naive() {
        assert_session_matches_one_shot("dense", 3e-5);
    }

    #[test]
    fn session_equivalence_sfa_layout_flash() {
        assert_session_matches_one_shot("sfa:k=8,bq=8,bk=8", 3e-5);
    }

    #[test]
    fn session_equivalence_sfa_layout_reference() {
        assert_session_matches_one_shot("sfa_ref:k=4", 3e-5);
    }

    #[test]
    fn sparse_layout_uses_fewer_cache_bytes() {
        let (batch, heads, d, n) = (1, 2, 64, 40);
        let (q, k, v) = full_qkv(batch, heads, n, d, 7);
        let cfg = SessionConfig::new(batch, heads, d, d).with_paging(8, 4096);
        let mut dense = AttentionSession::from_spec("flash_dense", cfg).unwrap();
        let mut sparse = AttentionSession::from_spec("sfa:k=8", cfg).unwrap();
        dense.prefill(&q, &k, &v, true).unwrap();
        sparse.prefill(&q, &k, &v, true).unwrap();
        assert!(sparse.cache_bytes() < dense.cache_bytes());
        assert_eq!(dense.len(), n);
        assert_eq!(sparse.len(), n);
    }

    #[test]
    fn out_of_pages_surfaces_the_cache_error() {
        let (batch, heads, d, n) = (1, 1, 8, 12);
        let (q, k, v) = full_qkv(batch, heads, n, d, 3);
        let cfg = SessionConfig::new(batch, heads, d, d).with_paging(2, 1);
        let mut sess = AttentionSession::from_spec("dense", cfg).unwrap();
        assert_eq!(sess.prefill(&q, &k, &v, true).unwrap_err(), PageError::OutOfPages);
    }

    #[test]
    fn oversized_feature_budget_is_rejected() {
        let cfg = SessionConfig::new(1, 1, 16, 16);
        let e = AttentionSession::from_spec("sfa:k=128", cfg).unwrap_err();
        assert!(e.0.contains("exceeds head dim"), "{e}");
    }

    #[test]
    #[should_panic(expected = "prefill must be the first call")]
    fn second_prefill_is_rejected() {
        let (batch, heads, d, n) = (1, 1, 8, 4);
        let (q, k, v) = full_qkv(batch, heads, n, d, 5);
        let mut sess =
            AttentionSession::from_spec("dense", SessionConfig::new(batch, heads, d, d)).unwrap();
        sess.prefill(&q, &k, &v, true).unwrap();
        let _ = sess.prefill(&q, &k, &v, true);
    }

    #[test]
    fn pack_unpack_roundtrips_any_index_pair() {
        check("idx pair packing", 64, |g| {
            let a = g.usize_in(0..65536) as u16;
            let b = g.usize_in(0..65536) as u16;
            assert_eq!(unpack_idx(pack_idx(a, b)), (a, b));
        });
        assert_eq!(unpack_idx(pack_idx(u16::MAX, u16::MAX)), (u16::MAX, u16::MAX));
        assert_eq!(unpack_idx(pack_idx(0, 0)), (0, 0));
    }
}
