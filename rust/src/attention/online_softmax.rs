//! The FlashAttention online-softmax recurrence (paper §3.2), shared by
//! the flash-dense and FlashSFA engines.
//!
//! State per query row: running max m, running denominator l, and the
//! un-normalized output accumulator acc (length d_v). Feeding score
//! tiles in any left-to-right order and calling [`OnlineSoftmax::finish`]
//! yields exactly softmax(S)·V without materializing S.

use crate::attention::NEG_INF;

/// Online softmax state for a block of query rows.
#[derive(Debug, Clone)]
pub struct OnlineSoftmax {
    pub rows: usize,
    pub d_v: usize,
    pub m: Vec<f32>,
    pub l: Vec<f32>,
    pub acc: Vec<f32>, // rows × d_v, row-major
}

impl OnlineSoftmax {
    pub fn new(rows: usize, d_v: usize) -> Self {
        OnlineSoftmax {
            rows,
            d_v,
            m: vec![NEG_INF; rows],
            l: vec![0.0; rows],
            acc: vec![0.0; rows * d_v],
        }
    }

    /// Consume one score tile: `scores` is rows × tile_w (row-major),
    /// `v_tile` is tile_w × d_v (row-major slice accessor).
    ///
    /// Masked-out entries must already be NEG_INF in `scores`.
    pub fn update(&mut self, scores: &[f32], tile_w: usize, v_tile: impl Fn(usize) -> *const f32) {
        debug_assert_eq!(scores.len(), self.rows * tile_w);
        for r in 0..self.rows {
            let srow = &scores[r * tile_w..(r + 1) * tile_w];
            let mut tile_max = NEG_INF;
            for &s in srow {
                tile_max = tile_max.max(s);
            }
            if tile_max <= NEG_INF {
                continue; // fully masked tile for this row
            }
            let m_new = self.m[r].max(tile_max);
            let alpha = if self.m[r] <= NEG_INF { 0.0 } else { (self.m[r] - m_new).exp() };
            let acc_row = &mut self.acc[r * self.d_v..(r + 1) * self.d_v];
            if alpha != 1.0 {
                for a in acc_row.iter_mut() {
                    *a *= alpha;
                }
                self.l[r] *= alpha;
            }
            let mut lsum = 0.0;
            for (c, &s) in srow.iter().enumerate() {
                if s <= NEG_INF {
                    continue;
                }
                let p = (s - m_new).exp();
                lsum += p;
                // acc += p * v_row(c)
                let vp = v_tile(c);
                unsafe {
                    for t in 0..self.d_v {
                        acc_row[t] += p * *vp.add(t);
                    }
                }
            }
            self.l[r] += lsum;
            self.m[r] = m_new;
        }
    }

    /// Normalize into the output block (rows × d_v). Rows that never saw
    /// an unmasked score produce zeros.
    pub fn finish(self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.rows * self.d_v);
        for r in 0..self.rows {
            let l = self.l[r];
            let acc_row = &self.acc[r * self.d_v..(r + 1) * self.d_v];
            let out_row = &mut out[r * self.d_v..(r + 1) * self.d_v];
            if l > 0.0 {
                let inv = 1.0 / l;
                for (o, a) in out_row.iter_mut().zip(acc_row) {
                    *o = a * inv;
                }
            } else {
                out_row.fill(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::matrix::{assert_close, Matrix};
    use crate::util::prop::check;

    /// Naive reference: softmax over the full row then weighted sum.
    fn naive(scores: &Matrix, v: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(scores.rows, v.cols);
        for i in 0..scores.rows {
            let row = scores.row(i);
            let m = row.iter().fold(NEG_INF, |a, &b| a.max(b));
            if m <= NEG_INF {
                continue;
            }
            let exps: Vec<f32> = row.iter().map(|&s| if s <= NEG_INF { 0.0 } else { (s - m).exp() }).collect();
            let l: f32 = exps.iter().sum();
            for (j, &p) in exps.iter().enumerate() {
                if p == 0.0 {
                    continue;
                }
                for t in 0..v.cols {
                    out.data[i * v.cols + t] += p / l * v.get(j, t);
                }
            }
        }
        out
    }

    fn run_tiled(scores: &Matrix, v: &Matrix, tile_w: usize) -> Matrix {
        let mut os = OnlineSoftmax::new(scores.rows, v.cols);
        let n = scores.cols;
        let mut j0 = 0;
        while j0 < n {
            let w = tile_w.min(n - j0);
            let mut tile = vec![0f32; scores.rows * w];
            for r in 0..scores.rows {
                tile[r * w..(r + 1) * w].copy_from_slice(&scores.row(r)[j0..j0 + w]);
            }
            let vdata = &v.data;
            let cols = v.cols;
            os.update(&tile, w, |c| vdata[(j0 + c) * cols..].as_ptr());
            j0 += w;
        }
        let mut out = Matrix::zeros(scores.rows, v.cols);
        os.finish(&mut out.data);
        out
    }

    #[test]
    fn matches_naive_any_tiling() {
        check("online softmax == naive", 48, |g| {
            let n = g.usize_in(1..40);
            let rows = g.usize_in(1..6);
            let dv = g.usize_in(1..10);
            let tile = g.usize_in(1..n + 1);
            let s = Matrix::from_vec(rows, n, g.vec_normal(rows * n, 3.0));
            let v = Matrix::from_vec(n, dv, g.vec_normal(n * dv, 1.0));
            let a = run_tiled(&s, &v, tile);
            let b = naive(&s, &v);
            assert_close(&a, &b, 1e-5, 1e-6);
        });
    }

    #[test]
    fn handles_masked_entries() {
        let mut s = Matrix::from_vec(2, 4, vec![1.0, NEG_INF, 0.5, NEG_INF,
                                                NEG_INF, NEG_INF, NEG_INF, NEG_INF]);
        let v = Matrix::from_vec(4, 2, vec![1., 0., 0., 1., 2., 2., 3., 3.]);
        let out = run_tiled(&s, &v, 2);
        let expected = naive(&s, &v);
        assert_close(&out, &expected, 1e-6, 1e-7);
        // Fully masked row yields zeros.
        assert_eq!(&out.data[2..4], &[0.0, 0.0]);
        s.set(0, 0, 1.0);
    }

    #[test]
    fn numerically_stable_for_large_scores() {
        let s = Matrix::from_vec(1, 3, vec![500.0, 499.0, -500.0]);
        let v = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let out = run_tiled(&s, &v, 1);
        assert!(out.data[0].is_finite());
        let e = 1.0 / (1.0 + (-1.0f32).exp());
        let expect = e * 1.0 + (1.0 - e) * 2.0;
        assert!((out.data[0] - expect).abs() < 1e-3);
    }
}
