//! The FlashAttention online-softmax recurrence (paper §3.2), shared by
//! the flash-dense and FlashSFA engines.
//!
//! State per query row: running max m, running denominator l, and the
//! un-normalized output accumulator acc (length d_v). Feeding score
//! tiles in any left-to-right order and calling [`OnlineSoftmax::finish`]
//! yields exactly softmax(S)·V without materializing S.

use crate::attention::NEG_INF;

/// Online softmax state for a block of query rows.
#[derive(Debug, Clone)]
pub struct OnlineSoftmax {
    pub rows: usize,
    pub d_v: usize,
    pub m: Vec<f32>,
    pub l: Vec<f32>,
    pub acc: Vec<f32>, // rows × d_v, row-major
}

impl OnlineSoftmax {
    pub fn new(rows: usize, d_v: usize) -> Self {
        OnlineSoftmax {
            rows,
            d_v,
            m: vec![NEG_INF; rows],
            l: vec![0.0; rows],
            acc: vec![0.0; rows * d_v],
        }
    }

    /// Re-initialize for a new `(rows, d_v)` block, keeping the
    /// allocations — the per-worker scratch reuse path of the tiled
    /// engines.
    pub fn reset(&mut self, rows: usize, d_v: usize) {
        self.rows = rows;
        self.d_v = d_v;
        self.m.clear();
        self.m.resize(rows, NEG_INF);
        self.l.clear();
        self.l.resize(rows, 0.0);
        self.acc.clear();
        self.acc.resize(rows * d_v, 0.0);
    }

    /// The running row maximum (NEG_INF until the row sees an unmasked
    /// score) — the block-skipping classifier compares tile upper
    /// bounds against this.
    #[inline]
    pub fn row_max(&self, r: usize) -> f32 {
        self.m[r]
    }

    /// Consume one score tile: `scores` is rows × tile_w (row-major),
    /// `v_tile` is tile_w × d_v (row-major slice accessor).
    ///
    /// Masked-out entries must already be NEG_INF in `scores`.
    pub fn update(&mut self, scores: &[f32], tile_w: usize, v_tile: impl Fn(usize) -> *const f32) {
        debug_assert_eq!(scores.len(), self.rows * tile_w);
        for r in 0..self.rows {
            let srow = &scores[r * tile_w..(r + 1) * tile_w];
            let mut tile_max = NEG_INF;
            for &s in srow {
                tile_max = tile_max.max(s);
            }
            if tile_max <= NEG_INF {
                continue; // fully masked tile for this row
            }
            let m_new = self.m[r].max(tile_max);
            let alpha = if self.m[r] <= NEG_INF { 0.0 } else { (self.m[r] - m_new).exp() };
            let acc_row = &mut self.acc[r * self.d_v..(r + 1) * self.d_v];
            if alpha != 1.0 {
                for a in acc_row.iter_mut() {
                    *a *= alpha;
                }
                self.l[r] *= alpha;
            }
            let mut lsum = 0.0;
            for (c, &s) in srow.iter().enumerate() {
                if s <= NEG_INF {
                    continue;
                }
                let p = (s - m_new).exp();
                lsum += p;
                // acc += p * v_row(c)
                let vp = v_tile(c);
                unsafe {
                    for t in 0..self.d_v {
                        acc_row[t] += p * *vp.add(t);
                    }
                }
            }
            self.l[r] += lsum;
            self.m[r] = m_new;
        }
    }

    /// Fold a whole tile of `width` keys that all share one unmasked
    /// score `s` for every row, given `v_sum` = the column sum of the
    /// tile's V rows. Mathematically equal to [`Self::update`] on a
    /// constant score tile, but O(d_v) per row instead of
    /// O(width · d_v) — the FlashSFA empty-tile fast path (zero-overlap
    /// keys score 0 yet still participate in the softmax).
    pub fn fold_uniform(&mut self, s: f32, width: usize, v_sum: &[f32]) {
        debug_assert_eq!(v_sum.len(), self.d_v);
        if width == 0 {
            return;
        }
        let w = width as f32;
        for r in 0..self.rows {
            let m_new = self.m[r].max(s);
            let alpha = if self.m[r] <= NEG_INF { 0.0 } else { (self.m[r] - m_new).exp() };
            let acc_row = &mut self.acc[r * self.d_v..(r + 1) * self.d_v];
            if alpha != 1.0 {
                for a in acc_row.iter_mut() {
                    *a *= alpha;
                }
                self.l[r] *= alpha;
            }
            let p = (s - m_new).exp();
            self.l[r] += p * w;
            for (a, &vs) in acc_row.iter_mut().zip(v_sum) {
                *a += p * vs;
            }
            self.m[r] = m_new;
        }
    }

    /// Normalize into the output block (rows × d_v). Rows that never saw
    /// an unmasked score produce zeros.
    pub fn finish(self, out: &mut [f32]) {
        self.finish_into(out);
    }

    /// Non-consuming [`Self::finish`] — scratch-reuse callers normalize
    /// and then [`Self::reset`] the same state for the next tile.
    pub fn finish_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.rows * self.d_v);
        for r in 0..self.rows {
            let l = self.l[r];
            let acc_row = &self.acc[r * self.d_v..(r + 1) * self.d_v];
            let out_row = &mut out[r * self.d_v..(r + 1) * self.d_v];
            if l > 0.0 {
                let inv = 1.0 / l;
                for (o, a) in out_row.iter_mut().zip(acc_row) {
                    *o = a * inv;
                }
            } else {
                out_row.fill(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::matrix::{assert_close, Matrix};
    use crate::util::prop::check;

    /// Naive reference: softmax over the full row then weighted sum.
    fn naive(scores: &Matrix, v: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(scores.rows, v.cols);
        for i in 0..scores.rows {
            let row = scores.row(i);
            let m = row.iter().fold(NEG_INF, |a, &b| a.max(b));
            if m <= NEG_INF {
                continue;
            }
            let exps: Vec<f32> = row.iter().map(|&s| if s <= NEG_INF { 0.0 } else { (s - m).exp() }).collect();
            let l: f32 = exps.iter().sum();
            for (j, &p) in exps.iter().enumerate() {
                if p == 0.0 {
                    continue;
                }
                for t in 0..v.cols {
                    out.data[i * v.cols + t] += p / l * v.get(j, t);
                }
            }
        }
        out
    }

    fn run_tiled(scores: &Matrix, v: &Matrix, tile_w: usize) -> Matrix {
        let mut os = OnlineSoftmax::new(scores.rows, v.cols);
        let n = scores.cols;
        let mut j0 = 0;
        while j0 < n {
            let w = tile_w.min(n - j0);
            let mut tile = vec![0f32; scores.rows * w];
            for r in 0..scores.rows {
                tile[r * w..(r + 1) * w].copy_from_slice(&scores.row(r)[j0..j0 + w]);
            }
            let vdata = &v.data;
            let cols = v.cols;
            os.update(&tile, w, |c| vdata[(j0 + c) * cols..].as_ptr());
            j0 += w;
        }
        let mut out = Matrix::zeros(scores.rows, v.cols);
        os.finish(&mut out.data);
        out
    }

    #[test]
    fn matches_naive_any_tiling() {
        check("online softmax == naive", 48, |g| {
            let n = g.usize_in(1..40);
            let rows = g.usize_in(1..6);
            let dv = g.usize_in(1..10);
            let tile = g.usize_in(1..n + 1);
            let s = Matrix::from_vec(rows, n, g.vec_normal(rows * n, 3.0));
            let v = Matrix::from_vec(n, dv, g.vec_normal(n * dv, 1.0));
            let a = run_tiled(&s, &v, tile);
            let b = naive(&s, &v);
            assert_close(&a, &b, 1e-5, 1e-6);
        });
    }

    #[test]
    fn handles_masked_entries() {
        let mut s = Matrix::from_vec(2, 4, vec![1.0, NEG_INF, 0.5, NEG_INF,
                                                NEG_INF, NEG_INF, NEG_INF, NEG_INF]);
        let v = Matrix::from_vec(4, 2, vec![1., 0., 0., 1., 2., 2., 3., 3.]);
        let out = run_tiled(&s, &v, 2);
        let expected = naive(&s, &v);
        assert_close(&out, &expected, 1e-6, 1e-7);
        // Fully masked row yields zeros.
        assert_eq!(&out.data[2..4], &[0.0, 0.0]);
        s.set(0, 0, 1.0);
    }

    #[test]
    fn fold_uniform_matches_update_on_constant_tile() {
        check("fold_uniform == update(const tile)", 48, |g| {
            let rows = g.usize_in(1..6);
            let dv = g.usize_in(1..10);
            let w = g.usize_in(1..12);
            let s = g.f32_in(-4.0..4.0);
            let pre = g.usize_in(0..10);
            // Shared prefix of random scores so both states start from
            // a non-trivial (m, l, acc).
            let spre = Matrix::from_vec(rows, pre.max(1), g.vec_normal(rows * pre.max(1), 2.0));
            let vpre = Matrix::from_vec(pre.max(1), dv, g.vec_normal(pre.max(1) * dv, 1.0));
            let vtile = Matrix::from_vec(w, dv, g.vec_normal(w * dv, 1.0));
            let mut a = OnlineSoftmax::new(rows, dv);
            let mut b = OnlineSoftmax::new(rows, dv);
            if pre > 0 {
                for os in [&mut a, &mut b] {
                    let vdata = &vpre.data;
                    os.update(&spre.data[..rows * pre], pre, |c| vdata[c * dv..].as_ptr());
                }
            }
            // a: explicit constant tile through update.
            let tile = vec![s; rows * w];
            let vdata = &vtile.data;
            a.update(&tile, w, |c| vdata[c * dv..].as_ptr());
            // b: the O(1)-per-row fold over the same tile.
            let mut v_sum = vec![0f32; dv];
            for c in 0..w {
                for t in 0..dv {
                    v_sum[t] += vtile.get(c, t);
                }
            }
            b.fold_uniform(s, w, &v_sum);
            let mut oa = vec![0f32; rows * dv];
            let mut ob = vec![0f32; rows * dv];
            a.finish_into(&mut oa);
            b.finish_into(&mut ob);
            for (x, y) in oa.iter().zip(&ob) {
                assert!((x - y).abs() <= 1e-5 + 1e-5 * y.abs(), "{x} vs {y}");
            }
        });
    }

    #[test]
    fn reset_reuses_state_like_fresh() {
        let s = Matrix::from_vec(2, 3, vec![1.0, -0.5, 2.0, 0.0, 0.3, -1.0]);
        let v = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let fresh = run_tiled(&s, &v, 2);
        let mut os = OnlineSoftmax::new(5, 2);
        let vdata = &v.data;
        os.update(&[9.0; 15], 3, |c| vdata[c * 2..].as_ptr());
        os.reset(2, 2);
        assert_eq!(os.row_max(0), NEG_INF);
        let mut j0 = 0;
        while j0 < 3 {
            let w = 2.min(3 - j0);
            let mut tile = vec![0f32; 2 * w];
            for r in 0..2 {
                tile[r * w..(r + 1) * w].copy_from_slice(&s.row(r)[j0..j0 + w]);
            }
            os.update(&tile, w, |c| vdata[(j0 + c) * 2..].as_ptr());
            j0 += w;
        }
        let mut out = Matrix::zeros(2, 2);
        os.finish_into(&mut out.data);
        assert_close(&out, &fresh, 0.0, 0.0);
        assert!(os.row_max(0) > NEG_INF);
    }

    #[test]
    fn numerically_stable_for_large_scores() {
        let s = Matrix::from_vec(1, 3, vec![500.0, 499.0, -500.0]);
        let v = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let out = run_tiled(&s, &v, 1);
        assert!(out.data[0].is_finite());
        let e = 1.0 / (1.0 + (-1.0f32).exp());
        let expect = e * 1.0 + (1.0 - e) * 2.0;
        assert!((out.data[0] - expect).abs() < 1e-3);
    }
}
