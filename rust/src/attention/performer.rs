//! Performer / FAVOR+ random-feature attention (Choromanski et al.,
//! 2021) — the kernel-approximation baseline of Table 11. Linear-time
//! but *approximate*; the paper contrasts this with SFA's exactness
//! over learned supports.

use crate::attention::Engine;
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct PerformerAttention {
    /// Number of random features m.
    pub features: usize,
    pub seed: u64,
}

impl PerformerAttention {
    pub fn new(features: usize) -> Self {
        PerformerAttention { features, seed: 0 }
    }

    /// Positive random features φ(x) = exp(ωᵀx̂ − ‖x̂‖²/2)/√m with
    /// x̂ = x / d^(1/4) (so φ(q)·φ(k) ≈ exp(qᵀk/√d), the softmax kernel).
    fn phi(&self, x: &Matrix, omega: &Matrix) -> Matrix {
        let d = x.cols;
        let root = (d as f32).powf(0.25);
        let mut xs = x.clone();
        for v in xs.data.iter_mut() {
            *v /= root;
        }
        let proj = xs.matmul(omega); // (n, m)
        let mut out = Matrix::zeros(x.rows, self.features);
        let inv_sqrt_m = 1.0 / (self.features as f32).sqrt();
        for i in 0..x.rows {
            let norm2: f32 = xs.row(i).iter().map(|v| v * v).sum();
            let prow = proj.row(i);
            let orow = out.row_mut(i);
            for (o, &p) in orow.iter_mut().zip(prow) {
                // Clamp the exponent for numerical robustness.
                *o = (p - 0.5 * norm2).clamp(-30.0, 30.0).exp() * inv_sqrt_m;
            }
        }
        out
    }
}

impl Engine for PerformerAttention {
    fn name(&self) -> String {
        format!("performer_m{}", self.features)
    }

    fn spec(&self) -> String {
        format!("performer:m={},seed={}", self.features, self.seed)
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        let d = q.cols;
        let mut rng = Rng::new(self.seed);
        let omega = Matrix::randn(d, self.features, &mut rng, 1.0);
        let qf = self.phi(q, &omega); // (n, m)
        let kf = self.phi(k, &omega); // (n, m)
        let n = q.rows;
        let m = self.features;
        let dv = v.cols;
        let mut out = Matrix::zeros(n, dv);
        if causal {
            // Prefix-sum linear attention: S_t = Σ_{j<=t} φ(k_j) v_jᵀ,
            // z_t = Σ_{j<=t} φ(k_j); o_t = (φ(q_t)ᵀ S_t) / (φ(q_t)ᵀ z_t).
            let mut s = vec![0f32; m * dv];
            let mut z = vec![0f32; m];
            for t in 0..n {
                let kf_row = kf.row(t);
                let v_row = v.row(t);
                for a in 0..m {
                    let kfa = kf_row[a];
                    if kfa != 0.0 {
                        z[a] += kfa;
                        let srow = &mut s[a * dv..(a + 1) * dv];
                        for (sv, &vv) in srow.iter_mut().zip(v_row) {
                            *sv += kfa * vv;
                        }
                    }
                }
                let qf_row = qf.row(t);
                let mut denom = 1e-9;
                for a in 0..m {
                    denom += qf_row[a] * z[a];
                }
                let orow = out.row_mut(t);
                for a in 0..m {
                    let qa = qf_row[a];
                    if qa != 0.0 {
                        let srow = &s[a * dv..(a + 1) * dv];
                        for (o, &sv) in orow.iter_mut().zip(srow) {
                            *o += qa * sv;
                        }
                    }
                }
                for o in orow.iter_mut() {
                    *o /= denom;
                }
            }
        } else {
            // O = φ(Q) (φ(K)ᵀ V) / (φ(Q) (φ(K)ᵀ 1))
            let ktv = kf.transpose().matmul(v); // (m, dv)
            let num = qf.matmul(&ktv); // (n, dv)
            let mut z = vec![0f32; m];
            for i in 0..n {
                for (a, &x) in kf.row(i).iter().enumerate() {
                    z[a] += x;
                }
            }
            for i in 0..n {
                let mut denom = 1e-9;
                for (a, &x) in qf.row(i).iter().enumerate() {
                    denom += x * z[a];
                }
                for t in 0..dv {
                    out.set(i, t, num.get(i, t) / denom);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::DenseAttention;
    use crate::attention::testutil::qkv;

    #[test]
    fn approximates_dense_attention() {
        // With many random features the estimate should be close in a
        // relative-Frobenius sense (it is a Monte-Carlo approximation
        // whose variance grows with the score magnitude, so the test
        // uses moderate-scale inputs).
        let (mut q, mut k, v) = qkv(32, 16, 16, 0);
        for x in q.data.iter_mut() {
            *x *= 0.5;
        }
        for x in k.data.iter_mut() {
            *x *= 0.5;
        }
        let approx = PerformerAttention { features: 1024, seed: 1 }.forward(&q, &k, &v, false);
        let exact = DenseAttention.forward(&q, &k, &v, false);
        let mut err = Matrix::zeros(32, 16);
        for i in 0..err.data.len() {
            err.data[i] = approx.data[i] - exact.data[i];
        }
        let rel = err.fro_norm() / exact.fro_norm();
        assert!(rel < 0.35, "relative error {rel}");
    }

    #[test]
    fn causal_output_finite_and_causal() {
        let (q, mut k, mut v) = qkv(48, 16, 16, 2);
        let eng = PerformerAttention { features: 64, seed: 3 };
        let o1 = eng.forward(&q, &k, &v, true);
        assert!(o1.data.iter().all(|x| x.is_finite()));
        for i in 30..48 {
            k.row_mut(i).fill(5.0);
            v.row_mut(i).fill(-5.0);
        }
        let o2 = eng.forward(&q, &k, &v, true);
        crate::util::matrix::assert_close(&o1.head_rows(30), &o2.head_rows(30), 1e-5, 1e-6);
    }

    #[test]
    fn more_features_reduce_error() {
        let (q, k, v) = qkv(24, 8, 8, 4);
        let exact = DenseAttention.forward(&q, &k, &v, false);
        let errs: Vec<f32> = [16, 1024]
            .iter()
            .map(|&m| {
                let approx = PerformerAttention { features: m, seed: 5 }
                    .forward(&q, &k, &v, false);
                let mut diff = 0.0;
                for i in 0..exact.data.len() {
                    diff += (approx.data[i] - exact.data[i]).powi(2);
                }
                diff.sqrt() / exact.fro_norm()
            })
            .collect();
        assert!(errs[1] < errs[0], "{errs:?}");
    }
}
