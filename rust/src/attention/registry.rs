//! Engine registry — construct any attention engine from a compact,
//! human-typable spec string (the CLI `--engine` surface and the
//! spec-driven bench grids).
//!
//! Grammar: `family[:key=value[,key=value]*]`, e.g.
//!
//! ```text
//! dense
//! flash_dense:bq=64,bk=64
//! sfa:k=8,bq=64,bk=64            (alias: flash_sfa)
//! sfa:k=8,skip=on,thresh=8      (block-skipping FlashSFA; thresh
//!                                 optional, 0 = exact empty-tile folds)
//! sfa:k=8,skip=on,mass=0.01     (auto-tuned threshold: derives
//!                                 thresh = ln(n/mass) at forward time so
//!                                 the dropped mass per row is bounded by
//!                                 `mass`; mutually exclusive with thresh)
//! sfa_ref:k=8
//! window:w=256,scorer=sfa_k8
//! lowrank:r=16,iters=6,seed=0,scorer=dense
//! mla:r=16,seed=0,scorer=sfa_k4
//! performer:m=128,seed=0
//! quant:scorer=sfa_k8
//! ```
//!
//! Omitted keys take the family defaults shown above. Every engine's
//! [`Engine::spec`] returns its canonical spec string, and
//! `parse_spec(engine.spec())` round-trips to the same configuration.
//! Thread counts are deliberately *not* part of a spec — pin them with
//! the `SFA_THREADS` env var (see [`crate::util::threadpool`]) so a
//! spec means the same engine on every machine.

use std::collections::BTreeMap;
use std::fmt;

use crate::attention::dense::{DenseAttention, SfaReference};
use crate::attention::flash_dense::FlashDense;
use crate::attention::flash_sfa::FlashSfa;
use crate::attention::lowrank::LowRankAttention;
use crate::attention::mla::MlaAttention;
use crate::attention::performer::PerformerAttention;
use crate::attention::quant::QuantAttention;
use crate::attention::window::WindowAttention;
use crate::attention::{Engine, Scorer};
use crate::util::spec::tokenize;
use crate::util::threadpool::default_threads;

/// Every spec family the registry understands (alias `flash_sfa` maps
/// onto `sfa`).
pub const FAMILIES: &[&str] = &[
    "dense",
    "flash_dense",
    "sfa",
    "sfa_ref",
    "window",
    "lowrank",
    "mla",
    "performer",
    "quant",
];

/// Spec parse/build error with a human-oriented message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecError(pub String);

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "engine spec error: {}", self.0)
    }
}

impl std::error::Error for SpecError {}

fn err(msg: impl Into<String>) -> SpecError {
    SpecError(msg.into())
}

/// Parsed, typed engine specification — one variant per engine family.
/// (`FlashSfa::thresh` is an `f32`, so the enum is `PartialEq` but not
/// `Eq` — specs are compared, never used as map keys.)
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EngineSpec {
    Dense,
    SfaRef { k: usize },
    FlashDense { bq: usize, bk: usize },
    FlashSfa { k: usize, bq: usize, bk: usize, skip: bool, thresh: f32, mass: f32 },
    Window { w: usize, scorer: Scorer },
    LowRank { r: usize, iters: usize, seed: u64, scorer: Scorer },
    Mla { r: usize, seed: u64, scorer: Scorer },
    Performer { m: usize, seed: u64 },
    Quant { scorer: Scorer },
}

/// Key-value bag for one spec's parameters; every key must be consumed.
struct Params<'a> {
    family: &'a str,
    map: BTreeMap<&'a str, &'a str>,
}

impl<'a> Params<'a> {
    fn take_usize(&mut self, key: &str, default: usize) -> Result<usize, SpecError> {
        match self.map.remove(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                err(format!(
                    "{}: key {key:?} expects a non-negative integer, got {v:?}",
                    self.family
                ))
            }),
        }
    }

    fn take_u64(&mut self, key: &str, default: u64) -> Result<u64, SpecError> {
        match self.map.remove(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                err(format!(
                    "{}: key {key:?} expects a non-negative integer, got {v:?}",
                    self.family
                ))
            }),
        }
    }

    fn take_f32(&mut self, key: &str, default: f32) -> Result<f32, SpecError> {
        match self.map.remove(key) {
            None => Ok(default),
            Some(v) => match v.parse::<f32>() {
                Ok(x) if x.is_finite() => Ok(x),
                _ => Err(err(format!(
                    "{}: key {key:?} expects a finite number, got {v:?}",
                    self.family
                ))),
            },
        }
    }

    fn take_on_off(&mut self, key: &str, default: bool) -> Result<bool, SpecError> {
        match self.map.remove(key) {
            None => Ok(default),
            Some("on") => Ok(true),
            Some("off") => Ok(false),
            Some(v) => Err(err(format!(
                "{}: key {key:?} expects `on` or `off`, got {v:?}",
                self.family
            ))),
        }
    }

    fn take_scorer(&mut self, key: &str) -> Result<Scorer, SpecError> {
        match self.map.remove(key) {
            None | Some("dense") => Ok(Scorer::Dense),
            Some(v) => match v.strip_prefix("sfa_k").and_then(|s| s.parse::<usize>().ok()) {
                Some(k) if k >= 1 => Ok(Scorer::Sfa { k }),
                _ => Err(err(format!(
                    "{}: scorer must be `dense` or `sfa_k<K>`, got {v:?}",
                    self.family
                ))),
            },
        }
    }

    fn finish(self) -> Result<(), SpecError> {
        if let Some((k, _)) = self.map.into_iter().next() {
            return Err(err(format!("{}: unknown key {k:?}", self.family)));
        }
        Ok(())
    }
}

/// Parse a spec string into a typed [`EngineSpec`]. Bad specs return a
/// descriptive error naming the family, key, or value at fault.
/// Tokenization (trimming, `key=value` splitting, duplicate rejection)
/// is the shared [`crate::util::spec`] grammar, so the registry's
/// errors read identically to the KV-policy / speculation / SLO spec
/// surfaces.
pub fn parse_spec(spec: &str) -> Result<EngineSpec, SpecError> {
    let raw = tokenize(spec).map_err(SpecError)?;
    let family = raw.family;
    let map: BTreeMap<&str, &str> = raw.pairs.iter().copied().collect();
    let mut p = Params { family, map };
    let parsed = match family {
        "dense" => EngineSpec::Dense,
        "sfa_ref" => EngineSpec::SfaRef { k: p.take_usize("k", 8)? },
        "flash_dense" => EngineSpec::FlashDense {
            bq: p.take_usize("bq", 64)?,
            bk: p.take_usize("bk", 64)?,
        },
        "sfa" | "flash_sfa" => EngineSpec::FlashSfa {
            k: p.take_usize("k", 8)?,
            bq: p.take_usize("bq", 64)?,
            bk: p.take_usize("bk", 64)?,
            skip: p.take_on_off("skip", false)?,
            thresh: p.take_f32("thresh", 0.0)?,
            mass: p.take_f32("mass", 0.0)?,
        },
        "window" => EngineSpec::Window {
            w: p.take_usize("w", 256)?,
            scorer: p.take_scorer("scorer")?,
        },
        "lowrank" => EngineSpec::LowRank {
            r: p.take_usize("r", 16)?,
            iters: p.take_usize("iters", 6)?,
            seed: p.take_u64("seed", 0)?,
            scorer: p.take_scorer("scorer")?,
        },
        "mla" => EngineSpec::Mla {
            r: p.take_usize("r", 16)?,
            seed: p.take_u64("seed", 0)?,
            scorer: p.take_scorer("scorer")?,
        },
        "performer" => EngineSpec::Performer {
            m: p.take_usize("m", 128)?,
            seed: p.take_u64("seed", 0)?,
        },
        "quant" => EngineSpec::Quant { scorer: p.take_scorer("scorer")? },
        other => {
            return Err(err(format!(
                "unknown engine family {other:?} — known families: {}",
                FAMILIES.join(", ")
            )))
        }
    };
    p.finish()?;
    parsed.validate()?;
    Ok(parsed)
}

impl EngineSpec {
    /// The registry family name this spec belongs to.
    pub fn family(&self) -> &'static str {
        match self {
            EngineSpec::Dense => "dense",
            EngineSpec::SfaRef { .. } => "sfa_ref",
            EngineSpec::FlashDense { .. } => "flash_dense",
            EngineSpec::FlashSfa { .. } => "sfa",
            EngineSpec::Window { .. } => "window",
            EngineSpec::LowRank { .. } => "lowrank",
            EngineSpec::Mla { .. } => "mla",
            EngineSpec::Performer { .. } => "performer",
            EngineSpec::Quant { .. } => "quant",
        }
    }

    fn validate(&self) -> Result<(), SpecError> {
        let zero = match *self {
            EngineSpec::Dense => false,
            EngineSpec::SfaRef { k } => k == 0,
            EngineSpec::FlashDense { bq, bk } => bq == 0 || bk == 0,
            EngineSpec::FlashSfa { k, bq, bk, .. } => k == 0 || bq == 0 || bk == 0,
            EngineSpec::Window { w, .. } => w == 0,
            EngineSpec::LowRank { r, iters, .. } => r == 0 || iters == 0,
            EngineSpec::Mla { r, .. } => r == 0,
            EngineSpec::Performer { m, .. } => m == 0,
            EngineSpec::Quant { .. } => false,
        };
        if zero {
            return Err(err(format!(
                "{}: size parameters must be >= 1",
                self.family()
            )));
        }
        if let EngineSpec::FlashSfa { skip, thresh, mass, .. } = *self {
            if thresh < 0.0 {
                return Err(err("sfa: thresh must be >= 0"));
            }
            if thresh > 0.0 && !skip {
                return Err(err("sfa: thresh requires skip=on"));
            }
            if mass < 0.0 {
                return Err(err("sfa: mass must be >= 0"));
            }
            if mass > 0.0 && !skip {
                return Err(err("sfa: mass requires skip=on"));
            }
            if mass > 0.0 && thresh > 0.0 {
                return Err(err(
                    "sfa: mass and thresh are mutually exclusive (mass derives thresh)",
                ));
            }
        }
        Ok(())
    }

    /// Canonical spec string: `parse_spec(spec.canonical()) == spec`.
    pub fn canonical(&self) -> String {
        match *self {
            EngineSpec::Dense => "dense".into(),
            EngineSpec::SfaRef { k } => format!("sfa_ref:k={k}"),
            EngineSpec::FlashDense { bq, bk } => format!("flash_dense:bq={bq},bk={bk}"),
            EngineSpec::FlashSfa { k, bq, bk, skip, thresh, mass } => {
                let mut s = format!("sfa:k={k},bq={bq},bk={bk}");
                if skip {
                    s.push_str(",skip=on");
                    if mass > 0.0 {
                        s.push_str(&format!(",mass={mass}"));
                    } else if thresh != 0.0 {
                        s.push_str(&format!(",thresh={thresh}"));
                    }
                }
                s
            }
            EngineSpec::Window { w, scorer } => {
                format!("window:w={w},scorer={}", scorer.label())
            }
            EngineSpec::LowRank { r, iters, seed, scorer } => {
                format!("lowrank:r={r},iters={iters},seed={seed},scorer={}", scorer.label())
            }
            EngineSpec::Mla { r, seed, scorer } => {
                format!("mla:r={r},seed={seed},scorer={}", scorer.label())
            }
            EngineSpec::Performer { m, seed } => format!("performer:m={m},seed={seed}"),
            EngineSpec::Quant { scorer } => format!("quant:scorer={}", scorer.label()),
        }
    }

    /// The SFA feature-sparsity budget this spec implies, if any — it
    /// drives the session cache layout and the bench JSON `k` column.
    pub fn feature_k(&self) -> Option<usize> {
        match *self {
            EngineSpec::SfaRef { k } | EngineSpec::FlashSfa { k, .. } => Some(k),
            EngineSpec::Window { scorer, .. }
            | EngineSpec::LowRank { scorer, .. }
            | EngineSpec::Mla { scorer, .. }
            | EngineSpec::Quant { scorer } => match scorer {
                Scorer::Sfa { k } => Some(k),
                Scorer::Dense => None,
            },
            EngineSpec::Dense | EngineSpec::FlashDense { .. } | EngineSpec::Performer { .. } => {
                None
            }
        }
    }

    /// Decode-side cache scorer for [`crate::attention::session`]:
    /// feature-sparse families score the cache through top-k codes,
    /// everything else through dense dot products.
    pub fn cache_scorer(&self) -> Scorer {
        match self.feature_k() {
            Some(k) => Scorer::Sfa { k },
            None => Scorer::Dense,
        }
    }

    /// Construct the engine (thread counts come from
    /// [`default_threads`], i.e. the `SFA_THREADS` override).
    pub fn build(&self) -> Box<dyn Engine> {
        let threads = default_threads();
        match *self {
            EngineSpec::Dense => Box::new(DenseAttention),
            EngineSpec::SfaRef { k } => Box::new(SfaReference { k }),
            EngineSpec::FlashDense { bq, bk } => {
                Box::new(FlashDense { block_q: bq, block_k: bk, threads })
            }
            EngineSpec::FlashSfa { k, bq, bk, skip, thresh, mass } => Box::new(FlashSfa {
                k,
                block_q: bq,
                block_k: bk,
                threads,
                skip,
                skip_thresh: thresh,
                skip_mass: mass,
            }),
            EngineSpec::Window { w, scorer } => {
                Box::new(WindowAttention { window: w, scorer, threads })
            }
            EngineSpec::LowRank { r, iters, seed, scorer } => {
                Box::new(LowRankAttention { rank: r, power_iters: iters, seed, scorer })
            }
            EngineSpec::Mla { r, seed, scorer } => {
                Box::new(MlaAttention { latent: r, seed, scorer })
            }
            EngineSpec::Performer { m, seed } => {
                Box::new(PerformerAttention { features: m, seed })
            }
            EngineSpec::Quant { scorer } => Box::new(QuantAttention { scorer }),
        }
    }
}

/// Parse + build in one step.
pub fn build_engine(spec: &str) -> Result<Box<dyn Engine>, SpecError> {
    Ok(parse_spec(spec)?.build())
}

/// Split a `"spec;spec;..."` list (specs contain commas, so lists use
/// `;` as the separator — the CLI `--engines` / env grammar).
pub fn split_spec_list(s: &str) -> Vec<String> {
    s.split(';').map(str::trim).filter(|x| !x.is_empty()).map(String::from).collect()
}

/// Sanity-check a speculative-decoding draft spec against its target.
/// Correctness never depends on the draft (the target verifies every
/// position), so this only rejects configurations that are nonsense
/// rather than merely slow: a draft identical to the target (speculation
/// becomes pure overhead) and a feature-sparse draft whose top-k budget
/// *exceeds* the target's (the "cheap" engine would out-spend the
/// engine checking it).
pub fn validate_draft_spec(draft: &EngineSpec, target: &EngineSpec) -> Result<(), SpecError> {
    if draft == target {
        return Err(err(format!(
            "speculative draft {:?} is identical to the target engine — \
             drafting would only add overhead",
            draft.canonical()
        )));
    }
    if let (Some(dk), Some(tk)) = (draft.feature_k(), target.feature_k()) {
        if dk > tk {
            return Err(err(format!(
                "speculative draft {:?} has feature budget k={dk} above the \
                 target's k={tk} — the draft must be the cheaper engine",
                draft.canonical()
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::HeadTensor;
    use crate::util::matrix::assert_close;
    use crate::util::rng::Rng;

    fn sample_specs() -> Vec<&'static str> {
        vec![
            "dense",
            "sfa_ref:k=4",
            "flash_dense:bq=32,bk=16",
            "sfa:k=8,bq=32,bk=32,skip=on,thresh=2.5",
            "window:w=64,scorer=sfa_k4",
            "lowrank:r=8,iters=4,seed=1,scorer=dense",
            "mla:r=8,seed=2,scorer=sfa_k4",
            "performer:m=64,seed=3",
            "quant:scorer=sfa_k8",
        ]
    }

    #[test]
    fn all_nine_families_parse_and_roundtrip() {
        let specs = sample_specs();
        assert_eq!(specs.len(), FAMILIES.len());
        for s in specs {
            let spec = parse_spec(s).unwrap();
            let canon = spec.canonical();
            assert_eq!(parse_spec(&canon).unwrap(), spec, "canonical round-trip of {s}");
            let engine = spec.build();
            assert_eq!(parse_spec(&engine.spec()).unwrap(), spec, "engine.spec() of {s}");
        }
    }

    #[test]
    fn defaults_aliases_and_whitespace() {
        assert_eq!(parse_spec("sfa").unwrap(), parse_spec("flash_sfa:k=8,bq=64,bk=64").unwrap());
        assert_eq!(parse_spec(" window : w=128 ").unwrap(), parse_spec("window:w=128").unwrap());
        assert_eq!(
            parse_spec("window").unwrap(),
            EngineSpec::Window { w: 256, scorer: Scorer::Dense }
        );
        assert_eq!(parse_spec("quant").unwrap(), EngineSpec::Quant { scorer: Scorer::Dense });
    }

    #[test]
    fn bad_specs_are_descriptive() {
        for (s, needle) in [
            ("warp", "unknown engine family"),
            ("sfa:k=zero", "non-negative integer"),
            ("sfa:q=1", "unknown key"),
            ("window:w=0", "must be >= 1"),
            ("window:w", "key=value"),
            ("quant:scorer=sfa8", "scorer"),
            ("", "empty spec"),
            ("sfa:k=2,k=3", "duplicate"),
            ("sfa:skip=maybe", "`on` or `off`"),
            ("sfa:skip=on,thresh=nan", "finite number"),
            ("sfa:skip=on,thresh=-1", "thresh must be >= 0"),
            ("sfa:thresh=2", "thresh requires skip=on"),
            ("sfa:skip=on,mass=-0.5", "mass must be >= 0"),
            ("sfa:mass=0.1", "mass requires skip=on"),
            ("sfa:skip=on,thresh=4,mass=0.1", "mutually exclusive"),
        ] {
            let e = parse_spec(s).unwrap_err();
            assert!(e.0.contains(needle), "{s:?} -> {e}");
        }
    }

    #[test]
    fn spec_string_roundtrip_property_every_family() {
        // Satellite pin: parse(engine.spec()).build().spec() == engine.spec()
        // for randomized configurations of every registry family —
        // including the FlashSfa skip/thresh parameters, whose f32
        // display must survive the string round-trip.
        use crate::util::prop::check;
        check("parse(spec()).spec() == spec()", 96, |g| {
            let scorers = ["dense", "sfa_k2", "sfa_k8"];
            let fam = *g.choose(FAMILIES);
            let s = match fam {
                "dense" => "dense".to_string(),
                "sfa_ref" => format!("sfa_ref:k={}", g.usize_in(1..17)),
                "flash_dense" => format!(
                    "flash_dense:bq={},bk={}",
                    g.usize_in(1..129),
                    g.usize_in(1..129)
                ),
                "sfa" => {
                    let mut s = format!(
                        "sfa:k={},bq={},bk={}",
                        g.usize_in(1..17),
                        g.usize_in(1..129),
                        g.usize_in(1..129)
                    );
                    if g.bool() {
                        s.push_str(",skip=on");
                        if g.bool() {
                            if g.bool() {
                                s.push_str(&format!(",mass={}", g.f32_in(0.001..2.0)));
                            } else {
                                s.push_str(&format!(",thresh={}", g.f32_in(0.0..16.0)));
                            }
                        }
                    }
                    s
                }
                "window" => {
                    format!("window:w={},scorer={}", g.usize_in(1..512), g.choose(&scorers))
                }
                "lowrank" => format!(
                    "lowrank:r={},iters={},seed={},scorer={}",
                    g.usize_in(1..33),
                    g.usize_in(1..9),
                    g.usize_in(0..100),
                    g.choose(&scorers)
                ),
                "mla" => format!(
                    "mla:r={},seed={},scorer={}",
                    g.usize_in(1..33),
                    g.usize_in(0..100),
                    g.choose(&scorers)
                ),
                "performer" => {
                    format!("performer:m={},seed={}", g.usize_in(1..257), g.usize_in(0..100))
                }
                "quant" => format!("quant:scorer={}", g.choose(&scorers)),
                other => other.to_string(),
            };
            let parsed = parse_spec(&s).unwrap();
            let spec_str = parsed.build().spec();
            let reparsed = parse_spec(&spec_str).unwrap();
            assert_eq!(reparsed, parsed, "engine.spec() of {s:?}");
            assert_eq!(
                reparsed.build().spec(),
                spec_str,
                "parse(spec()).spec() == spec() for {s:?}"
            );
            assert_eq!(parsed.canonical(), spec_str, "engine.spec() is canonical for {s:?}");
        });
    }

    #[test]
    fn feature_k_and_cache_scorer() {
        assert_eq!(parse_spec("sfa:k=4").unwrap().feature_k(), Some(4));
        assert_eq!(parse_spec("window:scorer=sfa_k2").unwrap().feature_k(), Some(2));
        assert_eq!(parse_spec("flash_dense").unwrap().feature_k(), None);
        assert_eq!(parse_spec("dense").unwrap().cache_scorer(), Scorer::Dense);
        assert_eq!(parse_spec("sfa_ref:k=3").unwrap().cache_scorer(), Scorer::Sfa { k: 3 });
    }

    #[test]
    fn draft_spec_validation_rejects_nonsense_pairs() {
        let target = parse_spec("sfa:k=8").unwrap();
        // Cheaper SFA drafts and non-SFA drafts pass.
        validate_draft_spec(&parse_spec("sfa:k=2").unwrap(), &target).unwrap();
        validate_draft_spec(&parse_spec("window:w=64").unwrap(), &target).unwrap();
        validate_draft_spec(&parse_spec("lowrank:r=4").unwrap(), &target).unwrap();
        // Equal-k drafts with different tiling are still distinct engines.
        validate_draft_spec(&parse_spec("sfa:k=8,bq=16,bk=16").unwrap(), &target).unwrap();
        // Identical draft == target is rejected.
        let e = validate_draft_spec(&parse_spec("sfa:k=8,bq=64,bk=64").unwrap(), &target)
            .unwrap_err();
        assert!(e.0.contains("identical to the target"), "{e}");
        // A draft more feature-hungry than the target is rejected.
        let e = validate_draft_spec(&parse_spec("sfa:k=12").unwrap(), &target).unwrap_err();
        assert!(e.0.contains("above the"), "{e}");
        // Dense targets accept any feature budget (nothing to compare).
        validate_draft_spec(&parse_spec("sfa:k=12").unwrap(), &parse_spec("dense").unwrap())
            .unwrap();
    }

    #[test]
    fn batched_forward_matches_per_head_loop_on_all_engines() {
        for s in sample_specs() {
            let engine = build_engine(s).unwrap();
            let mut rng = Rng::new(9);
            let (batch, heads, n, d) = (2, 2, 24, 16);
            let q = HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0);
            let k = HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0);
            let v = HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0);
            let out = engine.forward_batched(&q, &k, &v, true);
            assert_eq!((out.batch, out.heads, out.n, out.d), (batch, heads, n, d));
            for b in 0..batch {
                for h in 0..heads {
                    let expect =
                        engine.forward(&q.head(b, h), &k.head(b, h), &v.head(b, h), true);
                    assert_close(&out.head(b, h), &expect, 0.0, 0.0);
                }
            }
        }
    }
}
