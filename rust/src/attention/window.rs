//! Longformer-style local window attention — the paper's token-level
//! sparsity baseline (Tables 10/11), composable with the SFA scorer:
//! the "+SFA (k=8)" rows apply feature-overlap scoring to the retained
//! window pairs, multiplying the two sparsity axes.

use crate::attention::{Engine, Scorer, NEG_INF};
use crate::sparse::{topk_codes, TopkCodes};
use crate::util::matrix::Matrix;
use crate::util::threadpool::{parallel_for_dynamic, SendPtr};

#[derive(Debug, Clone, Copy)]
pub struct WindowAttention {
    /// Causal window width: query i attends to keys (i-window, i].
    pub window: usize,
    pub scorer: Scorer,
    pub threads: usize,
}

impl WindowAttention {
    pub fn new(window: usize, scorer: Scorer) -> Self {
        WindowAttention { window, scorer, threads: crate::util::threadpool::default_threads() }
    }

    fn row_forward(
        &self,
        i: usize,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        codes: Option<(&TopkCodes, &TopkCodes)>,
        out: &mut [f32],
    ) {
        let d = q.cols;
        let scale = 1.0 / (d as f32).sqrt();
        let lo = i.saturating_sub(self.window - 1);
        let width = i - lo + 1;
        let mut scores = vec![NEG_INF; width];
        match codes {
            None => {
                let qrow = q.row(i);
                for (c, s) in scores.iter_mut().enumerate() {
                    let krow = k.row(lo + c);
                    let mut acc = 0.0;
                    for t in 0..d {
                        acc += qrow[t] * krow[t];
                    }
                    *s = acc * scale;
                }
            }
            Some((qc, kc)) => {
                for (c, s) in scores.iter_mut().enumerate() {
                    *s = qc.overlap_dot(i, kc, lo + c) * scale;
                }
            }
        }
        // softmax over the window + weighted V sum
        let m = scores.iter().fold(NEG_INF, |a, &b| a.max(b));
        let mut l = 0.0;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            l += *s;
        }
        out.fill(0.0);
        for (c, &p) in scores.iter().enumerate() {
            let w = p / l;
            let vrow = v.row(lo + c);
            for (o, &x) in out.iter_mut().zip(vrow) {
                *o += w * x;
            }
        }
    }
}

impl Engine for WindowAttention {
    fn name(&self) -> String {
        format!("longformer_w{}+{}", self.window, self.scorer.label())
    }

    fn spec(&self) -> String {
        format!("window:w={},scorer={}", self.window, self.scorer.label())
    }

    fn forward(&self, q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        assert!(causal, "window attention is defined causally here");
        assert_eq!(q.rows, k.rows);
        let codes = match self.scorer {
            Scorer::Dense => None,
            Scorer::Sfa { k: kk } => Some((topk_codes(q, kk), topk_codes(k, kk))),
        };
        let mut out = Matrix::zeros(q.rows, v.cols);
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let vcols = v.cols;
        parallel_for_dynamic(q.rows, self.threads, 16, |i| {
            let out_slice = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.get().add(i * vcols), vcols)
            };
            self.row_forward(
                i, q, k, v,
                codes.as_ref().map(|(a, b)| (a, b)),
                out_slice,
            );
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::{DenseAttention, SfaReference};
    use crate::attention::testutil::qkv;
    use crate::util::matrix::assert_close;

    #[test]
    fn full_window_matches_dense() {
        let (q, k, v) = qkv(32, 16, 16, 0);
        let a = WindowAttention::new(1000, Scorer::Dense).forward(&q, &k, &v, true);
        let b = DenseAttention.forward(&q, &k, &v, true);
        assert_close(&a, &b, 2e-5, 2e-6);
    }

    #[test]
    fn full_window_sfa_matches_sfa_reference() {
        let (q, k, v) = qkv(32, 32, 16, 1);
        let a = WindowAttention::new(1000, Scorer::Sfa { k: 4 }).forward(&q, &k, &v, true);
        let b = SfaReference { k: 4 }.forward(&q, &k, &v, true);
        assert_close(&a, &b, 2e-5, 2e-6);
    }

    #[test]
    fn window_one_copies_own_value() {
        let (q, k, v) = qkv(16, 8, 8, 2);
        let out = WindowAttention::new(1, Scorer::Dense).forward(&q, &k, &v, true);
        assert_close(&out, &v, 1e-6, 1e-7);
    }

    #[test]
    fn out_of_window_keys_ignored() {
        let (q, mut k, mut v) = qkv(64, 16, 8, 3);
        let w = WindowAttention::new(8, Scorer::Dense);
        let o1 = w.forward(&q, &k, &v, true);
        // Corrupt everything more than 8 positions before the end.
        for i in 0..48 {
            k.row_mut(i).fill(7.0);
            v.row_mut(i).fill(-7.0);
        }
        let o2 = w.forward(&q, &k, &v, true);
        // Last row's window is [56..64]: unaffected.
        for t in 0..8 {
            assert!((o1.get(63, t) - o2.get(63, t)).abs() < 1e-6);
        }
    }
}
