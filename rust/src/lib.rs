//! # SFA — Sparse Feature Attention, end to end
//!
//! Production-quality reproduction of *"Scaling Attention via Feature
//! Sparsity"* as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas, build time)** — the FlashSFA kernel and a row-wise
//!   top-k kernel live in `python/compile/kernels/`; they lower (with
//!   `interpret=True`) into the model HLO.
//! * **L2 (JAX, build time)** — a GPT-2-style LM with pluggable
//!   attention (`dense | sfa | short | window`) in
//!   `python/compile/model.py`, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3 (this crate, run time)** — everything else: the PJRT runtime
//!   that executes the artifacts, the serving coordinator (router /
//!   continuous batcher / scheduler / KV-cache manager), the training
//!   driver, the CPU FlashSFA engine used for the paper's latency
//!   benchmarks, every baseline it is compared against, and the
//!   benchmark harness that regenerates each table and figure.
//!
//! Python never runs on the request path: after `make artifacts` the
//! `sfa` binary is self-contained.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | offline-environment substrates: RNG, JSON, CLI, stats, thread pool, matrices, mini property testing |
//! | [`sparse`] | CSR / feature-wise CSC formats, row-wise top-k, Gustavson SpGEMM, App-J memory model |
//! | [`attention`] | the CPU FlashSFA engine (paper App. C Algorithm 1) plus dense/flash/token-sparse/low-rank/kernel baselines, the spec-string engine registry, and the multi-head `AttentionSession` (prefill → paged KV cache → decode; see ARCHITECTURE.md) |
//! | [`kv_cache`] | paged dense + sparse KV caches with eviction policies (H2O/SnapKV-style) |
//! | [`runtime`] | PJRT client, artifact registry, executable cache |
//! | [`serve`] | the request-lifecycle serving API: `ServeRequest` builder, typed state machine, streaming events, and the continuous-batching scheduler over `AttentionSession` (see ARCHITECTURE.md §Serving lifecycle) |
//! | [`coordinator`] | **deprecated wave path**: request router, wave batcher, artifact-driven generation engine |
//! | [`train`] | corpus + NIAH generators, training loop over the AOT'd train_step, PPL / retrieval eval |
//! | [`analysis`] | FLOP/INOP counter, bandwidth model, top-k entropy, SVD effective rank, latency cost model |
//! | [`bench`] | median-of-N micro-bench harness + paper table/figure regeneration |

// Numeric-kernel idiom: index loops keep the q[i]/k[i]/v[i]
// correspondence of the paper's algorithms visible, and the iterator
// rewrites clippy suggests often fight the borrow checker in the
// scheduler/parallel sections. Everything else clippy flags is denied
// in CI (`cargo clippy --all-targets -- -D warnings`).
#![allow(clippy::needless_range_loop)]

pub mod analysis;
pub mod attention;
pub mod bench;
pub mod coordinator;
pub mod kv_cache;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
