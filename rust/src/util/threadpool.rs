//! Scoped data parallelism (no `rayon` offline).
//!
//! [`parallel_for_chunks`] splits an index range into contiguous chunks
//! and runs one OS thread per chunk via `std::thread::scope`. The
//! attention engines use it for query-tile parallelism — the same
//! decomposition the paper's CUDA kernel expresses with its grid.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (physical parallelism, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into `threads`
/// contiguous chunks. `f` must be Sync; chunks are disjoint so callers
/// can hand out `&mut` slices via raw splitting if needed.
pub fn parallel_for_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Dynamic work-stealing-lite: threads grab the next index atomically.
/// Better than static chunks when per-item cost is skewed (e.g. causal
/// attention rows near the end of the sequence cost more).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let grain = grain.max(1);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + grain).min(n) {
                    f(i);
                }
            });
        }
    });
}

/// Map over `[0, n)` in parallel, collecting results in order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_for_dynamic(n, threads, 1, move |i| {
        // SAFETY: each index is visited exactly once; writes are disjoint.
        unsafe { *out_ptr.get().add(i) = f(i) };
    });
    out
}

/// Wrapper to move a raw pointer across the scoped-thread boundary.
/// Safe because writes through it are index-disjoint (see callers).
///
/// NOTE: always access through [`SendPtr::get`] inside closures —
/// edition-2021 disjoint capture would otherwise capture the raw
/// pointer *field* (which is !Sync) rather than the wrapper.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    #[inline]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits = AtomicU64::new(0);
        let sum = AtomicU64::new(0);
        parallel_for_chunks(1000, 8, |lo, hi| {
            for i in lo..hi {
                hits.fetch_add(1, Ordering::Relaxed);
                sum.fetch_add(i as u64, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn dynamic_covers_range_exactly_once() {
        let hits = AtomicU64::new(0);
        parallel_for_dynamic(777, 8, 13, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 777);
    }

    #[test]
    fn map_collects_in_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn handles_degenerate_sizes() {
        let hits = AtomicU64::new(0);
        parallel_for_chunks(0, 8, |lo, hi| {
            hits.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 0);
        let v = parallel_map(1, 8, |i| i + 1);
        assert_eq!(v, vec![1]);
    }

    #[test]
    fn single_thread_path() {
        let hits = AtomicU64::new(0);
        parallel_for_dynamic(10, 1, 1, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }
}
