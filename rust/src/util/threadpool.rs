//! Scoped data parallelism (no `rayon` offline).
//!
//! [`parallel_for_chunks`] splits an index range into contiguous chunks
//! and runs one OS thread per chunk via `std::thread::scope`. The
//! attention engines use it for query-tile parallelism — the same
//! decomposition the paper's CUDA kernel expresses with its grid.
//!
//! Thread-count override: set `SFA_THREADS=<n>` (n ≥ 1) to pin
//! [`default_threads`] regardless of the machine's core count. Benches
//! on shared CI machines want reproducible parallelism, and every
//! engine constructor and session consults `default_threads`, so one
//! env var pins the whole stack.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: the `SFA_THREADS` env override when
/// set to a positive integer, else physical parallelism capped at 16.
pub fn default_threads() -> usize {
    match env_thread_override(std::env::var("SFA_THREADS").ok().as_deref()) {
        Some(n) => n,
        None => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(16),
    }
}

/// Parse an `SFA_THREADS` value; unset, non-numeric, or zero means no
/// override.
fn env_thread_override(raw: Option<&str>) -> Option<usize> {
    raw.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n >= 1)
}

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into `threads`
/// contiguous chunks. `f` must be Sync; chunks are disjoint so callers
/// can hand out `&mut` slices via raw splitting if needed.
pub fn parallel_for_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Dynamic work-stealing-lite: threads grab the next index atomically.
/// Better than static chunks when per-item cost is skewed (e.g. causal
/// attention rows near the end of the sequence cost more).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let grain = grain.max(1);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + grain).min(n) {
                    f(i);
                }
            });
        }
    });
}

/// [`parallel_for_dynamic`] with a stable worker index: `f(worker, i)`
/// where `worker < threads` identifies the executing thread. Callers
/// hand each worker its own reusable scratch slot (disjoint `&mut`
/// access via raw splitting) so hot loops allocate nothing after
/// warm-up.
pub fn parallel_for_dynamic_worker<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        for i in 0..n {
            f(0, i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let grain = grain.max(1);
    std::thread::scope(|s| {
        for w in 0..threads {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + grain).min(n) {
                    f(w, i);
                }
            });
        }
    });
}

/// Map over `[0, n)` in parallel, collecting results in order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_for_dynamic(n, threads, 1, move |i| {
        // SAFETY: each index is visited exactly once; writes are disjoint.
        unsafe { *out_ptr.get().add(i) = f(i) };
    });
    out
}

/// Wrapper to move a raw pointer across the scoped-thread boundary.
/// Safe because writes through it are index-disjoint (see callers).
///
/// NOTE: always access through [`SendPtr::get`] inside closures —
/// edition-2021 disjoint capture would otherwise capture the raw
/// pointer *field* (which is !Sync) rather than the wrapper.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    #[inline]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits = AtomicU64::new(0);
        let sum = AtomicU64::new(0);
        parallel_for_chunks(1000, 8, |lo, hi| {
            for i in lo..hi {
                hits.fetch_add(1, Ordering::Relaxed);
                sum.fetch_add(i as u64, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn dynamic_covers_range_exactly_once() {
        let hits = AtomicU64::new(0);
        parallel_for_dynamic(777, 8, 13, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 777);
    }

    #[test]
    fn worker_indexed_covers_range_with_bounded_workers() {
        let hits = AtomicU64::new(0);
        let bad_worker = AtomicU64::new(0);
        parallel_for_dynamic_worker(500, 4, 7, |w, _| {
            hits.fetch_add(1, Ordering::Relaxed);
            if w >= 4 {
                bad_worker.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 500);
        assert_eq!(bad_worker.load(Ordering::Relaxed), 0);
        // Single-thread path pins worker 0.
        parallel_for_dynamic_worker(10, 1, 1, |w, _| {
            assert_eq!(w, 0);
        });
    }

    #[test]
    fn map_collects_in_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn handles_degenerate_sizes() {
        let hits = AtomicU64::new(0);
        parallel_for_chunks(0, 8, |lo, hi| {
            hits.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 0);
        let v = parallel_map(1, 8, |i| i + 1);
        assert_eq!(v, vec![1]);
    }

    #[test]
    fn sfa_threads_override_parsing() {
        // The override is tested through the pure parser (no
        // env::set_var — concurrent setenv/getenv across test threads
        // is UB on glibc).
        assert_eq!(env_thread_override(Some("3")), Some(3));
        assert_eq!(env_thread_override(Some(" 8 ")), Some(8));
        assert_eq!(env_thread_override(Some("0")), None);
        assert_eq!(env_thread_override(Some("not-a-number")), None);
        assert_eq!(env_thread_override(None), None);
        assert!(default_threads() >= 1);
    }

    #[test]
    fn single_thread_path() {
        let hits = AtomicU64::new(0);
        parallel_for_dynamic(10, 1, 1, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }
}
