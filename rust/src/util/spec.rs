//! Shared `family[:key=value,...]` spec-string grammar.
//!
//! Every spec surface consumes this one tokenizer — the engine
//! registry ([`crate::attention::registry::parse_spec`]), the paged-KV
//! policy surface (`PagedKvPolicy::parse`), the speculative-decoding
//! config (`SpeculateConfig::parse`), and the serve router's SLO
//! classes (`SloClass::parse`) — so every `--engine` / `--policy` /
//! `--speculate` / `--slo` string splits, trims, and fails
//! identically: `"<family>: malformed parameter ... (expected
//! key=value)"` and `"<family>: duplicate key ..."` read the same no
//! matter which parser raised them.

/// One tokenized spec: the family name plus its `key=value` pairs in
/// written order (both halves trimmed). Typing and key validation stay
/// with the consumer — the grammar layer only splits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RawSpec<'a> {
    pub family: &'a str,
    pub pairs: Vec<(&'a str, &'a str)>,
}

impl<'a> RawSpec<'a> {
    /// The value written for `key`, if any.
    pub fn get(&self, key: &str) -> Option<&'a str> {
        self.pairs.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
    }
}

/// Split one `key=value` atom (both halves trimmed). `None` when there
/// is no `=` — the caller owns the error message so the family name
/// can lead it.
pub fn split_kv(part: &str) -> Option<(&str, &str)> {
    let (k, v) = part.split_once('=')?;
    Some((k.trim(), v.trim()))
}

/// Tokenize `family[:key=value,...]`: trim the whole spec, split the
/// family off the first `:`, split parameters on `,` (empty parts
/// skipped), and reject missing `=` and duplicate keys. Errors are
/// plain `String`s; consumers wrap them in their own error types.
pub fn tokenize(spec: &str) -> Result<RawSpec<'_>, String> {
    let spec = spec.trim();
    if spec.is_empty() {
        return Err("empty spec — expected `family[:key=value,...]`".into());
    }
    let (family, rest) = match spec.split_once(':') {
        Some((f, r)) => (f.trim(), r),
        None => (spec, ""),
    };
    let mut pairs: Vec<(&str, &str)> = Vec::new();
    for part in rest.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (k, v) = split_kv(part).ok_or_else(|| {
            format!("{family}: malformed parameter {part:?} (expected key=value)")
        })?;
        if pairs.iter().any(|&(pk, _)| pk == k) {
            return Err(format!("{family}: duplicate key {k:?}"));
        }
        pairs.push((k, v));
    }
    Ok(RawSpec { family, pairs })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_family_and_pairs() {
        let r = tokenize("dense").unwrap();
        assert_eq!(r.family, "dense");
        assert!(r.pairs.is_empty());
        let r = tokenize("h2o:budget=32,recent=8").unwrap();
        assert_eq!(r.family, "h2o");
        assert_eq!(r.pairs, vec![("budget", "32"), ("recent", "8")]);
        assert_eq!(r.get("budget"), Some("32"));
        assert_eq!(r.get("window"), None);
    }

    #[test]
    fn trims_and_skips_empty_parts() {
        let r = tokenize(" window : w=128 , , scorer=sfa_k4 ").unwrap();
        assert_eq!(r.family, "window");
        assert_eq!(r.pairs, vec![("w", "128"), ("scorer", "sfa_k4")]);
    }

    #[test]
    fn errors_are_uniform() {
        assert!(tokenize("").unwrap_err().contains("empty spec"));
        assert!(tokenize("   ").unwrap_err().contains("empty spec"));
        let e = tokenize("window:w").unwrap_err();
        assert_eq!(e, "window: malformed parameter \"w\" (expected key=value)");
        let e = tokenize("sfa:k=2,k=3").unwrap_err();
        assert_eq!(e, "sfa: duplicate key \"k\"");
    }

    #[test]
    fn split_kv_trims_both_halves() {
        assert_eq!(split_kv("draft=sfa:k=2"), Some(("draft", "sfa:k=2")));
        assert_eq!(split_kv(" ttft_ms = 250 "), Some(("ttft_ms", "250")));
        assert_eq!(split_kv("batch"), None);
    }
}
