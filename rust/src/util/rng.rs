//! Deterministic pseudo-random number generation (no `rand` offline).
//!
//! [`Rng`] is xoshiro256** seeded via SplitMix64 — fast, high quality,
//! and reproducible across runs, which the experiment harnesses rely on
//! (every table in EXPERIMENTS.md records its seed).

/// SplitMix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller normal sample.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Deterministic generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Independent child generator (stable fork for parallel workers).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Standard normal f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of iid N(0, scale²) f32 values.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| scale * self.normal_f32()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from a Zipf(s) distribution over {0, .., n-1} by inverse
    /// CDF on the precomputed table (used by the synthetic corpus).
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.next_f64();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

/// Precompute the CDF for [`Rng::zipf`] over `n` items with exponent `s`.
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=n).map(|i| (i as f64).powf(-s)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / total;
        *x = acc;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zipf_skews_to_small_indices() {
        let cdf = zipf_cdf(100, 1.2);
        let mut r = Rng::new(8);
        let mut count0 = 0;
        let mut count_tail = 0;
        for _ in 0..10_000 {
            let i = r.zipf(&cdf);
            assert!(i < 100);
            if i == 0 {
                count0 += 1;
            }
            if i >= 50 {
                count_tail += 1;
            }
        }
        assert!(count0 > count_tail, "zipf head should dominate tail");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
