//! Summary statistics used by the bench harness and metrics endpoints.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Quantile with linear interpolation, q in [0, 1]. Sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median (paper methodology: medians over warm runs).
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Shannon entropy (nats) of a histogram, normalized to [0, 1] by
/// log(bins). Used for the paper's Fig. 7 load-balance analysis.
pub fn normalized_entropy(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 || counts.len() < 2 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / total as f64;
            h -= p * p.ln();
        }
    }
    h / (counts.len() as f64).ln()
}

/// Online mean/min/max/count accumulator for streaming metrics.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
        assert!((quantile(&xs, 0.95) - 95.0).abs() < 1e-9);
    }

    #[test]
    fn entropy_uniform_is_one() {
        assert!((normalized_entropy(&[5, 5, 5, 5]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_degenerate_is_zero() {
        assert_eq!(normalized_entropy(&[10, 0, 0, 0]), 0.0);
        assert_eq!(normalized_entropy(&[]), 0.0);
    }

    #[test]
    fn entropy_monotone_in_balance() {
        let skewed = normalized_entropy(&[97, 1, 1, 1]);
        let mild = normalized_entropy(&[40, 30, 20, 10]);
        let uniform = normalized_entropy(&[25, 25, 25, 25]);
        assert!(skewed < mild && mild < uniform);
    }

    #[test]
    fn accumulator() {
        let mut a = Accumulator::new();
        for x in [1.0, 2.0, 3.0] {
            a.add(x);
        }
        assert_eq!(a.count, 3);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 3.0);
        assert!((a.mean() - 2.0).abs() < 1e-12);
    }
}
