//! Minimal JSON parser + writer (no `serde` offline).
//!
//! Supports the full JSON grammar the artifact manifest uses: objects,
//! arrays, strings (with escapes), numbers, booleans, null. Numbers are
//! kept as f64 (integers up to 2^53 round-trip exactly, far above any
//! shape we store).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for the writer side.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a [`Json::Obj`] from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-walk multi-byte UTF-8 sequences intact.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.b.len());
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xF0..=0xF7 => 4,
        0xE0..=0xEF => 3,
        0xC0..=0xDF => 2,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\nb\t\"q\" é é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" é é");
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"arr":[1,2.5,true,null,"x\ny"],"num":42,"obj":{"k":"v"}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 3, "s": "x", "b": false}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("s").unwrap().as_str().unwrap(), "x");
        assert!(!j.get("b").unwrap().as_bool().unwrap());
        assert!(j.get("missing").is_err());
        assert!(j.get("n").unwrap().as_str().is_err());
        assert!(Json::parse("2.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn builder_obj() {
        let j = obj(vec![
            ("name", Json::from("sfa")),
            ("k", Json::from(8usize)),
            ("shape", Json::from(vec![2usize, 3, 4])),
        ]);
        let t = j.to_string();
        assert!(t.contains(r#""name":"sfa""#), "{t}");
        assert_eq!(Json::parse(&t).unwrap(), j);
    }

    #[test]
    fn manifest_like_document() {
        // Shape of the real artifact manifest.
        let text = r#"{
          "preset": "small", "seed": 42,
          "variants": {"dense": {"entries": {"train_step": {
             "file": "dense/train_step.hlo.txt",
             "inputs": [{"name": "param:tok_emb", "shape": [512, 256], "dtype": "f32"}]
          }}}}
        }"#;
        let j = Json::parse(text).unwrap();
        let shape = j.get("variants").unwrap().get("dense").unwrap()
            .get("entries").unwrap().get("train_step").unwrap()
            .get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 512);
        assert_eq!(shape[1].as_usize().unwrap(), 256);
    }
}
