//! Dense row-major f32 matrices — the lingua franca of the attention
//! engines and analysis modules. Deliberately minimal: this is a
//! compute substrate, not a linear-algebra library.

use crate::util::rng::Rng;

/// Row-major dense matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// iid N(0, scale²) entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng, scale: f32) -> Self {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols, scale) }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Dense matmul (naive ikj loop order, auto-vectorizes on the j axis).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let o_row = out.row_mut(i);
            for (kk, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = other.row(kk);
                for (j, &b) in b_row.iter().enumerate() {
                    o_row[j] += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    /// Max |a - b| over all entries.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Take the first `n` rows as a view-copy.
    pub fn head_rows(&self, n: usize) -> Matrix {
        assert!(n <= self.rows);
        Matrix::from_vec(n, self.cols, self.data[..n * self.cols].to_vec())
    }
}

/// assert_allclose analog for tests: relative + absolute tolerance.
pub fn assert_close(a: &Matrix, b: &Matrix, rtol: f32, atol: f32) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "shape mismatch");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol,
            "mismatch at flat index {i} (row {} col {}): {x} vs {y} (tol {tol})",
            i / a.cols,
            i % a.cols,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(4, 4, &mut rng, 1.0);
        let mut eye = Matrix::zeros(4, 4);
        for i in 0..4 {
            eye.set(i, i, 1.0);
        }
        assert_close(&a.matmul(&eye), &a, 1e-6, 1e-7);
        assert_close(&eye.matmul(&a), &a, 1e-6, 1e-7);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(3, 5, &mut rng, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn assert_close_catches_difference() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_vec(1, 2, vec![1.0, 2.1]);
        assert_close(&a, &b, 1e-6, 1e-6);
    }
}
