//! Mini property-based testing helper (no `proptest` offline).
//!
//! [`check`] runs a property over `cases` seeded inputs; on failure it
//! reports the failing seed so the case can be replayed exactly:
//!
//! ```no_run
//! // (no_run: doctest executables lack the libstdc++ rpath the xla
//! // link step needs in this offline image; the same property runs
//! // as a unit test below.)
//! use sfa::util::prop::{check, Gen};
//! check("sorting is idempotent", 64, |g: &mut Gen| {
//!     let mut v = g.vec_f32(0..100, -1e3..1e3);
//!     v.sort_by(|a, b| a.total_cmp(b));
//!     let w = {
//!         let mut w = v.clone();
//!         w.sort_by(|a, b| a.total_cmp(b));
//!         w
//!     };
//!     assert_eq!(v, w);
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;

/// Input generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        self.rng.range(r.start, r.end)
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        r.start + (r.end - r.start) * self.rng.next_f32()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    pub fn vec_normal(&mut self, n: usize, scale: f32) -> Vec<f32> {
        self.rng.normal_vec(n, scale)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len())]
    }
}

/// Run `property` across `cases` deterministic seeds. Panics (with the
/// failing seed in the message) if any case panics.
pub fn check<F>(name: &str, cases: u64, property: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Rng::new(seed), seed };
            property(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("abs is non-negative", 32, |g| {
            let x = g.f32_in(-100.0..100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn failing_property_reports_seed() {
        check("always fails", 4, |_| panic!("boom"));
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 64, |g| {
            let n = g.usize_in(1..10);
            assert!((1..10).contains(&n));
            let x = g.f32_in(2.0..3.0);
            assert!((2.0..3.0).contains(&x));
            let v = g.vec_f32(0..5, -1.0..1.0);
            assert!(v.len() < 5);
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
        });
    }
}
