//! Tiny command-line parser (no `clap` offline).
//!
//! Supports `command subcommand --flag value --switch pos1 pos2` with
//! typed accessors and a generated usage string. Each binary declares
//! its options up front so `--help` stays truthful.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments: subcommand path, `--key value` options, bare
/// `--switch` flags, and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (without program name). `n_commands` leading bare
    /// words are treated as the (sub)command path.
    pub fn parse(argv: &[String], n_commands: usize) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.len() < n_commands && out.positional.is_empty() {
                out.command.push(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects an integer, got {v:?}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects a number, got {v:?}: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects an integer, got {v:?}: {e}")),
        }
    }

    /// Comma-separated list option: `--ks 2,4,8`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|e| anyhow!("--{key} element {s:?}: {e}"))
                })
                .collect(),
        }
    }

    pub fn str_list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().to_string())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_command_options_switches() {
        let a = Args::parse(&argv("bench fig3 --ctx 16384 --verbose --ks 2,4,8 out.txt"), 2)
            .unwrap();
        assert_eq!(a.command, vec!["bench", "fig3"]);
        assert_eq!(a.get("ctx"), Some("16384"));
        assert!(a.has("verbose"));
        assert_eq!(a.usize_list_or("ks", &[]).unwrap(), vec![2, 4, 8]);
        assert_eq!(a.positional, vec!["out.txt"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&argv("run --k=8 --name=sfa"), 1).unwrap();
        assert_eq!(a.usize_or("k", 0).unwrap(), 8);
        assert_eq!(a.get("name"), Some("sfa"));
    }

    #[test]
    fn defaults_and_type_errors() {
        let a = Args::parse(&argv("x --k eight"), 1).unwrap();
        assert!(a.usize_or("k", 1).is_err());
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!((a.f64_or("lr", 0.5).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn trailing_switch() {
        let a = Args::parse(&argv("serve --quiet"), 1).unwrap();
        assert!(a.has("quiet"));
        assert!(!a.has("loud"));
    }

    #[test]
    fn option_value_starting_with_dash_number() {
        // Values beginning with "--" are treated as the next flag.
        let a = Args::parse(&argv("x --a --b v"), 1).unwrap();
        assert!(a.has("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn str_list_defaults() {
        let a = Args::parse(&argv("x"), 1).unwrap();
        assert_eq!(a.str_list_or("variants", &["dense", "sfa_k8"]),
                   vec!["dense".to_string(), "sfa_k8".to_string()]);
    }
}
