//! Offline-environment substrates.
//!
//! Only the `xla` crate's dependency closure is vendored in this image,
//! so the usual ecosystem crates (serde, clap, rayon, criterion, rand,
//! proptest) are replaced by small, tested, in-repo implementations.

pub mod cli;
pub mod json;
pub mod matrix;
pub mod prop;
pub mod rng;
pub mod spec;
pub mod stats;
pub mod threadpool;
