//! Aligned text tables for the bench harness output — each `cargo
//! bench` target prints the same rows/series as the paper table or
//! figure it regenerates.

/// Column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("| ");
            for (cell, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{cell:>w$} | ", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let mut sep = String::from("|-");
        for w in &widths {
            sep.push_str(&"-".repeat(*w));
            sep.push_str("-|-");
        }
        sep.pop();
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds as adaptive ms/us string.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Format a speedup multiplier.
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["variant", "ms"]);
        t.row(vec!["dense".into(), "12.3".into()]);
        t.row(vec!["sfa_k8".into(), "4.5".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| variant |"));
        assert!(s.contains("|  sfa_k8 |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn rejects_misshapen_rows() {
        let mut t = Table::new("X", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.50s");
        assert_eq!(fmt_time(0.0123), "12.30ms");
        assert_eq!(fmt_time(42e-6), "42.0us");
        assert_eq!(fmt_speedup(2.07), "2.07x");
    }
}
