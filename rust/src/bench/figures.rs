//! Regeneration drivers for the paper's latency/cost tables & figures.
//!
//! Each function returns the printed [`Table`]s so both `cargo bench`
//! targets and the `sfa bench <item>` CLI share one implementation.
//! Absolute milliseconds are CPU-testbed numbers; the reproduction
//! target is the *shape* — who wins, crossover points, scaling
//! exponents (DESIGN.md §Substitutions).
//!
//! Engines are constructed through the
//! [`registry`](crate::attention::registry) from spec strings, so every
//! grid here is data, not a hand-built match arm, and any driver can be
//! re-pointed at a different engine with `--engine`/`--engines`.
//! Every spec measurement is also logged via [`crate::bench::record`]
//! for the `BENCH_attention.json` satellite output.

use crate::analysis::bandwidth::{
    dense_flash_bytes, effective_bandwidth, flash_sfa_bytes, measure_stream_bandwidth,
};
use crate::analysis::costmodel::PowerLaw;
use crate::analysis::flops::{dense_forward, sfa_forward, AttnShape};
use crate::attention::decode::{DenseKvCache, SparseKvCache};
use crate::attention::flash_sfa::{FlashSfa, SfaTileCounts};
use crate::attention::registry::{parse_spec, EngineSpec};
use crate::attention::{Engine, Scorer};
use crate::bench::harness::{bench, BenchResult};
use crate::bench::table::{fmt_speedup, fmt_time, Table};
use crate::sparse::memory::{kv_cache_bytes_dense, kv_cache_bytes_sfa, Widths};
use crate::sparse::topk::{topk_with, TopkAlgo};
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

fn qkv(n: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    (
        Matrix::randn(n, d, &mut rng, 1.0),
        Matrix::randn(n, d, &mut rng, 1.0),
        Matrix::randn(n, d, &mut rng, 1.0),
    )
}

/// Benchmark one registry spec's causal forward and log the result for
/// `BENCH_attention.json`.
fn run_forward_spec(spec: &str, n: usize, d: usize, budget_s: f64) -> BenchResult {
    run_forward_spec_counted(spec, n, d, budget_s).0
}

/// [`run_forward_spec`] that additionally instruments FlashSFA specs:
/// one extra counted forward at the same shape yields the tile-level
/// work counters (dense-visited / folded / threshold-skipped), logged
/// alongside the timing in `BENCH_attention.json`.
fn run_forward_spec_counted(
    spec: &str,
    n: usize,
    d: usize,
    budget_s: f64,
) -> (BenchResult, Option<SfaTileCounts>) {
    let parsed = parse_spec(spec).expect("engine spec");
    let engine = parsed.build();
    let (q, k, v) = qkv(n, d, 42);
    let r = bench(&engine.name(), budget_s, || {
        std::hint::black_box(engine.forward(&q, &k, &v, true));
    });
    let tiles = match parsed {
        EngineSpec::FlashSfa { k: fk, bq, bk, skip, thresh, mass } => {
            let eng = FlashSfa {
                k: fk,
                block_q: bq,
                block_k: bk,
                threads: crate::util::threadpool::default_threads(),
                skip,
                skip_thresh: thresh,
                skip_mass: mass,
            };
            let qc = crate::sparse::topk_codes(&q, fk);
            let kc = crate::sparse::topk_codes(&k, fk);
            let kf = crate::sparse::CscFeat::from_codes(&kc);
            Some(eng.forward_codes_counted(&qc, &kf, &v, d, true).1)
        }
        _ => None,
    };
    crate::bench::record_with_tiles(
        &parsed.canonical(),
        n,
        d,
        parsed.feature_k().unwrap_or(0),
        &r,
        tiles,
    );
    (r, tiles)
}

/// Paper-taxonomy category of an engine family (Table 10/11 rows).
fn spec_category(spec: &EngineSpec) -> &'static str {
    match spec {
        EngineSpec::Dense | EngineSpec::FlashDense { .. } => "dense",
        EngineSpec::FlashSfa { .. } | EngineSpec::SfaRef { .. } => "feature",
        EngineSpec::Window { scorer, .. } => match scorer {
            Scorer::Dense => "token",
            Scorer::Sfa { .. } => "token+SFA",
        },
        EngineSpec::LowRank { scorer, .. }
        | EngineSpec::Mla { scorer, .. }
        | EngineSpec::Quant { scorer } => match scorer {
            Scorer::Dense => "feature",
            Scorer::Sfa { .. } => "feature+SFA",
        },
        EngineSpec::Performer { .. } => "kernel",
    }
}

/// Spec-driven engine latency grid: arbitrary registry specs × context
/// lengths at one head dim (the CLI `bench engines` surface). The
/// `flash_dense` baseline is always measured for the speedup column.
pub fn engine_grid(specs: &[String], ctxs: &[usize], d: usize, budget_s: f64) -> Table {
    let mut t = Table::new(
        &format!("Engine grid — forward latency via registry specs (d={d})"),
        &[
            "engine spec",
            "ctx",
            "median",
            "p95",
            "speedup vs flash_dense",
            "tiles v/f/s",
            "posting hits",
        ],
    );
    let tile_cols = |tiles: Option<SfaTileCounts>| -> (String, String) {
        match tiles {
            Some(c) => (
                format!("{}/{}/{}", c.tiles_visited, c.tiles_folded, c.tiles_skipped),
                c.posting_hits.to_string(),
            ),
            None => ("-".into(), "-".into()),
        }
    };
    for &ctx in ctxs {
        let (dense, _) = run_forward_spec_counted("flash_dense", ctx, d, budget_s);
        let (dv, dp) = tile_cols(None);
        t.row(vec![
            "flash_dense".into(),
            ctx.to_string(),
            fmt_time(dense.median_s),
            fmt_time(dense.p95_s),
            "1.00x".into(),
            dv,
            dp,
        ]);
        for spec in specs {
            // Only the exact default baseline is deduplicated; other
            // flash_dense block configs are benchmarked like any spec.
            if parse_spec(spec).ok() == parse_spec("flash_dense").ok() {
                continue;
            }
            let (r, tiles) = run_forward_spec_counted(spec, ctx, d, budget_s);
            let (tv, tp) = tile_cols(tiles);
            t.row(vec![
                spec.clone(),
                ctx.to_string(),
                fmt_time(r.median_s),
                fmt_time(r.p95_s),
                fmt_speedup(dense.median_s / r.median_s),
                tv,
                tp,
            ]);
        }
    }
    t
}

/// Fig. 3: latency vs sparsity at different modular levels (score-only,
/// +softmax+PV fused, full layer ≈ flash path) at one context length.
pub fn fig3(ctx: usize, d: usize, ks: &[usize], budget_s: f64) -> Table {
    let mut t = Table::new(
        &format!("Fig 3 — latency vs sparsity at module levels (ctx={ctx}, d={d})"),
        &["level", "variant", "median", "speedup vs dense"],
    );
    let (q, k, _v) = qkv(ctx, d, 1);
    // Level 1: scoring only (dot-product module).
    let dense_score = bench("dense-score", budget_s, || {
        std::hint::black_box(crate::attention::dense::scores(&q, &k, 1.0, true));
    });
    t.row(vec!["score".into(), "dense".into(), fmt_time(dense_score.median_s), "1.00x".into()]);
    for &kk in ks {
        let qc = crate::sparse::topk_codes(&q, kk);
        let kc = crate::sparse::topk_codes(&k, kk);
        let kf = crate::sparse::CscFeat::from_codes(&kc);
        let r = bench(&format!("sfa-score k={kk}"), budget_s, || {
            std::hint::black_box(crate::sparse::spgemm::spgemm_scores(&qc, &kf, 1.0, true));
        });
        t.row(vec![
            "score".into(),
            format!("sfa_k{kk}"),
            fmt_time(r.median_s),
            fmt_speedup(dense_score.median_s / r.median_s),
        ]);
    }
    // Level 2: full attention (score+softmax+PV), flash engines.
    let dense_full = run_forward_spec("flash_dense", ctx, d, budget_s);
    t.row(vec![
        "attention".into(),
        "dense(flash)".into(),
        fmt_time(dense_full.median_s),
        "1.00x".into(),
    ]);
    for &kk in ks {
        let r = run_forward_spec(&format!("sfa:k={kk}"), ctx, d, budget_s);
        t.row(vec![
            "attention".into(),
            format!("flash_sfa_k{kk}"),
            fmt_time(r.median_s),
            fmt_speedup(dense_full.median_s / r.median_s),
        ]);
    }
    // Level 3: naive materializing attention for reference ("module
    // levels compound": gains grow with more of the stack included).
    let dense_naive = run_forward_spec("dense", ctx, d, budget_s);
    t.row(vec![
        "attention".into(),
        "dense(naive)".into(),
        fmt_time(dense_naive.median_s),
        fmt_speedup(dense_full.median_s / dense_naive.median_s),
    ]);
    t
}

/// Fig. 4 / Table 9: the latency grid over (d, k, ctx).
pub fn table9(ctxs: &[usize], dims: &[usize], ks: &[usize], budget_s: f64) -> Table {
    let mut t = Table::new(
        "Table 9 / Fig 4 — forward latency (ms) vs context, dim, sparsity",
        &["variant", "ctx", "median", "speedup vs dense"],
    );
    for &d in dims {
        for &ctx in ctxs {
            let dense = run_forward_spec("flash_dense", ctx, d, budget_s);
            t.row(vec![
                format!("Dense_{d}"),
                ctx.to_string(),
                fmt_time(dense.median_s),
                "1.00x".into(),
            ]);
            for &kk in ks {
                if kk >= d {
                    continue;
                }
                let r = run_forward_spec(&format!("sfa:k={kk}"), ctx, d, budget_s);
                t.row(vec![
                    format!("Sparse_{kk}/{d}"),
                    ctx.to_string(),
                    fmt_time(r.median_s),
                    fmt_speedup(dense.median_s / r.median_s),
                ]);
            }
        }
    }
    t
}

/// Fig. 5: FLOPs and KV-cache bytes vs context (cost model).
pub fn fig5(ctxs: &[usize], d: usize, k: usize) -> Table {
    let mut t = Table::new(
        &format!("Fig 5 — FLOPs & KV-cache scaling (d={d}, k={k}, fp16/int8 widths)"),
        &["ctx", "dense TFLOPs", "SFA TFLOPs", "FLOP ratio",
          "dense KV MB", "SFA KV MB", "KV saving"],
    );
    for &ctx in ctxs {
        let shape = AttnShape::table6(ctx, d);
        let df = dense_forward(shape).tflops();
        let sf = sfa_forward(shape, k, 64).tflops();
        let w = Widths::PAPER;
        let dkv = kv_cache_bytes_dense(ctx, d, w) as f64 / 1e6;
        let skv = kv_cache_bytes_sfa(ctx, d, k, w) as f64 / 1e6;
        t.row(vec![
            ctx.to_string(),
            format!("{df:.2}"),
            format!("{sf:.2}"),
            format!("{:.2}x", df / sf),
            format!("{dkv:.1}"),
            format!("{skv:.1}"),
            format!("{:.0}%", (1.0 - skv / dkv) * 100.0),
        ]);
    }
    t
}

/// Fig. 6: log-log TTFT & TTNT scaling + fitted exponents. The sparse
/// side is any registry spec (default `sfa:k=<k>` from the CLI).
pub fn fig6(ctxs: &[usize], d: usize, k: usize, budget_s: f64) -> (Table, Table) {
    fig6_spec(ctxs, d, k, &format!("sfa:k={k}"), budget_s)
}

/// Fig. 6 with an explicit engine spec on the sparse side.
pub fn fig6_spec(
    ctxs: &[usize],
    d: usize,
    k: usize,
    spec: &str,
    budget_s: f64,
) -> (Table, Table) {
    let label = parse_spec(spec).map(|p| p.canonical()).unwrap_or_else(|_| spec.to_string());
    let mut prefill = Table::new(
        &format!("Fig 6a — TTFT (prefill) scaling, d={d}, engine={label}"),
        &["ctx", "dense", "engine", "speedup"],
    );
    let mut dense_pts = Vec::new();
    let mut sfa_pts = Vec::new();
    for &ctx in ctxs {
        let dense = run_forward_spec("flash_dense", ctx, d, budget_s);
        let sfa = run_forward_spec(spec, ctx, d, budget_s);
        dense_pts.push(dense.median_s);
        sfa_pts.push(sfa.median_s);
        prefill.row(vec![
            ctx.to_string(),
            fmt_time(dense.median_s),
            fmt_time(sfa.median_s),
            fmt_speedup(dense.median_s / sfa.median_s),
        ]);
    }
    let pl_dense = PowerLaw::fit(ctxs, &dense_pts);
    let pl_sfa = PowerLaw::fit(ctxs, &sfa_pts);
    prefill.row(vec![
        "fit α".into(),
        format!("{:.2}", pl_dense.alpha),
        format!("{:.2}", pl_sfa.alpha),
        "-".into(),
    ]);

    let mut decode = Table::new(
        &format!("Fig 6b — TTNT (decode w/ KV cache) vs context, d={d}"),
        &["ctx", "dense", "sfa", "speedup"],
    );
    for &ctx in ctxs {
        let mut rng = Rng::new(3);
        let keys = Matrix::randn(ctx, d, &mut rng, 1.0);
        let vals = Matrix::randn(ctx, d, &mut rng, 1.0);
        let q: Vec<f32> = rng.normal_vec(d, 1.0);
        let mut dc = DenseKvCache::new(d, d);
        let mut sc = SparseKvCache::new(d, d, k);
        for i in 0..ctx {
            dc.append(keys.row(i), vals.row(i));
            sc.append(keys.row(i), vals.row(i));
        }
        let mut out = vec![0f32; d];
        let rd = bench("dense-decode", budget_s, || {
            dc.decode(&q, &mut out);
            std::hint::black_box(&out);
        });
        let rs = bench("sfa-decode", budget_s, || {
            sc.decode(&q, &mut out);
            std::hint::black_box(&out);
        });
        decode.row(vec![
            ctx.to_string(),
            fmt_time(rd.median_s),
            fmt_time(rs.median_s),
            fmt_speedup(rd.median_s / rs.median_s),
        ]);
    }
    (prefill, decode)
}

/// Table 6: TFLOPs / INOPs per configuration (cost model, validated
/// against instrumented engine counts in analysis::flops tests).
pub fn table6(ctxs: &[usize]) -> Table {
    let mut t = Table::new(
        "Table 6 — operation counts (B=8, H=8)",
        &["config", "ctx", "TFLOPs", "GINOPs"],
    );
    for (d, ks) in [(128usize, vec![32usize, 16, 8]), (64usize, vec![16, 8, 4])] {
        for &ctx in ctxs {
            let dense = dense_forward(AttnShape::table6(ctx, d));
            t.row(vec![
                format!("Dense_{d}"),
                ctx.to_string(),
                format!("{:.2}", dense.tflops()),
                "-".into(),
            ]);
            for &kk in &ks {
                let c = sfa_forward(AttnShape::table6(ctx, d), kk, 64);
                t.row(vec![
                    format!("Sparse_{kk}/{d}"),
                    ctx.to_string(),
                    format!("{:.2}", c.tflops()),
                    format!("{:.2}", c.ginops()),
                ]);
            }
        }
    }
    t
}

/// Table 7: memory bandwidth with and without compute.
pub fn table7(ctx: usize, d: usize, k: usize, budget_s: f64) -> Table {
    let mut t = Table::new(
        "Table 7 — effective bandwidth (GB/s): kernels are compute-bound",
        &["kernel", "GB/s"],
    );
    let stream = measure_stream_bandwidth(64 << 20, 5);
    let w = Widths::OURS;
    let dense = run_forward_spec("flash_dense", ctx, d, budget_s);
    let sfa = run_forward_spec(&format!("sfa:k={k}"), ctx, d, budget_s);
    let dense_bw = effective_bandwidth(dense_flash_bytes(ctx, d, d, 64, w), dense.median_s);
    let sfa_bw = effective_bandwidth(flash_sfa_bytes(ctx, d, d, k, 64, w), sfa.median_s);
    t.row(vec!["dense (full kernel)".into(), format!("{dense_bw:.2}")]);
    t.row(vec!["stream (w/o compute)".into(), format!("{stream:.2}")]);
    t.row(vec![format!("flash_sfa k={k} (full kernel)"), format!("{sfa_bw:.2}")]);
    t.row(vec!["stream (w/o compute)".into(), format!("{stream:.2}")]);
    t
}

/// Table 8: top-k selection latency, partial-select (RTopK analog) vs
/// full-sort (torch.topk analog), plus share of total attention time.
pub fn table8(ctxs: &[usize], d: usize, k: usize, budget_s: f64) -> Table {
    let mut t = Table::new(
        &format!("Table 8 — top-k selection latency (d={d}, k={k})"),
        &["ctx", "full-sort", "partial-select", "speedup", "% of attention fwd"],
    );
    for &ctx in ctxs {
        let mut rng = Rng::new(7);
        let x = Matrix::randn(ctx, d, &mut rng, 1.0);
        let full = bench("full-sort", budget_s, || {
            std::hint::black_box(topk_with(&x, k, TopkAlgo::FullSort));
        });
        let part = bench("partial", budget_s, || {
            std::hint::black_box(topk_with(&x, k, TopkAlgo::PartialSelect));
        });
        let attn = run_forward_spec(&format!("sfa:k={k}"), ctx, d, budget_s * 0.5);
        t.row(vec![
            ctx.to_string(),
            fmt_time(full.median_s),
            fmt_time(part.median_s),
            fmt_speedup(full.median_s / part.median_s),
            format!("{:.2}%", 100.0 * part.median_s / attn.median_s),
        ]);
    }
    t
}

/// The Table 10/11 default engine line-up at one (ctx, d, k) point —
/// token-sparse / feature-level baselines and their SFA compositions,
/// expressed as registry specs.
pub fn table10_specs(ctx: usize, d: usize, k: usize) -> Vec<String> {
    let w = ctx / 8;
    let r = d / 4;
    vec![
        format!("sfa:k={k}"),
        format!("window:w={w}"),
        format!("window:w={w},scorer=sfa_k{k}"),
        format!("lowrank:r={r}"),
        format!("lowrank:r={r},scorer=sfa_k{k}"),
        format!("mla:r={r}"),
        format!("mla:r={r},scorer=sfa_k{k}"),
        "quant".to_string(),
        format!("quant:scorer=sfa_k{k}"),
        format!("performer:m={}", 2 * d),
    ]
}

/// Table 10/11 latency block over a spec grid (defaults from
/// [`table10_specs`]; `--engines` re-points it).
pub fn table10_latency(ctx: usize, d: usize, k: usize, budget_s: f64) -> Table {
    table10_latency_specs(&table10_specs(ctx, d, k), ctx, d, budget_s)
}

pub fn table10_latency_specs(specs: &[String], ctx: usize, d: usize, budget_s: f64) -> Table {
    let mut t = Table::new(
        &format!(
            "Table 10/11 — forward latency of methods & SFA compositions (ctx={ctx}, d={d})"
        ),
        &["category", "engine", "median", "speedup vs dense"],
    );
    let dense = run_forward_spec("flash_dense", ctx, d, budget_s);
    t.row(vec![
        "dense".into(),
        "flash_dense".into(),
        fmt_time(dense.median_s),
        "1.00x".into(),
    ]);
    for spec in specs {
        let parsed = parse_spec(spec).expect("table10 spec");
        let r = run_forward_spec(spec, ctx, d, budget_s);
        t.row(vec![
            spec_category(&parsed).into(),
            parsed.canonical(),
            fmt_time(r.median_s),
            fmt_speedup(dense.median_s / r.median_s),
        ]);
    }
    t
}

/// Fig 1b headline: FLOPs + KV reductions at the default config
/// (k comes from the CLI `--engine` spec's feature budget).
pub fn fig1(ctx: usize, k: usize) -> Table {
    let mut t = Table::new(
        &format!("Fig 1b — headline efficiency (d=128, k={k}, fp16/int8)"),
        &["metric", "dense", "sfa", "reduction"],
    );
    let shape = AttnShape::table6(ctx, 128);
    let df = dense_forward(shape).tflops();
    let sf = sfa_forward(shape, k, 64).tflops();
    let w = Widths::PAPER;
    let dkv = kv_cache_bytes_dense(ctx, 128, w) as f64 / 1e6;
    let skv = kv_cache_bytes_sfa(ctx, 128, k, w) as f64 / 1e6;
    t.row(vec![
        "attention TFLOPs".into(),
        format!("{df:.2}"),
        format!("{sf:.2}"),
        format!("{:.0}%", (1.0 - sf / df) * 100.0),
    ]);
    t.row(vec![
        "KV-cache MB".into(),
        format!("{dkv:.0}"),
        format!("{skv:.0}"),
        format!("{:.0}%", (1.0 - skv / dkv) * 100.0),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    // Smoke tests with tiny budgets: every driver runs end-to-end and
    // produces a sane table; absolute timing is not asserted.

    #[test]
    fn fig5_table_has_expected_shape() {
        let t = fig5(&[1024, 4096], 64, 4);
        assert_eq!(t.rows.len(), 2);
        assert!(t.render().contains("KV saving"));
    }

    #[test]
    fn table6_matches_paper_dense_columns() {
        let t = table6(&[8192]);
        let rendered = t.render();
        assert!(rendered.contains("Dense_128"));
        // Spot value: Dense_128@8192 = 2.23 TFLOPs in the paper; our
        // count lands within rounding (2.22-2.23).
        assert!(
            rendered.contains("2.23") || rendered.contains("2.22"),
            "{rendered}"
        );
    }

    #[test]
    fn fig1_headline_near_paper_numbers() {
        let t = fig1(131072, 16);
        let r = t.render();
        // FLOPs reduction ≈ 49%, KV ≈ 41% (paper Fig. 1b).
        assert!(r.contains("%"), "{r}");
    }

    #[test]
    fn small_latency_sweep_runs() {
        let t = table9(&[256], &[64], &[8], 0.02);
        assert!(t.rows.len() >= 2);
    }

    #[test]
    fn engine_grid_runs_and_records() {
        let t = engine_grid(
            &["sfa:k=4,bq=16,bk=16".to_string(), "sfa:k=4,bq=16,bk=16,skip=on".to_string()],
            &[128],
            32,
            0.01,
        );
        assert_eq!(t.rows.len(), 3);
        let recs = crate::bench::snapshot_records();
        let hit = recs
            .iter()
            .find(|r| r.spec == "sfa:k=4,bq=16,bk=16" && r.n == 128 && r.d == 32)
            .expect("engine grid logged its measurement");
        assert_eq!(hit.k, 4);
        assert!(hit.median_s > 0.0 && hit.p95_s >= hit.median_s);
        // FlashSFA rows carry tile counters; skip=off runs every
        // enumerated tile through the dense path, and skip=on
        // partitions the same causal tile grid.
        let tiles = hit.tiles.expect("sfa rows carry tile counters");
        assert!(tiles.tiles_visited > 0 && tiles.total_tiles() > 0);
        assert_eq!(tiles.tiles_folded + tiles.tiles_skipped, 0);
        let skip_hit = recs
            .iter()
            .find(|r| r.spec == "sfa:k=4,bq=16,bk=16,skip=on" && r.n == 128)
            .expect("skip=on row recorded");
        let st = skip_hit.tiles.expect("skip row carries counters");
        assert_eq!(st.total_tiles(), tiles.total_tiles());
        let dense_rec = recs
            .iter()
            .find(|r| r.spec == "flash_dense:bq=64,bk=64" && r.n == 128)
            .expect("baseline recorded");
        assert!(dense_rec.tiles.is_none(), "non-sfa rows omit counters");
    }

    #[test]
    fn table10_specs_cover_compositions() {
        let specs = table10_specs(4096, 128, 8);
        assert!(specs.iter().any(|s| s.contains("scorer=sfa_k8")));
        for s in &specs {
            parse_spec(s).unwrap();
        }
    }
}
