//! `sfa bench serve` — scheduling-policy comparison on a
//! mixed-prompt-length workload, over identical request streams and
//! the identical lane/session substrate: the wave baseline, the
//! continuous batcher with worst-case page reservations, and the
//! continuous batcher under each configured KV eviction policy
//! (`{none, h2o, snapkv, quest}` by default — policy-budget admission
//! reserves the pruned steady state, so achieved concurrency at a
//! fixed `max_pages` is the headline delta). Reports tokens/s,
//! time-to-first-token, p50/p95/p99 per-token latency, page occupancy,
//! pruned pages, and achieved concurrency; serializes the whole
//! comparison to BENCH_serve.json.
//!
//! `--replicas N` switches to the multi-replica router comparison: a
//! trace-driven workload (bursty on-off arrivals, heavy-tailed batch
//! prompts, shared system prompts, an interactive/batch SLO mix)
//! driven through [`ReplicaRouter`] under the SLO-aware cost model and
//! under round-robin, plus a single-replica stream reference — pinning
//! placement-independent streams and reporting goodput to
//! BENCH_serve_router.json.

use std::time::Instant;

use crate::bench::table::{fmt_speedup, fmt_time, Table};
use crate::coordinator::metrics::{Goodput, Percentiles};
use crate::coordinator::router::{tally_goodput, ReplicaRouter, RouterPolicy};
use crate::attention::registry::{parse_spec, validate_draft_spec};
use crate::serve::{
    pages_needed, ContinuousBatcher, FinishedRequest, KvTierCfg, PagedKvPolicy,
    PrefixCacheConfig, PrefixCacheStats, RequestId, RequestState, Scheduler, ServeConfig,
    ServeRequest, ServeSampling, SloClass, SpeculateConfig, TierPolicy, WaveScheduler,
};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use crate::util::stats::mean;

/// Workload shape for one `bench serve` run.
#[derive(Debug, Clone)]
pub struct ServeBenchConfig {
    pub requests: usize,
    /// Prompt lengths drawn uniformly from `[prompt_min, prompt_max]`.
    pub prompt_min: usize,
    pub prompt_max: usize,
    /// `max_new` drawn uniformly from `[max_new_min, max_new_max]` —
    /// the length skew that makes wave tails expensive.
    pub max_new_min: usize,
    pub max_new_max: usize,
    /// Engine specs assigned round-robin across requests.
    pub engines: Vec<String>,
    /// KV eviction policies to sweep the continuous batcher over
    /// (`None` = worst-case reservations, the policy baseline).
    pub policies: Vec<Option<PagedKvPolicy>>,
    /// `Some` switches `bench serve` to the **prefix-cache comparison**
    /// (`--prefix-cache`): a repeated-system-prompt workload driven
    /// through the continuous batcher cold (no cache) and warm (radix
    /// prefix cache on), pinning bit-identical greedy streams and
    /// recording hit rate and TTFT gain.
    pub prefix: Option<PrefixBenchConfig>,
    /// `Some` switches `bench serve` to the **chunked-prefill
    /// interference comparison** (`--prefill-chunk`): one long prompt
    /// against a set of short decode lanes, swept over chunk sizes
    /// (0 = monolithic baseline), reporting the decode lanes'
    /// time-to-first-token under long-prompt interference and pinning
    /// bit-identical greedy streams across every chunk size.
    pub chunked: Option<ChunkedBenchConfig>,
    /// `Some` switches `bench serve` to the **speculative-decoding
    /// comparison** (`--speculate draft=<spec> --gamma N`): the same
    /// workload driven through the continuous batcher plain and
    /// speculating, pinning bit-identical token streams and recording
    /// acceptance rate and tokens per decode step vs the baseline.
    pub speculate: Option<SpeculateConfig>,
    /// `Some` switches `bench serve` to the **multi-replica router
    /// comparison** (`--replicas`): a trace-driven workload (bursty
    /// on-off arrivals, heavy-tailed batch prompt lengths, shared
    /// system prompts, a fixed interactive/batch SLO mix) driven
    /// through the SLO-aware `ReplicaRouter` and a round-robin
    /// baseline, pinning placement-independent streams and reporting
    /// goodput (tokens/s within SLO).
    pub router: Option<RouterBenchConfig>,
    /// `Some` switches `bench serve` to the **tiered-KV comparison**
    /// (`--kv-tier`): the same workload driven through the continuous
    /// batcher all-fp32, with the configured cold tier, and with a
    /// never-triggering tier (the bit-for-bit identity pin) —
    /// recording demotions, the effective-capacity ratio the half-unit
    /// accounting buys, achieved concurrency, and the worst dequant
    /// error ratio.
    pub tiered: Option<KvTierCfg>,
    pub serve: ServeConfig,
    pub seed: u64,
    /// Base for per-request sampler seeds: request `i` decodes with
    /// sampler seed `sampler_seed + i` (`--sampler-seed`; 0 keeps the
    /// historical seeds). Only observable under stochastic sampling.
    pub sampler_seed: u64,
    /// `Some(t)` samples every workload request at temperature `t`
    /// instead of greedy (`--temperature`) — the stochastic path the
    /// speculative verify must also preserve bit-for-bit.
    pub temperature: Option<f32>,
}

/// Shape of the long-prompt-interference workload + chunk sweep for
/// the chunked-prefill comparison.
#[derive(Debug, Clone)]
pub struct ChunkedBenchConfig {
    /// Tokens in the single interfering long prompt.
    pub long_prompt: usize,
    /// `max_new` for the long request (small — its decode tail is not
    /// what this bench measures).
    pub long_max_new: usize,
    /// Number of short requests competing with the long prefill.
    pub decode_lanes: usize,
    /// Prompt length of each short request.
    pub decode_prompt: usize,
    /// `max_new` of each short request.
    pub decode_max_new: usize,
    /// Chunk sizes to sweep; must include 0 (the monolithic baseline).
    pub chunks: Vec<usize>,
}

impl Default for ChunkedBenchConfig {
    fn default() -> ChunkedBenchConfig {
        ChunkedBenchConfig {
            long_prompt: 4096,
            long_max_new: 8,
            decode_lanes: 8,
            decode_prompt: 16,
            decode_max_new: 32,
            chunks: vec![0, 64, 256, 1024],
        }
    }
}

/// Shape of the shared-prefix workload + cache sizing for the
/// prefix-cache comparison.
#[derive(Debug, Clone, Copy)]
pub struct PrefixBenchConfig {
    /// Tokens of system prompt shared by every request.
    pub system_prompt: usize,
    /// Nominal page budget for the radix cache.
    pub cache_pages: usize,
}

impl Default for PrefixBenchConfig {
    fn default() -> PrefixBenchConfig {
        PrefixBenchConfig { system_prompt: 512, cache_pages: 1024 }
    }
}

/// Shape of the trace-driven multi-replica workload + SLO deadlines
/// for the router comparison (`--replicas`).
#[derive(Debug, Clone, Copy)]
pub struct RouterBenchConfig {
    /// Replica count behind the router (each its own page pool and
    /// prefix cache).
    pub replicas: usize,
    /// Fraction of requests carrying the interactive SLO class,
    /// assigned by stratified accumulator (the mix is exact, not a
    /// coin flip).
    pub interactive_frac: f64,
    /// Interactive SLO deadlines, seconds.
    pub ttft_s: f64,
    pub tpot_s: f64,
    /// Distinct shared system prompts — the prefix-affinity targets —
    /// and their length in tokens.
    pub system_prompts: usize,
    pub system_prompt_len: usize,
    /// Radix prefix-cache page budget per replica (affinity routing
    /// needs warm caches to probe).
    pub cache_pages: usize,
    /// On-burst shape: arrivals per burst, and mean arrivals per
    /// scheduler quantum inside a burst (exponential inter-arrival
    /// gaps — the Poisson half of on-off traffic).
    pub burst_len: usize,
    pub burst_rate: f64,
    /// Idle scheduler quanta between bursts (the off phase).
    pub burst_gap_steps: usize,
    /// Bounded-Pareto tail exponent for batch prompt lengths (smaller
    /// = heavier tail; interactive prompts stay short).
    pub tail_alpha: f64,
}

impl Default for RouterBenchConfig {
    fn default() -> RouterBenchConfig {
        RouterBenchConfig {
            replicas: 2,
            interactive_frac: 0.5,
            ttft_s: 0.25,
            tpot_s: 0.05,
            system_prompts: 4,
            system_prompt_len: 64,
            cache_pages: 1024,
            burst_len: 8,
            burst_rate: 2.0,
            burst_gap_steps: 12,
            tail_alpha: 1.2,
        }
    }
}

/// Display label for one swept policy slot.
pub fn policy_label(p: &Option<PagedKvPolicy>) -> String {
    match p {
        None => "none".into(),
        Some(p) => p.label(),
    }
}

impl Default for ServeBenchConfig {
    fn default() -> ServeBenchConfig {
        ServeBenchConfig {
            requests: 32,
            prompt_min: 32,
            prompt_max: 1024,
            max_new_min: 8,
            max_new_max: 96,
            engines: vec!["sfa:k=8".into()],
            policies: vec![
                None,
                Some(PagedKvPolicy::H2o { budget: 128, recent: 16 }),
                Some(PagedKvPolicy::SnapKv { budget: 128, recent: 16 }),
                Some(PagedKvPolicy::Quest { budget: 128 }),
            ],
            prefix: None,
            chunked: None,
            speculate: None,
            router: None,
            tiered: None,
            // Enough lanes that the page budget, not the lane cap, is
            // what policy-budget admission relaxes.
            serve: ServeConfig { max_lanes: 32, ..ServeConfig::default() },
            seed: 42,
            sampler_seed: 0,
            temperature: None,
        }
    }
}

/// One scheduler's measurements over the workload.
#[derive(Debug, Clone)]
pub struct RunStats {
    pub scheduler: String,
    /// KV eviction policy label (`"none"` when unpruned).
    pub policy: String,
    pub requests: usize,
    pub failed: usize,
    pub tokens_out: u64,
    pub wall_s: f64,
    pub tok_s: f64,
    pub ttft: Percentiles,
    pub token_lat: Percentiles,
    pub e2e: Percentiles,
    pub steps: usize,
    pub peak_pages: usize,
    pub mean_pages: f64,
    pub mean_live: f64,
    /// Most concurrently live sequences observed after any step — the
    /// achieved-concurrency headline at a fixed page budget.
    pub peak_live: usize,
    /// Pages returned to the pool by policy eviction over the run.
    pub pages_pruned: usize,
    /// Pages demoted to the int8 cold tier over the run (lane tiering
    /// plus radix demote-before-drop; zero without `kv_tier`).
    pub pages_demoted: usize,
    /// Cold pages promoted back to fp32 over the run.
    pub pages_promoted: usize,
    /// Worst per-element dequant error / (scale/2) observed by any
    /// demotion (`<= 1.0` is within the quantizer contract).
    pub tier_error_ratio: f32,
    /// Step-mean of `2 * pages_in_use / units_in_use` — 1.0 all-hot,
    /// → 2.0 as the whole cache demotes: how many nominal pages one
    /// physical page budget holds.
    pub capacity_ratio_mean: f64,
    /// Peak of the same ratio over the run's steps.
    pub capacity_ratio_peak: f64,
    /// Mean time-to-first-token over all finished requests, s.
    pub ttft_mean_s: f64,
    /// Prompt-prefix cache counters (all-zero without a prefix cache).
    pub prefix: PrefixCacheStats,
}

/// Build the deterministic mixed-length request stream.
pub fn workload(cfg: &ServeBenchConfig) -> Vec<ServeRequest> {
    let mut rng = Rng::new(cfg.seed);
    let vocab = cfg.serve.vocab as u64;
    (0..cfg.requests)
        .map(|i| {
            let plen = rng.range(cfg.prompt_min, cfg.prompt_max + 1);
            let max_new = rng.range(cfg.max_new_min, cfg.max_new_max + 1);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
            let mut req = ServeRequest::new(prompt)
                .max_new(max_new)
                .engine(&cfg.engines[i % cfg.engines.len()])
                .seed(cfg.sampler_seed.wrapping_add(i as u64));
            if let Some(t) = cfg.temperature {
                req = req.sampling(ServeSampling::Temperature(t));
            }
            req
        })
        .collect()
}

/// Submit the whole stream, then step the scheduler to completion,
/// integrating page-occupancy and achieved concurrency along the way.
pub fn drive(
    sched: &mut dyn Scheduler,
    label: &str,
    policy: &str,
    reqs: &[ServeRequest],
) -> RunStats {
    drive_keep(sched, label, policy, reqs).0
}

/// [`drive`], also returning the finished-request records (the
/// prefix-cache comparison pins cold-vs-warm token streams on them).
pub fn drive_keep(
    sched: &mut dyn Scheduler,
    label: &str,
    policy: &str,
    reqs: &[ServeRequest],
) -> (RunStats, Vec<FinishedRequest>) {
    let t0 = Instant::now();
    for r in reqs {
        sched.submit(r.clone()).expect("bench workload fits queue and budget");
    }
    let mut steps = 0usize;
    let mut peak_pages = 0usize;
    let mut sum_pages = 0f64;
    let mut sum_live = 0f64;
    let mut peak_live = 0usize;
    let mut pages_pruned = 0usize;
    let mut pages_demoted = 0usize;
    let mut pages_promoted = 0usize;
    let mut sum_ratio = 0f64;
    let mut peak_ratio = 1f64;
    while sched.has_work() {
        let r = sched.step();
        steps += 1;
        peak_pages = peak_pages.max(r.pages_in_use);
        sum_pages += r.pages_in_use as f64;
        sum_live += r.live as f64;
        peak_live = peak_live.max(r.live);
        pages_pruned += r.pages_pruned;
        pages_demoted += r.pages_demoted;
        pages_promoted += r.pages_promoted;
        // Nominal pages per half-unit of physical budget: the tiered
        // capacity multiplier this step (1.0 when everything is hot).
        let ratio = if r.kv_units_in_use > 0 {
            2.0 * r.pages_in_use as f64 / r.kv_units_in_use as f64
        } else {
            1.0
        };
        sum_ratio += ratio;
        peak_ratio = peak_ratio.max(ratio);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    sched.metrics_mut().wall_s = wall_s;
    let finished = sched.take_finished();
    let failed =
        finished.iter().filter(|f| matches!(f.state, RequestState::Failed { .. })).count();
    let m = sched.metrics();
    let stats = RunStats {
        scheduler: label.to_string(),
        policy: policy.to_string(),
        requests: finished.len(),
        failed,
        tokens_out: m.tokens_out,
        wall_s,
        tok_s: m.throughput_tok_s(),
        ttft: m.ttft(),
        token_lat: m.token_latency(),
        e2e: m.e2e(),
        steps,
        peak_pages,
        mean_pages: if steps == 0 { 0.0 } else { sum_pages / steps as f64 },
        mean_live: if steps == 0 { 0.0 } else { sum_live / steps as f64 },
        peak_live,
        pages_pruned,
        pages_demoted,
        pages_promoted,
        tier_error_ratio: sched.tier_error_ratio(),
        capacity_ratio_mean: if steps == 0 { 1.0 } else { sum_ratio / steps as f64 },
        capacity_ratio_peak: peak_ratio,
        ttft_mean_s: mean(&m.ttft_s),
        prefix: sched.prefix_stats(),
    };
    (stats, finished)
}

/// Build the repeated-system-prompt request stream: every prompt is
/// `system_prompt` shared tokens followed by a per-request suffix
/// (first suffix token forced distinct so the shared prefix is exactly
/// the system prompt), lengths drawn from the configured ranges.
pub fn workload_shared_prefix(cfg: &ServeBenchConfig, px: &PrefixBenchConfig) -> Vec<ServeRequest> {
    let mut rng = Rng::new(cfg.seed ^ 0x5157_EA11);
    let vocab = cfg.serve.vocab as u64;
    let sys: Vec<i32> = (0..px.system_prompt).map(|_| rng.below(vocab) as i32).collect();
    let max_suffix = cfg.prompt_max.saturating_sub(px.system_prompt).max(2);
    (0..cfg.requests)
        .map(|i| {
            let suffix_len = rng.range(2, max_suffix + 1);
            let mut prompt = sys.clone();
            prompt.push((i % cfg.serve.vocab) as i32);
            for _ in 1..suffix_len {
                prompt.push(rng.below(vocab) as i32);
            }
            let max_new = rng.range(cfg.max_new_min, cfg.max_new_max + 1);
            let mut req = ServeRequest::new(prompt)
                .max_new(max_new)
                .engine(&cfg.engines[i % cfg.engines.len()])
                .seed(cfg.sampler_seed.wrapping_add(i as u64));
            if let Some(t) = cfg.temperature {
                req = req.sampling(ServeSampling::Temperature(t));
            }
            req
        })
        .collect()
}

/// The prefix-cache comparison: cold vs warm continuous batching over
/// the identical shared-prefix stream.
#[derive(Debug, Clone)]
pub struct PrefixComparison {
    pub cold: RunStats,
    pub warm: RunStats,
    /// Hit fraction over the warm run's admissions.
    pub hit_rate: f64,
    /// Mean prompt tokens served from cache per finished request.
    pub shared_tokens_mean: f64,
    /// Greedy streams bit-for-bit identical cold vs warm (the
    /// correctness pin; recorded so CI trajectories catch a break).
    pub streams_identical: bool,
    /// cold mean TTFT / warm mean TTFT (> 1 means the cache helps).
    pub ttft_gain: f64,
    /// warm tok/s / cold tok/s.
    pub tok_s_gain: f64,
}

/// Run the shared-prefix workload cold (no prefix cache) and warm
/// (radix prefix cache on) through the continuous batcher, staggering
/// the first request so its prompt path is cached before the rest of
/// the stream arrives (the steady-state serving shape).
pub fn bench_serve_prefix(cfg: &ServeBenchConfig) -> (Table, PrefixComparison) {
    let px = cfg.prefix.unwrap_or_default();
    let reqs = workload_shared_prefix(cfg, &px);
    assert!(!reqs.is_empty(), "prefix comparison needs at least one request");
    let run = |prefix: Option<PrefixCacheConfig>, label: &str| {
        let serve = ServeConfig { prefix_cache: prefix, kv_policy: None, ..cfg.serve };
        let mut s = ContinuousBatcher::new(serve);
        // Stagger: first request alone (it inserts the system-prompt
        // path), then the rest of the stream.
        let t0 = Instant::now();
        let (warmup, rest) = reqs.split_at(1);
        let (w0, mut f0) = drive_keep(&mut s, label, "none", warmup);
        let (mut stats, mut fin) = drive_keep(&mut s, label, "none", rest);
        // Merge the two drive segments into one run record: the
        // metrics-derived fields (tokens_out, TTFT/latency
        // percentiles, prefix stats) already accumulate across both
        // drives; wall-clock, throughput, and the per-step integrals
        // must be re-based on the whole staggered run or the JSON
        // artifact over-reports tok/s.
        fin.append(&mut f0);
        fin.sort_by_key(|f| f.id);
        let total_steps = w0.steps + stats.steps;
        if total_steps > 0 {
            stats.mean_pages = (w0.mean_pages * w0.steps as f64
                + stats.mean_pages * stats.steps as f64)
                / total_steps as f64;
            stats.mean_live = (w0.mean_live * w0.steps as f64
                + stats.mean_live * stats.steps as f64)
                / total_steps as f64;
            stats.capacity_ratio_mean = (w0.capacity_ratio_mean * w0.steps as f64
                + stats.capacity_ratio_mean * stats.steps as f64)
                / total_steps as f64;
        }
        stats.steps = total_steps;
        stats.peak_pages = stats.peak_pages.max(w0.peak_pages);
        stats.peak_live = stats.peak_live.max(w0.peak_live);
        stats.pages_pruned += w0.pages_pruned;
        stats.pages_demoted += w0.pages_demoted;
        stats.pages_promoted += w0.pages_promoted;
        stats.tier_error_ratio = stats.tier_error_ratio.max(w0.tier_error_ratio);
        stats.capacity_ratio_peak = stats.capacity_ratio_peak.max(w0.capacity_ratio_peak);
        stats.requests += w0.requests;
        stats.failed += w0.failed;
        stats.wall_s = t0.elapsed().as_secs_f64();
        stats.tok_s =
            if stats.wall_s > 0.0 { stats.tokens_out as f64 / stats.wall_s } else { 0.0 };
        s.metrics_mut().wall_s = stats.wall_s;
        (stats, fin)
    };
    let (cold, cold_fin) = run(None, "continuous-cold");
    let (warm, warm_fin) = run(
        Some(PrefixCacheConfig { max_pages: px.cache_pages }),
        "continuous-prefix",
    );
    let streams_identical = cold_fin.len() == warm_fin.len()
        && cold_fin.iter().zip(&warm_fin).all(|(c, w)| c.id == w.id && c.tokens == w.tokens);
    let admissions = warm.prefix.hits + warm.prefix.misses;
    let hit_rate =
        if admissions == 0 { 0.0 } else { warm.prefix.hits as f64 / admissions as f64 };
    let shared_tokens_mean = if warm_fin.is_empty() {
        0.0
    } else {
        warm_fin.iter().map(|f| f.prefix_shared as f64).sum::<f64>() / warm_fin.len() as f64
    };
    let cmp = PrefixComparison {
        ttft_gain: if warm.ttft_mean_s > 0.0 { cold.ttft_mean_s / warm.ttft_mean_s } else { 0.0 },
        tok_s_gain: if cold.tok_s > 0.0 { warm.tok_s / cold.tok_s } else { 0.0 },
        hit_rate,
        shared_tokens_mean,
        streams_identical,
        cold,
        warm,
    };

    let mut t = Table::new(
        &format!(
            "bench serve --prefix-cache — cold vs radix prefix cache over {} requests \
             (system prompt {}, prompts ≤{}, max_new {}–{}, engines {})",
            cfg.requests,
            px.system_prompt,
            cfg.prompt_max,
            cfg.max_new_min,
            cfg.max_new_max,
            cfg.engines.join(";"),
        ),
        &[
            "run",
            "tok/s",
            "TTFT mean",
            "TTFT p50",
            "hit rate",
            "shared tok (mean)",
            "peak pages",
            "identical streams",
        ],
    );
    for (label, s) in [("cold", &cmp.cold), ("prefix", &cmp.warm)] {
        t.row(vec![
            label.into(),
            format!("{:.1}", s.tok_s),
            fmt_time(s.ttft_mean_s),
            fmt_time(s.ttft.p50),
            if label == "cold" {
                "-".into()
            } else {
                format!("{:.0}%", cmp.hit_rate * 100.0)
            },
            if label == "cold" { "-".into() } else { format!("{:.1}", cmp.shared_tokens_mean) },
            s.peak_pages.to_string(),
            if label == "cold" { "-".into() } else { cmp.streams_identical.to_string() },
        ]);
    }
    t.row(vec![
        "gain".into(),
        fmt_speedup(cmp.tok_s_gain),
        fmt_speedup(cmp.ttft_gain),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
    ]);
    (t, cmp)
}

/// One chunk size's measurements over the interference workload.
#[derive(Debug, Clone)]
pub struct ChunkedRun {
    /// Swept `ServeConfig::prefill_chunk` (0 = monolithic baseline).
    pub chunk: usize,
    /// Time-to-first-token over the short decode lanes only — the
    /// latency the long prompt's prefill interferes with.
    pub decode_ttft: Percentiles,
    pub decode_ttft_mean_s: f64,
    /// The long request's own TTFT (chunking trades it away).
    pub long_ttft_s: f64,
    pub tok_s: f64,
    pub wall_s: f64,
    pub steps: usize,
    /// Per-request greedy streams, id-ordered (the invariance pin).
    pub streams: Vec<(RequestId, Vec<i32>)>,
}

/// The chunked-prefill comparison: the chunk-size sweep over the
/// identical interference workload.
#[derive(Debug, Clone)]
pub struct ChunkedComparison {
    pub shape: ChunkedBenchConfig,
    pub runs: Vec<ChunkedRun>,
    /// Greedy streams bit-for-bit identical across every chunk size,
    /// monolithic included (the correctness pin; recorded so CI
    /// trajectories catch a break).
    pub streams_identical: bool,
    /// Chunk size with the lowest decode-lane TTFT p95.
    pub best_chunk: usize,
    /// monolithic decode-TTFT p95 / best chunked decode-TTFT p95
    /// (> 1 means interleaving shields the decode lanes).
    pub ttft_p95_gain: f64,
}

/// The chunked-prefill interference comparison: one long prompt
/// submitted ahead of `decode_lanes` short requests, the whole stream
/// re-run at every swept chunk size. Monolithic (chunk 0) stalls the
/// short lanes' first tokens behind the entire long prefill; chunked
/// runs bound the per-step interference to one chunk.
pub fn bench_serve_chunked(cfg: &ServeBenchConfig) -> (Table, ChunkedComparison) {
    let ck = cfg.chunked.clone().unwrap_or_default();
    assert!(ck.chunks.contains(&0), "sweep needs the monolithic baseline (chunk 0)");
    assert!(ck.long_prompt >= 1 && ck.decode_lanes >= 1 && ck.decode_prompt >= 1);
    let mut rng = Rng::new(cfg.seed ^ 0xC41C);
    let vocab = cfg.serve.vocab as u64;
    let long_prompt: Vec<i32> = (0..ck.long_prompt).map(|_| rng.below(vocab) as i32).collect();
    let shorts: Vec<Vec<i32>> = (0..ck.decode_lanes)
        .map(|_| (0..ck.decode_prompt).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    let engine = cfg.engines.first().cloned().unwrap_or_else(|| "sfa:k=8".into());
    let mut runs: Vec<ChunkedRun> = Vec::with_capacity(ck.chunks.len());
    for &chunk in &ck.chunks {
        let mut serve = ServeConfig {
            prefill_chunk: chunk,
            kv_policy: None,
            prefix_cache: None,
            ..cfg.serve
        };
        // Auto-size the geometry so the workload itself (not the
        // config defaults) decides what fits: every lane must be live
        // at once for the interference to be measured.
        serve.max_seq = serve.max_seq.max(ck.long_prompt + ck.long_max_new + 1);
        serve.max_lanes = serve.max_lanes.max(ck.decode_lanes + 1);
        let needed = pages_needed(ck.long_prompt, ck.long_max_new, serve.heads, serve.page_size)
            + ck.decode_lanes
                * pages_needed(ck.decode_prompt, ck.decode_max_new, serve.heads, serve.page_size);
        serve.max_pages = serve.max_pages.max(needed);
        let mut s = ContinuousBatcher::new(serve);
        let t0 = Instant::now();
        let long_id = s
            .submit(
                ServeRequest::new(long_prompt.clone())
                    .max_new(ck.long_max_new)
                    .engine(&engine)
                    .seed(0),
            )
            .expect("interference workload fits the auto-sized budget");
        let short_ids: Vec<RequestId> = shorts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                s.submit(
                    ServeRequest::new(p.clone())
                        .max_new(ck.decode_max_new)
                        .engine(&engine)
                        .seed(1 + i as u64),
                )
                .expect("interference workload fits the auto-sized budget")
            })
            .collect();
        let mut steps = 0usize;
        while s.has_work() {
            s.step();
            steps += 1;
        }
        let wall_s = t0.elapsed().as_secs_f64();
        s.metrics_mut().wall_s = wall_s;
        let tok_s = s.metrics().throughput_tok_s();
        let fin = s.take_finished();
        assert!(
            fin.iter().all(|f| matches!(f.state, RequestState::Finished { .. })),
            "chunk={chunk}: every interference request terminates"
        );
        let ttfts: Vec<f64> = short_ids
            .iter()
            .map(|id| fin.iter().find(|f| f.id == *id).expect("short finished").ttft_s)
            .collect();
        let long_ttft_s = fin.iter().find(|f| f.id == long_id).expect("long finished").ttft_s;
        let mut streams: Vec<(RequestId, Vec<i32>)> =
            fin.iter().map(|f| (f.id, f.tokens.clone())).collect();
        streams.sort_by_key(|(id, _)| *id);
        runs.push(ChunkedRun {
            chunk,
            decode_ttft: Percentiles::of(&ttfts),
            decode_ttft_mean_s: mean(&ttfts),
            long_ttft_s,
            tok_s,
            wall_s,
            steps,
            streams,
        });
    }
    let mono = runs.iter().find(|r| r.chunk == 0).expect("baseline present").clone();
    let streams_identical = runs.iter().all(|r| r.streams == mono.streams);
    let best = runs
        .iter()
        .filter(|r| r.chunk > 0)
        .min_by(|a, b| a.decode_ttft.p95.partial_cmp(&b.decode_ttft.p95).unwrap())
        .cloned();
    let (best_chunk, ttft_p95_gain) = match &best {
        Some(b) if b.decode_ttft.p95 > 0.0 => {
            (b.chunk, mono.decode_ttft.p95 / b.decode_ttft.p95)
        }
        Some(b) => (b.chunk, 0.0),
        None => (0, 0.0),
    };
    let cmp = ChunkedComparison { shape: ck.clone(), runs, streams_identical, best_chunk, ttft_p95_gain };

    let mut t = Table::new(
        &format!(
            "bench serve --prefill-chunk — prefill–decode interleaving: one {}-token prompt \
             against {} decode lanes (prompt {}, max_new {}, engine {})",
            ck.long_prompt, ck.decode_lanes, ck.decode_prompt, ck.decode_max_new, engine,
        ),
        &[
            "chunk",
            "decode TTFT p50",
            "decode TTFT p95",
            "long TTFT",
            "tok/s",
            "steps",
            "identical streams",
        ],
    );
    for r in &cmp.runs {
        t.row(vec![
            if r.chunk == 0 { "0 (monolithic)".into() } else { r.chunk.to_string() },
            fmt_time(r.decode_ttft.p50),
            fmt_time(r.decode_ttft.p95),
            fmt_time(r.long_ttft_s),
            format!("{:.1}", r.tok_s),
            r.steps.to_string(),
            cmp.streams_identical.to_string(),
        ]);
    }
    let mut row = vec![
        format!("gain (chunk {})", cmp.best_chunk),
        String::new(),
        fmt_speedup(cmp.ttft_p95_gain),
    ];
    row.resize(7, String::new());
    t.row(row);
    (t, cmp)
}

/// The speculative-decoding comparison: plain vs speculating
/// continuous batching over the identical request stream.
#[derive(Debug, Clone)]
pub struct SpecComparison {
    /// Canonical draft-engine spec.
    pub draft: String,
    pub gamma: usize,
    pub baseline: RunStats,
    pub speculative: RunStats,
    /// Token streams bit-for-bit identical with speculation on vs off
    /// (the correctness pin; the CLI hard-fails when false).
    pub streams_identical: bool,
    /// Fraction of proposed draft tokens the target accepted.
    pub acceptance_rate: f64,
    /// Mean tokens committed per decode-pass lane-step, speculating.
    pub tokens_per_step: f64,
    /// Same for the plain run — exactly 1.0 by construction.
    pub baseline_tokens_per_step: f64,
    /// `tokens_per_step / baseline_tokens_per_step` — > 1.0 iff any
    /// draft token was ever accepted.
    pub tokens_per_step_gain: f64,
    /// speculating tok/s ÷ plain tok/s (wall-clock; the toy model's
    /// draft forwards are not free, so this can sit below the
    /// tokens/step gain).
    pub tok_s_gain: f64,
}

/// Drive the workload through the continuous batcher twice — plain and
/// speculating — pinning bit-identical streams and reporting the
/// acceptance economics.
pub fn bench_serve_spec(cfg: &ServeBenchConfig) -> (Table, SpecComparison) {
    let sp = cfg.speculate.expect("speculative comparison requires a draft spec + γ");
    // Fail fast with the registry's own message if any workload engine
    // is an invalid target for this draft (drive() would panic later).
    for e in &cfg.engines {
        let target = parse_spec(e).expect("workload engine parses");
        if let Err(err) = validate_draft_spec(&sp.draft, &target) {
            panic!("--speculate: {}", err.0);
        }
    }
    let reqs = workload(cfg);
    let run = |speculate: Option<SpeculateConfig>, label: &str| {
        let serve = ServeConfig { speculate, kv_policy: None, ..cfg.serve };
        let mut s = ContinuousBatcher::new(serve);
        let (stats, mut fin) = drive_keep(&mut s, label, "none", &reqs);
        fin.sort_by_key(|f| f.id);
        let m = s.metrics();
        (stats, fin, m.acceptance_rate(), m.tokens_per_step())
    };
    let (base, base_fin, _, base_tps) = run(None, "continuous");
    let (spec, spec_fin, acceptance_rate, spec_tps) = run(Some(sp), "continuous-spec");
    let streams_identical = base_fin.len() == spec_fin.len()
        && base_fin.iter().zip(&spec_fin).all(|(a, b)| a.id == b.id && a.tokens == b.tokens);
    let cmp = SpecComparison {
        draft: sp.draft.canonical(),
        gamma: sp.gamma,
        streams_identical,
        acceptance_rate,
        tokens_per_step: spec_tps,
        baseline_tokens_per_step: base_tps,
        tokens_per_step_gain: if base_tps > 0.0 { spec_tps / base_tps } else { 0.0 },
        tok_s_gain: if base.tok_s > 0.0 { spec.tok_s / base.tok_s } else { 0.0 },
        baseline: base,
        speculative: spec,
    };

    let mut t = Table::new(
        &format!(
            "bench serve --speculate — plain vs draft-and-verify (draft {}, γ={}) over {} \
             requests (prompts {}–{}, max_new {}–{}, engines {})",
            cmp.draft,
            cmp.gamma,
            cfg.requests,
            cfg.prompt_min,
            cfg.prompt_max,
            cfg.max_new_min,
            cfg.max_new_max,
            cfg.engines.join(";"),
        ),
        &["run", "tok/s", "tok/step", "accept rate", "steps", "identical streams"],
    );
    for (label, s, tps, acc) in [
        ("plain", &cmp.baseline, cmp.baseline_tokens_per_step, None),
        ("speculative", &cmp.speculative, cmp.tokens_per_step, Some(cmp.acceptance_rate)),
    ] {
        t.row(vec![
            label.into(),
            format!("{:.1}", s.tok_s),
            format!("{tps:.2}"),
            match acc {
                None => "-".into(),
                Some(a) => format!("{:.0}%", a * 100.0),
            },
            s.steps.to_string(),
            if label == "plain" { "-".into() } else { cmp.streams_identical.to_string() },
        ]);
    }
    let mut row = vec![
        "gain".into(),
        fmt_speedup(cmp.tok_s_gain),
        fmt_speedup(cmp.tokens_per_step_gain),
    ];
    row.resize(6, String::new());
    t.row(row);
    (t, cmp)
}

/// The BENCH_serve_spec.json document: workload shape plus the
/// `speculative` comparison block (stream pin, acceptance rate,
/// tokens/step vs the non-speculative baseline).
pub fn spec_to_json(cfg: &ServeBenchConfig, cmp: &SpecComparison) -> String {
    obj(vec![
        (
            "workload",
            obj(vec![
                ("requests", Json::from(cfg.requests)),
                ("prompt_min", Json::from(cfg.prompt_min)),
                ("prompt_max", Json::from(cfg.prompt_max)),
                ("max_new_min", Json::from(cfg.max_new_min)),
                ("max_new_max", Json::from(cfg.max_new_max)),
                (
                    "engines",
                    Json::Arr(cfg.engines.iter().map(|e| Json::from(e.as_str())).collect()),
                ),
                ("max_lanes", Json::from(cfg.serve.max_lanes)),
                ("max_pages", Json::from(cfg.serve.max_pages)),
                ("page_size", Json::from(cfg.serve.page_size)),
                ("heads", Json::from(cfg.serve.heads)),
                ("d", Json::from(cfg.serve.d)),
                ("seed", Json::from(cfg.seed as usize)),
                ("sampler_seed", Json::from(cfg.sampler_seed as usize)),
                (
                    "temperature",
                    match cfg.temperature {
                        None => Json::from("greedy"),
                        Some(t) => Json::from(t as f64),
                    },
                ),
            ]),
        ),
        (
            "speculative",
            obj(vec![
                ("draft", Json::from(cmp.draft.as_str())),
                ("gamma", Json::from(cmp.gamma)),
                ("streams_identical", Json::from(cmp.streams_identical)),
                ("acceptance_rate", Json::from(cmp.acceptance_rate)),
                ("tokens_per_step", Json::from(cmp.tokens_per_step)),
                ("baseline_tokens_per_step", Json::from(cmp.baseline_tokens_per_step)),
                ("tokens_per_step_gain", Json::from(cmp.tokens_per_step_gain)),
                ("tokens_per_s_gain", Json::from(cmp.tok_s_gain)),
                ("baseline", stats_json(&cmp.baseline)),
                ("speculative_run", stats_json(&cmp.speculative)),
            ]),
        ),
    ])
    .to_string()
}

/// The tiered-KV comparison (`--kv-tier`): the same workload three
/// ways through the continuous batcher.
#[derive(Debug, Clone)]
pub struct TieredComparison {
    /// The tier config the `tiered` run demotes under.
    pub tier: KvTierCfg,
    /// All-fp32 reference (`kv_tier: None`).
    pub base: RunStats,
    /// The configured cold tier.
    pub tiered: RunStats,
    /// A tier whose hot window exceeds `max_seq` — configured but
    /// unable to fire, the bit-for-bit identity pin.
    pub no_trigger: RunStats,
    /// Peak of `2 * pages_in_use / units_in_use` over the tiered run:
    /// how many nominal pages the fixed physical budget held at the
    /// most-compressed step (1.0 all-hot, → 2.0 fully cold).
    pub effective_capacity_gain: f64,
    /// tiered mean_live / base mean_live at the same `max_pages` — the
    /// admission headroom compressed reservations buy.
    pub concurrency_gain_mean_live: f64,
    /// Tiered vs base stream equality. Legitimately false once pages
    /// demote (int8 round-trip perturbs logits); recorded, not gated.
    pub tiered_streams_identical: bool,
    /// No-trigger vs base stream equality — must be true (a cold tier
    /// that never fires is invisible).
    pub streams_identical_no_trigger: bool,
}

/// Canonical spec string for a tier config (table + JSON labels).
pub fn tier_label(t: &KvTierCfg) -> String {
    format!("tier:cold_after={},policy={}", t.cold_after, t.policy.label())
}

/// The tiered-KV comparison: identical request streams driven all-fp32,
/// under the configured cold tier, and under a tier that can never
/// fire. Records demotion traffic, the worst dequant error ratio, the
/// effective-capacity multiplier of the half-unit accounting, achieved
/// concurrency at the fixed `max_pages`, and the two stream pins.
pub fn bench_serve_tiered(cfg: &ServeBenchConfig) -> (Table, TieredComparison) {
    let tier = cfg.tiered.expect("bench_serve_tiered requires ServeBenchConfig::tiered");
    let reqs = workload(cfg);
    let policy = policy_label(&cfg.serve.kv_policy);
    let run = |kv_tier: Option<KvTierCfg>, label: &str| {
        let serve = ServeConfig { kv_tier, ..cfg.serve };
        let mut s = ContinuousBatcher::new(serve);
        let (stats, mut fin) = drive_keep(&mut s, label, &policy, &reqs);
        fin.sort_by_key(|f| f.id);
        (stats, fin)
    };
    let (base, base_fin) = run(None, "fp32");
    let (tiered, tiered_fin) = run(Some(tier), "tiered");
    // Same machinery, hot window past any reachable sequence length:
    // zero demotions, and the streams must match the fp32 run exactly.
    let quiet = KvTierCfg { cold_after: cfg.serve.max_seq + 1, policy: TierPolicy::Lru };
    let (no_trigger, quiet_fin) = run(Some(quiet), "no-trigger");
    let same = |a: &[FinishedRequest], b: &[FinishedRequest]| {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| x.id == y.id && x.tokens == y.tokens)
    };
    let cmp = TieredComparison {
        tier,
        effective_capacity_gain: tiered.capacity_ratio_peak,
        concurrency_gain_mean_live: if base.mean_live > 0.0 {
            tiered.mean_live / base.mean_live
        } else {
            0.0
        },
        tiered_streams_identical: same(&base_fin, &tiered_fin),
        streams_identical_no_trigger: same(&base_fin, &quiet_fin),
        base,
        tiered,
        no_trigger,
    };

    let mut t = Table::new(
        &format!(
            "bench serve --kv-tier — fp32 vs int8 cold tier ({}) over {} requests \
             (prompts {}–{}, max_new {}–{}, engines {}, policy {}, max_pages {})",
            tier_label(&tier),
            cfg.requests,
            cfg.prompt_min,
            cfg.prompt_max,
            cfg.max_new_min,
            cfg.max_new_max,
            cfg.engines.join(";"),
            policy,
            cfg.serve.max_pages,
        ),
        &[
            "run",
            "tok/s",
            "demoted",
            "promoted",
            "err ratio",
            "capacity x̄",
            "capacity peak",
            "mean live",
            "peak live",
            "identical streams",
        ],
    );
    for (label, s, ident) in [
        ("fp32", &cmp.base, None),
        ("tiered", &cmp.tiered, Some(cmp.tiered_streams_identical)),
        ("no-trigger", &cmp.no_trigger, Some(cmp.streams_identical_no_trigger)),
    ] {
        t.row(vec![
            label.into(),
            format!("{:.1}", s.tok_s),
            s.pages_demoted.to_string(),
            s.pages_promoted.to_string(),
            format!("{:.3}", s.tier_error_ratio),
            format!("{:.2}", s.capacity_ratio_mean),
            format!("{:.2}", s.capacity_ratio_peak),
            format!("{:.2}", s.mean_live),
            s.peak_live.to_string(),
            match ident {
                None => "-".into(),
                Some(b) => b.to_string(),
            },
        ]);
    }
    let mut row = vec![
        "gain".into(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        fmt_speedup(cmp.effective_capacity_gain),
        fmt_speedup(cmp.concurrency_gain_mean_live),
    ];
    row.resize(10, String::new());
    t.row(row);
    (t, cmp)
}

/// The BENCH_serve_tiered.json document: workload shape, the three
/// runs, and the `tiered_kv` block (capacity gain, concurrency gain,
/// demotion traffic, dequant error bound, and both stream pins) the CI
/// smoke gate reads.
pub fn tiered_to_json(cfg: &ServeBenchConfig, cmp: &TieredComparison) -> String {
    obj(vec![
        (
            "workload",
            obj(vec![
                ("requests", Json::from(cfg.requests)),
                ("prompt_min", Json::from(cfg.prompt_min)),
                ("prompt_max", Json::from(cfg.prompt_max)),
                ("max_new_min", Json::from(cfg.max_new_min)),
                ("max_new_max", Json::from(cfg.max_new_max)),
                (
                    "engines",
                    Json::Arr(cfg.engines.iter().map(|e| Json::from(e.as_str())).collect()),
                ),
                ("policy", Json::from(policy_label(&cfg.serve.kv_policy).as_str())),
                ("max_lanes", Json::from(cfg.serve.max_lanes)),
                ("max_pages", Json::from(cfg.serve.max_pages)),
                ("page_size", Json::from(cfg.serve.page_size)),
                ("heads", Json::from(cfg.serve.heads)),
                ("d", Json::from(cfg.serve.d)),
                ("seed", Json::from(cfg.seed as usize)),
            ]),
        ),
        (
            "runs",
            Json::Arr(
                [&cmp.base, &cmp.tiered, &cmp.no_trigger].into_iter().map(stats_json).collect(),
            ),
        ),
        (
            "tiered_kv",
            obj(vec![
                ("tier", Json::from(tier_label(&cmp.tier).as_str())),
                ("cold_after", Json::from(cmp.tier.cold_after)),
                ("pages_demoted", Json::from(cmp.tiered.pages_demoted)),
                ("pages_promoted", Json::from(cmp.tiered.pages_promoted)),
                ("max_error_ratio", Json::from(cmp.tiered.tier_error_ratio as f64)),
                ("effective_capacity_gain", Json::from(cmp.effective_capacity_gain)),
                ("capacity_ratio_mean", Json::from(cmp.tiered.capacity_ratio_mean)),
                ("base_mean_live", Json::from(cmp.base.mean_live)),
                ("tiered_mean_live", Json::from(cmp.tiered.mean_live)),
                ("base_peak_live", Json::from(cmp.base.peak_live)),
                ("tiered_peak_live", Json::from(cmp.tiered.peak_live)),
                ("concurrency_gain_mean_live", Json::from(cmp.concurrency_gain_mean_live)),
                ("tiered_streams_identical", Json::from(cmp.tiered_streams_identical)),
                (
                    "streams_identical_no_trigger",
                    Json::from(cmp.streams_identical_no_trigger),
                ),
            ]),
        ),
    ])
    .to_string()
}

/// Build the trace-driven router workload: `(arrival_step, request)`
/// pairs in nondecreasing arrival order. Arrivals are bursty on-off
/// (exponential inter-arrival gaps inside a burst, an idle gap between
/// bursts), interactive prompts are short while batch prompts draw a
/// bounded-Pareto heavy tail up to `prompt_max`, every prompt opens
/// with one of a small set of shared system prompts (the
/// prefix-affinity targets), and the interactive/batch mix follows
/// `interactive_frac` exactly via a stratified accumulator.
pub fn workload_trace(
    cfg: &ServeBenchConfig,
    rb: &RouterBenchConfig,
) -> Vec<(usize, ServeRequest)> {
    let mut rng = Rng::new(cfg.seed ^ 0x2007_7E12);
    let vocab = cfg.serve.vocab as u64;
    let sys: Vec<Vec<i32>> = (0..rb.system_prompts.max(1))
        .map(|_| (0..rb.system_prompt_len).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    let slo = SloClass::Interactive { ttft_s: rb.ttft_s, tpot_s: rb.tpot_s };
    let short_max =
        (2 * cfg.prompt_min).clamp(cfg.prompt_min + 1, cfg.prompt_max.max(cfg.prompt_min + 1));
    let mut step = 0usize;
    let mut acc = 0.0f64;
    let mut out = Vec::with_capacity(cfg.requests);
    for i in 0..cfg.requests {
        if i > 0 && i % rb.burst_len.max(1) == 0 {
            step += rb.burst_gap_steps; // the off phase between bursts
        }
        let u = rng.next_f64().max(1e-12);
        step += (-u.ln() / rb.burst_rate.max(1e-9)) as usize;
        acc += rb.interactive_frac;
        let interactive = acc >= 1.0 - 1e-9;
        if interactive {
            acc -= 1.0;
        }
        let plen = if interactive {
            rng.range(cfg.prompt_min, short_max + 1)
        } else {
            let u = rng.next_f64().max(1e-12);
            let raw = cfg.prompt_min as f64 * u.powf(-1.0 / rb.tail_alpha.max(0.1));
            (raw as usize).clamp(cfg.prompt_min, cfg.prompt_max)
        };
        let mut prompt = sys[rng.below(sys.len() as u64) as usize].clone();
        // One forced-distinct token bounds the shared prefix at the
        // system prompt even when random suffixes collide.
        prompt.push((i % cfg.serve.vocab) as i32);
        while prompt.len() < plen.max(rb.system_prompt_len + 2) {
            prompt.push(rng.below(vocab) as i32);
        }
        let max_new = rng.range(cfg.max_new_min, cfg.max_new_max + 1);
        let mut req = ServeRequest::new(prompt)
            .max_new(max_new)
            .engine(&cfg.engines[i % cfg.engines.len()])
            .seed(cfg.sampler_seed.wrapping_add(i as u64))
            .slo(if interactive { slo } else { SloClass::Batch });
        if let Some(t) = cfg.temperature {
            req = req.sampling(ServeSampling::Temperature(t));
        }
        out.push((step, req));
    }
    out
}

/// One router policy's measurements over the arrival trace.
#[derive(Debug, Clone)]
pub struct RouterRunStats {
    pub policy: String,
    pub requests: usize,
    pub failed: usize,
    pub tokens_out: u64,
    pub wall_s: f64,
    pub tok_s: f64,
    /// SLO-meeting tokens per wall second — the headline.
    pub goodput_tok_s: f64,
    /// Fraction of requests that met their SLO class.
    pub attainment: f64,
    /// TTFT percentiles over the interactive / batch subsets.
    pub interactive_ttft: Percentiles,
    pub batch_ttft: Percentiles,
    pub interactive_requests: usize,
    /// Scheduler quanta stepped (every replica advances per quantum).
    pub steps: usize,
    /// Batch lanes preempted for interactive admission, all replicas.
    pub preempted: usize,
    /// Prefix-cache hit admissions summed across replicas.
    pub prefix_hits: u64,
    /// Routing decisions that landed on a replica with a warm prefix.
    pub affinity_hits: usize,
}

/// Drive one [`ReplicaRouter`] through an arrival trace: at each
/// scheduler quantum, submit every request whose arrival step has
/// come, then advance all replicas by one step (idle quanta between
/// bursts cost nothing). Returns the stats and the drained terminal
/// records in global-id order.
pub fn drive_router(
    router: &mut ReplicaRouter,
    label: &str,
    trace: &[(usize, ServeRequest)],
) -> (RouterRunStats, Vec<FinishedRequest>) {
    let t0 = Instant::now();
    let mut next = 0usize;
    let mut clock = 0usize;
    let mut steps = 0usize;
    let mut preempted = 0usize;
    while next < trace.len() || router.has_work() {
        while next < trace.len() && trace[next].0 <= clock {
            router.submit(trace[next].1.clone()).expect("trace fits queue and budget");
            next += 1;
        }
        if router.has_work() {
            let r = router.step();
            steps += 1;
            preempted += r.preempted;
        }
        clock += 1;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let finished = router.take_finished();
    let failed =
        finished.iter().filter(|f| matches!(f.state, RequestState::Failed { .. })).count();
    let mut goodput = Goodput::default();
    tally_goodput(&mut goodput, &finished);
    goodput.wall_s = wall_s;
    let inter: Vec<f64> =
        finished.iter().filter(|f| f.slo.is_interactive()).map(|f| f.ttft_s).collect();
    let batch: Vec<f64> =
        finished.iter().filter(|f| !f.slo.is_interactive()).map(|f| f.ttft_s).collect();
    let m = router.metrics();
    let stats = RouterRunStats {
        policy: label.to_string(),
        requests: finished.len(),
        failed,
        tokens_out: m.tokens_out,
        wall_s,
        tok_s: if wall_s > 0.0 { m.tokens_out as f64 / wall_s } else { 0.0 },
        goodput_tok_s: goodput.goodput_tok_s(),
        attainment: goodput.attainment(),
        interactive_ttft: Percentiles::of(&inter),
        batch_ttft: Percentiles::of(&batch),
        interactive_requests: inter.len(),
        steps,
        preempted,
        prefix_hits: router.prefix_hits(),
        affinity_hits: router.decisions().iter().filter(|d| d.affinity > 0).count(),
    };
    (stats, finished)
}

/// The `--replicas` comparison: the SLO-aware cost model vs round-robin
/// over the identical trace, plus a single-replica reference run that
/// pins placement-independent streams (any placement of any request
/// must produce the identical tokens).
#[derive(Debug, Clone)]
pub struct RouterComparison {
    pub replicas: usize,
    pub slo_aware: RouterRunStats,
    pub round_robin: RouterRunStats,
    pub single: RouterRunStats,
    /// All three runs' per-request token streams bit-for-bit identical
    /// (the correctness pin; the CI gate hard-fails when false).
    pub streams_identical: bool,
    /// round-robin interactive TTFT p95 ÷ SLO-aware p95 (> 1 means the
    /// cost model shields interactive latency).
    pub ttft_p95_gain: f64,
    /// SLO-aware goodput ÷ round-robin goodput.
    pub goodput_gain: f64,
}

/// Drive the arrival trace through the router three times — one
/// replica (the stream reference), `replicas` under the SLO-aware cost
/// model, and `replicas` under round-robin — and render the
/// comparison. Every run gets a radix prefix cache (affinity routing
/// probes it) and no KV eviction policy (mutually exclusive).
pub fn bench_serve_router(cfg: &ServeBenchConfig) -> (Table, RouterComparison) {
    let rb = cfg.router.unwrap_or_default();
    let trace = workload_trace(cfg, &rb);
    assert!(!trace.is_empty(), "router comparison needs at least one request");
    let serve = ServeConfig {
        kv_policy: None,
        prefix_cache: Some(PrefixCacheConfig { max_pages: rb.cache_pages }),
        ..cfg.serve
    };
    let mut run = |n: usize, policy: RouterPolicy, label: &str| {
        let mut router =
            ReplicaRouter::new(serve, n, policy).expect("bench serve config validates");
        drive_router(&mut router, label, &trace)
    };
    let (single, single_fin) = run(1, RouterPolicy::SloAware, "single");
    let (slo_aware, slo_fin) = run(rb.replicas, RouterPolicy::SloAware, "slo-aware");
    let (round_robin, rr_fin) = run(rb.replicas, RouterPolicy::RoundRobin, "round-robin");
    let same = |a: &[FinishedRequest], b: &[FinishedRequest]| {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| x.id == y.id && x.tokens == y.tokens)
    };
    let streams_identical = same(&single_fin, &slo_fin) && same(&single_fin, &rr_fin);
    let ttft_p95_gain = if slo_aware.interactive_ttft.p95 > 0.0 {
        round_robin.interactive_ttft.p95 / slo_aware.interactive_ttft.p95
    } else {
        0.0
    };
    let goodput_gain = if round_robin.goodput_tok_s > 0.0 {
        slo_aware.goodput_tok_s / round_robin.goodput_tok_s
    } else {
        0.0
    };
    let cmp = RouterComparison {
        replicas: rb.replicas,
        slo_aware,
        round_robin,
        single,
        streams_identical,
        ttft_p95_gain,
        goodput_gain,
    };

    let interactive = trace.iter().filter(|(_, r)| r.slo.is_interactive()).count();
    let mut t = Table::new(
        &format!(
            "bench serve --replicas — SLO-aware routing vs round-robin over {} replicas \
             ({} requests, {} interactive, system prompts {}×{}, prompts {}–{}, engines {})",
            cmp.replicas,
            cfg.requests,
            interactive,
            rb.system_prompts,
            rb.system_prompt_len,
            cfg.prompt_min,
            cfg.prompt_max,
            cfg.engines.join(";"),
        ),
        &[
            "policy",
            "goodput tok/s",
            "attainment",
            "int TTFT p50",
            "int TTFT p95",
            "batch TTFT p50",
            "preempted",
            "prefix hits",
            "affinity routed",
            "identical streams",
        ],
    );
    for (label, s) in [
        ("slo-aware", &cmp.slo_aware),
        ("round-robin", &cmp.round_robin),
        ("single (ref)", &cmp.single),
    ] {
        t.row(vec![
            label.into(),
            format!("{:.1}", s.goodput_tok_s),
            format!("{:.0}%", s.attainment * 100.0),
            fmt_time(s.interactive_ttft.p50),
            fmt_time(s.interactive_ttft.p95),
            fmt_time(s.batch_ttft.p50),
            s.preempted.to_string(),
            s.prefix_hits.to_string(),
            s.affinity_hits.to_string(),
            if label == "single (ref)" { "-".into() } else { cmp.streams_identical.to_string() },
        ]);
    }
    let mut row = vec![
        "gain (slo/rr)".into(),
        fmt_speedup(cmp.goodput_gain),
        String::new(),
        String::new(),
        fmt_speedup(cmp.ttft_p95_gain),
    ];
    row.resize(10, String::new());
    t.row(row);
    (t, cmp)
}

fn router_stats_json(s: &RouterRunStats) -> Json {
    obj(vec![
        ("policy", Json::from(s.policy.as_str())),
        ("requests", Json::from(s.requests)),
        ("failed", Json::from(s.failed)),
        ("tokens_out", Json::from(s.tokens_out as usize)),
        ("wall_s", Json::from(s.wall_s)),
        ("tokens_per_s", Json::from(s.tok_s)),
        ("goodput_tok_s", Json::from(s.goodput_tok_s)),
        ("slo_attainment", Json::from(s.attainment)),
        ("interactive_requests", Json::from(s.interactive_requests)),
        ("interactive_ttft", pcts_json(&s.interactive_ttft)),
        ("batch_ttft", pcts_json(&s.batch_ttft)),
        ("steps", Json::from(s.steps)),
        ("preempted", Json::from(s.preempted)),
        ("prefix_hits", Json::from(s.prefix_hits as usize)),
        ("affinity_hits", Json::from(s.affinity_hits)),
    ])
}

/// The BENCH_serve_router.json document: trace-workload shape plus the
/// `router` comparison block (stream pin, goodput, interactive TTFT
/// percentiles per policy — what the CI gate asserts on).
pub fn router_to_json(cfg: &ServeBenchConfig, cmp: &RouterComparison) -> String {
    let rb = cfg.router.unwrap_or_default();
    obj(vec![
        (
            "workload",
            obj(vec![
                ("requests", Json::from(cfg.requests)),
                ("prompt_min", Json::from(cfg.prompt_min)),
                ("prompt_max", Json::from(cfg.prompt_max)),
                ("max_new_min", Json::from(cfg.max_new_min)),
                ("max_new_max", Json::from(cfg.max_new_max)),
                (
                    "engines",
                    Json::Arr(cfg.engines.iter().map(|e| Json::from(e.as_str())).collect()),
                ),
                ("replicas", Json::from(rb.replicas)),
                ("interactive_frac", Json::from(rb.interactive_frac)),
                ("slo_ttft_s", Json::from(rb.ttft_s)),
                ("slo_tpot_s", Json::from(rb.tpot_s)),
                ("system_prompts", Json::from(rb.system_prompts)),
                ("system_prompt_len", Json::from(rb.system_prompt_len)),
                ("cache_pages", Json::from(rb.cache_pages)),
                ("burst_len", Json::from(rb.burst_len)),
                ("burst_rate", Json::from(rb.burst_rate)),
                ("burst_gap_steps", Json::from(rb.burst_gap_steps)),
                ("tail_alpha", Json::from(rb.tail_alpha)),
                ("max_lanes", Json::from(cfg.serve.max_lanes)),
                ("max_pages", Json::from(cfg.serve.max_pages)),
                ("page_size", Json::from(cfg.serve.page_size)),
                ("heads", Json::from(cfg.serve.heads)),
                ("d", Json::from(cfg.serve.d)),
                ("seed", Json::from(cfg.seed as usize)),
            ]),
        ),
        (
            "router",
            obj(vec![
                ("replicas", Json::from(cmp.replicas)),
                ("streams_identical", Json::from(cmp.streams_identical)),
                ("interactive_ttft_p95_gain", Json::from(cmp.ttft_p95_gain)),
                ("goodput_gain", Json::from(cmp.goodput_gain)),
                ("slo_aware", router_stats_json(&cmp.slo_aware)),
                ("round_robin", router_stats_json(&cmp.round_robin)),
                ("single_replica", router_stats_json(&cmp.single)),
            ]),
        ),
    ])
    .to_string()
}

/// Run the workload through the wave baseline and the continuous
/// batcher under every configured KV policy, and render the comparison.
pub fn bench_serve(cfg: &ServeBenchConfig) -> (Table, Vec<RunStats>) {
    let reqs = workload(cfg);
    let mut runs = Vec::with_capacity(1 + cfg.policies.len());
    let mut wave = WaveScheduler::new(cfg.serve);
    runs.push(drive(&mut wave, "wave", "none", &reqs));
    for pol in &cfg.policies {
        let mut cont = ContinuousBatcher::new(ServeConfig { kv_policy: *pol, ..cfg.serve });
        runs.push(drive(&mut cont, "continuous", &policy_label(pol), &reqs));
    }

    let mut t = Table::new(
        &format!(
            "bench serve — wave vs continuous (policy sweep) over {} requests \
             (prompts {}–{}, max_new {}–{}, engines {}, max_pages {})",
            cfg.requests,
            cfg.prompt_min,
            cfg.prompt_max,
            cfg.max_new_min,
            cfg.max_new_max,
            cfg.engines.join(";"),
            cfg.serve.max_pages,
        ),
        &[
            "scheduler",
            "policy",
            "tok/s",
            "TTFT p50",
            "tok p50",
            "tok p95",
            "steps",
            "peak pages",
            "pruned",
            "mean live",
            "peak live",
        ],
    );
    for s in &runs {
        t.row(vec![
            s.scheduler.clone(),
            s.policy.clone(),
            format!("{:.1}", s.tok_s),
            fmt_time(s.ttft.p50),
            fmt_time(s.token_lat.p50),
            fmt_time(s.token_lat.p95),
            s.steps.to_string(),
            s.peak_pages.to_string(),
            s.pages_pruned.to_string(),
            format!("{:.2}", s.mean_live),
            s.peak_live.to_string(),
        ]);
    }
    if let (Some(w), Some(c)) = (
        runs.iter().find(|r| r.scheduler == "wave"),
        runs.iter().find(|r| r.scheduler == "continuous" && r.policy == "none"),
    ) {
        let speedup = fmt_speedup(c.tok_s / w.tok_s.max(1e-12));
        let mut row = vec!["speedup".into(), String::new(), speedup];
        row.resize(11, String::new());
        t.row(row);
    }
    (t, runs)
}

fn pcts_json(p: &Percentiles) -> Json {
    obj(vec![
        ("p50_s", Json::from(p.p50)),
        ("p95_s", Json::from(p.p95)),
        ("p99_s", Json::from(p.p99)),
    ])
}

fn stats_json(s: &RunStats) -> Json {
    obj(vec![
        ("scheduler", Json::from(s.scheduler.as_str())),
        ("policy", Json::from(s.policy.as_str())),
        ("requests", Json::from(s.requests)),
        ("failed", Json::from(s.failed)),
        ("tokens_out", Json::from(s.tokens_out as usize)),
        ("wall_s", Json::from(s.wall_s)),
        ("tokens_per_s", Json::from(s.tok_s)),
        ("ttft", pcts_json(&s.ttft)),
        ("token_latency", pcts_json(&s.token_lat)),
        ("e2e", pcts_json(&s.e2e)),
        ("steps", Json::from(s.steps)),
        ("peak_pages", Json::from(s.peak_pages)),
        ("mean_pages", Json::from(s.mean_pages)),
        ("mean_live", Json::from(s.mean_live)),
        ("peak_live", Json::from(s.peak_live)),
        ("pages_pruned", Json::from(s.pages_pruned)),
        ("pages_demoted", Json::from(s.pages_demoted)),
        ("pages_promoted", Json::from(s.pages_promoted)),
        ("tier_error_ratio", Json::from(s.tier_error_ratio as f64)),
        ("capacity_ratio_mean", Json::from(s.capacity_ratio_mean)),
        ("capacity_ratio_peak", Json::from(s.capacity_ratio_peak)),
        ("ttft_mean_s", Json::from(s.ttft_mean_s)),
        (
            "prefix_cache",
            obj(vec![
                ("hits", Json::from(s.prefix.hits as usize)),
                ("misses", Json::from(s.prefix.misses as usize)),
                ("inserted", Json::from(s.prefix.inserted as usize)),
                ("evicted", Json::from(s.prefix.evicted as usize)),
                ("demoted", Json::from(s.prefix.demoted as usize)),
                ("promoted", Json::from(s.prefix.promoted as usize)),
                ("pages_nominal", Json::from(s.prefix.pages_nominal)),
            ]),
        ),
    ])
}

/// The BENCH_serve.json document: workload shape, every run (wave +
/// per-policy continuous), the wave-vs-continuous speedup, and the
/// policy-budget admission comparison (achieved concurrency at the
/// fixed `max_pages` versus worst-case reservation).
pub fn to_json(cfg: &ServeBenchConfig, runs: &[RunStats]) -> String {
    to_json_with_prefix(cfg, runs, None)
}

/// [`to_json`], optionally embedding the `--prefix-cache` comparison
/// block (hit rate, TTFT gain, and the bit-identical-streams pin).
pub fn to_json_with_prefix(
    cfg: &ServeBenchConfig,
    runs: &[RunStats],
    prefix: Option<&PrefixComparison>,
) -> String {
    to_json_full(cfg, runs, prefix, None)
}

/// The full BENCH_serve.json document: [`to_json_with_prefix`] plus an
/// optional `chunked_prefill` block (the `--prefill-chunk` interference
/// sweep: decode-lane TTFT per chunk size and the stream-invariance
/// pin).
pub fn to_json_full(
    cfg: &ServeBenchConfig,
    runs: &[RunStats],
    prefix: Option<&PrefixComparison>,
    chunked: Option<&ChunkedComparison>,
) -> String {
    let baseline = runs.iter().find(|r| r.scheduler == "continuous" && r.policy == "none");
    let mut doc = vec![
        (
            "workload",
            obj(vec![
                ("requests", Json::from(cfg.requests)),
                ("prompt_min", Json::from(cfg.prompt_min)),
                ("prompt_max", Json::from(cfg.prompt_max)),
                ("max_new_min", Json::from(cfg.max_new_min)),
                ("max_new_max", Json::from(cfg.max_new_max)),
                (
                    "engines",
                    Json::Arr(cfg.engines.iter().map(|e| Json::from(e.as_str())).collect()),
                ),
                (
                    "policies",
                    Json::Arr(
                        cfg.policies
                            .iter()
                            .map(|p| Json::from(policy_label(p).as_str()))
                            .collect(),
                    ),
                ),
                ("max_lanes", Json::from(cfg.serve.max_lanes)),
                ("max_pages", Json::from(cfg.serve.max_pages)),
                ("page_size", Json::from(cfg.serve.page_size)),
                ("heads", Json::from(cfg.serve.heads)),
                ("d", Json::from(cfg.serve.d)),
                ("seed", Json::from(cfg.seed as usize)),
            ]),
        ),
        ("runs", Json::Arr(runs.iter().map(stats_json).collect())),
    ];
    // Wave-vs-continuous speedup only exists when the sweep ran the
    // unpruned continuous baseline — omit the statistic rather than
    // record a fake 0x for trajectory tooling to trip over.
    if let (Some(w), Some(c)) = (runs.iter().find(|r| r.scheduler == "wave"), baseline) {
        if w.tok_s > 0.0 {
            doc.push(("speedup_tokens_per_s", Json::from(c.tok_s / w.tok_s)));
        }
    }
    // Achieved-concurrency delta: best eviction policy vs the
    // worst-case-reservation baseline at the same page budget.
    let best = runs
        .iter()
        .filter(|r| r.scheduler == "continuous" && r.policy != "none")
        .max_by(|a, b| a.mean_live.partial_cmp(&b.mean_live).unwrap());
    if let (Some(base), Some(best)) = (baseline, best) {
        doc.push((
            "policy_admission",
            obj(vec![
                ("baseline_mean_live", Json::from(base.mean_live)),
                ("baseline_peak_live", Json::from(base.peak_live)),
                ("best_policy", Json::from(best.policy.as_str())),
                ("best_mean_live", Json::from(best.mean_live)),
                ("best_peak_live", Json::from(best.peak_live)),
                (
                    "concurrency_gain_mean_live",
                    Json::from(if base.mean_live > 0.0 {
                        best.mean_live / base.mean_live
                    } else {
                        0.0
                    }),
                ),
                (
                    "tokens_per_s_vs_baseline",
                    Json::from(if base.tok_s > 0.0 { best.tok_s / base.tok_s } else { 0.0 }),
                ),
            ]),
        ));
    }
    if let Some(p) = prefix {
        let px = cfg.prefix.unwrap_or_default();
        doc.push((
            "prefix_cache",
            obj(vec![
                ("system_prompt", Json::from(px.system_prompt)),
                ("cache_pages", Json::from(px.cache_pages)),
                ("hit_rate", Json::from(p.hit_rate)),
                ("hits", Json::from(p.warm.prefix.hits as usize)),
                ("misses", Json::from(p.warm.prefix.misses as usize)),
                ("shared_tokens_mean", Json::from(p.shared_tokens_mean)),
                ("streams_identical", Json::from(p.streams_identical)),
                ("cold_ttft_mean_s", Json::from(p.cold.ttft_mean_s)),
                ("warm_ttft_mean_s", Json::from(p.warm.ttft_mean_s)),
                ("ttft_gain", Json::from(p.ttft_gain)),
                ("tokens_per_s_gain", Json::from(p.tok_s_gain)),
            ]),
        ));
    }
    if let Some(c) = chunked {
        doc.push((
            "chunked_prefill",
            obj(vec![
                ("long_prompt", Json::from(c.shape.long_prompt)),
                ("long_max_new", Json::from(c.shape.long_max_new)),
                ("decode_lanes", Json::from(c.shape.decode_lanes)),
                ("decode_prompt", Json::from(c.shape.decode_prompt)),
                ("decode_max_new", Json::from(c.shape.decode_max_new)),
                ("streams_identical", Json::from(c.streams_identical)),
                ("best_chunk", Json::from(c.best_chunk)),
                ("decode_ttft_p95_gain", Json::from(c.ttft_p95_gain)),
                (
                    "runs",
                    Json::Arr(
                        c.runs
                            .iter()
                            .map(|r| {
                                obj(vec![
                                    ("chunk", Json::from(r.chunk)),
                                    ("decode_ttft_p50_s", Json::from(r.decode_ttft.p50)),
                                    ("decode_ttft_p95_s", Json::from(r.decode_ttft.p95)),
                                    ("decode_ttft_mean_s", Json::from(r.decode_ttft_mean_s)),
                                    ("long_ttft_s", Json::from(r.long_ttft_s)),
                                    ("tokens_per_s", Json::from(r.tok_s)),
                                    ("wall_s", Json::from(r.wall_s)),
                                    ("steps", Json::from(r.steps)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ));
    }
    obj(doc).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ServeBenchConfig {
        ServeBenchConfig {
            requests: 6,
            prompt_min: 4,
            prompt_max: 16,
            max_new_min: 2,
            max_new_max: 6,
            engines: vec!["dense".into(), "sfa:k=4".into()],
            policies: vec![None],
            prefix: None,
            chunked: None,
            speculate: None,
            router: None,
            tiered: None,
            serve: ServeConfig {
                heads: 2,
                d: 8,
                vocab: 32,
                page_size: 4,
                max_pages: 512,
                max_lanes: 3,
                queue_capacity: 64,
                max_seq: 128,
                model_seed: 7,
                kv_policy: None,
                prefix_cache: None,
                prefill_chunk: 0,
                speculate: None,
                kv_tier: None,
            },
            seed: 1,
            sampler_seed: 0,
            temperature: None,
        }
    }

    #[test]
    fn bench_serve_completes_and_serializes() {
        let cfg = tiny();
        let (table, runs) = bench_serve(&cfg);
        assert_eq!(runs.len(), 2, "wave + one continuous policy slot");
        for r in &runs {
            assert_eq!(r.requests, cfg.requests, "{}: every request terminates", r.scheduler);
            assert_eq!(r.failed, 0, "{}", r.scheduler);
            assert!(r.tokens_out > 0 && r.steps > 0 && r.peak_pages > 0);
        }
        // Identical request streams ⇒ identical token counts; only the
        // schedule differs.
        assert_eq!(runs[0].tokens_out, runs[1].tokens_out);
        assert!(runs.iter().all(|r| r.mean_pages > 0.0 && r.mean_live > 0.0));
        let rendered = table.render();
        assert!(rendered.contains("continuous") && rendered.contains("wave"), "{rendered}");
        let doc = to_json(&cfg, &runs);
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.get("runs").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("speedup_tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            j.get("workload").unwrap().get("requests").unwrap().as_usize().unwrap(),
            6
        );
    }

    /// `--kv-tier` comparison: the tiered run demotes, its dequant
    /// error stays within the quantizer contract, the capacity ratio
    /// shows the half-unit headroom, and the never-firing tier leaves
    /// streams bit-for-bit identical to the fp32 run.
    #[test]
    fn tiered_bench_demotes_and_pins_no_trigger_streams() {
        let mut cfg = tiny();
        cfg.requests = 8;
        cfg.prompt_min = 8;
        cfg.prompt_max = 24;
        cfg.max_new_min = 8;
        cfg.max_new_max = 16;
        cfg.tiered = Some(KvTierCfg { cold_after: 4, policy: TierPolicy::Lru });
        let (table, cmp) = bench_serve_tiered(&cfg);
        for r in [&cmp.base, &cmp.tiered, &cmp.no_trigger] {
            assert_eq!(r.requests, cfg.requests, "{}: every request terminates", r.scheduler);
            assert_eq!(r.failed, 0, "{}", r.scheduler);
        }
        assert!(cmp.tiered.pages_demoted > 0, "cold_after 4 over ≥16-token lanes must demote");
        assert_eq!(cmp.no_trigger.pages_demoted, 0);
        assert_eq!(cmp.base.pages_demoted, 0);
        assert!(cmp.streams_identical_no_trigger, "untriggered tier changed streams");
        assert!(cmp.effective_capacity_gain > 1.0, "{}", cmp.effective_capacity_gain);
        assert!(cmp.tiered.tier_error_ratio <= 1.0 + 1e-3, "{}", cmp.tiered.tier_error_ratio);
        assert_eq!(cmp.base.tier_error_ratio, 0.0);
        // All-hot runs sit exactly at capacity ratio 1.0.
        assert_eq!(cmp.base.capacity_ratio_mean, 1.0);
        assert_eq!(cmp.base.capacity_ratio_peak, 1.0);
        assert_eq!(cmp.no_trigger.capacity_ratio_peak, 1.0);
        let doc = tiered_to_json(&cfg, &cmp);
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.get("runs").unwrap().as_arr().unwrap().len(), 3);
        let tk = j.get("tiered_kv").unwrap();
        assert!(tk.get("effective_capacity_gain").unwrap().as_f64().unwrap() > 1.0);
        assert!(tk.get("streams_identical_no_trigger").unwrap().as_bool().unwrap());
        assert!(tk.get("pages_demoted").unwrap().as_usize().unwrap() > 0);
        assert!(table.render().contains("no-trigger"));
    }

    /// Acceptance invariant: at a fixed `max_pages` the policy sweep
    /// shows strictly higher achieved concurrency for at least one
    /// eviction policy versus worst-case reservation, every request
    /// still terminates, and BENCH_serve.json carries the comparison.
    #[test]
    fn policy_sweep_raises_achieved_concurrency() {
        let mut cfg = tiny();
        cfg.requests = 10;
        cfg.prompt_min = 16;
        cfg.prompt_max = 32;
        cfg.max_new_min = 6;
        cfg.max_new_max = 10;
        cfg.engines = vec!["dense".into()]; // one group — one page budget
        cfg.serve.max_pages = 60; // pages, not lanes, bind admission
        cfg.serve.max_lanes = 8;
        cfg.policies = vec![
            None,
            Some(PagedKvPolicy::H2o { budget: 8, recent: 4 }),
            Some(PagedKvPolicy::SnapKv { budget: 8, recent: 4 }),
            Some(PagedKvPolicy::Quest { budget: 8 }),
        ];
        let (_, runs) = bench_serve(&cfg);
        assert_eq!(runs.len(), 5);
        for r in &runs {
            assert_eq!(r.failed, 0, "{} {}", r.scheduler, r.policy);
            assert_eq!(r.requests, 10, "{} {}", r.scheduler, r.policy);
            assert_eq!(r.tokens_out, runs[0].tokens_out, "same stream, same token count");
        }
        let base = runs
            .iter()
            .find(|r| r.scheduler == "continuous" && r.policy == "none")
            .unwrap();
        assert_eq!(base.pages_pruned, 0);
        let best_mean = runs
            .iter()
            .filter(|r| r.scheduler == "continuous" && r.policy != "none")
            .map(|r| r.mean_live)
            .fold(0.0, f64::max);
        assert!(
            best_mean > base.mean_live,
            "policy-budget admission must beat worst-case reservation \
             ({best_mean:.2} vs {:.2})",
            base.mean_live
        );
        assert!(runs
            .iter()
            .any(|r| r.policy != "none" && r.pages_pruned > 0 && r.peak_live > base.peak_live));
        let j = Json::parse(&to_json(&cfg, &runs)).unwrap();
        let pa = j.get("policy_admission").unwrap();
        assert!(pa.get("concurrency_gain_mean_live").unwrap().as_f64().unwrap() > 1.0);
        assert!(pa.get("best_policy").unwrap().as_str().is_ok());
    }

    /// Acceptance pin for `sfa bench serve --prefix-cache`: on a
    /// repeated-system-prompt workload the warm run hits (> 0 rate),
    /// shares the system prompt, finishes everything, and its greedy
    /// streams are bit-for-bit identical to the cold run; the JSON
    /// document carries the whole comparison.
    #[test]
    fn prefix_cache_bench_hits_and_streams_match() {
        let mut cfg = tiny();
        cfg.requests = 8;
        cfg.prompt_max = 48;
        cfg.engines = vec!["sfa:k=4".into()];
        cfg.prefix = Some(PrefixBenchConfig { system_prompt: 32, cache_pages: 256 });
        let (table, cmp) = bench_serve_prefix(&cfg);
        assert_eq!(cmp.cold.requests, 8);
        assert_eq!(cmp.warm.requests, 8);
        assert_eq!((cmp.cold.failed, cmp.warm.failed), (0, 0));
        assert!(cmp.streams_identical, "prefix cache must not change greedy tokens");
        assert!(cmp.hit_rate > 0.0, "staggered stream must hit ({:?})", cmp.warm.prefix);
        assert!(
            cmp.shared_tokens_mean > 0.0,
            "hits share the system prompt ({})",
            cmp.shared_tokens_mean
        );
        assert!(cmp.warm.prefix.hits >= 6, "{:?}", cmp.warm.prefix);
        let rendered = table.render();
        assert!(rendered.contains("prefix") && rendered.contains("hit rate"), "{rendered}");

        let doc = to_json_with_prefix(&cfg, &[cmp.cold.clone(), cmp.warm.clone()], Some(&cmp));
        let j = Json::parse(&doc).unwrap();
        let p = j.get("prefix_cache").unwrap();
        assert!(p.get("hit_rate").unwrap().as_f64().unwrap() > 0.0);
        assert!(p.get("streams_identical").unwrap().as_bool().unwrap());
        assert!(p.get("warm_ttft_mean_s").unwrap().as_f64().unwrap() >= 0.0);
        // Per-run prefix counters ride along in the runs array.
        let runs = j.get("runs").unwrap().as_arr().unwrap();
        assert!(
            runs[1].get("prefix_cache").unwrap().get("hits").unwrap().as_usize().unwrap() > 0
        );
    }

    /// Acceptance pin for `sfa bench serve --prefill-chunk`: the
    /// interference sweep completes at every chunk size, greedy
    /// streams are bit-for-bit identical across the sweep, chunked
    /// runs spread the long prefill over many more scheduler steps
    /// than the monolithic baseline, and the JSON document carries the
    /// whole `chunked_prefill` block. (The wall-clock TTFT gain is
    /// asserted by the CI bench at real scale, not here — timer
    /// resolution at toy sizes would make it flaky.)
    #[test]
    fn chunked_prefill_bench_pins_streams_and_serializes() {
        let mut cfg = tiny();
        cfg.engines = vec!["sfa:k=4".into()];
        cfg.chunked = Some(ChunkedBenchConfig {
            long_prompt: 96,
            long_max_new: 3,
            decode_lanes: 4,
            decode_prompt: 6,
            decode_max_new: 8,
            chunks: vec![0, 8, 32],
        });
        let (table, cmp) = bench_serve_chunked(&cfg);
        assert_eq!(cmp.runs.len(), 3);
        assert!(cmp.streams_identical, "chunk size must not change greedy streams");
        let mono = cmp.runs.iter().find(|r| r.chunk == 0).unwrap();
        let c8 = cmp.runs.iter().find(|r| r.chunk == 8).unwrap();
        assert!(
            c8.steps > mono.steps,
            "chunk 8 spreads a 96-token prefill over many steps ({} vs {})",
            c8.steps,
            mono.steps
        );
        assert!(cmp.best_chunk > 0, "best chunk comes from the swept non-zero sizes");
        let rendered = table.render();
        assert!(rendered.contains("monolithic") && rendered.contains("decode TTFT p95"));
        let doc = to_json_full(&cfg, &[], None, Some(&cmp));
        let j = Json::parse(&doc).unwrap();
        let c = j.get("chunked_prefill").unwrap();
        assert_eq!(c.get("long_prompt").unwrap().as_usize().unwrap(), 96);
        assert_eq!(c.get("decode_lanes").unwrap().as_usize().unwrap(), 4);
        assert!(c.get("streams_identical").unwrap().as_bool().unwrap());
        let runs = c.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 3);
        assert_eq!(runs[0].get("chunk").unwrap().as_usize().unwrap(), 0);
        assert!(runs[1].get("decode_ttft_p95_s").unwrap().as_f64().unwrap() >= 0.0);
        assert!(runs[1].get("steps").unwrap().as_usize().unwrap() > 0);
    }

    /// Acceptance pin for `sfa bench serve --speculate`: streams are
    /// bit-for-bit identical plain vs speculating (the hard-fail pin),
    /// the plain run's tokens/step is exactly 1.0 (which makes any
    /// gain > 1.0 certify real acceptance), and BENCH_serve_spec.json
    /// carries the whole `speculative` block. Runs greedy *and* at
    /// temperature with per-request sampler seeds — the stochastic
    /// path the CLI satellites expose.
    #[test]
    fn speculative_bench_pins_streams_and_serializes() {
        for temperature in [None, Some(0.8)] {
            let mut cfg = tiny();
            cfg.engines = vec!["sfa:k=4".into()];
            cfg.speculate = Some(SpeculateConfig::parse("sfa:k=2", 4).unwrap());
            cfg.temperature = temperature;
            cfg.sampler_seed = 9;
            let (table, cmp) = bench_serve_spec(&cfg);
            assert_eq!(cmp.baseline.failed, 0);
            assert_eq!(cmp.speculative.failed, 0);
            assert_eq!(cmp.baseline.requests, cfg.requests);
            assert_eq!(cmp.speculative.requests, cfg.requests);
            assert!(
                cmp.streams_identical,
                "temperature={temperature:?}: speculation must not change streams"
            );
            assert!(
                (cmp.baseline_tokens_per_step - 1.0).abs() < 1e-12,
                "plain decoding commits exactly one token per lane-step"
            );
            assert!(cmp.tokens_per_step >= 1.0, "verify always commits at least one token");
            assert!((0.0..=1.0).contains(&cmp.acceptance_rate));
            assert_eq!(cmp.draft, "sfa:k=2,bq=64,bk=64");
            let rendered = table.render();
            assert!(rendered.contains("speculative") && rendered.contains("accept rate"));
            let j = Json::parse(&spec_to_json(&cfg, &cmp)).unwrap();
            let s = j.get("speculative").unwrap();
            assert_eq!(s.get("gamma").unwrap().as_usize().unwrap(), 4);
            assert!(s.get("streams_identical").unwrap().as_bool().unwrap());
            assert!(s.get("acceptance_rate").unwrap().as_f64().unwrap() >= 0.0);
            assert!(s.get("tokens_per_step_gain").unwrap().as_f64().unwrap() >= 1.0);
            assert!(s.get("baseline").unwrap().get("tokens_out").unwrap().as_usize().is_ok());
        }
    }

    /// The draft must be a valid cheap engine for every workload
    /// target — nonsense pairs die before any scheduler runs.
    #[test]
    #[should_panic(expected = "--speculate")]
    fn speculative_bench_rejects_draft_equal_to_target() {
        let mut cfg = tiny();
        cfg.engines = vec!["sfa:k=2,bq=64,bk=64".into()];
        cfg.speculate = Some(SpeculateConfig::parse("sfa:k=2", 4).unwrap());
        bench_serve_spec(&cfg);
    }

    #[test]
    fn shared_prefix_workload_shape() {
        let mut cfg = tiny();
        cfg.requests = 5;
        cfg.prompt_max = 24;
        let px = PrefixBenchConfig { system_prompt: 16, cache_pages: 64 };
        let a = workload_shared_prefix(&cfg, &px);
        let b = workload_shared_prefix(&cfg, &px);
        assert_eq!(a.len(), 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt, "deterministic");
            assert!(x.prompt.len() > px.system_prompt);
            assert!(x.prompt.len() <= cfg.prompt_max.max(px.system_prompt + 2));
            assert_eq!(&x.prompt[..16], &a[0].prompt[..16], "system prompt shared");
        }
        // First suffix token is forced distinct, so the shared prefix
        // is exactly the system prompt.
        assert_ne!(a[0].prompt[16], a[1].prompt[16]);
    }

    /// The trace generator is deterministic, arrival steps are
    /// nondecreasing, the stratified SLO mix is exact, and every
    /// prompt opens with one of the shared system prompts.
    #[test]
    fn router_trace_workload_shape() {
        let mut cfg = tiny();
        cfg.requests = 12;
        cfg.prompt_min = 8;
        cfg.prompt_max = 48;
        let rb = RouterBenchConfig { system_prompt_len: 12, ..RouterBenchConfig::default() };
        let a = workload_trace(&cfg, &rb);
        let b = workload_trace(&cfg, &rb);
        assert_eq!(a.len(), 12);
        for ((sa, ra), (sb, rbq)) in a.iter().zip(&b) {
            assert_eq!(sa, sb, "deterministic arrival steps");
            assert_eq!(ra.prompt, rbq.prompt, "deterministic prompts");
            assert_eq!(ra.slo.is_interactive(), rbq.slo.is_interactive());
            assert!(ra.prompt.len() >= rb.system_prompt_len + 2);
            assert!(ra.prompt.len() <= cfg.prompt_max.max(rb.system_prompt_len + 2));
        }
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0), "arrivals in order");
        // interactive_frac = 0.5 stratified: exactly half interactive.
        let interactive = a.iter().filter(|(_, r)| r.slo.is_interactive()).count();
        assert_eq!(interactive, 6, "stratified mix is exact, not a coin flip");
        // Interactive prompts stay short; the heavy tail is batch-only.
        for (_, r) in &a {
            if r.slo.is_interactive() {
                assert!(r.prompt.len() <= (2 * cfg.prompt_min).max(rb.system_prompt_len + 2));
            }
        }
        // Some pair of requests shares a full system prompt (the
        // affinity routing target).
        let shared = a.iter().enumerate().any(|(i, (_, x))| {
            a.iter().skip(i + 1).any(|(_, y)| {
                x.prompt[..rb.system_prompt_len] == y.prompt[..rb.system_prompt_len]
            })
        });
        assert!(shared, "system prompts must repeat across the trace");
    }

    /// Acceptance pin for `sfa bench serve --replicas`: streams are
    /// bit-for-bit identical across single-replica, SLO-aware, and
    /// round-robin placements (placement moves latency, never
    /// content), every request terminates, goodput is positive, and
    /// BENCH_serve_router.json carries the whole `router` block. (The
    /// interactive-TTFT-p95 win over round-robin is asserted by the CI
    /// bench at real scale, not here — wall-clock at toy sizes would
    /// make it flaky.)
    #[test]
    fn router_bench_pins_streams_and_reports_goodput() {
        let mut cfg = tiny();
        cfg.requests = 10;
        cfg.prompt_min = 8;
        cfg.prompt_max = 40;
        cfg.max_new_min = 2;
        cfg.max_new_max = 6;
        cfg.engines = vec!["sfa:k=4".into()];
        cfg.serve.max_lanes = 2; // queueing pressure so routing matters
        cfg.router = Some(RouterBenchConfig {
            replicas: 2,
            system_prompts: 2,
            system_prompt_len: 12,
            ..RouterBenchConfig::default()
        });
        let (table, cmp) = bench_serve_router(&cfg);
        assert_eq!(cmp.replicas, 2);
        for s in [&cmp.slo_aware, &cmp.round_robin, &cmp.single] {
            assert_eq!(s.requests, 10, "{}: every request terminates", s.policy);
            assert_eq!(s.failed, 0, "{}", s.policy);
            assert!(s.tokens_out > 0 && s.steps > 0, "{}", s.policy);
            assert!(s.goodput_tok_s > 0.0, "{}: goodput is positive", s.policy);
            assert!((0.0..=1.0).contains(&s.attainment), "{}", s.policy);
            assert_eq!(s.interactive_requests, 5, "stratified mix survives the run");
        }
        assert!(cmp.streams_identical, "placement must never change tokens");
        assert_eq!(
            cmp.slo_aware.tokens_out, cmp.single.tokens_out,
            "identical trace, identical token count"
        );
        let rendered = table.render();
        assert!(rendered.contains("slo-aware") && rendered.contains("round-robin"), "{rendered}");
        let j = Json::parse(&router_to_json(&cfg, &cmp)).unwrap();
        let r = j.get("router").unwrap();
        assert!(r.get("streams_identical").unwrap().as_bool().unwrap());
        assert!(r.get("slo_aware").unwrap().get("goodput_tok_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(
            r.get("round_robin")
                .unwrap()
                .get("interactive_ttft")
                .unwrap()
                .get("p95_s")
                .unwrap()
                .as_f64()
                .unwrap()
                >= 0.0
        );
        assert_eq!(r.get("single_replica").unwrap().get("requests").unwrap().as_usize().unwrap(), 10);
        assert_eq!(j.get("workload").unwrap().get("replicas").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn workload_is_deterministic_and_in_range() {
        let cfg = tiny();
        let a = workload(&cfg);
        let b = workload(&cfg);
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new, y.max_new);
            assert!((cfg.prompt_min..=cfg.prompt_max).contains(&x.prompt.len()));
            assert!((cfg.max_new_min..=cfg.max_new_max).contains(&x.max_new));
        }
        // Round-robin engine assignment.
        assert_eq!(a[0].engine, "dense");
        assert_eq!(a[1].engine, "sfa:k=4");
        assert_eq!(a[2].engine, "dense");
    }
}
