//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Methodology matches the paper (§4.3): warm runs first, then report
//! the **median** over N timed iterations. [`table`] renders the
//! aligned text tables the `cargo bench` targets print — one per paper
//! table/figure.

pub mod figures;
pub mod harness;
pub mod table;

pub use harness::{bench, bench_n, BenchResult};
pub use table::Table;
