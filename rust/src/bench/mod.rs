//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Methodology matches the paper (§4.3): warm runs first, then report
//! the **median** over N timed iterations. [`table`] renders the
//! aligned text tables the `cargo bench` targets print — one per paper
//! table/figure.
//!
//! Every spec-driven engine measurement also lands in a process-wide
//! record log ([`record`] / [`drain_records`]); the CLI and bench
//! binaries serialize it to `BENCH_attention.json` so the perf
//! trajectory is machine-readable across PRs.

pub mod figures;
pub mod harness;
pub mod serve_bench;
pub mod table;

use std::sync::Mutex;

use crate::attention::flash_sfa::SfaTileCounts;

pub use harness::{bench, bench_n, BenchResult};
pub use table::Table;

/// One machine-readable benchmark record (a `BENCH_attention.json` row).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// Canonical engine registry spec.
    pub spec: String,
    /// Context length benchmarked.
    pub n: usize,
    /// Head dim.
    pub d: usize,
    /// SFA sparsity budget (0 when the engine has none).
    pub k: usize,
    pub median_s: f64,
    pub p95_s: f64,
    /// Tile-level work counters from one instrumented FlashSFA forward
    /// (None for engines without a tiled sparse kernel).
    pub tiles: Option<SfaTileCounts>,
}

static RECORDS: Mutex<Vec<BenchRecord>> = Mutex::new(Vec::new());

/// Append one engine measurement to the process-wide record log.
pub fn record(spec: &str, n: usize, d: usize, k: usize, r: &BenchResult) {
    record_with_tiles(spec, n, d, k, r, None);
}

/// [`record`] plus the tile counters from one instrumented FlashSFA
/// forward at the same shape.
pub fn record_with_tiles(
    spec: &str,
    n: usize,
    d: usize,
    k: usize,
    r: &BenchResult,
    tiles: Option<SfaTileCounts>,
) {
    RECORDS.lock().unwrap().push(BenchRecord {
        spec: spec.to_string(),
        n,
        d,
        k,
        median_s: r.median_s,
        p95_s: r.p95_s,
        tiles,
    });
}

/// Copy the current record log without clearing it.
pub fn snapshot_records() -> Vec<BenchRecord> {
    RECORDS.lock().unwrap().clone()
}

/// Take (and clear) the record log — call once per bench invocation,
/// right before serializing.
pub fn drain_records() -> Vec<BenchRecord> {
    std::mem::take(&mut *RECORDS.lock().unwrap())
}

/// Drain the record log and write it to `path` as the
/// `BENCH_attention.json` document. Returns how many records were
/// written; 0 means the log was empty and nothing was touched.
pub fn write_records(path: &str) -> std::io::Result<usize> {
    let records = drain_records();
    if records.is_empty() {
        return Ok(0);
    }
    std::fs::write(path, records_to_json(&records))?;
    Ok(records.len())
}

/// Serialize records as the `BENCH_attention.json` document.
pub fn records_to_json(records: &[BenchRecord]) -> String {
    use crate::util::json::{obj, Json};
    Json::Arr(
        records
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("engine", Json::from(r.spec.as_str())),
                    ("n", Json::from(r.n)),
                    ("d", Json::from(r.d)),
                    ("k", Json::from(r.k)),
                    ("median_s", Json::from(r.median_s)),
                    ("p95_s", Json::from(r.p95_s)),
                ];
                if let Some(t) = &r.tiles {
                    fields.push(("tiles_visited", Json::from(t.tiles_visited as usize)));
                    fields.push(("tiles_folded", Json::from(t.tiles_folded as usize)));
                    fields.push(("tiles_skipped", Json::from(t.tiles_skipped as usize)));
                    fields.push(("rows_skipped", Json::from(t.rows_skipped as usize)));
                    fields.push(("posting_hits", Json::from(t.posting_hits as usize)));
                }
                obj(fields)
            })
            .collect(),
    )
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn records_serialize_to_parseable_json() {
        let recs = vec![
            BenchRecord {
                spec: "sfa:k=8,bq=64,bk=64".into(),
                n: 1024,
                d: 128,
                k: 8,
                median_s: 0.0123,
                p95_s: 0.0150,
                tiles: Some(SfaTileCounts {
                    tiles_visited: 100,
                    tiles_folded: 20,
                    tiles_skipped: 16,
                    rows_skipped: 7,
                    posting_hits: 4096,
                }),
            },
            BenchRecord {
                spec: "flash_dense:bq=64,bk=64".into(),
                n: 1024,
                d: 128,
                k: 0,
                median_s: 0.05,
                p95_s: 0.06,
                tiles: None,
            },
        ];
        let text = records_to_json(&recs);
        let j = Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("engine").unwrap().as_str().unwrap(), "sfa:k=8,bq=64,bk=64");
        assert_eq!(arr[0].get("n").unwrap().as_usize().unwrap(), 1024);
        assert_eq!(arr[0].get("k").unwrap().as_usize().unwrap(), 8);
        assert_eq!(arr[0].get("tiles_folded").unwrap().as_usize().unwrap(), 20);
        assert_eq!(arr[0].get("rows_skipped").unwrap().as_usize().unwrap(), 7);
        assert_eq!(arr[0].get("posting_hits").unwrap().as_usize().unwrap(), 4096);
        assert!(arr[1].get("tiles_visited").is_none(), "non-sfa rows omit tile counters");
        assert!((arr[1].get("median_s").unwrap().as_f64().unwrap() - 0.05).abs() < 1e-12);
    }
}
