//! Timing core: warmup + median-of-N wall-clock measurement.

use crate::util::stats::{mean, median, quantile, std_dev};
use std::time::Instant;

/// Summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median_s * 1e3
    }

    pub fn median_us(&self) -> f64 {
        self.median_s * 1e6
    }

    /// Throughput given a per-iteration work amount.
    pub fn per_second(&self, work_per_iter: f64) -> f64 {
        work_per_iter / self.median_s
    }
}

/// Run `f` `warmup` times untimed, then `iters` timed; report medians
/// (the paper reports "medians over 50 warm runs").
pub fn bench_n<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        median_s: median(&samples),
        mean_s: mean(&samples),
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
        p95_s: quantile(&samples, 0.95),
        std_s: std_dev(&samples),
    }
}

/// Adaptive variant: chooses an iteration count so the total timed
/// budget is ~`budget_s` seconds (min 3 iters), then measures.
pub fn bench(name: &str, budget_s: f64, mut f: impl FnMut()) -> BenchResult {
    // Pilot run to estimate cost.
    let t0 = Instant::now();
    f();
    let pilot = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / pilot) as usize).clamp(3, 200);
    let warmup = (iters / 5).clamp(1, 10);
    bench_n(name, warmup, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let r = bench_n("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(acc);
        });
        assert!(r.median_s > 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.p95_s);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn adaptive_budget_respects_bounds() {
        let r = bench("fast", 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters <= 200 && r.iters >= 3);
    }

    #[test]
    fn ordering_detects_slower_code() {
        let fast = bench_n("fast", 2, 9, || {
            let mut acc = 0u64;
            for i in 0..1_000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(acc);
        });
        let slow = bench_n("slow", 2, 9, || {
            let mut acc = 0u64;
            for i in 0..200_000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(acc);
        });
        assert!(slow.median_s > fast.median_s);
    }
}
