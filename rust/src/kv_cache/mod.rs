//! KV-cache management for the serving coordinator.
//!
//! Three layers:
//! * [`paged`] — a vLLM-style paged allocator: fixed-size pages, a page
//!   table per sequence, copy-free append, reference-counted sharing
//!   ([`PagedKvCache::fork`] / [`PagedKvCache::fork_prefix`], with
//!   [`PagedKvCache::pin_seq`] pinning sequences out of every eviction
//!   surface), token eviction ([`PagedKvCache::retain`] /
//!   [`PagedKvCache::evict_tokens`] — compaction that returns whole
//!   pages to the pool, copy-on-evict safe under `fork`, the substrate
//!   the serve stack's KV eviction policies prune through), and a
//!   two-tier page payload ([`paged::PagePayload`]): cold pages demote
//!   to per-row int8 at half the budget cost
//!   ([`PagedKvCache::demote_pages`] / [`PagedKvCache::promote_pages`],
//!   configured by [`paged::KvTierCfg`]), read tier-transparently via
//!   [`PagedKvCache::token_slices_tiered`].
//!   SFA shrinks the K-page payload to top-k codes (App. J memory).
//! * [`radix`] — the radix/trie prompt-prefix cache mapping prompt
//!   token prefixes to pinned forked sequences (the serve stack's
//!   `ServeConfig::prefix_cache` substrate).
//! * [`accounting`] — byte accounting across whole model instances
//!   (drives Fig. 1b / Fig. 5 KV-memory curves).

pub mod accounting;
pub mod paged;
pub mod radix;

pub use paged::{
    KvTierCfg, PageError, PagePayload, PagedKvCache, SeqId, SlotLayout, TierPolicy, TierScratch,
};
pub use radix::{PrefixCacheStats, PrefixHit, RadixPrefixCache};
