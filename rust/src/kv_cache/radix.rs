//! [`RadixPrefixCache`] — a radix (compressed-trie) cache mapping
//! prompt-token prefixes to forked [`PagedKvCache`] sequences, the
//! prefix-sharing layer behind `serve`'s `ServeConfig::prefix_cache`.
//!
//! Serving millions of users means serving the *same system prompt*
//! millions of times; re-prefilling it per request is pure waste. The
//! serve stack records each finished request's prompt path here: one
//! **entry** = one node on the token trie + one pinned cache sequence
//! per head holding exactly that prefix's KV (created with
//! [`PagedKvCache::fork_prefix`], so it shares pages — insertion never
//! copies KV). On admission, the batcher looks up the longest cached
//! prefix of the incoming prompt, forks it into the new lane, and
//! prefills only the suffix.
//!
//! Key properties:
//!
//! * **Lookup is structural.** The match may end mid-edge; any entry in
//!   the subtree below the match point starts with the matched tokens,
//!   so it can be prefix-forked at the match length. Ancestor entries
//!   serve shorter matches. A hit therefore never requires an exact
//!   prompt repeat — only a shared prefix.
//! * **Entries are pinned.** Every entry sequence is
//!   [`PagedKvCache::pin_seq`]-pinned, so no eviction surface
//!   (`retain`/`evict_tokens`/`free`) can prune pages a cached prefix
//!   still references; children pruning themselves copy-on-evict
//!   around the shared pages.
//! * **LRU under a nominal page budget.** Each entry is charged
//!   `heads × ⌈len / page_size⌉` pages (nominal: fork-sharing between
//!   entries makes exact attribution ill-defined, and nominal
//!   over-counts, which is the safe direction for admission math).
//!   Inserting past the budget evicts least-recently-used entries
//!   first. Entries currently borrowed by a live lane are never
//!   evicted — their shared pages back that lane's suffix-only page
//!   reservation.
//! * **Demote before drop.** Under the tiered page payload
//!   ([`crate::kv_cache::paged::PagePayload`]) an LRU victim is first
//!   *demoted* — its pinned sequences' pages quantize to int8 in place
//!   ([`PagedKvCache::demote_pages`]) and its nominal charge halves —
//!   and only dropped if pressure persists while it is already cold.
//!   A cold entry still serves hits (forks read tier-transparently);
//!   [`RadixPrefixCache::borrow`] promotes it back to fp32, so a
//!   prefix that proves hot again pays the dequant once, not a full
//!   re-prefill.

use std::collections::HashMap;

use crate::kv_cache::paged::{PagedKvCache, SeqId};

/// Stable handle for one cached prefix entry.
pub type EntryId = u64;

/// Counters the serve stack reports (`bench serve --prefix-cache`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Admissions that forked a cached prefix.
    pub hits: u64,
    /// Admissions that found no usable prefix.
    pub misses: u64,
    /// Entries inserted over the cache's life.
    pub inserted: u64,
    /// Entries evicted (LRU) over the cache's life.
    pub evicted: u64,
    /// Entries demoted to int8 under LRU pressure (tiered payload).
    pub demoted: u64,
    /// Cold entries promoted back to fp32 on borrow.
    pub promoted: u64,
    /// Nominal pages currently attributed to live entries.
    pub pages_nominal: usize,
}

/// One lookup result: fork `seqs[h]` at `shared` tokens per head.
#[derive(Debug, Clone)]
pub struct PrefixHit {
    pub entry: EntryId,
    /// Prompt tokens covered by the cached prefix.
    pub shared: usize,
    /// Entry sequences, one per head, to `fork_prefix` at `shared`.
    pub seqs: Vec<SeqId>,
}

struct Entry {
    id: EntryId,
    /// One pinned sequence per head; each holds exactly `depth` tokens.
    seqs: Vec<SeqId>,
    /// The LRU budget charge: `heads × ⌈depth / page_size⌉` while hot,
    /// halved (rounded up) once demoted to int8.
    pages_nominal: usize,
    last_used: u64,
    /// Live lanes currently sharing this entry's pages.
    borrowers: usize,
    /// Entry pages are int8-demoted (half charge, lossy-but-bounded).
    cold: bool,
}

struct Node {
    /// Compressed token run from the parent node.
    edge: Vec<i32>,
    /// First token of each child's edge -> arena index.
    children: HashMap<i32, usize>,
    parent: usize,
    /// Token depth of this node (prefix length it represents).
    depth: usize,
    entry: Option<Entry>,
}

/// Radix tree over prompt tokens; entries hold pinned forked sequences.
pub struct RadixPrefixCache {
    nodes: Vec<Option<Node>>,
    free_nodes: Vec<usize>,
    root: usize,
    heads: usize,
    page_size: usize,
    /// Nominal page budget across all entries.
    max_pages: usize,
    pages_nominal: usize,
    clock: u64,
    entries: HashMap<EntryId, usize>,
    next_entry: EntryId,
    hits: u64,
    misses: u64,
    inserted: u64,
    evicted: u64,
    demoted: u64,
    promoted: u64,
}

impl RadixPrefixCache {
    pub fn new(heads: usize, page_size: usize, max_pages: usize) -> RadixPrefixCache {
        assert!(heads >= 1 && page_size >= 1 && max_pages >= 1);
        let root = Node {
            edge: Vec::new(),
            children: HashMap::new(),
            parent: usize::MAX,
            depth: 0,
            entry: None,
        };
        RadixPrefixCache {
            nodes: vec![Some(root)],
            free_nodes: Vec::new(),
            root: 0,
            heads,
            page_size,
            max_pages,
            pages_nominal: 0,
            clock: 0,
            entries: HashMap::new(),
            next_entry: 0,
            hits: 0,
            misses: 0,
            inserted: 0,
            evicted: 0,
            demoted: 0,
            promoted: 0,
        }
    }

    fn node(&self, i: usize) -> &Node {
        self.nodes[i].as_ref().expect("live node index")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node {
        self.nodes[i].as_mut().expect("live node index")
    }

    fn alloc_node(&mut self, n: Node) -> usize {
        match self.free_nodes.pop() {
            Some(i) => {
                self.nodes[i] = Some(n);
                i
            }
            None => {
                self.nodes.push(Some(n));
                self.nodes.len() - 1
            }
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn nominal(&self, len: usize) -> usize {
        self.heads * len.div_ceil(self.page_size)
    }

    pub fn stats(&self) -> PrefixCacheStats {
        PrefixCacheStats {
            hits: self.hits,
            misses: self.misses,
            inserted: self.inserted,
            evicted: self.evicted,
            demoted: self.demoted,
            promoted: self.promoted,
            pages_nominal: self.pages_nominal,
        }
    }

    /// Nominal pages currently held by entries (the admission pass adds
    /// this to its reservation math — over-counting shared pages, which
    /// is the safe direction).
    pub fn pages_nominal(&self) -> usize {
        self.pages_nominal
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Walk the trie as deep as `prompt[..limit]` matches. Returns
    /// (deepest touched node, matched token count). The match may end
    /// mid-edge of the returned node; every entry in that node's
    /// subtree still starts with the matched tokens.
    fn walk(&self, prompt: &[i32], limit: usize) -> (usize, usize) {
        let mut cur = self.root;
        let mut matched = 0usize;
        while matched < limit {
            let Some(&child) = self.node(cur).children.get(&prompt[matched]) else {
                break;
            };
            let edge = &self.node(child).edge;
            let cap = (limit - matched).min(edge.len());
            let mut common = 0usize;
            while common < cap && edge[common] == prompt[matched + common] {
                common += 1;
            }
            matched += common;
            cur = child;
            if common < edge.len() {
                break; // diverged (or limit hit) mid-edge
            }
        }
        (cur, matched)
    }

    /// Most-recently-used entry in the subtree rooted at `start`.
    fn subtree_best(&self, start: usize) -> Option<EntryId> {
        let mut best: Option<(u64, EntryId)> = None;
        let mut stack = vec![start];
        while let Some(i) = stack.pop() {
            let n = self.node(i);
            if let Some(e) = &n.entry {
                if best.map(|(t, _)| e.last_used > t).unwrap_or(true) {
                    best = Some((e.last_used, e.id));
                }
            }
            stack.extend(n.children.values().copied());
        }
        best.map(|(_, id)| id)
    }

    /// Longest usable cached prefix of `prompt`, capped at
    /// `prompt.len() - 1` so at least one suffix token is always left
    /// to prefill (the token whose output the first sample needs).
    /// Read-only: stats and LRU move on [`RadixPrefixCache::borrow`] /
    /// [`RadixPrefixCache::note_miss`], so a peek the admission pass
    /// later abandons (page budget) costs nothing.
    pub fn peek(&self, prompt: &[i32]) -> Option<PrefixHit> {
        let limit = prompt.len().saturating_sub(1);
        if limit == 0 {
            return None;
        }
        let (deepest, matched) = self.walk(prompt, limit);
        if matched == 0 {
            return None;
        }
        // Preferred: an entry at/below the match point — it contains
        // the full matched prefix. Fallback: the nearest ancestor
        // entry, usable at its own (shorter) depth.
        if let Some(id) = self.subtree_best(deepest) {
            let node = self.entries[&id];
            let e = self.node(node).entry.as_ref().expect("entry node");
            debug_assert!(self.node(node).depth >= matched);
            return Some(PrefixHit { entry: id, shared: matched, seqs: e.seqs.clone() });
        }
        let mut cur = self.node(deepest).parent;
        while cur != usize::MAX {
            if let Some(e) = &self.node(cur).entry {
                let shared = self.node(cur).depth;
                debug_assert!(shared <= matched);
                if shared >= 1 {
                    return Some(PrefixHit { entry: e.id, shared, seqs: e.seqs.clone() });
                }
            }
            cur = self.node(cur).parent;
        }
        None
    }

    /// Longest cached prefix of `prompt`, in tokens — the cheap
    /// cross-replica affinity probe (`sfa bench serve --replicas`).
    /// Unlike [`RadixPrefixCache::peek`] this is a pure trie walk: no
    /// entry lookup, no `seqs` clone, no cap at `prompt.len() - 1` —
    /// it answers "how warm is this cache for this prompt", not "which
    /// entry should admission fork". Read-only (stats and LRU
    /// untouched), so a router may probe every replica per request
    /// without perturbing any replica's admission behaviour.
    pub fn longest_prefix(&self, prompt: &[i32]) -> usize {
        self.walk(prompt, prompt.len()).1
    }

    /// Record a consumed hit: bump the borrow count (the entry is now
    /// backing a live lane and is exempt from LRU eviction) and touch
    /// the LRU clock. A cold (int8-demoted) entry is promoted back to
    /// fp32 in place — a borrowed prefix is hot again by definition —
    /// restoring its full nominal charge (the transient may overshoot
    /// the budget; the next insert's eviction loop settles it).
    pub fn borrow(&mut self, entry: EntryId, cache: &mut PagedKvCache) {
        let t = self.tick();
        let node = self.entries[&entry];
        let full = self.nominal(self.node(node).depth);
        let mut restored = 0;
        {
            let e = self.node_mut(node).entry.as_mut().expect("entry node");
            e.borrowers += 1;
            e.last_used = t;
            if e.cold {
                for &s in &e.seqs {
                    cache.promote_pages(s).expect("entry sequence exists");
                }
                restored = full - e.pages_nominal;
                e.pages_nominal = full;
                e.cold = false;
            }
        }
        if restored > 0 {
            self.pages_nominal += restored;
            self.promoted += 1;
        }
        self.hits += 1;
    }

    /// Release a borrow taken by [`RadixPrefixCache::borrow`] (lane
    /// finished or failed).
    pub fn release(&mut self, entry: EntryId) {
        if let Some(&node) = self.entries.get(&entry) {
            let e = self.node_mut(node).entry.as_mut().expect("entry node");
            e.borrowers = e.borrowers.checked_sub(1).expect("borrow released twice");
        }
    }

    pub fn note_miss(&mut self) {
        self.misses += 1;
    }

    /// Insert `prompt`'s path, forking (and pinning) `src_seqs` — one
    /// per head, each holding at least `prompt.len()` tokens — at the
    /// prompt length. No-op (returns false) when the exact path is
    /// already cached (LRU-touched instead), when the entry alone
    /// exceeds the whole budget, or when eviction cannot make room
    /// (every resident entry borrowed). Never allocates pages: forks
    /// share, and the budget is enforced by evicting other entries.
    pub fn insert(
        &mut self,
        prompt: &[i32],
        cache: &mut PagedKvCache,
        src_seqs: &[SeqId],
    ) -> bool {
        assert_eq!(src_seqs.len(), self.heads, "one source sequence per head");
        if prompt.is_empty() {
            return false;
        }
        // Duplicate check before any eviction: re-inserting an
        // already-cached path must only touch its LRU clock, never
        // evict innocent entries to "make room" for nothing.
        let (node, matched) = self.walk(prompt, prompt.len());
        if matched == prompt.len()
            && self.node(node).depth == prompt.len()
            && self.node(node).entry.is_some()
        {
            let t = self.tick();
            self.node_mut(node).entry.as_mut().expect("checked").last_used = t;
            return false;
        }
        let nominal = self.nominal(prompt.len());
        if nominal > self.max_pages {
            return false;
        }
        while self.pages_nominal + nominal > self.max_pages {
            if !self.evict_lru(cache, None) {
                return false;
            }
        }
        // Walk/create the node for the full prompt path.
        let mut cur = self.root;
        let mut pos = 0usize;
        while pos < prompt.len() {
            let tok = prompt[pos];
            let Some(&child) = self.node(cur).children.get(&tok) else {
                let depth = prompt.len();
                let leaf = Node {
                    edge: prompt[pos..].to_vec(),
                    children: HashMap::new(),
                    parent: cur,
                    depth,
                    entry: None,
                };
                let leaf = self.alloc_node(leaf);
                self.node_mut(cur).children.insert(tok, leaf);
                cur = leaf;
                pos = depth;
                break;
            };
            let rest = &prompt[pos..];
            let edge_len = self.node(child).edge.len();
            let cap = rest.len().min(edge_len);
            let mut common = 0usize;
            while common < cap && self.node(child).edge[common] == rest[common] {
                common += 1;
            }
            if common == edge_len {
                pos += common;
                cur = child;
                continue;
            }
            // Split the child's edge at `common`.
            let mid_depth = self.node(cur).depth + common;
            let mid_edge = self.node(child).edge[..common].to_vec();
            let child_rest = self.node(child).edge[common..].to_vec();
            let mid = self.alloc_node(Node {
                edge: mid_edge,
                children: HashMap::new(),
                parent: cur,
                depth: mid_depth,
                entry: None,
            });
            let child_first = child_rest[0];
            {
                let c = self.node_mut(child);
                c.edge = child_rest;
                c.parent = mid;
            }
            self.node_mut(mid).children.insert(child_first, child);
            self.node_mut(cur).children.insert(tok, mid);
            cur = mid;
            pos += common;
        }
        debug_assert_eq!(self.node(cur).depth, prompt.len());
        if self.node(cur).entry.is_some() {
            let t = self.tick();
            self.node_mut(cur).entry.as_mut().expect("checked").last_used = t;
            return false;
        }
        let mut seqs = Vec::with_capacity(self.heads);
        for &src in src_seqs {
            let forked = cache
                .fork_prefix(src, prompt.len())
                .expect("insert source sequence exists");
            cache.pin_seq(forked).expect("freshly forked sequence");
            seqs.push(forked);
        }
        let id = self.next_entry;
        self.next_entry += 1;
        let t = self.tick();
        self.node_mut(cur).entry = Some(Entry {
            id,
            seqs,
            pages_nominal: nominal,
            last_used: t,
            borrowers: 0,
            cold: false,
        });
        self.entries.insert(id, cur);
        self.pages_nominal += nominal;
        self.inserted += 1;
        true
    }

    /// Reclaim budget from the least-recently-used unborrowed entry
    /// (skipping `exclude`). Two-phase: a hot victim is *demoted* —
    /// its pages quantize to int8 and its nominal charge halves — and
    /// only an already-cold victim (or one whose charge a halving
    /// cannot shrink) is removed, unpinning and freeing its sequences.
    /// Returns false when nothing is reclaimable; each true strictly
    /// lowers `pages_nominal`, so the insert loop always terminates.
    pub fn evict_lru(&mut self, cache: &mut PagedKvCache, exclude: Option<EntryId>) -> bool {
        let victim = self
            .entries
            .iter()
            .filter_map(|(&id, &node)| {
                let e = self.node(node).entry.as_ref().expect("entry node");
                (e.borrowers == 0 && Some(id) != exclude).then_some((e.last_used, id))
            })
            .min()
            .map(|(_, id)| id);
        match victim {
            Some(id) => {
                let node = self.entries[&id];
                let e = self.node(node).entry.as_ref().expect("entry node");
                if !e.cold && e.pages_nominal >= 2 {
                    self.demote_entry(id, cache);
                } else {
                    self.remove_entry(id, cache);
                }
                true
            }
            None => false,
        }
    }

    /// Demote a hot entry's pinned sequences to int8 (whole pages, the
    /// partial tail included — nothing appends to an entry) and halve
    /// its nominal budget charge.
    fn demote_entry(&mut self, id: EntryId, cache: &mut PagedKvCache) {
        let node = self.entries[&id];
        let (old, cold_nominal);
        {
            let e = self.node_mut(node).entry.as_mut().expect("entry node");
            debug_assert!(!e.cold);
            for &s in &e.seqs {
                cache.demote_pages(s, 0).expect("entry sequence exists");
            }
            old = e.pages_nominal;
            cold_nominal = old.div_ceil(2);
            e.pages_nominal = cold_nominal;
            e.cold = true;
        }
        self.pages_nominal -= old - cold_nominal;
        self.demoted += 1;
    }

    fn remove_entry(&mut self, id: EntryId, cache: &mut PagedKvCache) {
        let node = self.entries.remove(&id).expect("known entry");
        let e = self.node_mut(node).entry.take().expect("entry node");
        for s in e.seqs {
            cache.unpin_seq(s).expect("entry sequence exists");
            cache.free(s).expect("entry sequence exists");
        }
        self.pages_nominal -= e.pages_nominal;
        self.evicted += 1;
        // Prune now-useless nodes upward (entry-less, child-less).
        let mut cur = node;
        while cur != self.root {
            let (prune, parent, first) = {
                let n = self.node(cur);
                (
                    n.entry.is_none() && n.children.is_empty(),
                    n.parent,
                    n.edge.first().copied(),
                )
            };
            if !prune {
                break;
            }
            let first = first.expect("non-root node has a non-empty edge");
            self.node_mut(parent).children.remove(&first);
            self.nodes[cur] = None;
            self.free_nodes.push(cur);
            cur = parent;
        }
    }

    /// Drop every entry, freeing all pinned sequences (shutdown /
    /// tests).
    pub fn clear(&mut self, cache: &mut PagedKvCache) {
        let ids: Vec<EntryId> = self.entries.keys().copied().collect();
        for id in ids {
            self.remove_entry(id, cache);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv_cache::paged::SlotLayout;

    const HEADS: usize = 2;
    const PS: usize = 4;

    fn cache() -> PagedKvCache {
        PagedKvCache::new(1024, PS, SlotLayout::Dense { d: 1, d_v: 1 })
    }

    /// Append `tokens` into `heads` fresh sequences (payload = token
    /// value, so reads identify tokens).
    fn seed(cache: &mut PagedKvCache, tokens: &[i32]) -> Vec<SeqId> {
        (0..HEADS)
            .map(|_| {
                let s = cache.create_seq();
                for &t in tokens {
                    cache.append(s, &[t as f32, 0.0]).unwrap();
                }
                s
            })
            .collect()
    }

    fn prompt(tokens: &[i32]) -> Vec<i32> {
        tokens.to_vec()
    }

    #[test]
    fn miss_then_insert_then_hit_on_shared_prefix() {
        let mut c = cache();
        let mut px = RadixPrefixCache::new(HEADS, PS, 1024);
        let p1 = prompt(&[1, 2, 3, 4, 5, 6, 7, 8]);
        assert!(px.peek(&p1).is_none());
        px.note_miss();

        let src = seed(&mut c, &p1);
        assert!(px.insert(&p1, &mut c, &src));
        assert_eq!(px.len(), 1);
        assert_eq!(px.pages_nominal(), HEADS * 2); // ceil(8/4) per head

        // Same system prompt, different user suffix: the match ends
        // mid-path and the leaf entry serves it at the shared length.
        let p2 = prompt(&[1, 2, 3, 4, 5, 99, 100]);
        let hit = px.peek(&p2).expect("shared prefix of 5 tokens");
        assert_eq!(hit.shared, 5);
        assert_eq!(hit.seqs.len(), HEADS);
        // The forked prefix reads exactly the shared tokens.
        let f = c.fork_prefix(hit.seqs[0], hit.shared).unwrap();
        for (i, &t) in p2[..5].iter().enumerate() {
            assert_eq!(c.get(f, i).unwrap()[0], t as f32);
        }
        c.free(f).unwrap();

        // Exact repeat is capped at len - 1 (one suffix token always
        // remains to prefill).
        let hit = px.peek(&p1).expect("full-path repeat");
        assert_eq!(hit.shared, p1.len() - 1);

        // Entirely different prompt: miss.
        assert!(px.peek(&[9, 9, 9]).is_none());
        let s = px.stats();
        assert_eq!((s.misses, s.inserted), (1, 1));
    }

    #[test]
    fn longest_prefix_probe_is_uncapped_and_stat_free() {
        let mut c = cache();
        let mut px = RadixPrefixCache::new(HEADS, PS, 1024);
        let p = prompt(&[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(px.longest_prefix(&p), 0, "cold cache probes 0");
        let src = seed(&mut c, &p);
        assert!(px.insert(&p, &mut c, &src));
        // Unlike peek, the probe reports the full match — including an
        // exact repeat (peek caps at len - 1 to leave a suffix token).
        assert_eq!(px.longest_prefix(&p), p.len());
        assert_eq!(px.longest_prefix(&[1, 2, 3, 4, 9, 9]), 4);
        assert_eq!(px.longest_prefix(&[9, 9]), 0);
        let s = px.stats();
        assert_eq!((s.hits, s.misses), (0, 0), "probing records nothing");
    }

    #[test]
    fn edge_split_keeps_both_paths_servable() {
        let mut c = cache();
        let mut px = RadixPrefixCache::new(HEADS, PS, 1024);
        let a = prompt(&[1, 2, 3, 4, 5, 6]);
        let b = prompt(&[1, 2, 3, 9, 9, 9]);
        let sa = seed(&mut c, &a);
        let sb = seed(&mut c, &b);
        assert!(px.insert(&a, &mut c, &sa));
        assert!(px.insert(&b, &mut c, &sb)); // splits the edge at depth 3
        assert_eq!(px.len(), 2);

        let ha = px.peek(&[1, 2, 3, 4, 5, 6, 7]).expect("a-path");
        assert_eq!(ha.shared, 6);
        let hb = px.peek(&[1, 2, 3, 9, 9, 9, 7]).expect("b-path");
        assert_eq!(hb.shared, 6);
        // Divergence right after the split point: either entry serves
        // the 3-token shared prefix.
        let hc = px.peek(&[1, 2, 3, 7, 7]).expect("split-point prefix");
        assert_eq!(hc.shared, 3);
        let f = c.fork_prefix(hc.seqs[0], 3).unwrap();
        for i in 0..3 {
            assert_eq!(c.get(f, i).unwrap()[0], (i as f32) + 1.0);
        }
        c.free(f).unwrap();
    }

    #[test]
    fn duplicate_insert_is_an_lru_touch_not_a_leak() {
        let mut c = cache();
        let mut px = RadixPrefixCache::new(HEADS, PS, 1024);
        let p = prompt(&[5, 6, 7, 8]);
        let s1 = seed(&mut c, &p);
        let s2 = seed(&mut c, &p);
        assert!(px.insert(&p, &mut c, &s1));
        let before = c.pages_in_use();
        assert!(!px.insert(&p, &mut c, &s2), "duplicate path is not re-inserted");
        assert_eq!(c.pages_in_use(), before, "duplicate insert forks nothing");
        assert_eq!(px.len(), 1);
        assert_eq!(px.stats().inserted, 1);
    }

    #[test]
    fn lru_eviction_respects_budget_and_borrowers() {
        let mut c = cache();
        // Budget fits exactly two 8-token entries (2 heads × 2 pages).
        let mut px = RadixPrefixCache::new(HEADS, PS, 2 * HEADS * 2);
        let p1 = prompt(&[1; 8]);
        let p2 = prompt(&[2; 8]);
        let p3 = prompt(&[3; 8]);
        let s1 = seed(&mut c, &p1);
        let s2 = seed(&mut c, &p2);
        let s3 = seed(&mut c, &p3);
        assert!(px.insert(&p1, &mut c, &s1));
        assert!(px.insert(&p2, &mut c, &s2));
        // Touch p1 so p2 is the LRU victim.
        let h1 = px.peek(&[1, 1, 1, 1, 1, 1, 1, 1, 7]).unwrap();
        px.borrow(h1.entry, &mut c);
        px.release(h1.entry);
        assert!(px.insert(&p3, &mut c, &s3));
        assert_eq!(px.len(), 2);
        assert_eq!(px.stats().evicted, 1);
        assert!(px.peek(&[2, 2, 2, 2, 2, 2, 2, 2, 7]).is_none(), "p2 evicted");
        assert!(px.peek(&[1, 1, 1, 1, 1, 1, 1, 1, 7]).is_some(), "p1 survived");

        // Borrowed entries are never evicted: borrow both residents,
        // then try to insert a third.
        let h1 = px.peek(&[1, 1, 1, 1, 1, 1, 1, 1, 7]).unwrap();
        let h3 = px.peek(&[3, 3, 3, 3, 3, 3, 3, 3, 7]).unwrap();
        px.borrow(h1.entry, &mut c);
        px.borrow(h3.entry, &mut c);
        let p4 = prompt(&[4; 8]);
        let s4 = seed(&mut c, &p4);
        assert!(!px.insert(&p4, &mut c, &s4), "no unborrowed victim -> insert refused");
        assert_eq!(px.len(), 2);
        px.release(h1.entry);
        px.release(h3.entry);
        assert!(px.insert(&p4, &mut c, &s4), "room after borrows release");
    }

    /// Tiered lifecycle (satellite regression): LRU pressure demotes
    /// the victim's pinned sequences to int8 instead of dropping them
    /// when the halved charge alone makes room; the cold entry still
    /// serves hits (forks read tier-transparently via `slot_values`),
    /// and borrowing it promotes the pages back to fp32 in place.
    #[test]
    fn lru_pressure_demotes_before_dropping_and_borrow_promotes() {
        let mut c = cache();
        // Budget 10: two hot 8-token entries charge 8; a third needs 4
        // more, and halving the LRU victim (4 -> 2) is exactly enough.
        let mut px = RadixPrefixCache::new(HEADS, PS, 10);
        let p1 = prompt(&[1; 8]);
        let p2 = prompt(&[2; 8]);
        let p3 = prompt(&[3; 8]);
        let s1 = seed(&mut c, &p1);
        let s2 = seed(&mut c, &p2);
        let s3 = seed(&mut c, &p3);
        assert!(px.insert(&p1, &mut c, &s1));
        assert!(px.insert(&p2, &mut c, &s2));
        for s in s1.into_iter().chain(s2) {
            c.free(s).unwrap();
        }
        assert_eq!(px.pages_nominal(), 8);
        assert_eq!(c.pages_demoted(), 0);

        assert!(px.insert(&p3, &mut c, &s3));
        for s in s3 {
            c.free(s).unwrap();
        }
        let st = px.stats();
        assert_eq!((st.demoted, st.evicted), (1, 0), "p1 demoted, nothing dropped");
        assert_eq!(px.len(), 3, "all three entries resident");
        assert_eq!(px.pages_nominal(), 2 + 4 + 4, "cold p1 charges half");
        assert_eq!(c.pages_demoted(), HEADS * 2, "p1's 2 pages per head are int8");

        // The cold entry still serves: fork it and read the prefix
        // tier-transparently (plain `get` is hot-only by contract).
        let hit = px.peek(&[1, 1, 1, 1, 1, 1, 1, 1, 9]).expect("cold entry still cached");
        assert_eq!(hit.shared, 8);
        let f = c.fork_prefix(hit.seqs[0], hit.shared).unwrap();
        for i in 0..hit.shared {
            let v = c.slot_values(f, i).unwrap()[0];
            // One int8 round trip: |err| <= scale/2 = maxabs/254.
            assert!((v - 1.0).abs() <= 1.0 / 254.0 + 1e-6, "slot {i}: {v}");
        }
        c.free(f).unwrap();

        // Borrowing the cold entry promotes every head's pages back to
        // fp32 and restores the full nominal charge (transiently over
        // budget — settled by the next insert's eviction loop).
        px.borrow(hit.entry, &mut c);
        assert_eq!(c.pages_demoted(), 0, "borrow promoted the entry");
        assert_eq!(px.stats().promoted, 1);
        assert_eq!(px.pages_nominal(), 12);
        let f2 = c.fork_prefix(hit.seqs[0], hit.shared).unwrap();
        for i in 0..hit.shared {
            let v = c.get(f2, i).unwrap()[0]; // hot again: plain reads work
            assert!((v - 1.0).abs() <= 1.0 / 254.0 + 1e-6, "slot {i}: {v}");
        }
        c.free(f2).unwrap();
        px.release(hit.entry);

        // Full drain: cold and hot entries both return all pages.
        px.clear(&mut c);
        assert_eq!(c.pages_in_use(), 0);
        assert_eq!(px.pages_nominal(), 0);
    }

    /// When one demotion is not enough, the same LRU victim is removed
    /// on the next pass — demote, then drop, never a stuck loop.
    #[test]
    fn persistent_pressure_drops_the_already_cold_victim() {
        let mut c = cache();
        // Budget 8: fits two hot 8-token entries exactly; a third
        // demotes p1 (8 -> 6, not enough) and then drops it (6 -> 2).
        let mut px = RadixPrefixCache::new(HEADS, PS, 2 * HEADS * 2);
        let p1 = prompt(&[1; 8]);
        let p2 = prompt(&[2; 8]);
        let p3 = prompt(&[3; 8]);
        let s1 = seed(&mut c, &p1);
        let s2 = seed(&mut c, &p2);
        let s3 = seed(&mut c, &p3);
        assert!(px.insert(&p1, &mut c, &s1));
        assert!(px.insert(&p2, &mut c, &s2));
        // Touch p2 so p1 is the LRU victim for both phases.
        let h2 = px.peek(&[2, 2, 2, 2, 2, 2, 2, 2, 9]).unwrap();
        px.borrow(h2.entry, &mut c);
        px.release(h2.entry);
        assert!(px.insert(&p3, &mut c, &s3));
        let st = px.stats();
        assert_eq!((st.demoted, st.evicted), (1, 1), "demote first, then drop");
        assert_eq!(px.len(), 2);
        assert!(px.peek(&[1, 1, 1, 1, 1, 1, 1, 1, 9]).is_none(), "p1 gone");
        assert!(px.peek(&[2, 2, 2, 2, 2, 2, 2, 2, 9]).is_some(), "p2 stays hot");
        for s in s1.into_iter().chain(s2).chain(s3) {
            c.free(s).unwrap();
        }
        px.clear(&mut c);
        assert_eq!(c.pages_in_use(), 0, "dropping a cold entry frees its int8 pages");
    }

    #[test]
    fn eviction_unpins_and_frees_entry_pages() {
        let mut c = cache();
        let mut px = RadixPrefixCache::new(HEADS, PS, 1024);
        let p = prompt(&[1, 2, 3, 4, 5]);
        let src = seed(&mut c, &p);
        assert!(px.insert(&p, &mut c, &src));
        // Drop the source lanes (what retire does after inserting).
        for &s in &src {
            c.free(s).unwrap();
        }
        let held = c.pages_in_use();
        assert!(held > 0, "entry keeps the prefix pages alive");
        px.clear(&mut c);
        assert_eq!(c.pages_in_use(), 0, "evicted entry returns its pages");
        assert!(px.is_empty());
        assert_eq!(px.pages_nominal(), 0);
    }

    #[test]
    fn oversized_entry_is_refused_outright() {
        let mut c = cache();
        let mut px = RadixPrefixCache::new(HEADS, PS, 1); // 1-page budget
        let p = prompt(&[1; 16]);
        let src = seed(&mut c, &p);
        assert!(!px.insert(&p, &mut c, &src));
        assert!(px.is_empty());
    }

    /// Speculation-style rollback over a pinned radix parent
    /// (satellite regression): a lane admitted from a cache hit forks
    /// the entry's pinned sequences; a verify fork then forks *that*
    /// lane. Releasing the verify fork — cleanly or after a mid-append
    /// OutOfPages — must return page accounting exactly to its
    /// pre-fork value, leave the lane's own bytes intact, and leave
    /// the pinned parent entry borrowable and forkable for the next
    /// hit.
    #[test]
    fn speculative_fork_release_keeps_pinned_parents_borrowable() {
        let mut c = cache();
        let mut px = RadixPrefixCache::new(HEADS, PS, 1024);
        let p = prompt(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let src = seed(&mut c, &p);
        assert!(px.insert(&p, &mut c, &src));
        for &s in &src {
            c.free(s).unwrap(); // retire the inserting lane
        }

        // Hit path: borrow the entry, fork a serving lane from the
        // pinned parent, extend it past the shared prefix (decode).
        let hit = px.peek(&[1, 2, 3, 4, 5, 6, 7, 8, 9]).expect("warm hit");
        px.borrow(hit.entry, &mut c);
        let lanes: Vec<SeqId> = hit
            .seqs
            .iter()
            .map(|&s| c.fork_prefix(s, hit.shared).unwrap())
            .collect();
        for &l in &lanes {
            for t in [9, 10, 11] {
                c.append(l, &[t as f32, 0.0]).unwrap();
            }
        }
        let before = c.pages_in_use();

        // Speculative verify: fork the lane at its full length, append
        // γ+1 rows, then roll back.
        let forks: Vec<SeqId> = lanes
            .iter()
            .map(|&l| c.fork_prefix(l, hit.shared + 3).unwrap())
            .collect();
        assert_eq!(c.pages_in_use(), before, "fork_prefix allocates nothing");
        for &f in &forks {
            for t in [12, 13, 14, 15, 16] {
                c.append(f, &[t as f32, 0.0]).unwrap();
            }
            c.free(f).unwrap();
        }
        assert_eq!(c.pages_in_use(), before, "rollback returns every verify page");
        // The lane's own tail bytes survived the shared-page rollback.
        for &l in &lanes {
            for (i, t) in [9, 10, 11].iter().enumerate() {
                assert_eq!(c.get(l, hit.shared + i).unwrap()[0], *t as f32);
            }
        }

        // The pinned parent is still a servable hit: release the
        // borrow, hit again, fork again, read the prefix bytes.
        px.release(hit.entry);
        let hit2 = px.peek(&[1, 2, 3, 4, 5, 6, 7, 8, 9]).expect("still cached");
        assert_eq!(hit2.shared, hit.shared);
        px.borrow(hit2.entry, &mut c);
        let f2 = c.fork_prefix(hit2.seqs[0], hit2.shared).unwrap();
        for (i, &t) in p[..hit2.shared].iter().enumerate() {
            assert_eq!(c.get(f2, i).unwrap()[0], t as f32);
        }
        c.free(f2).unwrap();
        px.release(hit2.entry);

        // Mid-append OutOfPages on the verify fork: tight pool where
        // the verify rows can't fit. The failed fork frees without
        // touching the lane or the pinned parent.
        let mut tc = PagedKvCache::new(
            // prefix pages for HEADS seqs + one freshly-opened page per
            // lane fork — nothing spare for verify appends.
            HEADS * 2 + HEADS,
            PS,
            SlotLayout::Dense { d: 1, d_v: 1 },
        );
        let mut tpx = RadixPrefixCache::new(HEADS, PS, 1024);
        let tp = prompt(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let tsrc = seed(&mut tc, &tp);
        assert!(tpx.insert(&tp, &mut tc, &tsrc));
        for &s in &tsrc {
            tc.free(s).unwrap();
        }
        let th = tpx.peek(&[1, 2, 3, 4, 5, 6, 7, 8, 9]).expect("warm hit");
        tpx.borrow(th.entry, &mut tc);
        let tl: Vec<SeqId> =
            th.seqs.iter().map(|&s| tc.fork_prefix(s, th.shared).unwrap()).collect();
        for &l in &tl {
            tc.append(l, &[9.0, 0.0]).unwrap(); // opens the lane's own page
        }
        let used = tc.pages_in_use();
        let tf = tc.fork_prefix(tl[0], th.shared + 1).unwrap();
        let mut failed = false;
        for t in 0..2 * PS as i32 {
            if tc.append(tf, &[t as f32, 0.0]).is_err() {
                failed = true;
                break;
            }
        }
        assert!(failed, "tight pool must exhaust mid-verify");
        tc.free(tf).unwrap();
        assert_eq!(tc.pages_in_use(), used, "failed verify rolls back to pre-fork use");
        assert_eq!(tc.get(tl[0], th.shared).unwrap()[0], 9.0, "lane tail intact");
        tpx.release(th.entry);
        assert!(
            tpx.peek(&[1, 2, 3, 4, 5, 6, 7, 8, 9]).is_some(),
            "pinned parent survives the failed speculation"
        );

        // Full drain of both pools: lanes, then entries.
        for l in tl {
            tc.free(l).unwrap();
        }
        tpx.clear(&mut tc);
        assert_eq!(tc.pages_in_use(), 0);
        for l in lanes {
            c.free(l).unwrap();
        }
        px.clear(&mut c);
        assert_eq!(c.pages_in_use(), 0);
    }

    #[test]
    fn ancestor_entry_serves_deeper_probes() {
        let mut c = cache();
        let mut px = RadixPrefixCache::new(HEADS, PS, 1024);
        let short = prompt(&[1, 2, 3]);
        let s = seed(&mut c, &short);
        assert!(px.insert(&short, &mut c, &s));
        // Probe continues past the cached path with unseen tokens: the
        // walk ends at the leaf (full edge match), whose own entry
        // serves depth 3.
        let hit = px.peek(&[1, 2, 3, 4, 5, 6]).expect("ancestor path");
        assert_eq!(hit.shared, 3);
    }
}
