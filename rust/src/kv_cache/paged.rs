//! Paged KV-cache allocator (vLLM-style), with a dense and a sparse
//! (SFA top-k codes) page payload and a two-tier page representation.
//!
//! The coordinator assigns each live sequence a page table; pages are
//! allocated on append and freed when the sequence finishes. Prefix
//! sharing is supported through per-page reference counts (fork).
//!
//! Tiering: every page starts **hot** ([`PagePayload::Fp32`]). Cold
//! pages — old tokens a [`KvTierCfg`] marks past `cold_after`, or
//! radix-cache entries no lane is borrowing — demote to the per-row
//! symmetric int8 layout `attention::quant` already implements
//! ([`PagePayload::Int8`]), at **half** the budget cost. The budget is
//! therefore tracked internally in half-page *units* (fp32 page = 2
//! units, int8 page = 1), so the same physical `max_pages` byte budget
//! holds up to ~2x the nominal tokens once pages go cold. With no
//! demotion the unit arithmetic is exactly the old page arithmetic —
//! streams, errors, and counters are bit-for-bit unchanged.
//!
//! Reads are tier-transparent: [`PagedKvCache::token_slices_tiered`]
//! dequantizes cold pages into a caller-borrowed [`TierScratch`] (zero
//! cost when nothing is demoted), [`PagedKvCache::slot_values`] returns
//! one owned slot, and appends promote a cold tail page in place
//! (copy-on-write from a shared cold page dequantizes into the fresh
//! hot copy). Sparse layouts carry packed u16 index pairs as f32 bit
//! patterns; those floats are stored verbatim beside the scales and
//! survive demotion bit-exactly — only genuine values are quantized.

use std::collections::{HashMap, HashSet};

use crate::attention::quant::{dequantize_rows, quantize_rows};
use crate::util::matrix::Matrix;

/// Sequence handle.
pub type SeqId = u64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageError {
    OutOfPages,
    UnknownSeq,
    /// The sequence is pinned (a prefix-cache entry): token eviction
    /// and free are refused until it is unpinned.
    PinnedSeq,
}

impl std::fmt::Display for PageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PageError::OutOfPages => write!(f, "paged KV cache is out of pages"),
            PageError::UnknownSeq => write!(f, "unknown KV-cache sequence id"),
            PageError::PinnedSeq => {
                write!(f, "sequence is pinned by a prefix cache (unpin before evicting)")
            }
        }
    }
}

impl std::error::Error for PageError {}

/// Payload layout of one token slot inside a page.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotLayout {
    /// Dense K (d) + dense V (d_v) floats.
    Dense { d: usize, d_v: usize },
    /// SFA: k key values + k key indices + dense V.
    Sparse { k: usize, d_v: usize },
}

impl SlotLayout {
    /// f32/u16 payload floats-equivalent per token (indices packed two
    /// per float slot for accounting purposes).
    pub fn floats_per_token(&self) -> usize {
        match *self {
            SlotLayout::Dense { d, d_v } => d + d_v,
            SlotLayout::Sparse { k, d_v } => k + k.div_ceil(2) + d_v,
        }
    }

    /// Quantizable floats *before* the packed-index region of a slot
    /// (Sparse: the k top-k key values; Dense: the whole slot — there
    /// is no index region).
    pub fn value_head(&self) -> usize {
        match *self {
            SlotLayout::Dense { d, d_v } => d + d_v,
            SlotLayout::Sparse { k, .. } => k,
        }
    }

    /// Packed u16 index floats per token — raw bit patterns that must
    /// never pass through the quantizer.
    pub fn idx_cols(&self) -> usize {
        match *self {
            SlotLayout::Dense { .. } => 0,
            SlotLayout::Sparse { k, .. } => k.div_ceil(2),
        }
    }

    /// Quantizable floats per token: everything except packed indices.
    pub fn value_cols(&self) -> usize {
        self.floats_per_token() - self.idx_cols()
    }
}

/// Tier-demotion policy for [`KvTierCfg`]: who decides which pages go
/// cold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierPolicy {
    /// Positional: everything but the last `cold_after` tokens of a
    /// lane demotes; unborrowed LRU radix entries demote whole.
    Lru,
    /// Attention-mass: the lane's KV policy (H2O family) nominates the
    /// cold set from its eviction scores *before* it would evict.
    H2o,
}

impl TierPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            TierPolicy::Lru => "lru",
            TierPolicy::H2o => "h2o",
        }
    }
}

/// Tiered-KV configuration, parsed from the shared
/// `family[:key=value,...]` grammar: `tier:cold_after=N,policy=lru|h2o`
/// (`ServeConfig::kv_tier` / `--kv-tier`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvTierCfg {
    /// Tokens at the tail of every lane kept hot; everything older is
    /// demotion-eligible. Must be >= 1 (0 would demote the slot the
    /// next decode step writes).
    pub cold_after: usize,
    pub policy: TierPolicy,
}

impl Default for KvTierCfg {
    fn default() -> Self {
        KvTierCfg { cold_after: 64, policy: TierPolicy::Lru }
    }
}

impl KvTierCfg {
    /// Parse `tier:cold_after=N,policy=lru|h2o` (both keys optional;
    /// defaults `cold_after=64`, `policy=lru`).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let raw = crate::util::spec::tokenize(spec)?;
        if raw.family != "tier" {
            return Err(format!(
                "unknown kv-tier family {:?} (expected `tier:cold_after=N,policy=lru|h2o`)",
                raw.family
            ));
        }
        let mut cfg = KvTierCfg::default();
        for &(k, v) in &raw.pairs {
            match k {
                "cold_after" => {
                    cfg.cold_after = v
                        .parse()
                        .map_err(|_| format!("tier: cold_after must be an integer, got {v:?}"))?;
                    if cfg.cold_after == 0 {
                        return Err("tier: cold_after must be >= 1".into());
                    }
                }
                "policy" => {
                    cfg.policy = match v {
                        "lru" => TierPolicy::Lru,
                        "h2o" => TierPolicy::H2o,
                        other => {
                            return Err(format!(
                                "tier: unknown policy {other:?} (expected lru|h2o)"
                            ))
                        }
                    };
                }
                other => return Err(format!("tier: unknown key {other:?}")),
            }
        }
        Ok(cfg)
    }

    pub fn label(&self) -> String {
        format!("tier:cold_after={},policy={}", self.cold_after, self.policy.label())
    }
}

/// One page's backing store, by tier.
#[derive(Debug, Clone)]
pub enum PagePayload {
    /// Hot tier: fp32 slots, directly sliceable.
    Fp32(Vec<f32>),
    /// Cold tier: per-slot symmetric int8 codes over the quantizable
    /// columns ([`SlotLayout::value_cols`]); `scales` holds, per slot,
    /// `[scale, packed idx floats...]` so a sparse layout's u16 index
    /// bit patterns ride along verbatim and survive round trips
    /// bit-exactly.
    Int8 { codes: Vec<i8>, scales: Vec<f32> },
}

fn payload_units(p: &PagePayload) -> usize {
    match p {
        PagePayload::Fp32(_) => 2,
        PagePayload::Int8 { .. } => 1,
    }
}

/// Reconstruct one slot of a cold page as owned fp32 floats.
fn dequant_slot(codes: &[i8], scales: &[f32], slot: usize, layout: SlotLayout) -> Vec<f32> {
    let (vh, ic, vc) = (layout.value_head(), layout.idx_cols(), layout.value_cols());
    let chunk = &scales[slot * (1 + ic)..(slot + 1) * (1 + ic)];
    let scale = chunk[0];
    let row = &codes[slot * vc..(slot + 1) * vc];
    let mut out = vec![0.0f32; layout.floats_per_token()];
    for (dst, &c) in out[..vh].iter_mut().zip(&row[..vh]) {
        *dst = c as f32 * scale;
    }
    out[vh..vh + ic].copy_from_slice(&chunk[1..]);
    for (dst, &c) in out[vh + ic..].iter_mut().zip(&row[vh..]) {
        *dst = c as f32 * scale;
    }
    out
}

/// Reconstruct a whole cold page as an fp32 buffer (the promote /
/// scratch-fill primitive), built on [`dequantize_rows`].
fn dequant_page(codes: &[i8], scales: &[f32], page_size: usize, layout: SlotLayout) -> Vec<f32> {
    let fpt = layout.floats_per_token();
    let (vh, ic, vc) = (layout.value_head(), layout.idx_cols(), layout.value_cols());
    let plain: Vec<f32> = (0..page_size).map(|s| scales[s * (1 + ic)]).collect();
    let m = dequantize_rows(codes, &plain, page_size, vc);
    let mut out = vec![0.0f32; page_size * fpt];
    for (s, slot) in out.chunks_mut(fpt).enumerate() {
        let row = m.row(s);
        slot[..vh].copy_from_slice(&row[..vh]);
        slot[vh..vh + ic].copy_from_slice(&scales[s * (1 + ic) + 1..(s + 1) * (1 + ic)]);
        slot[vh + ic..].copy_from_slice(&row[vh..]);
    }
    out
}

/// Caller-borrowed dequantization scratch for tier-transparent reads:
/// [`PagedKvCache::token_slices_tiered`] fills it with the cold pages a
/// walk touches and hands out slices that borrow either the page or the
/// scratch. Empty (no allocation) while nothing is demoted. Buffers are
/// snapshots — create a fresh scratch (or [`TierScratch::clear`]) after
/// any cache mutation.
#[derive(Debug, Default)]
pub struct TierScratch {
    bufs: HashMap<u32, Vec<f32>>,
}

impl TierScratch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn clear(&mut self) {
        self.bufs.clear();
    }

    /// Cold pages currently materialized in this scratch.
    pub fn pages_buffered(&self) -> usize {
        self.bufs.len()
    }
}

/// A paged KV cache for one layer-head group.
#[derive(Debug)]
pub struct PagedKvCache {
    pub page_size: usize,
    pub layout: SlotLayout,
    /// Backing store: one payload per page (allocated lazily).
    pages: Vec<PagePayload>,
    free_list: Vec<u32>,
    ref_counts: Vec<u32>,
    /// seq -> (page ids, token count)
    tables: HashMap<SeqId, (Vec<u32>, usize)>,
    /// Sequences pinned out of `retain`/`evict_tokens`/`free` (prefix
    /// cache entries — see [`crate::kv_cache::radix`]). Demotion is a
    /// representation change, not an eviction: pinned sequences may
    /// still demote/promote.
    pinned: HashSet<SeqId>,
    next_seq: SeqId,
    max_pages: usize,
    /// Budget actually consumed, in half-page units (fp32 page = 2,
    /// int8 page = 1, free-listed = 0) against `2 * max_pages`.
    units_in_use: usize,
    /// In-use pages currently on the int8 tier.
    int8_in_use: usize,
    /// Cumulative successful page allocations (appends + rebuilds).
    alloc_total: usize,
    /// Cumulative pages consumed by `retain` rebuilds — the share of
    /// `alloc_total` that is compaction traffic, not new tokens.
    rebuild_total: usize,
    /// Cumulative demote / promote transitions (promote counts both
    /// in-place promotions and copy-on-write re-materializations).
    demote_total: usize,
    promote_total: usize,
    /// Worst observed per-element |v - dequant(quant(v))| across every
    /// demotion, and the same error as a fraction of the contractual
    /// half-step bound `scale/2` (<= 1.0 by construction).
    tier_max_err: f32,
    tier_max_ratio: f32,
}

impl PagedKvCache {
    pub fn new(max_pages: usize, page_size: usize, layout: SlotLayout) -> Self {
        PagedKvCache {
            page_size,
            layout,
            pages: Vec::new(),
            free_list: Vec::new(),
            ref_counts: Vec::new(),
            tables: HashMap::new(),
            pinned: HashSet::new(),
            next_seq: 0,
            max_pages,
            units_in_use: 0,
            int8_in_use: 0,
            alloc_total: 0,
            rebuild_total: 0,
            demote_total: 0,
            promote_total: 0,
            tier_max_err: 0.0,
            tier_max_ratio: 0.0,
        }
    }

    /// Allocate one hot page. The budget check is in half-page units,
    /// which reduces exactly to the old `max_pages` check while nothing
    /// is demoted; once cold pages hold units back, the physical page
    /// vector may legitimately grow past `max_pages` (same bytes, more
    /// pages).
    fn alloc_page(&mut self) -> Result<u32, PageError> {
        if self.units_in_use + 2 > 2 * self.max_pages {
            return Err(PageError::OutOfPages);
        }
        let fpt = self.layout.floats_per_token();
        if let Some(p) = self.free_list.pop() {
            self.ref_counts[p as usize] = 1;
            // Recycled pages come back hot; a buffer freed while cold
            // is re-materialized at full width (contents are dead —
            // every slot is rewritten before it becomes readable).
            if matches!(self.pages[p as usize], PagePayload::Int8 { .. }) {
                self.pages[p as usize] = PagePayload::Fp32(vec![0.0; self.page_size * fpt]);
            }
            self.units_in_use += 2;
            self.alloc_total += 1;
            return Ok(p);
        }
        let id = self.pages.len() as u32;
        self.pages
            .push(PagePayload::Fp32(vec![0.0; self.page_size * fpt]));
        self.ref_counts.push(1);
        self.units_in_use += 2;
        self.alloc_total += 1;
        Ok(id)
    }

    /// Drop one reference; on the last, return the page (and its units)
    /// to the pool. Returns true when the page was actually freed.
    fn release_page(&mut self, p: u32) -> bool {
        self.ref_counts[p as usize] -= 1;
        if self.ref_counts[p as usize] > 0 {
            return false;
        }
        self.units_in_use -= payload_units(&self.pages[p as usize]);
        if matches!(self.pages[p as usize], PagePayload::Int8 { .. }) {
            self.int8_in_use -= 1;
        }
        self.free_list.push(p);
        true
    }

    /// Borrow a page's hot buffer; panics on a cold page (internal
    /// callers must promote or go through the tiered read path).
    fn page_f32(&self, page: u32) -> &[f32] {
        match &self.pages[page as usize] {
            PagePayload::Fp32(buf) => buf,
            PagePayload::Int8 { .. } => panic!(
                "page {page} is demoted to int8 — read via slot_values/token_slices_tiered \
                 or promote_pages first"
            ),
        }
    }

    /// Demote one hot page to int8. Returns false when already cold.
    fn demote_page(&mut self, page: u32) -> bool {
        let fpt = self.layout.floats_per_token();
        let (vh, ic, vc) =
            (self.layout.value_head(), self.layout.idx_cols(), self.layout.value_cols());
        let (codes, scales, max_err, max_ratio) = {
            let buf = match &self.pages[page as usize] {
                PagePayload::Fp32(buf) => buf,
                PagePayload::Int8 { .. } => return false,
            };
            // Gather the quantizable columns (skipping packed-index
            // floats) into one matrix row per slot.
            let mut m = Matrix::zeros(self.page_size, vc);
            for (s, slot) in buf.chunks(fpt).enumerate() {
                let row = m.row_mut(s);
                row[..vh].copy_from_slice(&slot[..vh]);
                row[vh..].copy_from_slice(&slot[vh + ic..]);
            }
            let (codes, plain) = quantize_rows(&m);
            let mut max_err = 0f32;
            let mut max_ratio = 0f32;
            for (s, &scale) in plain.iter().enumerate() {
                let crow = &codes[s * vc..(s + 1) * vc];
                for (&v, &c) in m.row(s).iter().zip(crow) {
                    let err = (v - c as f32 * scale).abs();
                    max_err = max_err.max(err);
                    if scale > 0.0 {
                        max_ratio = max_ratio.max(err / (0.5 * scale));
                    }
                }
            }
            // Interleave [scale, idx floats...] per slot so packed
            // sparse indices survive bit-exactly.
            let mut scales = Vec::with_capacity(self.page_size * (1 + ic));
            for (s, slot) in buf.chunks(fpt).enumerate() {
                scales.push(plain[s]);
                scales.extend_from_slice(&slot[vh..vh + ic]);
            }
            (codes, scales, max_err, max_ratio)
        };
        self.pages[page as usize] = PagePayload::Int8 { codes, scales };
        self.units_in_use -= 1;
        self.int8_in_use += 1;
        self.demote_total += 1;
        self.tier_max_err = self.tier_max_err.max(max_err);
        self.tier_max_ratio = self.tier_max_ratio.max(max_ratio);
        true
    }

    /// Promote one cold page back to fp32 in place. Never fails: a
    /// promotion may transiently overshoot the unit budget — page
    /// *allocation* is the enforced boundary. Returns false when
    /// already hot.
    fn promote_page(&mut self, page: u32) -> bool {
        let buf = match &self.pages[page as usize] {
            PagePayload::Int8 { codes, scales } => {
                dequant_page(codes, scales, self.page_size, self.layout)
            }
            PagePayload::Fp32(_) => return false,
        };
        self.pages[page as usize] = PagePayload::Fp32(buf);
        self.units_in_use += 1;
        self.int8_in_use -= 1;
        self.promote_total += 1;
        true
    }

    /// Register a new sequence; returns its handle.
    pub fn create_seq(&mut self) -> SeqId {
        let id = self.next_seq;
        self.next_seq += 1;
        self.tables.insert(id, (Vec::new(), 0));
        id
    }

    /// Append one token's payload; allocates a page on boundary crossing.
    pub fn append(&mut self, seq: SeqId, payload: &[f32]) -> Result<(), PageError> {
        let fpt = self.layout.floats_per_token();
        assert_eq!(payload.len(), fpt, "payload must match layout");
        // Determine state first (split borrows around alloc_page).
        let (n_pages, len) = {
            let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
            (table.len(), *len)
        };
        let slot = len % self.page_size;
        let page_id = if slot == 0 {
            let p = self.alloc_page()?;
            let (table, _) = self.tables.get_mut(&seq).unwrap();
            table.push(p);
            p
        } else {
            let (table, _) = self.tables.get(&seq).unwrap();
            table[n_pages - 1]
        };
        // Copy-on-write if the page is shared (tier-transparently: a
        // shared cold page dequantizes straight into the hot copy).
        let page_id = if self.ref_counts[page_id as usize] > 1 {
            let copy = self.alloc_page()?;
            self.ref_counts[page_id as usize] -= 1;
            let (src, was_cold) = match &self.pages[page_id as usize] {
                PagePayload::Fp32(buf) => (buf.clone(), false),
                PagePayload::Int8 { codes, scales } => {
                    (dequant_page(codes, scales, self.page_size, self.layout), true)
                }
            };
            if was_cold {
                self.promote_total += 1;
            }
            match &mut self.pages[copy as usize] {
                PagePayload::Fp32(buf) => buf.copy_from_slice(&src),
                PagePayload::Int8 { .. } => unreachable!("alloc_page returns hot pages"),
            }
            let (table, _) = self.tables.get_mut(&seq).unwrap();
            *table.last_mut().unwrap() = copy;
            copy
        } else {
            // Exclusively-owned cold tail page: promote in place before
            // the write lands.
            if matches!(self.pages[page_id as usize], PagePayload::Int8 { .. }) {
                self.promote_page(page_id);
            }
            page_id
        };
        match &mut self.pages[page_id as usize] {
            PagePayload::Fp32(page) => {
                page[slot * fpt..(slot + 1) * fpt].copy_from_slice(payload)
            }
            PagePayload::Int8 { .. } => unreachable!("append target was promoted above"),
        }
        let (_, len) = self.tables.get_mut(&seq).unwrap();
        *len += 1;
        Ok(())
    }

    /// Read one token slot (hot pages only — panics on a demoted page;
    /// use [`PagedKvCache::slot_values`] for tier-transparent reads).
    pub fn get(&self, seq: SeqId, pos: usize) -> Result<&[f32], PageError> {
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        assert!(pos < *len, "pos {pos} >= len {len}");
        let fpt = self.layout.floats_per_token();
        let page = table[pos / self.page_size];
        let slot = pos % self.page_size;
        Ok(&self.page_f32(page)[slot * fpt..(slot + 1) * fpt])
    }

    /// Read one token slot tier-transparently: hot slots are copied,
    /// cold slots dequantized (packed index floats verbatim).
    pub fn slot_values(&self, seq: SeqId, pos: usize) -> Result<Vec<f32>, PageError> {
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        assert!(pos < *len, "pos {pos} >= len {len}");
        let fpt = self.layout.floats_per_token();
        let page = table[pos / self.page_size];
        let slot = pos % self.page_size;
        match &self.pages[page as usize] {
            PagePayload::Fp32(buf) => Ok(buf[slot * fpt..(slot + 1) * fpt].to_vec()),
            PagePayload::Int8 { codes, scales } => {
                Ok(dequant_slot(codes, scales, slot, self.layout))
            }
        }
    }

    /// Borrow every token slot of a sequence in order, one slice per
    /// token — the decode path's scan view (attention sessions walk the
    /// whole cached sequence per step). Hot pages only — panics on a
    /// demoted page; mixed-tier lanes go through
    /// [`PagedKvCache::token_slices_tiered`].
    pub fn token_slices(&self, seq: SeqId) -> Result<Vec<&[f32]>, PageError> {
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        let fpt = self.layout.floats_per_token();
        let mut out = Vec::with_capacity(*len);
        for pos in 0..*len {
            let page = table[pos / self.page_size];
            let slot = pos % self.page_size;
            out.push(&self.page_f32(page)[slot * fpt..(slot + 1) * fpt]);
        }
        Ok(out)
    }

    /// Tier-transparent [`PagedKvCache::token_slices`]: cold pages the
    /// walk touches dequantize once into the caller's [`TierScratch`];
    /// the returned slices borrow either the page or the scratch. While
    /// nothing is demoted this is exactly `token_slices` (the scratch
    /// stays empty). The scratch holds snapshots — reuse it across
    /// *reads* freely, refresh it after any cache mutation.
    pub fn token_slices_tiered<'a>(
        &'a self,
        seq: SeqId,
        scratch: &'a mut TierScratch,
    ) -> Result<Vec<&'a [f32]>, PageError> {
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        let fpt = self.layout.floats_per_token();
        // Phase 1: materialize every cold page the walk touches.
        for &p in &table[..len.div_ceil(self.page_size)] {
            if let PagePayload::Int8 { codes, scales } = &self.pages[p as usize] {
                scratch
                    .bufs
                    .entry(p)
                    .or_insert_with(|| dequant_page(codes, scales, self.page_size, self.layout));
            }
        }
        // Phase 2: build the walk over shared reborrows.
        let bufs = &scratch.bufs;
        let mut out = Vec::with_capacity(*len);
        for pos in 0..*len {
            let p = table[pos / self.page_size];
            let slot = pos % self.page_size;
            let base: &[f32] = match &self.pages[p as usize] {
                PagePayload::Fp32(buf) => buf,
                PagePayload::Int8 { .. } => &bufs[&p],
            };
            out.push(&base[slot * fpt..(slot + 1) * fpt]);
        }
        Ok(out)
    }

    /// Demote every fully-cold page of `seq` to int8, keeping the last
    /// `keep_hot` tokens hot. Pages spanning the hot boundary stay hot;
    /// `keep_hot == 0` demotes the partial tail page too (the radix
    /// cache's whole-entry demotion). Allowed on pinned sequences —
    /// demotion is a representation change, not an eviction. Shared
    /// (forked) pages demote in place for every sharer; reads stay
    /// tier-transparent and the first append copy-on-writes hot.
    /// Returns the number of pages that transitioned.
    pub fn demote_pages(&mut self, seq: SeqId, keep_hot: usize) -> Result<usize, PageError> {
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        let cold_tokens = len.saturating_sub(keep_hot);
        let cold_pages = if keep_hot == 0 {
            cold_tokens.div_ceil(self.page_size)
        } else {
            cold_tokens / self.page_size
        };
        let targets: Vec<u32> = table[..cold_pages.min(table.len())].to_vec();
        let mut n = 0;
        for p in targets {
            if self.demote_page(p) {
                n += 1;
            }
        }
        Ok(n)
    }

    /// Demote every page of `seq` whose in-range tokens are *all* in
    /// `cold` (the KV-policy verdict path: H2O-family scores nominate
    /// cold tokens; only wholly-cold pages transition). Positions out
    /// of range are ignored. Returns pages transitioned.
    pub fn demote_token_set(&mut self, seq: SeqId, cold: &[u32]) -> Result<usize, PageError> {
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        let len = *len;
        let mut is_cold = vec![false; len];
        for &pos in cold {
            if (pos as usize) < len {
                is_cold[pos as usize] = true;
            }
        }
        let mut targets = Vec::new();
        for (pi, &p) in table.iter().enumerate() {
            let start = pi * self.page_size;
            if start >= len {
                break;
            }
            let end = (start + self.page_size).min(len);
            if is_cold[start..end].iter().all(|&c| c) {
                targets.push(p);
            }
        }
        let mut n = 0;
        for p in targets {
            if self.demote_page(p) {
                n += 1;
            }
        }
        Ok(n)
    }

    /// Promote every cold page of `seq` back to fp32 (the radix cache's
    /// borrow path: a lane about to read a cached prefix every step
    /// re-heats it once). Never fails; returns pages transitioned.
    pub fn promote_pages(&mut self, seq: SeqId) -> Result<usize, PageError> {
        let (table, _) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        let targets: Vec<u32> = table.clone();
        let mut n = 0;
        for p in targets {
            if self.promote_page(p) {
                n += 1;
            }
        }
        Ok(n)
    }

    /// Fork a sequence sharing all current pages (prefix caching).
    pub fn fork(&mut self, seq: SeqId) -> Result<SeqId, PageError> {
        let len = self.seq_len(seq).ok_or(PageError::UnknownSeq)?;
        self.fork_prefix(seq, len)
    }

    /// Fork only the first `n_tokens` of a sequence: the new sequence
    /// shares the `⌈n_tokens / page_size⌉` pages covering that prefix
    /// (refcounted — never copied, hot or cold). A partially filled
    /// last page is shared too: its beyond-prefix slots are unreachable
    /// (reads are length-bounded) and the first append into it
    /// copy-on-writes while the page is shared. This is the radix
    /// prefix cache's hit path: seed a lane with a cached prompt
    /// prefix, then append only the suffix.
    pub fn fork_prefix(&mut self, seq: SeqId, n_tokens: usize) -> Result<SeqId, PageError> {
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        assert!(n_tokens <= *len, "fork_prefix of {n_tokens} tokens from a {len}-token seq");
        let shared = n_tokens.div_ceil(self.page_size);
        let table: Vec<u32> = table[..shared].to_vec();
        for &p in &table {
            self.ref_counts[p as usize] += 1;
        }
        let id = self.next_seq;
        self.next_seq += 1;
        self.tables.insert(id, (table, n_tokens));
        Ok(id)
    }

    /// Pin a sequence: `retain`/`evict_tokens`/`free` refuse it until
    /// [`PagedKvCache::unpin_seq`]. The radix prefix cache pins its
    /// entries so no eviction path can prune pages a cached prefix
    /// still references. Tier transitions remain allowed — a pinned
    /// entry can go cold and come back without ever being evictable.
    pub fn pin_seq(&mut self, seq: SeqId) -> Result<(), PageError> {
        if !self.tables.contains_key(&seq) {
            return Err(PageError::UnknownSeq);
        }
        self.pinned.insert(seq);
        Ok(())
    }

    /// Remove a sequence's pin (no-op when not pinned).
    pub fn unpin_seq(&mut self, seq: SeqId) -> Result<(), PageError> {
        if !self.tables.contains_key(&seq) {
            return Err(PageError::UnknownSeq);
        }
        self.pinned.remove(&seq);
        Ok(())
    }

    pub fn is_pinned(&self, seq: SeqId) -> bool {
        self.pinned.contains(&seq)
    }

    /// Free a sequence, returning pages whose refcount drops to zero.
    /// Pinned sequences are refused ([`PageError::PinnedSeq`]) — unpin
    /// first, so a prefix-cache entry can't be dropped by accident.
    pub fn free(&mut self, seq: SeqId) -> Result<usize, PageError> {
        if self.pinned.contains(&seq) {
            return Err(PageError::PinnedSeq);
        }
        let (table, _) = self.tables.remove(&seq).ok_or(PageError::UnknownSeq)?;
        let mut freed = 0;
        for p in table {
            if self.release_page(p) {
                freed += 1;
            }
        }
        Ok(freed)
    }

    /// Retain only the token positions in `keep` (strictly ascending,
    /// all `< seq_len`), compacting the surviving payloads to the front
    /// of the sequence; token `keep[i]` becomes token `i`. Pages whose
    /// last reference drops go back to the pool. Pages shared with a
    /// fork are never mutated (copy-on-evict): the sequence is rebuilt
    /// onto exclusively-owned pages, so forks keep reading the original
    /// data. Cold source pages are read tier-transparently and the
    /// rebuilt sequence comes back fully hot (a tier policy may
    /// re-demote it later). Returns how many pages the call returned to
    /// the allocatable budget (0 when the rebuild consumed as many
    /// fresh pages as it released, which can happen under heavy
    /// sharing).
    ///
    /// Fails with [`PageError::OutOfPages`] — leaving the sequence
    /// untouched — only when every surviving page is fork-shared *and*
    /// the pool has no headroom for the rebuilt copies.
    pub fn retain(&mut self, seq: SeqId, keep: &[usize]) -> Result<usize, PageError> {
        if self.pinned.contains(&seq) {
            return Err(PageError::PinnedSeq);
        }
        let fpt = self.layout.floats_per_token();
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?.clone();
        for w in keep.windows(2) {
            assert!(w[0] < w[1], "keep positions must be strictly ascending");
        }
        if let Some(&last) = keep.last() {
            assert!(last < len, "keep position {last} >= len {len}");
        }
        if keep.len() == len {
            return Ok(0); // ascending + in-range + full length == identity
        }
        let free_before = self.pages_free();
        // Feasibility before mutating anything, in half-page units: the
        // rebuild needs `2 * new_pages` hot units, fed by the pool plus
        // whatever this sequence exclusively owns (shared pages only
        // drop a ref; cold exclusives give back one unit, hot two).
        let new_pages = keep.len().div_ceil(self.page_size);
        let reclaimable_units: usize = table
            .iter()
            .filter(|&&p| self.ref_counts[p as usize] == 1)
            .map(|&p| payload_units(&self.pages[p as usize]))
            .sum();
        let pool_units = (2 * self.max_pages).saturating_sub(self.units_in_use);
        if 2 * new_pages > pool_units + reclaimable_units {
            return Err(PageError::OutOfPages);
        }
        // Gather the surviving payloads tier-transparently (each cold
        // page dequantizes at most once), release the old table,
        // rebuild onto hot pages.
        let mut kept: Vec<f32> = Vec::with_capacity(keep.len() * fpt);
        let mut cold_bufs: HashMap<u32, Vec<f32>> = HashMap::new();
        for &pos in keep {
            let page = table[pos / self.page_size];
            let slot = pos % self.page_size;
            let base: &[f32] = match &self.pages[page as usize] {
                PagePayload::Fp32(buf) => buf,
                PagePayload::Int8 { codes, scales } => cold_bufs
                    .entry(page)
                    .or_insert_with(|| dequant_page(codes, scales, self.page_size, self.layout)),
            };
            kept.extend_from_slice(&base[slot * fpt..(slot + 1) * fpt]);
        }
        for &p in &table {
            self.release_page(p);
        }
        let mut new_table = Vec::with_capacity(new_pages);
        for _ in 0..new_pages {
            new_table.push(self.alloc_page().expect("feasibility checked above"));
        }
        self.rebuild_total += new_pages;
        for (i, chunk) in kept.chunks(self.page_size * fpt).enumerate() {
            match &mut self.pages[new_table[i] as usize] {
                PagePayload::Fp32(buf) => buf[..chunk.len()].copy_from_slice(chunk),
                PagePayload::Int8 { .. } => unreachable!("alloc_page returns hot pages"),
            }
        }
        *self.tables.get_mut(&seq).unwrap() = (new_table, keep.len());
        Ok(self.pages_free().saturating_sub(free_before))
    }

    /// Evict the token positions in `drop` (any order, duplicates
    /// ignored), keeping everything else — the complement convenience
    /// over [`PagedKvCache::retain`].
    pub fn evict_tokens(&mut self, seq: SeqId, drop: &[usize]) -> Result<usize, PageError> {
        let (_, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        let len = *len;
        let mut dropped = vec![false; len];
        for &pos in drop {
            assert!(pos < len, "drop position {pos} >= len {len}");
            dropped[pos] = true;
        }
        let keep: Vec<usize> = (0..len).filter(|&i| !dropped[i]).collect();
        self.retain(seq, &keep)
    }

    pub fn seq_len(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|(_, l)| *l)
    }

    /// Pages currently mapped by one sequence's page table.
    pub fn seq_pages(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|(t, _)| t.len())
    }

    /// Pages of one sequence currently on the int8 tier.
    pub fn seq_pages_demoted(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|(t, _)| {
            t.iter()
                .filter(|&&p| matches!(self.pages[p as usize], PagePayload::Int8 { .. }))
                .count()
        })
    }

    /// Hard page cap this cache was constructed with.
    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    /// Whole hot pages still allocatable before
    /// [`PageError::OutOfPages`]: the unit headroom below the cap,
    /// floored to full (2-unit) pages. Equals the classic
    /// `free list + never-allocated headroom` while nothing is demoted.
    pub fn pages_free(&self) -> usize {
        (2 * self.max_pages).saturating_sub(self.units_in_use) / 2
    }

    pub fn pages_in_use(&self) -> usize {
        self.pages.len() - self.free_list.len()
    }

    /// In-use pages currently demoted to the int8 tier.
    pub fn pages_demoted(&self) -> usize {
        self.int8_in_use
    }

    /// Budget consumed in half-page units (fp32 page = 2, int8 = 1)
    /// against `2 * max_pages` — the tiered capacity bookkeeping the
    /// bench reports effective-capacity gain from.
    pub fn units_in_use(&self) -> usize {
        self.units_in_use
    }

    pub fn bytes_in_use(&self) -> usize {
        let hot_bytes = self.page_size * self.layout.floats_per_token() * 4;
        let cold_bytes = self.page_size * self.layout.value_cols()
            + self.page_size * (1 + self.layout.idx_cols()) * 4;
        let hot = self.pages_in_use() - self.int8_in_use;
        hot * hot_bytes + self.int8_in_use * cold_bytes
    }

    /// Cumulative successful page allocations over the cache's life
    /// (appends and `retain` rebuilds alike). With
    /// [`PagedKvCache::pages_rebuild_total`] this gives the page
    /// conservation law the session accounting tests pin: once every
    /// sequence is freed, `net frees == alloc_total - rebuild_total`.
    pub fn pages_alloc_total(&self) -> usize {
        self.alloc_total
    }

    /// Cumulative pages consumed by `retain`/`evict_tokens` rebuilds.
    pub fn pages_rebuild_total(&self) -> usize {
        self.rebuild_total
    }

    /// Cumulative hot→cold page transitions.
    pub fn pages_demote_total(&self) -> usize {
        self.demote_total
    }

    /// Cumulative cold→hot transitions (in-place promotions plus
    /// copy-on-write re-materializations of shared cold pages).
    pub fn pages_promote_total(&self) -> usize {
        self.promote_total
    }

    /// Worst per-element absolute dequantization error observed across
    /// every demotion so far (the accuracy contract's empirical side).
    pub fn tier_max_dequant_error(&self) -> f32 {
        self.tier_max_err
    }

    /// The same worst error as a fraction of the contractual `scale/2`
    /// half-step bound — <= 1.0 by construction of `quantize_rows`.
    pub fn tier_max_error_ratio(&self) -> f32 {
        self.tier_max_ratio
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn payload(layout: SlotLayout, tag: f32) -> Vec<f32> {
        vec![tag; layout.floats_per_token()]
    }

    #[test]
    fn append_and_get_roundtrip() {
        let layout = SlotLayout::Dense { d: 4, d_v: 4 };
        let mut c = PagedKvCache::new(16, 4, layout);
        let s = c.create_seq();
        for i in 0..10 {
            c.append(s, &payload(layout, i as f32)).unwrap();
        }
        assert_eq!(c.seq_len(s), Some(10));
        for i in 0..10 {
            assert_eq!(c.get(s, i).unwrap()[0], i as f32);
        }
        assert_eq!(c.pages_in_use(), 3); // ceil(10/4)
    }

    #[test]
    fn out_of_pages_reported() {
        let layout = SlotLayout::Dense { d: 2, d_v: 2 };
        let mut c = PagedKvCache::new(2, 2, layout);
        let s = c.create_seq();
        for _ in 0..4 {
            c.append(s, &payload(layout, 0.0)).unwrap();
        }
        assert_eq!(c.append(s, &payload(layout, 0.0)), Err(PageError::OutOfPages));
    }

    #[test]
    fn free_recycles_pages() {
        let layout = SlotLayout::Dense { d: 2, d_v: 2 };
        let mut c = PagedKvCache::new(2, 2, layout);
        let s = c.create_seq();
        for _ in 0..4 {
            c.append(s, &payload(layout, 1.0)).unwrap();
        }
        assert_eq!(c.free(s).unwrap(), 2);
        assert_eq!(c.pages_in_use(), 0);
        let s2 = c.create_seq();
        for _ in 0..4 {
            c.append(s2, &payload(layout, 2.0)).unwrap();
        }
        assert_eq!(c.get(s2, 3).unwrap()[0], 2.0);
    }

    #[test]
    fn fork_shares_then_copies_on_write() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(8, 2, layout);
        let a = c.create_seq();
        c.append(a, &payload(layout, 1.0)).unwrap();
        let b = c.fork(a).unwrap();
        assert_eq!(c.pages_in_use(), 1, "fork shares pages");
        // Appending to the fork must not disturb the parent (CoW).
        c.append(b, &payload(layout, 9.0)).unwrap();
        c.append(a, &payload(layout, 5.0)).unwrap();
        assert_eq!(c.get(a, 1).unwrap()[0], 5.0);
        assert_eq!(c.get(b, 1).unwrap()[0], 9.0);
        assert_eq!(c.get(b, 0).unwrap()[0], 1.0);
    }

    #[test]
    fn sparse_layout_is_smaller() {
        let dense = SlotLayout::Dense { d: 64, d_v: 64 };
        let sparse = SlotLayout::Sparse { k: 8, d_v: 64 };
        assert!(sparse.floats_per_token() < dense.floats_per_token());
        // App-J shape: K-payload shrinks from d to ~1.5k.
        assert_eq!(sparse.floats_per_token(), 8 + 4 + 64);
    }

    #[test]
    fn token_slices_walk_in_order() {
        let layout = SlotLayout::Dense { d: 2, d_v: 1 };
        let mut c = PagedKvCache::new(16, 3, layout);
        let s = c.create_seq();
        for i in 0..7 {
            c.append(s, &payload(layout, i as f32)).unwrap();
        }
        let slots = c.token_slices(s).unwrap();
        assert_eq!(slots.len(), 7);
        for (i, sl) in slots.iter().enumerate() {
            assert_eq!(sl.len(), layout.floats_per_token());
            assert_eq!(sl[0], i as f32);
        }
        assert_eq!(c.token_slices(99).unwrap_err(), PageError::UnknownSeq);
    }

    #[test]
    fn page_budget_accounting() {
        let layout = SlotLayout::Dense { d: 2, d_v: 2 };
        let mut c = PagedKvCache::new(4, 2, layout);
        assert_eq!(c.max_pages(), 4);
        assert_eq!(c.pages_free(), 4);
        let s = c.create_seq();
        for _ in 0..3 {
            c.append(s, &payload(layout, 1.0)).unwrap();
        }
        assert_eq!(c.seq_pages(s), Some(2));
        assert_eq!(c.pages_free(), 2);
        assert_eq!(c.pages_in_use() + c.pages_free(), c.max_pages());
        c.free(s).unwrap();
        // Recycled pages return to the allocatable budget.
        assert_eq!(c.pages_free(), 4);
        assert_eq!(c.seq_pages(s), None);
    }

    #[test]
    fn unknown_seq_errors() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(2, 2, layout);
        assert_eq!(c.free(42), Err(PageError::UnknownSeq));
        assert_eq!(
            c.append(42, &payload(layout, 0.0)),
            Err(PageError::UnknownSeq)
        );
    }

    #[test]
    fn retain_compacts_and_frees_pages() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(16, 4, layout);
        let s = c.create_seq();
        for i in 0..12 {
            c.append(s, &payload(layout, i as f32)).unwrap();
        }
        assert_eq!(c.pages_in_use(), 3);
        // Keep every third token: 12 -> 4 tokens -> 1 page.
        let freed = c.retain(s, &[0, 3, 6, 9]).unwrap();
        assert_eq!(freed, 2);
        assert_eq!(c.seq_len(s), Some(4));
        assert_eq!(c.pages_in_use(), 1);
        for (new, old) in [0usize, 3, 6, 9].iter().enumerate() {
            assert_eq!(c.get(s, new).unwrap()[0], *old as f32);
        }
        // Appends continue from the compacted tail.
        c.append(s, &payload(layout, 99.0)).unwrap();
        assert_eq!(c.seq_len(s), Some(5));
        assert_eq!(c.get(s, 4).unwrap()[0], 99.0);
        assert_eq!(c.pages_in_use(), 2);
        // Identity retain is a no-op; empty retain drops everything.
        assert_eq!(c.retain(s, &[0, 1, 2, 3, 4]).unwrap(), 0);
        assert_eq!(c.retain(s, &[]).unwrap(), 2);
        assert_eq!(c.seq_len(s), Some(0));
        assert_eq!(c.pages_in_use(), 0);
    }

    #[test]
    fn evict_tokens_is_the_retain_complement() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(16, 2, layout);
        let s = c.create_seq();
        for i in 0..6 {
            c.append(s, &payload(layout, i as f32)).unwrap();
        }
        c.evict_tokens(s, &[4, 1, 1]).unwrap();
        assert_eq!(c.seq_len(s), Some(4));
        for (new, old) in [0usize, 2, 3, 5].iter().enumerate() {
            assert_eq!(c.get(s, new).unwrap()[0], *old as f32);
        }
        assert_eq!(c.evict_tokens(99, &[]).unwrap_err(), PageError::UnknownSeq);
    }

    /// Regression (fork × eviction): a fork sharing the parent's pages
    /// must survive both the parent's `retain` (copy-on-evict — shared
    /// pages are never rewritten) and the parent's `free`, and the
    /// refcounted pages must come back only when *both* sides are gone.
    #[test]
    fn forked_seq_survives_parent_eviction_and_free() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(16, 2, layout);
        let a = c.create_seq();
        for i in 0..6 {
            c.append(a, &payload(layout, i as f32)).unwrap();
        }
        let b = c.fork(a).unwrap();
        assert_eq!(c.pages_in_use(), 3, "fork shares all pages");
        // Parent prunes hard: shared pages must not be mutated in place.
        let freed = c.retain(a, &[0, 5]).unwrap();
        assert_eq!(freed, 0, "shared pages only dropped a ref; 1 fresh page consumed");
        assert_eq!(c.seq_len(a), Some(2));
        assert_eq!(c.get(a, 0).unwrap()[0], 0.0);
        assert_eq!(c.get(a, 1).unwrap()[0], 5.0);
        // The fork still reads the full original stream.
        assert_eq!(c.seq_len(b), Some(6));
        for i in 0..6 {
            assert_eq!(c.get(b, i).unwrap()[0], i as f32, "fork data intact");
        }
        // Parent release keeps the fork alive; fork release empties it.
        c.free(a).unwrap();
        for i in 0..6 {
            assert_eq!(c.get(b, i).unwrap()[0], i as f32);
        }
        c.free(b).unwrap();
        assert_eq!(c.pages_in_use(), 0, "all refcounts drained");
        assert_eq!(c.pages_free(), 16);
    }

    /// With every page fork-shared and zero pool headroom, a rebuild
    /// has nowhere to put the copies: retain must fail cleanly and
    /// leave the sequence untouched.
    #[test]
    fn retain_on_fully_shared_pages_without_headroom_errors() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(2, 2, layout);
        let a = c.create_seq();
        for i in 0..4 {
            c.append(a, &payload(layout, i as f32)).unwrap();
        }
        let b = c.fork(a).unwrap();
        assert_eq!(c.retain(a, &[0, 2]).unwrap_err(), PageError::OutOfPages);
        assert_eq!(c.seq_len(a), Some(4), "failed retain mutates nothing");
        for i in 0..4 {
            assert_eq!(c.get(a, i).unwrap()[0], i as f32);
            assert_eq!(c.get(b, i).unwrap()[0], i as f32);
        }
        // Once the fork releases its references the same retain fits.
        c.free(b).unwrap();
        c.retain(a, &[0, 2]).unwrap();
        assert_eq!(c.seq_len(a), Some(2));
        assert_eq!(c.get(a, 1).unwrap()[0], 2.0);
    }

    /// fork_prefix shares only the pages covering the prefix; the fork
    /// reads exactly the prefix, survives the parent's mutation of its
    /// own tail (CoW on the shared partial page), and appends continue
    /// from the prefix without disturbing the parent.
    #[test]
    fn fork_prefix_shares_prefix_pages_only() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(32, 4, layout);
        let a = c.create_seq();
        for i in 0..10 {
            c.append(a, &payload(layout, i as f32)).unwrap();
        }
        assert_eq!(c.pages_in_use(), 3);
        // Prefix of 6 tokens covers ceil(6/4) = 2 pages, page 1 partial.
        let b = c.fork_prefix(a, 6).unwrap();
        assert_eq!(c.pages_in_use(), 3, "fork_prefix allocates nothing");
        assert_eq!(c.seq_len(b), Some(6));
        for i in 0..6 {
            assert_eq!(c.get(b, i).unwrap()[0], i as f32);
        }
        // Appending token 6 to the fork lands in the shared partial
        // page -> copy-on-write; the parent's token 6 is untouched.
        c.append(b, &payload(layout, 99.0)).unwrap();
        assert_eq!(c.get(b, 6).unwrap()[0], 99.0);
        assert_eq!(c.get(a, 6).unwrap()[0], 6.0);
        assert_eq!(c.pages_in_use(), 4, "CoW consumed one fresh page");
        // Parent release keeps the shared prefix alive for the fork.
        c.free(a).unwrap();
        for i in 0..6 {
            assert_eq!(c.get(b, i).unwrap()[0], i as f32);
        }
        c.free(b).unwrap();
        assert_eq!(c.pages_in_use(), 0);
    }

    #[test]
    fn fork_prefix_at_page_boundary_and_full_length() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(32, 4, layout);
        let a = c.create_seq();
        for i in 0..8 {
            c.append(a, &payload(layout, i as f32)).unwrap();
        }
        let b = c.fork_prefix(a, 4).unwrap();
        // Boundary prefix: the fork's next append opens a fresh page,
        // no CoW needed.
        c.append(b, &payload(layout, 50.0)).unwrap();
        assert_eq!(c.get(b, 4).unwrap()[0], 50.0);
        assert_eq!(c.get(a, 4).unwrap()[0], 4.0);
        // Full-length fork_prefix == fork.
        let full = c.fork_prefix(a, 8).unwrap();
        assert_eq!(c.seq_len(full), Some(8));
        let empty = c.fork_prefix(a, 0).unwrap();
        assert_eq!(c.seq_len(empty), Some(0));
    }

    /// Satellite regression (fork-pin × eviction): a prefix pinned by
    /// the radix cache must survive a child's `retain`/`evict_tokens`
    /// and a child release — and the pinned sequence itself refuses
    /// every eviction surface until unpinned.
    #[test]
    fn pinned_prefix_survives_child_retain_evict_and_release() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(64, 2, layout);
        // Build the "cached prefix" and pin it (what RadixPrefixCache
        // does at insert).
        let parent = c.create_seq();
        for i in 0..6 {
            c.append(parent, &payload(layout, i as f32)).unwrap();
        }
        let entry = c.fork_prefix(parent, 6).unwrap();
        c.pin_seq(entry).unwrap();
        assert!(c.is_pinned(entry));
        c.free(parent).unwrap();

        // A child forks the cached prefix and lives its own life.
        let child = c.fork_prefix(entry, 6).unwrap();
        for i in 6..10 {
            c.append(child, &payload(layout, i as f32)).unwrap();
        }
        // Child prunes hard (KV policy): the entry's pages only drop a
        // ref (copy-on-evict), never mutate.
        c.evict_tokens(child, &[0, 1, 2, 3, 4, 6, 8]).unwrap();
        assert_eq!(c.seq_len(child), Some(3));
        for i in 0..6 {
            assert_eq!(c.get(entry, i).unwrap()[0], i as f32, "entry intact after child prune");
        }
        // Child release: entry still intact.
        c.free(child).unwrap();
        for i in 0..6 {
            assert_eq!(c.get(entry, i).unwrap()[0], i as f32, "entry intact after child free");
        }

        // The pinned entry refuses every eviction surface.
        assert_eq!(c.retain(entry, &[0]).unwrap_err(), PageError::PinnedSeq);
        assert_eq!(c.evict_tokens(entry, &[0]).unwrap_err(), PageError::PinnedSeq);
        assert_eq!(c.free(entry).unwrap_err(), PageError::PinnedSeq);
        assert_eq!(c.seq_len(entry), Some(6), "refused eviction mutates nothing");

        // Unpin -> the entry frees normally and every page drains.
        c.unpin_seq(entry).unwrap();
        c.free(entry).unwrap();
        assert_eq!(c.pages_in_use(), 0);
    }

    #[test]
    fn pin_unknown_seq_errors_and_unpin_is_idempotent() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(4, 2, layout);
        assert_eq!(c.pin_seq(42).unwrap_err(), PageError::UnknownSeq);
        let s = c.create_seq();
        c.pin_seq(s).unwrap();
        c.pin_seq(s).unwrap();
        c.unpin_seq(s).unwrap();
        c.unpin_seq(s).unwrap();
        assert!(!c.is_pinned(s));
        c.free(s).unwrap();
    }

    /// Page conservation: once every sequence is freed, the pages that
    /// came back equal cumulative allocations; rebuild traffic is
    /// tracked separately (the counter the session's freed-accounting
    /// test builds on).
    #[test]
    fn alloc_counters_track_appends_and_rebuilds() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(64, 2, layout);
        let s = c.create_seq();
        for i in 0..8 {
            c.append(s, &payload(layout, i as f32)).unwrap();
        }
        assert_eq!(c.pages_alloc_total(), 4);
        assert_eq!(c.pages_rebuild_total(), 0);
        c.retain(s, &[0, 3, 6]).unwrap(); // 3 tokens -> 2 rebuild pages
        assert_eq!(c.pages_alloc_total(), 6);
        assert_eq!(c.pages_rebuild_total(), 2);
        c.free(s).unwrap();
        assert_eq!(c.pages_in_use(), 0);
        // Conservation: everything allocated is back in the pool.
        assert_eq!(c.pages_free(), 64);
    }

    #[test]
    fn property_retain_preserves_kept_payloads() {
        check("paged retain compaction", 24, |g| {
            let page_size = g.usize_in(1..6);
            let layout = SlotLayout::Dense { d: 2, d_v: 1 };
            let mut c = PagedKvCache::new(256, page_size, layout);
            let s = c.create_seq();
            let len = g.usize_in(1..40);
            for i in 0..len {
                c.append(s, &payload(layout, i as f32)).unwrap();
            }
            let keep: Vec<usize> = (0..len).filter(|_| g.usize_in(0..2) == 1).collect();
            c.retain(s, &keep).unwrap();
            assert_eq!(c.seq_len(s), Some(keep.len()));
            assert_eq!(c.pages_in_use(), keep.len().div_ceil(page_size));
            for (new, &old) in keep.iter().enumerate() {
                assert_eq!(c.get(s, new).unwrap()[0], old as f32);
            }
        });
    }

    #[test]
    fn property_len_and_bytes_track_appends() {
        check("paged cache bookkeeping", 24, |g| {
            let page_size = g.usize_in(1..8);
            let layout = SlotLayout::Sparse { k: 4, d_v: 8 };
            let mut c = PagedKvCache::new(1024, page_size, layout);
            let n_seqs = g.usize_in(1..5);
            let seqs: Vec<SeqId> = (0..n_seqs).map(|_| c.create_seq()).collect();
            let mut lens = vec![0usize; n_seqs];
            for _ in 0..g.usize_in(0..64) {
                let i = g.usize_in(0..n_seqs);
                c.append(seqs[i], &vec![0.5; layout.floats_per_token()]).unwrap();
                lens[i] += 1;
            }
            let mut expect_pages = 0;
            for (i, &s) in seqs.iter().enumerate() {
                assert_eq!(c.seq_len(s), Some(lens[i]));
                expect_pages += lens[i].div_ceil(page_size);
            }
            assert_eq!(c.pages_in_use(), expect_pages);
        });
    }

    // ---- tiered-page tests (PR 10) ----

    /// A slot payload with distinct per-column values so quantization
    /// error is visible and positional mixups impossible.
    fn graded(layout: SlotLayout, tag: f32) -> Vec<f32> {
        (0..layout.floats_per_token())
            .map(|j| tag + 0.13 * j as f32 - 1.7)
            .collect()
    }

    /// Demoted pages read back within the quantization contract: each
    /// element within `scale/2` of the original (ratio <= 1), via both
    /// `slot_values` and the scratch-backed `token_slices_tiered`.
    #[test]
    fn demote_then_read_roundtrip_within_bound() {
        let layout = SlotLayout::Dense { d: 3, d_v: 2 };
        let mut c = PagedKvCache::new(8, 4, layout);
        let s = c.create_seq();
        let originals: Vec<Vec<f32>> = (0..8).map(|i| graded(layout, i as f32)).collect();
        for p in &originals {
            c.append(s, p).unwrap();
        }
        assert_eq!(c.demote_pages(s, 0).unwrap(), 2);
        assert_eq!(c.pages_demoted(), 2);
        assert_eq!(c.seq_pages_demoted(s), Some(2));
        let mut scratch = TierScratch::new();
        let slots = c.token_slices_tiered(s, &mut scratch).unwrap();
        assert_eq!(scratch.pages_buffered(), 2);
        for (i, orig) in originals.iter().enumerate() {
            let via_slot = c.slot_values(s, i).unwrap();
            let maxabs = orig.iter().fold(0f32, |a, &b| a.max(b.abs()));
            let half_step = 0.5 * maxabs / 127.0 + 1e-6;
            for ((&v, &a), &b) in orig.iter().zip(&via_slot).zip(slots[i]) {
                assert!((v - a).abs() <= half_step, "slot_values outside bound: {v} vs {a}");
                assert_eq!(a, b, "both tiered read paths must agree exactly");
            }
        }
        assert!(c.tier_max_error_ratio() <= 1.0 + 1e-4, "contract: err <= scale/2");
        assert!(c.tier_max_dequant_error() > 0.0, "graded data must quantize lossily");
    }

    /// Demotion returns budget: cold pages cost half a page, so a full
    /// cache gains headroom for new hot pages without evicting a token,
    /// and the enlarged footprint drains back to a full pool.
    #[test]
    fn demote_frees_budget_and_raises_effective_capacity() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(4, 2, layout);
        let s = c.create_seq();
        for i in 0..8 {
            c.append(s, &graded(layout, i as f32)).unwrap();
        }
        assert_eq!(c.pages_free(), 0);
        assert_eq!(c.append(s, &graded(layout, 8.0)), Err(PageError::OutOfPages));
        // Demote everything: 4 pages x 1 unit = half the budget back.
        assert_eq!(c.demote_pages(s, 0).unwrap(), 4);
        assert_eq!(c.pages_free(), 2);
        assert_eq!(c.units_in_use(), 4);
        // The freed headroom admits 4 more tokens (2 hot pages) at the
        // same max_pages — effective capacity 12 tokens vs nominal 8.
        for i in 8..12 {
            c.append(s, &graded(layout, i as f32)).unwrap();
        }
        assert_eq!(c.pages_free(), 0);
        assert_eq!(c.append(s, &graded(layout, 12.0)), Err(PageError::OutOfPages));
        assert_eq!(c.seq_len(s), Some(12));
        assert_eq!(c.pages_in_use(), 6, "physical pages legitimately exceed max_pages");
        // Old cold tokens and new hot tokens both read back.
        for i in 0..12 {
            let v = c.slot_values(s, i).unwrap();
            let orig = graded(layout, i as f32);
            let half = 0.5 * orig.iter().fold(0f32, |a, &b| a.max(b.abs())) / 127.0 + 1e-6;
            assert!((v[0] - orig[0]).abs() <= half);
        }
        // Drain: all units come back.
        c.free(s).unwrap();
        assert_eq!(c.pages_in_use(), 0);
        assert_eq!(c.units_in_use(), 0);
        assert_eq!(c.pages_free(), 4);
    }

    /// Sparse layouts carry packed u16 index pairs as raw f32 bit
    /// patterns; a demote/promote round trip must preserve those bits
    /// exactly (a quantized index would address the wrong feature).
    #[test]
    fn sparse_packed_indices_survive_demotion_bit_exactly() {
        let layout = SlotLayout::Sparse { k: 4, d_v: 3 }; // idx_cols = 2
        let mut c = PagedKvCache::new(8, 2, layout);
        let s = c.create_seq();
        let idx_bits: [u32; 2] = [0x1234_5678, 0xABCD_0001];
        let mut slots = Vec::new();
        for i in 0..4 {
            let mut p = graded(layout, i as f32);
            // Overwrite the index region (cols k..k+2) with bit patterns
            // (including a signaling-NaN-adjacent one).
            p[4] = f32::from_bits(idx_bits[0] ^ i);
            p[5] = f32::from_bits(idx_bits[1] ^ i);
            slots.push(p);
        }
        for p in &slots {
            c.append(s, p).unwrap();
        }
        assert_eq!(c.demote_pages(s, 0).unwrap(), 2);
        for (i, orig) in slots.iter().enumerate() {
            let v = c.slot_values(s, i).unwrap();
            assert_eq!(v[4].to_bits(), orig[4].to_bits(), "idx float 0 must be bit-exact");
            assert_eq!(v[5].to_bits(), orig[5].to_bits(), "idx float 1 must be bit-exact");
        }
        // Promote back in place: still bit-exact.
        assert_eq!(c.promote_pages(s).unwrap(), 2);
        assert_eq!(c.pages_demoted(), 0);
        for (i, orig) in slots.iter().enumerate() {
            let v = c.get(s, i).unwrap();
            assert_eq!(v[4].to_bits(), orig[4].to_bits());
            assert_eq!(v[5].to_bits(), orig[5].to_bits());
        }
    }

    /// Satellite 3: fork_prefix over a mixed-tier prefix — shared cold
    /// pages stay shared, an append into the shared cold tail page
    /// copy-on-writes *hot* while the parent's page stays cold, and the
    /// parent promotes back losslessly w.r.t. its own cold copy.
    #[test]
    fn fork_prefix_of_mixed_tier_prefix_and_cow_from_cold() {
        let layout = SlotLayout::Dense { d: 2, d_v: 1 };
        let mut c = PagedKvCache::new(16, 2, layout);
        let a = c.create_seq();
        for i in 0..6 {
            c.append(a, &graded(layout, i as f32)).unwrap();
        }
        // Demote the first 2 of 3 pages: mixed-tier parent.
        assert_eq!(c.demote_pages(a, 2).unwrap(), 2);
        assert_eq!(c.seq_pages_demoted(a), Some(2));
        // Fork 3 tokens: ceil(3/2) = 2 shared pages, second cold+partial.
        let b = c.fork_prefix(a, 3).unwrap();
        assert_eq!(c.pages_in_use(), 3, "fork allocates nothing");
        let parent_view: Vec<Vec<f32>> =
            (0..3).map(|i| c.slot_values(a, i).unwrap()).collect();
        // Child appends into the shared cold partial page: CoW must
        // land hot without touching the parent's cold page.
        c.append(b, &graded(layout, 42.0)).unwrap();
        assert_eq!(c.seq_pages_demoted(a), Some(2), "parent pages stay cold");
        assert_eq!(c.seq_pages_demoted(b), Some(1), "child still shares cold page 0");
        assert_eq!(c.pages_promote_total(), 1, "CoW from cold counts as a promote");
        let cow = c.slot_values(b, 3).unwrap();
        assert_eq!(cow, graded(layout, 42.0), "CoW page is hot: write is exact");
        // The child's view of the shared prefix equals the parent's.
        for (i, pv) in parent_view.iter().enumerate() {
            assert_eq!(&c.slot_values(b, i).unwrap(), pv);
        }
        // Promoting the parent reproduces its cold-read view exactly
        // (dequantization is deterministic).
        c.promote_pages(a).unwrap();
        for (i, pv) in parent_view.iter().enumerate() {
            assert_eq!(c.get(a, i).unwrap(), &pv[..]);
        }
        c.free(a).unwrap();
        c.free(b).unwrap();
        assert_eq!(c.units_in_use(), 0);
        assert_eq!(c.pages_free(), 16);
    }

    /// Satellite 3: a pinned radix-style entry demoted to int8 stays
    /// borrowable — fork_prefix works off the cold entry, reads flow
    /// through the tiered paths, eviction surfaces still refuse, and
    /// promote-on-borrow restores hot reads.
    #[test]
    fn pinned_entry_demotes_and_stays_borrowable() {
        let layout = SlotLayout::Dense { d: 2, d_v: 2 };
        let mut c = PagedKvCache::new(32, 2, layout);
        let parent = c.create_seq();
        for i in 0..6 {
            c.append(parent, &graded(layout, i as f32)).unwrap();
        }
        let entry = c.fork_prefix(parent, 6).unwrap();
        c.pin_seq(entry).unwrap();
        c.free(parent).unwrap();
        // Pinned entries demote (tiering is not eviction)...
        assert_eq!(c.demote_pages(entry, 0).unwrap(), 3);
        assert!(c.is_pinned(entry));
        // ...but still refuse every true eviction surface.
        assert_eq!(c.retain(entry, &[0]).unwrap_err(), PageError::PinnedSeq);
        assert_eq!(c.free(entry).unwrap_err(), PageError::PinnedSeq);
        // A lane can still borrow the cold entry and extend it.
        let lane = c.fork_prefix(entry, 6).unwrap();
        c.append(lane, &graded(layout, 9.0)).unwrap();
        let mut scratch = TierScratch::new();
        let slots = c.token_slices_tiered(lane, &mut scratch).unwrap();
        assert_eq!(slots.len(), 7);
        // Promote-on-borrow: the entry re-heats in place; the lane's
        // already-forked cold view is unaffected (pages are shared, so
        // the promotion re-heats the lane's prefix too).
        c.promote_pages(entry).unwrap();
        assert_eq!(c.seq_pages_demoted(entry), Some(0));
        assert_eq!(c.token_slices(entry).unwrap().len(), 6);
        c.free(lane).unwrap();
        c.unpin_seq(entry).unwrap();
        c.free(entry).unwrap();
        assert_eq!(c.units_in_use(), 0);
    }

    /// Satellite 3: the conservation law extended to tier counters —
    /// after demotes, promotes, CoW, retain, and a full drain, the pool
    /// is whole again and every counter agrees.
    #[test]
    fn tier_conservation_after_full_drain() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(16, 2, layout);
        let a = c.create_seq();
        for i in 0..8 {
            c.append(a, &graded(layout, i as f32)).unwrap();
        }
        assert_eq!(c.demote_pages(a, 2).unwrap(), 3); // pages 0-2 cold
        let b = c.fork_prefix(a, 4).unwrap(); // shares 2 cold pages
        c.append(b, &graded(layout, 77.0)).unwrap(); // fresh hot page (boundary)
        c.retain(a, &[0, 2, 5, 7]).unwrap(); // mixed-tier gather, hot rebuild
        assert_eq!(c.seq_pages_demoted(a), Some(0), "retain rebuilds hot");
        c.promote_pages(b).unwrap();
        assert_eq!(c.pages_demoted(), 0);
        let freed = c.free(a).unwrap() + c.free(b).unwrap();
        assert!(freed > 0);
        assert_eq!(c.pages_in_use(), 0);
        assert_eq!(c.units_in_use(), 0);
        assert_eq!(c.pages_free(), 16);
        assert_eq!(c.pages_demote_total(), 3);
        // b's promote_pages re-heated the 2 surviving shared cold pages.
        assert_eq!(c.pages_promote_total(), 2);
        assert!(c.tier_max_error_ratio() <= 1.0 + 1e-4);
    }

    /// Appending into an exclusively-owned cold tail page promotes it
    /// in place first — the write lands exact, older slots of that page
    /// keep their (dequantized) values.
    #[test]
    fn append_into_cold_tail_promotes_in_place() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(8, 4, layout);
        let s = c.create_seq();
        for i in 0..3 {
            c.append(s, &graded(layout, i as f32)).unwrap();
        }
        // keep_hot = 0 demotes the partial tail page too.
        assert_eq!(c.demote_pages(s, 0).unwrap(), 1);
        let cold_view: Vec<Vec<f32>> = (0..3).map(|i| c.slot_values(s, i).unwrap()).collect();
        c.append(s, &graded(layout, 3.0)).unwrap();
        assert_eq!(c.pages_demoted(), 0, "tail page promoted in place");
        assert_eq!(c.pages_promote_total(), 1);
        assert_eq!(c.get(s, 3).unwrap(), &graded(layout, 3.0)[..], "write is exact");
        for (i, cv) in cold_view.iter().enumerate() {
            assert_eq!(c.get(s, i).unwrap(), &cv[..], "promoted slots match cold reads");
        }
    }

    /// The policy-verdict path: only pages whose tokens are *all* cold
    /// transition; a page with one hot token stays hot.
    #[test]
    fn demote_token_set_requires_whole_pages() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(8, 2, layout);
        let s = c.create_seq();
        for i in 0..6 {
            c.append(s, &graded(layout, i as f32)).unwrap();
        }
        // Tokens 0,1 (page 0) and 2 (half of page 1) are cold.
        assert_eq!(c.demote_token_set(s, &[0, 1, 2]).unwrap(), 1);
        assert_eq!(c.seq_pages_demoted(s), Some(1));
        // Completing page 1's cold set demotes it; page 2 stays hot.
        assert_eq!(c.demote_token_set(s, &[2, 3]).unwrap(), 1);
        assert_eq!(c.seq_pages_demoted(s), Some(2));
        assert_eq!(c.demote_token_set(s, &[0, 1]).unwrap(), 0, "already cold");
        assert_eq!(c.demote_token_set(99, &[0]).unwrap_err(), PageError::UnknownSeq);
    }

    #[test]
    fn kv_tier_cfg_parses_and_labels() {
        let d = KvTierCfg::parse("tier").unwrap();
        assert_eq!(d, KvTierCfg { cold_after: 64, policy: TierPolicy::Lru });
        let t = KvTierCfg::parse("tier:cold_after=16,policy=h2o").unwrap();
        assert_eq!(t, KvTierCfg { cold_after: 16, policy: TierPolicy::H2o });
        assert_eq!(t.label(), "tier:cold_after=16,policy=h2o");
        assert_eq!(KvTierCfg::parse(&t.label()).unwrap(), t, "label round-trips");
        assert!(KvTierCfg::parse("tiers:cold_after=1").unwrap_err().contains("family"));
        assert!(KvTierCfg::parse("tier:cold_after=0").unwrap_err().contains(">= 1"));
        assert!(KvTierCfg::parse("tier:cold_after=x").unwrap_err().contains("integer"));
        assert!(KvTierCfg::parse("tier:policy=fifo").unwrap_err().contains("unknown policy"));
        assert!(KvTierCfg::parse("tier:budget=4").unwrap_err().contains("unknown key"));
        assert!(KvTierCfg::parse("tier:cold_after=1,cold_after=2")
            .unwrap_err()
            .contains("duplicate"));
    }

    /// Property: random append/demote/promote/free traffic never breaks
    /// the unit ledger — `units_in_use` always equals the sum of
    /// per-page costs, and a full drain restores the whole pool.
    #[test]
    fn property_tier_transitions_conserve_units() {
        check("tiered page unit ledger", 24, |g| {
            let page_size = g.usize_in(1..5);
            let layout = SlotLayout::Dense { d: 2, d_v: 1 };
            let mut c = PagedKvCache::new(64, page_size, layout);
            let n_seqs = g.usize_in(1..4);
            let mut seqs: Vec<SeqId> = (0..n_seqs).map(|_| c.create_seq()).collect();
            for step in 0..g.usize_in(1..80) {
                let i = g.usize_in(0..seqs.len());
                let s = seqs[i];
                match g.usize_in(0..6) {
                    0 | 1 | 2 => {
                        let _ = c.append(s, &graded(layout, step as f32));
                    }
                    3 => {
                        let keep_hot = g.usize_in(0..4);
                        c.demote_pages(s, keep_hot).unwrap();
                    }
                    4 => {
                        c.promote_pages(s).unwrap();
                    }
                    _ => {
                        if c.seq_len(s).unwrap() > 0 && g.usize_in(0..2) == 0 {
                            let f = c.fork_prefix(s, g.usize_in(0..c.seq_len(s).unwrap())).unwrap();
                            seqs.push(f);
                        }
                    }
                }
                let expect_units = 2 * (c.pages_in_use() - c.pages_demoted())
                    + c.pages_demoted();
                assert_eq!(c.units_in_use(), expect_units, "unit ledger out of sync");
            }
            for s in seqs {
                c.free(s).unwrap();
            }
            assert_eq!(c.pages_in_use(), 0);
            assert_eq!(c.units_in_use(), 0);
            assert_eq!(c.pages_free(), 64);
            assert!(c.tier_max_error_ratio() <= 1.0 + 1e-4);
        });
    }
}
