//! Paged KV-cache allocator (vLLM-style), with a dense and a sparse
//! (SFA top-k codes) page payload.
//!
//! The coordinator assigns each live sequence a page table; pages are
//! allocated on append and freed when the sequence finishes. Prefix
//! sharing is supported through per-page reference counts (fork).

use std::collections::{HashMap, HashSet};

/// Sequence handle.
pub type SeqId = u64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageError {
    OutOfPages,
    UnknownSeq,
    /// The sequence is pinned (a prefix-cache entry): token eviction
    /// and free are refused until it is unpinned.
    PinnedSeq,
}

impl std::fmt::Display for PageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PageError::OutOfPages => write!(f, "paged KV cache is out of pages"),
            PageError::UnknownSeq => write!(f, "unknown KV-cache sequence id"),
            PageError::PinnedSeq => {
                write!(f, "sequence is pinned by a prefix cache (unpin before evicting)")
            }
        }
    }
}

impl std::error::Error for PageError {}

/// Payload layout of one token slot inside a page.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotLayout {
    /// Dense K (d) + dense V (d_v) floats.
    Dense { d: usize, d_v: usize },
    /// SFA: k key values + k key indices + dense V.
    Sparse { k: usize, d_v: usize },
}

impl SlotLayout {
    /// f32/u16 payload floats-equivalent per token (indices packed two
    /// per float slot for accounting purposes).
    pub fn floats_per_token(&self) -> usize {
        match *self {
            SlotLayout::Dense { d, d_v } => d + d_v,
            SlotLayout::Sparse { k, d_v } => k + k.div_ceil(2) + d_v,
        }
    }
}

/// A paged KV cache for one layer-head group.
#[derive(Debug)]
pub struct PagedKvCache {
    pub page_size: usize,
    pub layout: SlotLayout,
    /// Backing store: one Vec<f32> per page (allocated lazily).
    pages: Vec<Vec<f32>>,
    free_list: Vec<u32>,
    ref_counts: Vec<u32>,
    /// seq -> (page ids, token count)
    tables: HashMap<SeqId, (Vec<u32>, usize)>,
    /// Sequences pinned out of `retain`/`evict_tokens`/`free` (prefix
    /// cache entries — see [`crate::kv_cache::radix`]).
    pinned: HashSet<SeqId>,
    next_seq: SeqId,
    max_pages: usize,
    /// Cumulative successful page allocations (appends + rebuilds).
    alloc_total: usize,
    /// Cumulative pages consumed by `retain` rebuilds — the share of
    /// `alloc_total` that is compaction traffic, not new tokens.
    rebuild_total: usize,
}

impl PagedKvCache {
    pub fn new(max_pages: usize, page_size: usize, layout: SlotLayout) -> Self {
        PagedKvCache {
            page_size,
            layout,
            pages: Vec::new(),
            free_list: Vec::new(),
            ref_counts: Vec::new(),
            tables: HashMap::new(),
            pinned: HashSet::new(),
            next_seq: 0,
            max_pages,
            alloc_total: 0,
            rebuild_total: 0,
        }
    }

    fn alloc_page(&mut self) -> Result<u32, PageError> {
        if let Some(p) = self.free_list.pop() {
            self.ref_counts[p as usize] = 1;
            self.alloc_total += 1;
            return Ok(p);
        }
        if self.pages.len() >= self.max_pages {
            return Err(PageError::OutOfPages);
        }
        let id = self.pages.len() as u32;
        self.pages
            .push(vec![0.0; self.page_size * self.layout.floats_per_token()]);
        self.ref_counts.push(1);
        self.alloc_total += 1;
        Ok(id)
    }

    /// Register a new sequence; returns its handle.
    pub fn create_seq(&mut self) -> SeqId {
        let id = self.next_seq;
        self.next_seq += 1;
        self.tables.insert(id, (Vec::new(), 0));
        id
    }

    /// Append one token's payload; allocates a page on boundary crossing.
    pub fn append(&mut self, seq: SeqId, payload: &[f32]) -> Result<(), PageError> {
        let fpt = self.layout.floats_per_token();
        assert_eq!(payload.len(), fpt, "payload must match layout");
        // Determine state first (split borrows around alloc_page).
        let (n_pages, len) = {
            let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
            (table.len(), *len)
        };
        let slot = len % self.page_size;
        let page_id = if slot == 0 {
            let p = self.alloc_page()?;
            let (table, _) = self.tables.get_mut(&seq).unwrap();
            table.push(p);
            p
        } else {
            let (table, _) = self.tables.get(&seq).unwrap();
            table[n_pages - 1]
        };
        // Copy-on-write if the page is shared.
        let page_id = if self.ref_counts[page_id as usize] > 1 {
            let copy = self.alloc_page()?;
            self.ref_counts[page_id as usize] -= 1;
            let src = self.pages[page_id as usize].clone();
            self.pages[copy as usize].copy_from_slice(&src);
            let (table, _) = self.tables.get_mut(&seq).unwrap();
            *table.last_mut().unwrap() = copy;
            copy
        } else {
            page_id
        };
        let page = &mut self.pages[page_id as usize];
        page[slot * fpt..(slot + 1) * fpt].copy_from_slice(payload);
        let (_, len) = self.tables.get_mut(&seq).unwrap();
        *len += 1;
        Ok(())
    }

    /// Read one token slot.
    pub fn get(&self, seq: SeqId, pos: usize) -> Result<&[f32], PageError> {
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        assert!(pos < *len, "pos {pos} >= len {len}");
        let fpt = self.layout.floats_per_token();
        let page = table[pos / self.page_size];
        let slot = pos % self.page_size;
        Ok(&self.pages[page as usize][slot * fpt..(slot + 1) * fpt])
    }

    /// Borrow every token slot of a sequence in order, one slice per
    /// token — the decode path's scan view (attention sessions walk the
    /// whole cached sequence per step).
    pub fn token_slices(&self, seq: SeqId) -> Result<Vec<&[f32]>, PageError> {
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        let fpt = self.layout.floats_per_token();
        let mut out = Vec::with_capacity(*len);
        for pos in 0..*len {
            let page = table[pos / self.page_size] as usize;
            let slot = pos % self.page_size;
            out.push(&self.pages[page][slot * fpt..(slot + 1) * fpt]);
        }
        Ok(out)
    }

    /// Fork a sequence sharing all current pages (prefix caching).
    pub fn fork(&mut self, seq: SeqId) -> Result<SeqId, PageError> {
        let len = self.seq_len(seq).ok_or(PageError::UnknownSeq)?;
        self.fork_prefix(seq, len)
    }

    /// Fork only the first `n_tokens` of a sequence: the new sequence
    /// shares the `⌈n_tokens / page_size⌉` pages covering that prefix
    /// (refcounted — never copied). A partially filled last page is
    /// shared too: its beyond-prefix slots are unreachable (reads are
    /// length-bounded) and the first append into it copy-on-writes
    /// while the page is shared. This is the radix prefix cache's hit
    /// path: seed a lane with a cached prompt prefix, then append only
    /// the suffix.
    pub fn fork_prefix(&mut self, seq: SeqId, n_tokens: usize) -> Result<SeqId, PageError> {
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        assert!(n_tokens <= *len, "fork_prefix of {n_tokens} tokens from a {len}-token seq");
        let shared = n_tokens.div_ceil(self.page_size);
        let table: Vec<u32> = table[..shared].to_vec();
        for &p in &table {
            self.ref_counts[p as usize] += 1;
        }
        let id = self.next_seq;
        self.next_seq += 1;
        self.tables.insert(id, (table, n_tokens));
        Ok(id)
    }

    /// Pin a sequence: `retain`/`evict_tokens`/`free` refuse it until
    /// [`PagedKvCache::unpin_seq`]. The radix prefix cache pins its
    /// entries so no eviction path can prune pages a cached prefix
    /// still references.
    pub fn pin_seq(&mut self, seq: SeqId) -> Result<(), PageError> {
        if !self.tables.contains_key(&seq) {
            return Err(PageError::UnknownSeq);
        }
        self.pinned.insert(seq);
        Ok(())
    }

    /// Remove a sequence's pin (no-op when not pinned).
    pub fn unpin_seq(&mut self, seq: SeqId) -> Result<(), PageError> {
        if !self.tables.contains_key(&seq) {
            return Err(PageError::UnknownSeq);
        }
        self.pinned.remove(&seq);
        Ok(())
    }

    pub fn is_pinned(&self, seq: SeqId) -> bool {
        self.pinned.contains(&seq)
    }

    /// Free a sequence, returning pages whose refcount drops to zero.
    /// Pinned sequences are refused ([`PageError::PinnedSeq`]) — unpin
    /// first, so a prefix-cache entry can't be dropped by accident.
    pub fn free(&mut self, seq: SeqId) -> Result<usize, PageError> {
        if self.pinned.contains(&seq) {
            return Err(PageError::PinnedSeq);
        }
        let (table, _) = self.tables.remove(&seq).ok_or(PageError::UnknownSeq)?;
        let mut freed = 0;
        for p in table {
            let rc = &mut self.ref_counts[p as usize];
            *rc -= 1;
            if *rc == 0 {
                self.free_list.push(p);
                freed += 1;
            }
        }
        Ok(freed)
    }

    /// Retain only the token positions in `keep` (strictly ascending,
    /// all `< seq_len`), compacting the surviving payloads to the front
    /// of the sequence; token `keep[i]` becomes token `i`. Pages whose
    /// last reference drops go back to the pool. Pages shared with a
    /// fork are never mutated (copy-on-evict): the sequence is rebuilt
    /// onto exclusively-owned pages, so forks keep reading the original
    /// data. Returns how many pages the call returned to the
    /// allocatable budget (0 when the rebuild consumed as many fresh
    /// pages as it released, which can happen under heavy sharing).
    ///
    /// Fails with [`PageError::OutOfPages`] — leaving the sequence
    /// untouched — only when every surviving page is fork-shared *and*
    /// the pool has no headroom for the rebuilt copies.
    pub fn retain(&mut self, seq: SeqId, keep: &[usize]) -> Result<usize, PageError> {
        if self.pinned.contains(&seq) {
            return Err(PageError::PinnedSeq);
        }
        let fpt = self.layout.floats_per_token();
        let (table, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?.clone();
        for w in keep.windows(2) {
            assert!(w[0] < w[1], "keep positions must be strictly ascending");
        }
        if let Some(&last) = keep.last() {
            assert!(last < len, "keep position {last} >= len {len}");
        }
        if keep.len() == len {
            return Ok(0); // ascending + in-range + full length == identity
        }
        let free_before = self.pages_free();
        // Feasibility before mutating anything: the rebuild needs
        // `new_pages` allocations, fed by the pool plus whatever this
        // sequence exclusively owns (shared pages only drop a ref).
        let new_pages = keep.len().div_ceil(self.page_size);
        let reclaimable =
            table.iter().filter(|&&p| self.ref_counts[p as usize] == 1).count();
        if new_pages > self.pages_free() + reclaimable {
            return Err(PageError::OutOfPages);
        }
        // Gather the surviving payloads, release the old table, rebuild.
        let mut kept: Vec<f32> = Vec::with_capacity(keep.len() * fpt);
        for &pos in keep {
            let page = table[pos / self.page_size] as usize;
            let slot = pos % self.page_size;
            kept.extend_from_slice(&self.pages[page][slot * fpt..(slot + 1) * fpt]);
        }
        for &p in &table {
            let rc = &mut self.ref_counts[p as usize];
            *rc -= 1;
            if *rc == 0 {
                self.free_list.push(p);
            }
        }
        let mut new_table = Vec::with_capacity(new_pages);
        for _ in 0..new_pages {
            new_table.push(self.alloc_page().expect("feasibility checked above"));
        }
        self.rebuild_total += new_pages;
        for (i, chunk) in kept.chunks(self.page_size * fpt).enumerate() {
            self.pages[new_table[i] as usize][..chunk.len()].copy_from_slice(chunk);
        }
        *self.tables.get_mut(&seq).unwrap() = (new_table, keep.len());
        Ok(self.pages_free().saturating_sub(free_before))
    }

    /// Evict the token positions in `drop` (any order, duplicates
    /// ignored), keeping everything else — the complement convenience
    /// over [`PagedKvCache::retain`].
    pub fn evict_tokens(&mut self, seq: SeqId, drop: &[usize]) -> Result<usize, PageError> {
        let (_, len) = self.tables.get(&seq).ok_or(PageError::UnknownSeq)?;
        let len = *len;
        let mut dropped = vec![false; len];
        for &pos in drop {
            assert!(pos < len, "drop position {pos} >= len {len}");
            dropped[pos] = true;
        }
        let keep: Vec<usize> = (0..len).filter(|&i| !dropped[i]).collect();
        self.retain(seq, &keep)
    }

    pub fn seq_len(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|(_, l)| *l)
    }

    /// Pages currently mapped by one sequence's page table.
    pub fn seq_pages(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|(t, _)| t.len())
    }

    /// Hard page cap this cache was constructed with.
    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    /// Pages still allocatable before [`PageError::OutOfPages`]: the
    /// recycled free list plus the never-allocated headroom below the
    /// cap.
    pub fn pages_free(&self) -> usize {
        self.free_list.len() + (self.max_pages - self.pages.len())
    }

    pub fn pages_in_use(&self) -> usize {
        self.pages.len() - self.free_list.len()
    }

    pub fn bytes_in_use(&self) -> usize {
        self.pages_in_use() * self.page_size * self.layout.floats_per_token() * 4
    }

    /// Cumulative successful page allocations over the cache's life
    /// (appends and `retain` rebuilds alike). With
    /// [`PagedKvCache::pages_rebuild_total`] this gives the page
    /// conservation law the session accounting tests pin: once every
    /// sequence is freed, `net frees == alloc_total - rebuild_total`.
    pub fn pages_alloc_total(&self) -> usize {
        self.alloc_total
    }

    /// Cumulative pages consumed by `retain`/`evict_tokens` rebuilds.
    pub fn pages_rebuild_total(&self) -> usize {
        self.rebuild_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn payload(layout: SlotLayout, tag: f32) -> Vec<f32> {
        vec![tag; layout.floats_per_token()]
    }

    #[test]
    fn append_and_get_roundtrip() {
        let layout = SlotLayout::Dense { d: 4, d_v: 4 };
        let mut c = PagedKvCache::new(16, 4, layout);
        let s = c.create_seq();
        for i in 0..10 {
            c.append(s, &payload(layout, i as f32)).unwrap();
        }
        assert_eq!(c.seq_len(s), Some(10));
        for i in 0..10 {
            assert_eq!(c.get(s, i).unwrap()[0], i as f32);
        }
        assert_eq!(c.pages_in_use(), 3); // ceil(10/4)
    }

    #[test]
    fn out_of_pages_reported() {
        let layout = SlotLayout::Dense { d: 2, d_v: 2 };
        let mut c = PagedKvCache::new(2, 2, layout);
        let s = c.create_seq();
        for _ in 0..4 {
            c.append(s, &payload(layout, 0.0)).unwrap();
        }
        assert_eq!(c.append(s, &payload(layout, 0.0)), Err(PageError::OutOfPages));
    }

    #[test]
    fn free_recycles_pages() {
        let layout = SlotLayout::Dense { d: 2, d_v: 2 };
        let mut c = PagedKvCache::new(2, 2, layout);
        let s = c.create_seq();
        for _ in 0..4 {
            c.append(s, &payload(layout, 1.0)).unwrap();
        }
        assert_eq!(c.free(s).unwrap(), 2);
        assert_eq!(c.pages_in_use(), 0);
        let s2 = c.create_seq();
        for _ in 0..4 {
            c.append(s2, &payload(layout, 2.0)).unwrap();
        }
        assert_eq!(c.get(s2, 3).unwrap()[0], 2.0);
    }

    #[test]
    fn fork_shares_then_copies_on_write() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(8, 2, layout);
        let a = c.create_seq();
        c.append(a, &payload(layout, 1.0)).unwrap();
        let b = c.fork(a).unwrap();
        assert_eq!(c.pages_in_use(), 1, "fork shares pages");
        // Appending to the fork must not disturb the parent (CoW).
        c.append(b, &payload(layout, 9.0)).unwrap();
        c.append(a, &payload(layout, 5.0)).unwrap();
        assert_eq!(c.get(a, 1).unwrap()[0], 5.0);
        assert_eq!(c.get(b, 1).unwrap()[0], 9.0);
        assert_eq!(c.get(b, 0).unwrap()[0], 1.0);
    }

    #[test]
    fn sparse_layout_is_smaller() {
        let dense = SlotLayout::Dense { d: 64, d_v: 64 };
        let sparse = SlotLayout::Sparse { k: 8, d_v: 64 };
        assert!(sparse.floats_per_token() < dense.floats_per_token());
        // App-J shape: K-payload shrinks from d to ~1.5k.
        assert_eq!(sparse.floats_per_token(), 8 + 4 + 64);
    }

    #[test]
    fn token_slices_walk_in_order() {
        let layout = SlotLayout::Dense { d: 2, d_v: 1 };
        let mut c = PagedKvCache::new(16, 3, layout);
        let s = c.create_seq();
        for i in 0..7 {
            c.append(s, &payload(layout, i as f32)).unwrap();
        }
        let slots = c.token_slices(s).unwrap();
        assert_eq!(slots.len(), 7);
        for (i, sl) in slots.iter().enumerate() {
            assert_eq!(sl.len(), layout.floats_per_token());
            assert_eq!(sl[0], i as f32);
        }
        assert_eq!(c.token_slices(99).unwrap_err(), PageError::UnknownSeq);
    }

    #[test]
    fn page_budget_accounting() {
        let layout = SlotLayout::Dense { d: 2, d_v: 2 };
        let mut c = PagedKvCache::new(4, 2, layout);
        assert_eq!(c.max_pages(), 4);
        assert_eq!(c.pages_free(), 4);
        let s = c.create_seq();
        for _ in 0..3 {
            c.append(s, &payload(layout, 1.0)).unwrap();
        }
        assert_eq!(c.seq_pages(s), Some(2));
        assert_eq!(c.pages_free(), 2);
        assert_eq!(c.pages_in_use() + c.pages_free(), c.max_pages());
        c.free(s).unwrap();
        // Recycled pages return to the allocatable budget.
        assert_eq!(c.pages_free(), 4);
        assert_eq!(c.seq_pages(s), None);
    }

    #[test]
    fn unknown_seq_errors() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(2, 2, layout);
        assert_eq!(c.free(42), Err(PageError::UnknownSeq));
        assert_eq!(
            c.append(42, &payload(layout, 0.0)),
            Err(PageError::UnknownSeq)
        );
    }

    #[test]
    fn retain_compacts_and_frees_pages() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(16, 4, layout);
        let s = c.create_seq();
        for i in 0..12 {
            c.append(s, &payload(layout, i as f32)).unwrap();
        }
        assert_eq!(c.pages_in_use(), 3);
        // Keep every third token: 12 -> 4 tokens -> 1 page.
        let freed = c.retain(s, &[0, 3, 6, 9]).unwrap();
        assert_eq!(freed, 2);
        assert_eq!(c.seq_len(s), Some(4));
        assert_eq!(c.pages_in_use(), 1);
        for (new, old) in [0usize, 3, 6, 9].iter().enumerate() {
            assert_eq!(c.get(s, new).unwrap()[0], *old as f32);
        }
        // Appends continue from the compacted tail.
        c.append(s, &payload(layout, 99.0)).unwrap();
        assert_eq!(c.seq_len(s), Some(5));
        assert_eq!(c.get(s, 4).unwrap()[0], 99.0);
        assert_eq!(c.pages_in_use(), 2);
        // Identity retain is a no-op; empty retain drops everything.
        assert_eq!(c.retain(s, &[0, 1, 2, 3, 4]).unwrap(), 0);
        assert_eq!(c.retain(s, &[]).unwrap(), 2);
        assert_eq!(c.seq_len(s), Some(0));
        assert_eq!(c.pages_in_use(), 0);
    }

    #[test]
    fn evict_tokens_is_the_retain_complement() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(16, 2, layout);
        let s = c.create_seq();
        for i in 0..6 {
            c.append(s, &payload(layout, i as f32)).unwrap();
        }
        c.evict_tokens(s, &[4, 1, 1]).unwrap();
        assert_eq!(c.seq_len(s), Some(4));
        for (new, old) in [0usize, 2, 3, 5].iter().enumerate() {
            assert_eq!(c.get(s, new).unwrap()[0], *old as f32);
        }
        assert_eq!(c.evict_tokens(99, &[]).unwrap_err(), PageError::UnknownSeq);
    }

    /// Regression (fork × eviction): a fork sharing the parent's pages
    /// must survive both the parent's `retain` (copy-on-evict — shared
    /// pages are never rewritten) and the parent's `free`, and the
    /// refcounted pages must come back only when *both* sides are gone.
    #[test]
    fn forked_seq_survives_parent_eviction_and_free() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(16, 2, layout);
        let a = c.create_seq();
        for i in 0..6 {
            c.append(a, &payload(layout, i as f32)).unwrap();
        }
        let b = c.fork(a).unwrap();
        assert_eq!(c.pages_in_use(), 3, "fork shares all pages");
        // Parent prunes hard: shared pages must not be mutated in place.
        let freed = c.retain(a, &[0, 5]).unwrap();
        assert_eq!(freed, 0, "shared pages only dropped a ref; 1 fresh page consumed");
        assert_eq!(c.seq_len(a), Some(2));
        assert_eq!(c.get(a, 0).unwrap()[0], 0.0);
        assert_eq!(c.get(a, 1).unwrap()[0], 5.0);
        // The fork still reads the full original stream.
        assert_eq!(c.seq_len(b), Some(6));
        for i in 0..6 {
            assert_eq!(c.get(b, i).unwrap()[0], i as f32, "fork data intact");
        }
        // Parent release keeps the fork alive; fork release empties it.
        c.free(a).unwrap();
        for i in 0..6 {
            assert_eq!(c.get(b, i).unwrap()[0], i as f32);
        }
        c.free(b).unwrap();
        assert_eq!(c.pages_in_use(), 0, "all refcounts drained");
        assert_eq!(c.pages_free(), 16);
    }

    /// With every page fork-shared and zero pool headroom, a rebuild
    /// has nowhere to put the copies: retain must fail cleanly and
    /// leave the sequence untouched.
    #[test]
    fn retain_on_fully_shared_pages_without_headroom_errors() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(2, 2, layout);
        let a = c.create_seq();
        for i in 0..4 {
            c.append(a, &payload(layout, i as f32)).unwrap();
        }
        let b = c.fork(a).unwrap();
        assert_eq!(c.retain(a, &[0, 2]).unwrap_err(), PageError::OutOfPages);
        assert_eq!(c.seq_len(a), Some(4), "failed retain mutates nothing");
        for i in 0..4 {
            assert_eq!(c.get(a, i).unwrap()[0], i as f32);
            assert_eq!(c.get(b, i).unwrap()[0], i as f32);
        }
        // Once the fork releases its references the same retain fits.
        c.free(b).unwrap();
        c.retain(a, &[0, 2]).unwrap();
        assert_eq!(c.seq_len(a), Some(2));
        assert_eq!(c.get(a, 1).unwrap()[0], 2.0);
    }

    /// fork_prefix shares only the pages covering the prefix; the fork
    /// reads exactly the prefix, survives the parent's mutation of its
    /// own tail (CoW on the shared partial page), and appends continue
    /// from the prefix without disturbing the parent.
    #[test]
    fn fork_prefix_shares_prefix_pages_only() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(32, 4, layout);
        let a = c.create_seq();
        for i in 0..10 {
            c.append(a, &payload(layout, i as f32)).unwrap();
        }
        assert_eq!(c.pages_in_use(), 3);
        // Prefix of 6 tokens covers ceil(6/4) = 2 pages, page 1 partial.
        let b = c.fork_prefix(a, 6).unwrap();
        assert_eq!(c.pages_in_use(), 3, "fork_prefix allocates nothing");
        assert_eq!(c.seq_len(b), Some(6));
        for i in 0..6 {
            assert_eq!(c.get(b, i).unwrap()[0], i as f32);
        }
        // Appending token 6 to the fork lands in the shared partial
        // page -> copy-on-write; the parent's token 6 is untouched.
        c.append(b, &payload(layout, 99.0)).unwrap();
        assert_eq!(c.get(b, 6).unwrap()[0], 99.0);
        assert_eq!(c.get(a, 6).unwrap()[0], 6.0);
        assert_eq!(c.pages_in_use(), 4, "CoW consumed one fresh page");
        // Parent release keeps the shared prefix alive for the fork.
        c.free(a).unwrap();
        for i in 0..6 {
            assert_eq!(c.get(b, i).unwrap()[0], i as f32);
        }
        c.free(b).unwrap();
        assert_eq!(c.pages_in_use(), 0);
    }

    #[test]
    fn fork_prefix_at_page_boundary_and_full_length() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(32, 4, layout);
        let a = c.create_seq();
        for i in 0..8 {
            c.append(a, &payload(layout, i as f32)).unwrap();
        }
        let b = c.fork_prefix(a, 4).unwrap();
        // Boundary prefix: the fork's next append opens a fresh page,
        // no CoW needed.
        c.append(b, &payload(layout, 50.0)).unwrap();
        assert_eq!(c.get(b, 4).unwrap()[0], 50.0);
        assert_eq!(c.get(a, 4).unwrap()[0], 4.0);
        // Full-length fork_prefix == fork.
        let full = c.fork_prefix(a, 8).unwrap();
        assert_eq!(c.seq_len(full), Some(8));
        let empty = c.fork_prefix(a, 0).unwrap();
        assert_eq!(c.seq_len(empty), Some(0));
    }

    /// Satellite regression (fork-pin × eviction): a prefix pinned by
    /// the radix cache must survive a child's `retain`/`evict_tokens`
    /// and a child release — and the pinned sequence itself refuses
    /// every eviction surface until unpinned.
    #[test]
    fn pinned_prefix_survives_child_retain_evict_and_release() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(64, 2, layout);
        // Build the "cached prefix" and pin it (what RadixPrefixCache
        // does at insert).
        let parent = c.create_seq();
        for i in 0..6 {
            c.append(parent, &payload(layout, i as f32)).unwrap();
        }
        let entry = c.fork_prefix(parent, 6).unwrap();
        c.pin_seq(entry).unwrap();
        assert!(c.is_pinned(entry));
        c.free(parent).unwrap();

        // A child forks the cached prefix and lives its own life.
        let child = c.fork_prefix(entry, 6).unwrap();
        for i in 6..10 {
            c.append(child, &payload(layout, i as f32)).unwrap();
        }
        // Child prunes hard (KV policy): the entry's pages only drop a
        // ref (copy-on-evict), never mutate.
        c.evict_tokens(child, &[0, 1, 2, 3, 4, 6, 8]).unwrap();
        assert_eq!(c.seq_len(child), Some(3));
        for i in 0..6 {
            assert_eq!(c.get(entry, i).unwrap()[0], i as f32, "entry intact after child prune");
        }
        // Child release: entry still intact.
        c.free(child).unwrap();
        for i in 0..6 {
            assert_eq!(c.get(entry, i).unwrap()[0], i as f32, "entry intact after child free");
        }

        // The pinned entry refuses every eviction surface.
        assert_eq!(c.retain(entry, &[0]).unwrap_err(), PageError::PinnedSeq);
        assert_eq!(c.evict_tokens(entry, &[0]).unwrap_err(), PageError::PinnedSeq);
        assert_eq!(c.free(entry).unwrap_err(), PageError::PinnedSeq);
        assert_eq!(c.seq_len(entry), Some(6), "refused eviction mutates nothing");

        // Unpin -> the entry frees normally and every page drains.
        c.unpin_seq(entry).unwrap();
        c.free(entry).unwrap();
        assert_eq!(c.pages_in_use(), 0);
    }

    #[test]
    fn pin_unknown_seq_errors_and_unpin_is_idempotent() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(4, 2, layout);
        assert_eq!(c.pin_seq(42).unwrap_err(), PageError::UnknownSeq);
        let s = c.create_seq();
        c.pin_seq(s).unwrap();
        c.pin_seq(s).unwrap();
        c.unpin_seq(s).unwrap();
        c.unpin_seq(s).unwrap();
        assert!(!c.is_pinned(s));
        c.free(s).unwrap();
    }

    /// Page conservation: once every sequence is freed, the pages that
    /// came back equal cumulative allocations; rebuild traffic is
    /// tracked separately (the counter the session's freed-accounting
    /// test builds on).
    #[test]
    fn alloc_counters_track_appends_and_rebuilds() {
        let layout = SlotLayout::Dense { d: 1, d_v: 1 };
        let mut c = PagedKvCache::new(64, 2, layout);
        let s = c.create_seq();
        for i in 0..8 {
            c.append(s, &payload(layout, i as f32)).unwrap();
        }
        assert_eq!(c.pages_alloc_total(), 4);
        assert_eq!(c.pages_rebuild_total(), 0);
        c.retain(s, &[0, 3, 6]).unwrap(); // 3 tokens -> 2 rebuild pages
        assert_eq!(c.pages_alloc_total(), 6);
        assert_eq!(c.pages_rebuild_total(), 2);
        c.free(s).unwrap();
        assert_eq!(c.pages_in_use(), 0);
        // Conservation: everything allocated is back in the pool.
        assert_eq!(c.pages_free(), 64);
    }

    #[test]
    fn property_retain_preserves_kept_payloads() {
        check("paged retain compaction", 24, |g| {
            let page_size = g.usize_in(1..6);
            let layout = SlotLayout::Dense { d: 2, d_v: 1 };
            let mut c = PagedKvCache::new(256, page_size, layout);
            let s = c.create_seq();
            let len = g.usize_in(1..40);
            for i in 0..len {
                c.append(s, &payload(layout, i as f32)).unwrap();
            }
            let keep: Vec<usize> = (0..len).filter(|_| g.usize_in(0..2) == 1).collect();
            c.retain(s, &keep).unwrap();
            assert_eq!(c.seq_len(s), Some(keep.len()));
            assert_eq!(c.pages_in_use(), keep.len().div_ceil(page_size));
            for (new, &old) in keep.iter().enumerate() {
                assert_eq!(c.get(s, new).unwrap()[0], old as f32);
            }
        });
    }

    #[test]
    fn property_len_and_bytes_track_appends() {
        check("paged cache bookkeeping", 24, |g| {
            let page_size = g.usize_in(1..8);
            let layout = SlotLayout::Sparse { k: 4, d_v: 8 };
            let mut c = PagedKvCache::new(1024, page_size, layout);
            let n_seqs = g.usize_in(1..5);
            let seqs: Vec<SeqId> = (0..n_seqs).map(|_| c.create_seq()).collect();
            let mut lens = vec![0usize; n_seqs];
            for _ in 0..g.usize_in(0..64) {
                let i = g.usize_in(0..n_seqs);
                c.append(seqs[i], &vec![0.5; layout.floats_per_token()]).unwrap();
                lens[i] += 1;
            }
            let mut expect_pages = 0;
            for (i, &s) in seqs.iter().enumerate() {
                assert_eq!(c.seq_len(s), Some(lens[i]));
                expect_pages += lens[i].div_ceil(page_size);
            }
            assert_eq!(c.pages_in_use(), expect_pages);
        });
    }
}
