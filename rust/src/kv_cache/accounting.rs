//! Whole-model KV-cache byte accounting (Fig. 1b "41% KV reduction",
//! Fig. 5 memory curves). Mirrors `python/compile/model.py::
//! kv_cache_bytes` so L2 and L3 agree on the memory story.

use crate::sparse::memory::{csr_bytes, dense_bytes, Widths};

/// Model-level shape parameters needed for cache accounting.
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// Per-head Q/K dim (differs from d_head for "short" baselines).
    pub qk_dim: usize,
    /// SFA sparsity (None = dense cache).
    pub sparsity: Option<usize>,
}

impl CacheConfig {
    /// Total K+V cache bytes at context `seq` for `batch` sequences.
    pub fn bytes(&self, seq: usize, batch: usize, w: Widths) -> usize {
        let per_head_v = dense_bytes(seq, self.d_head, w);
        let per_head_k = match self.sparsity {
            Some(k) => csr_bytes(seq, k, w),
            None => dense_bytes(seq, self.qk_dim, w),
        };
        self.n_layers * self.n_heads * batch * (per_head_k + per_head_v)
    }

    /// Fractional saving vs a dense config with the same architecture.
    pub fn saving_vs_dense(&self, seq: usize, w: Widths) -> f64 {
        let dense = CacheConfig { sparsity: None, qk_dim: self.d_head, ..*self };
        1.0 - self.bytes(seq, 1, w) as f64 / dense.bytes(seq, 1, w) as f64
    }

    /// Max context length that fits in `budget` bytes (batch 1) — the
    /// "orders of magnitude longer context" claim quantified (§3.1).
    pub fn max_context_for_budget(&self, budget: usize, w: Widths) -> usize {
        // bytes() is linear in seq up to the +1 indptr term; solve directly.
        let per_tok = self.bytes(4096, 1, w).saturating_sub(self.bytes(2048, 1, w)) as f64
            / 2048.0;
        (budget as f64 / per_tok) as usize
    }

    /// Total K+V cache bytes when a `cold_fraction` of the context sits
    /// in the int8 cold tier (the serve stack's page demotion): cold
    /// value bytes halve (f16 payload → int8 codes + one f32 scale per
    /// row, amortized over `d_head` elements), while index/structure
    /// bytes (CSR indices, indptr) stay full width — exactly how
    /// `PagePayload::Int8` keeps SFA's packed index pairs verbatim.
    /// `cold_fraction: 0.0` is bit-identical to [`Self::bytes`].
    pub fn bytes_tiered(
        &self,
        seq: usize,
        batch: usize,
        w: Widths,
        cold_fraction: f64,
    ) -> usize {
        debug_assert!((0.0..=1.0).contains(&cold_fraction));
        let full = self.bytes(seq, batch, w);
        let cold_seq = (seq as f64 * cold_fraction) as usize;
        if cold_seq == 0 {
            return full;
        }
        // Value-payload bytes of the cold span: these are what the
        // int8 tier halves. Per row: d_head values (V) plus k sparse
        // values (SFA K) or qk_dim values (dense K).
        let value_elems_per_tok = self.d_head
            + match self.sparsity {
                Some(k) => k,
                None => self.qk_dim,
            };
        let cold_value_bytes =
            self.n_layers * self.n_heads * batch * cold_seq * value_elems_per_tok * w.s_val;
        // int8 code (1 byte) per element + one f32 scale per quantized
        // row; each token contributes two rows (one K, one V).
        let tiered_value_bytes = self.n_layers
            * self.n_heads
            * batch
            * cold_seq
            * (value_elems_per_tok + 2 * 4);
        full - cold_value_bytes + tiered_value_bytes.min(cold_value_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qwen_like(sparsity: Option<usize>) -> CacheConfig {
        CacheConfig { n_layers: 28, n_heads: 8, d_head: 128, qk_dim: 128, sparsity }
    }

    #[test]
    fn sfa_saves_about_forty_percent_at_default_config() {
        // Paper Fig. 1b: ~41% KV reduction at d=128, k=16 (fp16/int8).
        let s = qwen_like(Some(16)).saving_vs_dense(131072, Widths::PAPER);
        assert!((0.35..0.45).contains(&s), "saving {s}");
    }

    #[test]
    fn saving_grows_as_k_shrinks() {
        let w = Widths::PAPER;
        let s16 = qwen_like(Some(16)).saving_vs_dense(8192, w);
        let s8 = qwen_like(Some(8)).saving_vs_dense(8192, w);
        let s4 = qwen_like(Some(4)).saving_vs_dense(8192, w);
        assert!(s4 > s8 && s8 > s16);
    }

    #[test]
    fn max_context_extends_with_sparsity() {
        let w = Widths::PAPER;
        let budget = 8 << 30; // 8 GiB
        let dense_ctx = qwen_like(None).max_context_for_budget(budget, w);
        let sfa_ctx = qwen_like(Some(16)).max_context_for_budget(budget, w);
        assert!(sfa_ctx as f64 > 1.5 * dense_ctx as f64,
                "{sfa_ctx} vs {dense_ctx}");
    }

    #[test]
    fn tiered_bytes_shrink_monotonically_with_cold_fraction() {
        let cfg = qwen_like(Some(16));
        let w = Widths::PAPER;
        let seq = 8192;
        // No cold pages -> identical to the flat accounting.
        assert_eq!(cfg.bytes_tiered(seq, 1, w, 0.0), cfg.bytes(seq, 1, w));
        let mut prev = cfg.bytes_tiered(seq, 1, w, 0.0);
        for cf in [0.25, 0.5, 0.75, 1.0] {
            let b = cfg.bytes_tiered(seq, 1, w, cf);
            assert!(b < prev, "cold_fraction {cf}: {b} !< {prev}");
            prev = b;
        }
        // Fully cold at fp16 widths: value payload roughly halves,
        // CSR index/indptr bytes are untouched.
        let ratio = cfg.bytes_tiered(seq, 1, w, 1.0) as f64 / cfg.bytes(seq, 1, w) as f64;
        assert!((0.5..0.65).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn bytes_scale_linearly_in_batch_and_layers() {
        let cfg = qwen_like(Some(8));
        let w = Widths::OURS;
        assert_eq!(cfg.bytes(1024, 4, w), 4 * cfg.bytes(1024, 1, w));
        let half = CacheConfig { n_layers: 14, ..cfg };
        assert_eq!(cfg.bytes(1024, 1, w), 2 * half.bytes(1024, 1, w));
    }
}
