//! Whole-model KV-cache byte accounting (Fig. 1b "41% KV reduction",
//! Fig. 5 memory curves). Mirrors `python/compile/model.py::
//! kv_cache_bytes` so L2 and L3 agree on the memory story.

use crate::sparse::memory::{csr_bytes, dense_bytes, Widths};

/// Model-level shape parameters needed for cache accounting.
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// Per-head Q/K dim (differs from d_head for "short" baselines).
    pub qk_dim: usize,
    /// SFA sparsity (None = dense cache).
    pub sparsity: Option<usize>,
}

impl CacheConfig {
    /// Total K+V cache bytes at context `seq` for `batch` sequences.
    pub fn bytes(&self, seq: usize, batch: usize, w: Widths) -> usize {
        let per_head_v = dense_bytes(seq, self.d_head, w);
        let per_head_k = match self.sparsity {
            Some(k) => csr_bytes(seq, k, w),
            None => dense_bytes(seq, self.qk_dim, w),
        };
        self.n_layers * self.n_heads * batch * (per_head_k + per_head_v)
    }

    /// Fractional saving vs a dense config with the same architecture.
    pub fn saving_vs_dense(&self, seq: usize, w: Widths) -> f64 {
        let dense = CacheConfig { sparsity: None, qk_dim: self.d_head, ..*self };
        1.0 - self.bytes(seq, 1, w) as f64 / dense.bytes(seq, 1, w) as f64
    }

    /// Max context length that fits in `budget` bytes (batch 1) — the
    /// "orders of magnitude longer context" claim quantified (§3.1).
    pub fn max_context_for_budget(&self, budget: usize, w: Widths) -> usize {
        // bytes() is linear in seq up to the +1 indptr term; solve directly.
        let per_tok = self.bytes(4096, 1, w).saturating_sub(self.bytes(2048, 1, w)) as f64
            / 2048.0;
        (budget as f64 / per_tok) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qwen_like(sparsity: Option<usize>) -> CacheConfig {
        CacheConfig { n_layers: 28, n_heads: 8, d_head: 128, qk_dim: 128, sparsity }
    }

    #[test]
    fn sfa_saves_about_forty_percent_at_default_config() {
        // Paper Fig. 1b: ~41% KV reduction at d=128, k=16 (fp16/int8).
        let s = qwen_like(Some(16)).saving_vs_dense(131072, Widths::PAPER);
        assert!((0.35..0.45).contains(&s), "saving {s}");
    }

    #[test]
    fn saving_grows_as_k_shrinks() {
        let w = Widths::PAPER;
        let s16 = qwen_like(Some(16)).saving_vs_dense(8192, w);
        let s8 = qwen_like(Some(8)).saving_vs_dense(8192, w);
        let s4 = qwen_like(Some(4)).saving_vs_dense(8192, w);
        assert!(s4 > s8 && s8 > s16);
    }

    #[test]
    fn max_context_extends_with_sparsity() {
        let w = Widths::PAPER;
        let budget = 8 << 30; // 8 GiB
        let dense_ctx = qwen_like(None).max_context_for_budget(budget, w);
        let sfa_ctx = qwen_like(Some(16)).max_context_for_budget(budget, w);
        assert!(sfa_ctx as f64 > 1.5 * dense_ctx as f64,
                "{sfa_ctx} vs {dense_ctx}");
    }

    #[test]
    fn bytes_scale_linearly_in_batch_and_layers() {
        let cfg = qwen_like(Some(8));
        let w = Widths::OURS;
        assert_eq!(cfg.bytes(1024, 4, w), 4 * cfg.bytes(1024, 1, w));
        let half = CacheConfig { n_layers: 14, ..cfg };
        assert_eq!(cfg.bytes(1024, 1, w), 2 * half.bytes(1024, 1, w));
    }
}
