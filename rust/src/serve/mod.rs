//! The serve subsystem — the repo's primary public serving API: a
//! **request-lifecycle** surface driving **continuous batching** over
//! [`AttentionSession`](crate::attention::session::AttentionSession).
//!
//! This replaces the coordinator's wave API (`Batcher::next_batch` →
//! `Engine::run_wave` → one blocking `GenResponse`), which is
//! structurally wave-synchronous: a finished sequence held its batch
//! slot and KV pages until the slowest request in its wave completed.
//! Here the unit of scheduling is the *request*, not the wave:
//!
//! * [`ServeRequest`] — builder: prompt, `max_new`, engine spec string
//!   (any [`registry`](crate::attention::registry) family), sampling,
//!   stop conditions, streaming event sink;
//! * [`RequestState`] — typed lifecycle, `Queued → Prefilling →
//!   Decoding → Finished{reason} / Failed{error}`;
//! * [`ServeEvent`] — per-token streaming over a channel instead of one
//!   blocking response;
//! * [`Scheduler`] — the policy trait; [`ContinuousBatcher`] admits
//!   sequences into a live decode wave at their own prefill boundary
//!   under a page-budget admission policy and evicts finished
//!   sequences' pages mid-wave; [`WaveScheduler`] reproduces the old
//!   wave semantics over the same substrate as the bench baseline;
//! * [`PagedKvPolicy`] — optional per-lane KV eviction (H2O / SnapKV /
//!   Quest acting on live [`PagedKvCache`](crate::kv_cache::paged)
//!   pages): lanes prune themselves under a token budget between
//!   decode steps, and admission reserves that budget instead of the
//!   worst-case `prompt + max_new` footprint, raising achievable
//!   concurrency at a fixed page budget;
//! * [`ToyLm`] — the deterministic, artifact-free model the schedulers
//!   drive (bit-for-bit independent of batch composition, which is
//!   what makes the greedy solo-vs-batched equivalence testable);
//! * [`PrefixCacheConfig`] — optional radix prompt-prefix sharing
//!   across requests: finished prompts are recorded in a
//!   [`RadixPrefixCache`](crate::kv_cache::radix::RadixPrefixCache)
//!   (pinned forked pages — no copies), admissions fork the longest
//!   cached prefix and prefill only the suffix, and the admission
//!   accounting charges only that un-shared suffix
//!   ([`pages_reserved_shared`]). Greedy streams are bit-for-bit
//!   identical with the cache on or off;
//! * `ServeConfig::prefill_chunk` — chunked prefill with
//!   prefill–decode interleaving: prompts are ingested incrementally
//!   (at most one chunk per lane per step) so a long prompt no longer
//!   stalls live decode lanes, with `RequestState::Prefilling {
//!   consumed, total }` reporting per-chunk progress. `0` keeps the
//!   legacy monolithic path; greedy streams are bit-for-bit identical
//!   across every chunk size, including 0.
//! * `ServeConfig::speculate` — speculative decoding
//!   ([`SpeculateConfig`]): a cheap registry engine drafts γ tokens on
//!   a lane in its own draft session, the target scores all γ+1
//!   positions in one [`AttentionSession::score_lanes`] verify forward
//!   on a `fork_prefix`-forked lane, and the exact-match acceptance
//!   rule commits the agreed prefix (rollback = `release_lane` on the
//!   fork). Streams — greedy *and* temperature — are bit-for-bit
//!   identical with speculation on or off; only tokens/step changes.
//!
//! See ARCHITECTURE.md §"Serving lifecycle" for the state machine and
//! the admission rules, and `sfa bench serve` for the continuous-vs-
//! wave comparison (BENCH_serve.json).

pub mod model;
pub mod request;
pub mod scheduler;
pub mod speculate;
pub mod wave;

pub use crate::attention::decode::PagedKvPolicy;
pub use crate::kv_cache::radix::PrefixCacheStats;
pub use model::ToyLm;
pub use request::{
    FinishReason, FinishedRequest, RequestId, RequestState, ServeError, ServeEvent,
    ServeRequest, ServeSampling, SloClass,
};
pub use crate::kv_cache::paged::{KvTierCfg, TierPolicy};
pub use scheduler::{
    pages_needed, pages_reserved, pages_reserved_shared, pages_reserved_tiered,
    ContinuousBatcher, PrefixCacheConfig, Scheduler, ServeConfig, ServeConfigBuilder,
    ServeConfigError, StepReport,
};
pub use speculate::SpeculateConfig;
pub use wave::WaveScheduler;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            heads: 2,
            d: 8,
            vocab: 32,
            page_size: 4,
            max_pages: 512,
            max_lanes: 4,
            queue_capacity: 64,
            max_seq: 256,
            model_seed: 7,
            kv_policy: None,
            prefix_cache: None,
            prefill_chunk: 0,
            speculate: None,
            kv_tier: None,
        }
    }

    fn prompt(seed: u64, len: usize, vocab: usize) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
    }

    fn solo_tokens(p: &[i32], max_new: usize, spec: &str) -> Vec<i32> {
        let mut s = ContinuousBatcher::new(tiny_cfg());
        let id = s
            .submit(ServeRequest::new(p.to_vec()).max_new(max_new).engine(spec))
            .unwrap();
        let fin = s.run_to_completion();
        let f = fin.iter().find(|f| f.id == id).unwrap();
        assert!(matches!(f.state, RequestState::Finished { .. }), "{:?}", f.state);
        f.tokens.clone()
    }

    /// The headline invariant: a sequence admitted into a *busy*
    /// continuous batch (joining a live decode wave at its own prefill
    /// boundary) produces, under greedy sampling, exactly the token
    /// stream of a solo run — bit-for-bit, first token included.
    #[test]
    fn admitted_sequence_matches_solo_run_bit_for_bit() {
        let spec = "sfa:k=4,bq=8,bk=8";
        let target = prompt(3, 13, 32);
        let solo = solo_tokens(&target, 8, spec);
        assert_eq!(solo.len(), 8);

        let mut s = ContinuousBatcher::new(tiny_cfg());
        s.submit(ServeRequest::new(prompt(1, 29, 32)).max_new(20).engine(spec)).unwrap();
        s.submit(ServeRequest::new(prompt(2, 7, 32)).max_new(20).engine(spec)).unwrap();
        s.step();
        s.step(); // both neighbours are now mid-decode
        assert_eq!(s.live(), 2);
        let id = s
            .submit(ServeRequest::new(target.clone()).max_new(8).engine(spec))
            .unwrap();
        let fin = s.run_to_completion();
        let f = fin.iter().find(|f| f.id == id).unwrap();
        assert_eq!(f.tokens, solo, "greedy decode must not depend on batch composition");
        assert!(matches!(
            f.state,
            RequestState::Finished { reason: FinishReason::MaxTokens }
        ));
        assert!(f.ttft_s >= 0.0 && f.total_s >= f.ttft_s);
    }

    /// Same workload through both schedulers: wave scheduling changes
    /// latency and page residency, never tokens.
    #[test]
    fn wave_and_continuous_agree_on_greedy_streams() {
        for spec in ["dense", "sfa:k=4"] {
            let reqs: Vec<(Vec<i32>, usize)> =
                (0..3).map(|i| (prompt(10 + i, 6 + 5 * i as usize, 32), 3 + i as usize)).collect();
            let mut cont = ContinuousBatcher::new(tiny_cfg());
            let mut wave = WaveScheduler::new(tiny_cfg());
            for (p, m) in &reqs {
                cont.submit(ServeRequest::new(p.clone()).max_new(*m).engine(spec)).unwrap();
                wave.submit(ServeRequest::new(p.clone()).max_new(*m).engine(spec)).unwrap();
            }
            let mut fc = cont.run_to_completion();
            let mut fw = wave.run_to_completion();
            fc.sort_by_key(|f| f.id);
            fw.sort_by_key(|f| f.id);
            assert_eq!(fc.len(), 3);
            for (c, w) in fc.iter().zip(&fw) {
                assert_eq!(c.id, w.id);
                assert_eq!(c.tokens, w.tokens, "{spec}: scheduler changed the tokens");
            }
        }
    }

    /// Scheduler invariant: a finished sequence's pages are freed on
    /// the same step it finishes (mid-wave, while others keep going).
    #[test]
    fn finished_lane_pages_are_freed_on_the_finishing_step() {
        let mut s = ContinuousBatcher::new(tiny_cfg());
        s.submit(ServeRequest::new(prompt(1, 6, 32)).max_new(3).engine("dense")).unwrap();
        s.submit(ServeRequest::new(prompt(2, 6, 32)).max_new(12).engine("dense")).unwrap();
        let mut saw_midwave_free = false;
        while s.has_work() {
            let r = s.step();
            if r.finished > 0 && s.has_work() {
                assert!(r.pages_freed > 0, "pages must return on the finishing step");
                assert_eq!(r.live, 1, "the long request keeps decoding");
                saw_midwave_free = true;
            }
        }
        assert!(saw_midwave_free, "short request should finish mid-wave");
        assert_eq!(s.pages_in_use(), 0, "idle scheduler holds no pages");
    }

    /// The wave baseline holds every page until the whole wave ends.
    #[test]
    fn wave_holds_pages_until_the_wave_ends() {
        let mut s = WaveScheduler::new(tiny_cfg());
        s.submit(ServeRequest::new(prompt(1, 6, 32)).max_new(2).engine("dense")).unwrap();
        s.submit(ServeRequest::new(prompt(2, 6, 32)).max_new(8).engine("dense")).unwrap();
        let mut final_free = 0;
        while s.has_work() {
            let r = s.step();
            if s.has_work() {
                assert_eq!(r.pages_freed, 0, "wave frees nothing mid-flight");
            } else {
                assert_eq!(r.finished, 2, "responses delivered at wave end");
                final_free = r.pages_freed;
            }
        }
        assert!(final_free > 0);
        assert_eq!(s.pages_in_use(), 0);
    }

    #[test]
    fn queue_backpressure_is_a_typed_error() {
        let cfg = ServeConfig { queue_capacity: 2, ..tiny_cfg() };
        let mut s = ContinuousBatcher::new(cfg);
        s.submit(ServeRequest::new(prompt(1, 4, 32)).engine("dense")).unwrap();
        s.submit(ServeRequest::new(prompt(2, 4, 32)).engine("dense")).unwrap();
        let e = s.submit(ServeRequest::new(prompt(3, 4, 32)).engine("dense")).unwrap_err();
        assert_eq!(e, ServeError::QueueFull { capacity: 2 });
    }

    /// Page-budget admission: a request that fits-but-not-yet waits in
    /// the queue; one that could never fit fails at submission.
    #[test]
    fn page_budget_gates_admission() {
        // One sequence of (8 prompt + 8 new) needs 2 heads × ⌈16/4⌉ = 8
        // pages — exactly the whole budget.
        let cfg = ServeConfig { max_pages: 8, ..tiny_cfg() };
        let mut s = ContinuousBatcher::new(cfg);
        let a = s
            .submit(ServeRequest::new(prompt(1, 8, 32)).max_new(8).engine("dense"))
            .unwrap();
        let b = s
            .submit(ServeRequest::new(prompt(2, 8, 32)).max_new(8).engine("dense"))
            .unwrap();
        let r = s.step();
        assert_eq!(r.admitted, 1, "second request must wait for pages");
        assert_eq!(s.queued(), 1);
        let fin = s.run_to_completion();
        for id in [a, b] {
            let f = fin.iter().find(|f| f.id == id).unwrap();
            assert!(matches!(f.state, RequestState::Finished { .. }), "{:?}", f.state);
        }
        // 2 heads × ⌈60/4⌉ = 30 pages can never fit an 8-page budget.
        let e = s
            .submit(ServeRequest::new(prompt(3, 30, 32)).max_new(30).engine("dense"))
            .unwrap_err();
        assert_eq!(e, ServeError::PageBudgetExceeded { needed_pages: 30, budget_pages: 8 });
    }

    #[test]
    fn invalid_requests_fail_with_typed_errors() {
        let mut s = ContinuousBatcher::new(tiny_cfg());
        assert_eq!(
            s.submit(ServeRequest::new(vec![]).engine("dense")).unwrap_err(),
            ServeError::EmptyPrompt
        );
        assert_eq!(
            s.submit(ServeRequest::new(vec![1]).max_new(0).engine("dense")).unwrap_err(),
            ServeError::NothingToGenerate
        );
        assert!(matches!(
            s.submit(ServeRequest::new(vec![1]).engine("warp")).unwrap_err(),
            ServeError::BadSpec(_)
        ));
        let long = prompt(1, 256, 32);
        assert!(matches!(
            s.submit(ServeRequest::new(long).engine("dense")).unwrap_err(),
            ServeError::PromptTooLong { .. }
        ));
        // Parses at submit but the session rejects k > d at admission:
        // the request fails through the lifecycle, not a panic.
        let id = s
            .submit(ServeRequest::new(vec![1, 2, 3]).engine("sfa:k=64"))
            .unwrap();
        while s.has_work() {
            s.step();
        }
        assert!(
            matches!(s.state(id), Some(RequestState::Failed { .. })),
            "terminal state visible until drained"
        );
        let fin = s.take_finished();
        let f = fin.iter().find(|f| f.id == id).unwrap();
        assert!(
            matches!(f.state, RequestState::Failed { error: ServeError::BadSpec(_) }),
            "{:?}",
            f.state
        );
        assert!(
            s.state(id).is_none(),
            "take_finished prunes terminal lifecycle entries (bounded memory)"
        );
    }

    #[test]
    fn stop_tokens_end_generation_early() {
        let p = prompt(5, 9, 32);
        let solo = solo_tokens(&p, 6, "dense");
        let mut s = ContinuousBatcher::new(tiny_cfg());
        let id = s
            .submit(
                ServeRequest::new(p)
                    .max_new(6)
                    .engine("dense")
                    .stop_tokens(vec![solo[0]]),
            )
            .unwrap();
        let fin = s.run_to_completion();
        let f = fin.iter().find(|f| f.id == id).unwrap();
        assert_eq!(f.tokens, vec![solo[0]], "stop token is included, then generation ends");
        assert!(matches!(
            f.state,
            RequestState::Finished { reason: FinishReason::StopToken }
        ));
    }

    #[test]
    fn context_cap_finishes_with_context_full() {
        let cfg = ServeConfig { max_seq: 16, ..tiny_cfg() };
        let mut s = ContinuousBatcher::new(cfg);
        let id = s
            .submit(ServeRequest::new(prompt(1, 10, 32)).max_new(20).engine("dense"))
            .unwrap();
        let fin = s.run_to_completion();
        let f = fin.iter().find(|f| f.id == id).unwrap();
        assert_eq!(f.tokens.len(), 6, "10 prompt + 6 generated hits max_seq 16");
        assert!(matches!(
            f.state,
            RequestState::Finished { reason: FinishReason::ContextFull }
        ));
    }

    /// The streaming surface: state transitions and per-token events
    /// arrive on the channel, in lifecycle order.
    #[test]
    fn events_stream_over_the_channel() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut s = ContinuousBatcher::new(tiny_cfg());
        let id = s
            .submit(ServeRequest::new(prompt(1, 5, 32)).max_new(4).engine("dense").events(tx))
            .unwrap();
        let fin = s.run_to_completion();
        let tokens = &fin.iter().find(|f| f.id == id).unwrap().tokens;
        let events: Vec<ServeEvent> = rx.try_iter().collect();
        let states: Vec<RequestState> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::State { state, .. } => Some(state.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(states[0], RequestState::Queued);
        assert!(matches!(states[1], RequestState::Prefilling { .. }));
        assert_eq!(states[2], RequestState::Decoding);
        assert!(states[3].is_terminal());
        let streamed: Vec<i32> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(&streamed, tokens, "every token is streamed, in order");
        let indices: Vec<usize> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Token { index, .. } => Some(*index),
                _ => None,
            })
            .collect();
        assert_eq!(indices, (0..tokens.len()).collect::<Vec<_>>());
    }

    /// Heterogeneous engine families coexist in one serving process —
    /// each group keeps its own session, cache layout, and budget.
    #[test]
    fn heterogeneous_engine_groups_coexist() {
        let mut s = ContinuousBatcher::new(tiny_cfg());
        let specs = ["dense", "sfa:k=4", "window:w=8,scorer=sfa_k4"];
        let ids: Vec<_> = specs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                s.submit(
                    ServeRequest::new(prompt(i as u64, 5 + i, 32)).max_new(4).engine(spec),
                )
                .unwrap()
            })
            .collect();
        let r = s.step();
        assert_eq!(r.admitted, 3, "one admission pass spans all groups");
        let fin = s.run_to_completion();
        for (id, spec) in ids.iter().zip(&specs) {
            let f = fin.iter().find(|f| f.id == *id).unwrap();
            assert!(matches!(f.state, RequestState::Finished { .. }), "{spec}");
            assert_eq!(f.tokens.len(), 4);
            assert_eq!(
                f.engine,
                crate::attention::registry::parse_spec(spec).unwrap().canonical()
            );
        }
        assert_eq!(s.pages_in_use(), 0);
        let m = s.metrics();
        assert_eq!(m.requests, 3);
        assert_eq!(m.tokens_out, 12);
        assert!(m.ttft().p95 >= m.ttft().p50);
    }

    /// Satellite guarantee: under *every* eviction policy, a budget
    /// that exceeds the sequence length makes the policy a no-op, and
    /// the greedy token stream matches an unpruned solo run exactly —
    /// inside a busy batch, first token included.
    #[test]
    fn noop_budget_policies_preserve_greedy_tokens() {
        let spec = "sfa:k=4,bq=8,bk=8";
        let p = prompt(21, 13, 32);
        let solo = solo_tokens(&p, 8, spec); // kv_policy: None baseline
        // prompt 13 + max_new 8 = 21 tokens; budgets comfortably above.
        let policies = [
            PagedKvPolicy::H2o { budget: 48, recent: 8 },
            PagedKvPolicy::SnapKv { budget: 48, recent: 8 },
            PagedKvPolicy::Quest { budget: 48 },
        ];
        for pol in policies {
            let cfg = ServeConfig { kv_policy: Some(pol), ..tiny_cfg() };
            let mut s = ContinuousBatcher::new(cfg);
            // Busy neighbours, also policy lanes.
            s.submit(ServeRequest::new(prompt(1, 29, 32)).max_new(20).engine(spec)).unwrap();
            s.submit(ServeRequest::new(prompt(2, 7, 32)).max_new(20).engine(spec)).unwrap();
            s.step();
            s.step();
            let id = s.submit(ServeRequest::new(p.clone()).max_new(8).engine(spec)).unwrap();
            let fin = s.run_to_completion();
            let f = fin.iter().find(|f| f.id == id).unwrap();
            assert_eq!(
                f.tokens, solo,
                "{pol:?}: a no-op-budget policy must not change greedy tokens"
            );
            assert!(matches!(f.state, RequestState::Finished { .. }));
        }
    }

    /// The tentpole invariant: at a fixed page budget, policy-budget
    /// admission (reserving the pruned steady state instead of the
    /// worst-case `prompt + max_new` footprint) achieves strictly
    /// higher concurrency, finishes the same workload, and prunes
    /// pages mid-wave.
    #[test]
    fn policy_budget_admission_raises_achieved_concurrency() {
        let base = ServeConfig {
            heads: 2,
            d: 8,
            vocab: 32,
            page_size: 4,
            max_pages: 60,
            max_lanes: 8,
            queue_capacity: 64,
            max_seq: 128,
            model_seed: 7,
            kv_policy: None,
            prefix_cache: None,
            prefill_chunk: 0,
            speculate: None,
            kv_tier: None,
        };
        let run = |pol: Option<PagedKvPolicy>| -> (f64, usize, usize, usize) {
            let mut s = ContinuousBatcher::new(ServeConfig { kv_policy: pol, ..base });
            for i in 0..10u64 {
                s.submit(
                    ServeRequest::new(prompt(i, 24 + (i as usize % 8), 32))
                        .max_new(10)
                        .engine("dense"),
                )
                .unwrap();
            }
            let (mut sum_live, mut steps, mut peak, mut pruned) = (0f64, 0usize, 0usize, 0usize);
            while s.has_work() {
                let r = s.step();
                sum_live += r.live as f64;
                steps += 1;
                peak = peak.max(r.live);
                pruned += r.pages_pruned;
            }
            let fin = s.take_finished();
            assert_eq!(fin.len(), 10);
            let failed = fin
                .iter()
                .filter(|f| matches!(f.state, RequestState::Failed { .. }))
                .count();
            assert_eq!(failed, 0);
            (sum_live / steps as f64, peak, pruned, steps)
        };
        let (mean_none, peak_none, pruned_none, _) = run(None);
        assert_eq!(pruned_none, 0, "no policy, no pruning");
        for pol in [
            PagedKvPolicy::H2o { budget: 8, recent: 4 },
            PagedKvPolicy::SnapKv { budget: 8, recent: 4 },
            PagedKvPolicy::Quest { budget: 8 },
        ] {
            let (mean_pol, peak_pol, pruned_pol, _) = run(Some(pol));
            assert!(
                peak_pol > peak_none && mean_pol > mean_none,
                "{pol:?}: policy admission must raise concurrency \
                 (peak {peak_pol} vs {peak_none}, mean {mean_pol:.2} vs {mean_none:.2})"
            );
            assert!(pruned_pol > 0, "{pol:?}: long prompts must be pruned");
        }
    }

    /// The tentpole correctness pin: with the radix prefix cache ON,
    /// greedy token streams are **bit-for-bit identical** to the
    /// cache-OFF run — for the inserting request (miss) and for every
    /// later request served from a cached prefix (hit) — while the
    /// hits actually happen and are visible per request.
    #[test]
    fn prefix_cache_on_and_off_greedy_streams_are_bit_identical() {
        for spec in ["dense", "sfa:k=4,bq=8,bk=8"] {
            let sys = prompt(77, 24, 32);
            let mk = |i: usize| {
                let mut p = sys.clone();
                p.push(20 + i as i32); // distinct first suffix token
                p.extend(prompt(100 + i as u64, 5, 32));
                p
            };
            let run = |prefix: Option<PrefixCacheConfig>| {
                let cfg = ServeConfig { prefix_cache: prefix, ..tiny_cfg() };
                let mut s = ContinuousBatcher::new(cfg);
                // Stagger so the first prompt's path is cached before
                // the rest arrive (insertion happens at retirement).
                s.submit(ServeRequest::new(mk(0)).max_new(6).engine(spec)).unwrap();
                let mut fin = s.run_to_completion();
                for i in 1..4 {
                    s.submit(ServeRequest::new(mk(i)).max_new(6).engine(spec)).unwrap();
                }
                fin.extend(s.run_to_completion());
                fin.sort_by_key(|f| f.id);
                (fin, s.prefix_stats())
            };
            let (cold, cold_stats) = run(None);
            let (warm, warm_stats) = run(Some(PrefixCacheConfig::default()));
            assert_eq!(cold_stats, PrefixCacheStats::default(), "{spec}: no cache, no stats");
            assert_eq!(warm.len(), 4);
            assert!(warm_stats.hits >= 3, "{spec}: later requests hit ({warm_stats:?})");
            assert!(warm_stats.inserted >= 1, "{spec}: first prompt path inserted");
            for (c, w) in cold.iter().zip(&warm) {
                assert!(matches!(w.state, RequestState::Finished { .. }), "{spec}");
                assert_eq!(
                    c.tokens, w.tokens,
                    "{spec}: prefix cache must not change greedy tokens"
                );
                assert_eq!(c.prefix_shared, 0, "{spec}: cache off shares nothing");
            }
            // Every staggered request shares the 24-token system
            // prefix (the first one missed).
            assert_eq!(warm[0].prefix_shared, 0);
            for w in &warm[1..] {
                assert_eq!(w.prefix_shared, 24, "{spec}: hit covers the system prompt");
            }
        }
    }

    /// Suffix-only admission accounting: at a page budget where two
    /// worst-case reservations cannot coexist, two prefix-cache hits
    /// (each charged only its un-shared suffix) are admitted in one
    /// pass — the concurrency the prefix cache buys.
    #[test]
    fn prefix_hits_reserve_only_the_unshared_suffix() {
        // heads=2, page_size=4. Prompt = 16 shared + 3 suffix = 19
        // tokens, max_new=5 -> full footprint 2*ceil(24/4) = 12 pages;
        // a hit reserves 12 - 2*(16/4) = 4. Entry nominal =
        // 2*ceil(19/4) = 10. Budget 20: cold fits one (12+12 > 20),
        // warm fits both hits (10+4+4 = 18 <= 20).
        let base = ServeConfig { max_pages: 20, ..tiny_cfg() };
        let sys = prompt(3, 16, 32);
        let mk = |i: usize| {
            let mut p = sys.clone();
            p.push(20 + i as i32);
            p.extend(prompt(50 + i as u64, 2, 32));
            p
        };
        let admitted_together = |prefix: Option<PrefixCacheConfig>| -> (usize, usize) {
            let cfg = ServeConfig { prefix_cache: prefix, ..base };
            let mut s = ContinuousBatcher::new(cfg);
            s.submit(ServeRequest::new(mk(0)).max_new(5).engine("dense")).unwrap();
            s.run_to_completion();
            s.submit(ServeRequest::new(mk(1)).max_new(5).engine("dense")).unwrap();
            s.submit(ServeRequest::new(mk(2)).max_new(5).engine("dense")).unwrap();
            let r = s.step();
            let out = (r.admitted, r.prefix_hits);
            s.run_to_completion();
            out
        };
        let (cold_admitted, cold_hits) = admitted_together(None);
        assert_eq!((cold_admitted, cold_hits), (1, 0), "worst-case fits one lane");
        let (warm_admitted, warm_hits) =
            admitted_together(Some(PrefixCacheConfig { max_pages: 10 }));
        assert_eq!(warm_admitted, 2, "suffix-only reservations fit both");
        assert_eq!(warm_hits, 2);
    }

    /// LRU pressure: a prefix cache whose budget cannot hold every
    /// prompt path keeps serving (evicting old entries) and never
    /// wedges admission.
    #[test]
    fn prefix_cache_evicts_under_pressure_and_serving_continues() {
        // Each 8-token prompt path costs 2*ceil(8/4) = 4 nominal
        // pages; budget 8 holds two entries.
        let cfg = ServeConfig {
            prefix_cache: Some(PrefixCacheConfig { max_pages: 8 }),
            ..tiny_cfg()
        };
        let mut s = ContinuousBatcher::new(cfg);
        for i in 0..6u64 {
            s.submit(
                ServeRequest::new(prompt(i, 8, 32)).max_new(3).engine("dense"),
            )
            .unwrap();
            let fin = s.run_to_completion();
            assert!(fin
                .iter()
                .all(|f| matches!(f.state, RequestState::Finished { .. })));
        }
        let st = s.prefix_stats();
        assert!(st.inserted >= 3, "{st:?}");
        assert!(st.evicted >= 1, "budget pressure evicts LRU entries: {st:?}");
        assert!(st.pages_nominal <= 8, "{st:?}");
        // Idle scheduler: the only pages still resident back cached
        // entries, and nominal accounting over-counts them (safe side).
        assert!(s.pages_in_use() <= st.pages_nominal, "{st:?}");
    }

    /// Temperature sampling draws from a per-request stream, so it is
    /// also batch-composition independent.
    #[test]
    fn temperature_sampling_is_batch_independent() {
        let p = prompt(9, 8, 32);
        let run = |busy: bool| -> Vec<i32> {
            let mut s = ContinuousBatcher::new(tiny_cfg());
            if busy {
                s.submit(ServeRequest::new(prompt(1, 20, 32)).max_new(16).engine("dense"))
                    .unwrap();
                s.step();
            }
            let id = s
                .submit(
                    ServeRequest::new(p.clone())
                        .max_new(5)
                        .engine("dense")
                        .sampling(ServeSampling::Temperature(0.8)),
                )
                .unwrap();
            let fin = s.run_to_completion();
            fin.iter().find(|f| f.id == id).unwrap().tokens.clone()
        };
        assert_eq!(run(false), run(true));
    }

    /// The tentpole acceptance pin: greedy token streams are
    /// **bit-for-bit identical** for `prefill_chunk ∈ {0, 64, 256,
    /// 1024}` — plus small chunk sizes that split the prompt many
    /// times — for every engine family. Chunking changes *when* cache
    /// bytes land, never which bytes.
    #[test]
    fn chunked_prefill_streams_are_chunk_size_invariant() {
        for spec in ["dense", "flash_dense", "sfa:k=4,bq=8,bk=8"] {
            let run = |chunk: usize| -> Vec<(RequestId, Vec<i32>)> {
                let cfg = ServeConfig { prefill_chunk: chunk, ..tiny_cfg() };
                let mut s = ContinuousBatcher::new(cfg);
                s.submit(ServeRequest::new(prompt(1, 200, 32)).max_new(5).engine(spec))
                    .unwrap();
                s.submit(ServeRequest::new(prompt(2, 7, 32)).max_new(5).engine(spec))
                    .unwrap();
                s.submit(ServeRequest::new(prompt(3, 33, 32)).max_new(5).engine(spec))
                    .unwrap();
                let mut fin = s.run_to_completion();
                fin.sort_by_key(|f| f.id);
                assert_eq!(s.pages_in_use(), 0, "{spec}: idle scheduler holds no pages");
                fin.iter()
                    .map(|f| {
                        assert!(matches!(f.state, RequestState::Finished { .. }), "{spec}");
                        (f.id, f.tokens.clone())
                    })
                    .collect()
            };
            let monolithic = run(0);
            for chunk in [1, 5, 64, 256, 1024] {
                assert_eq!(
                    run(chunk),
                    monolithic,
                    "{spec}: chunk={chunk} must reproduce the monolithic streams"
                );
            }
        }
    }

    /// The speculative-decoding acceptance pin at the scheduler level:
    /// token streams are **bit-for-bit identical** with
    /// `ServeConfig::speculate` on or off — for greedy *and*
    /// temperature sampling, across engine families and γ values, in a
    /// mixed multi-request batch. Speculation changes how many tokens
    /// a step commits, never which tokens.
    #[test]
    fn speculative_streams_match_plain_decoding_bitwise() {
        for (spec, draft) in
            [("dense", "sfa:k=2,bq=8,bk=8"), ("sfa:k=4,bq=8,bk=8", "sfa:k=2,bq=8,bk=8")]
        {
            let run = |speculate: Option<SpeculateConfig>| -> (Vec<(RequestId, Vec<i32>)>, u64) {
                let cfg = ServeConfig { speculate, ..tiny_cfg() };
                let mut s = ContinuousBatcher::new(cfg);
                s.submit(ServeRequest::new(prompt(1, 24, 32)).max_new(12).engine(spec))
                    .unwrap();
                s.submit(
                    ServeRequest::new(prompt(2, 7, 32))
                        .max_new(9)
                        .engine(spec)
                        .sampling(ServeSampling::Temperature(0.8))
                        .seed(42),
                )
                .unwrap();
                s.submit(ServeRequest::new(prompt(3, 15, 32)).max_new(1).engine(spec))
                    .unwrap();
                let mut fin = s.run_to_completion();
                fin.sort_by_key(|f| f.id);
                assert_eq!(s.pages_in_use(), 0, "{spec}: idle scheduler holds no pages");
                let toks = fin
                    .iter()
                    .map(|f| {
                        assert!(matches!(f.state, RequestState::Finished { .. }), "{spec}");
                        (f.id, f.tokens.clone())
                    })
                    .collect();
                (toks, s.metrics().spec_proposed)
            };
            let (plain, _) = run(None);
            for gamma in [1, 3, 8] {
                let sp = SpeculateConfig::parse(draft, gamma).unwrap();
                let (spec_toks, proposed) = run(Some(sp));
                assert_eq!(
                    spec_toks, plain,
                    "{spec}: γ={gamma} draft={draft} must reproduce the plain streams"
                );
                assert!(proposed > 0, "{spec}: γ={gamma} speculation never ran");
            }
        }
    }

    /// Stop tokens end a speculative step mid-batch: emissions past the
    /// first stop are discarded (sequential decoding would never have
    /// sampled them), so the finished stream and its `StopToken` finish
    /// reason match the plain run exactly.
    #[test]
    fn speculative_stop_token_truncation_matches_plain() {
        let spec = "dense";
        let run = |speculate: Option<SpeculateConfig>, stop: Vec<i32>| -> FinishedRequest {
            let cfg = ServeConfig { speculate, ..tiny_cfg() };
            let mut s = ContinuousBatcher::new(cfg);
            let id = s
                .submit(
                    ServeRequest::new(prompt(5, 18, 32))
                        .max_new(20)
                        .engine(spec)
                        .stop_tokens(stop),
                )
                .unwrap();
            let fin = s.run_to_completion();
            fin.into_iter().find(|f| f.id == id).unwrap()
        };
        // Learn the greedy stream, then stop on a token from its middle
        // so the speculative run must truncate inside a verify batch.
        let free = run(None, vec![]);
        assert!(free.tokens.len() >= 4, "need a few tokens to pick a stop from");
        let stop = vec![free.tokens[2]];
        let plain = run(None, stop.clone());
        assert!(matches!(plain.state, RequestState::Finished { reason: FinishReason::StopToken }));
        let sp = SpeculateConfig::parse("sfa:k=2,bq=8,bk=8", 4).unwrap();
        let speced = run(Some(sp), stop);
        assert_eq!(speced.tokens, plain.tokens, "stop truncation changed the stream");
        assert!(
            matches!(speced.state, RequestState::Finished { reason: FinishReason::StopToken }),
            "{:?}",
            speced.state
        );
    }

    /// Speculation composes with the radix prefix cache: forked-prefix
    /// admissions, cache hits, and speculative verify forks coexist on
    /// one paged pool, and streams still match the both-knobs-off run.
    #[test]
    fn speculation_composes_with_prefix_cache() {
        let spec = "sfa:k=4,bq=8,bk=8";
        let sys = prompt(77, 24, 32);
        let mk = |i: usize| {
            let mut p = sys.clone();
            p.push(20 + i as i32);
            p.extend(prompt(200 + i as u64, 5, 32));
            p
        };
        let run = |px: Option<PrefixCacheConfig>,
                   sp: Option<SpeculateConfig>|
         -> (Vec<Vec<i32>>, u64) {
            let cfg = ServeConfig { prefix_cache: px, speculate: sp, ..tiny_cfg() };
            let mut s = ContinuousBatcher::new(cfg);
            s.submit(ServeRequest::new(mk(0)).max_new(6).engine(spec)).unwrap();
            let mut fin = s.run_to_completion();
            for i in 1..4 {
                s.submit(ServeRequest::new(mk(i)).max_new(6).engine(spec)).unwrap();
            }
            fin.extend(s.run_to_completion());
            fin.sort_by_key(|f| f.id);
            let toks = fin
                .iter()
                .map(|f| {
                    assert!(matches!(f.state, RequestState::Finished { .. }));
                    f.tokens.clone()
                })
                .collect();
            (toks, s.prefix_stats().hits)
        };
        let (base, _) = run(None, None);
        let sp = SpeculateConfig::parse("sfa:k=2,bq=8,bk=8", 3).unwrap();
        let (both, hits) = run(Some(PrefixCacheConfig::default()), Some(sp));
        assert_eq!(both, base, "prefix cache + speculation changed greedy streams");
        assert!(hits >= 3, "later requests still hit the prefix cache");
    }

    /// Satellite rollback pin at the scheduler level: with the page
    /// pool sized exactly to the admission reservation, the verify
    /// fork's γ+1 scratch appends routinely hit OutOfPages mid-step.
    /// Every such failure must roll back (fork auto-released, draft
    /// lane dropped) and fall back to plain decode — every request
    /// still finishes, streams still match the plain run, and the idle
    /// pool is empty.
    #[test]
    fn speculative_oop_fallback_preserves_streams_and_accounting() {
        let spec = "dense";
        let base = tiny_cfg();
        // One request's worst case: heads × ⌈(18 + 10) / 4⌉ = 14 pages.
        let tight = pages_reserved(18, 10, &base);
        let run = |speculate: Option<SpeculateConfig>| -> Vec<Vec<i32>> {
            let cfg = ServeConfig { max_pages: tight, speculate, ..base };
            let mut s = ContinuousBatcher::new(cfg);
            for i in 0..3u64 {
                s.submit(ServeRequest::new(prompt(30 + i, 18, 32)).max_new(10).engine(spec))
                    .unwrap();
            }
            let mut fin = s.run_to_completion();
            fin.sort_by_key(|f| f.id);
            assert_eq!(s.pages_in_use(), 0, "idle pool must be empty after rollbacks");
            fin.iter()
                .map(|f| {
                    assert!(matches!(f.state, RequestState::Finished { .. }), "{:?}", f.state);
                    f.tokens.clone()
                })
                .collect()
        };
        let plain = run(None);
        let sp = SpeculateConfig::parse("sfa:k=2,bq=8,bk=8", 6).unwrap();
        assert_eq!(run(Some(sp)), plain, "OOP fallbacks must not change streams");
    }

    /// Chunked prefill composes with KV eviction policies: per-chunk
    /// key observation plus the finish-time query replay leave the
    /// policy in exactly the monolithic state (pinned bitwise at the
    /// session layer), so greedy streams match chunk-for-chunk — for
    /// a no-op budget *and* for genuinely pruning ones.
    #[test]
    fn chunked_prefill_composes_with_kv_policies() {
        let spec = "sfa:k=4,bq=8,bk=8";
        let policies = [
            PagedKvPolicy::H2o { budget: 48, recent: 8 }, // no-op for this workload
            PagedKvPolicy::SnapKv { budget: 16, recent: 4 }, // prunes the long prompt
            PagedKvPolicy::Quest { budget: 16 },
        ];
        for pol in policies {
            let run = |chunk: usize| -> Vec<Vec<i32>> {
                let cfg =
                    ServeConfig { kv_policy: Some(pol), prefill_chunk: chunk, ..tiny_cfg() };
                let mut s = ContinuousBatcher::new(cfg);
                s.submit(ServeRequest::new(prompt(11, 24, 32)).max_new(8).engine(spec))
                    .unwrap();
                s.submit(ServeRequest::new(prompt(12, 9, 32)).max_new(8).engine(spec))
                    .unwrap();
                let mut fin = s.run_to_completion();
                fin.sort_by_key(|f| f.id);
                fin.iter()
                    .map(|f| {
                        assert!(matches!(f.state, RequestState::Finished { .. }), "{pol:?}");
                        f.tokens.clone()
                    })
                    .collect()
            };
            let mono = run(0);
            for chunk in [1, 5, 64] {
                assert_eq!(run(chunk), mono, "{pol:?}: chunk={chunk} changed greedy tokens");
            }
        }
    }

    /// Chunked prefill composes with the radix prefix cache: a hit
    /// forks the shared prefix and chunk-ingests only the un-shared
    /// suffix, reproducing the monolithic streams bit-for-bit while
    /// the hits still happen and share the same token counts.
    #[test]
    fn chunked_prefill_composes_with_prefix_cache() {
        for spec in ["dense", "sfa:k=4,bq=8,bk=8"] {
            let sys = prompt(77, 24, 32);
            let mk = |i: usize| {
                let mut p = sys.clone();
                p.push(20 + i as i32);
                p.extend(prompt(100 + i as u64, 5, 32));
                p
            };
            let run = |chunk: usize| -> (Vec<Vec<i32>>, Vec<usize>, u64) {
                let cfg = ServeConfig {
                    prefix_cache: Some(PrefixCacheConfig::default()),
                    prefill_chunk: chunk,
                    ..tiny_cfg()
                };
                let mut s = ContinuousBatcher::new(cfg);
                s.submit(ServeRequest::new(mk(0)).max_new(6).engine(spec)).unwrap();
                let mut fin = s.run_to_completion();
                for i in 1..4 {
                    s.submit(ServeRequest::new(mk(i)).max_new(6).engine(spec)).unwrap();
                }
                fin.extend(s.run_to_completion());
                fin.sort_by_key(|f| f.id);
                let shared = fin.iter().map(|f| f.prefix_shared).collect();
                let toks = fin
                    .iter()
                    .map(|f| {
                        assert!(matches!(f.state, RequestState::Finished { .. }), "{spec}");
                        f.tokens.clone()
                    })
                    .collect();
                (toks, shared, s.prefix_stats().hits)
            };
            let (mono_toks, mono_shared, mono_hits) = run(0);
            assert!(mono_hits >= 3, "{spec}: later requests hit");
            for chunk in [2, 7, 64] {
                let (toks, shared, hits) = run(chunk);
                assert_eq!(toks, mono_toks, "{spec}: chunk={chunk} changed greedy tokens");
                assert_eq!(shared, mono_shared, "{spec}: chunk={chunk} changed sharing");
                assert_eq!(hits, mono_hits, "{spec}: chunk={chunk} changed hit counts");
            }
        }
    }

    /// The tentpole behavior: while a long prompt is mid-prefill,
    /// decode lanes keep producing a token every step — prompt
    /// ingestion no longer stalls the wave. Also pins the per-chunk
    /// progress surface: `Prefilling { consumed, total }` advances by
    /// at most the chunk quantum per step.
    #[test]
    fn chunked_prefill_interleaves_decode_with_a_long_prompt() {
        let cfg = ServeConfig { prefill_chunk: 8, ..tiny_cfg() };
        let mut s = ContinuousBatcher::new(cfg);
        // A short request first; one step makes it a live decode lane.
        let short = s
            .submit(ServeRequest::new(prompt(1, 5, 32)).max_new(40).engine("dense"))
            .unwrap();
        s.step();
        assert!(matches!(s.state(short), Some(RequestState::Decoding)));
        // The long prompt arrives: 120 tokens at chunk 8 = 15 steps.
        let long = s
            .submit(ServeRequest::new(prompt(2, 120, 32)).max_new(4).engine("dense"))
            .unwrap();
        let mut interleaved_steps = 0;
        let mut last_consumed = 0;
        while matches!(
            s.state(long),
            Some(RequestState::Queued) | Some(RequestState::Prefilling { .. })
        ) {
            let r = s.step();
            if let Some(RequestState::Prefilling { consumed, total }) = s.state(long) {
                assert_eq!(*total, 120);
                assert!(*consumed > last_consumed && *consumed - last_consumed <= 8);
                last_consumed = *consumed;
                assert!(r.prefill_tokens > 0);
                assert!(
                    r.decoded_tokens >= 1,
                    "the short lane decodes while the long one prefills"
                );
                interleaved_steps += 1;
            }
        }
        assert!(
            interleaved_steps >= 10,
            "a 120-token prompt at chunk 8 spends many steps mid-prefill \
             ({interleaved_steps} observed)"
        );
        let fin = s.run_to_completion();
        for id in [short, long] {
            let f = fin.iter().find(|f| f.id == id).unwrap();
            assert!(matches!(f.state, RequestState::Finished { .. }));
        }
    }

    /// Satellite regression: the wave scheduler's `take_finished`
    /// (via `SchedulerCore`) prunes terminal lifecycle entries just
    /// like the batcher's, so a long-running wave server's state map
    /// stays bounded by queued + live requests.
    #[test]
    fn wave_take_finished_prunes_terminal_lifecycle_entries() {
        let mut s = WaveScheduler::new(tiny_cfg());
        let id = s
            .submit(ServeRequest::new(prompt(1, 6, 32)).max_new(3).engine("dense"))
            .unwrap();
        while s.has_work() {
            s.step();
        }
        assert!(
            matches!(s.state(id), Some(RequestState::Finished { .. })),
            "terminal state visible until drained"
        );
        let fin = s.take_finished();
        assert_eq!(fin.len(), 1);
        assert!(
            s.state(id).is_none(),
            "take_finished prunes terminal lifecycle entries (bounded memory)"
        );
    }

    /// Router determinism pin: routing is a pure function of (request,
    /// replica states), so two identical runs produce the identical
    /// routing trace — and placement never changes content: replaying
    /// each replica's partition of the trace on a standalone batcher
    /// reproduces the router's token streams bit-for-bit.
    #[test]
    fn router_trace_is_deterministic_and_partition_replayable() {
        use crate::coordinator::router::{ReplicaRouter, RouterPolicy};
        let cfg = tiny_cfg();
        let reqs: Vec<ServeRequest> = (0..8)
            .map(|i| {
                let mut r = ServeRequest::new(prompt(40 + i, 6 + 3 * i as usize, 32))
                    .max_new(3 + (i as usize % 4))
                    .engine("sfa:k=4")
                    .seed(i);
                if i % 2 == 0 {
                    r = r.slo(SloClass::Interactive { ttft_s: 0.25, tpot_s: 0.05 });
                }
                r
            })
            .collect();
        let mut run = || {
            let mut router = ReplicaRouter::new(cfg, 2, RouterPolicy::SloAware).unwrap();
            for r in &reqs {
                router.submit(r.clone()).unwrap();
            }
            let fin = router.run_to_completion();
            (router.decisions().to_vec(), fin)
        };
        let (da, fa) = run();
        let (db, fb) = run();
        assert_eq!(da, db, "identical states must yield an identical routing trace");
        assert_eq!(fa.len(), 8);
        for (x, y) in fa.iter().zip(&fb) {
            assert_eq!((x.id, &x.tokens), (y.id, &y.tokens));
        }
        assert!(
            da.iter().any(|d| d.replica != da[0].replica),
            "load spreading must use both replicas"
        );
        // Replay: global ids are assigned in submission order, so
        // decision i refers to reqs[i]; each replica's partition run
        // alone must regenerate the router's streams exactly.
        for replica in 0..2 {
            let part: Vec<_> = da.iter().filter(|d| d.replica == replica).collect();
            let mut solo = ContinuousBatcher::new(cfg);
            let locals: Vec<RequestId> = part
                .iter()
                .map(|d| solo.submit(reqs[d.id as usize].clone()).unwrap())
                .collect();
            let fin = solo.run_to_completion();
            for (d, &lid) in part.iter().zip(&locals) {
                let routed = fa.iter().find(|f| f.id == d.id).unwrap();
                let alone = fin.iter().find(|f| f.id == lid).unwrap();
                assert_eq!(
                    alone.tokens, routed.tokens,
                    "placement moved latency, not content (replica {replica})"
                );
            }
        }
    }

    /// Preemption pin: under the global lane cap an interactive arrival
    /// preempts the newest batch lane (observable as `StepReport::
    /// preempted`), everything still finishes, and the preempted
    /// request's restart regenerates its exact solo token stream.
    #[test]
    fn preempted_batch_lane_streams_are_bit_for_bit_identical() {
        let spec = "sfa:k=4";
        let cfg = ServeConfig { max_lanes: 2, ..tiny_cfg() };
        let batch: Vec<Vec<i32>> = (0..2).map(|i| prompt(60 + i, 10, 32)).collect();
        let inter = prompt(70, 6, 32);

        let mut s = ContinuousBatcher::new(cfg);
        let b0 = s
            .submit(ServeRequest::new(batch[0].clone()).max_new(16).engine(spec))
            .unwrap();
        let b1 = s
            .submit(ServeRequest::new(batch[1].clone()).max_new(16).engine(spec))
            .unwrap();
        s.step();
        assert_eq!(s.live(), 2, "both batch lanes occupy the cap");
        let it = s
            .submit(
                ServeRequest::new(inter.clone())
                    .max_new(4)
                    .engine(spec)
                    .slo(SloClass::Interactive { ttft_s: 0.25, tpot_s: 0.05 }),
            )
            .unwrap();
        let mut preempted = 0;
        while s.has_work() {
            preempted += s.step().preempted;
        }
        assert!(preempted >= 1, "interactive pressure must preempt a batch lane");
        let fin = s.take_finished();
        assert_eq!(fin.len(), 3);
        for (id, p, m) in [(b0, &batch[0], 16), (b1, &batch[1], 16), (it, &inter, 4)] {
            let f = fin.iter().find(|f| f.id == id).unwrap();
            assert!(matches!(f.state, RequestState::Finished { .. }), "{:?}", f.state);
            assert_eq!(
                f.tokens,
                solo_tokens(p, m, spec),
                "preemption restart must not change the token stream"
            );
        }
    }

    /// Affinity pin: after one request warms a replica's radix cache,
    /// the SLO-aware router sends shared-prefix followers to that
    /// replica (positive `affinity` in the routing trace, prefix hits
    /// at admission), while an unrelated prompt — zero affinity
    /// everywhere — routes by load to the idle replica.
    #[test]
    fn router_routes_shared_prefixes_to_the_warm_replica() {
        use crate::coordinator::router::{ReplicaRouter, RouterPolicy};
        let cfg = ServeConfig {
            prefix_cache: Some(PrefixCacheConfig { max_pages: 128 }),
            ..tiny_cfg()
        };
        let sys = prompt(90, 80, 32); // long shared system prompt
        let mut router = ReplicaRouter::new(cfg, 2, RouterPolicy::SloAware).unwrap();

        // Warm: the first submission ties at zero everywhere and lands
        // on replica 0; finishing records its prompt path there.
        let mut warm = sys.clone();
        warm.extend([1, 2]);
        router.submit(ServeRequest::new(warm).max_new(2).engine("sfa:k=4")).unwrap();
        router.run_to_completion();
        assert_eq!(router.decisions()[0].replica, 0);
        assert_eq!(router.prefix_hits(), 0, "a cold cache has nothing to hit");

        // Followers share the system prompt; the unrelated prompt
        // shares nothing and should flee replica 0's queue depth.
        for i in 0..3 {
            let mut p = sys.clone();
            p.extend([10 + i, 3]);
            router.submit(ServeRequest::new(p).max_new(2).engine("sfa:k=4")).unwrap();
        }
        let mut other = prompt(99, 20, 32);
        other[0] = (sys[0] + 1) % 32; // guaranteed divergence at token 0
        router.submit(ServeRequest::new(other).max_new(2).engine("sfa:k=4")).unwrap();
        router.run_to_completion();

        let d = router.decisions();
        for dec in &d[1..4] {
            assert_eq!(dec.replica, 0, "shared prefix must chase the warm cache");
            let aff = dec.affinity;
            assert!(aff >= 40, "probe must see the cached system prompt (got {aff})");
        }
        assert_eq!(d[4].affinity, 0, "unrelated prompt has no cached prefix");
        assert_eq!(d[4].replica, 1, "no affinity → load routes to the idle replica");
        let hits = router.prefix_hits();
        assert!(hits >= 3, "each follower admission borrows the warm prefix (got {hits})");
    }

    /// Satellite pin (admission-time re-routing): a request that
    /// followed its warm prefix onto a replica, then got stuck in that
    /// replica's queue behind page pressure, is migrated by the
    /// router's rebalance pass to the current cost-model winner
    /// *before prefill starts* — visible in the routing trace as a
    /// second decision with `migrated: true` — and the migrated
    /// stream is bit-for-bit what a solo run produces.
    #[test]
    fn queued_request_on_pressured_replica_migrates_with_unchanged_stream() {
        use crate::coordinator::router::{ReplicaRouter, RouterPolicy};
        // 69 pages: the long-runner (22 reserved after its prefix hit)
        // plus the 24-page pinned prefix entry fit, but the follower's
        // worst-case 54-page reservation cannot join them.
        let cfg = ServeConfig {
            prefix_cache: Some(PrefixCacheConfig { max_pages: 128 }),
            max_pages: 69,
            ..tiny_cfg()
        };
        let sys = prompt(90, 48, 32);
        let mut router = ReplicaRouter::new(cfg, 2, RouterPolicy::SloAware).unwrap();
        // Warm replica 0 with the system prompt's path.
        router.submit(ServeRequest::new(sys.clone()).max_new(1).engine("dense")).unwrap();
        router.run_to_completion();
        // A long-running lane occupies replica 0 (affinity 40 beats the
        // idle replica's 0)...
        let long = sys[..40].to_vec();
        let f_id =
            router.submit(ServeRequest::new(long.clone()).max_new(40).engine("dense")).unwrap();
        router.step();
        assert_eq!(router.live(), 1, "long-runner admitted on the warm replica");
        // ...so the follower also chases the warm cache (affinity 48 −
        // one in-flight's load beats 0) and lands in replica 0's queue.
        let b_id =
            router.submit(ServeRequest::new(sys.clone()).max_new(60).engine("dense")).unwrap();
        let placed = *router.decisions().last().unwrap();
        assert_eq!((placed.id, placed.replica, placed.migrated), (b_id, 0, false));
        // Next step: the rebalance pass sees it still queued on a
        // page-pressured replica, re-scores it (its own queue slot now
        // counts against replica 0), and migrates it to replica 1.
        router.step();
        let mig: Vec<_> = router.decisions().iter().filter(|d| d.migrated).collect();
        assert_eq!(mig.len(), 1, "exactly one migration in the trace");
        assert_eq!((mig[0].id, mig[0].replica), (b_id, 1));
        assert_eq!(
            router.decisions().iter().filter(|d| d.id == b_id).count(),
            2,
            "a migrated request has both its placement and its migration in the trace"
        );
        let fin = router.run_to_completion();
        assert_eq!(fin.len(), 2);
        for (id, p, m) in [(f_id, &long, 40), (b_id, &sys, 60)] {
            let f = fin.iter().find(|f| f.id == id).unwrap();
            assert!(matches!(f.state, RequestState::Finished { .. }), "{:?}", f.state);
            assert_eq!(
                f.tokens,
                solo_tokens(p, m, "dense"),
                "migration re-places a stream without changing a token"
            );
        }
    }
}
