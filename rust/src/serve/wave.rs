//! [`WaveScheduler`] — the deprecated coordinator's wave semantics
//! re-expressed over the same lane substrate (and the same
//! [`SchedulerCore`] state) as
//! [`ContinuousBatcher`](crate::serve::scheduler::ContinuousBatcher),
//! so `bench serve` compares scheduling policies and nothing else.
//!
//! Wave semantics, faithfully mirrored from `Engine::run_wave`:
//!
//! * a wave admits up to `max_lanes` same-engine requests at once and
//!   **no one joins mid-wave** — later arrivals wait in the queue;
//! * every wave lane decodes every step until the *slowest* member
//!   finishes — finished members keep burning decode compute (the old
//!   padding slots) and **hold their pages until wave end**;
//! * responses (and page reclamation) are delivered at wave end.
//!
//! Everything a continuous batcher fixes is on display: page occupancy
//! stays at the wave's high-water mark, time-to-first-token includes
//! the whole previous wave, and the decode tail runs at low occupancy.

use std::time::Instant;

use crate::attention::registry::parse_spec;
use crate::attention::session::LaneId;
use crate::attention::HeadTensor;
use crate::coordinator::metrics::ServeMetrics;
use crate::serve::model::sample;
use crate::serve::request::{
    FinishedRequest, RequestId, RequestState, ServeError, ServeRequest,
};
use crate::serve::scheduler::{
    emit, finish_reason, finished_record, group_index, pages_needed, set_state, start_seq,
    QueuedReq, Scheduler, SchedulerCore, ServeConfig, StepReport,
};
use crate::serve::ServeEvent;

/// Wave-synchronous scheduling over the lane substrate (the baseline
/// `bench serve` measures the continuous batcher against).
pub struct WaveScheduler {
    core: SchedulerCore,
}

impl WaveScheduler {
    /// Panics on a degenerate config (see `ServeConfig::assert_valid`);
    /// CLI layers should range-check user input first. Every
    /// batcher-only knob (`kv_policy`, `prefix_cache`, `prefill_chunk`,
    /// `speculate`) is stripped through the one shared
    /// [`ServeConfig::strip_incompatible`]: the wave scheduler *is* the
    /// worst-case, cold-monolithic baseline the policy-budgeted,
    /// prefix-sharing, chunk-prefilling, speculating batcher is
    /// measured against — a knob that leaked through here would
    /// silently poison every baseline comparison.
    pub fn new(cfg: ServeConfig) -> WaveScheduler {
        WaveScheduler { core: SchedulerCore::new(cfg.strip_incompatible()) }
    }

    /// Checked constructor: validates the (pre-strip) config through
    /// [`ServeConfig::validate`] and returns the typed error instead of
    /// panicking — the CLI-facing path (pair with
    /// [`ServeConfig::builder`]).
    pub fn try_new(
        cfg: ServeConfig,
    ) -> Result<WaveScheduler, crate::serve::scheduler::ServeConfigError> {
        cfg.validate()?;
        Ok(WaveScheduler::new(cfg))
    }

    fn wave_active(&self) -> bool {
        self.core.groups.iter().any(|g| !g.active.is_empty())
    }

    /// Form the next wave from the queue front's engine spec: take
    /// same-spec requests in FIFO order until the lane cap or the
    /// wave's collective page reservation stops fitting, then prefill
    /// them all behind the barrier.
    fn form_wave(&mut self, report: &mut StepReport) {
        let front_spec = match self.core.queue.front() {
            Some(qr) => qr.req.engine.clone(),
            None => return,
        };
        let gi = match group_index(&mut self.core.groups, &front_spec, &self.core.cfg) {
            Ok(gi) => gi,
            Err(e) => {
                let qr = self.core.queue.pop_front().expect("front exists");
                self.core.fail_request(qr.id, &qr.req, e);
                report.failed += 1;
                return;
            }
        };
        let canon = self.core.groups[gi].spec.clone();
        let mut members: Vec<QueuedReq> = Vec::new();
        let mut rest: std::collections::VecDeque<QueuedReq> = std::collections::VecDeque::new();
        let mut wave_steps = 0usize;
        let mut spec_scan_open = true;
        while let Some(qr) = self.core.queue.pop_front() {
            let matches = parse_spec(&qr.req.engine)
                .map(|s| s.canonical() == canon)
                .unwrap_or(false);
            if spec_scan_open && matches && members.len() < self.core.cfg.max_lanes {
                let plen = qr.req.prompt.len();
                let budget = qr.req.max_new.min(self.core.cfg.max_seq - plen);
                let steps = wave_steps.max(budget);
                // Every lane decodes for the whole wave, so each
                // member's reservation is sized by the wave's slowest.
                let total: usize = members
                    .iter()
                    .chain(std::iter::once(&qr))
                    .map(|m| {
                        pages_needed(
                            m.req.prompt.len(),
                            steps,
                            self.core.cfg.heads,
                            self.core.cfg.page_size,
                        )
                    })
                    .sum();
                if total <= self.core.cfg.max_pages {
                    wave_steps = steps;
                    members.push(qr);
                    continue;
                }
                spec_scan_open = false; // FIFO within the spec
            }
            rest.push_back(qr);
        }
        self.core.queue = rest;

        for qr in members {
            let QueuedReq { id, req, submitted } = qr;
            set_state(
                &mut self.core.states,
                &req,
                id,
                RequestState::Prefilling { consumed: 0, total: req.prompt.len() },
            );
            let reserved = pages_needed(
                req.prompt.len(),
                wave_steps,
                self.core.cfg.heads,
                self.core.cfg.page_size,
            );
            let mut seq = match start_seq(
                &self.core.model,
                &mut self.core.groups[gi],
                id,
                req,
                submitted,
                &self.core.cfg,
                reserved,
                None,
            ) {
                Ok(seq) => seq,
                Err((req, e)) => {
                    self.core.fail_request(id, &req, e);
                    report.failed += 1;
                    continue;
                }
            };
            report.admitted += 1;
            report.decoded_tokens += 1;
            set_state(&mut self.core.states, &seq.req, id, RequestState::Decoding);
            emit(&seq.req, ServeEvent::Token { id, index: 0, token: seq.last_token });
            if let Some(reason) = finish_reason(&seq) {
                seq.done = Some(reason);
                set_state(
                    &mut self.core.states,
                    &seq.req,
                    id,
                    RequestState::Finished { reason },
                );
            }
            self.core.groups[gi].active.push(seq);
        }
    }

    /// One barrier decode step: every wave lane decodes, finished or
    /// not (the old padding slots), and nothing is freed.
    fn decode_wave(&mut self, report: &mut StepReport) {
        for gi in 0..self.core.groups.len() {
            if self.core.groups[gi].active.is_empty() {
                continue;
            }
            // Batch rows: every lane still below the context cap
            // (finished lanes included — that's the wave's burnt
            // compute).
            let rows: Vec<usize> = (0..self.core.groups[gi].active.len())
                .filter(|&i| {
                    let seq = &self.core.groups[gi].active[i];
                    self.core.groups[gi].session.lane_len(seq.lane) < self.core.cfg.max_seq
                })
                .collect();
            if !rows.is_empty() {
                let heads = self.core.cfg.heads;
                let d = self.core.cfg.d;
                let n = rows.len();
                let mut q = HeadTensor::zeros(n, heads, 1, d);
                let mut k = HeadTensor::zeros(n, heads, 1, d);
                let mut v = HeadTensor::zeros(n, heads, 1, d);
                let mut lanes: Vec<LaneId> = Vec::with_capacity(n);
                for (bi, &i) in rows.iter().enumerate() {
                    let seq = &self.core.groups[gi].active[i];
                    let pos = self.core.groups[gi].session.lane_len(seq.lane);
                    self.core
                        .model
                        .fill_decode_row(&mut q, &mut k, &mut v, bi, seq.last_token, pos);
                    lanes.push(seq.lane);
                }
                let out = self.core.groups[gi]
                    .session
                    .decode_step_lanes(&lanes, &q, &k, &v)
                    .expect("wave reservation covers every decode step");
                let now = Instant::now();
                for (bi, &i) in rows.iter().enumerate() {
                    let logits = self.core.model.logits_at(&out, bi, 0);
                    let seq = &mut self.core.groups[gi].active[i];
                    let tok = sample(&logits, seq.req.sampling, &mut seq.rng);
                    seq.last_token = tok;
                    if seq.done.is_some() {
                        continue; // burnt compute, discarded sample
                    }
                    seq.generated.push(tok);
                    emit(
                        &seq.req,
                        ServeEvent::Token {
                            id: seq.id,
                            index: seq.generated.len() - 1,
                            token: tok,
                        },
                    );
                    self.core.metrics.record_token_latency(
                        now.duration_since(seq.last_token_at).as_secs_f64(),
                    );
                    seq.last_token_at = now;
                    report.decoded_tokens += 1;
                    if let Some(reason) = finish_reason(seq) {
                        seq.done = Some(reason);
                        let (id, req) = (seq.id, seq.req.clone());
                        set_state(
                            &mut self.core.states,
                            &req,
                            id,
                            RequestState::Finished { reason },
                        );
                    }
                }
            }
        }
    }

    /// Wave barrier: once *every* member is done, deliver responses
    /// and free every lane's pages — not a step earlier.
    fn finalize_finished_waves(&mut self, report: &mut StepReport) {
        for group in &mut self.core.groups {
            if group.active.is_empty() || group.active.iter().any(|s| s.done.is_none()) {
                continue;
            }
            let wave = std::mem::take(&mut group.active);
            for seq in wave {
                let freed = group.session.release_lane(seq.lane).unwrap_or(0);
                group.return_reservation(&seq);
                report.pages_freed += freed;
                report.finished += 1;
                let reason = seq.done.expect("wave member is done");
                self.core.metrics.record_finished(
                    seq.ttft_s,
                    seq.submitted.elapsed().as_secs_f64(),
                    seq.generated.len(),
                );
                self.core.finished.push(finished_record(
                    &seq,
                    &group.spec,
                    RequestState::Finished { reason },
                ));
            }
        }
    }
}

impl Scheduler for WaveScheduler {
    fn submit(&mut self, req: ServeRequest) -> Result<RequestId, ServeError> {
        self.core.submit(req)
    }

    fn step(&mut self) -> StepReport {
        let mut report = StepReport::default();
        if self.wave_active() {
            self.decode_wave(&mut report);
        } else {
            self.form_wave(&mut report);
        }
        self.finalize_finished_waves(&mut report);
        report.pages_in_use = self.core.pages_in_use();
        // Wave strips `kv_tier` (no demotion path), so every page is
        // hot: units are exactly twice the page count.
        report.kv_units_in_use = 2 * report.pages_in_use;
        report.live = self
            .core
            .groups
            .iter()
            .map(|g| g.active.iter().filter(|s| s.done.is_none()).count())
            .sum();
        report
    }

    fn has_work(&self) -> bool {
        !self.core.queue.is_empty() || self.wave_active()
    }

    fn state(&self, id: RequestId) -> Option<&RequestState> {
        self.core.state(id)
    }

    fn take_finished(&mut self) -> Vec<FinishedRequest> {
        self.core.take_finished()
    }

    fn metrics(&self) -> &ServeMetrics {
        &self.core.metrics
    }

    fn metrics_mut(&mut self) -> &mut ServeMetrics {
        &mut self.core.metrics
    }

    fn pages_in_use(&self) -> usize {
        self.core.pages_in_use()
    }
}
