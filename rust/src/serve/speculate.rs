//! Speculative decoding — draft-and-verify over the fork machinery.
//!
//! A cheap **draft** engine from the registry (an aggressively small-k
//! SFA spec, a window engine, …) proposes γ tokens by greedy argmax on
//! its own lane; the **target** engine then scores all γ+1 positions in
//! one multi-position verify forward
//! ([`AttentionSession::score_lanes`](crate::attention::AttentionSession::score_lanes))
//! on a `fork_prefix`-forked lane, and the acceptance rule below keeps
//! the agreed prefix. Rollback is `release_lane` on the fork, so paged
//! accounting, the radix prefix cache, and page-budget admission
//! survive speculation unchanged. `serve::ContinuousBatcher` drives
//! the lifecycle; this module owns the config and the acceptance rule.
//!
//! ## The acceptance rule: exact-match, stream-preserving
//!
//! Classic speculative sampling accepts draft token x with probability
//! `min(1, p_target(x) / p_draft(x))` — distribution-preserving, but
//! it consumes a *different* rng draw sequence than plain decoding, so
//! a request's token stream would change the moment speculation turns
//! on. This repo's serving invariant is stronger than
//! distribution-equality: **streams are bit-for-bit identical with
//! speculation on or off**, for greedy *and* temperature sampling.
//!
//! So [`verify_emit`] instead replays exactly what non-speculative
//! decoding would do: walk the verified positions in order, call the
//! one true [`sample`] per position (greedy consumes zero rng draws,
//! temperature exactly one — the same draws, in the same order, as
//! sequential decoding), and keep going while the sampled token equals
//! the draft's next candidate. The first disagreement (or the bonus
//! position after a fully accepted draft) ends the step. Accepted
//! positions are "free" target-quality tokens; the draft only ever
//! decides how far ahead the target got to look, never what is
//! emitted.

use crate::attention::registry::{parse_spec, EngineSpec, SpecError};
use crate::serve::model::sample;
use crate::serve::request::ServeSampling;
use crate::util::rng::Rng;

/// Speculative-decoding knobs carried by
/// [`ServeConfig`](crate::serve::ServeConfig).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeculateConfig {
    /// The draft engine (registry spec, e.g. `sfa:k=2` or
    /// `window:w=64`) — one draft session per engine group, shared by
    /// every lane in the group.
    pub draft: EngineSpec,
    /// Draft tokens proposed per speculative step (γ ≥ 1).
    pub gamma: usize,
}

impl SpeculateConfig {
    /// Parse the CLI surface: a draft spec (with or without the
    /// `draft=` prefix `--speculate draft=<spec>` passes through) plus
    /// γ. The draft's compatibility with a *target* spec is checked
    /// per-request at admission
    /// ([`validate_draft_spec`](crate::attention::registry::validate_draft_spec))
    /// — targets are a request property, not a config property.
    pub fn parse(draft: &str, gamma: usize) -> Result<SpeculateConfig, SpecError> {
        let raw = draft.trim();
        // `--speculate draft=<spec>` passes the `draft=` atom through;
        // the shared grammar's kv splitter peels it off (anything else
        // containing `=` is the spec's own parameter list).
        let raw = match crate::util::spec::split_kv(raw) {
            Some(("draft", v)) => v,
            _ => raw,
        };
        if gamma == 0 {
            return Err(SpecError("speculate: gamma must be >= 1".into()));
        }
        Ok(SpeculateConfig { draft: parse_spec(raw)?, gamma })
    }
}

/// Walk one verify step's logits and emit the step's tokens under the
/// exact-match acceptance rule (module docs).
///
/// `candidates` are the draft's proposals for positions `1..`;
/// `logits[j]` is the target's distribution at verified position `j`
/// (`logits.len() == candidates.len() + 1` — the extra row is the
/// bonus position after a fully accepted draft). Emission `j` draws
/// through the one true [`sample`] on `rng`, so the rng stream
/// advances exactly as sequential decoding would for the same emitted
/// tokens — the batch-composition / step-boundary invariance the
/// property test pins.
///
/// Returns the emitted tokens (1 ..= γ+1 of them). The number of
/// *accepted* draft candidates is always `emitted.len() - 1`: a
/// mismatch at position `j` emits `j` accepted tokens plus the
/// target's correction, and a full accept emits all γ plus the bonus.
pub fn verify_emit(
    candidates: &[i32],
    logits: &[Vec<f32>],
    sampling: ServeSampling,
    rng: &mut Rng,
) -> Vec<i32> {
    assert_eq!(
        logits.len(),
        candidates.len() + 1,
        "one logits row per draft candidate plus the bonus position"
    );
    let mut emitted = Vec::with_capacity(logits.len());
    for (j, row) in logits.iter().enumerate() {
        let tok = sample(row, sampling, rng);
        emitted.push(tok);
        if j == candidates.len() || tok != candidates[j] {
            break;
        }
    }
    emitted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    /// One-hot-ish logits that make `sample` (greedy or any
    /// temperature) pick `tok` with near-certainty.
    fn peaked(vocab: usize, tok: i32) -> Vec<f32> {
        let mut l = vec![-50.0; vocab];
        l[tok as usize] = 50.0;
        l
    }

    #[test]
    fn parse_accepts_prefix_and_rejects_zero_gamma() {
        let a = SpeculateConfig::parse("sfa:k=2", 4).unwrap();
        let b = SpeculateConfig::parse("draft=sfa:k=2", 4).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.gamma, 4);
        assert_eq!(a.draft, parse_spec("sfa:k=2").unwrap());
        assert!(SpeculateConfig::parse("sfa:k=2", 0).unwrap_err().0.contains("gamma"));
        assert!(SpeculateConfig::parse("warp", 4).is_err());
    }

    #[test]
    fn full_accept_mismatch_and_empty_draft() {
        let mut rng = Rng::new(1);
        // All candidates agree: γ accepted + the bonus emission.
        let logits: Vec<Vec<f32>> =
            [3, 5, 7, 2].iter().map(|&t| peaked(16, t)).collect();
        let out = verify_emit(&[3, 5, 7], &logits, ServeSampling::Greedy, &mut rng);
        assert_eq!(out, vec![3, 5, 7, 2]);
        // Mismatch at position 1: one accepted token + the correction.
        let out = verify_emit(&[3, 9, 7], &logits, ServeSampling::Greedy, &mut rng);
        assert_eq!(out, vec![3, 5]);
        // Immediate mismatch: just the correction.
        let out = verify_emit(&[8, 5, 7], &logits, ServeSampling::Greedy, &mut rng);
        assert_eq!(out, vec![3]);
        // γ_eff == 0 (budget tail): plain single-token decode.
        let out = verify_emit(&[], &logits[..1], ServeSampling::Greedy, &mut rng);
        assert_eq!(out, vec![3]);
        // accepted == emitted.len() - 1 in every case above.
    }

    /// Satellite property pin: the sampler stream is invariant to step
    /// boundaries. One `verify_emit` call over γ positions must
    /// produce the same emissions *and* leave the rng in the same
    /// state as sampling the same logits rows one token at a time —
    /// i.e. the accept/reject coin flips are identical whether γ
    /// tokens arrive in one verify step or one per step, and whatever
    /// the batch around them looks like (the rng is per-request, so
    /// batch composition can't touch it by construction).
    #[test]
    fn verify_stream_matches_one_token_at_a_time_sampling() {
        check("speculative rng stream invariance", 64, |g| {
            let vocab = 8 + g.usize_in(0..9);
            let gamma = g.usize_in(1..6);
            let temp = 0.3 + g.f32_in(0.0..1.5);
            let seed = g.usize_in(0..1 << 30) as u64;
            // Random (sometimes flat, sometimes peaked) logits rows and
            // random candidates — mismatches land at random depths.
            let logits: Vec<Vec<f32>> = (0..gamma + 1)
                .map(|_| (0..vocab).map(|_| g.f32_in(-4.0..4.0)).collect())
                .collect();
            let candidates: Vec<i32> =
                (0..gamma).map(|_| g.usize_in(0..vocab) as i32).collect();
            for sampling in [ServeSampling::Greedy, ServeSampling::Temperature(temp)] {
                let mut r_spec = Rng::new(seed);
                let emitted = verify_emit(&candidates, &logits, sampling, &mut r_spec);

                // Sequential reference: sample position j only after
                // positions 0..j emitted and matched the draft — the
                // call sequence plain decoding makes for this stream.
                let mut r_seq = Rng::new(seed);
                let mut expect = Vec::new();
                for (j, row) in logits.iter().enumerate() {
                    let tok = sample(row, sampling, &mut r_seq);
                    expect.push(tok);
                    if j == candidates.len() || tok != candidates[j] {
                        break;
                    }
                }
                assert_eq!(emitted, expect, "emissions differ ({sampling:?})");
                assert!(!emitted.is_empty() && emitted.len() <= gamma + 1);
                // Same rng state afterwards: the next draws agree.
                for _ in 0..4 {
                    assert_eq!(
                        r_spec.next_f64().to_bits(),
                        r_seq.next_f64().to_bits(),
                        "rng stream diverged after the step ({sampling:?})"
                    );
                }
            }
        });
    }
}
