//! [`ToyLm`] — a deterministic, artifact-free token→token model for
//! the serve stack: hashed Q/K/V embeddings per (token, position,
//! head) plus a fixed output projection over the attention output.
//!
//! This is *not* a trained model — it exists so the scheduler, the
//! page-budget admission policy, and the continuous-vs-wave benches
//! can run a realistic prefill/decode workload with zero setup. The
//! load-bearing property is **bit-for-bit determinism independent of
//! batch composition**: a sequence's Q/K/V rows depend only on its own
//! (token, position) history, and each lane's attention is scored
//! per-(lane, head) in isolation, so a prompt decoded greedily inside
//! a busy continuous batch reproduces its solo run exactly — the
//! equivalence the serve tests pin.

use crate::attention::HeadTensor;
use crate::serve::request::ServeSampling;
use crate::util::rng::{splitmix64, Rng};

/// Map one hash to a uniform f32 in [-1, 1).
#[inline]
fn unit(h: u64) -> f32 {
    (h >> 40) as f32 / (1u64 << 24) as f32 * 2.0 - 1.0
}

/// The deterministic toy decoder-only LM.
pub struct ToyLm {
    pub heads: usize,
    /// Q/K/V dim per head (`d_v == d`).
    pub d: usize,
    pub vocab: usize,
    seed: u64,
    /// Output projection, `[heads * d, vocab]` row-major.
    w_out: Vec<f32>,
}

impl ToyLm {
    pub fn new(heads: usize, d: usize, vocab: usize, seed: u64) -> ToyLm {
        assert!(heads >= 1 && d >= 1 && vocab >= 2);
        let mut rng = Rng::new(seed ^ 0x7A11_E57);
        let scale = 1.0 / ((heads * d) as f32).sqrt();
        let w_out = rng.normal_vec(heads * d * vocab, scale);
        ToyLm { heads, d, vocab, seed, w_out }
    }

    /// Fill one head's `d`-dim embedding row for `(role, token, pos)`.
    /// Roles 1/2/3 are Q/K/V; the stream is a pure function of the
    /// arguments, so identical histories give identical rows.
    fn fill_row(&self, role: u64, token: i32, pos: usize, h: usize, out: &mut [f32]) {
        let mut s = self
            .seed
            .wrapping_add(role.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            ^ (token as u32 as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)
            ^ (pos as u64).wrapping_mul(0x2545_F491_4F6C_DD1D)
            ^ ((h as u64 + 1) << 32);
        for x in out.iter_mut() {
            *x = unit(splitmix64(&mut s));
        }
    }

    /// Q/K/V for `tokens[i]` at absolute position `start_pos + i`, as
    /// `[1, heads, n, d]` tensors (one lane's prefill input).
    pub fn qkv_prompt(
        &self,
        tokens: &[i32],
        start_pos: usize,
    ) -> (HeadTensor, HeadTensor, HeadTensor) {
        let n = tokens.len();
        let mut q = HeadTensor::zeros(1, self.heads, n, self.d);
        let mut k = HeadTensor::zeros(1, self.heads, n, self.d);
        let mut v = HeadTensor::zeros(1, self.heads, n, self.d);
        for h in 0..self.heads {
            for (t, &tok) in tokens.iter().enumerate() {
                let pos = start_pos + t;
                self.fill_row(1, tok, pos, h, q.head_row_mut(0, h, t));
                self.fill_row(2, tok, pos, h, k.head_row_mut(0, h, t));
                self.fill_row(3, tok, pos, h, v.head_row_mut(0, h, t));
            }
        }
        (q, k, v)
    }

    /// Write one token's Q/K/V rows into batch row `b` of decode-step
    /// tensors (`n == 1`) — the scheduler's batch-forming path.
    pub fn fill_decode_row(
        &self,
        q: &mut HeadTensor,
        k: &mut HeadTensor,
        v: &mut HeadTensor,
        b: usize,
        token: i32,
        pos: usize,
    ) {
        for h in 0..self.heads {
            self.fill_row(1, token, pos, h, q.head_row_mut(b, h, 0));
            self.fill_row(2, token, pos, h, k.head_row_mut(b, h, 0));
            self.fill_row(3, token, pos, h, v.head_row_mut(b, h, 0));
        }
    }

    /// Project row `t` of batch slot `b` of an attention output
    /// (`[batch, heads, n, d]`) to vocab logits. Accumulation order is
    /// fixed, so logits are bit-for-bit reproducible.
    pub fn logits_at(&self, out: &HeadTensor, b: usize, t: usize) -> Vec<f32> {
        assert_eq!((out.heads, out.d), (self.heads, self.d), "output/head grid");
        let mut logits = vec![0.0f32; self.vocab];
        let mut feat = 0;
        for h in 0..self.heads {
            for &x in out.head_row(b, h, t) {
                let row = &self.w_out[feat * self.vocab..(feat + 1) * self.vocab];
                for (lg, &w) in logits.iter_mut().zip(row) {
                    *lg += x * w;
                }
                feat += 1;
            }
        }
        logits
    }
}

/// Select the next token. Greedy is pure argmax (first max wins);
/// temperature sampling draws from the per-request `rng` so the
/// sequence of draws is independent of batch composition.
pub fn sample(logits: &[f32], sampling: ServeSampling, rng: &mut Rng) -> i32 {
    match sampling {
        ServeSampling::Greedy => {
            let mut best = 0;
            let mut bv = f32::NEG_INFINITY;
            for (i, &x) in logits.iter().enumerate() {
                if x > bv {
                    bv = x;
                    best = i;
                }
            }
            best as i32
        }
        ServeSampling::Temperature(t) => {
            let inv = 1.0 / t.max(1e-4);
            let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let weights: Vec<f64> =
                logits.iter().map(|&x| (((x - m) * inv) as f64).exp()).collect();
            let total: f64 = weights.iter().sum();
            let mut u = rng.next_f64() * total;
            for (i, w) in weights.iter().enumerate() {
                u -= w;
                if u <= 0.0 {
                    return i as i32;
                }
            }
            (weights.len() - 1) as i32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embeddings_are_deterministic_and_distinct() {
        let a = ToyLm::new(2, 8, 16, 7);
        let b = ToyLm::new(2, 8, 16, 7);
        let (qa, ka, va) = a.qkv_prompt(&[3, 5], 0);
        let (qb, kb, vb) = b.qkv_prompt(&[3, 5], 0);
        assert_eq!(qa.data, qb.data);
        assert_eq!(ka.data, kb.data);
        assert_eq!(va.data, vb.data);
        // Q/K/V roles differ, tokens differ, positions differ.
        assert_ne!(qa.head_row(0, 0, 0), ka.head_row(0, 0, 0));
        assert_ne!(qa.head_row(0, 0, 0), qa.head_row(0, 0, 1));
        let (q2, _, _) = a.qkv_prompt(&[4], 0);
        assert_ne!(qa.head_row(0, 0, 0), q2.head_row(0, 0, 0));
        // Same token at a shifted position embeds differently.
        let (q3, _, _) = a.qkv_prompt(&[3], 1);
        assert_ne!(qa.head_row(0, 0, 0), q3.head_row(0, 0, 0));
        assert!(qa.data.iter().all(|x| (-1.0..1.0).contains(x)));
    }

    #[test]
    fn decode_row_matches_prompt_row() {
        let lm = ToyLm::new(3, 4, 16, 1);
        let (qp, kp, vp) = lm.qkv_prompt(&[9, 2], 5);
        let mut q = HeadTensor::zeros(2, 3, 1, 4);
        let mut k = HeadTensor::zeros(2, 3, 1, 4);
        let mut v = HeadTensor::zeros(2, 3, 1, 4);
        lm.fill_decode_row(&mut q, &mut k, &mut v, 0, 9, 5);
        lm.fill_decode_row(&mut q, &mut k, &mut v, 1, 2, 6);
        for h in 0..3 {
            assert_eq!(q.head_row(0, h, 0), qp.head_row(0, h, 0));
            assert_eq!(k.head_row(1, h, 0), kp.head_row(0, h, 1));
            assert_eq!(v.head_row(1, h, 0), vp.head_row(0, h, 1));
        }
    }

    #[test]
    fn logits_and_sampling() {
        let lm = ToyLm::new(2, 4, 8, 3);
        let mut out = HeadTensor::zeros(1, 2, 1, 4);
        out.data.iter_mut().enumerate().for_each(|(i, x)| *x = (i as f32 + 1.0) * 0.1);
        let l1 = lm.logits_at(&out, 0, 0);
        let l2 = lm.logits_at(&out, 0, 0);
        assert_eq!(l1, l2, "logits are deterministic");
        assert_eq!(l1.len(), 8);

        let mut rng = Rng::new(0);
        let g = sample(&l1, ServeSampling::Greedy, &mut rng);
        let best = l1
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(g, best as i32);
        // Temperature draws stay in range and reproduce under the same
        // rng stream.
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        for _ in 0..32 {
            let a = sample(&l1, ServeSampling::Temperature(0.8), &mut r1);
            let b = sample(&l1, ServeSampling::Temperature(0.8), &mut r2);
            assert_eq!(a, b);
            assert!((0..8).contains(&a));
        }
    }
}
